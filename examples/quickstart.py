"""Quickstart: tune the tail latency of a distributed graph workload.

Builds a social graph, samples an interactive short-read workload, and
walks the latency/replication trade-off of the paper (Fig 1/6): for each
latency bound t, the greedy replication algorithm produces a scheme, and
the simulated cluster reports latency percentiles + storage overhead.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import is_latency_feasible, replicate_workload
from repro.distsys import Cluster, LatencyModel, execute_workload
from repro.graph import hash_partition, snb_like
from repro.workload import snb_workload_materialized

N_SERVERS = 6

print("== latency-bound replication quickstart ==")
snb = snb_like(scale=1, seed=0)
graph = snb.graph
print(f"graph: {graph.n_nodes:,} vertices, {graph.n_edges:,} edges")

workload = snb_workload_materialized(snb, n_queries=1500, seed=0)
print(f"workload: {workload.n_queries:,} queries -> "
      f"{workload.n_paths:,} causal access paths")

shard = hash_partition(graph.n_nodes, N_SERVERS)
sizes = graph.object_sizes()

print(f"\n{'t':>4} {'feasible':>8} {'overhead':>9} {'mean_us':>8} "
      f"{'p99_us':>8} {'replicas':>9}")
for t in [0, 1, 2, 3]:
    scheme, stats = replicate_workload(
        workload, shard, N_SERVERS, t=t, f=sizes.astype(np.float32))
    ok = is_latency_feasible(workload, scheme, t)
    report = execute_workload(Cluster(scheme, f=sizes), workload,
                              LatencyModel(), seed=0)
    s = report.summary()
    print(f"{t:>4} {str(ok):>8} {scheme.replication_overhead(sizes):>9.3f} "
          f"{s['mean_us']:>8.1f} {s['p99_us']:>8.1f} "
          f"{stats.replicas:>9,}")

print("\nReading the table: tightening t cuts latency but multiplies "
      "storage;\nthe sweet spot (paper §6) is where overhead flattens "
      "while latency stays bounded.")
