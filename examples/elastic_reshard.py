"""Elastic scaling drill: lose half the devices mid-training, continue
bit-exact; and patch the serving tier's replication scheme (§5.4).

Run:  PYTHONPATH=src python examples/elastic_reshard.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import (
    ReshardingMap,
    is_latency_feasible,
    repair_paths,
    replicate_workload,
)
from repro.core.reshard import drain_server
from repro.graph import hash_partition, snb_like
from repro.launch.elastic import elastic_drill
from repro.models.transformer import TransformerConfig
from repro.workload import snb_workload_materialized

print("== 1) tensor-program elasticity: scale-in mid-training ==")
cfg = TransformerConfig(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                        d_ff=128, vocab=512, dtype=jnp.float32, remat=False)
out = elastic_drill(cfg, steps_before=3, steps_after=3)
print(f"losses before fail : {[round(l, 4) for l in out['losses_before']]}")
print(f"losses after scale-in: {[round(l, 4) for l in out['losses_after']]}")
print(f"reference (no fail): {[round(l, 4) for l in out['reference']]}")
print(f"bit-exact continuation: {out['bit_exact']}")
assert out["bit_exact"]

print("\n== 2) replication-scheme elasticity: server loss (§5.4) ==")
snb = snb_like(1, seed=0)
ps = snb_workload_materialized(snb, n_queries=800, seed=0)
shard = hash_partition(snb.graph.n_nodes, 6)
t = 1
scheme, stats = replicate_workload(ps, shard, 6, t=t, track_rm=True)
rmap = ReshardingMap.from_entries(stats.rm, scheme.shard)
print(f"initial: feasible={is_latency_feasible(ps, scheme, t)}, "
      f"replicas={scheme.replica_count():,}")
moves, rep = drain_server(scheme, rmap, 5, strategy="single")
stats2 = repair_paths(scheme, rmap, ps, t)
print(f"drained server 5: moved {rep.moved_originals:,} originals, "
      f"transferred {rep.replicas_transferred:,} replicas, repaired "
      f"{stats2['repaired_paths']} paths")
print(f"post-drain feasible: {is_latency_feasible(ps, scheme, t)}")
assert is_latency_feasible(ps, scheme, t)
print("\nelastic drills OK")
