"""Serve a replicated graph store under live traffic with an adaptive SLO loop.

The full online story on top of the paper's offline algorithm:

  1. replicate the observed workload for an SLO of t distributed traversals,
  2. serve Poisson traffic through the discrete-event simulator
     (per-server FIFO queues, hop sequences from the engine's access trace),
  3. let the workload DRIFT (the root hotspot moves),
  4. watch the adaptive controller detect the p99/feasibility violation and
     repair the scheme *incrementally* (warm-started greedy against the
     device-resident packed scheme — no rebuild),
  5. keep serving: the drifted phase is back inside the SLO.

Run:  PYTHONPATH=src python examples/serve_replicated.py
"""
import numpy as np

from repro.core import is_latency_feasible, replicate_workload
from repro.distsys import Cluster, LatencyModel
from repro.graph import make_sharding, snb_like
from repro.serve import (
    AdaptiveController,
    ControllerConfig,
    drift_stream,
    simulate,
    snb_drift,
)

T, N_SERVERS, RATE_QPS = 1, 6, 20_000

print(f"== online serving with latency SLO t={T} ({N_SERVERS} servers, "
      f"{RATE_QPS:,} qps offered) ==")
snb = snb_like(1, seed=0)
f = snb.graph.object_sizes().astype(np.float32)
shard = make_sharding("hash", snb.graph, N_SERVERS, seed=0)
phases = snb_drift(snb, n_phases=3, queries_per_phase=600, seed=0)

scheme, stats, engine = replicate_workload(
    phases[0].pathset, shard, N_SERVERS, t=T, f=f, return_engine=True)
cluster = Cluster(scheme, f=f)
controller = AdaptiveController(
    cluster, ControllerConfig(t=T, window=400, min_queries=100),
    f=f, engine=engine)

model = LatencyModel()
for delta in drift_stream(phases):
    rep = simulate(cluster, delta.pathset, rate_qps=RATE_QPS, model=model,
                   seed=delta.phase)
    act = controller.observe(delta.pathset, latency_us=rep.latency_us)
    feas = is_latency_feasible(delta.pathset, cluster.scheme, T)
    line = (f"phase {delta.phase}: +{delta.added.n_paths} new paths | "
            f"p50 {rep.p50_us:5.0f}us p99 {rep.p99_us:5.0f}us | "
            f"util {rep.utilization().max():.2f}")
    if act is not None:
        line += (f" | ADAPTED: {act.replicas_added} replicas "
                 f"({act.bytes_added:.0f} bytes) in {act.runtime_s:.2f}s")
    print(line + f" | feasible={feas}")
    assert feas, "controller failed to restore the latency bound"

print(f"\nreplication overhead now: "
      f"{cluster.scheme.replication_overhead(f):.3f}x original data")
print("online serving + drift adaptation OK")
