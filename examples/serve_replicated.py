"""Serve a replicated graph store with a latency SLO + survive a failure.

The paper's end-to-end story: pick an SLO (t distributed traversals),
replicate to meet it, serve batched requests, lose a server, patch the
scheme incrementally (§5.4), keep serving within the SLO.

Run:  PYTHONPATH=src python examples/serve_replicated.py
"""
from repro.launch.serve import serve

print("== serving with latency SLO t=1 (hash sharding, 6 servers) ==")
rep = serve(t=1, n_servers=6, n_queries=2000, sharding="hash",
            fail_server=4, hedge=True)
print(f"feasible pre-fault : {rep.feasible}")
print(f"replication overhead: {rep.overhead:.3f}x original data")
print(f"mean latency        : {rep.mean_us:.0f} us")
print(f"p99 latency         : {rep.p99_us:.0f} us")
print(f"throughput          : {rep.qps:,.0f} qps")
print(f"feasible post-fault : {rep.post_fault_feasible} "
      f"(server 4 drained via the §5.4 incremental update)")
assert rep.feasible and rep.post_fault_feasible
print("\nserving + fault drill OK")
