"""Trace a tail-latency violation down to the hop and server that caused it.

The observability walkthrough on top of the serving simulator:

  1. replicate the phase-0 workload, then serve the *drifted* phase with a
     hop-level span ``Tracer`` attached — every access records which
     server served it and how the time split between FIFO queue wait and
     service,
  2. set the trace budget to the run's p99: the ~1% of queries above it
     are *violators*, and tail-biased sampling keeps every one of them,
  3. print the worst query's hop-by-hop walk (the p99 is no longer an
     opaque scalar — it is THIS query waiting THIS long on THIS server),
  4. fold all violators into a burn-rate blame table
     (``attribute_burn``): which server consumed the violators' budgets,
  5. export a Chrome ``trace_event`` JSON — load it in chrome://tracing
     or https://ui.perfetto.dev and the hotspot server is a dense lane.

Run:  PYTHONPATH=src python examples/trace_tail.py
"""
import numpy as np

from repro.core import replicate_workload
from repro.distsys import Cluster, LatencyModel
from repro.graph import make_sharding, snb_like
from repro.obs import Tracer, attribute_burn
from repro.serve import simulate, snb_drift

T, N_SERVERS, RATE_QPS = 1, 6, 60_000

print(f"== tracing the serving tail (t={T}, {N_SERVERS} servers, "
      f"{RATE_QPS:,} qps offered) ==")
snb = snb_like(1, seed=0)
f = snb.graph.object_sizes().astype(np.float32)
shard = make_sharding("hash", snb.graph, N_SERVERS, seed=0)
phases = snb_drift(snb, n_phases=3, queries_per_phase=800, seed=0)

scheme, _ = replicate_workload(phases[0].pathset, shard, N_SERVERS, t=T, f=f)
cluster = Cluster(scheme, f=f)
model = LatencyModel()
drifted = phases[-1].pathset

# pass 1 (untraced) just to learn the run's p99 -> the violation budget
rep = simulate(cluster, drifted, rate_qps=RATE_QPS, model=model, seed=11)
p99 = float(np.percentile(rep.latency_us, 99.0))
print(f"\nserved {drifted.n_queries} queries: p50 {rep.p50_us:.0f}us, "
      f"p99 {p99:.0f}us")

# pass 2: identical run (same seed), now with spans
tracer = Tracer(budget_us=p99)
rep = simulate(
    cluster, drifted, rate_qps=RATE_QPS, model=model, seed=11, trace=tracer
)
print(f"spans recorded: {tracer.n_spans}; violators kept: "
      f"{tracer.n_violations} (tail-biased: never sampled away)")

# -- the worst query, hop by hop -------------------------------------------
worst = tracer.worst(1)[0]
print(f"\nworst query #{worst.query}: latency {worst.latency_us:.0f}us "
      f"vs budget {worst.budget_us:.0f}us")
for s in worst.spans:
    print(f"  hop {s.hop}: object {s.obj} on server {s.server} ({s.why}) "
          f"queue {s.queue_wait_us:7.1f}us  service {s.service_us:6.1f}us")
blamed = worst.worst_hop()
print(f"  -> budget went to hop {blamed.hop} on server {blamed.server} "
      f"({blamed.queue_wait_us:.0f}us of queue wait)")

# -- all violators folded into per-server blame ----------------------------
burn = attribute_burn(tracer, allowed_frac=0.01)
tb = burn["default"]
print(f"\nburn rate {tb.burn_rate:.1f}x allowed "
      f"({tb.n_violations}/{tb.n_queries} queries over budget)")
print("per-server blame (violators' worst hops + queue-wait blame):")
for srv in sorted(
    tb.blame_queue_us,
    key=lambda s: (tb.blamed_counts.get(s, 0), tb.blame_queue_us[s]),
    reverse=True,
):
    n = tb.blamed_counts.get(srv, 0)
    print(f"  server {srv}: worst hop of {n} violator(s), "
          f"{tb.blame_queue_us[srv]:9.0f}us queue blame")
print(f"=> server {tb.top_server()} ate the tail")

out = "trace_tail.json"
tracer.chrome_trace(out)
print(f"\nwrote {out} — open in chrome://tracing or ui.perfetto.dev")
