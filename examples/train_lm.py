"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

Exercises the full training substrate on the host mesh: sharded params
(FSDP x TP), AdamW + cosine schedule, deterministic prefetched data,
async checkpointing, and a mid-run restore drill proving restart-exact
recovery (the fault-tolerance path a multi-pod job relies on).

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
(defaults to 60 steps so the example finishes in a few minutes on CPU;
pass --steps 300 for the full run)
"""
import argparse
import tempfile

import jax.numpy as jnp

from repro.launch.train import train_lm
from repro.models.transformer import TransformerConfig

# ~103M params: 12 layers x d512 x ff2048, vocab 32768
CFG_100M = TransformerConfig(
    name="lm-100m", n_layers=12, d_model=512, n_heads=8, n_kv_heads=4,
    d_ff=2048, vocab=32768, dtype=jnp.float32, remat=False,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    import repro.configs.base as cb
    from repro.configs.lm_family import make_bundle

    # register the example config as a proper arch bundle
    smoke = CFG_100M
    if "lm-100m" not in cb._REGISTRY:
        cb._REGISTRY["lm-100m"] = lambda: make_bundle(
            "lm-100m", CFG_100M, smoke, skip_long=True)

    with tempfile.TemporaryDirectory() as ckpt:
        half = args.steps // 2
        print(f"== phase 1: steps 0..{half - 1} (checkpoint every 10) ==")
        out1 = train_lm("lm-100m", steps=half, smoke=True, ckpt_dir=ckpt,
                        ckpt_every=10, batch=args.batch, seq=args.seq)
        print(f"== phase 2: restart from checkpoint, continue to "
              f"{args.steps} ==")
        out2 = train_lm("lm-100m", steps=args.steps, smoke=True,
                        ckpt_dir=ckpt, ckpt_every=10, batch=args.batch,
                        seq=args.seq)
        print(f"\nphase1: {out1}")
        print(f"phase2 (restored from step {out2['restored_from']}):"
              f" {out2}")
        assert out2["restored_from"] > 0, "restore did not engage"
        assert out2["last_loss"] < out1["first_loss"], "loss did not drop"
        print("\ntraining + checkpoint/restart drill OK")


if __name__ == "__main__":
    main()
