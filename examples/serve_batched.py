"""Batched dispatch serving plane: ladders, admission, hedging — validated.

The serving simulator prices each access as its own engine dispatch; at
saturation the per-dispatch overhead IS the tail.  This example walks the
PR-8 serving plane end to end on one workload:

  1. **batch ladders** — per-server collectors flush queued accesses in
     ladder rungs (1/2/4/8/16); a batch is ONE engine dispatch, so the
     fixed dispatch cost amortizes across its occupants and the p99 at
     saturation drops below per-query dispatch;
  2. **deadline-aware admission** — queries whose floor latency can no
     longer meet their SLO deadline are shed at admission (fail fast,
     never queued), which protects the *surviving* p99 at overload;
  3. **SLO-driven hedging** — a backup variant fires when a query's
     elapsed time crosses its tenant's learned latency quantile; first
     completion wins, the loser's queued work is cancelled;
  4. **harness validation** — the same runs replayed on a REAL asyncio
     clock (semaphores, tasks, wall time) agree with the simulator at low
     load and reproduce the batching win.

Run:  PYTHONPATH=src python examples/serve_batched.py
"""
import numpy as np

from repro.core import replicate_workload
from repro.core.slo import SLOSpec
from repro.distsys import Cluster, LatencyModel
from repro.graph import make_sharding, snb_like
from repro.serve import (
    AdmissionConfig,
    BatchingConfig,
    HedgePolicy,
    harness_simulate,
    simulate,
    snb_drift,
)

T, N_SERVERS = 1, 6

snb = snb_like(1, seed=0)
f = snb.graph.object_sizes().astype(np.float32)
shard = make_sharding("hash", snb.graph, N_SERVERS, seed=0)
ps = snb_drift(snb, n_phases=2, queries_per_phase=300, seed=0)[0].pathset
scheme, _ = replicate_workload(ps, shard, N_SERVERS, t=T, f=f)
cluster = Cluster(scheme, f=f)

# a real per-dispatch cost and scarce slots: the regime batching exists for
model = LatencyModel(dispatch_us=20.0)
sat = dict(rate_qps=120_000, model=model, concurrency=2, seed=3)

print("== 1. batch ladders at saturation ==")
pq = simulate(cluster, ps, **sat)
bt = simulate(cluster, ps, batching=BatchingConfig(), **sat)
bs = bt.batch_stats
print(f"per-query dispatch p99 : {pq.p99_us:10.1f} us")
print(f"ladder-batched    p99 : {bt.p99_us:10.1f} us   "
      f"({bs.n_batches} batches, mean occupancy {bs.mean_occupancy:.1f}, "
      f"max {bs.max_occupancy})")
assert bt.p99_us <= pq.p99_us

print("\n== 2. deadline-aware admission at overload ==")
slo = SLOSpec.uniform(T, ps.n_queries)
over = dict(rate_qps=300_000, concurrency=2, seed=5, slo=slo)
drown = simulate(cluster, ps, **over)
shed = simulate(cluster, ps, admission=AdmissionConfig(stretch=4.0), **over)
surv_p99 = float(np.percentile(shed.surviving_latencies(), 99.0))
adm = shed.summary()["admission"]
print(f"no admission     p99 : {drown.p99_us:10.1f} us")
print(f"with shedding    p99 : {surv_p99:10.1f} us surviving "
      f"(shed {shed.shed_frac:.0%}, per tenant {adm['per_tenant_shed_frac']})")
assert surv_p99 < drown.p99_us

print("\n== 3. SLO-driven hedging ==")
hed = simulate(
    cluster, ps, rate_qps=30_000, concurrency=4, seed=7, slo=slo,
    hedge=HedgePolicy(quantile=75.0, min_samples=32),
)
h = hed.summary()["hedging"]
print(f"hedges fired {h['fired']}, backup wins {h['wins']}, "
      f"cancelled jobs {h['cancelled']} (hedge frac {h['hedge_frac']:.1%})")

print("\n== 4. asyncio harness validation (real clock) ==")
low = dict(rate_qps=20_000, concurrency=32, seed=11)
sim_lo = simulate(cluster, ps, **low)
har_lo = harness_simulate(cluster, ps, **low)
err = abs(har_lo.p99_us - sim_lo.p99_us) / sim_lo.p99_us
print(f"simulator p50/p99 : {sim_lo.p50_us:7.1f} / {sim_lo.p99_us:7.1f} us")
print(f"harness   p50/p99 : {har_lo.p50_us:7.1f} / {har_lo.p99_us:7.1f} us "
      f"(p99 rel err {err:.1%})")
hbt = harness_simulate(cluster, ps, batching=BatchingConfig(), **sat)
hpq = harness_simulate(cluster, ps, **sat)
print(f"real-clock batched p99 {hbt.p99_us:.1f} us vs per-query "
      f"{hpq.p99_us:.1f} us")
assert hbt.p99_us < hpq.p99_us
print("\nbatched dispatch plane validated against the wall clock.")
