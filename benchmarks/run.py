"""Benchmark harness: one module per paper table/figure.

  fig2_traversals       — Fig 2a-2d (traversal CDFs, single-site cost)
  fig6_latency_tradeoff — Fig 6a-6f (latency/throughput/replication vs t)
  fig7_sharding         — Fig 7a-7d + Table 3 (sharding schemes, dangling)
  table4_runtime        — Table 4 (algorithm runtime) + kernel timing
  reshard_cost          — §5.4 incremental-update cost
  beyond_paper          — MoE expert + recsys hot-row replication
  engine_backends       — LatencyEngine backend/chunk/transfer micro-bench
  perf_iterate          — engine transfer profile (resident vs legacy h2d)
  serve_tail            — serving simulator p99 vs load + controller value
  tenant_frontier       — multi-tenant SLOs: vector-t frontier, per-tenant
                          p99 static vs arbitrating controller
  routing_policies      — hop-routing policies: p99 vs load x
                          {home_first, nearest_copy, queue_aware} +
                          nearest-copy replica pruning
  provisioning_policies — policy-aware greedy vs home-first(+prune):
                          shipped/resident replication bytes at equal
                          nearest_copy feasibility over drift sequences
  provisioning_scale    — fused UPDATE megakernel vs separate dispatch
                          (bit-identical, >= 5x) + servers x paths scale
                          grid with streamed ingestion
  incremental_eval      — dirty-set window re-checks vs full re-eval on
                          the controller drift-repair loop (bit-identical,
                          >= 4x warm speedup, dirty-fraction accounting)
  fault_resilience      — k-resilient provisioning vs exhaustive
                          single-server loss (3-backend parity), chaos
                          kill/revive violation windows static vs
                          controller-on, routing-table coordinator savings

Usage: PYTHONPATH=src python -m benchmarks.run [module ...]
Prints ``bench,metric,tags,value`` CSV.

The harness runs with the telemetry plane enabled (``repro.obs``) and the
jit compile hook installed; each module executes inside its own
``TRANSFER.scope()`` so the per-module transfer snapshot is the module's
own traffic.  At exit the global metrics registry plus the per-module
transfer snapshots land in ``BENCH_metrics.json`` — the nightly metrics
artifact that rides next to the other ``BENCH_*.json`` files.
"""
import json
import sys
import time

from repro import obs
from repro.engine import TRANSFER
from repro.obs import install_compile_hook

MODULES = ["fig2_traversals", "fig6_latency_tradeoff", "fig7_sharding",
           "table4_runtime", "reshard_cost", "beyond_paper",
           "engine_backends", "perf_iterate", "serve_tail",
           "tenant_frontier", "routing_policies", "provisioning_policies",
           "provisioning_scale", "incremental_eval", "fault_resilience"]

# zero-arg entry point per module when it isn't ``run`` (perf_iterate's
# ``run`` is the arch-cell driver; its benchmark entry is ``run_engine``)
ENTRY = {"perf_iterate": "run_engine"}


def main() -> None:
    want = sys.argv[1:] or MODULES
    obs.enable()
    install_compile_hook()
    transfer_per_module = {}
    t0 = time.perf_counter()
    print("bench,metric,tags,value")
    for name in want:
        entry = ENTRY.get(name, "run")
        mod = __import__(f"benchmarks.{name}", fromlist=[entry])
        t1 = time.perf_counter()
        with TRANSFER.scope():
            out = getattr(mod, entry)()
            transfer_per_module[name] = TRANSFER.snapshot()
        if name in ENTRY and out is not None:
            # detail blob; '#'-prefixed to keep the CSV stream parseable
            for line in json.dumps(out, indent=2).splitlines():
                print(f"# {line}")
        print(f"# {name} done in {time.perf_counter()-t1:.1f}s")
    print(f"# total {time.perf_counter()-t0:.1f}s")
    with open("BENCH_metrics.json", "w") as fh:
        json.dump(
            {
                "modules": want,
                "registry": obs.REGISTRY.snapshot(),
                "transfer_per_module": transfer_per_module,
            },
            fh,
            indent=2,
        )
    print("# metrics snapshot -> BENCH_metrics.json")


if __name__ == "__main__":
    main()
