"""Paper Fig 2: distributed traversals + the cost of single-site execution.

  2a — query latency vs #distributed traversals (executor latency model)
  2b — CDF of traversals per query, hash sharding, |S| in {3, 6, 12}
  2c — CDF with min-cut sharding
  2d — single-site oracle replication overhead per placement scheme
"""
import numpy as np

from benchmarks.common import build_snb_setup, emit
from repro.core import ReplicationScheme, evaluate_baseline, single_site_oracle
from repro.distsys import Cluster, LatencyModel, execute_workload
from repro.engine import LatencyEngine


def run():
    # --- 2a: latency vs traversal count
    snb, ps, shard = build_snb_setup(sharding="hash")
    scheme = ReplicationScheme.from_sharding(shard, 6)
    rep = execute_workload(Cluster(scheme), ps, LatencyModel(), seed=0)
    trav = rep.query_traversals
    lat = rep.query_latency_us
    for k in range(0, int(trav.max()) + 1):
        sel = trav == k
        if sel.sum() < 5:
            continue
        emit("fig2a", "mean_us", round(float(lat[sel].mean()), 1), k=k)
        emit("fig2a", "p99_us",
             round(float(np.percentile(lat[sel], 99)), 1), k=k)

    # --- 2b/2c: traversal CDFs per sharding and cluster size (one
    # device-resident engine per scheme; the bool mask never transfers)
    for fig, kind in (("fig2b", "hash"), ("fig2c", "mincut")):
        for n_srv in (3, 6, 12):
            snb, ps, shard = build_snb_setup(n_servers=n_srv, sharding=kind)
            scheme = ReplicationScheme.from_sharding(shard, n_srv)
            lq = LatencyEngine(scheme).query_latencies(ps)
            for k in (0, 1, 2, 4):
                frac = float((lq <= k).mean())
                emit(fig, "cdf", round(frac, 4), servers=n_srv, k=k)

    # --- 2d: oracle single-site overhead per placement
    for kind in ("hash", "mincut", "hypergraph"):
        snb, ps, shard = build_snb_setup(sharding=kind)
        f = snb.graph.object_sizes()
        oracle = single_site_oracle(ps, shard, 6)
        res = evaluate_baseline(ps, oracle, f=f)
        emit("fig2d", "oracle_overhead",
             round(res["overhead"], 4), sharding=kind)
        emit("fig2d", "oracle_mean_latency",
             round(res["mean_latency"], 3), sharding=kind)
