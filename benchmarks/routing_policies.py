"""Routing-policy benchmark: p99 vs load x policy + nearest-copy pruning.

Two measurements, written to ``BENCH_routing.json`` (and emitted as CSV
rows via ``benchmarks.common``):

  1. **p99 vs offered load x {home_first, nearest_copy, queue_aware}** —
     the drifted hotspot phase of an SNB drift sequence served through the
     discrete-event simulator against ONE fixed replication scheme (greedy
     on the union workload, so the drifted phase's objects actually have
     replicas to route between).  ``queue_aware`` re-picks hop targets
     every ``REROUTE_EVERY`` arrivals against the simulator's live queue
     depths.  Acceptance gate: at the saturated end of the sweep,
     ``queue_aware`` p99 <= ``home_first`` p99 with replication held
     fixed — replica-aware hop routing converts existing replication
     bytes into tail latency, shipping nothing.

  2. **nearest-copy pruning** — the greedy scheme provisions against the
     home-first walk; scored under ``nearest_copy`` (the paper-faithful
     "any co-located replica counts" reading of Eqn 1) many of those
     bytes are redundant.  ``prune_scheme_replicas`` greedily drops
     replicas while the workload stays nearest-copy feasible; the report
     carries the bytes saved and the fraction of the replica set dropped.

Usage: PYTHONPATH=src python -m benchmarks.routing_policies [--smoke] [out.json]
"""
from __future__ import annotations

import json
import sys
import time

import numpy as np

from benchmarks.common import emit
from repro.core import replicate_workload
from repro.core.paths import PathSet
from repro.core.replication import prune_scheme_replicas
from repro.distsys import Cluster, LatencyModel
from repro.engine import LatencyEngine
from repro.graph import make_sharding, snb_like
from repro.serve import snb_drift

T = 2
N_SERVERS = 6
REROUTE_EVERY = 25
POLICIES = ("home_first", "nearest_copy", "queue_aware")


def run(out_path: str = "BENCH_routing.json", smoke: bool = False) -> dict:
    queries_per_phase = 200 if smoke else 500
    load_sweep = (100_000, 700_000) if smoke else (100_000, 400_000, 700_000)

    snb = snb_like(1, seed=0)
    f = snb.graph.object_sizes().astype(np.float32)
    shard = make_sharding("hash", snb.graph, N_SERVERS, seed=0)
    model = LatencyModel()

    phases = snb_drift(
        snb, n_phases=3, queries_per_phase=queries_per_phase, hot_prob=0.9,
        seed=0,
    )
    union = PathSet.concatenate([p.pathset for p in phases])
    drifted = phases[-1].pathset

    # replication held fixed across the whole sweep: one greedy scheme on
    # the union workload (so drifted-phase objects have replicas at all)
    scheme, _ = replicate_workload(union, shard, N_SERVERS, t=T, f=f)

    result: dict = {
        "t": T,
        "workload": {
            "n_servers": N_SERVERS,
            "queries_per_phase": queries_per_phase,
            "union_paths": union.n_paths,
            "replicas": scheme.replica_count(),
        },
        "smoke": smoke,
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
    }

    # ------------------------------------------------------------------ 1.
    sweep = []
    for qps in load_sweep:
        row: dict = {"offered_qps": qps}
        for pol in POLICIES:
            kw = (
                {"reroute_every": REROUTE_EVERY}
                if pol == "queue_aware"
                else {}
            )
            from repro.serve import simulate

            rep = simulate(
                Cluster(scheme.copy(), f=f), drifted, rate_qps=qps,
                model=model, seed=7, policy=pol, **kw,
            )
            row[pol] = {
                "p50_us": round(rep.p50_us, 1),
                "p99_us": round(rep.p99_us, 1),
                "p999_us": round(rep.p999_us, 1),
                "max_utilization": round(float(rep.utilization().max()), 4),
                "reroutes": rep.reroutes,
            }
            emit("routing", "p99_us", round(rep.p99_us, 1),
                 qps=qps, policy=pol)
        sweep.append(row)
    result["load_sweep"] = sweep

    saturated = sweep[-1]
    result["queue_aware_le_home_first"] = bool(
        saturated["queue_aware"]["p99_us"]
        <= saturated["home_first"]["p99_us"]
    )
    assert result["queue_aware_le_home_first"], (
        "queue_aware must not lose to home_first at saturation "
        f"({saturated['queue_aware']['p99_us']} vs "
        f"{saturated['home_first']['p99_us']})"
    )

    # ------------------------------------------------------------------ 2.
    # nearest-copy pruning on a phase-0 greedy scheme (t=1: plenty of
    # replicas, all provisioned against home-first hops)
    ps0 = phases[0].pathset
    p_scheme, _ = replicate_workload(ps0, shard, N_SERVERS, t=1, f=f)
    replicas_before = p_scheme.replica_count()
    bytes_before = float(p_scheme.storage_per_server(f).sum())
    n_dropped, bytes_saved = prune_scheme_replicas(
        p_scheme, ps0, 1, policy="nearest_copy", f=f
    )
    eng = LatencyEngine(p_scheme)
    result["nearest_copy_prune"] = {
        "replicas_before": replicas_before,
        "replicas_dropped": n_dropped,
        "drop_frac": round(n_dropped / max(replicas_before, 1), 4),
        "bytes_saved": round(bytes_saved, 1),
        "bytes_saved_frac_of_storage": round(
            bytes_saved / bytes_before, 4
        ),
        "still_feasible_nearest_copy": bool(
            eng.is_feasible(ps0, 1, policy="nearest_copy")
        ),
    }
    assert result["nearest_copy_prune"]["still_feasible_nearest_copy"]
    emit("routing", "prune_replicas_dropped", n_dropped)
    emit("routing", "prune_bytes_saved", round(bytes_saved, 1))

    with open(out_path, "w") as fh:
        json.dump(result, fh, indent=2)
    print(f"# wrote {out_path}")
    return result


if __name__ == "__main__":
    args = [a for a in sys.argv[1:]]
    smoke = "--smoke" in args
    args = [a for a in args if a != "--smoke"]
    run(args[0] if args else "BENCH_routing.json", smoke=smoke)
