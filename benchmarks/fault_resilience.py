"""Fault-resilience benchmark: k-resilient provisioning vs single-server
loss, chaos kill/revive windows, and client-side routing tables.

Three sections, one per layer of the fault path:

  1. **resilience** — provision the same workload twice (plain ``t`` vs
     ``resilience=KResilient(k=1)``) and evaluate both schemes under
     EVERY single-server loss case exhaustively: the k=1 scheme must
     stay within budget in all S cases while the k=0 scheme violates in
     at least one, and the replication overhead the guarantee costs is
     reported (the paper's Fig 6 trade-off, extended to loss cases).
     The k=1 scheme is built on all three engine backends
     (reference | jnp | pallas) and must agree bit-for-bit.

  2. **chaos** — a mid-run kill/revive injected into the serving
     simulator.  The static scheme rides the outage through an SLO
     violation window; the AdaptiveController's liveness reaction
     (k-resilient ``replicate_delta`` over the dead set) provisions
     survivors so the same chaos timeline closes strictly shorter
     windows.  Reported: total violation-window length and
     time-to-repair for both arms.

  3. **routing** — the same serving run with and without a client-side
     :class:`RoutingTable`: direct-to-shard dispatch skips the root
     coordinator hop, so mean latency drops by the coordinator barrier
     at a ~100% direct-hit rate on a fresh table; under chaos the table
     degrades to fallbacks + force-refreshes instead of misrouting.

Headline keys (asserted here, gated by ``check_regress``):

  * ``resilience.k1_feasible_all_losses`` — true (all S cases pass);
  * ``resilience.k0_violates``            — true (the guarantee is not
                                            vacuous for this workload);
  * ``parity.bit_identical``              — 3-backend scheme agreement;
  * ``chaos.controller_shrinks_window``   — controller arm strictly
                                            shorter than the static arm.

Usage: PYTHONPATH=src python -m benchmarks.fault_resilience [--smoke] [out.json]
"""
from __future__ import annotations

import json
import sys
import time

import numpy as np

from benchmarks.common import emit
from repro.core import replicate_workload
from repro.core.paths import PathSet
from repro.distsys import ChaosEvent, Cluster, LatencyModel, RoutingTable
from repro.distsys.faults import time_to_repair, violation_windows
from repro.engine import KResilient, LatencyEngine
from repro.serve import simulate
from repro.serve.controller import AdaptiveController, ControllerConfig

N_SERVERS = 6
T = 2
SEED = 11
BACKENDS = ("reference", "jnp", "pallas")


def _workload(smoke: bool):
    rng = np.random.default_rng(SEED)
    n_obj = 120 if smoke else 400
    n_paths = 160 if smoke else 600
    paths = [
        rng.integers(0, n_obj, rng.integers(1, 8)).tolist()
        for _ in range(n_paths)
    ]
    shard = rng.integers(0, N_SERVERS, n_obj).astype(np.int32)
    return PathSet.from_lists(paths), shard


def _loss_case_table(eng: LatencyEngine, ps: PathSet, t_q, res) -> dict:
    """Worst per-query latency under each single loss case, exhaustively."""
    h = eng.resilient_path_latencies(ps, res)  # [D, P]
    qids = np.asarray(ps.query_ids)
    per_case = []
    for d in range(h.shape[0]):
        lq = np.zeros(ps.n_queries, np.int64)
        np.maximum.at(lq, qids, h[d])
        per_case.append(
            {"case": d, "max_l_q": int(lq.max()),
             "violations": int((lq > t_q).sum())}
        )
    return {
        "cases": per_case,
        "feasible_all": bool(all(c["violations"] == 0 for c in per_case)),
        "total_violations": int(sum(c["violations"] for c in per_case)),
    }


def _bench_resilience(ps, shard, result):
    t_q = np.full(ps.n_queries, T, np.int32)
    res = KResilient(k=1)

    k0, s0 = replicate_workload(ps, shard.copy(), N_SERVERS, T)
    k0_table = _loss_case_table(LatencyEngine(k0), ps, t_q, res)

    masks = {}
    k1 = stats = None
    for b in BACKENDS:
        scheme, st = replicate_workload(
            ps, shard.copy(), N_SERVERS, T, resilience=res, policy_backend=b)
        masks[b] = scheme.mask
        if b == "jnp":
            k1, stats = scheme, st
    bit_identical = bool(
        np.array_equal(masks["reference"], masks["jnp"])
        and np.array_equal(masks["reference"], masks["pallas"])
    )
    k1_table = _loss_case_table(LatencyEngine(k1), ps, t_q, res)

    result["resilience"] = {
        "n_loss_cases": len(k1_table["cases"]),
        "k0_replicas": int(s0.replicas),
        "k1_replicas": int(stats.replicas),
        "resilience_overhead_replicas": int(stats.replicas - s0.replicas),
        "resilience_rounds": int(stats.resilience_rounds),
        "residual_violations": int(stats.resilient_violations),
        "k0_loss_cases": k0_table,
        "k1_loss_cases": k1_table,
        "k0_violates": bool(not k0_table["feasible_all"]),
        "k1_feasible_all_losses": bool(k1_table["feasible_all"]),
    }
    result["parity"] = {"backends": list(BACKENDS),
                        "bit_identical": bit_identical}
    emit("faults", "k1_feasible_all_losses",
         result["resilience"]["k1_feasible_all_losses"])
    emit("faults", "k0_violates", result["resilience"]["k0_violates"])
    emit("faults", "overhead_replicas",
         result["resilience"]["resilience_overhead_replicas"])
    emit("faults", "parity_bit_identical", bit_identical)
    return k1


def _bench_chaos(ps, shard, result, smoke):
    scheme, _ = replicate_workload(ps, shard.copy(), N_SERVERS, T)
    model = LatencyModel()
    kill_t, revive_t = 30_000.0, 70_000.0
    chaos = [ChaosEvent(kill_t, "kill", 2), ChaosEvent(revive_t, "revive", 2)]
    rate = 2_000.0

    def sim(scm, **kw):
        return simulate(Cluster(scm.copy()), ps, rate_qps=rate, model=model,
                        seed=5, concurrency=8, **kw)

    calm = sim(scheme)
    thr = 1.3 * float(np.percentile(calm.latency_us, 99))

    def windows(rep):
        fin = rep.arrival_us + rep.latency_us
        return violation_windows(fin, rep.latency_us > thr)

    static = sim(scheme, chaos=chaos)
    w_static = windows(static)

    cluster = Cluster(scheme.copy())
    ctl = AdaptiveController(
        cluster, ControllerConfig(t=T),
        engine=LatencyEngine(cluster.scheme, backend="jnp"))
    cluster.fail_server(2)
    t0 = time.perf_counter()
    rep = ctl.on_liveness_change(ps)
    repair_s = time.perf_counter() - t0
    cluster.recover_server(2)
    reactive = sim(cluster.scheme, chaos=chaos)
    w_react = windows(reactive)

    total = lambda w: float(sum(hi - lo for lo, hi in w))  # noqa: E731
    result["chaos"] = {
        "slo_threshold_us": round(thr, 2),
        "kill_us": kill_t,
        "revive_us": revive_t,
        "static_window_us": total(w_static),
        "static_windows": w_static,
        "static_time_to_repair_us": time_to_repair(w_static, kill_t),
        "controller_window_us": total(w_react),
        "controller_windows": w_react,
        "controller_time_to_repair_us": time_to_repair(w_react, kill_t),
        "controller_replicas_added": int(rep.replicas_added),
        "controller_repair_s": round(repair_s, 3),
        "controller_feasible_after": bool(rep.feasible_after),
        "controller_shrinks_window": total(w_react) < total(w_static),
    }
    emit("faults", "static_window_us", result["chaos"]["static_window_us"])
    emit("faults", "controller_window_us",
         result["chaos"]["controller_window_us"])
    emit("faults", "controller_shrinks_window",
         result["chaos"]["controller_shrinks_window"])


def _bench_routing(ps, shard, result):
    scheme, _ = replicate_workload(ps, shard.copy(), N_SERVERS, T)
    model = LatencyModel()

    base = simulate(Cluster(scheme.copy()), ps, rate_qps=500.0, model=model,
                    seed=3, concurrency=4)
    cl = Cluster(scheme.copy())
    direct = simulate(cl, ps, rate_qps=500.0, model=model, seed=3,
                      concurrency=4, routing_table=RoutingTable(cl))

    # under chaos the snapshot misses instead of misrouting
    cl2 = Cluster(scheme.copy())
    chaos = [ChaosEvent(30_000.0, "kill", 1),
             ChaosEvent(70_000.0, "revive", 1)]
    stale = simulate(cl2, ps, rate_qps=500.0, model=model, seed=3,
                     concurrency=4, chaos=chaos,
                     routing_table=RoutingTable(cl2, max_age_us=1e12))

    result["routing"] = {
        "coordinator_us": model.coordinator_us,
        "mean_latency_coordinator_us": round(float(np.mean(base.latency_us)), 3),
        "mean_latency_direct_us": round(float(np.mean(direct.latency_us)), 3),
        "saved_us_per_query": round(
            float(np.mean(base.latency_us) - np.mean(direct.latency_us)), 3),
        "direct_hit_rate": direct.routing["direct_hit_rate"],
        "chaos_direct_hit_rate": stale.routing["direct_hit_rate"],
        "chaos_fallbacks": stale.routing["fallbacks"],
        "chaos_refreshes": stale.routing["refreshes"],
    }
    emit("faults", "direct_hit_rate", result["routing"]["direct_hit_rate"])
    emit("faults", "saved_us_per_query",
         result["routing"]["saved_us_per_query"])
    emit("faults", "chaos_fallbacks", result["routing"]["chaos_fallbacks"])


def run(out_path: str = "BENCH_faults.json", smoke: bool = False) -> dict:
    result: dict = {
        "t": T,
        "n_servers": N_SERVERS,
        "seed": SEED,
        "smoke": smoke,
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
    }
    ps, shard = _workload(smoke)
    result["workload"] = {"n_objects": int(len(shard)),
                          "n_paths": ps.n_paths,
                          "n_queries": ps.n_queries}
    _bench_resilience(ps, shard, result)
    _bench_chaos(ps, shard, result, smoke)
    _bench_routing(ps, shard, result)

    assert result["resilience"]["k1_feasible_all_losses"], (
        "k=1 scheme violated under some single-server loss"
    )
    assert result["resilience"]["k0_violates"], (
        "k=0 scheme survived every loss: the workload does not exercise "
        "the resilience guarantee"
    )
    assert result["parity"]["bit_identical"], (
        "k-resilient gate diverged across backends"
    )
    assert result["chaos"]["controller_shrinks_window"], (
        "controller-on chaos violation window must be strictly shorter "
        "than the static scheme's"
    )

    with open(out_path, "w") as fh:
        json.dump(result, fh, indent=2)
    print(f"# wrote {out_path}")
    return result


if __name__ == "__main__":
    args = [a for a in sys.argv[1:]]
    smoke = "--smoke" in args
    args = [a for a in args if a != "--smoke"]
    run(args[0] if args else "BENCH_faults.json", smoke=smoke)
