"""Paper Table 4: replication-algorithm running time vs graph scale.

Also reports the §5.3 pruning ablation (the paper: without pruning,
runtime exceeds an hour in all but the smallest case) and the Pallas
path-latency kernel vs the jnp oracle on the analysis hot loop.
"""
import time

import numpy as np

from benchmarks.common import build_snb_setup, emit, timer
from repro.core import replicate_workload


def run():
    for scale, n_queries in ((1, 1000), (2, 2000), (4, 4000)):
        snb, ps, shard = build_snb_setup(scale=scale, n_queries=n_queries)
        f = snb.graph.object_sizes().astype(np.float32)
        for t in (1, 3):
            scheme, stats = replicate_workload(ps, shard, 6, t, f=f)
            emit("table4", "runtime_s", round(stats.runtime_s, 2),
                 scale=scale, t=t, paths=stats.paths_processed)
        # pruning ablation at t=1
        with timer() as tm:
            replicate_workload(ps, shard, 6, 1, f=f, prune=False)
        emit("table4", "runtime_noprune_s", round(tm.dt, 2), scale=scale)

    # engine backends on the latency-evaluation hot loop (shared packed
    # scheme, shared pinned pathset; only the backend dispatch differs)
    from repro.core import ReplicationScheme
    from repro.engine import LatencyEngine

    snb, ps, shard = build_snb_setup(scale=2, n_queries=3000)
    scheme = ReplicationScheme.from_sharding(shard, 6)
    results = {}
    for backend in ("jnp", "pallas"):
        eng = LatencyEngine(scheme, backend=backend)
        dev_ps = eng.prepare(ps)
        eng.path_latencies(dev_ps)  # warm the jit cache
        with timer() as tm:
            results[backend] = eng.path_latencies(dev_ps)
        emit("kernel_path_latency", f"{backend}_s", round(tm.dt, 3),
             paths=ps.n_paths)
    assert np.array_equal(results["jnp"], results["pallas"])
