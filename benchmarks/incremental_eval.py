"""Incremental dirty-set evaluation benchmark: the controller drift-repair
loop with and without the persistent latency cache.

The scenario is the serve plane's steady state: a controller holds a
sizable window of served paths (the resident workload), a drift phase
flips, and ``replicate_delta`` ships a small repair — after which every
windowed entry must be re-judged against the mutated scheme.  The full
path re-evaluates the whole window; the incremental path
(``path_latencies(..., incremental=True)``) re-walks only the paths
touching the repair's objects — the exact dirty set of the engine's
object->path index — as one gather-compacted block.

Per drift family (the PR-5 trio: SNB hot-community flips, GNN sampled
fan-outs, recsys user/item skew):

  1. provision phase 0 from scratch (``replicate_workload``,
     ``return_engine=True``) and tile the phase-0 paths into a
     controller-scale window;
  2. seed the incremental cache with one cold evaluation (checked
     bit-identical to the direct evaluation);
  3. for each later phase: repair the phase's delta paths
     (``replicate_delta`` — its additions invalidate the cache through
     ``engine.note_changed``), then time ``REPS`` window re-checks both
     ways, re-dirtying the cache before each incremental rep so every
     rep pays the real dirty re-walk, not a clean cache hit.

Headline keys (asserted here, gated by ``check_regress``):

  * ``bit_identical``       — every timed incremental result equals the
                              full evaluation, all families, all phases;
  * ``min_speedup``         — min over families of (full re-check time /
                              incremental re-check time) >= 4x;
  * ``mean_dirty_fraction`` — mean |dirty| / |window| across repairs
                              (the locality the speedup is made of).

Usage: PYTHONPATH=src python -m benchmarks.incremental_eval [--smoke] [out.json]
"""
from __future__ import annotations

import json
import sys
import time

import numpy as np

from benchmarks.common import emit
from repro.core import replicate_delta, replicate_workload
from repro.core.paths import PathSet
from repro.engine import PathIndex
from repro.serve import drift_stream, gnn_drift, recsys_drift, snb_drift

N_SERVERS = 6
T = 1
# the routed policy the serve plane scores with: heavier per-path walks
# than home_first, i.e. the evaluation cost the dirty set actually saves
SCORE_POLICY = "nearest_copy"
REPS = 5
# wall-clock ceiling of default_grid_point() — the tier-1 guard
# (tests/test_incremental.py) runs that one point and asserts this bound
DEFAULT_BUDGET_S = 120.0


def _families(smoke: bool):
    """(name, drift phases, shard, f) per workload family (PR-5 trio)."""
    from repro.graph import make_sharding, snb_like

    q = 120 if smoke else 320
    snb = snb_like(1, seed=0)
    g = snb.graph
    f_g = g.object_sizes().astype(np.float32)
    shard_g = make_sharding("hash", g, N_SERVERS, seed=0)

    yield (
        "snb",
        snb_drift(snb, n_phases=3, queries_per_phase=q, hot_prob=0.9, seed=0),
        shard_g,
        f_g,
    )
    yield (
        "gnn",
        gnn_drift(g, n_phases=3, queries_per_phase=max(q // 2, 60),
                  fanouts=(5, 3), hot_prob=0.9, seed=0),
        shard_g,
        f_g,
    )
    n_users, n_items = 600, 4000
    yield (
        "recsys",
        recsys_drift(n_users, n_items, n_phases=3, queries_per_phase=q,
                     hot_prob=0.9, seed=0),
        np.concatenate(
            [np.arange(n_users) % N_SERVERS, np.arange(n_items) % N_SERVERS]
        ).astype(np.int32),
        np.ones(n_users + n_items, np.float32),
    )


def _tile(ps: PathSet, target_paths: int) -> PathSet:
    """Controller-scale window: the phase's paths tiled up to
    ``target_paths`` rows.

    A sliding window holds every recently served batch, so the same hot
    paths appear many times across entries; re-checking the window costs
    the *total* path count.  Tiling reproduces that cost shape in one
    PathSet (identical rows dirty together, so the dirty *fraction* is
    unchanged — the speedup is not an artifact of the tiling), and
    tiling every family to the same window size keeps the comparison
    about dirty locality, not each generator's path yield.
    """
    k = max(1, -(-target_paths // max(ps.n_paths, 1)))
    return PathSet.concatenate([ps] * k)


def _bench_family(name, phases, shard, f, smoke, result):
    deltas = list(drift_stream(phases))
    window = _tile(deltas[0].pathset, 4000 if smoke else 12000)
    _, _, eng = replicate_workload(
        deltas[0].pathset, shard, N_SERVERS, t=T, f=f, return_engine=True,
        policy=SCORE_POLICY, policy_prune=False,
    )
    n_obj = int(np.asarray(shard).shape[0])
    index = PathIndex(np.asarray(window.objects), n_obj)

    # cold seed: first incremental call = one full evaluation + cache fill
    t0 = time.perf_counter()
    h_cold = eng.path_latencies(window, policy=SCORE_POLICY, incremental=True)
    cold_s = time.perf_counter() - t0
    bit_identical = bool(np.array_equal(
        h_cold, eng.path_latencies(window, policy=SCORE_POLICY)
    ))

    full_s = []
    inc_s = []
    dirty_fracs = []
    for d in deltas[1:]:
        if d.added.n_paths == 0:
            continue
        _, (ao, _) = replicate_delta(
            d.added, eng, T, f=f, policy=SCORE_POLICY
        )
        if not len(ao):
            continue
        dirty_fracs.append(
            len(index.dirty_paths(ao)) / max(window.n_paths, 1)
        )
        # warm both code paths once so neither arm pays first-trace jit
        # compilation inside the timed region
        eng.note_changed(ao)
        h_inc = eng.path_latencies(
            window, policy=SCORE_POLICY, incremental=True
        )
        h_full = eng.path_latencies(window, policy=SCORE_POLICY)
        bit_identical = bit_identical and bool(np.array_equal(h_inc, h_full))

        t0 = time.perf_counter()
        for _ in range(REPS):
            h_full = eng.path_latencies(window, policy=SCORE_POLICY)
        full_s.append((time.perf_counter() - t0) / REPS)

        t0 = time.perf_counter()
        for _ in range(REPS):
            # re-dirty the repair's rows: each rep pays the genuine
            # invalidate -> gather -> re-walk -> scatter cycle
            eng.note_changed(ao)
            h_inc = eng.path_latencies(
                window, policy=SCORE_POLICY, incremental=True
            )
        inc_s.append((time.perf_counter() - t0) / REPS)
        bit_identical = bit_identical and bool(np.array_equal(h_inc, h_full))

    speedup = float(np.sum(full_s) / max(np.sum(inc_s), 1e-9))
    fam = {
        "window_paths": window.n_paths,
        "repairs": len(full_s),
        "cold_eval_s": round(cold_s, 4),
        "full_recheck_s": round(float(np.mean(full_s)), 5),
        "inc_recheck_s": round(float(np.mean(inc_s)), 5),
        "speedup": round(speedup, 2),
        "dirty_fraction": round(float(np.mean(dirty_fracs)), 4),
        "bit_identical": bit_identical,
    }
    result["families"][name] = fam
    emit("incremental", "speedup", fam["speedup"], family=name)
    emit("incremental", "dirty_fraction", fam["dirty_fraction"], family=name)
    emit("incremental", "full_recheck_s", fam["full_recheck_s"], family=name)
    emit("incremental", "inc_recheck_s", fam["inc_recheck_s"], family=name)
    return fam


def default_grid_point(smoke: bool = True) -> dict:
    """The single (family x scale) cell the tier-1 wall-clock guard runs:
    the SNB drift family at smoke scale (one provisioning pass, two
    repairs, REPS timed re-checks each way)."""
    result: dict = {"families": {}}
    name, phases, shard, f = next(iter(_families(smoke)))
    return _bench_family(name, phases, shard, f, smoke, result)


def run(out_path: str = "BENCH_incremental.json", smoke: bool = False) -> dict:
    result: dict = {
        "t": T,
        "score_policy": SCORE_POLICY,
        "n_servers": N_SERVERS,
        "reps": REPS,
        "smoke": smoke,
        "families": {},
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
    }
    for name, phases, shard, f in _families(smoke):
        _bench_family(name, phases, shard, f, smoke, result)

    fams = result["families"].values()
    result["bit_identical"] = bool(all(f["bit_identical"] for f in fams))
    result["min_speedup"] = round(min(f["speedup"] for f in fams), 2)
    result["mean_dirty_fraction"] = round(
        float(np.mean([f["dirty_fraction"] for f in fams])), 4
    )
    assert result["bit_identical"], (
        "incremental window re-checks diverged from full re-evaluation"
    )
    assert result["min_speedup"] >= 4.0, (
        "incremental re-check must be >= 4x faster than the full window "
        f"re-evaluation on every drift family (min {result['min_speedup']}x)"
    )

    with open(out_path, "w") as fh:
        json.dump(result, fh, indent=2)
    print(f"# wrote {out_path}")
    return result


if __name__ == "__main__":
    args = [a for a in sys.argv[1:]]
    smoke = "--smoke" in args
    args = [a for a in args if a != "--smoke"]
    run(args[0] if args else "BENCH_incremental.json", smoke=smoke)
