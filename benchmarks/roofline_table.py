"""Generate the §Roofline table: raw + scan-corrected terms per cell.

Reads the raw sweep (benchmarks/results/dryrun.jsonl), adds the
unroll-delta corrected terms (repro.analysis.corrected), recomputes the
three roofline times and the dominant bottleneck from the corrected
values, and writes benchmarks/results/roofline.jsonl + a markdown table.

  PYTHONPATH=src python -m benchmarks.roofline_table
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import json
import sys


def main():
    from repro.analysis import roofline as R
    from repro.analysis.corrected import corrected_cell
    from repro.configs import get_arch
    from repro.launch.dryrun import model_flops_for

    raw = {}
    for line in open("benchmarks/results/dryrun.jsonl"):
        r = json.loads(line)
        if r.get("status") == "ok" and r["mesh"] == "pod16x16":
            raw[(r["arch"], r["shape"])] = r

    rows = []
    only = sys.argv[1:] or None
    for (arch, shape), r in sorted(raw.items()):
        if only and arch not in only:
            continue
        try:
            corr = corrected_cell(arch, shape)
        except Exception as e:
            print(f"# corrected failed for {arch}/{shape}: {e}",
                  file=sys.stderr)
            corr = None
        bundle = get_arch(arch)
        model = model_flops_for(bundle, shape)
        if corr is None:
            flops = r["hlo_flops"]
            bytes_ = r["t_memory_s"] * R.HBM_BW
            t_coll = r["t_collective_s"]
        else:
            flops, bytes_, coll = (corr["flops"], corr["bytes"],
                                   corr["coll_bytes"])
            t_coll = coll / R.ICI_BW
        t_comp = flops / R.PEAK_FLOPS_BF16
        t_mem = bytes_ / R.HBM_BW
        terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
        bneck = max(terms, key=terms.get)
        useful = model / (flops * 256) if flops else 0.0
        roofline_frac = t_comp / max(t_comp, t_mem, t_coll)
        row = {
            "arch": arch, "shape": shape, "mesh": "pod16x16", "chips": 256,
            "t_compute_s": t_comp, "t_memory_s": t_mem,
            "t_collective_s": t_coll, "bottleneck": bneck,
            "useful_frac": useful, "roofline_frac": roofline_frac,
            "peak_mem_gb": r["peak_mem_gb"],
            "raw_t_compute_s": r["t_compute_s"],
            "raw_t_memory_s": r["t_memory_s"],
            "raw_t_collective_s": r["t_collective_s"],
            "corrected": corr is not None,
            "notes": corr.get("notes", "") if corr else "raw-only",
        }
        rows.append(row)
        print(f"{arch:24s} {shape:14s} comp={t_comp:9.3e} mem={t_mem:9.3e} "
              f"coll={t_coll:9.3e} {bneck:10s} useful={useful:6.3f} "
              f"rf={roofline_frac:6.3f}")

    with open("benchmarks/results/roofline.jsonl", "w") as fh:
        for row in rows:
            fh.write(json.dumps(row) + "\n")
    print(f"# wrote {len(rows)} rows")


if __name__ == "__main__":
    main()
