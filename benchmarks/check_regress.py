"""Benchmark regression gate: fresh BENCH_*.json vs committed floors.

``benchmarks/baselines.json`` records the headline invariants the
benchmarks must keep — simulator/model agreement error, incremental
repair beating a rebuild, the fused megakernel's bit-identity and
speedup floor, the tracing-overhead budget.  This script diffs a fresh
benchmark run against those floors and exits non-zero on any miss, so
the nightly job fails loudly instead of letting a regression coast in a
JSON artifact nobody reads.

Bounds are deliberately machine-independent (booleans, ratios, relative
errors) rather than wall-clock numbers: the gate must hold on a slow CI
runner as well as a dev box.

Usage:  PYTHONPATH=src python -m benchmarks.check_regress [--dir DIR]

``--dir`` points at the directory holding the fresh ``BENCH_*.json``
files (default: current directory).  A baseline file that is absent
from the directory is reported and counts as a failure — a benchmark
that silently stopped producing output is itself a regression.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

BASELINES = os.path.join(os.path.dirname(__file__), "baselines.json")


def lookup(blob, dotted: str):
    """Walk a dotted path through dicts and lists ('a.2.b')."""
    cur = blob
    for part in dotted.split("."):
        if isinstance(cur, list):
            cur = cur[int(part)]
        else:
            cur = cur[part]
    return cur


def check_value(value, bound: dict) -> tuple[bool, str]:
    """Apply one bound; returns (ok, human-readable verdict)."""
    if "equals" in bound:
        want = bound["equals"]
        return value == want, f"{value!r} == {want!r}"
    if "max" in bound:
        return value <= bound["max"], f"{value} <= {bound['max']}"
    if "min" in bound:
        return value >= bound["min"], f"{value} >= {bound['min']}"
    return False, f"unknown bound {bound!r}"


def run(bench_dir: str = ".", baselines_path: str = BASELINES) -> int:
    with open(baselines_path) as fh:
        baselines = json.load(fh)
    failures = 0
    checks = 0
    for fname, bounds in baselines.items():
        if fname.startswith("_"):
            continue
        path = os.path.join(bench_dir, fname)
        if not os.path.exists(path):
            print(f"FAIL {fname}: missing (benchmark produced no output)")
            failures += 1
            continue
        with open(path) as fh:
            blob = json.load(fh)
        for dotted, bound in bounds.items():
            checks += 1
            try:
                value = lookup(blob, dotted)
            except (KeyError, IndexError, TypeError):
                print(f"FAIL {fname}:{dotted}: path missing from output")
                failures += 1
                continue
            ok, verdict = check_value(value, bound)
            tag = "ok  " if ok else "FAIL"
            print(f"{tag} {fname}:{dotted}: {verdict}")
            failures += 0 if ok else 1
    print(
        f"# {checks} checks, {failures} failures"
        if failures
        else f"# all {checks} checks passed"
    )
    return 1 if failures else 0


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--dir", default=".", help="directory holding fresh BENCH_*.json"
    )
    ap.add_argument("--baselines", default=BASELINES)
    args = ap.parse_args()
    sys.exit(run(args.dir, args.baselines))


if __name__ == "__main__":
    main()
