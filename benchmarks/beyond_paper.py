"""Beyond-paper applications of the latency-bound replication algorithm.

The paper targets graph queries; the same formalism (objects, causal
access paths, latency = distributed traversals) applies to two placement
problems inside this framework:

* **MoE expert placement** — token-group -> expert dispatches are 1-hop
  causal paths; zipf-skewed router popularity means a few hot experts
  dominate tail dispatch latency.  Replicating hot experts with the
  greedy algorithm bounds the tail at a fraction of full replication.

* **RecSys hot rows** — user -> behavior-row -> candidate-row lookups are
  1-2-hop paths over sharded embedding tables; replicating heavy-hitter
  rows bounds tail lookup latency.

Both report: tail traversal count + replication cost at each bound t vs
(a) no replication and (b) full replication of the touched objects.
"""
import numpy as np

from benchmarks.common import emit
from repro.core import (
    is_latency_feasible,
    query_latencies,
    replicate_workload,
    single_site_oracle,
)
from repro.workload import (
    expert_shard,
    moe_workload_materialized,
    recsys_workload_materialized,
)


def run():
    # --- MoE expert replication (qwen3-like: 128 experts, top-8)
    n_groups, n_experts, n_servers = 64, 128, 16
    ps = moe_workload_materialized(n_groups, n_experts, 8,
                                   n_queries=3000, zipf_a=1.2, seed=0)
    shard = expert_shard(n_groups, n_experts, n_servers)
    base_lat = query_latencies(
        ps, __import__("repro.core", fromlist=["ReplicationScheme"])
        .ReplicationScheme.from_sharding(shard, n_servers))
    emit("moe_experts", "p99_traversals_base",
         float(np.percentile(base_lat, 99)))
    for t in (0, 1):
        scheme, stats = replicate_workload(ps, shard, n_servers, t)
        lq = query_latencies(ps, scheme)
        # replicas counted over expert objects only
        expert_mask = scheme.mask[n_groups:]
        emit("moe_experts", "p99_traversals",
             float(np.percentile(lq, 99)), t=t)
        emit("moe_experts", "expert_replicas",
             int(expert_mask.sum()) - n_experts, t=t)
        emit("moe_experts", "feasible", is_latency_feasible(ps, scheme, t),
             t=t)
    full = n_experts * (n_servers - 1)  # replicate-everything baseline
    emit("moe_experts", "full_replication_replicas", full)

    # --- RecSys hot-row replication (MIND-like tables)
    n_users, n_items, n_servers = 2000, 20000, 8
    ps = recsys_workload_materialized(
        n_users, n_items, n_requests=2000, zipf_a=1.3, seed=0)
    shard = np.concatenate([
        np.arange(n_users) % n_servers,
        np.arange(n_items) % n_servers]).astype(np.int32)
    for t in (0, 1, 2):
        scheme, stats = replicate_workload(ps, shard, n_servers, t)
        lq = query_latencies(ps, scheme)
        emit("recsys_rows", "p99_traversals",
             float(np.percentile(lq, 99)), t=t)
        emit("recsys_rows", "row_replicas", scheme.replica_count(), t=t)
    oracle = single_site_oracle(ps, shard, n_servers)
    emit("recsys_rows", "oracle_replicas", oracle.replica_count())
