"""Policy-aware greedy provisioning benchmark: price replicas under the
routing policy you serve with.

Each workload family (SNB / GNN / recsys) runs its three drift phases as
an online sequence — phase 0 provisions from scratch, later phases ship
incremental deltas (``replicate_delta`` over the paths that appeared) —
under two provisioning pipelines with identical budgets:

  ``hf`` / ``hf+prune``  home-first greedy (the paper's Alg 1/2
                verbatim): every candidate priced as if remote hops
                always pay the trip to the object's home server; the
                PR-4 recovery then *post-hoc* prunes every replica the
                ``nearest_copy`` walk does not need.  The prune refunds
                resident storage — but the bytes were already **paid**:
                provisioned, shipped to their servers, then dropped.
  ``policy``    PR-5 policy-aware greedy
                (``replicate_workload``/``replicate_delta`` with
                ``policy="nearest_copy"``): every batch gates its paths
                on the *routed* latency against the evolving scheme and
                rebuilds the per-budget C(h, t) tables on the surviving
                paths, so replicas the router never uses are not bought
                in the first place; the same-policy prune runs once at
                the end of the sequence.

Two cost metrics, both at nearest_copy-scored feasibility over the
phase-union workload:

  * **shipped bytes** — every replica ever provisioned across the
    sequence (construction + deltas): what the cluster actually paid in
    placement traffic and transient storage.  The post-hoc prune cannot
    refund these; the routed gate avoids them up front.
  * **resident bytes** — final storage after each pipeline's prune.

Acceptance gates (asserted):

  * ``policy`` shipped bytes <= ``hf+prune`` shipped bytes at >= equal
    nearest_copy feasibility on at least two of the three families
    (the prune ships nothing, so its arm pays full home-first freight);
  * ``policy`` resident bytes <= plain ``hf`` resident bytes on every
    family (the gate + end-of-sequence prune never leave more storage
    than un-pruned home-first greedy);
  * ``replicate_workload(policy="home_first")`` stays bit-identical to
    the pre-refactor driver (checked on the SNB family).

Usage: PYTHONPATH=src python -m benchmarks.provisioning_policies [--smoke] [out.json]
"""
from __future__ import annotations

import json
import sys
import time

import numpy as np

from benchmarks.common import emit
from repro.core import replicate_delta, replicate_workload
from repro.core.paths import PathSet
from repro.core.replication import prune_scheme_replicas
from repro.engine import LatencyEngine
from repro.graph import make_sharding, snb_like
from repro.serve import drift_stream, gnn_drift, recsys_drift, snb_drift

N_SERVERS = 6
T = 1
SCORE_POLICY = "nearest_copy"


def _families(smoke: bool):
    """(name, drift phases, shard, f) per workload family."""
    q = 120 if smoke else 320
    snb = snb_like(1, seed=0)
    g = snb.graph
    f_g = g.object_sizes().astype(np.float32)
    shard_g = make_sharding("hash", g, N_SERVERS, seed=0)

    yield (
        "snb",
        snb_drift(snb, n_phases=3, queries_per_phase=q, hot_prob=0.9, seed=0),
        shard_g,
        f_g,
    )
    yield (
        "gnn",
        gnn_drift(g, n_phases=3, queries_per_phase=max(q // 2, 60),
                  fanouts=(5, 3), hot_prob=0.9, seed=0),
        shard_g,
        f_g,
    )
    n_users, n_items = 600, 4000
    yield (
        "recsys",
        recsys_drift(n_users, n_items, n_phases=3, queries_per_phase=q,
                     hot_prob=0.9, seed=0),
        np.concatenate(
            [np.arange(n_users) % N_SERVERS, np.arange(n_items) % N_SERVERS]
        ).astype(np.int32),
        np.ones(n_users + n_items, np.float32),
    )


def _drift_sequence(deltas, shard, f, policy):
    """Provision phase 0, ship deltas for later phases; returns
    (scheme, engine, shipped_bytes, routed_skips)."""
    f64 = np.asarray(f, np.float64)
    kw = {"policy": policy, "policy_prune": False} if policy else {}
    scheme, stats, eng = replicate_workload(
        deltas[0].pathset, shard, N_SERVERS, t=T, f=f, return_engine=True,
        **kw,
    )
    repl = scheme.mask.copy()
    repl[np.arange(scheme.n_objects), scheme.shard] = False
    shipped = float(f64[np.nonzero(repl)[0]].sum())
    skips = stats.routed_skips
    for d in deltas[1:]:
        if d.added.n_paths == 0:
            continue
        st, (add_obj, _) = replicate_delta(
            d.added, eng, T, f=f, policy=policy or None
        )
        shipped += float(f64[add_obj].sum())
        skips += st.routed_skips
    return scheme, eng, shipped, skips


def run(out_path: str = "BENCH_provisioning.json", smoke: bool = False) -> dict:
    result: dict = {
        "t": T,
        "score_policy": SCORE_POLICY,
        "n_servers": N_SERVERS,
        "smoke": smoke,
        "families": {},
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
    }
    wins = 0
    for name, phases, shard, f in _families(smoke):
        deltas = list(drift_stream(phases))
        union = PathSet.concatenate([p.pathset for p in phases])
        f64 = np.asarray(f, np.float64)
        orig = float(f64.sum())

        def resident(scheme):
            return round(float(scheme.storage_per_server(f).sum()) - orig, 1)

        def feas(scheme):
            slack = LatencyEngine(scheme).query_slack(
                union, T, policy=SCORE_POLICY
            )
            return round(float((slack >= 0).mean()), 4)

        fam: dict = {
            "paths": union.n_paths,
            "queries": union.n_queries,
            "phases": len(deltas),
        }

        # -- home-first pipeline (+ post-hoc prune) -----------------------
        t0 = time.perf_counter()
        s_hf, _, shipped_hf, _ = _drift_sequence(deltas, shard, f, None)
        fam["hf"] = {
            "shipped_bytes": round(shipped_hf, 1),
            "resident_bytes": resident(s_hf),
            "feasible_frac": feas(s_hf),
            "runtime_s": round(time.perf_counter() - t0, 2),
        }
        if name == "snb":
            # acceptance: policy="home_first" stays bit-identical to the
            # pre-refactor greedy (checked on the from-scratch phase)
            s_id, _ = replicate_workload(
                deltas[0].pathset, shard, N_SERVERS, t=T, f=f,
                policy="home_first",
            )
            s_plain, _ = replicate_workload(
                deltas[0].pathset, shard, N_SERVERS, t=T, f=f
            )
            assert np.array_equal(s_plain.mask, s_id.mask), (
                "policy='home_first' diverged from the pre-refactor greedy"
            )
            fam["home_first_bit_identical"] = True

        t0 = time.perf_counter()
        s_pr = s_hf.copy()
        n_dropped, _ = prune_scheme_replicas(
            s_pr, union, T, policy=SCORE_POLICY, f=f
        )
        fam["hf_prune"] = {
            # the prune drops local copies; it ships nothing back
            "shipped_bytes": round(shipped_hf, 1),
            "resident_bytes": resident(s_pr),
            "feasible_frac": feas(s_pr),
            "replicas_dropped": n_dropped,
            "runtime_s": round(time.perf_counter() - t0, 2),
        }

        # -- policy-aware pipeline ----------------------------------------
        t0 = time.perf_counter()
        s_pa, _, shipped_pa, skips = _drift_sequence(
            deltas, shard, f, SCORE_POLICY
        )
        n_pa_drop, _ = prune_scheme_replicas(
            s_pa, union, T, policy=SCORE_POLICY, f=f
        )
        fam["policy"] = {
            "shipped_bytes": round(shipped_pa, 1),
            "resident_bytes": resident(s_pa),
            "feasible_frac": feas(s_pa),
            "routed_skips": skips,
            "replicas_dropped": n_pa_drop,
            "runtime_s": round(time.perf_counter() - t0, 2),
        }

        fam["policy_le_prune_shipped"] = bool(
            fam["policy"]["shipped_bytes"] <= fam["hf_prune"]["shipped_bytes"]
        )
        fam["policy_ge_prune_feasibility"] = bool(
            fam["policy"]["feasible_frac"] >= fam["hf_prune"]["feasible_frac"]
        )
        assert fam["policy"]["resident_bytes"] <= fam["hf"]["resident_bytes"], (
            f"{name}: policy-aware resident bytes exceed un-pruned home-first"
        )
        if fam["policy_le_prune_shipped"] and fam["policy_ge_prune_feasibility"]:
            wins += 1
        result["families"][name] = fam
        for variant in ("hf", "hf_prune", "policy"):
            emit("provisioning", "shipped_bytes",
                 fam[variant]["shipped_bytes"], family=name, variant=variant)
            emit("provisioning", "resident_bytes",
                 fam[variant]["resident_bytes"], family=name, variant=variant)
            emit("provisioning", "feasible_frac",
                 fam[variant]["feasible_frac"], family=name, variant=variant)

    result["families_policy_wins"] = wins
    assert wins >= 2, (
        "policy-aware greedy must ship <= home_first+prune bytes at >= "
        f"equal nearest_copy feasibility on >= 2 families (got {wins})"
    )

    with open(out_path, "w") as fh:
        json.dump(result, fh, indent=2)
    print(f"# wrote {out_path}")
    return result


if __name__ == "__main__":
    args = [a for a in sys.argv[1:]]
    smoke = "--smoke" in args
    args = [a for a in args if a != "--smoke"]
    run(args[0] if args else "BENCH_provisioning.json", smoke=smoke)
