"""Paper Fig 6: fine-tuning latency vs replication with the constraint t.

  6a/6b — SNB: mean + p99 latency (and normalized slowdown) vs t,
          replication overhead vs t
  6c     — SNB relative throughput vs t
  6d/6e  — GNN sampling: the same
  6f     — GNN relative throughput vs t
"""
import numpy as np

from benchmarks.common import build_gnn_setup, build_snb_setup, emit, timer
from repro.core import replicate_workload
from repro.distsys import Cluster, LatencyModel, execute_workload

TS = [0, 1, 2, 3, 4, -1]  # -1 = no constraint (t = inf)


def _sweep(tag, ps, shard, n_servers, f):
    base = {}
    for t in TS:
        if t < 0:
            from repro.core import ReplicationScheme

            scheme = ReplicationScheme.from_sharding(shard, n_servers)
            feasible = True
        else:
            # the greedy driver hands back its device-resident engine, so
            # the feasibility sweep re-uses the packed scheme in place.
            scheme, stats, eng = replicate_workload(
                ps, shard, n_servers, t, f=f.astype(np.float32),
                return_engine=True)
            feasible = eng.is_feasible(ps, t)
        rep = execute_workload(Cluster(scheme, f=f), ps, LatencyModel(),
                               seed=0)
        s = rep.summary()
        tstr = "inf" if t < 0 else t
        emit(tag, "feasible", feasible, t=tstr)
        emit(tag, "mean_us", round(s["mean_us"], 1), t=tstr)
        emit(tag, "p99_us", round(s["p99_us"], 1), t=tstr)
        emit(tag, "overhead", round(scheme.replication_overhead(f), 4),
             t=tstr)
        emit(tag, "qps", round(s["throughput_qps"], 0), t=tstr)
        if t == 0:
            base["mean"] = s["mean_us"]
            base["qps"] = s["throughput_qps"]
        if base:
            emit(tag, "slowdown_vs_t0",
                 round(s["mean_us"] / base["mean"], 2), t=tstr)
            emit(tag, "rel_qps", round(s["throughput_qps"] / base["qps"], 3),
                 t=tstr)


def run():
    snb, ps, shard = build_snb_setup(sharding="hash")
    _sweep("fig6_snb", ps, shard, 6, snb.graph.object_sizes())

    g, gps, gshard = build_gnn_setup(sharding="mincut")
    _sweep("fig6_gnn", gps, gshard, 6, g.object_sizes())
