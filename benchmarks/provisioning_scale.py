"""Provisioning at production scale: fused megakernel vs separate dispatch.

Two arms build the same scheme on the SNB drift union (``n_servers=6``,
``t=1``, ``nearest_copy`` pricing, prune included):

  ``separate``  the PR-5 pipeline — per batch, a host-driven routed-gate
                dispatch, the UPDATE dispatch, and three blocking stat
                readbacks; then the serial per-candidate prune sweep
                (~3 dispatches per candidate).
  ``fused``     one ``_fused_update_batch`` jit step per batch (gate +
                candidate scoring + bit-test + scatter-OR in a single
                dispatch, stats reduced on device) and the batched
                independent-group prune (~1 dispatch per group).

Both arms are run twice and the second (warm) run is timed, so the
comparison excludes jit compilation.  Asserted, not just reported:

  * the two arms produce **bit-identical** schemes (pre- and post-prune);
  * fused is >= 5x faster end-to-end (>= 2x under ``--smoke``, where the
    problem is too small to amortize per-batch overheads);
  * the servers x paths scale grid tops out at ``n_servers=128`` x
    >= 100k synthetic paths provisioned through **streamed ingestion**
    (``replicate_stream``), with peak host-resident paths < the total
    path count (the PathStream residency contract).

Usage: PYTHONPATH=src python -m benchmarks.provisioning_scale [--smoke] [out.json]
"""
from __future__ import annotations

import json
import sys
import time

import numpy as np

from benchmarks.common import emit
from repro.core.greedy import replicate_stream, replicate_workload
from repro.core.paths import PathSet
from repro.core.replication import prune_scheme_replicas
from repro.engine import PathStream
from repro.graph import make_sharding, snb_like
from repro.serve import snb_drift

N_SERVERS = 6
T = 1
POLICY = "nearest_copy"
# tier-1 guard budget for the default (smoke) grid point, cold compile
# included — tests/test_provision_scale.py fails loudly past this
DEFAULT_BUDGET_S = 120.0

STREAM_CHUNK = 8192
SCALE_PATH_LEN = 6


def snb_union(smoke: bool):
    """The PR-5 benchmark workload: SNB drift phases, concatenated."""
    q = 120 if smoke else 320
    snb = snb_like(1, seed=0)
    g = snb.graph
    f = g.object_sizes().astype(np.float32)
    shard = make_sharding("hash", g, N_SERVERS, seed=0)
    phases = snb_drift(snb, n_phases=3, queries_per_phase=q, hot_prob=0.9,
                       seed=0)
    union = PathSet.concatenate([p.pathset for p in phases])
    return union, shard, f


def run_pipeline(union, shard, f, fused: bool):
    """One provisioning pipeline end-to-end; returns (mask, seconds)."""
    t0 = time.perf_counter()
    scheme, _ = replicate_workload(
        union, shard, N_SERVERS, t=T, f=f, policy=POLICY,
        policy_prune=False, fused=fused,
    )
    prune_scheme_replicas(scheme, union, T, policy=POLICY, f=f, fused=fused)
    return scheme.mask, time.perf_counter() - t0


def default_grid_point():
    """The tier-1 guard target: smoke union, fused arm, cold compile.

    Returns (runtime_s, mask); the guard asserts runtime < DEFAULT_BUDGET_S.
    """
    union, shard, f = snb_union(smoke=True)
    mask, secs = run_pipeline(union, shard, f, fused=True)
    return secs, mask


def synthetic_stream(n_paths: int, n_objects: int, seed: int,
                     chunk: int = STREAM_CHUNK):
    """Zipf-skewed fixed-length synthetic paths, yielded chunk-by-chunk.

    A generator — each chunk is materialized on demand and dropped after
    the yield, so host residency peaks at ``chunk`` paths.
    """
    rng = np.random.default_rng(seed)
    L = SCALE_PATH_LEN
    for start in range(0, n_paths, chunk):
        rows = min(chunk, n_paths - start)
        # zipf-ish skew: low object ids are hot (drift hotsets at scale)
        raw = rng.zipf(1.3, size=(rows, L)).astype(np.int64)
        objects = ((raw - 1) % n_objects).astype(np.int32)
        lengths = np.full(rows, L, np.int32)
        yield PathSet(objects, lengths, np.arange(rows, dtype=np.int32))


def run_scale_point(n_servers: int, n_paths: int, smoke: bool):
    """One streamed grid point; returns the result row (asserts residency)."""
    n_objects = max(4 * n_servers, n_paths // 8)
    shard = (np.arange(n_objects) % n_servers).astype(np.int32)
    stream = PathStream(synthetic_stream(n_paths, n_objects, seed=n_servers))
    t0 = time.perf_counter()
    scheme, stats = replicate_stream(
        stream, shard, n_servers, t=T, fused=True,
        batch_size=1024, prune=False,
    )
    secs = time.perf_counter() - t0
    assert stats.peak_resident_paths < stats.paths_processed, (
        f"streamed ingestion held {stats.peak_resident_paths} paths "
        f"host-resident out of {stats.paths_processed} — not a stream"
    )
    assert stats.failed_paths == 0
    return {
        "n_servers": n_servers,
        "n_paths": int(stats.paths_processed),
        "peak_resident_paths": int(stats.peak_resident_paths),
        "chunks": stream.stats.chunks,
        "replicas": int(stats.replicas),
        "runtime_s": round(secs, 2),
        "paths_per_s": round(stats.paths_processed / max(secs, 1e-9), 1),
    }


def run(out_path: str = "BENCH_scale.json", smoke: bool = False) -> dict:
    result: dict = {
        "t": T,
        "policy": POLICY,
        "smoke": smoke,
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
    }

    # -- fused vs separate on the SNB union (bit-identical + speedup) ------
    union, shard, f = snb_union(smoke)
    result["union_paths"] = union.n_paths
    arms = {}
    for name, fused in (("separate", False), ("fused", True)):
        run_pipeline(union, shard, f, fused)          # warm (jit compile)
        mask, secs = run_pipeline(union, shard, f, fused)
        arms[name] = (mask, secs)
        emit("provisioning_scale", "runtime_s", round(secs, 3), arm=name,
             n_servers=N_SERVERS, paths=union.n_paths)
    assert np.array_equal(arms["separate"][0], arms["fused"][0]), (
        "fused megakernel pipeline diverged from the separate-dispatch "
        "pipeline (schemes must be bit-identical)"
    )
    speedup = arms["separate"][1] / max(arms["fused"][1], 1e-9)
    floor = 2.0 if smoke else 5.0
    assert speedup >= floor, (
        f"fused pipeline speedup {speedup:.2f}x < required {floor}x "
        f"(separate {arms['separate'][1]:.2f}s, fused {arms['fused'][1]:.2f}s)"
    )
    result["snb_union"] = {
        "separate_s": round(arms["separate"][1], 3),
        "fused_s": round(arms["fused"][1], 3),
        "speedup": round(speedup, 2),
        "speedup_floor": floor,
        "bit_identical": True,
    }
    emit("provisioning_scale", "speedup", round(speedup, 2),
         n_servers=N_SERVERS, paths=union.n_paths)

    # -- servers x paths scale grid, streamed ingestion --------------------
    grid = [(16, 20_000), (128, 12_000)] if smoke else [
        (16, 20_000), (32, 50_000), (128, 100_000),
    ]
    result["scale_grid"] = []
    for n_servers, n_paths in grid:
        row = run_scale_point(n_servers, n_paths, smoke)
        result["scale_grid"].append(row)
        emit("provisioning_scale", "paths_per_s", row["paths_per_s"],
             n_servers=n_servers, paths=row["n_paths"])
    if not smoke:
        top = result["scale_grid"][-1]
        assert top["n_servers"] == 128 and top["n_paths"] >= 100_000, (
            "scale grid must top out at n_servers=128 x >=100k paths"
        )

    with open(out_path, "w") as fh:
        json.dump(result, fh, indent=2)
    print(f"# wrote {out_path}")
    return result


if __name__ == "__main__":
    args = list(sys.argv[1:])
    smoke = "--smoke" in args
    args = [a for a in args if a != "--smoke"]
    run(args[0] if args else "BENCH_scale.json", smoke=smoke)
