"""Multi-tenant SLO benchmark: vector-t frontier + per-tenant serving.

Three measurements, written to ``BENCH_tenants.json`` (and emitted as CSV
rows via ``benchmarks.common``):

  1. **scalar-vs-vector parity** — on the fig6 SNB workload,
     ``replicate_workload(t=k)`` and
     ``replicate_workload(SLOSpec.uniform(k))`` must produce bit-identical
     replication masks (the degenerate case really is degenerate);
  2. **replication-cost frontier** — a two-tenant workload (SNB short
     reads + GNN sampling over the same graph/object space): the GNN
     tenant's t_Q tightens step by step while SNB's holds, and the
     f-weighted replication overhead must rise monotonically — the
     cost-of-SLO curve a capacity planner reads;
  3. **per-tenant p99 under drift** — both tenants' hotspots move
     (scripted drift phases); the drifted phase is served at load on the
     static phase-0 scheme and on a cluster repaired by the multi-tenant
     arbitrating controller.  The controller run must show a lower p99
     for every tenant.

Usage: PYTHONPATH=src python -m benchmarks.tenant_frontier [out.json]
"""
from __future__ import annotations

import json
import sys
import time

import numpy as np

from benchmarks.common import build_snb_setup, emit
from repro.core import PathSet, SLOSpec, replicate_workload
from repro.distsys import Cluster, LatencyModel
from repro.graph import make_sharding, snb_like
from repro.serve import (
    AdaptiveController,
    ControllerConfig,
    gnn_drift,
    simulate,
    snb_drift,
)
from repro.workload import (
    gnn_workload_materialized,
    multi_tenant_workload,
    snb_workload_materialized,
)

N_SERVERS = 6
T_SNB = 1                      # the holding tenant's budget
GNN_SWEEP = (3, 2, 1, 0)       # the tightening tenant's budgets
QUERIES_PER_PHASE = 400
BATCH_QUERIES = 100
DRIFT_RATE_QPS = 20_000.0


def _parity(result: dict) -> None:
    """Scalar t and SLOSpec.uniform(t) must produce identical masks."""
    _, ps, shard = build_snb_setup(sharding="hash")
    rows = []
    for t in (0, 1, 2):
        a, _ = replicate_workload(ps, shard, N_SERVERS, t)
        b, _ = replicate_workload(
            ps, shard, N_SERVERS, SLOSpec.uniform(t, ps.n_queries)
        )
        same = bool(np.array_equal(a.mask, b.mask))
        rows.append({"t": t, "masks_identical": same})
        emit("tenant_frontier", "scalar_vector_parity", same, t=t)
        assert same, f"scalar t={t} and SLOSpec.uniform({t}) masks diverge"
    result["parity"] = rows


def _frontier(result: dict) -> None:
    """Cost frontier as the GNN tenant's t_Q tightens while SNB holds."""
    snb = snb_like(1, seed=0)
    g = snb.graph
    f = g.object_sizes().astype(np.float32)
    shard = make_sharding("hash", g, N_SERVERS, seed=0)
    rng = np.random.default_rng(0)
    sps = snb_workload_materialized(snb, n_queries=500, seed=0)
    gps = gnn_workload_materialized(
        g, rng.integers(0, g.n_nodes, 250), (6, 4), seed=0
    )
    rows = []
    prev = -1.0
    for t_gnn in GNN_SWEEP:
        ps, slo = multi_tenant_workload(
            [("snb", sps), ("gnn", gps)],
            budgets={"snb": T_SNB, "gnn": t_gnn},
        )
        scheme, stats = replicate_workload(ps, shard, N_SERVERS, slo, f=f)
        overhead = scheme.replication_overhead(f)
        rows.append(
            {
                "t_snb": T_SNB,
                "t_gnn": t_gnn,
                "overhead": round(overhead, 4),
                "replicas": stats.replicas,
                "failed_paths": stats.failed_paths,
            }
        )
        emit("tenant_frontier", "overhead", round(overhead, 4),
             t_gnn=t_gnn, t_snb=T_SNB)
        assert overhead >= prev - 1e-9, (
            "replication cost must not drop as one tenant's t_Q tightens"
        )
        prev = overhead
    result["frontier"] = rows
    result["frontier_monotone"] = True


def _drift(result: dict) -> None:
    """Per-tenant p99 on the drifted phase: static vs controller-on."""
    snb = snb_like(1, seed=0)
    g = snb.graph
    f = g.object_sizes().astype(np.float32)
    shard = make_sharding("hash", g, N_SERVERS, seed=0)
    model = LatencyModel()

    s_phases = snb_drift(
        snb, n_phases=3, queries_per_phase=QUERIES_PER_PHASE, seed=0
    )
    g_phases = gnn_drift(
        g, n_phases=3, queries_per_phase=QUERIES_PER_PHASE // 2,
        fanouts=(6, 4), seed=0,
    )
    # gnn serves at budget 1 here: its 2-hop sampling paths are trivially
    # within the family default t=2, which would leave the drifted phase
    # with nothing to repair (and nothing to measure)
    phases = [
        multi_tenant_workload(
            [("snb", sp.pathset), ("gnn", gp.pathset)],
            budgets={"snb": T_SNB, "gnn": 1},
        )
        for sp, gp in zip(s_phases, g_phases)
    ]

    ps0, slo0 = phases[0]
    static_scheme, _ = replicate_workload(ps0, shard, N_SERVERS, slo0, f=f)
    static_cluster = Cluster(static_scheme, f=f)

    ctl_scheme = static_scheme.copy()
    ctl_cluster = Cluster(ctl_scheme, f=f)
    # finite capacity headroom => simultaneous tenant repairs arbitrate
    cap = float(static_scheme.storage_per_server(f).max() * 2.5)
    controller = AdaptiveController(
        ctl_cluster,
        ControllerConfig(
            window=4 * BATCH_QUERIES,
            min_queries=BATCH_QUERIES // 2,
            capacity=cap,
            demote_after=3,
            tenants=tuple(slo0.tenants),
        ),
        f=f,
    )
    deferrals = 0
    adaptations = 0
    for (ps, slo), sp, gp in zip(phases, s_phases, g_phases):
        # interleave the tenants within each served batch (they share the
        # cluster in production): one snb slice + one gnn slice per round,
        # so both windows fill together and their repairs can actually
        # contend for the capacity headroom
        n_s = sp.pathset.n_queries
        n_g = gp.pathset.n_queries
        rounds = max(1, -(-n_s // BATCH_QUERIES))
        bs_g = max(1, -(-n_g // rounds))
        for r in range(rounds):
            s_lo, s_hi = r * BATCH_QUERIES, min((r + 1) * BATCH_QUERIES, n_s)
            g_lo = n_s + r * bs_g
            g_hi = n_s + min((r + 1) * bs_g, n_g)
            sections = [
                (ps.select_queries(s_lo, s_hi), slo.select_queries(s_lo, s_hi)),
                (ps.select_queries(g_lo, g_hi), slo.select_queries(g_lo, g_hi)),
            ]
            batch = PathSet.concatenate([p for p, _ in sections])
            # align each section's spec to its pathset before concat:
            # PathSet.concatenate offsets by the pathset's query count,
            # which undercounts a slice whose trailing queries are pathless
            batch_slo = SLOSpec.concat(
                [s.align_to(p) for p, s in sections]
            )
            assert batch_slo.n_queries == batch.n_queries
            if batch.n_paths == 0:
                continue
            rep = simulate(
                ctl_cluster, batch, rate_qps=DRIFT_RATE_QPS, model=model,
                seed=r, slo=batch_slo,
            )
            act = controller.observe(
                batch, latency_us=rep.latency_us, slo=batch_slo,
            )
            if act is not None:
                adaptations += 1
                deferrals += len(act.deferred)

    drifted_ps, drifted_slo = phases[-1]
    per_tenant = []
    for name, cluster in (("static", static_cluster),
                          ("controller", ctl_cluster)):
        rep = simulate(
            cluster, drifted_ps, rate_qps=DRIFT_RATE_QPS, model=model,
            seed=7, slo=drifted_slo,
        )
        row = {"scheme": name, **rep.summary()["per_tenant"]}
        per_tenant.append(row)
        for tenant, ss in rep.summary()["per_tenant"].items():
            emit("tenant_frontier", "p99_us", round(ss["p99_us"], 1),
                 scheme=name, tenant=tenant)
    result["drift"] = {
        "adaptations": adaptations,
        "arbitration_deferrals": deferrals,
        "per_tenant_p99": per_tenant,
    }
    static_row, ctl_row = per_tenant
    improved = {
        t: ctl_row[t]["p99_us"] < static_row[t]["p99_us"]
        for t in ("snb", "gnn")
    }
    result["drift"]["controller_beats_static"] = improved
    assert all(improved.values()), (
        f"controller must lower every tenant's drifted-phase p99: {improved}"
    )


def run(out_path: str = "BENCH_tenants.json") -> dict:
    result: dict = {
        "n_servers": N_SERVERS,
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
    }
    _parity(result)
    _frontier(result)
    _drift(result)
    with open(out_path, "w") as fh:
        json.dump(result, fh, indent=2)
    print(f"# wrote {out_path}")
    return result


if __name__ == "__main__":
    run(sys.argv[1] if len(sys.argv) > 1 else "BENCH_tenants.json")
