"""§5.4 / §6: incremental replication-scheme update cost on reshard.

Compares the incremental path (RM transfer + repair of violated paths)
against re-running the full replication from scratch, for a server drain.
"""
import numpy as np

from benchmarks.common import build_snb_setup, emit, timer
from repro.core import (
    ReshardingMap,
    is_latency_feasible,
    repair_paths,
    replicate_workload,
)
from repro.core.reshard import drain_server


def run():
    t = 1
    snb, ps, shard = build_snb_setup(sharding="hash")
    f = snb.graph.object_sizes()

    scheme, stats = replicate_workload(
        ps, shard.copy(), 6, t, f=f.astype(np.float32), track_rm=True)
    rmap = ReshardingMap.from_entries(stats.rm, scheme.shard)
    emit("reshard", "initial_runtime_s", round(stats.runtime_s, 2))
    emit("reshard", "initial_replicas", scheme.replica_count())

    # incremental: drain one server (partition-preserving) + repair
    with timer() as tm:
        moves, rep = drain_server(scheme, rmap, 5, f, strategy="single")
        repair = repair_paths(scheme, rmap, ps, t, f)
    emit("reshard", "incremental_s", round(tm.dt, 2))
    emit("reshard", "transferred_replicas", rep.replicas_transferred)
    emit("reshard", "repaired_paths", repair["repaired_paths"])
    emit("reshard", "feasible_after", is_latency_feasible(ps, scheme, t))

    # from-scratch baseline on the new sharding
    new_shard = scheme.shard.copy()
    with timer() as tm2:
        scheme2, stats2 = replicate_workload(
            ps, new_shard, 6, t, f=f.astype(np.float32))
    emit("reshard", "scratch_s", round(tm2.dt, 2))
    emit("reshard", "speedup_vs_scratch",
         round(tm2.dt / max(tm.dt, 1e-9), 1))
