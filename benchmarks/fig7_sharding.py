"""Paper Fig 7: replication cost across initial data placements (SNB) +
the dangling-edges comparison (7d / Table 3)."""
import numpy as np

from benchmarks.common import build_snb_setup, emit
from repro.core import dangling_edge_replication, replicate_workload
from repro.graph import hash_partition, ldg_partition, ogb_like


def run():
    # --- 7a-c: replication overhead vs t per sharding scheme
    for kind in ("hash", "mincut", "hypergraph"):
        for n_srv in (3, 6):
            snb, ps, shard = build_snb_setup(n_servers=n_srv, sharding=kind)
            f = snb.graph.object_sizes()
            for t in (0, 1, 2, 3):
                scheme, _ = replicate_workload(
                    ps, shard, n_srv, t, f=f.astype(np.float32))
                emit("fig7", "overhead",
                     round(scheme.replication_overhead(f), 4),
                     sharding=kind, servers=n_srv, t=t)

    # --- 7d/Table 3: greedy (t = floor(n/2)) vs dangling-edge replication
    for kind in ("hash", "mincut"):
        snb, ps, shard = build_snb_setup(sharding=kind)
        g = snb.graph
        f = g.object_sizes()
        # dangling-edge k=1 enforces t = floor(max_hops/2); max path len
        # in the short-read mix is ~5 -> t = 2
        dang = dangling_edge_replication(g.indptr, g.indices, shard, 6, k=1)
        greedy, _ = replicate_workload(ps, shard, 6, t=2,
                                       f=f.astype(np.float32))
        emit("table3", "dangling_overhead",
             round(dang.replication_overhead(f), 4), sharding=kind)
        emit("table3", "greedy_overhead",
             round(greedy.replication_overhead(f), 4), sharding=kind)

    # GNN variant of Table 3 (OGB-like)
    from repro.workload import gnn_workload_materialized

    g = ogb_like(15000, seed=0)
    rng = np.random.default_rng(0)
    ps = gnn_workload_materialized(
        g, rng.integers(0, g.n_nodes, 200), (25, 10), seed=0)
    f = g.object_sizes()
    for kind, shard in (("hash", hash_partition(g.n_nodes, 6)),
                        ("mincut", ldg_partition(g, 6, passes=1))):
        dang = dangling_edge_replication(g.indptr, g.indices, shard, 6, k=1)
        greedy, _ = replicate_workload(ps, shard, 6, t=1,
                                       f=f.astype(np.float32))
        emit("table3_gnn", "dangling_overhead",
             round(dang.replication_overhead(f), 4), sharding=kind)
        emit("table3_gnn", "greedy_overhead",
             round(greedy.replication_overhead(f), 4), sharding=kind)
