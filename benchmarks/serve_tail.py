"""Serving-tail benchmark: p99 under load + adaptive-controller value.

Three measurements, written to ``BENCH_serve.json`` (and emitted as CSV
rows via ``benchmarks.common``):

  1. **low-load validation** — at near-zero utilization the discrete-event
     simulator's mean latency must match the closed-form executor model
     within 10% (same access counts, same RPC constants, queueing -> 0);
  2. **p99 vs offered load x {static scheme, controller-on}** — the
     workload's hotspot moves (scripted drift phase); the static scheme
     serves the drifted phase as-is, the controller-repaired scheme serves
     it after adaptation, both swept over offered load;
  3. **adaptation** — per drift phase: detection-to-feasible lag (queries
     and simulated time), bytes replicated by the incremental repair, and
     the same repair priced as a *from-scratch greedy rebuild* (bytes of
     new copies the rebuilt scheme would have to ship vs the pre-drift
     scheme).  The incremental path must ship strictly fewer bytes.
  4. **telemetry overhead + fidelity** — the same serve run with span
     tracing enabled vs disabled (best-of-N wall clock each; the tracing
     overhead must stay under 2%), the obs streaming histogram's p99 vs
     the exact ``np.percentile`` (must agree within one log bucket), and
     the burn-rate blame decomposition of the drifted phase's violations
     (which server ate the violators' budgets).
  5. **batched dispatch plane** — at saturation with a real per-dispatch
     cost, ladder-batched dispatch must hold p99 at or below per-query
     dispatch; deadline-aware admission at overload must improve the
     *surviving* p99 (with the per-tenant shed fraction reported next to
     it); SLO-driven hedging accounting; and the asyncio wall-clock
     harness must reproduce the simulator's p50/p99 within the stated
     <= 15% band at low load AND the batching win on a real clock.

Usage: PYTHONPATH=src python -m benchmarks.serve_tail [--smoke] [out.json]

``--smoke`` shrinks the wall-clock harness runs (fewer queries, smaller
time scale) for CI smoke passes; every assertion still fires.
"""
from __future__ import annotations

import json
import sys
import time

import numpy as np

from benchmarks.common import emit
from repro.core import replicate_workload
from repro.distsys import Cluster, LatencyModel
from repro.graph import make_sharding, snb_like
from repro.serve import (
    AdaptiveController,
    ControllerConfig,
    drift_stream,
    simulate,
    snb_drift,
)

T = 1
N_SERVERS = 6
QUERIES_PER_PHASE = 800
BATCH_QUERIES = 100
LOAD_SWEEP = (2_000, 20_000, 60_000, 120_000)


def _scheme_delta_bytes(old_mask, new_mask, f) -> float:
    """f-weighted bytes of copies present in ``new`` but not ``old``."""
    added = new_mask & ~old_mask
    return float((f[:, None] * added).sum())


def _serve_phase_with_controller(
    controller: AdaptiveController,
    cluster: Cluster,
    pathset,
    rate_qps: float,
    model: LatencyModel,
    seed: int,
) -> dict:
    """Feed one phase batch-by-batch; record adaptation lag + bytes."""
    nq = pathset.n_queries
    t_sim = 0.0
    lag_queries = None
    lag_sim_us = None
    bytes_added = 0.0
    replicas_added = 0
    n_adapts = 0
    served = 0
    for lo in range(0, nq, BATCH_QUERIES):
        batch = pathset.select_queries(lo, min(lo + BATCH_QUERIES, nq))
        if batch.n_paths == 0:
            continue
        rep = simulate(
            cluster, batch, rate_qps=rate_qps, model=model,
            seed=seed + lo,
        )
        served += batch.n_queries
        t_sim += float(rep.duration_us)
        act = controller.observe(batch, latency_us=rep.latency_us)
        if act is not None:
            n_adapts += 1
            bytes_added += act.bytes_added
            replicas_added += act.replicas_added
            if act.feasible_after and lag_queries is None:
                lag_queries = served
                lag_sim_us = t_sim
    return {
        "adaptations": n_adapts,
        "adaptation_lag_queries": lag_queries,
        "adaptation_lag_sim_us": lag_sim_us,
        "bytes_replicated": bytes_added,
        "replicas_added": replicas_added,
    }


def run(out_path: str = "BENCH_serve.json", smoke: bool = False) -> dict:
    snb = snb_like(1, seed=0)
    f = snb.graph.object_sizes().astype(np.float32)
    shard = make_sharding("hash", snb.graph, N_SERVERS, seed=0)
    model = LatencyModel()

    phases = snb_drift(
        snb, n_phases=3, queries_per_phase=QUERIES_PER_PHASE, seed=0
    )
    ps0 = phases[0].pathset

    # static scheme: greedy on the phase-0 workload only
    static_scheme, _ = replicate_workload(ps0, shard, N_SERVERS, t=T, f=f)
    static_cluster = Cluster(static_scheme, f=f)

    result: dict = {
        "t": T,
        "workload": {
            "n_servers": N_SERVERS,
            "queries_per_phase": QUERIES_PER_PHASE,
            "phase_paths": [p.pathset.n_paths for p in phases],
        },
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
    }

    # ------------------------------------------------------------------ 1.
    lo_sim = simulate(static_cluster, ps0, rate_qps=500, model=model, seed=1)
    from repro.distsys import execute_workload

    closed = execute_workload(Cluster(static_scheme, f=f), ps0, model, seed=1)
    rel_err = abs(lo_sim.mean_us - closed.mean_us) / closed.mean_us
    result["lowload_validation"] = {
        "sim_mean_us": round(lo_sim.mean_us, 2),
        "closed_form_mean_us": round(closed.mean_us, 2),
        "rel_err": round(rel_err, 4),
        "within_10pct": bool(rel_err < 0.10),
        "max_utilization": round(float(lo_sim.utilization().max()), 4),
    }
    emit("serve_tail", "lowload_rel_err", round(rel_err, 4))
    assert rel_err < 0.10, "simulator no longer matches the latency model"

    # ------------------------------------------------------------------ 3.
    # drive the drift through an adaptive controller on a fresh cluster
    ctl_scheme = static_scheme.copy()
    ctl_cluster = Cluster(ctl_scheme, f=f)
    controller = AdaptiveController(
        ctl_cluster,
        ControllerConfig(t=T, window=4 * BATCH_QUERIES, min_queries=BATCH_QUERIES),
        f=f,
    )
    drift_rows = []
    pre_drift_mask = static_scheme.mask.copy()
    for delta in drift_stream(phases):
        phase_rate = 20_000.0
        adapt = _serve_phase_with_controller(
            controller, ctl_cluster, delta.pathset, phase_rate, model,
            seed=100 + delta.phase,
        )
        # price the same phase as a from-scratch rebuild: greedy on the
        # observed phase workload, bytes = new copies vs the pre-drift
        # scheme (what a rebuild would have to ship to the cluster)
        rebuilt, _ = replicate_workload(
            delta.pathset, shard, N_SERVERS, t=T, f=f
        )
        rebuild_bytes = _scheme_delta_bytes(pre_drift_mask, rebuilt.mask, f)
        row = {
            "phase": delta.phase,
            "name": delta.name,
            "added_paths": delta.added.n_paths,
            "removed_paths": delta.n_removed,
            **adapt,
            "rebuild_bytes": rebuild_bytes,
            "incremental_lt_rebuild": bool(
                delta.phase == 0 or adapt["bytes_replicated"] < rebuild_bytes
            ),
        }
        drift_rows.append(row)
        emit(
            "serve_tail", "bytes_replicated", round(adapt["bytes_replicated"], 1),
            phase=delta.phase,
        )
        emit(
            "serve_tail", "rebuild_bytes", round(rebuild_bytes, 1),
            phase=delta.phase,
        )
        if adapt["adaptation_lag_queries"] is not None:
            emit(
                "serve_tail", "adaptation_lag_queries",
                adapt["adaptation_lag_queries"], phase=delta.phase,
            )
    result["drift"] = drift_rows
    drifted = [r for r in drift_rows if r["phase"] > 0 and r["adaptations"]]
    result["incremental_vs_rebuild_ok"] = bool(
        drifted and all(r["incremental_lt_rebuild"] for r in drifted)
    )
    assert result["incremental_vs_rebuild_ok"], (
        "incremental repair should ship strictly fewer bytes than a rebuild"
    )

    # ------------------------------------------------------------------ 2.
    # p99 vs offered load on the drifted phase: static vs controller-on
    drifted_ps = phases[-1].pathset
    sweep = []
    for qps in LOAD_SWEEP:
        srow = simulate(
            static_cluster, drifted_ps, rate_qps=qps, model=model, seed=7
        )
        crow = simulate(
            ctl_cluster, drifted_ps, rate_qps=qps, model=model, seed=7
        )
        sweep.append(
            {
                "offered_qps": qps,
                "static": {
                    "p50_us": round(srow.p50_us, 1),
                    "p99_us": round(srow.p99_us, 1),
                    "p999_us": round(srow.p999_us, 1),
                    "max_utilization": round(
                        float(srow.utilization().max()), 4
                    ),
                },
                "controller": {
                    "p50_us": round(crow.p50_us, 1),
                    "p99_us": round(crow.p99_us, 1),
                    "p999_us": round(crow.p999_us, 1),
                    "max_utilization": round(
                        float(crow.utilization().max()), 4
                    ),
                },
            }
        )
        emit("serve_tail", "p99_us", round(srow.p99_us, 1),
             qps=qps, scheme="static")
        emit("serve_tail", "p99_us", round(crow.p99_us, 1),
             qps=qps, scheme="controller")
    result["load_sweep"] = sweep

    # ------------------------------------------------------------------ 4.
    # tracing overhead: identical serve run, trace=None vs a live Tracer.
    # Interleaved base/traced pairs with best-of-N (min) per mode — the
    # minimum is the low-noise estimator of the work actually required,
    # and interleaving keeps a frequency/load drift mid-measurement from
    # billing the whole drift to one mode.
    from repro.obs import Histogram, Tracer, attribute_burn

    def once(tr):
        t1 = time.perf_counter()
        rep = simulate(
            static_cluster, drifted_ps, rate_qps=60_000, model=model,
            seed=11, trace=tr,
        )
        return time.perf_counter() - t1, rep

    once(None)  # warm caches before timing
    _, rep_off = once(None)
    p99_budget = float(np.percentile(rep_off.latency_us, 99.0))
    base_s = traced_s = float("inf")
    for _ in range(8):
        b, _ = once(None)
        tr_s, _ = once(Tracer(budget_us=p99_budget))
        base_s = min(base_s, b)
        traced_s = min(traced_s, tr_s)
    overhead = traced_s / base_s - 1.0
    tracer = Tracer(budget_us=p99_budget)
    rep_tr = simulate(
        static_cluster, drifted_ps, rate_qps=60_000, model=model,
        seed=11, trace=tracer,
    )
    assert np.allclose(rep_tr.latency_us, rep_off.latency_us), (
        "tracing changed simulated latencies"
    )

    # histogram fidelity: streamed log-bucket p99 vs exact, within one
    # bucket width (multiplicative error <= growth)
    hist = Histogram("serve.latency_us", lo=1.0, growth=1.1)
    hist.record_many(rep_tr.latency_us)
    exact_p99 = float(np.percentile(rep_tr.latency_us, 99.0))
    hist_p99 = hist.percentile(99.0)
    bucket_ok = hist_p99 / hist.growth <= exact_p99 <= hist_p99 * hist.growth
    assert bucket_ok, (
        f"histogram p99 {hist_p99:.1f} not within one bucket of {exact_p99:.1f}"
    )

    # blame: which server consumed the violators' budgets
    burn = attribute_burn(tracer, allowed_frac=0.01)
    blame = burn.summary()
    result["telemetry"] = {
        "baseline_best_s": round(base_s, 4),
        "traced_best_s": round(traced_s, 4),
        "tracing_overhead": round(overhead, 4),
        "spans_recorded": tracer.n_spans,
        "violations_kept": tracer.n_violations,
        "hist_p99_us": round(hist_p99, 1),
        "exact_p99_us": round(exact_p99, 1),
        "hist_within_one_bucket": bool(bucket_ok),
        "blame": blame,
    }
    emit("serve_tail", "tracing_overhead", round(overhead, 4))
    assert overhead < 0.02, (
        f"span tracing costs {overhead:.1%} — over the 2% budget"
    )

    # ------------------------------------------------------------------ 5.
    # the batched dispatch serving plane: ladder batching at saturation,
    # deadline-aware admission at overload, SLO hedging accounting, and
    # the wall-clock harness validation of all of it
    from repro.core.slo import SLOSpec
    from repro.serve import (
        AdmissionConfig,
        BatchingConfig,
        HedgePolicy,
        harness_simulate,
    )

    # 240 queries keeps the p99 estimate stable enough for the 15% band;
    # the time scale stays at 5e-4 even in smoke runs — shrinking it
    # pushes event-loop scheduling slop INTO the latencies being compared
    n_val = 240 if smoke else 400
    time_scale = 5e-4
    batch_model = LatencyModel(dispatch_us=20.0)
    val_ps = ps0.select_queries(0, min(n_val, ps0.n_queries))

    # 5a. batching at saturation: scarce slots + a real per-dispatch cost
    sat_kw = dict(rate_qps=120_000, model=batch_model, concurrency=2, seed=13)
    pq = simulate(static_cluster, val_ps, **sat_kw)
    bt = simulate(static_cluster, val_ps, batching=BatchingConfig(), **sat_kw)
    batched_wins = bool(bt.p99_us <= pq.p99_us)
    bsum = bt.batch_stats.summary()
    emit("serve_tail", "p99_us", round(pq.p99_us, 1), dispatch="per_query")
    emit("serve_tail", "p99_us", round(bt.p99_us, 1), dispatch="batched")
    assert batched_wins, (
        f"batched p99 {bt.p99_us:.1f} lost to per-query {pq.p99_us:.1f}"
    )

    # 5b. admission at overload: shed early, save the survivors' tail
    adm_slo = SLOSpec.uniform(T, val_ps.n_queries)
    adm_kw = dict(rate_qps=300_000, concurrency=2, seed=17, slo=adm_slo,
                  model=model)
    over = simulate(static_cluster, val_ps, **adm_kw)
    shed = simulate(
        static_cluster, val_ps, admission=AdmissionConfig(stretch=4.0),
        **adm_kw,
    )
    surv = shed.surviving_latencies()
    surv_p99 = float(np.percentile(surv, 99.0)) if surv.size else None
    surviving_improves = bool(
        surv_p99 is not None and 0.0 < shed.shed_frac < 1.0
        and surv_p99 < over.p99_us
    )
    adm_sum = shed.summary()["admission"]
    emit("serve_tail", "shed_frac", round(shed.shed_frac, 4))
    assert surviving_improves, (
        f"shedding did not improve surviving p99 "
        f"({surv_p99} vs {over.p99_us:.1f} at shed {shed.shed_frac:.2f})"
    )

    # 5c. SLO-driven hedging: learned per-tenant threshold, cancellation
    hed = simulate(
        static_cluster, val_ps, rate_qps=30_000, concurrency=4, seed=19,
        model=model, slo=adm_slo,
        hedge=HedgePolicy(quantile=75.0, min_samples=32),
    )
    hed_sum = hed.summary().get("hedging", {})

    # 5d. harness validation on a real clock: the stated <= 15% band at
    # low load, and the batching win reproduced outside the simulator
    har_kw = dict(rate_qps=20_000, concurrency=32, seed=23, model=model)
    sim_lo = simulate(static_cluster, val_ps, **har_kw)
    har_lo = harness_simulate(
        static_cluster, val_ps, time_scale=time_scale, **har_kw
    )
    rel_err_p99 = abs(har_lo.p99_us - sim_lo.p99_us) / sim_lo.p99_us
    rel_err_p50 = abs(har_lo.p50_us - sim_lo.p50_us) / sim_lo.p50_us
    within_band = bool(rel_err_p99 <= 0.15 and rel_err_p50 <= 0.15)
    emit("serve_tail", "harness_rel_err_p99", round(rel_err_p99, 4))
    assert within_band, (
        f"harness off the 15% band: p50 err {rel_err_p50:.3f}, "
        f"p99 err {rel_err_p99:.3f}"
    )
    hpq = harness_simulate(
        static_cluster, val_ps, time_scale=time_scale, **sat_kw
    )
    hbt = harness_simulate(
        static_cluster, val_ps, time_scale=time_scale,
        batching=BatchingConfig(), **sat_kw,
    )
    real_clock_win = bool(hbt.p99_us < hpq.p99_us)
    emit("serve_tail", "harness_p99_us", round(hpq.p99_us, 1),
         dispatch="per_query")
    emit("serve_tail", "harness_p99_us", round(hbt.p99_us, 1),
         dispatch="batched")
    assert real_clock_win, (
        f"batched harness p99 {hbt.p99_us:.1f} lost to per-query "
        f"{hpq.p99_us:.1f} on the real clock"
    )

    result["batching"] = {
        "n_queries": val_ps.n_queries,
        "smoke": bool(smoke),
        "saturation": {
            "per_query_p99_us": round(pq.p99_us, 1),
            "batched_p99_us": round(bt.p99_us, 1),
            "batched_le_per_query": batched_wins,
            **bsum,
        },
        "admission": {
            "overloaded_p99_us": round(over.p99_us, 1),
            "surviving_p99_us": round(surv_p99, 1),
            "shed_frac": round(shed.shed_frac, 4),
            "per_tenant_shed_frac": adm_sum["per_tenant_shed_frac"],
            "surviving_improves": surviving_improves,
        },
        "hedging": hed_sum,
        "harness": {
            "sim_p50_us": round(sim_lo.p50_us, 1),
            "sim_p99_us": round(sim_lo.p99_us, 1),
            "harness_p50_us": round(har_lo.p50_us, 1),
            "harness_p99_us": round(har_lo.p99_us, 1),
            "rel_err_p50": round(rel_err_p50, 4),
            "rel_err_p99": round(rel_err_p99, 4),
            "within_band": within_band,
            "per_query_p99_us": round(hpq.p99_us, 1),
            "batched_p99_us": round(hbt.p99_us, 1),
            "batched_wins_real_clock": real_clock_win,
            "time_scale": time_scale,
        },
    }

    with open(out_path, "w") as fh:
        json.dump(result, fh, indent=2)
    print(f"# wrote {out_path}")
    return result


if __name__ == "__main__":
    argv = sys.argv[1:]
    smoke = "--smoke" in argv
    argv = [a for a in argv if a != "--smoke"]
    run(argv[0] if argv else "BENCH_serve.json", smoke=smoke)
