"""Serving-tail benchmark: p99 under load + adaptive-controller value.

Three measurements, written to ``BENCH_serve.json`` (and emitted as CSV
rows via ``benchmarks.common``):

  1. **low-load validation** — at near-zero utilization the discrete-event
     simulator's mean latency must match the closed-form executor model
     within 10% (same access counts, same RPC constants, queueing -> 0);
  2. **p99 vs offered load x {static scheme, controller-on}** — the
     workload's hotspot moves (scripted drift phase); the static scheme
     serves the drifted phase as-is, the controller-repaired scheme serves
     it after adaptation, both swept over offered load;
  3. **adaptation** — per drift phase: detection-to-feasible lag (queries
     and simulated time), bytes replicated by the incremental repair, and
     the same repair priced as a *from-scratch greedy rebuild* (bytes of
     new copies the rebuilt scheme would have to ship vs the pre-drift
     scheme).  The incremental path must ship strictly fewer bytes.
  4. **telemetry overhead + fidelity** — the same serve run with span
     tracing enabled vs disabled (best-of-N wall clock each; the tracing
     overhead must stay under 2%), the obs streaming histogram's p99 vs
     the exact ``np.percentile`` (must agree within one log bucket), and
     the burn-rate blame decomposition of the drifted phase's violations
     (which server ate the violators' budgets).

Usage: PYTHONPATH=src python -m benchmarks.serve_tail [out.json]
"""
from __future__ import annotations

import json
import sys
import time

import numpy as np

from benchmarks.common import emit
from repro.core import replicate_workload
from repro.distsys import Cluster, LatencyModel
from repro.graph import make_sharding, snb_like
from repro.serve import (
    AdaptiveController,
    ControllerConfig,
    drift_stream,
    simulate,
    snb_drift,
)

T = 1
N_SERVERS = 6
QUERIES_PER_PHASE = 800
BATCH_QUERIES = 100
LOAD_SWEEP = (2_000, 20_000, 60_000, 120_000)


def _scheme_delta_bytes(old_mask, new_mask, f) -> float:
    """f-weighted bytes of copies present in ``new`` but not ``old``."""
    added = new_mask & ~old_mask
    return float((f[:, None] * added).sum())


def _serve_phase_with_controller(
    controller: AdaptiveController,
    cluster: Cluster,
    pathset,
    rate_qps: float,
    model: LatencyModel,
    seed: int,
) -> dict:
    """Feed one phase batch-by-batch; record adaptation lag + bytes."""
    nq = pathset.n_queries
    t_sim = 0.0
    lag_queries = None
    lag_sim_us = None
    bytes_added = 0.0
    replicas_added = 0
    n_adapts = 0
    served = 0
    for lo in range(0, nq, BATCH_QUERIES):
        batch = pathset.select_queries(lo, min(lo + BATCH_QUERIES, nq))
        if batch.n_paths == 0:
            continue
        rep = simulate(
            cluster, batch, rate_qps=rate_qps, model=model,
            seed=seed + lo,
        )
        served += batch.n_queries
        t_sim += float(rep.duration_us)
        act = controller.observe(batch, latency_us=rep.latency_us)
        if act is not None:
            n_adapts += 1
            bytes_added += act.bytes_added
            replicas_added += act.replicas_added
            if act.feasible_after and lag_queries is None:
                lag_queries = served
                lag_sim_us = t_sim
    return {
        "adaptations": n_adapts,
        "adaptation_lag_queries": lag_queries,
        "adaptation_lag_sim_us": lag_sim_us,
        "bytes_replicated": bytes_added,
        "replicas_added": replicas_added,
    }


def run(out_path: str = "BENCH_serve.json") -> dict:
    snb = snb_like(1, seed=0)
    f = snb.graph.object_sizes().astype(np.float32)
    shard = make_sharding("hash", snb.graph, N_SERVERS, seed=0)
    model = LatencyModel()

    phases = snb_drift(
        snb, n_phases=3, queries_per_phase=QUERIES_PER_PHASE, seed=0
    )
    ps0 = phases[0].pathset

    # static scheme: greedy on the phase-0 workload only
    static_scheme, _ = replicate_workload(ps0, shard, N_SERVERS, t=T, f=f)
    static_cluster = Cluster(static_scheme, f=f)

    result: dict = {
        "t": T,
        "workload": {
            "n_servers": N_SERVERS,
            "queries_per_phase": QUERIES_PER_PHASE,
            "phase_paths": [p.pathset.n_paths for p in phases],
        },
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
    }

    # ------------------------------------------------------------------ 1.
    lo_sim = simulate(static_cluster, ps0, rate_qps=500, model=model, seed=1)
    from repro.distsys import execute_workload

    closed = execute_workload(Cluster(static_scheme, f=f), ps0, model, seed=1)
    rel_err = abs(lo_sim.mean_us - closed.mean_us) / closed.mean_us
    result["lowload_validation"] = {
        "sim_mean_us": round(lo_sim.mean_us, 2),
        "closed_form_mean_us": round(closed.mean_us, 2),
        "rel_err": round(rel_err, 4),
        "within_10pct": bool(rel_err < 0.10),
        "max_utilization": round(float(lo_sim.utilization().max()), 4),
    }
    emit("serve_tail", "lowload_rel_err", round(rel_err, 4))
    assert rel_err < 0.10, "simulator no longer matches the latency model"

    # ------------------------------------------------------------------ 3.
    # drive the drift through an adaptive controller on a fresh cluster
    ctl_scheme = static_scheme.copy()
    ctl_cluster = Cluster(ctl_scheme, f=f)
    controller = AdaptiveController(
        ctl_cluster,
        ControllerConfig(t=T, window=4 * BATCH_QUERIES, min_queries=BATCH_QUERIES),
        f=f,
    )
    drift_rows = []
    pre_drift_mask = static_scheme.mask.copy()
    for delta in drift_stream(phases):
        phase_rate = 20_000.0
        adapt = _serve_phase_with_controller(
            controller, ctl_cluster, delta.pathset, phase_rate, model,
            seed=100 + delta.phase,
        )
        # price the same phase as a from-scratch rebuild: greedy on the
        # observed phase workload, bytes = new copies vs the pre-drift
        # scheme (what a rebuild would have to ship to the cluster)
        rebuilt, _ = replicate_workload(
            delta.pathset, shard, N_SERVERS, t=T, f=f
        )
        rebuild_bytes = _scheme_delta_bytes(pre_drift_mask, rebuilt.mask, f)
        row = {
            "phase": delta.phase,
            "name": delta.name,
            "added_paths": delta.added.n_paths,
            "removed_paths": delta.n_removed,
            **adapt,
            "rebuild_bytes": rebuild_bytes,
            "incremental_lt_rebuild": bool(
                delta.phase == 0 or adapt["bytes_replicated"] < rebuild_bytes
            ),
        }
        drift_rows.append(row)
        emit(
            "serve_tail", "bytes_replicated", round(adapt["bytes_replicated"], 1),
            phase=delta.phase,
        )
        emit(
            "serve_tail", "rebuild_bytes", round(rebuild_bytes, 1),
            phase=delta.phase,
        )
        if adapt["adaptation_lag_queries"] is not None:
            emit(
                "serve_tail", "adaptation_lag_queries",
                adapt["adaptation_lag_queries"], phase=delta.phase,
            )
    result["drift"] = drift_rows
    drifted = [r for r in drift_rows if r["phase"] > 0 and r["adaptations"]]
    result["incremental_vs_rebuild_ok"] = bool(
        drifted and all(r["incremental_lt_rebuild"] for r in drifted)
    )
    assert result["incremental_vs_rebuild_ok"], (
        "incremental repair should ship strictly fewer bytes than a rebuild"
    )

    # ------------------------------------------------------------------ 2.
    # p99 vs offered load on the drifted phase: static vs controller-on
    drifted_ps = phases[-1].pathset
    sweep = []
    for qps in LOAD_SWEEP:
        srow = simulate(
            static_cluster, drifted_ps, rate_qps=qps, model=model, seed=7
        )
        crow = simulate(
            ctl_cluster, drifted_ps, rate_qps=qps, model=model, seed=7
        )
        sweep.append(
            {
                "offered_qps": qps,
                "static": {
                    "p50_us": round(srow.p50_us, 1),
                    "p99_us": round(srow.p99_us, 1),
                    "p999_us": round(srow.p999_us, 1),
                    "max_utilization": round(
                        float(srow.utilization().max()), 4
                    ),
                },
                "controller": {
                    "p50_us": round(crow.p50_us, 1),
                    "p99_us": round(crow.p99_us, 1),
                    "p999_us": round(crow.p999_us, 1),
                    "max_utilization": round(
                        float(crow.utilization().max()), 4
                    ),
                },
            }
        )
        emit("serve_tail", "p99_us", round(srow.p99_us, 1),
             qps=qps, scheme="static")
        emit("serve_tail", "p99_us", round(crow.p99_us, 1),
             qps=qps, scheme="controller")
    result["load_sweep"] = sweep

    # ------------------------------------------------------------------ 4.
    # tracing overhead: identical serve run, trace=None vs a live Tracer.
    # Interleaved base/traced pairs with best-of-N (min) per mode — the
    # minimum is the low-noise estimator of the work actually required,
    # and interleaving keeps a frequency/load drift mid-measurement from
    # billing the whole drift to one mode.
    from repro.obs import Histogram, Tracer, attribute_burn

    def once(tr):
        t1 = time.perf_counter()
        rep = simulate(
            static_cluster, drifted_ps, rate_qps=60_000, model=model,
            seed=11, trace=tr,
        )
        return time.perf_counter() - t1, rep

    once(None)  # warm caches before timing
    _, rep_off = once(None)
    p99_budget = float(np.percentile(rep_off.latency_us, 99.0))
    base_s = traced_s = float("inf")
    for _ in range(8):
        b, _ = once(None)
        tr_s, _ = once(Tracer(budget_us=p99_budget))
        base_s = min(base_s, b)
        traced_s = min(traced_s, tr_s)
    overhead = traced_s / base_s - 1.0
    tracer = Tracer(budget_us=p99_budget)
    rep_tr = simulate(
        static_cluster, drifted_ps, rate_qps=60_000, model=model,
        seed=11, trace=tracer,
    )
    assert np.allclose(rep_tr.latency_us, rep_off.latency_us), (
        "tracing changed simulated latencies"
    )

    # histogram fidelity: streamed log-bucket p99 vs exact, within one
    # bucket width (multiplicative error <= growth)
    hist = Histogram("serve.latency_us", lo=1.0, growth=1.1)
    hist.record_many(rep_tr.latency_us)
    exact_p99 = float(np.percentile(rep_tr.latency_us, 99.0))
    hist_p99 = hist.percentile(99.0)
    bucket_ok = hist_p99 / hist.growth <= exact_p99 <= hist_p99 * hist.growth
    assert bucket_ok, (
        f"histogram p99 {hist_p99:.1f} not within one bucket of {exact_p99:.1f}"
    )

    # blame: which server consumed the violators' budgets
    burn = attribute_burn(tracer, allowed_frac=0.01)
    blame = burn.summary()
    result["telemetry"] = {
        "baseline_best_s": round(base_s, 4),
        "traced_best_s": round(traced_s, 4),
        "tracing_overhead": round(overhead, 4),
        "spans_recorded": tracer.n_spans,
        "violations_kept": tracer.n_violations,
        "hist_p99_us": round(hist_p99, 1),
        "exact_p99_us": round(exact_p99, 1),
        "hist_within_one_bucket": bool(bucket_ok),
        "blame": blame,
    }
    emit("serve_tail", "tracing_overhead", round(overhead, 4))
    assert overhead < 0.02, (
        f"span tracing costs {overhead:.1%} — over the 2% budget"
    )

    with open(out_path, "w") as fh:
        json.dump(result, fh, indent=2)
    print(f"# wrote {out_path}")
    return result


if __name__ == "__main__":
    run(sys.argv[1] if len(sys.argv) > 1 else "BENCH_serve.json")
