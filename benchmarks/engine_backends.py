"""Micro-benchmark of the LatencyEngine backends and chunk sizes.

Compares the three backends (reference | jnp | pallas) and a chunk-size
sweep on the paper's hot primitive — h(p, r, rho) over an SNB-like
workload — plus the transfer profile of the device-resident packed path
against the legacy per-call bool-mask upload.  Emits CSV rows via
``benchmarks.common`` and writes ``BENCH_engine.json`` so the perf
trajectory is recorded across PRs.

Usage: PYTHONPATH=src python -m benchmarks.engine_backends [out.json]
"""
from __future__ import annotations

import json
import sys
import time

import numpy as np

from benchmarks.common import build_snb_setup, emit, timer
from repro.core import ReplicationScheme, replicate_workload
from repro.engine import TRANSFER, LatencyEngine

CHUNKS = (1024, 4096, 8192)
REPEATS = 3


def _bench_eval(eng: LatencyEngine, ps, chunk=None) -> float:
    eng.path_latencies(ps, chunk=chunk)  # warm the jit cache
    best = float("inf")
    for _ in range(REPEATS):
        with timer() as tm:
            eng.path_latencies(ps, chunk=chunk)
        best = min(best, tm.dt)
    return best


def run(out_path: str = "BENCH_engine.json") -> dict:
    snb, ps, shard = build_snb_setup(scale=1, n_queries=1500)
    scheme, _ = replicate_workload(ps, shard, 6, t=1)
    result: dict = {
        "workload": {"paths": ps.n_paths, "max_len": ps.max_len,
                     "objects": scheme.n_objects, "servers": scheme.n_servers},
        "backends": {},
        "chunks": {},
        "transfers": {},
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
    }

    # --- backend comparison at the default chunk (+ exact agreement)
    outs = {}
    for backend in ("reference", "jnp", "pallas"):
        eng = LatencyEngine(scheme, backend=backend)
        outs[backend] = eng.path_latencies(ps)
        dt = _bench_eval(eng, ps)
        result["backends"][backend] = round(dt, 4)
        emit("engine_backends", "eval_s", round(dt, 4), backend=backend)
    assert np.array_equal(outs["reference"], outs["jnp"])
    assert np.array_equal(outs["jnp"], outs["pallas"])

    # --- chunk-size sweep (jnp backend, streamed double-buffered)
    eng = LatencyEngine(scheme, backend="jnp")
    for chunk in CHUNKS:
        dt = _bench_eval(eng, ps, chunk=chunk)
        result["chunks"][str(chunk)] = round(dt, 4)
        emit("engine_backends", "eval_s", round(dt, 4), chunk=chunk)

    # --- transfer profile: packed-resident vs legacy bool-per-call
    n_evals = 5
    TRANSFER.reset()
    eng = LatencyEngine(scheme, backend="jnp", resident=True)
    for _ in range(n_evals):
        eng.path_latencies(ps)
    packed_bytes = TRANSFER.h2d_bytes

    TRANSFER.reset()
    legacy = LatencyEngine(scheme, backend="jnp", resident=False)
    for _ in range(n_evals):
        legacy.path_latencies(ps)
    legacy_bytes = TRANSFER.h2d_bytes

    result["transfers"] = {
        "evals": n_evals,
        "resident_h2d_bytes": packed_bytes,
        "legacy_h2d_bytes": legacy_bytes,
        "ratio": round(legacy_bytes / max(packed_bytes, 1), 2),
    }
    emit("engine_backends", "h2d_bytes", packed_bytes, mode="resident")
    emit("engine_backends", "h2d_bytes", legacy_bytes, mode="legacy")
    emit("engine_backends", "h2d_ratio", result["transfers"]["ratio"])

    with open(out_path, "w") as fh:
        json.dump(result, fh, indent=2)
    print(f"# wrote {out_path}")
    return result


if __name__ == "__main__":
    run(sys.argv[1] if len(sys.argv) > 1 else "BENCH_engine.json")
