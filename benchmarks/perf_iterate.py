"""Perf-iteration driver for the §Perf hillclimb.

Runs one (arch x shape) cell on the single-pod mesh with optional config
overrides, printing the three roofline terms + collective breakdown so
every hypothesis->change->measure cycle is one command:

  PYTHONPATH=src python -m benchmarks.perf_iterate qwen3-moe-235b-a22b \
      train_4k moe_chunk=65536 remat_block=2
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import dataclasses
import json
import sys

import jax


def parse_override(s: str):
    k, v = s.split("=", 1)
    for cast in (int, float):
        try:
            return k, cast(v)
        except ValueError:
            pass
    if v in ("True", "False"):
        return k, v == "True"
    return k, v


def run(arch: str, shape: str, overrides: dict, multi_pod=False) -> dict:
    from repro.configs import get_arch
    from repro.launch import dryrun

    bundle = get_arch(arch)
    if overrides:
        bundle.config = dataclasses.replace(bundle.config, **overrides)
    row = dryrun.run_cell(arch, shape, multi_pod, verbose=False)
    keep = {k: row[k] for k in (
        "t_compute_s", "t_memory_s", "t_collective_s", "bottleneck",
        "peak_mem_gb", "useful_frac", "t_compile_s")}
    keep["collectives"] = {
        k: round(v / 2**20, 1) for k, v in row["collectives"].items()
        if k.endswith("bytes")}
    return keep


def main():
    arch, shape = sys.argv[1], sys.argv[2]
    overrides = dict(parse_override(s) for s in sys.argv[3:])
    out = run(arch, shape, overrides)
    print(json.dumps(out, indent=2, default=str))


if __name__ == "__main__":
    main()
