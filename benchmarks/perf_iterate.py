"""Perf-iteration driver for the §Perf hillclimb.

Runs one (arch x shape) cell on the single-pod mesh with optional config
overrides, printing the three roofline terms + collective breakdown so
every hypothesis->change->measure cycle is one command:

  PYTHONPATH=src python -m benchmarks.perf_iterate qwen3-moe-235b-a22b \
      train_4k moe_chunk=65536 remat_block=2

The special cell name ``engine`` instead measures the replication
engine's transfer profile on the greedy UPDATE loop (fig6-style driver,
default benchmark size): the device-resident packed path — one packed
upload, pinned paths, per-path latencies computed once and reused for
feasibility + the CDF — against the seed behavior of re-uploading the
unpacked bool mask and re-scanning per consumer call:

  PYTHONPATH=src python -m benchmarks.perf_iterate engine
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import dataclasses
import json
import sys

import jax


def parse_override(s: str):
    k, v = s.split("=", 1)
    for cast in (int, float):
        try:
            return k, cast(v)
        except ValueError:
            pass
    if v in ("True", "False"):
        return k, v == "True"
    return k, v


def run(arch: str, shape: str, overrides: dict, multi_pod=False) -> dict:
    from repro.configs import get_arch
    from repro.launch import dryrun

    bundle = get_arch(arch)
    if overrides:
        bundle.config = dataclasses.replace(bundle.config, **overrides)
    row = dryrun.run_cell(arch, shape, multi_pod, verbose=False)
    keep = {k: row[k] for k in (
        "t_compute_s", "t_memory_s", "t_collective_s", "bottleneck",
        "peak_mem_gb", "useful_frac", "t_compile_s")}
    keep["collectives"] = {
        k: round(v / 2**20, 1) for k, v in row["collectives"].items()
        if k.endswith("bytes")}
    return keep


def run_engine(ts=(0, 1, 2, 3), n_queries=1500) -> dict:
    """Transfer bytes of the greedy UPDATE loop: packed-resident vs legacy.

    Per t the driver replicates the workload and then consumes the result
    twice, as fig6 does (feasibility check + traversal CDF).  The resident
    path pins the pathset, streams one evaluation pass, and reuses the
    per-path latencies; the legacy path re-uploads the unpacked bool mask
    and re-runs the full Eqn 1-2 scan for every consumer call — the seed
    implementation's behavior.
    """
    import numpy as np

    from benchmarks.common import build_snb_setup
    from repro.core import replicate_workload
    from repro.engine import TRANSFER, LatencyEngine

    snb, ps, shard = build_snb_setup(n_queries=n_queries, sharding="hash")
    f = snb.graph.object_sizes().astype(np.float32)

    def cdf(lq):
        return {k: round(float((lq <= k).mean()), 4) for k in (0, 1, 2, 4)}

    TRANSFER.reset()
    resident_cdfs = []
    for t in ts:
        scheme, stats, eng = replicate_workload(
            ps, shard, 6, t, f=f, return_engine=True)
        pinned = eng.prepare(ps)               # one upload of the paths
        pl = eng.path_latencies(pinned)        # one streaming pass
        assert eng.is_feasible(ps, t, path_lats=pl)
        resident_cdfs.append(cdf(eng.query_latencies(ps, pl)))
    resident = TRANSFER.snapshot()

    TRANSFER.reset()
    legacy_cdfs = []
    for t in ts:
        scheme, stats = replicate_workload(ps, shard, 6, t, f=f)
        legacy = LatencyEngine(scheme, backend="jnp", resident=False)
        assert legacy.is_feasible(ps, t)       # full re-scan (seed behavior)
        legacy_cdfs.append(cdf(legacy.query_latencies(ps)))  # and again
    legacy = TRANSFER.snapshot()

    assert resident_cdfs == legacy_cdfs  # identical results either way
    ratio = legacy["h2d_bytes"] / max(resident["h2d_bytes"], 1)

    # incremental dirty-set re-check profile: after a small scheme delta,
    # the cached path goes back over the bus with one compacted dirty-row
    # index vector (TRANSFER.gathered_bytes) instead of the full path
    # block a cold evaluation streams — the h2d savings satellite of the
    # incremental engine, kept visible here so they never silently vanish
    # from the accounting
    TRANSFER.reset()
    t_inc = ts[-1]
    scheme, stats, eng = replicate_workload(
        ps, shard, 6, t_inc, f=f, return_engine=True)
    pl_cold = eng.path_latencies(ps, incremental=True)   # seeds the cache
    cold = TRANSFER.snapshot()
    TRANSFER.reset()
    rng = np.random.default_rng(0)
    delta_obj = rng.integers(0, shard.shape[0], 32)
    eng.add_replicas(delta_obj, rng.integers(0, 6, 32))
    pl_warm = eng.path_latencies(ps, incremental=True)   # dirty rows only
    warm = TRANSFER.snapshot()
    assert np.array_equal(pl_warm, eng.path_latencies(ps))  # bit-identical

    return {
        "paths": ps.n_paths,
        "objects": int(shard.shape[0]),
        "ts": list(ts),
        "resident_h2d_bytes": resident["h2d_bytes"],
        "resident_h2d_calls": resident["h2d_calls"],
        "legacy_h2d_bytes": legacy["h2d_bytes"],
        "legacy_h2d_calls": legacy["h2d_calls"],
        "h2d_ratio": round(ratio, 2),
        "meets_2x": bool(ratio >= 2.0),
        "incremental_cold_h2d_bytes": cold["h2d_bytes"],
        "incremental_warm_h2d_bytes": warm["h2d_bytes"],
        "incremental_gathered_bytes": warm["gathered_bytes"],
        "incremental_h2d_ratio": round(
            cold["h2d_bytes"] / max(warm["h2d_bytes"], 1), 2
        ),
    }


def main():
    if len(sys.argv) < 2:
        sys.exit("usage: perf_iterate (engine | <arch> <shape> [k=v ...])")
    if sys.argv[1] == "engine":
        print(json.dumps(run_engine(), indent=2))
        return
    if len(sys.argv) < 3:
        sys.exit("usage: perf_iterate <arch> <shape> [k=v ...]")
    arch, shape = sys.argv[1], sys.argv[2]
    overrides = dict(parse_override(s) for s in sys.argv[3:])
    out = run(arch, shape, overrides)
    print(json.dumps(out, indent=2, default=str))


if __name__ == "__main__":
    main()
