"""Shared benchmark helpers: CSV emission + workload construction."""
from __future__ import annotations

import time

import numpy as np

_ROWS: list[tuple] = []


def emit(bench: str, metric: str, value, **tags):
    tag = ";".join(f"{k}={v}" for k, v in sorted(tags.items()))
    _ROWS.append((bench, metric, tag, value))
    print(f"{bench},{metric},{tag},{value}")


def rows():
    return list(_ROWS)


class timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.dt = time.perf_counter() - self.t0


def build_snb_setup(scale=1, n_servers=6, n_queries=1500, sharding="hash",
                    seed=0):
    from repro.graph import make_sharding, snb_like
    from repro.workload import snb_workload_materialized, trace_objects

    snb = snb_like(scale, seed=seed)
    ps = snb_workload_materialized(snb, n_queries=n_queries, seed=seed)
    traces = trace_objects(ps) if sharding in ("hypergraph",) else None
    shard = make_sharding(sharding, snb.graph, n_servers, traces, seed=seed)
    return snb, ps, shard


def build_gnn_setup(n_nodes=20000, n_servers=6, n_seeds=300,
                    sharding="mincut", seed=0):
    from repro.graph import make_sharding, ogb_like
    from repro.workload import gnn_workload_materialized

    g = ogb_like(n_nodes, seed=seed)
    rng = np.random.default_rng(seed)
    seeds = rng.integers(0, g.n_nodes, n_seeds)
    ps = gnn_workload_materialized(g, seeds, (25, 10), seed=seed)
    shard = make_sharding(sharding, g, n_servers, seed=seed)
    return g, ps, shard
