import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# NOTE: the XLA_FLAGS lines above MUST stay the first two lines — jax locks
# the device count at first init, so no other import may precede them.

DOC = """Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: 512 placeholder host devices stand in for 2 TPU v5e pods; every
cell must ``.lower().compile()`` under both the single-pod (16, 16) mesh
and the multi-pod (2, 16, 16) mesh, and the compiled artifact yields
``memory_analysis()`` (fits?) + ``cost_analysis()`` (roofline terms).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b \
      --shape train_4k --mesh both
  PYTHONPATH=src python -m repro.launch.dryrun --all \
      --out benchmarks/results/dryrun.jsonl
"""

import argparse
import json
import sys
import time
import traceback

import jax
from jax.sharding import NamedSharding

from repro.analysis import roofline as R
from repro.configs import arch_ids, get_arch
from repro.launch.mesh import make_production_mesh


def _named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))


def model_flops_for(bundle, shape_id: str) -> float:
    cell = bundle.cells[shape_id]
    m = cell.meta
    if bundle.family == "lm":
        cfg = bundle.config
        if cell.kind == "train":
            return R.lm_model_flops(cfg, m["batch"] * m["seq"], "train",
                                    kv_len=m["seq"])
        if cell.kind == "prefill":
            return R.lm_model_flops(cfg, m["batch"] * m["seq"], "prefill",
                                    kv_len=m["seq"])
        return R.lm_model_flops(cfg, m["batch"], "decode", kv_len=m["seq"])
    if bundle.family == "gnn":
        from repro.configs.gnn_family import cfg_for_cell

        cfg = cfg_for_cell(bundle, shape_id)
        if shape_id == "minibatch_lg":
            B = m["batch"]
            f1, f2 = m["fanouts"]
            n, e = B * (1 + f1 + f1 * f2), B * (f1 + f1 * f2)
        elif shape_id == "molecule":
            n, e = m["batch"] * m["n"], m["batch"] * m["e"]
        else:
            n, e = m["n"], m["e"]
        return R.gnn_model_flops(cfg, n, e, "train")
    # recsys
    cfg = bundle.config
    if cell.kind == "train":
        return R.mind_model_flops(cfg, m["batch"], m["batch"], "train")
    if cell.kind == "serve":
        from repro.configs.recsys_family import N_CANDIDATES_ONLINE

        return R.mind_model_flops(cfg, m["batch"], N_CANDIDATES_ONLINE,
                                  "serve")
    return R.mind_model_flops(cfg, m["batch"], m["n_candidates"], "serve")


def run_cell(arch: str, shape_id: str, multi_pod: bool,
             verbose: bool = True) -> dict:
    bundle = get_arch(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    chips = 512 if multi_pod else 256

    args = bundle.abstract_args(shape_id, multi_pod)
    in_specs, out_specs = bundle.shardings(shape_id, multi_pod)
    step = bundle.step_fn(shape_id, multi_pod)

    t0 = time.perf_counter()
    with jax.set_mesh(mesh):
        jitted = jax.jit(
            step,
            in_shardings=_named(mesh, in_specs),
            out_shardings=_named(mesh, out_specs),
        )
        lowered = jitted.lower(*args)
        t_lower = time.perf_counter() - t0
        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0

    mem = compiled.memory_analysis()
    if verbose:
        print(f"--- {arch} x {shape_id} x {mesh_name} ---")
        print("memory_analysis:", mem)
        print("cost_analysis:", {
            k: v for k, v in compiled.cost_analysis().items()
            if k in ("flops", "bytes accessed")})
    rf = R.analyze(arch, shape_id, mesh_name, chips, compiled,
                   model_flops_for(bundle, shape_id))
    row = rf.row()
    row.update({
        "t_lower_s": round(t_lower, 2),
        "t_compile_s": round(t_compile, 2),
        "collectives": rf.collectives,
        "ops": rf.ops,
        "status": "ok",
    })
    if verbose:
        print(json.dumps({k: row[k] for k in (
            "t_compute_s", "t_memory_s", "t_collective_s", "bottleneck",
            "useful_frac", "roofline_frac", "peak_mem_gb")}, indent=None,
            default=str))
    return row


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cells: list[tuple[str, str]] = []
    if args.all or args.arch is None:
        for a in arch_ids():
            for s in get_arch(a).shape_ids():
                cells.append((a, s))
    else:
        shapes = ([args.shape] if args.shape
                  else get_arch(args.arch).shape_ids())
        cells = [(args.arch, s) for s in shapes]

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    rows, failures = [], 0
    for arch, shape in cells:
        for mp in meshes:
            try:
                rows.append(run_cell(arch, shape, mp))
            except Exception as e:
                failures += 1
                traceback.print_exc()
                rows.append({
                    "arch": arch, "shape": shape,
                    "mesh": "pod2x16x16" if mp else "pod16x16",
                    "status": f"FAIL: {type(e).__name__}: {e}",
                })
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "a") as fh:
            for r in rows:
                fh.write(json.dumps(r, default=str) + "\n")
    ok = sum(1 for r in rows if r.get("status") == "ok")
    print(f"\ndry-run cells: {ok} ok / {len(rows)} total")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
