"""Serving driver: replica-aware distributed query serving.

This driver ties the whole paper stack together end-to-end on a live
(simulated) cluster:

  1. build a data graph + sharding,
  2. analyze the workload into causal access paths,
  3. run the greedy latency-bound replication algorithm for a target t,
  4. serve batched requests through the replica-aware executor with the
     calibrated RPC latency model, reporting mean/p99 latency + throughput,
  5. optionally inject a server failure mid-run: the §5.4 incremental
     update re-establishes the bound and serving continues (the fault
     drill exercised by tests/examples).

For LM serving (decode loop with KV cache) see examples/serve_lm.py; this
module serves *queries*, the paper's subject.
"""
from __future__ import annotations

import argparse
import dataclasses

import numpy as np

from repro.core import (
    ReshardingMap,
    is_latency_feasible,
    query_latencies,
    repair_paths,
    replicate_workload,
)
from repro.core.reshard import drain_server
from repro.distsys import Cluster, LatencyModel, execute_workload
from repro.graph import make_sharding, snb_like
from repro.workload import snb_workload_materialized, trace_objects


@dataclasses.dataclass
class ServeReport:
    t: int
    feasible: bool
    overhead: float
    mean_us: float
    p99_us: float
    qps: float
    post_fault_feasible: bool | None = None


def serve(
    t: int = 1,
    n_servers: int = 6,
    scale: int = 1,
    n_queries: int = 2000,
    sharding: str = "hash",
    fail_server: int | None = None,
    hedge: bool = False,
    seed: int = 0,
) -> ServeReport:
    snb = snb_like(scale, seed=seed)
    g = snb.graph
    f = g.object_sizes()
    ps = snb_workload_materialized(snb, n_queries=n_queries, seed=seed)
    traces = trace_objects(ps) if sharding in ("hypergraph", "hmetis") else None
    shard = make_sharding(sharding, g, n_servers, traces, seed=seed)

    scheme, stats = replicate_workload(
        ps, shard, n_servers, t=t, f=f.astype(np.float32), track_rm=True)
    feasible = is_latency_feasible(ps, scheme, t)

    cluster = Cluster(scheme, f=f)
    report = execute_workload(cluster, ps, LatencyModel(), seed=seed,
                              hedge_replicas=hedge)
    s = report.summary()
    out = ServeReport(
        t=t, feasible=feasible,
        overhead=scheme.replication_overhead(f),
        mean_us=s["mean_us"], p99_us=s["p99_us"], qps=s["throughput_qps"])

    if fail_server is not None:
        rmap = ReshardingMap.from_entries(stats.rm, scheme.shard)
        cluster.fail_server(fail_server)
        drain_server(scheme, rmap, fail_server, f, strategy="single")
        repair_paths(scheme, rmap, ps, t, f)
        out.post_fault_feasible = is_latency_feasible(ps, scheme, t)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--t", type=int, default=1)
    ap.add_argument("--servers", type=int, default=6)
    ap.add_argument("--scale", type=int, default=1)
    ap.add_argument("--queries", type=int, default=2000)
    ap.add_argument("--sharding", default="hash",
                    choices=["hash", "mincut", "hypergraph"])
    ap.add_argument("--fail-server", type=int, default=None)
    ap.add_argument("--hedge", action="store_true")
    args = ap.parse_args()
    rep = serve(args.t, args.servers, args.scale, args.queries,
                args.sharding, args.fail_server, args.hedge)
    print(f"[serve] t={rep.t} feasible={rep.feasible} "
          f"overhead={rep.overhead:.3f} mean={rep.mean_us:.0f}us "
          f"p99={rep.p99_us:.0f}us qps={rep.qps:.0f} "
          f"post_fault_feasible={rep.post_fault_feasible}")


if __name__ == "__main__":
    main()
