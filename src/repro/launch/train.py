"""Training driver: sharded train loop with checkpoint/restart + fault
tolerance hooks.

Runs any registered architecture on the locally available device mesh
(production meshes are exercised by the dry-run; this driver actually
executes, so it sizes the mesh to the host).  Features:

  * pjit train step with the bundle's parameter/batch shardings,
  * deterministic per-step synthetic data (restart-exact),
  * async checkpointing every ``ckpt_every`` steps + restore-on-start,
  * straggler/fault drill: optional simulated failure triggers a
    restore-and-continue cycle (exercised in tests).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b --steps 20 \
      --smoke   # reduced config, CPU-friendly
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_arch
from repro.data import Prefetcher, lm_batch_fn, shard_batch
from repro.distsys import CheckpointManager
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T
from repro.optim import AdamW, cosine_schedule


def train_lm(arch: str, steps: int = 20, smoke: bool = True,
             ckpt_dir: str | None = None, ckpt_every: int = 10,
             batch: int = 8, seq: int = 32, log_every: int = 5,
             fail_at: int | None = None) -> dict:
    """Train a (reduced) LM config for a few steps; returns metrics."""
    bundle = get_arch(arch)
    assert bundle.family == "lm", "train_lm drives LM archs"
    cfg = bundle.smoke_config if smoke else bundle.config
    mesh = make_host_mesh()
    dp, tp = ("data",), "model"
    tp_size = mesh.shape["model"]

    opt = AdamW(lr=cosine_schedule(3e-4, 10, max(steps, 100)))
    pspecs = T.param_specs(cfg, dp, tp, tp_size, mesh.shape['data'])
    ospecs = opt.state_specs(pspecs)
    bspecs = {"tokens": P(dp, None), "labels": P(dp, None)}

    def train_step(params, opt_state, batch_):
        loss, grads = jax.value_and_grad(
            lambda p: T.loss_fn(p, batch_["tokens"], batch_["labels"], cfg)
        )(params)
        params, opt_state, gnorm = opt.update(grads, opt_state, params)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    named = lambda s: jax.tree.map(
        lambda x: NamedSharding(mesh, x), s,
        is_leaf=lambda x: isinstance(x, P))
    step_jit = jax.jit(
        train_step,
        in_shardings=(named(pspecs), named(ospecs), named(bspecs)),
        out_shardings=(named(pspecs), named(ospecs), None),
        donate_argnums=(0, 1),
    )

    params = jax.device_put(T.init(cfg, jax.random.key(0)), named(pspecs))
    opt_state = jax.device_put(opt.init(params), named(ospecs))

    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    start = 0
    if mgr is not None:
        restored, at = mgr.restore_latest((params, opt_state))
        if restored is not None:
            params, opt_state = jax.device_put(
                restored, (named(pspecs), named(ospecs)))
            start = at + 1
            print(f"[train] restored checkpoint step {at}")

    make_batch = lm_batch_fn(cfg.vocab, batch, seq)
    pf = Prefetcher(make_batch, start_step=start)
    losses = []
    t0 = time.perf_counter()
    try:
        for step, host_batch in pf:
            if step >= steps:
                break
            dev_batch = shard_batch(host_batch, mesh, bspecs)
            params, opt_state, metrics = step_jit(params, opt_state,
                                                  dev_batch)
            if fail_at is not None and step == fail_at:
                raise RuntimeError("injected failure")
            loss = float(metrics["loss"])
            losses.append(loss)
            if step % log_every == 0:
                print(f"[train] step {step} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f}")
            if mgr is not None and (step + 1) % ckpt_every == 0:
                mgr.save_async(step, (params, opt_state))
    finally:
        pf.close()
        if mgr is not None:
            mgr.wait()
    dt = time.perf_counter() - t0
    return {
        "steps": len(losses),
        "first_loss": losses[0] if losses else float("nan"),
        "last_loss": losses[-1] if losses else float("nan"),
        "wall_s": dt,
        "restored_from": start,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=32)
    args = ap.parse_args()
    out = train_lm(args.arch, args.steps, args.smoke, args.ckpt_dir,
                   batch=args.batch, seq=args.seq)
    print("[train] done:", out)


if __name__ == "__main__":
    main()
