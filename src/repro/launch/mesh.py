"""Production meshes (TPU v5e pods).

Single-pod: 256 chips as (data=16, model=16).
Multi-pod:  2 pods x 256 chips as (pod=2, data=16, model=16); the "pod"
axis crosses DCN, the others stay on ICI.

Functions, not module-level constants: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int | None = None):
    """Small mesh over the actually-available devices (tests/examples)."""
    n = len(jax.devices())
    m = model or (2 if n % 2 == 0 and n > 1 else 1)
    return jax.make_mesh((n // m, m), ("data", "model"))
