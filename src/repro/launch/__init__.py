"""Launchers: mesh construction, dry-run, train/serve drivers, elasticity.

NOTE: do not import ``dryrun`` from here — it must own first-import of
jax (XLA_FLAGS); run it as ``python -m repro.launch.dryrun``.
"""
from repro.launch.mesh import make_host_mesh, make_production_mesh

__all__ = ["make_production_mesh", "make_host_mesh"]
