"""Elastic scaling driver: mesh re-creation + state resharding + §5.4.

Two elasticity layers in this framework:

1. **Tensor-program elasticity** (this module): when the device count
   changes (scale-out, node loss), re-create the mesh, re-derive the
   parameter shardings for the new topology, and ``jax.device_put`` the
   checkpointed state onto it.  Because checkpoints are host
   (fully-replicated logical) arrays, resharding is placement-only — no
   arithmetic changes; training resumes bit-exact (tested).

2. **Replication-scheme elasticity** (repro.core.reshard, exercised by
   the serve driver): the paper's incremental §5.4 update keeps query
   latency bounds valid across reshards without re-analyzing the
   workload.

The two compose: a production job losing a pod would restore the latest
checkpoint onto the shrunken mesh (this module) while the serving tier
patches its replication scheme (core.reshard).
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import transformer as T
from repro.optim import AdamW, cosine_schedule


@dataclasses.dataclass
class ElasticState:
    mesh: object
    params: object
    opt_state: object
    step_fn: object


def build_for_devices(cfg: T.TransformerConfig, devices: list,
                      opt: AdamW, model_axis: int | None = None):
    """Create mesh + shardings + jitted step for an arbitrary device set."""
    n = len(devices)
    m = model_axis or (2 if n % 2 == 0 and n > 1 else 1)
    mesh = jax.sharding.Mesh(
        np.asarray(devices).reshape(n // m, m), ("data", "model"))
    pspecs = T.param_specs(cfg, ("data",), "model", m, n // m)
    ospecs = opt.state_specs(pspecs)
    bspecs = {"tokens": P(("data",), None), "labels": P(("data",), None)}
    named = lambda s: jax.tree.map(
        lambda x: NamedSharding(mesh, x), s,
        is_leaf=lambda x: isinstance(x, P))

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: T.loss_fn(p, batch["tokens"], batch["labels"], cfg)
        )(params)
        params, opt_state, gnorm = opt.update(grads, opt_state, params)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    step = jax.jit(
        train_step,
        in_shardings=(named(pspecs), named(ospecs), named(bspecs)),
        out_shardings=(named(pspecs), named(ospecs), None),
    )
    return mesh, named(pspecs), named(ospecs), named(bspecs), step


def reshard_state(state_host, shardings):
    """Place host state onto a (new) mesh — the elastic transition."""
    return jax.device_put(state_host, shardings)


def elastic_drill(cfg: T.TransformerConfig, steps_before: int = 3,
                  steps_after: int = 3, batch: int = 4, seq: int = 16,
                  seed: int = 0) -> dict:
    """Scale-in drill: train on all devices, lose half, continue.

    Returns losses from both phases + a bit-exactness check: the
    continued run must match a never-failed run step-for-step because
    data is step-seeded and state resharding is placement-only.
    """
    devices = jax.devices()
    opt = AdamW(lr=cosine_schedule(1e-3, 2, 100))

    def make_batch(step):
        rng = np.random.default_rng(1000 + step)
        toks = rng.integers(0, cfg.vocab, (batch, seq + 1), dtype=np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def run(devs, params_h, opt_h, start, n):
        mesh, ps, os_, bs, step = build_for_devices(cfg, devs, opt)
        params = reshard_state(params_h, ps)
        opt_state = reshard_state(opt_h, os_)
        losses = []
        for i in range(start, start + n):
            b = jax.device_put(make_batch(i), bs)
            params, opt_state, m = step(params, opt_state, b)
            losses.append(float(m["loss"]))
        host = jax.tree.map(np.asarray, (params, opt_state))
        return losses, host

    params0 = T.init(cfg, jax.random.key(seed))
    opt0 = opt.init(params0)
    host0 = jax.tree.map(np.asarray, (params0, opt0))

    # phase 1: full cluster
    losses1, host1 = run(devices, host0[0], host0[1], 0, steps_before)
    # phase 2: half the devices "survive"
    survivors = devices[: max(1, len(devices) // 2)]
    losses2, _ = run(survivors, host1[0], host1[1], steps_before, steps_after)
    # reference: never-failed run
    ref_losses, _ = run(devices, host0[0], host0[1], 0,
                        steps_before + steps_after)
    return {
        "losses_before": losses1,
        "losses_after": losses2,
        "reference": ref_losses,
        "bit_exact": bool(np.allclose(losses1 + losses2, ref_losses,
                                      rtol=1e-5)),
    }
