"""graphsage-reddit  [arXiv:1706.02216] — 2L d_hidden=128, mean aggregator,
sample sizes 25-10 (the minibatch_lg shape uses its own 15-10 fanout)."""
from repro.configs import base
from repro.configs.gnn_family import make_bundle
from repro.models.gnn import GNNConfig

FULL = GNNConfig(name="graphsage-reddit", arch="graphsage", n_layers=2,
                 d_hidden=128, d_in=602, n_classes=41, aggregator="mean")
SMOKE = GNNConfig(name="graphsage-smoke", arch="graphsage", n_layers=2,
                  d_hidden=16, d_in=8, n_classes=4, aggregator="mean")


@base.register("graphsage-reddit")
def bundle():
    return make_bundle("graphsage-reddit", FULL, SMOKE)
