"""GNN-family bundle implementation (4 archs x 4 shapes).

Shapes (input-feature dim / labels follow the public dataset each shape
names; padded to mesh-divisible sizes for the dry-run):
  full_graph_sm — cora-size full-batch: N=2708, E=10556, F=1433, 7 classes
  minibatch_lg  — reddit-size sampled training: 1024 seeds, fanout 15-10,
                  F=602, 41 classes (real neighbor-sampler blocks)
  ogb_products  — full-batch large: N=2449029, E=61859140, F=100, 47 cls
  molecule      — 128 graphs x 30 nodes x 64 edges, regression

Geometric archs (egnn/schnet) receive synthetic 3-D positions on
non-molecular graphs — the arch runs on every shape per the assignment;
see DESIGN.md §4.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import base
from repro.models import gnn as G
from repro.optim import AdamW, AdamWState, cosine_schedule

OPT = AdamW(lr=cosine_schedule(1e-3, 100, 10_000), weight_decay=0.0)

SHAPES = {
    "full_graph_sm": base.ShapeCell(
        "full_graph_sm", "train",
        {"n": 2708, "e": 10556, "f": 1433, "classes": 7, "pad": 1}),
    "minibatch_lg": base.ShapeCell(
        "minibatch_lg", "train",
        {"batch": 1024, "fanouts": (15, 10), "f": 602, "classes": 41,
         "n_table": 232965}),
    "ogb_products": base.ShapeCell(
        "ogb_products", "train",
        {"n": 2449029, "e": 61859140, "f": 100, "classes": 47, "pad": 512}),
    "molecule": base.ShapeCell(
        "molecule", "train",
        {"batch": 128, "n": 30, "e": 64, "f": 32, "classes": 1}),
}


def _abs(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _opt_abstract(params_abs) -> AdamWState:
    f32 = lambda s: _abs(s.shape, jnp.float32)
    return AdamWState(step=_abs((), jnp.int32),
                      m=jax.tree.map(f32, params_abs),
                      v=jax.tree.map(f32, params_abs))


def cfg_for_cell(bundle, shape_id: str, multi_pod: bool = False) -> G.GNNConfig:
    cell = SHAPES[shape_id]
    big = shape_id == "ogb_products"
    kw = dict(d_in=cell.meta["f"], n_classes=cell.meta["classes"], remat=big)
    if big:
        # shard_map aggregation over the edge axes (see GNNConfig)
        dp = base.dp_axes(multi_pod)
        kw["agg_axes"] = dp + (base.TP_AXIS,)
        kw["node_axes"] = dp
    return dataclasses.replace(bundle.config, **kw)


def _needs_pos(arch: str) -> bool:
    return arch in ("egnn", "schnet")


def make_train_step(cfg: G.GNNConfig):
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: G.loss_fn(p, batch, cfg))(params)
        params, opt_state, gnorm = OPT.update(grads, opt_state, params)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return train_step


def _graph_batch_abstract(cell, arch: str):
    m = cell.meta
    pad = m.get("pad", 1)
    N, E, F = base.pad_up(m["n"], pad), base.pad_up(m["e"], pad), m["f"]
    batch = {
        "x": _abs((N, F), jnp.float32),
        "senders": _abs((E,), jnp.int32),
        "receivers": _abs((E,), jnp.int32),
        "labels": _abs((N,), jnp.int32),
    }
    if _needs_pos(arch):
        batch["pos"] = _abs((N, 3), jnp.float32)
    if arch == "graphcast":
        batch["edge_feat"] = _abs((E, 4), jnp.float32)
    return batch


def abstract_args(bundle, shape_id: str, multi_pod: bool):
    cfg = cfg_for_cell(bundle, shape_id)
    cell = bundle.cells[shape_id]
    params = G.init_abstract(cfg)
    arch = cfg.arch
    m = cell.meta
    if shape_id in ("full_graph_sm", "ogb_products"):
        batch = _graph_batch_abstract(cell, arch)
    elif shape_id == "minibatch_lg":
        B = m["batch"]
        f1, f2 = m["fanouts"]
        batch = {
            "seed_x": _abs((B, m["f"]), jnp.float32),
            "layer_x": [_abs((B, f1, m["f"]), jnp.float32),
                        _abs((B, f1 * f2, m["f"]), jnp.float32)],
            "layer_mask": [_abs((B, f1), jnp.bool_),
                           _abs((B, f1 * f2), jnp.bool_)],
            "labels": _abs((B,), jnp.int32),
        }
        if arch != "graphsage":
            # non-sampling archs run the flat (gathered) graph form:
            # blocks flattened to a node set + block-local edges
            batch = _minibatch_flat_abstract(cell, arch)
    else:  # molecule
        B, n, e, F = m["batch"], m["n"], m["e"], m["f"]
        batch = {
            "x": _abs((B, n, F), jnp.float32),
            "senders": _abs((B, e), jnp.int32),
            "receivers": _abs((B, e), jnp.int32),
            "labels": _abs((B,), jnp.float32),
        }
        if _needs_pos(arch):
            batch["pos"] = _abs((B, n, 3), jnp.float32)
        if arch == "graphcast":
            batch["edge_feat"] = _abs((B, e, 4), jnp.float32)
    return (params, _opt_abstract(params), batch)


def _minibatch_flat_abstract(cell, arch: str):
    """Sampled neighborhood as a flat graph (egnn/schnet/graphcast path):
    node set = seeds + sampled frontier; edges = sampling tree edges."""
    m = cell.meta
    B = m["batch"]
    f1, f2 = m["fanouts"]
    N = B * (1 + f1 + f1 * f2)
    E = B * (f1 + f1 * f2)
    batch = {
        "x": _abs((N, m["f"]), jnp.float32),
        "senders": _abs((E,), jnp.int32),
        "receivers": _abs((E,), jnp.int32),
        "labels": _abs((N,), jnp.int32),
    }
    if _needs_pos(arch):
        batch["pos"] = _abs((N, 3), jnp.float32)
    if arch == "graphcast":
        batch["edge_feat"] = _abs((E, 4), jnp.float32)
    return batch


def shardings(bundle, shape_id: str, multi_pod: bool):
    cfg = cfg_for_cell(bundle, shape_id)
    cell = bundle.cells[shape_id]
    dp = base.dp_axes(multi_pod)
    dpn = base.dp_size(multi_pod)
    pspecs = G.param_specs(cfg, dp, base.TP_AXIS, base.TP_SIZE)
    ospecs = OPT.state_specs(pspecs)
    m = cell.meta

    def node_spec(n):  # shard node arrays over dp when divisible
        return dp if n % dpn == 0 else None

    def edge_spec(e):  # edges over dp x tp (independent work)
        full = dp + (base.TP_AXIS,)
        if e % (dpn * base.TP_SIZE) == 0:
            return full
        return dp if e % dpn == 0 else None

    arch = cfg.arch
    if shape_id in ("full_graph_sm", "ogb_products"):
        pad = m.get("pad", 1)
        N, E = base.pad_up(m["n"], pad), base.pad_up(m["e"], pad)
        ns, es = node_spec(N), edge_spec(E)
        bspec = {
            "x": P(ns, None), "senders": P(es), "receivers": P(es),
            "labels": P(ns),
        }
        if _needs_pos(arch):
            bspec["pos"] = P(ns, None)
        if arch == "graphcast":
            bspec["edge_feat"] = P(es, None)
    elif shape_id == "minibatch_lg":
        B = m["batch"]
        bs = node_spec(B)
        if arch == "graphsage":
            # the minibatch model is pure data parallelism (its 128-wide
            # hiddens are below min_tp_dim, so params replicate): shard
            # the seed batch over EVERY mesh axis — 256-way instead of
            # 16-way (EXPERIMENTS.md §Perf graphsage iter 1)
            full = dp + (base.TP_AXIS,)
            if B % (dpn * base.TP_SIZE) == 0:
                bs = full
            bspec = {
                "seed_x": P(bs, None),
                "layer_x": [P(bs, None, None), P(bs, None, None)],
                "layer_mask": [P(bs, None), P(bs, None)],
                "labels": P(bs),
            }
        else:
            f1, f2 = m["fanouts"]
            N = B * (1 + f1 + f1 * f2)
            E = B * (f1 + f1 * f2)
            ns, es = node_spec(N), edge_spec(E)
            bspec = {"x": P(ns, None), "senders": P(es),
                     "receivers": P(es), "labels": P(ns)}
            if _needs_pos(arch):
                bspec["pos"] = P(ns, None)
            if arch == "graphcast":
                bspec["edge_feat"] = P(es, None)
    else:  # molecule: shard the graph batch dim
        B = m["batch"]
        bs = node_spec(B)
        bspec = {"x": P(bs, None, None), "senders": P(bs, None),
                 "receivers": P(bs, None), "labels": P(bs)}
        if _needs_pos(arch):
            bspec["pos"] = P(bs, None, None)
        if arch == "graphcast":
            bspec["edge_feat"] = P(bs, None, None)

    in_s = (pspecs, ospecs, bspec)
    out_s = (pspecs, ospecs, {"loss": P(), "grad_norm": P()})
    return in_s, out_s


def step_fn(bundle, shape_id: str, multi_pod: bool = False):
    return make_train_step(cfg_for_cell(bundle, shape_id, multi_pod))


def smoke_batch(bundle, rng: np.random.Generator):
    cfg = bundle.smoke_config
    N, E, F = 24, 60, cfg.d_in
    batch = {
        "x": jnp.asarray(rng.normal(size=(N, F)), jnp.float32),
        "senders": jnp.asarray(rng.integers(0, N, E), jnp.int32),
        "receivers": jnp.asarray(rng.integers(0, N, E), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.n_classes, N), jnp.int32),
    }
    if _needs_pos(cfg.arch):
        batch["pos"] = jnp.asarray(rng.normal(size=(N, 3)), jnp.float32)
    if cfg.arch == "graphcast":
        batch["edge_feat"] = jnp.asarray(rng.normal(size=(E, 4)), jnp.float32)
    return batch


def smoke_step(bundle):
    cfg = bundle.smoke_config

    def run(batch):
        params = G.init(cfg, jax.random.key(0))
        opt_state = OPT.init(params)
        step = make_train_step(cfg)
        params, opt_state, metrics = step(params, opt_state, batch)
        logits = G.forward(params, batch, cfg)
        return {"loss": metrics["loss"], "logits": logits}

    return run


def make_bundle(arch_id: str, config: G.GNNConfig,
                smoke_config: G.GNNConfig) -> base.ArchBundle:
    config.validate()
    smoke_config.validate()
    return base.ArchBundle(
        arch_id=arch_id, family="gnn", config=config,
        smoke_config=smoke_config, cells=dict(SHAPES), skip_shapes={},
        _abstract_args=abstract_args, _shardings=shardings,
        _step_fn=step_fn, _smoke_batch=smoke_batch, _smoke_step=smoke_step,
    )
