"""graphcast  [arXiv:2212.12794] — encoder-processor-decoder mesh GNN:
16L d_hidden=512, sum aggregation, n_vars=227 native input width.

Adaptation note (DESIGN.md §9): the icosahedral grid<->mesh remapping of
the original is replaced by per-node encoder/decoder MLPs over the
*provided* graph of each input shape; the 16-layer interaction-network
processor (edge MLP + node MLP, sum aggregation) is faithful.
"""
from repro.configs import base
from repro.configs.gnn_family import make_bundle
from repro.models.gnn import GNNConfig

FULL = GNNConfig(name="graphcast", arch="graphcast", n_layers=16,
                 d_hidden=512, d_in=227, n_classes=227, aggregator="sum",
                 d_edge=4)
SMOKE = GNNConfig(name="graphcast-smoke", arch="graphcast", n_layers=2,
                  d_hidden=16, d_in=8, n_classes=4, aggregator="sum")


@base.register("graphcast")
def bundle():
    return make_bundle("graphcast", FULL, SMOKE)
