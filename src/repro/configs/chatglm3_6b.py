"""chatglm3-6b  [arXiv:2406.12793]

28L d_model=4096 32H (GQA kv=2, head_dim=128) d_ff=13696 vocab=65024,
2d RoPE (rotary applied to half the head dims).
"""
import jax.numpy as jnp

from repro.configs import base
from repro.configs.lm_family import make_bundle
from repro.models.transformer import TransformerConfig

FULL = TransformerConfig(
    name="chatglm3-6b",
    n_layers=28, d_model=4096, n_heads=32, n_kv_heads=2,
    d_ff=13696, vocab=65024,
    rotary_pct=0.5, rope_theta=1e4,
    dtype=jnp.bfloat16, remat=True, remat_block=4,
    blockwise_from=2048, attn_block_q=1024, loss_chunk=16384,
)

SMOKE = TransformerConfig(
    name="chatglm3-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
    rotary_pct=0.5, dtype=jnp.float32, remat=False,
)


@base.register("chatglm3-6b")
def bundle():
    return make_bundle("chatglm3-6b", FULL, SMOKE, skip_long=True)
