"""h2o-danube-3-4b  [arXiv:2401.16818]

24L d_model=3840 32H (GQA kv=8, head_dim=120) d_ff=10240 vocab=32000,
llama+mistral mix with sliding-window attention (window 4096).  SWA makes
the long_500k decode cell feasible: the KV working set is bounded by the
window, so this is the one LM arch that runs long_500k.
"""
import jax.numpy as jnp

from repro.configs import base
from repro.configs.lm_family import make_bundle
from repro.models.transformer import TransformerConfig

FULL = TransformerConfig(
    name="h2o-danube-3-4b",
    n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8,
    d_ff=10240, vocab=32000,
    sliding_window=4096, rope_theta=1e4,
    dtype=jnp.bfloat16, remat=True, remat_block=4,
    blockwise_from=2048, attn_block_q=1024, loss_chunk=16384,
)

SMOKE = TransformerConfig(
    name="danube-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
    sliding_window=8, dtype=jnp.float32, remat=False,
)


@base.register("h2o-danube-3-4b")
def bundle():
    return make_bundle("h2o-danube-3-4b", FULL, SMOKE, skip_long=False)
