"""Architecture bundles: the uniform interface the launcher/dry-run uses.

An ArchBundle binds a model family to one assigned architecture and
exposes, for each of its input shapes:

  * ``abstract_args(shape, mesh_shape)``   — ShapeDtypeStruct pytrees for
    every argument of the step function (params, optimizer state, batch /
    cache), built WITHOUT allocating anything;
  * ``shardings(shape, mesh_axes)``        — matching PartitionSpec pytrees;
  * ``step_fn(shape)``                     — the jittable step
    (train_step / prefill / decode / serve scoring);
  * ``smoke()``                            — a reduced config + tiny batch
    that runs a real step on CPU (shape + finiteness asserted in tests).

Conventions: dp = data-parallel mesh axes (("data",) single-pod,
("pod", "data") multi-pod); tp = "model".
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import numpy as np


def pad_up(n: int, multiple: int) -> int:
    return ((n + multiple - 1) // multiple) * multiple


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One (architecture x input-shape) dry-run cell."""

    shape_id: str
    kind: str              # train | prefill | decode | serve | retrieval
    meta: dict


@dataclasses.dataclass
class ArchBundle:
    arch_id: str
    family: str                       # lm | gnn | recsys
    config: Any                       # full-size model config
    smoke_config: Any                 # reduced config
    cells: dict[str, ShapeCell]
    skip_shapes: dict[str, str]       # shape_id -> reason (DESIGN.md note)
    # family implementations (injected by the family module)
    _abstract_args: Callable = None
    _shardings: Callable = None
    _step_fn: Callable = None
    _smoke_batch: Callable = None
    _smoke_step: Callable = None

    def shape_ids(self) -> list[str]:
        return list(self.cells.keys())

    def abstract_args(self, shape_id: str, multi_pod: bool = False):
        return self._abstract_args(self, shape_id, multi_pod)

    def shardings(self, shape_id: str, multi_pod: bool = False):
        return self._shardings(self, shape_id, multi_pod)

    def step_fn(self, shape_id: str, multi_pod: bool = False):
        try:
            return self._step_fn(self, shape_id, multi_pod)
        except TypeError:
            return self._step_fn(self, shape_id)

    def smoke_batch(self, rng: np.random.Generator):
        return self._smoke_batch(self, rng)

    def smoke_step(self):
        return self._smoke_step(self)


_REGISTRY: dict[str, Callable[[], ArchBundle]] = {}


def register(arch_id: str):
    def deco(fn):
        _REGISTRY[arch_id] = fn
        return fn

    return deco


def get_arch(arch_id: str) -> ArchBundle:
    if arch_id not in _REGISTRY:
        raise KeyError(
            f"unknown arch '{arch_id}'; available: {sorted(_REGISTRY)}")
    return _REGISTRY[arch_id]()


def arch_ids() -> list[str]:
    return sorted(_REGISTRY)


def dp_axes(multi_pod: bool) -> tuple[str, ...]:
    return ("pod", "data") if multi_pod else ("data",)


def dp_size(multi_pod: bool) -> int:
    return 32 if multi_pod else 16


TP_AXIS = "model"
TP_SIZE = 16
