"""schnet  [arXiv:1706.08566] — continuous-filter convolutions:
3 interactions, d_hidden=64, 300 RBF, cutoff 10."""
from repro.configs import base
from repro.configs.gnn_family import make_bundle
from repro.models.gnn import GNNConfig

FULL = GNNConfig(name="schnet", arch="schnet", n_layers=3, d_hidden=64,
                 d_in=32, n_classes=1, n_rbf=300, cutoff=10.0)
SMOKE = GNNConfig(name="schnet-smoke", arch="schnet", n_layers=2, d_hidden=16,
                  d_in=8, n_classes=4, n_rbf=20, cutoff=5.0)


@base.register("schnet")
def bundle():
    return make_bundle("schnet", FULL, SMOKE)
