"""LM-family bundle implementation (5 transformer archs x 4 shapes).

Shapes:
  train_4k    — train_step (fwd + bwd + AdamW) on [256, 4096] tokens
  prefill_32k — serve prefill on [32, 32768] tokens -> (KV cache, logits)
  decode_32k  — one-token decode with a 32k KV cache, batch 128
  long_500k   — one-token decode with a 524288-position context; only
                lowered for sub-quadratic (SWA) archs — pure full-attention
                archs skip it (see DESIGN.md §4)
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import base
from repro.models import transformer as T
from repro.optim import AdamW, AdamWState, cosine_schedule

OPT = AdamW(lr=cosine_schedule(3e-4, 2000, 100_000), weight_decay=0.1)

SHAPES = {
    "train_4k": base.ShapeCell("train_4k", "train",
                               {"seq": 4096, "batch": 256}),
    "prefill_32k": base.ShapeCell("prefill_32k", "prefill",
                                  {"seq": 32768, "batch": 32}),
    "decode_32k": base.ShapeCell("decode_32k", "decode",
                                 {"seq": 32768, "batch": 128}),
    "long_500k": base.ShapeCell("long_500k", "decode",
                                {"seq": 524288, "batch": 1}),
}


def _abs(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _opt_abstract(params_abs) -> AdamWState:
    f32 = lambda s: _abs(s.shape, jnp.float32)
    return AdamWState(
        step=_abs((), jnp.int32),
        m=jax.tree.map(f32, params_abs),
        v=jax.tree.map(f32, params_abs),
    )


def make_train_step(cfg: T.TransformerConfig):
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: T.loss_fn(p, batch["tokens"], batch["labels"], cfg)
        )(params)
        params, opt_state, gnorm = OPT.update(grads, opt_state, params)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return train_step


def abstract_args(bundle, shape_id: str, multi_pod: bool):
    cfg: T.TransformerConfig = bundle.config
    cell = bundle.cells[shape_id]
    params = T.init_abstract(cfg)
    B, S = cell.meta["batch"], cell.meta["seq"]
    if cell.kind == "train":
        return (
            params,
            _opt_abstract(params),
            {"tokens": _abs((B, S), jnp.int32),
             "labels": _abs((B, S), jnp.int32)},
        )
    if cell.kind == "prefill":
        return (params, {"tokens": _abs((B, S), jnp.int32)})
    # decode: cache of S positions + one token per sequence
    cache = T.cache_abstract(cfg, B, S)
    return (params, cache, {"tokens": _abs((B,), jnp.int32)})


def _serve_needs_fsdp(cfg: T.TransformerConfig) -> bool:
    """Serving holds bf16 weights only (no optimizer moments): keep them
    RESIDENT per chip when they fit the TP shard (dense 4-8B archs), and
    FSDP-shard them only when they don't (the MoE archs) — per-layer
    weight gathers at decode cost ~1 GB/chip/layer otherwise
    (EXPERIMENTS.md §Perf, decode iteration)."""
    from repro.analysis.roofline import lm_param_count

    resident_gb = lm_param_count(cfg) * 2 / base.TP_SIZE / 2**30
    return resident_gb > 12.0


def shardings(bundle, shape_id: str, multi_pod: bool):
    cfg: T.TransformerConfig = bundle.config
    cell = bundle.cells[shape_id]
    dp = base.dp_axes(multi_pod)
    dpn = base.dp_size(multi_pod)
    tp = base.TP_AXIS
    fsdp = True if cell.kind == "train" else _serve_needs_fsdp(cfg)
    pspecs = T.param_specs(cfg, dp, tp, base.TP_SIZE, dpn, fsdp=fsdp)
    B = cell.meta["batch"]
    bspec = dp if B % dpn == 0 else None
    if cell.kind == "train":
        ospecs = OPT.state_specs(pspecs)
        bat = {"tokens": P(bspec, None), "labels": P(bspec, None)}
        in_s = (pspecs, ospecs, bat)
        out_s = (pspecs, ospecs, {"loss": P(), "grad_norm": P()})
        return in_s, out_s
    if cell.kind == "prefill":
        cspecs = T.cache_specs(cfg, B, dp, tp, dpn)
        in_s = (pspecs, {"tokens": P(bspec, None)})
        out_s = (cspecs, P(bspec, tp))
        return in_s, out_s
    cspecs = T.cache_specs(cfg, B, dp, tp, dpn)
    in_s = (pspecs, cspecs, {"tokens": P(bspec)})
    out_s = (cspecs, P(bspec, tp))
    return in_s, out_s


def _act_cfg(bundle, shape_id: str, multi_pod: bool) -> T.TransformerConfig:
    """Config with activation-sharding constraints for this mesh/shape."""
    cell = bundle.cells[shape_id]
    dp = base.dp_axes(multi_pod)
    dpn = base.dp_size(multi_pod)
    act_dp = dp if cell.meta["batch"] % dpn == 0 else ()
    act_seq = (cell.kind == "train"
               and cell.meta["seq"] % base.TP_SIZE == 0)
    return dataclasses.replace(bundle.config, act_dp=act_dp,
                               act_tp=base.TP_AXIS, act_seq=act_seq,
                               tp_size=base.TP_SIZE)


def step_fn(bundle, shape_id: str, multi_pod: bool = False):
    cfg = _act_cfg(bundle, shape_id, multi_pod)
    cell = bundle.cells[shape_id]
    if cell.kind == "train":
        return make_train_step(cfg)
    if cell.kind == "prefill":
        S = cell.meta["seq"]
        return lambda params, batch: T.prefill(params, batch["tokens"], cfg, S)
    return lambda params, cache, batch: T.decode_step(
        params, cache, batch["tokens"], cfg)


def smoke_batch(bundle, rng: np.random.Generator):
    cfg = bundle.smoke_config
    B, S = 2, 16
    toks = rng.integers(0, cfg.vocab, (B, S + 1)).astype(np.int32)
    return {"tokens": jnp.asarray(toks[:, :-1]),
            "labels": jnp.asarray(toks[:, 1:])}


def smoke_step(bundle):
    cfg = bundle.smoke_config

    def run(batch):
        params = T.init(cfg, jax.random.key(0))
        opt_state = OPT.init(params)
        step = make_train_step(cfg)
        params, opt_state, metrics = step(params, opt_state, batch)
        # serving path too
        cache, logits_p = T.prefill(params, batch["tokens"], cfg, 32)
        cache, logits_d = T.decode_step(params, cache,
                                        batch["tokens"][:, -1], cfg)
        return {"loss": metrics["loss"], "logits_prefill": logits_p,
                "logits_decode": logits_d}

    return run


def make_bundle(arch_id: str, config: T.TransformerConfig,
                smoke_config: T.TransformerConfig,
                skip_long: bool) -> base.ArchBundle:
    config.validate()
    smoke_config.validate()
    cells = dict(SHAPES)
    skip = {}
    if skip_long:
        cells.pop("long_500k")
        skip["long_500k"] = (
            "pure full-attention decoder: 524288-token decode has no "
            "sub-quadratic structure; skipped per assignment rule "
            "(see DESIGN.md §4)")
    return base.ArchBundle(
        arch_id=arch_id, family="lm", config=config,
        smoke_config=smoke_config, cells=cells, skip_shapes=skip,
        _abstract_args=abstract_args, _shardings=shardings,
        _step_fn=step_fn, _smoke_batch=smoke_batch, _smoke_step=smoke_step,
    )
