"""qwen3-moe-235b-a22b  [hf:Qwen/Qwen3-235B-A22B]

94L d_model=4096 64H (GQA kv=4, head_dim=128) vocab=151936,
MoE: 128 experts top-8, moe_d_ff=1536 (no shared experts).
"""
import jax.numpy as jnp

from repro.configs import base
from repro.configs.lm_family import make_bundle
from repro.models.transformer import TransformerConfig

FULL = TransformerConfig(
    name="qwen3-moe-235b-a22b",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, head_dim=128,
    d_ff=12288,  # unused (no dense layers); kept for completeness
    vocab=151936,
    n_experts=128, top_k=8, moe_d_ff=1536,
    rope_theta=1e6,
    dtype=jnp.bfloat16, remat=True, remat_block=2,
    blockwise_from=2048, attn_block_q=1024, loss_chunk=16384, moe_chunk=32768,
)

SMOKE = TransformerConfig(
    name="qwen3-moe-smoke",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
    d_ff=128, vocab=256,
    n_experts=8, top_k=2, moe_d_ff=32,
    dtype=jnp.float32, remat=False,
)


@base.register("qwen3-moe-235b-a22b")
def bundle():
    return make_bundle("qwen3-moe-235b-a22b", FULL, SMOKE, skip_long=True)
