"""egnn  [arXiv:2102.09844] — E(n)-equivariant GNN: 4L d_hidden=64."""
from repro.configs import base
from repro.configs.gnn_family import make_bundle
from repro.models.gnn import GNNConfig

FULL = GNNConfig(name="egnn", arch="egnn", n_layers=4, d_hidden=64,
                 d_in=32, n_classes=7)
SMOKE = GNNConfig(name="egnn-smoke", arch="egnn", n_layers=2, d_hidden=16,
                  d_in=8, n_classes=4)


@base.register("egnn")
def bundle():
    return make_bundle("egnn", FULL, SMOKE)
