"""mind  [arXiv:1904.08030] — multi-interest recsys retrieval:
embed_dim=64, 4 interests, 3 capsule-routing iterations.

Item table: 2^26 rows x 64 (4.3B params @ f32 16GB; row-sharded over the
model axis -> 1GB/chip on the 256-chip pod).
"""
import jax.numpy as jnp

from repro.configs import base
from repro.configs.recsys_family import make_bundle
from repro.models.recsys import MINDConfig

FULL = MINDConfig(
    name="mind",
    n_items=67_108_864,       # 2^26 rows
    n_user_feats=1_048_576,   # 2^20 rows
    embed_dim=64, n_interests=4, capsule_iters=3,
    hist_len=50, user_feat_len=8, d_hidden=128,
    dtype=jnp.float32,
)

SMOKE = MINDConfig(
    name="mind-smoke",
    n_items=1000, n_user_feats=100,
    embed_dim=16, n_interests=3, capsule_iters=2,
    hist_len=10, user_feat_len=4, d_hidden=32,
)


@base.register("mind")
def bundle():
    return make_bundle("mind", FULL, SMOKE)
