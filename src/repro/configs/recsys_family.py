"""RecSys-family bundle (MIND x 4 shapes).

Shapes:
  train_batch    — sampled-softmax training, batch 65536
  serve_p99      — online inference, batch 512, 100 candidates each
  serve_bulk     — offline scoring, batch 262144, 100 candidates each
  retrieval_cand — 1 user x 1,048,576 candidates (1M padded to 2^20),
                   batched-dot retrieval scoring
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import base
from repro.models import recsys as R
from repro.optim import AdamW, AdamWState, cosine_schedule

OPT = AdamW(lr=cosine_schedule(1e-3, 500, 50_000), weight_decay=0.0)

N_CANDIDATES_ONLINE = 100
N_CANDIDATES_RETRIEVAL = 1_048_576   # 1M padded to 2^20

SHAPES = {
    "train_batch": base.ShapeCell("train_batch", "train", {"batch": 65536}),
    "serve_p99": base.ShapeCell("serve_p99", "serve", {"batch": 512}),
    "serve_bulk": base.ShapeCell("serve_bulk", "serve", {"batch": 262144}),
    "retrieval_cand": base.ShapeCell(
        "retrieval_cand", "retrieval",
        {"batch": 1, "n_candidates": N_CANDIDATES_RETRIEVAL}),
}


def _abs(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _opt_abstract(params_abs) -> AdamWState:
    f32 = lambda s: _abs(s.shape, jnp.float32)
    return AdamWState(step=_abs((), jnp.int32),
                      m=jax.tree.map(f32, params_abs),
                      v=jax.tree.map(f32, params_abs))


def _user_batch_abstract(cfg: R.MINDConfig, B: int) -> dict:
    return {
        "hist": _abs((B, cfg.hist_len), jnp.int32),
        "hist_mask": _abs((B, cfg.hist_len), jnp.bool_),
        "user_feats": _abs((B, cfg.user_feat_len), jnp.int32),
    }


def make_train_step(cfg: R.MINDConfig):
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: R.loss_fn(p, batch, cfg))(params)
        params, opt_state, gnorm = OPT.update(grads, opt_state, params)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return train_step


def abstract_args(bundle, shape_id: str, multi_pod: bool):
    cfg: R.MINDConfig = bundle.config
    cell = bundle.cells[shape_id]
    params = R.init_abstract(cfg)
    B = cell.meta["batch"]
    batch = _user_batch_abstract(cfg, B)
    if cell.kind == "train":
        batch["target"] = _abs((B,), jnp.int32)
        return (params, _opt_abstract(params), batch)
    if cell.kind == "serve":
        batch["candidates"] = _abs((B, N_CANDIDATES_ONLINE), jnp.int32)
        return (params, batch)
    batch["candidate_ids"] = _abs((cell.meta["n_candidates"],), jnp.int32)
    return (params, batch)


def shardings(bundle, shape_id: str, multi_pod: bool):
    cfg: R.MINDConfig = bundle.config
    cell = bundle.cells[shape_id]
    dp = base.dp_axes(multi_pod)
    dpn = base.dp_size(multi_pod)
    pspecs = R.param_specs(cfg, dp, base.TP_AXIS, base.TP_SIZE)
    B = cell.meta["batch"]
    bs = dp if B % dpn == 0 else None
    user = {
        "hist": P(bs, None), "hist_mask": P(bs, None),
        "user_feats": P(bs, None),
    }
    if cell.kind == "train":
        ospecs = OPT.state_specs(pspecs)
        bat = {**user, "target": P(bs)}
        return ((pspecs, ospecs, bat),
                (pspecs, ospecs, {"loss": P(), "grad_norm": P()}))
    if cell.kind == "serve":
        bat = {**user, "candidates": P(bs, None)}
        return ((pspecs, bat), P(bs, None))
    cand = dp + (base.TP_AXIS,)
    bat = {**user, "candidate_ids": P(cand)}
    return ((pspecs, bat), P(None, cand))


def step_fn(bundle, shape_id: str):
    cfg: R.MINDConfig = bundle.config
    cell = bundle.cells[shape_id]
    if cell.kind == "train":
        return make_train_step(cfg)
    if cell.kind == "serve":
        return lambda params, batch: R.serve_score(params, batch, cfg)
    return lambda params, batch: R.retrieval_score(params, batch, cfg)


def smoke_batch(bundle, rng: np.random.Generator):
    cfg = bundle.smoke_config
    B = 8
    return {
        "hist": jnp.asarray(
            rng.integers(0, cfg.n_items, (B, cfg.hist_len)), jnp.int32),
        "hist_mask": jnp.asarray(rng.random((B, cfg.hist_len)) < 0.8),
        "user_feats": jnp.asarray(
            rng.integers(0, cfg.n_user_feats, (B, cfg.user_feat_len)),
            jnp.int32),
        "target": jnp.asarray(rng.integers(0, cfg.n_items, (B,)), jnp.int32),
        "candidates": jnp.asarray(
            rng.integers(0, cfg.n_items, (B, 16)), jnp.int32),
    }


def smoke_step(bundle):
    cfg = bundle.smoke_config

    def run(batch):
        params = R.init(cfg, jax.random.key(0))
        opt_state = OPT.init(params)
        step = make_train_step(cfg)
        train_batch = {k: batch[k] for k in
                       ("hist", "hist_mask", "user_feats", "target")}
        params, opt_state, metrics = step(params, opt_state, train_batch)
        serve_batch = {k: batch[k] for k in
                       ("hist", "hist_mask", "user_feats", "candidates")}
        scores = R.serve_score(params, serve_batch, cfg)
        return {"loss": metrics["loss"], "scores": scores}

    return run


def make_bundle(arch_id: str, config: R.MINDConfig,
                smoke_config: R.MINDConfig) -> base.ArchBundle:
    config.validate()
    smoke_config.validate()
    return base.ArchBundle(
        arch_id=arch_id, family="recsys", config=config,
        smoke_config=smoke_config, cells=dict(SHAPES), skip_shapes={},
        _abstract_args=abstract_args, _shardings=shardings,
        _step_fn=step_fn, _smoke_batch=smoke_batch, _smoke_step=smoke_step,
    )
