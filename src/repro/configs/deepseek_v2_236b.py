"""deepseek-v2-236b  [arXiv:2405.04434]

60L d_model=5120 128H, MLA kv_lora=512 (q_lora=1536, rope 64, nope 128,
v 128), vocab=102400, MoE: 160 routed experts top-6 + 2 shared,
moe_d_ff=1536, first layer dense (d_ff=12288).
"""
import jax.numpy as jnp

from repro.configs import base
from repro.configs.lm_family import make_bundle
from repro.models.transformer import TransformerConfig

FULL = TransformerConfig(
    name="deepseek-v2-236b",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
    d_ff=12288,                    # the leading dense layer's hidden
    vocab=102400,
    n_experts=160, top_k=6, moe_d_ff=1536,
    n_shared_experts=2, n_dense_layers=1,
    mla_kv_lora=512, mla_q_lora=1536, mla_rope_dim=64, mla_nope_dim=128,
    mla_v_dim=128,
    rope_theta=1e4,
    dtype=jnp.bfloat16, remat=True, remat_block=4,
    blockwise_from=2048, attn_block_q=1024, loss_chunk=16384, moe_chunk=32768,
)

SMOKE = TransformerConfig(
    name="deepseek-v2-smoke",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=256,
    n_experts=8, top_k=2, moe_d_ff=32, n_shared_experts=1, n_dense_layers=1,
    mla_kv_lora=32, mla_q_lora=24, mla_rope_dim=8, mla_nope_dim=16,
    mla_v_dim=16,
    dtype=jnp.float32, remat=False,
)


@base.register("deepseek-v2-236b")
def bundle():
    return make_bundle("deepseek-v2-236b", FULL, SMOKE, skip_long=True)
