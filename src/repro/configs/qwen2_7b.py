"""qwen2-7b  [arXiv:2407.10671]

28L d_model=3584 28H (GQA kv=4, head_dim=128) d_ff=18944 vocab=152064,
QKV bias.
"""
import jax.numpy as jnp

from repro.configs import base
from repro.configs.lm_family import make_bundle
from repro.models.transformer import TransformerConfig

FULL = TransformerConfig(
    name="qwen2-7b",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4, head_dim=128,
    d_ff=18944, vocab=152064,
    qkv_bias=True, rope_theta=1e6,
    dtype=jnp.bfloat16, remat=True, remat_block=4,
    blockwise_from=2048, attn_block_q=1024, loss_chunk=16384,
)

SMOKE = TransformerConfig(
    name="qwen2-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
    qkv_bias=True, dtype=jnp.float32, remat=False,
)


@base.register("qwen2-7b")
def bundle():
    return make_bundle("qwen2-7b", FULL, SMOKE, skip_long=True)
