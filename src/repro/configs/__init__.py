"""Architecture registry: one module per assigned architecture.

``get_arch(id)`` returns the ArchBundle; ``arch_ids()`` lists all ten.
"""
from repro.configs.base import ArchBundle, ShapeCell, arch_ids, get_arch

# importing registers each architecture
from repro.configs import (  # noqa: F401
    chatglm3_6b,
    deepseek_v2_236b,
    egnn,
    graphcast,
    graphsage_reddit,
    h2o_danube_3_4b,
    mind,
    qwen2_7b,
    qwen3_moe_235b_a22b,
    schnet,
)

__all__ = ["ArchBundle", "ShapeCell", "arch_ids", "get_arch"]
