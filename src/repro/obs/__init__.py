"""Unified telemetry plane: metrics registry, span tracing, burn-rate blame.

The paper's subject is the *tail*, yet before this package the repo could
only report tails as opaque p99 scalars — every subsystem grew its own
ad-hoc counters (``TRANSFER``, ``GreedyStats``, ``StreamStats``,
``SimReport``, ``AdaptationReport``) with no shared substrate, and nothing
could say **which server, hop, or tenant** put a query over its t_Q
budget.  Three layers, one gate:

  metrics   — :class:`MetricsRegistry` of counters / gauges /
              log-bucketed streaming :class:`Histogram`\\ s (exact-parity
              merges, percentile within one bucket of exact); the global
              :data:`REGISTRY` is what the ad-hoc stats objects
              additionally register onto, and what the nightly benchmark
              job snapshots to ``BENCH_metrics.json``
  trace     — hop-level :class:`Span` / :class:`Tracer`: the serving
              simulator and the executor emit one span per access
              (hop, server, object, local/remote, queue-wait vs service
              split), ring-buffer sampled head + tail-biased — a query
              that violated its t_Q is never dropped — exportable as
              Chrome ``trace_event`` JSON
  burnrate  — :func:`attribute_burn` folds spans into per-tenant SLO
              burn rates with a per-server/per-hop blame decomposition
              (which hop's queue wait ate the budget), surfaced through
              ``AdaptiveController`` reports

Gate: the plane is **off by default** and costs nothing when off — hot
paths check :func:`enabled` once (or a ``tracer is not None`` argument)
and skip all recording.  ``REPRO_OBS=1`` in the environment enables it at
import; ``enable()`` / ``disable()`` toggle it at runtime.  Span tracing
is pay-per-use regardless of the gate (pass a ``Tracer``); the asserted
bound is <2% serve-benchmark overhead with tracing *enabled*.
"""
from __future__ import annotations

import os

from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    install_compile_hook,
)
from repro.obs.trace import QueryTrace, Span, Tracer, chrome_trace
from repro.obs.burnrate import BurnReport, HopBlame, TenantBurn, attribute_burn

__all__ = [
    "REGISTRY",
    "enabled",
    "enable",
    "disable",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "install_compile_hook",
    "Span",
    "QueryTrace",
    "Tracer",
    "chrome_trace",
    "HopBlame",
    "TenantBurn",
    "BurnReport",
    "attribute_burn",
]

#: The process-global registry every instrumented subsystem records into.
REGISTRY = MetricsRegistry()

_enabled = os.environ.get("REPRO_OBS", "") not in ("", "0", "false")


def enabled() -> bool:
    """Whether passive metrics recording is on (off = zero overhead)."""
    return _enabled


def enable() -> None:
    global _enabled
    _enabled = True


def disable() -> None:
    global _enabled
    _enabled = False
