"""Hop-level span tracing for served queries (simulator + executor).

A *span* is one access of one query's routed walk: which hop, which
object, which server, local or remote, and — in the simulator, where time
is real — the split between FIFO **queue wait** and **service** time.
That split is the paper's whole subject made visible: a t_Q violation is
no longer an opaque p99 scalar but a named hop on a named server whose
queue ate the budget.

Sampling is ring-buffered and **tail-biased**: the first ``head``
completed queries are always kept (warm-up visibility), every query that
*violated its budget* is always kept (the tail is the point — a sampler
that can drop the 1-in-10000 violator is useless for tail debugging), and
the rest share a fixed-size ring of recent completions.  The hot path
appends one tuple per access and defers all object construction to
completion time, keeping tracing-enabled serving within the <2% overhead
bound ``benchmarks/serve_tail.py`` asserts.

Traces export as Chrome ``trace_event`` JSON (``chrome://tracing`` /
Perfetto): servers are rendered as process lanes, so a hotspot server's
pile-up is literally visible as a dense lane.
"""
from __future__ import annotations

import dataclasses
import json
from collections import deque

import numpy as np

__all__ = ["Span", "QueryTrace", "Tracer", "chrome_trace"]


@dataclasses.dataclass(frozen=True)
class Span:
    """One access of one traced query (all times in microseconds)."""

    query: int
    hop: int                 # dispatch order within the query's walk
    obj: int                 # object accessed
    server: int              # server that served it (-1: no alive copy)
    local: bool              # local access vs distributed traversal
    t_enqueue_us: float      # when the access was dispatched/enqueued
    t_start_us: float        # when service began (== enqueue if no wait)
    t_end_us: float          # when service completed
    variant: int = 0         # routing variant (hedged runs race two)

    @property
    def queue_wait_us(self) -> float:
        return self.t_start_us - self.t_enqueue_us

    @property
    def service_us(self) -> float:
        return self.t_end_us - self.t_start_us

    @property
    def why(self) -> str:
        """Why the hop landed where it did (the policy pick, readably)."""
        if self.server < 0:
            return "no-alive-copy"
        return "local-copy" if self.local else "remote-hop"


@dataclasses.dataclass
class QueryTrace:
    """All spans of one completed query plus its verdict vs t_Q."""

    query: int
    tenant: int                  # -1 when the run was not tenant-tagged
    arrival_us: float
    completion_us: float
    budget_us: float | None      # the query's t_Q in wall-clock terms
    violated: bool               # latency > budget (always kept if True)
    failed: bool                 # hit an object with no alive copy
    policy: str
    # deadline-aware admission dropped the query before serving it: a shed
    # query is NOT a violation (it failed fast by design) — burn-rate
    # attribution reports the two separately
    shed: bool = False
    # raw access tuples (obj, server, local, t_enq, t_start, t_end, variant)
    # in dispatch order; Span objects are built lazily — the hot path never
    # allocates anything heavier than a tuple
    accesses: list = dataclasses.field(default_factory=list, repr=False)

    @property
    def latency_us(self) -> float:
        return self.completion_us - self.arrival_us

    @property
    def spans(self) -> list[Span]:
        return [
            Span(self.query, hop, o, s, bool(lc), te, ts, td, v)
            for hop, (o, s, lc, te, ts, td, v) in enumerate(self.accesses)
        ]

    def worst_hop(self) -> Span | None:
        """The hop whose queue wait ate the most budget (ties: total time).

        This is the blame pointer the burn-rate attribution aggregates:
        for a violating query, the server named here is where the budget
        went.
        """
        spans = self.spans
        if not spans:
            return None
        return max(
            spans, key=lambda s: (s.queue_wait_us, s.t_end_us - s.t_enqueue_us)
        )


class Tracer:
    """Head + tail-biased span sampler threaded through a serving run.

    ``budget_us`` is the wall-clock t_Q: a scalar (every query shares a
    deadline), a per-query array, or None (no violation marking — only
    head/ring sampling applies).  ``head`` first completions and all
    violators are always kept; non-violators beyond that share a ring of
    ``ring`` recent traces (completion order).  One Tracer traces one run;
    pass a fresh one per ``simulate()``/``execute_workload()`` call or
    :meth:`clear` between runs.
    """

    def __init__(
        self,
        budget_us=None,
        head: int = 32,
        ring: int = 256,
        policy: str = "home_first",
    ):
        self.head = int(head)
        self.ring = int(ring)
        self.policy = policy
        self.budget_us = budget_us
        self._staging: dict[int, list] = {}
        self._head: list[QueryTrace] = []
        self._ring: deque = deque(maxlen=self.ring)
        self._violations: list[QueryTrace] = []
        self._n_completed = 0
        self._n_violations = 0
        self._n_spans = 0
        self._n_shed = 0
        self._shed_counts: dict[int, int] = {}  # tenant -> shed queries
        # deferred simulator run (begin_run/end_run): a flat raw-span list
        # plus the run's verdict arrays, folded in lazily by _materialize
        self._run_staging: list | None = None
        self._run: tuple | None = None
        self._run_n_queries = 0

    # -- hot path ----------------------------------------------------------
    def record(self, q, obj, server, local, t_enq, t_start, t_end, variant=0):
        """Append one access tuple (called once per served access)."""
        acc = self._staging.get(q)
        if acc is None:
            acc = self._staging[q] = []
        acc.append((obj, server, local, t_enq, t_start, t_end, variant))
        self._n_spans += 1

    def begin_run(self, n_queries: int) -> list:
        """Hand the simulator its zero-overhead staging structure.

        Returns one flat list; the simulator binds its ``append`` as a
        local and the service path appends ``job, t_start, t_end`` as
        three consecutive elements — where ``job = (q, variant, node,
        server, base_us, obj, t_dispatch)`` is the tuple it already
        holds — so recording a span allocates *nothing* (every appended
        object already exists; no wrapper tuple means no garbage for the
        collector to chase mid-run).  Everything heavier (grouping by
        query, decoding, verdicts, sampling) happens lazily in
        :meth:`_materialize`, outside the simulated run's wall clock.
        """
        if self._run_staging is not None:
            self._materialize()
        self._run_n_queries = int(n_queries)
        self._run_staging = []
        return self._run_staging

    def end_run(
        self, arrivals_us, completion_us, tenant_of, failed, local_us,
        shed=None,
    ) -> None:
        """Close a simulator run: store the verdict arrays, defer the rest.

        ``shed`` (bool [n_queries] or None) marks queries dropped by
        deadline-aware admission: their traces carry ``shed=True`` and
        are exempt from the violation verdict (fail-fast is the policy
        working, not the SLO burning).
        """
        self._run = (
            np.asarray(arrivals_us, np.float64),
            np.asarray(completion_us, np.float64),
            tenant_of,
            np.asarray(failed, bool),
            float(local_us),
            np.asarray(shed, bool) if shed is not None else None,
        )

    def _materialize(self) -> None:
        """Fold a deferred simulator run into the sampled trace stores."""
        staging, run = self._run_staging, self._run
        if staging is None:
            return
        self._run_staging = self._run = None
        if run is None:  # begin_run without end_run: simulate() crashed
            return
        arrivals, completion, tenant_of, failed, local_us, shed = run
        per_q: list[list] = [[] for _ in range(self._run_n_queries)]
        # the flat stream is stride-3 (job, t_start, t_end): group by query
        for k in range(0, len(staging), 3):
            job = staging[k]
            per_q[job[0]].append((job, staging[k + 1], staging[k + 2]))
        # completion order, the order a live collector would see
        for q in np.argsort(completion, kind="stable"):
            q = int(q)
            for job, ts, te in per_q[q]:
                # decode the simulator's raw job tuple into the canonical
                # access layout (obj, server, local, enq, start, end, var)
                self.record(
                    q, job[5], job[3], job[4] == local_us,
                    job[6], ts, te, job[1],
                )
            self.finalize(
                q,
                float(arrivals[q]),
                float(completion[q]),
                int(tenant_of[q]) if tenant_of is not None else -1,
                bool(failed[q]),
                shed=bool(shed[q]) if shed is not None else False,
            )

    def budget_of(self, q: int) -> float | None:
        b = self.budget_us
        if b is None:
            return None
        if np.ndim(b) == 0:
            return float(b)
        return float(b[q])

    def finalize(
        self,
        q: int,
        arrival_us: float,
        completion_us: float,
        tenant: int = -1,
        failed: bool = False,
        shed: bool = False,
    ) -> QueryTrace:
        """Close query ``q``'s trace and apply the sampling policy."""
        budget = self.budget_of(q)
        latency = completion_us - arrival_us
        # a shed query was never served: it cannot violate (fail-fast is
        # the admission policy working), it is accounted separately
        violated = not shed and budget is not None and latency > budget
        tr = QueryTrace(
            query=q,
            tenant=int(tenant),
            arrival_us=float(arrival_us),
            completion_us=float(completion_us),
            budget_us=budget,
            violated=violated,
            failed=bool(failed),
            policy=self.policy,
            shed=bool(shed),
            accesses=self._staging.pop(q, []),
        )
        self._n_completed += 1
        if shed:
            self._n_shed += 1
            t = int(tenant)
            self._shed_counts[t] = self._shed_counts.get(t, 0) + 1
        if violated:
            # tail bias: a violating query's trace is NEVER dropped
            self._n_violations += 1
            self._violations.append(tr)
        elif len(self._head) < self.head:
            self._head.append(tr)
        else:
            self._ring.append(tr)
        return tr

    # -- results -----------------------------------------------------------
    @property
    def violations(self) -> list[QueryTrace]:
        """Every violator's trace (tail bias: never sampled away)."""
        self._materialize()
        return self._violations

    @property
    def n_completed(self) -> int:
        self._materialize()
        return self._n_completed

    @property
    def n_violations(self) -> int:
        self._materialize()
        return self._n_violations

    @property
    def n_spans(self) -> int:
        self._materialize()
        return self._n_spans

    @property
    def n_shed(self) -> int:
        self._materialize()
        return self._n_shed

    @property
    def shed_counts(self) -> dict[int, int]:
        """Exact shed-query count per tenant id (-1: untagged run)."""
        self._materialize()
        return dict(self._shed_counts)

    @property
    def traces(self) -> list[QueryTrace]:
        """Every kept trace (head + ring + all violators)."""
        self._materialize()
        return self._head + list(self._ring) + self._violations

    def trace_of(self, q: int) -> QueryTrace | None:
        for tr in self.traces:
            if tr.query == q:
                return tr
        return None

    def worst(self, n: int = 1) -> list[QueryTrace]:
        """Kept traces sorted by latency, slowest first."""
        return sorted(self.traces, key=lambda t: -t.latency_us)[:n]

    def clear(self) -> None:
        self._staging.clear()
        self._head.clear()
        self._ring.clear()
        self._violations.clear()
        self._run_staging = self._run = None
        self._n_completed = self._n_violations = self._n_spans = 0
        self._n_shed = 0
        self._shed_counts.clear()

    def chrome_trace(self, path: str | None = None) -> dict:
        return chrome_trace(self.traces, path)


def chrome_trace(traces, path: str | None = None) -> dict:
    """Chrome ``trace_event`` JSON for a set of :class:`QueryTrace`.

    Servers map to processes (lanes), queries to threads within the lane
    that served them; each access emits a complete ("X") service slice,
    preceded by a queue-wait slice when the access waited.  Load the file
    in ``chrome://tracing`` or https://ui.perfetto.dev.
    """
    events: list[dict] = []
    servers_seen: set[int] = set()
    for tr in traces:
        for s in tr.spans:
            pid = int(s.server)
            servers_seen.add(pid)
            args = {
                "query": tr.query,
                "tenant": tr.tenant,
                "hop": s.hop,
                "object": s.obj,
                "why": s.why,
                "policy": tr.policy,
                "violated": tr.violated,
            }
            if s.queue_wait_us > 0:
                events.append({
                    "name": f"queue v{s.obj}",
                    "cat": "queue",
                    "ph": "X",
                    "ts": s.t_enqueue_us,
                    "dur": s.queue_wait_us,
                    "pid": pid,
                    "tid": tr.query,
                    "args": args,
                })
            events.append({
                "name": f"hop{s.hop} v{s.obj}",
                "cat": "local" if s.local else "remote",
                "ph": "X",
                "ts": s.t_start_us,
                "dur": s.service_us,
                "pid": pid,
                "tid": tr.query,
                "args": args,
            })
    for pid in sorted(servers_seen):
        events.append({
            "name": "process_name",
            "ph": "M",
            "pid": pid,
            "args": {
                "name": f"server-{pid}" if pid >= 0 else "no-alive-copy"
            },
        })
    out = {"traceEvents": events, "displayTimeUnit": "ms"}
    if path is not None:
        with open(path, "w") as fh:
            json.dump(out, fh)
    return out
