"""Streaming metrics registry: counters, gauges, log-bucketed histograms.

One shared substrate for the quantities every subsystem used to count in
its own ad-hoc stats object (``TRANSFER``, ``GreedyStats``,
``StreamStats``, ``SimReport``, ``AdaptationReport``).  Those objects keep
their public APIs; when the plane is enabled (``repro.obs.enabled()``)
they *additionally* register onto the global :data:`REGISTRY`, so one
``REGISTRY.snapshot()`` names every counter in the system.

Design constraints:

* **zero overhead when disabled** — instruments are plain attribute
  mutations; hot paths hold an instrument reference (or skip the call
  entirely behind ``obs.enabled()``), never a registry lookup;
* **streaming** — a :class:`Histogram` is log-bucketed: values land in
  geometric buckets ``lo * growth^i``, so percentile queries cost O(#
  buckets), memory is bounded by the dynamic range, and the worst-case
  percentile error is *one bucket width* (relative error ``growth - 1``);
* **exact-parity merges** — two histograms with the same bucket geometry
  merge by adding bucket counts, so ``merge(a, b).percentile(q)`` is
  *bit-identical* to the percentile of one histogram fed both streams —
  the property that makes per-shard / per-phase histograms aggregable
  without re-recording (and what the tests assert).
"""
from __future__ import annotations

import dataclasses
import math
import threading

import numpy as np

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "install_compile_hook",
]


@dataclasses.dataclass
class Counter:
    """Monotone accumulator (occurrences, bytes, readbacks, ...)."""

    name: str
    value: float = 0.0

    def inc(self, v: float = 1.0) -> None:
        self.value += v

    def snapshot(self):
        return self.value


@dataclasses.dataclass
class Gauge:
    """Last-write-wins instantaneous value (overlap won, utilization, ...)."""

    name: str
    value: float = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def add(self, v: float) -> None:
        self.value += float(v)

    def snapshot(self):
        return self.value


class Histogram:
    """Log-bucketed streaming histogram with exact-parity merge.

    Bucket ``i >= 1`` covers ``(lo * growth^(i-1), lo * growth^i]``;
    bucket 0 covers ``(-inf, lo]`` (zeros and small values).  A recorded
    value only moves a bucket count, the running sum, and min/max, so
    recording a numpy batch is vectorized (:meth:`record_many`).

    Percentiles return the *upper edge* of the bucket holding the
    rank-``q`` sample, hence are within one log-bucket of the exact
    order statistic — ``growth`` bounds the relative error (default 1.1:
    p99 within 10% multiplicative, far tighter than the factor-level
    differences the tail benchmarks reason about).
    """

    def __init__(self, name: str, lo: float = 1.0, growth: float = 1.1):
        if lo <= 0 or growth <= 1.0:
            raise ValueError("need lo > 0 and growth > 1")
        self.name = name
        self.lo = float(lo)
        self.growth = float(growth)
        self._log_g = math.log(growth)
        self.counts: dict[int, int] = {}
        self.n = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    # -- bucket geometry ---------------------------------------------------
    def bucket_index(self, v: float) -> int:
        """Index of the bucket covering ``v`` (0 for v <= lo)."""
        if v <= self.lo:
            return 0
        # 1e-9 slack keeps exact bucket edges lo * growth^k in bucket k
        # despite float log rounding (edge values are adversarial inputs)
        return max(0, math.ceil(math.log(v / self.lo) / self._log_g - 1e-9))

    def bucket_upper(self, i: int) -> float:
        return self.lo * self.growth**i

    # -- recording ---------------------------------------------------------
    def record(self, v: float) -> None:
        i = self.bucket_index(v)
        self.counts[i] = self.counts.get(i, 0) + 1
        self.n += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    def record_many(self, values: np.ndarray) -> None:
        a = np.asarray(values, np.float64).ravel()
        if a.size == 0:
            return
        small = a <= self.lo
        idx = np.zeros(a.shape, np.int64)
        with np.errstate(divide="ignore"):
            idx[~small] = np.maximum(
                0,
                np.ceil(
                    np.log(a[~small] / self.lo) / self._log_g - 1e-9
                ).astype(np.int64),
            )
        for i, c in zip(*np.unique(idx, return_counts=True)):
            self.counts[int(i)] = self.counts.get(int(i), 0) + int(c)
        self.n += int(a.size)
        self.sum += float(a.sum())
        self.min = min(self.min, float(a.min()))
        self.max = max(self.max, float(a.max()))

    # -- queries -----------------------------------------------------------
    def percentile(self, q: float) -> float | None:
        """Upper edge of the bucket holding the rank-``q`` sample."""
        if self.n == 0:
            return None
        # rank of the order statistic (1-based ceil — the 'inverted CDF'
        # convention; merge parity holds because the rank only depends on
        # the merged counts)
        rank = max(1, math.ceil(self.n * q / 100.0))
        cum = 0
        for i in sorted(self.counts):
            cum += self.counts[i]
            if cum >= rank:
                return self.bucket_upper(i)
        return self.bucket_upper(max(self.counts))  # pragma: no cover

    @property
    def mean(self) -> float | None:
        return self.sum / self.n if self.n else None

    def merge(self, other: "Histogram") -> "Histogram":
        """Exact-parity merge: identical to having recorded both streams."""
        if (self.lo, self.growth) != (other.lo, other.growth):
            raise ValueError("histograms must share bucket geometry to merge")
        out = Histogram(self.name, self.lo, self.growth)
        out.counts = dict(self.counts)
        for i, c in other.counts.items():
            out.counts[i] = out.counts.get(i, 0) + c
        out.n = self.n + other.n
        out.sum = self.sum + other.sum
        out.min = min(self.min, other.min)
        out.max = max(self.max, other.max)
        return out

    def snapshot(self) -> dict:
        return {
            "count": self.n,
            "sum": self.sum,
            "min": self.min if self.n else None,
            "max": self.max if self.n else None,
            "mean": self.mean,
            "p50": self.percentile(50.0),
            "p99": self.percentile(99.0),
            "p999": self.percentile(99.9),
        }


class MetricsRegistry:
    """Named instrument store (get-or-create; names are dot-paths).

    The registry is only touched at instrument-acquisition time — hot
    loops keep the returned object and mutate it directly.  ``snapshot``
    returns a plain JSON-serializable dict (the nightly metrics
    artifact); ``reset`` drops all instruments (tests).
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._instruments: dict[str, object] = {}

    def _get(self, name: str, factory):
        with self._lock:
            inst = self._instruments.get(name)
            if inst is None:
                inst = factory()
                self._instruments[name] = inst
            return inst

    def counter(self, name: str) -> Counter:
        c = self._get(name, lambda: Counter(name))
        if not isinstance(c, Counter):
            raise TypeError(f"{name!r} is already a {type(c).__name__}")
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._get(name, lambda: Gauge(name))
        if not isinstance(g, Gauge):
            raise TypeError(f"{name!r} is already a {type(g).__name__}")
        return g

    def histogram(
        self, name: str, lo: float = 1.0, growth: float = 1.1
    ) -> Histogram:
        h = self._get(name, lambda: Histogram(name, lo, growth))
        if not isinstance(h, Histogram):
            raise TypeError(f"{name!r} is already a {type(h).__name__}")
        return h

    def names(self) -> list[str]:
        return sorted(self._instruments)

    def snapshot(self) -> dict:
        return {
            name: inst.snapshot()
            for name, inst in sorted(self._instruments.items())
        }

    def reset(self) -> None:
        with self._lock:
            self._instruments.clear()


# jit cache misses: one '/jax/core/compile/backend_compile_duration'
# duration event fires per actual backend compile (a cache hit fires
# none), so counting them surfaces recompilation storms — the usual
# silent cause of BENCH regressions (shape churn breaking the jit cache).
_COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"
_compile_hook_installed = False


def install_compile_hook(registry: MetricsRegistry | None = None):
    """Count jit cache misses into ``<registry>.repro.jit.compiles``.

    Idempotent (JAX monitoring listeners cannot be individually removed);
    returns the counter, or None when the monitoring API is unavailable.
    The counter object stays live across ``registry.reset()`` — callers
    snapshot deltas around the region they care about.
    """
    global _compile_hook_installed
    from repro import obs  # local: the package-level default registry

    reg = registry or obs.REGISTRY
    counter = reg.counter("repro.jit.compiles")
    if _compile_hook_installed:
        return counter
    try:
        from jax import monitoring
    except Exception:  # pragma: no cover - jax always present in this repo
        return None

    def _listener(event: str, duration: float, **kw) -> None:
        if event == _COMPILE_EVENT:
            # re-fetch through the *current* registry so a reset() between
            # install and the compile doesn't strand increments on a
            # dropped counter object
            reg.counter("repro.jit.compiles").inc()

    monitoring.register_event_duration_secs_listener(_listener)
    _compile_hook_installed = True
    return counter
