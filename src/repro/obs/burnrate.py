"""SLO burn-rate attribution: fold spans into per-tenant blame tables.

SRE-style burn rate: a tenant with a violation-fraction SLO of
``allowed_frac`` burns its error budget at rate
``observed_frac / allowed_frac`` — burn 1.0 exactly exhausts the budget
over the window, burn 10 exhausts it 10x faster.  The number alone says
*that* a tenant is burning; the attribution below says *where*: for every
violating query the traced walk names the hop whose **queue wait** ate
the budget, and those blame pointers aggregate into a per-server table —
the per-tenant "which server do I fix" answer the adaptive controller
surfaces in its :class:`~repro.serve.controller.AdaptationReport`.
"""
from __future__ import annotations

import dataclasses

__all__ = ["HopBlame", "TenantBurn", "BurnReport", "attribute_burn"]


@dataclasses.dataclass(frozen=True)
class HopBlame:
    """The hop of one violating query that consumed the largest share."""

    query: int
    hop: int
    obj: int
    server: int
    queue_wait_us: float
    service_us: float
    share: float          # (queue+service) of this hop / query latency
    latency_us: float
    budget_us: float | None


@dataclasses.dataclass
class TenantBurn:
    """One tenant's violation budget burn + per-server blame decomposition."""

    tenant: str
    n_queries: int = 0
    n_violations: int = 0
    # queries dropped by deadline-aware admission: shed != violated — a
    # shed query failed fast by policy and never burned queue time
    n_shed: int = 0
    allowed_frac: float = 0.01
    # per-server microseconds of queue wait inside violating queries —
    # the decomposition of where the burned budget actually went
    blame_queue_us: dict = dataclasses.field(default_factory=dict)
    blame_service_us: dict = dataclasses.field(default_factory=dict)
    # how often each server's hop was THE largest consumer of a
    # violating query's budget (the argmax pointer, per query)
    blamed_counts: dict = dataclasses.field(default_factory=dict)
    worst_hops: list = dataclasses.field(default_factory=list)

    @property
    def violation_frac(self) -> float:
        return self.n_violations / self.n_queries if self.n_queries else 0.0

    @property
    def shed_frac(self) -> float:
        return self.n_shed / self.n_queries if self.n_queries else 0.0

    @property
    def burn_rate(self) -> float:
        """Error-budget burn: observed violation frac / allowed frac."""
        return self.violation_frac / self.allowed_frac

    def top_server(self) -> int | None:
        """The server most often blamed for this tenant's violations."""
        if not self.blamed_counts:
            return None
        return max(
            self.blamed_counts,
            key=lambda s: (self.blamed_counts[s], self.blame_queue_us.get(s, 0.0)),
        )

    def summary(self) -> dict:
        top = self.top_server()
        return {
            "n_queries": self.n_queries,
            "n_violations": self.n_violations,
            "n_shed": self.n_shed,
            "shed_frac": self.shed_frac,
            "violation_frac": self.violation_frac,
            "burn_rate": self.burn_rate,
            "top_server": top,
            "top_server_blamed": (
                self.blamed_counts.get(top, 0) if top is not None else 0
            ),
            "blame_queue_us": {
                int(k): float(v) for k, v in sorted(self.blame_queue_us.items())
            },
        }


@dataclasses.dataclass
class BurnReport:
    """Per-tenant burn + blame over one traced serving window."""

    tenants: dict

    def __getitem__(self, name: str) -> TenantBurn:
        return self.tenants[name]

    def summary(self) -> dict:
        return {name: tb.summary() for name, tb in self.tenants.items()}


def attribute_burn(
    tracer,
    tenant_names: tuple = (),
    allowed_frac: float = 0.01,
    worst_per_tenant: int = 8,
) -> BurnReport:
    """Fold a :class:`~repro.obs.trace.Tracer`'s kept traces into blame.

    ``tenant_names`` maps the traces' integer tenant tags to names (an
    ``SLOSpec.tenants`` order); untagged queries (tenant -1, single-tenant
    runs) fold under ``"default"``.  Violation counts use the tracer's
    *complete* completion/violation totals — tail-biased sampling keeps
    every violator, so the blame decomposition is exact over the window
    even though non-violating traces are sampled.  Note the per-tenant
    ``n_queries`` denominators are exact only when every query was
    tenant-tagged or there is a single tenant; the blame tables (built
    from the always-kept violators) are exact regardless.
    """
    names = {i: str(n) for i, n in enumerate(tenant_names)}
    tenants: dict[str, TenantBurn] = {}

    def tb_of(tid: int) -> TenantBurn:
        name = names.get(tid, "default")
        tb = tenants.get(name)
        if tb is None:
            tb = tenants[name] = TenantBurn(
                tenant=name, allowed_frac=allowed_frac
            )
        return tb

    # denominators: count every kept completion per tenant; with a single
    # tenant the tracer's exact totals override below
    for tr in tracer.traces:
        tb_of(tr.tenant).n_queries += 1
    if len(tenants) <= 1 and tracer.n_completed:
        for tb in tenants.values():
            tb.n_queries = tracer.n_completed

    # shed counts are exact (the tracer counts every finalize, sampled or
    # not) — shed is reported NEXT TO violations, never folded into them
    for tid, n in tracer.shed_counts.items():
        tb_of(tid).n_shed += n

    for tr in tracer.violations:
        tb = tb_of(tr.tenant)
        tb.n_violations += 1
        worst = tr.worst_hop()
        latency = tr.latency_us
        for s in tr.spans:
            tb.blame_queue_us[s.server] = (
                tb.blame_queue_us.get(s.server, 0.0) + s.queue_wait_us
            )
            tb.blame_service_us[s.server] = (
                tb.blame_service_us.get(s.server, 0.0) + s.service_us
            )
        if worst is not None:
            tb.blamed_counts[worst.server] = (
                tb.blamed_counts.get(worst.server, 0) + 1
            )
            hb = HopBlame(
                query=tr.query,
                hop=worst.hop,
                obj=worst.obj,
                server=worst.server,
                queue_wait_us=worst.queue_wait_us,
                service_us=worst.service_us,
                share=(
                    (worst.t_end_us - worst.t_enqueue_us) / latency
                    if latency > 0
                    else 0.0
                ),
                latency_us=latency,
                budget_us=tr.budget_us,
            )
            tb.worst_hops.append(hb)
    for tb in tenants.values():
        tb.worst_hops.sort(key=lambda h: -h.queue_wait_us)
        del tb.worst_hops[worst_per_tenant:]
    return BurnReport(tenants=tenants)
