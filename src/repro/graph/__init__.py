"""Graph storage, generators, partitioners, and neighbor sampling."""
from repro.graph.csr import CSRGraph
from repro.graph.generators import SNBLikeGraph, ogb_like, random_regular, snb_like
from repro.graph.partition import (
    hash_partition,
    hypergraph_partition,
    ldg_partition,
    make_sharding,
)
from repro.graph.sampler import (
    MiniBatch,
    distributed_hops,
    minibatch_sampler,
    sample_neighborhood,
)

__all__ = [
    "CSRGraph",
    "SNBLikeGraph",
    "snb_like",
    "ogb_like",
    "random_regular",
    "hash_partition",
    "ldg_partition",
    "hypergraph_partition",
    "make_sharding",
    "MiniBatch",
    "minibatch_sampler",
    "sample_neighborhood",
    "distributed_hops",
]
