"""Node-wise neighborhood sampling (paper §6.1 GNN workload; GraphSAGE [16]).

Samples L-hop neighborhoods with per-hop fan-outs (the paper uses 25-10-10
and notes queries need <= 2 distributed hops because the 3rd hop reads the
2nd hop's adjacency list).  Two front-ends:

* ``sample_neighborhood``      — host-side numpy sampler used by the
  workload analyzer and the distributed executor simulation;
* ``minibatch_sampler``        — batched sampler producing padded device
  arrays (seeds, per-hop neighbor blocks) feeding GNN training, i.e. the
  real neighbor sampler required by the ``minibatch_lg`` shape.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.graph.csr import CSRGraph


def sample_neighborhood(
    graph: CSRGraph,
    seed_node: int,
    fanouts: tuple[int, ...],
    rng: np.random.Generator,
) -> list[np.ndarray]:
    """One node-wise sample: returns the frontier per hop (hop 0 = seed)."""
    frontiers = [np.asarray([seed_node], dtype=np.int64)]
    for f in fanouts:
        nxt = []
        for v in frontiers[-1]:
            nbr = graph.neighbors(int(v))
            if len(nbr) == 0:
                continue
            take = min(f, len(nbr))
            nxt.append(rng.choice(nbr, size=take, replace=False))
        frontiers.append(
            np.unique(np.concatenate(nxt)) if nxt else np.zeros(0, np.int64)
        )
    return frontiers


@dataclasses.dataclass(frozen=True)
class MiniBatch:
    """Padded sampled sub-neighborhood for GNN training.

    seeds:       int32 [B]
    layer_nodes: list over hops of int32 [B, prod(fanouts[:h])] node ids
                 (-1 padding where a vertex had fewer neighbors)
    """

    seeds: np.ndarray
    layer_nodes: list[np.ndarray]

    def all_nodes(self) -> np.ndarray:
        parts = [self.seeds] + [l.reshape(-1) for l in self.layer_nodes]
        cat = np.concatenate(parts)
        return np.unique(cat[cat >= 0])


def minibatch_sampler(
    graph: CSRGraph,
    batch_nodes: np.ndarray,
    fanouts: tuple[int, ...],
    seed: int = 0,
) -> MiniBatch:
    """Fixed-shape fan-out sampling for a batch of seed nodes.

    Per-hop the frontier multiplies by the fan-out; missing neighbors pad
    with -1 so downstream segment-sum models can mask them.  Sampling uses
    independent per-(node, slot) draws — with replacement when the degree
    is below the fan-out, mirroring DistDGL's padded sampling.
    """
    rng = np.random.default_rng(seed)
    B = len(batch_nodes)
    frontier = np.asarray(batch_nodes, dtype=np.int64)
    layers: list[np.ndarray] = []
    width = 1
    for f in fanouts:
        width *= f
        flat = frontier.reshape(-1)
        deg = np.where(flat >= 0, graph.degree(np.maximum(flat, 0)), 0)
        draw = rng.integers(0, 2**31, size=(len(flat), f))
        take = np.where(deg[:, None] > 0, draw % np.maximum(deg[:, None], 1), -1)
        base = np.where(flat >= 0, graph.indptr[np.maximum(flat, 0)], 0)
        idx = base[:, None] + np.maximum(take, 0)
        nbrs = np.where(take >= 0, graph.indices[idx], -1)
        layer = nbrs.reshape(B, width).astype(np.int32)
        layers.append(layer)
        frontier = layer.astype(np.int64)
    return MiniBatch(seeds=np.asarray(batch_nodes, np.int32), layer_nodes=layers)


def distributed_hops(
    frontiers: list[np.ndarray], shard: np.ndarray
) -> int:
    """#distributed traversals on the critical path of one sampling query.

    The access tree is seed -> hop1 nodes -> hop2 nodes; a root-to-leaf
    path hops servers when the next frontier vertex's owner differs from
    where the current access runs (no replicas).  Worst case over leaves =
    query latency (Def 4.3) under d.
    """
    if len(frontiers) <= 1:
        return 0
    worst = 0
    # paths are seed -> v1 -> v2 ...; evaluate greedily per leaf chain.
    # For fan-out trees the worst path is bounded by hops where *some*
    # frontier vertex lives remotely from *its parent's* server.
    # Exact per-leaf evaluation:
    def rec(server: int, hop: int, node: int, acc: int):
        nonlocal worst
        if hop + 1 >= len(frontiers):
            worst = max(worst, acc)
            return
        for nxt in frontiers[hop + 1]:
            s = int(shard[nxt])
            cost = acc + (1 if s != server else 0)
            rec(s if s != server else server, hop + 1, int(nxt), cost)

    seed = int(frontiers[0][0])
    rec(int(shard[seed]), 0, seed, 0)
    return worst
