"""Sharding functions d(v) (paper §3.1 'system model', §6 'Q4').

The paper treats the sharding function as an *input* and stacks replication
on top of three families (Fig 7): hash, min-cut graph partitioning (Metis),
and workload-aware hypergraph partitioning (hmetis).  Metis/hmetis binaries
are unavailable offline, so we implement in-role substitutes:

* ``hash_partition``       — the common in-memory-graph-DB default.
* ``ldg_partition``        — Linear Deterministic Greedy streaming min-cut
                             [Stanton & Kliot, KDD'12]; data-aware.
* ``hypergraph_partition`` — place co-accessed objects together using a
                             sampled workload trace (hyperedges), refined
                             with label propagation; workload-aware.

All return an int32 server assignment [n_nodes] and respect a capacity
slack factor, matching how the paper balances partitions.
"""
from __future__ import annotations

import numpy as np

from repro.graph.csr import CSRGraph


def hash_partition(n_nodes: int, n_servers: int, seed: int = 0) -> np.ndarray:
    """Deterministic pseudo-random hash sharding (splittable mix)."""
    v = np.arange(n_nodes, dtype=np.uint64)
    z = v + np.uint64(seed) * np.uint64(0x9E3779B97F4A7C15) + np.uint64(1)
    z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    z = z ^ (z >> np.uint64(31))
    return (z % np.uint64(n_servers)).astype(np.int32)


def ldg_partition(
    graph: CSRGraph,
    n_servers: int,
    slack: float = 1.05,
    seed: int = 0,
    passes: int = 2,
) -> np.ndarray:
    """Linear Deterministic Greedy streaming partitioning (min-cut role).

    Each vertex goes to the partition maximizing
    |N(v) ∩ P_s| * (1 - |P_s| / C) with capacity C = slack * n / k.
    A second pass re-streams with the previous assignment as neighbor
    evidence, which substantially improves cut (~Metis-trend quality).
    """
    n = graph.n_nodes
    cap = slack * n / n_servers
    rng = np.random.default_rng(seed)
    order = rng.permutation(n)
    part = np.full(n, -1, dtype=np.int32)
    sizes = np.zeros(n_servers, dtype=np.int64)

    for pass_i in range(passes):
        for v in order:
            nbrs = graph.neighbors(v)
            scores = np.zeros(n_servers, dtype=np.float64)
            if len(nbrs):
                assigned = part[nbrs]
                assigned = assigned[assigned >= 0]
                if len(assigned):
                    scores += np.bincount(assigned, minlength=n_servers)
            penalty = 1.0 - sizes / cap
            scores = scores * np.maximum(penalty, 0.0)
            if pass_i == 0 and part[v] == -1 and not scores.any():
                s = int(np.argmin(sizes))
            else:
                s = int(np.argmax(scores + 1e-9 * penalty))
            if part[v] >= 0:
                sizes[part[v]] -= 1
            part[v] = s
            sizes[s] += 1
    return part


def hypergraph_partition(
    traces: list[np.ndarray],
    n_nodes: int,
    n_servers: int,
    slack: float = 1.05,
    seed: int = 0,
    iters: int = 8,
) -> np.ndarray:
    """Workload-aware placement from co-access hyperedges (hmetis role).

    ``traces`` is a list of object-id arrays — the objects touched by each
    sampled query (the hyperedges of [11, 32]).  Vertices are first seeded
    by hashing, then label propagation moves each vertex to the server where
    most of its co-accessed partners live, subject to capacity.
    Vertices never observed in the trace keep their hash placement — this
    is exactly the incompleteness the paper points out for workload-aware
    schemes (§6.2 Q4).
    """
    part = hash_partition(n_nodes, n_servers, seed)
    cap = int(slack * n_nodes / n_servers) + 1

    # bipartite incidence: object -> hyperedge ids
    obj_edges: dict[int, list[int]] = {}
    for e, tr in enumerate(traces):
        for v in np.unique(tr):
            obj_edges.setdefault(int(v), []).append(e)

    edge_members = [np.unique(tr).astype(np.int64) for tr in traces]
    rng = np.random.default_rng(seed + 1)
    touched = np.fromiter(obj_edges.keys(), dtype=np.int64)
    sizes = np.bincount(part, minlength=n_servers).astype(np.int64)

    for _ in range(iters):
        moved = 0
        for v in rng.permutation(touched):
            votes = np.zeros(n_servers, dtype=np.float64)
            for e in obj_edges[int(v)]:
                members = edge_members[e]
                ps = part[members[members != v]]
                if len(ps):
                    votes += np.bincount(ps, minlength=n_servers) / len(ps)
            s_new = int(np.argmax(votes))
            s_old = int(part[v])
            if votes[s_new] > votes[s_old] and sizes[s_new] < cap:
                part[v] = s_new
                sizes[s_new] += 1
                sizes[s_old] -= 1
                moved += 1
        if moved == 0:
            break
    return part


def make_sharding(
    kind: str,
    graph: CSRGraph,
    n_servers: int,
    traces: list[np.ndarray] | None = None,
    seed: int = 0,
) -> np.ndarray:
    """Uniform entry point used by benchmarks (paper Q4 schemes)."""
    if kind == "hash":
        return hash_partition(graph.n_nodes, n_servers, seed)
    if kind in ("mincut", "metis", "ldg"):
        return ldg_partition(graph, n_servers, seed=seed)
    if kind in ("hypergraph", "hmetis"):
        assert traces is not None, "hypergraph sharding needs a workload trace"
        return hypergraph_partition(traces, graph.n_nodes, n_servers, seed=seed)
    raise ValueError(f"unknown sharding kind: {kind}")
