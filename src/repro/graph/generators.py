"""Deterministic synthetic graph generators (paper §6.1 substitutes).

The paper evaluates on LDBC SNB (SF1-SF30) and OGB (mag, papers100M).
Those datasets are not shipped offline, so we generate graphs with the
same *structural properties the algorithms are sensitive to*:

* SNB-like social graph: typed vertices (person / post / comment / forum),
  typed edges (knows / created / replyOf / containerOf / likes), power-law
  "knows" degree (social), heavy post/comment fan-out — because the paper's
  short-read templates traverse specific edge types from person roots.
* OGB-like citation graph: untyped, heavier-tailed power-law in-degree —
  neighborhood sampling is type-blind and degree-driven.

Everything is seeded and reproducible; scale is a parameter (the SNB scale
factors map to vertex counts).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.graph.csr import CSRGraph

# SNB-like type ids
PERSON, POST, COMMENT, FORUM = 0, 1, 2, 3
KNOWS, CREATED, REPLY_OF, CONTAINER_OF, LIKES, HAS_CREATOR = 0, 1, 2, 3, 4, 5

NODE_TYPE_NAMES = {PERSON: "person", POST: "post", COMMENT: "comment", FORUM: "forum"}
EDGE_TYPE_NAMES = {
    KNOWS: "knows",
    CREATED: "created",
    REPLY_OF: "replyOf",
    CONTAINER_OF: "containerOf",
    LIKES: "likes",
    HAS_CREATOR: "hasCreator",
}


@dataclasses.dataclass(frozen=True)
class SNBLikeGraph:
    graph: CSRGraph
    persons: np.ndarray
    posts: np.ndarray
    comments: np.ndarray
    forums: np.ndarray


def _power_law_targets(rng, n_src, n_dst_pool, mean_deg, alpha=1.8, dst_offset=0):
    """Draw power-law out-degrees and preferential targets."""
    deg = np.minimum(
        rng.zipf(alpha, size=n_src), max(4 * mean_deg, 8)
    ) + np.maximum(mean_deg - 1, 0)
    total = int(deg.sum())
    # preferential attachment approximated with a zipf-ranked pool
    ranks = rng.zipf(1.4, size=total) % n_dst_pool
    src = np.repeat(np.arange(n_src, dtype=np.int64), deg)
    dst = ranks.astype(np.int64) + dst_offset
    return src, dst


def snb_like(scale: int = 1, seed: int = 0) -> SNBLikeGraph:
    """SNB-like typed social graph.  ``scale``≈SF: SF1 ~ 30k persons here
    (reduced ~100x vs real SNB for CPU memory; structure preserved)."""
    rng = np.random.default_rng(seed)
    n_person = 3000 * scale
    n_forum = 800 * scale
    n_post = 12000 * scale
    n_comment = 30000 * scale

    p0 = 0
    f0 = n_person
    o0 = f0 + n_forum
    c0 = o0 + n_post
    n = c0 + n_comment

    node_types = np.empty(n, dtype=np.int16)
    node_types[p0:f0] = PERSON
    node_types[f0:o0] = FORUM
    node_types[o0:c0] = POST
    node_types[c0:n] = COMMENT

    srcs, dsts, etys = [], [], []

    def add(src, dst, et):
        srcs.append(src)
        dsts.append(dst)
        etys.append(np.full(len(src), et, np.int16))

    # person -knows-> person (power law, symmetric)
    s, d = _power_law_targets(rng, n_person, n_person, mean_deg=12)
    keep = s != d
    add(s[keep], d[keep], KNOWS)
    add(d[keep], s[keep], KNOWS)

    # person -created-> post / comment; inverse hasCreator
    post_creator = rng.integers(0, n_person, n_post)
    add(post_creator, np.arange(o0, c0), CREATED)
    add(np.arange(o0, c0), post_creator, HAS_CREATOR)
    comment_creator = rng.integers(0, n_person, n_comment)
    add(comment_creator, np.arange(c0, n), CREATED)
    add(np.arange(c0, n), comment_creator, HAS_CREATOR)

    # comment -replyOf-> post|comment (threads; earlier ids only)
    parent_is_post = rng.random(n_comment) < 0.6
    parent = np.where(
        parent_is_post,
        rng.integers(o0, c0, n_comment),
        c0 + rng.integers(0, np.maximum(np.arange(n_comment), 1)),
    )
    add(np.arange(c0, n), parent, REPLY_OF)

    # forum -containerOf-> post
    post_forum = rng.integers(f0, o0, n_post)
    add(post_forum, np.arange(o0, c0), CONTAINER_OF)

    # person -likes-> post (power-law popularity)
    s, d = _power_law_targets(rng, n_person, n_post, mean_deg=6, dst_offset=o0)
    add(s, d, LIKES)

    graph = CSRGraph.from_edges(
        n,
        np.concatenate(srcs),
        np.concatenate(dsts),
        np.concatenate(etys),
        node_types,
    )
    return SNBLikeGraph(
        graph=graph,
        persons=np.arange(p0, f0),
        posts=np.arange(o0, c0),
        comments=np.arange(c0, n),
        forums=np.arange(f0, o0),
    )


def ogb_like(n_nodes: int = 50_000, mean_deg: int = 15, seed: int = 0) -> CSRGraph:
    """OGB-like citation graph: untyped, power-law in-degree."""
    rng = np.random.default_rng(seed)
    src, dst = _power_law_targets(rng, n_nodes, n_nodes, mean_deg=mean_deg)
    keep = src != dst
    return CSRGraph.from_edges(n_nodes, src[keep], dst[keep], symmetrize=True)


def random_regular(n: int, d: int = 3, seed: int = 0) -> list[list[int]]:
    """Small d-regular graph as adjacency lists (hardness-gadget tests)."""
    rng = np.random.default_rng(seed)
    for _ in range(200):
        stubs = np.repeat(np.arange(n), d)
        rng.shuffle(stubs)
        pairs = stubs.reshape(-1, 2)
        if np.any(pairs[:, 0] == pairs[:, 1]):
            continue
        key = pairs.min(1) * n + pairs.max(1)
        if len(np.unique(key)) != len(key):
            continue
        adj: list[list[int]] = [[] for _ in range(n)]
        for a, b in pairs:
            adj[int(a)].append(int(b))
            adj[int(b)].append(int(a))
        return adj
    raise RuntimeError("failed to generate a simple regular graph")
