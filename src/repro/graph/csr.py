"""CSR graph storage (numpy host-side, jnp device-side views).

The dataset objects of the paper (§3.1) are "a vertex and its adjacency
list"; this module is the storage substrate those objects live in.  The
same CSR arrays feed the partitioners, the workload analyzers, the
distributed executor and the GNN models (via edge-index views), so there is
exactly one definition of the data graph in the framework.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class CSRGraph:
    """Compressed-sparse-row adjacency with optional typed edges.

    Attributes:
      indptr:     int64 [n+1]
      indices:    int32 [m]      out-neighbors, sorted per row
      edge_types: int16 [m] | None   label of each edge (SNB-like graphs)
      node_types: int16 [n] | None   label of each vertex
    """

    indptr: np.ndarray
    indices: np.ndarray
    edge_types: np.ndarray | None = None
    node_types: np.ndarray | None = None

    @property
    def n_nodes(self) -> int:
        return int(self.indptr.shape[0]) - 1

    @property
    def n_edges(self) -> int:
        return int(self.indices.shape[0])

    def degree(self, v: int | np.ndarray | None = None) -> np.ndarray:
        deg = np.diff(self.indptr)
        return deg if v is None else deg[v]

    def neighbors(self, v: int) -> np.ndarray:
        return self.indices[self.indptr[v] : self.indptr[v + 1]]

    def neighbors_typed(self, v: int, etype: int) -> np.ndarray:
        lo, hi = self.indptr[v], self.indptr[v + 1]
        nbr = self.indices[lo:hi]
        if self.edge_types is None:
            return nbr
        return nbr[self.edge_types[lo:hi] == etype]

    def edge_list(self) -> tuple[np.ndarray, np.ndarray]:
        """(src, dst) int arrays — the edge-index view used by GNN models."""
        src = np.repeat(
            np.arange(self.n_nodes, dtype=np.int32), np.diff(self.indptr)
        )
        return src, self.indices.astype(np.int32)

    def object_sizes(self, unit: float = 1.0, per_edge: float = 0.1) -> np.ndarray:
        """Paper's storage function f(v): vertex record + adjacency list."""
        return (unit + per_edge * np.diff(self.indptr)).astype(np.float64)

    @staticmethod
    def from_edges(
        n_nodes: int,
        src: np.ndarray,
        dst: np.ndarray,
        edge_types: np.ndarray | None = None,
        node_types: np.ndarray | None = None,
        symmetrize: bool = False,
    ) -> "CSRGraph":
        src = np.asarray(src, dtype=np.int64)
        dst = np.asarray(dst, dtype=np.int64)
        if symmetrize:
            src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
            if edge_types is not None:
                edge_types = np.concatenate([edge_types, edge_types])
        order = np.lexsort((dst, src))
        src, dst = src[order], dst[order]
        if edge_types is not None:
            edge_types = np.asarray(edge_types)[order]
        # dedup parallel edges
        keep = np.ones(len(src), dtype=bool)
        if len(src):
            keep[1:] = (src[1:] != src[:-1]) | (dst[1:] != dst[:-1])
        src, dst = src[keep], dst[keep]
        if edge_types is not None:
            edge_types = edge_types[keep].astype(np.int16)
        indptr = np.zeros(n_nodes + 1, dtype=np.int64)
        np.add.at(indptr, src + 1, 1)
        indptr = np.cumsum(indptr)
        return CSRGraph(
            indptr=indptr,
            indices=dst.astype(np.int32),
            edge_types=edge_types,
            node_types=(
                None if node_types is None else np.asarray(node_types, np.int16)
            ),
        )

    def subgraph_stats(self, part: np.ndarray) -> dict:
        """Edge-cut statistics for a partition assignment (used by tests)."""
        src, dst = self.edge_list()
        cut = part[src] != part[dst]
        return {
            "edge_cut": int(cut.sum()),
            "cut_fraction": float(cut.mean()) if len(src) else 0.0,
            "part_sizes": np.bincount(part).tolist(),
        }
