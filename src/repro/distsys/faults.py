"""Fault injection + elastic-event driver (paper §5.4 scenario source).

Generates reproducible sequences of cluster events — server failures,
recoveries, scale-out/scale-in — and applies them to a Cluster while
invoking the §5.4 incremental replication update so the latency bound is
re-established after each event.  Used by tests, the elastic launcher, and
the reshard-cost benchmark.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

from repro.core.replication import ReplicationScheme
from repro.core.reshard import ReshardingMap, apply_reshard, drain_server, repair_paths
from repro.distsys.cluster import Cluster


@dataclasses.dataclass(frozen=True)
class Event:
    kind: str          # "fail" | "recover" | "scale_out" | "scale_in"
    server: int
    at_step: int


def event_schedule(
    n_servers: int,
    n_events: int,
    horizon: int,
    seed: int = 0,
    kinds: tuple[str, ...] = ("fail", "recover"),
) -> list[Event]:
    rng = np.random.default_rng(seed)
    events = []
    for _ in range(n_events):
        events.append(
            Event(
                kind=str(rng.choice(list(kinds))),
                server=int(rng.integers(0, n_servers)),
                at_step=int(rng.integers(1, horizon)),
            )
        )
    return sorted(events, key=lambda e: e.at_step)


def apply_event(
    cluster: Cluster,
    rmap: ReshardingMap,
    event: Event,
    f: np.ndarray | None = None,
) -> dict:
    """Apply one event; §5.4 incremental update restores feasibility."""
    if event.kind == "fail":
        if sum(s.alive for s in cluster.servers) <= 1:
            return {"skipped": True}
        cluster.fail_server(event.server)
        moves, rep = drain_server(cluster.scheme, rmap, event.server, f)
        return {
            "moved": rep.moved_originals,
            "transferred": rep.replicas_transferred,
            "deleted": rep.replicas_deleted,
            "bytes": rep.bytes_transferred,
        }
    if event.kind == "recover":
        cluster.recover_server(event.server)
        return {"recovered": event.server}
    if event.kind == "scale_in":
        return apply_event(
            cluster, rmap, Event("fail", event.server, event.at_step), f
        )
    if event.kind == "scale_out":
        # new server joins empty; rebalancing is a planned reshard:
        # move a 1/S' slice of originals to it.
        scheme = cluster.scheme
        S_new = event.server
        if S_new >= scheme.n_servers:
            grow = S_new + 1 - scheme.n_servers
            scheme.mask = np.pad(scheme.mask, ((0, 0), (0, grow)))
            for s in range(scheme.n_servers - grow, scheme.n_servers):
                from repro.distsys.cluster import ServerState

                cluster.servers.append(ServerState(s))
        victims = np.nonzero(scheme.shard != S_new)[0]
        take = victims[:: max(scheme.n_servers, 1)]
        moves = {int(u): S_new for u in take}
        rep = apply_reshard(scheme, rmap, moves, f)
        return {
            "moved": rep.moved_originals,
            "transferred": rep.replicas_transferred,
            "bytes": rep.bytes_transferred,
        }
    raise ValueError(event.kind)


def run_schedule(
    cluster: Cluster,
    rmap: ReshardingMap,
    events: list[Event],
    f: np.ndarray | None = None,
) -> Iterator[tuple[Event, dict]]:
    for ev in events:
        yield ev, apply_event(cluster, rmap, ev, f)
