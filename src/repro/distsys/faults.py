"""Fault injection + elastic-event driver (paper §5.4 scenario source).

Generates reproducible sequences of cluster events — server failures,
recoveries, scale-out/scale-in — and applies them to a Cluster while
invoking the §5.4 incremental replication update so the latency bound is
re-established after each event.  Used by tests, the elastic launcher, and
the reshard-cost benchmark.

Two event vocabularies live here:

* **step-indexed** :class:`Event` schedules (``event_schedule`` /
  ``apply_event`` / ``run_schedule``) drive the reshard machinery — a
  failure permanently drains the server and re-homes its partition;
* **microsecond-indexed** :class:`ChaosEvent` schedules
  (``chaos_schedule``) drive the serving simulator's mid-drift
  kill/revive injection (``repro.serve.simulate(chaos=...)``), where a
  killed server keeps its data and comes back.

Both samplers track liveness while sampling, so a schedule never asks to
kill a dead server or revive a live one.  :func:`violation_windows`
post-processes a simulated timeline into the contiguous SLO-violation
intervals a chaos run is scored on.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

from repro.core.replication import ReplicationScheme
from repro.core.reshard import ReshardingMap, apply_reshard, drain_server, repair_paths
from repro.distsys.cluster import Cluster, ServerState


@dataclasses.dataclass(frozen=True)
class Event:
    kind: str          # "fail" | "recover" | "scale_out" | "scale_in"
    server: int
    at_step: int


def event_schedule(
    n_servers: int,
    n_events: int,
    horizon: int,
    seed: int = 0,
    kinds: tuple[str, ...] = ("fail", "recover"),
) -> list[Event]:
    """Sample a reproducible, *state-consistent* event sequence.

    Liveness is tracked while sampling: ``fail`` only targets a live
    server (and never the last one), ``recover`` only a dead one,
    ``scale_out`` always joins the next fresh index.  When the sampled
    kind has no valid target the other fail/recover kind stands in; when
    neither has one the slot is dropped — so every emitted event is
    applicable, and ``apply_event`` never has to skip a scheduled event.
    (May therefore return fewer than ``n_events`` events.)
    """
    rng = np.random.default_rng(seed)
    alive = np.ones(n_servers, bool)
    steps = sorted(int(rng.integers(1, horizon)) for _ in range(n_events))
    events: list[Event] = []
    for step in steps:
        kind = str(rng.choice(list(kinds)))
        n_alive = int(alive.sum())
        if kind in ("fail", "scale_in") and n_alive <= 1:
            kind = "recover" if "recover" in kinds and (~alive).any() else None
        elif kind == "recover" and not (~alive).any():
            kind = "fail" if "fail" in kinds and n_alive > 1 else None
        if kind is None:
            continue
        if kind in ("fail", "scale_in"):
            server = int(rng.choice(np.nonzero(alive)[0]))
            alive[server] = False
        elif kind == "recover":
            server = int(rng.choice(np.nonzero(~alive)[0]))
            alive[server] = True
        else:  # scale_out: the next fresh server index joins
            server = len(alive)
            alive = np.append(alive, True)
        events.append(Event(kind=kind, server=server, at_step=step))
    return events


def _drain_dirty_objects(
    scheme: ReplicationScheme, rmap: ReshardingMap, server: int
) -> np.ndarray:
    """Objects whose replica rows a drain of ``server`` will touch.

    The drain clears every holder bit at the server, moves its partition,
    and transfers each moved original's RM-associated replicas — the
    union of all three is the exact dirty set an incremental latency
    cache must drop (computed *before* the drain mutates the scheme).
    """
    dirty = set(np.nonzero(scheme.mask[:, server])[0].tolist())
    for u in np.nonzero(scheme.shard == server)[0]:
        dirty.add(int(u))
        dirty.update(int(v) for v in rmap.rm.get(int(u), ()))
    return np.fromiter(dirty, np.int64) if dirty else np.zeros(0, np.int64)


def apply_event(
    cluster: Cluster,
    rmap: ReshardingMap,
    event: Event,
    f: np.ndarray | None = None,
    engine=None,
) -> dict:
    """Apply one event; §5.4 incremental update restores feasibility.

    ``engine`` (a :class:`~repro.engine.LatencyEngine` holding
    ``cluster.scheme``) is resynced after every scheme mutation: the
    device-resident packed words are re-packed and the incremental
    latency cache drops exactly the dirty objects the event touched
    (everything, for a scale-out's layout change).  Without it a
    resident engine would keep evaluating the pre-event words.

    An inapplicable event is reported, not silently swallowed: the
    returned dict carries ``{"skipped": True, "reason": ...}``.
    """
    scheme = cluster.scheme
    if event.kind == "fail":
        if sum(s.alive for s in cluster.servers) <= 1:
            return {
                "skipped": True,
                "reason": "last alive server cannot fail",
                "server": event.server,
            }
        if not cluster.servers[event.server].alive:
            return {
                "skipped": True,
                "reason": "server already dead",
                "server": event.server,
            }
        dirty = _drain_dirty_objects(scheme, rmap, event.server)
        cluster.fail_server(event.server)
        moves, rep = drain_server(scheme, rmap, event.server, f)
        if engine is not None:
            engine.refresh(objects=dirty)
        return {
            "moved": rep.moved_originals,
            "moves": moves,
            "dirty_objects": int(len(dirty)),
            "transferred": rep.replicas_transferred,
            "deleted": rep.replicas_deleted,
            "bytes": rep.bytes_transferred,
        }
    if event.kind == "recover":
        if cluster.servers[event.server].alive:
            return {
                "skipped": True,
                "reason": "server already alive",
                "server": event.server,
            }
        cluster.recover_server(event.server)
        return {"recovered": event.server}
    if event.kind == "scale_in":
        return apply_event(
            cluster, rmap, Event("fail", event.server, event.at_step), f,
            engine=engine,
        )
    if event.kind == "scale_out":
        # new server joins empty; rebalancing is a planned reshard:
        # move a 1/S' slice of originals to it.
        S_new = event.server
        if S_new >= scheme.n_servers:
            grow = S_new + 1 - scheme.n_servers
            scheme.mask = np.pad(scheme.mask, ((0, 0), (0, grow)))
            for s in range(scheme.n_servers - grow, scheme.n_servers):
                cluster.servers.append(ServerState(s))
        victims = np.nonzero(scheme.shard != S_new)[0]
        take = victims[:: max(scheme.n_servers, 1)]
        moves = {int(u): S_new for u in take}
        rep = apply_reshard(scheme, rmap, moves, f)
        if engine is not None:
            # the server axis itself changed: the packed [n, W] word
            # layout is re-derived and every cached latency dropped
            engine.refresh()
        return {
            "moved": rep.moved_originals,
            "transferred": rep.replicas_transferred,
            "bytes": rep.bytes_transferred,
        }
    raise ValueError(event.kind)


def run_schedule(
    cluster: Cluster,
    rmap: ReshardingMap,
    events: list[Event],
    f: np.ndarray | None = None,
    engine=None,
) -> Iterator[tuple[Event, dict]]:
    for ev in events:
        yield ev, apply_event(cluster, rmap, ev, f, engine=engine)


# -- chaos schedules for the serving simulator ---------------------------


@dataclasses.dataclass(frozen=True)
class ChaosEvent:
    """A liveness flip injected into a running simulation.

    Unlike :class:`Event`'s ``fail`` (permanent loss, data drained), a
    ``kill`` models a crash/partition: the server's replicas stay on disk
    and serve again the moment a ``revive`` lands.
    """

    at_us: float
    kind: str          # "kill" | "revive"
    server: int


def chaos_schedule(
    n_servers: int,
    n_events: int,
    horizon_us: float,
    seed: int = 0,
    min_alive: int = 1,
) -> list[ChaosEvent]:
    """Sample a state-consistent kill/revive timeline for ``simulate``.

    Kills only target live servers and never push the live count below
    ``min_alive``; revives only target dead ones.  Event times are
    uniform over ``(0, horizon_us)``, sorted.  Slots with no applicable
    event (everything alive and at the kill floor) are dropped.
    """
    rng = np.random.default_rng(seed)
    alive = np.ones(n_servers, bool)
    times = np.sort(rng.uniform(0.0, horizon_us, n_events))
    events: list[ChaosEvent] = []
    for at in times:
        can_kill = int(alive.sum()) > min_alive
        can_revive = bool((~alive).any())
        if not can_kill and not can_revive:
            continue
        if can_kill and (not can_revive or rng.random() < 0.5):
            server = int(rng.choice(np.nonzero(alive)[0]))
            alive[server] = False
            events.append(ChaosEvent(float(at), "kill", server))
        else:
            server = int(rng.choice(np.nonzero(~alive)[0]))
            alive[server] = True
            events.append(ChaosEvent(float(at), "revive", server))
    return events


def violation_windows(
    finish_us: np.ndarray,
    violated: np.ndarray,
    bin_us: float = 1000.0,
) -> list[tuple[float, float]]:
    """Contiguous SLO-violation windows of a simulated timeline.

    Bins query completions on ``bin_us`` boundaries; a bin violates if
    any query finishing in it missed its SLO, and adjacent violating
    bins merge into one ``(start_us, end_us)`` window.  The summed
    window length is the headline a chaos run is scored on — a reactive
    controller shortens it, a static scheme rides the whole outage.
    """
    finish_us = np.asarray(finish_us, np.float64)
    violated = np.asarray(violated, bool)
    if finish_us.size == 0 or not violated.any():
        return []
    bins = np.floor(finish_us / bin_us).astype(np.int64)
    bad = np.unique(bins[violated])
    windows: list[tuple[float, float]] = []
    start = prev = bad[0]
    for b in bad[1:]:
        if b == prev + 1:
            prev = b
            continue
        windows.append((float(start * bin_us), float((prev + 1) * bin_us)))
        start = prev = b
    windows.append((float(start * bin_us), float((prev + 1) * bin_us)))
    return windows


def time_to_repair(
    windows: list[tuple[float, float]], kill_us: float
) -> float:
    """Time from a kill to the end of the violation window it opened.

    0.0 when the kill never produced a violating window (the scheme rode
    through it — what a k-resilient scheme is supposed to do).
    """
    for lo, hi in windows:
        if hi > kill_us:
            return max(0.0, hi - kill_us)
    return 0.0
