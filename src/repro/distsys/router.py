"""Replica-aware request routing (paper Fig 4 'sharding-based routing').

The router decides which server coordinates a query (where its root access
runs) and which server serves each remote hop.  Policies:

* ``home``        — original copy per the sharding function (paper default;
                    Alg 2 assumes root routing by d).
* ``replica_lb``  — among servers holding a copy of the root, pick the one
                    with the least outstanding load (uses replicas produced
                    by the replication scheme as routing targets; a benefit
                    the paper notes for t=0 single-site schemes).
* ``hedged``      — primary + backup pick for straggler mitigation.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.replication import ReplicationScheme


@dataclasses.dataclass
class Router:
    scheme: ReplicationScheme
    policy: str = "home"

    def route_roots(
        self,
        roots: np.ndarray,
        alive: np.ndarray | None = None,
        seed: int = 0,
    ) -> np.ndarray:
        """Coordinator server per query root."""
        S = self.scheme.n_servers
        alive = np.ones(S, bool) if alive is None else alive
        home = self.scheme.shard[roots]
        if self.policy == "home":
            ok = alive[home]
            if ok.all():
                return home.astype(np.int32)
            # fail-over to first alive replica
            mask = self.scheme.mask[roots] & alive[None, :]
            fb = np.where(mask.any(1), mask.argmax(1), -1)
            return np.where(ok, home, fb).astype(np.int32)
        if self.policy in ("replica_lb", "hedged"):
            rng = np.random.default_rng(seed)
            mask = self.scheme.mask[roots] & alive[None, :]
            load = np.zeros(S, np.int64)
            out = np.empty(len(roots), np.int32)
            order = rng.permutation(len(roots))
            for i in order:
                cands = np.nonzero(mask[i])[0]
                if len(cands) == 0:
                    out[i] = -1
                    continue
                pick = cands[np.argmin(load[cands])]
                out[i] = pick
                load[pick] += 1
            return out
        raise ValueError(self.policy)

    def route_hop(
        self, obj: int, current: int, alive: np.ndarray | None = None
    ) -> tuple[int, bool]:
        """(server, is_remote) for one access from ``current`` (Eqn 1)."""
        alive_ok = True if alive is None else alive[current]
        if alive_ok and self.scheme.mask[obj, current]:
            return current, False
        home = int(self.scheme.shard[obj])
        if alive is None or alive[home]:
            return home, True
        copies = np.nonzero(
            self.scheme.mask[obj] & (alive if alive is not None else True)
        )[0]
        return (int(copies[0]) if len(copies) else -1), True
