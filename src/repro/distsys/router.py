"""Replica-aware request routing (paper Fig 4 'sharding-based routing').

The router decides which server coordinates a query (where its root access
runs) and which server serves each remote hop.  Policies:

* ``home``        — original copy per the sharding function (paper default;
                    Alg 2 assumes root routing by d).
* ``replica_lb``  — among servers holding a copy of the root, pick the one
                    with the least outstanding load (uses replicas produced
                    by the replication scheme as routing targets; a benefit
                    the paper notes for t=0 single-site schemes).
* ``hedged``      — primary + backup pick for straggler mitigation: the
                    primary is the least-loaded copy holder, the backup the
                    least-loaded *other* holder (-1 when the root has a
                    single alive copy).  The executor issues both and takes
                    the min-latency completion.

All load-balanced policies accept an optional external ``load`` vector —
the live per-server queue depths maintained by ``repro.serve.simulator`` /
``Cluster.queue_depths()`` — so routing is queue-aware under traffic rather
than balancing only the routing counts of the current batch.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.replication import ReplicationScheme


@dataclasses.dataclass
class Router:
    scheme: ReplicationScheme
    policy: str = "home"

    def _lb_pick(
        self,
        roots: np.ndarray,
        alive: np.ndarray,
        seed: int,
        load: np.ndarray | None,
        backup: bool = False,
    ) -> np.ndarray | tuple[np.ndarray, np.ndarray]:
        """Least-loaded copy-holder per root (optionally with a backup)."""
        S = self.scheme.n_servers
        rng = np.random.default_rng(seed)
        mask = self.scheme.mask[roots] & alive[None, :]
        run_load = (
            np.zeros(S, np.int64)
            if load is None
            else np.asarray(load, np.int64).copy()
        )
        out = np.empty(len(roots), np.int32)
        out2 = np.full(len(roots), -1, np.int32)
        order = rng.permutation(len(roots))
        for i in order:
            cands = np.nonzero(mask[i])[0]
            if len(cands) == 0:
                out[i] = -1
                continue
            by_load = cands[np.argsort(run_load[cands], kind="stable")]
            pick = by_load[0]
            out[i] = pick
            run_load[pick] += 1
            if backup and len(by_load) > 1:
                out2[i] = by_load[1]
        if backup:
            return out, out2
        return out

    def route_roots(
        self,
        roots: np.ndarray,
        alive: np.ndarray | None = None,
        seed: int = 0,
        load: np.ndarray | None = None,
    ) -> np.ndarray:
        """Coordinator server per query root (primary pick only).

        ``load`` seeds the balancing with live queue depths (queue-aware
        routing); without it only the routing counts of this call balance.
        """
        S = self.scheme.n_servers
        alive = np.ones(S, bool) if alive is None else alive
        home = self.scheme.shard[roots]
        if self.policy == "home":
            ok = alive[home]
            if ok.all():
                return home.astype(np.int32)
            # fail-over to first alive replica; -1 when no copy survives
            mask = self.scheme.mask[roots] & alive[None, :]
            fb = np.where(mask.any(1), mask.argmax(1), -1)
            return np.where(ok, home, fb).astype(np.int32)
        if self.policy == "replica_lb":
            return self._lb_pick(roots, alive, seed, load)
        if self.policy == "hedged":
            primary, _ = self._lb_pick(roots, alive, seed, load, backup=True)
            return primary
        raise ValueError(self.policy)

    def route_roots_hedged(
        self,
        roots: np.ndarray,
        alive: np.ndarray | None = None,
        seed: int = 0,
        load: np.ndarray | None = None,
    ) -> tuple[np.ndarray, np.ndarray]:
        """(primary, backup) coordinator picks for straggler hedging.

        The backup is the least-loaded alive copy holder distinct from the
        primary, or -1 when the root has at most one alive copy (nothing to
        hedge against).  The executor races both and keeps the faster
        completion.
        """
        S = self.scheme.n_servers
        alive = np.ones(S, bool) if alive is None else alive
        return self._lb_pick(roots, alive, seed, load, backup=True)

    def route_hop(
        self,
        obj: int,
        current: int,
        alive: np.ndarray | None = None,
        load: np.ndarray | None = None,
    ) -> tuple[int, bool]:
        """(server, is_remote) for one access from ``current`` (Eqn 1).

        Without ``load`` a remote hop goes to the object's home server
        (Eqn 1's second case), falling back to the lowest-id alive copy
        holder when the home is dead.  With ``load`` (live per-server
        queue depths, ``Cluster.queue_depths()``) the remote-hop replica
        tie-break is *queue-aware*: among alive copy holders the
        least-loaded one serves the hop, the home server winning ties —
        so a hot replica with a deep queue gets skipped even though Eqn 1
        would nominally route there.  Locality is unchanged either way: a
        copy at ``current`` always short-circuits the hop.

        This is the scalar twin of the batched ``queue_aware`` policy
        walk (``repro.engine.routing``): the loaded pick delegates to the
        same :func:`~repro.engine.routing.pick_holder_host` oracle the
        engine backends are parity-tested against.
        """
        from repro.engine.routing import pick_holder_host

        alive_ok = True if alive is None else alive[current]
        if alive_ok and self.scheme.mask[obj, current]:
            return current, False
        home = int(self.scheme.shard[obj])
        holders = self.scheme.mask[obj].copy()
        if alive is not None:
            holders &= alive
        if load is not None:
            return pick_holder_host(holders, home, load), True
        if alive is None or alive[home]:
            return home, True
        copies = np.nonzero(holders)[0]
        return (int(copies[0]) if len(copies) else -1), True
