"""Checkpoint / restore for long-running jobs (fault tolerance substrate).

Design (production-style, no orbax in this environment):
  * a checkpoint is a directory ``step_<N>/`` holding one ``.npz`` per
    top-level pytree group plus a JSON ``manifest.json`` with the tree
    structure, shapes, dtypes, step, and a content checksum;
  * writes go to ``step_<N>.tmp/`` then ``os.rename`` — atomic publish, a
    crashed writer never corrupts the latest checkpoint;
  * ``save_async`` snapshots to host memory synchronously (cheap) and
    writes on a background thread — training continues;
  * ``restore_latest`` scans the directory, verifies the manifest, and
    rebuilds the pytree (device placement is the caller's concern: pass
    the target sharding to ``jax.device_put`` after restore);
  * retention keeps the newest K checkpoints.

Works for model/optimizer pytrees and for replication-scheme artifacts
(mask + shard arrays) alike — anything jax.tree flattenable into arrays.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
import threading

import jax
import numpy as np


def _flatten(tree) -> tuple[list[np.ndarray], list[str], object]:
    leaves, treedef = jax.tree.flatten(tree)
    arrs = [np.asarray(l) for l in leaves]
    names = [f"leaf_{i}" for i in range(len(arrs))]
    return arrs, names, treedef


def _checksum(arrs: list[np.ndarray]) -> str:
    h = hashlib.sha256()
    for a in arrs:
        h.update(str(a.shape).encode())
        h.update(str(a.dtype).encode())
        h.update(a.tobytes()[:65536])  # prefix checksum: fast, catches trunc
    return h.hexdigest()[:16]


@dataclasses.dataclass
class CheckpointManager:
    directory: str
    keep: int = 3

    def __post_init__(self):
        os.makedirs(self.directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------------
    def _write(self, step: int, arrs, names, treedef_repr: str) -> None:
        tmp = os.path.join(self.directory, f"step_{step}.tmp")
        final = os.path.join(self.directory, f"step_{step}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **dict(zip(names, arrs)))
        manifest = {
            "step": step,
            "names": names,
            "shapes": [list(a.shape) for a in arrs],
            "dtypes": [str(a.dtype) for a in arrs],
            "treedef": treedef_repr,
            "checksum": _checksum(arrs),
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as fh:
            json.dump(manifest, fh)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._gc()

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"))

    # ------------------------------------------------------------------
    def save(self, step: int, tree) -> None:
        arrs, names, treedef = _flatten(tree)
        self._write(step, arrs, names, str(treedef))

    def save_async(self, step: int, tree) -> None:
        """Snapshot now (host copies), write in the background."""
        self.wait()
        arrs, names, treedef = _flatten(tree)  # host copy = snapshot
        self._thread = threading.Thread(
            target=self._write, args=(step, arrs, names, str(treedef)), daemon=True
        )
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # ------------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name.split("_", 1)[1]))
                except ValueError:
                    pass
        return sorted(out)

    def restore(self, step: int, like):
        """Restore into the structure of ``like`` (shape/dtype verified)."""
        self.wait()
        path = os.path.join(self.directory, f"step_{step}")
        with open(os.path.join(path, "manifest.json")) as fh:
            manifest = json.load(fh)
        data = np.load(os.path.join(path, "arrays.npz"))
        arrs = [data[n] for n in manifest["names"]]
        if _checksum(arrs) != manifest["checksum"]:
            raise IOError(f"checksum mismatch in checkpoint step_{step}")
        leaves, treedef = jax.tree.flatten(like)
        assert len(leaves) == len(arrs), "checkpoint/tree structure mismatch"
        for got, want in zip(arrs, leaves):
            assert got.shape == np.shape(want), (got.shape, np.shape(want))
        return jax.tree.unflatten(treedef, arrs)

    def restore_latest(self, like):
        steps = self.all_steps()
        if not steps:
            return None, -1
        return self.restore(steps[-1], like), steps[-1]
