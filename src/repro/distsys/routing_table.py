"""Client-side routing tables: direct-to-shard dispatch without the root
coordinator hop.

Every query in the baseline serving path enters through the root
coordinator (``LatencyModel.coordinator_us`` — the barrier of Def 4.3),
which also resolves where the root object lives.  A client that caches a
snapshot of the scheme + liveness can skip that hop and open the query
directly at the root's server — the standard "smart client" optimization
(HBase meta cache, Cassandra token-aware drivers).

The price is staleness: the snapshot ages while servers die, recover, and
replicas move.  :class:`RoutingTable` bounds it two ways:

* **staleness-bounded refresh** — :meth:`maybe_refresh` re-snapshots from
  the authoritative cluster state once the copy is older than
  ``max_age_us`` (a pull model: no invalidation fan-out on the write
  path, exactly because scheme deltas are monotone 0->1 flips — a stale
  table routes to a *valid but maybe suboptimal* holder, never to a
  server that lost the object, unless that server died);
* **fallback-to-coordinator on miss** — :meth:`route_root` returns the
  snapshot's pick; the serving layer checks it against live truth and,
  on a miss (target dead, or no longer holding the object), falls back
  to the coordinator path *and* force-refreshes the table, so one miss
  repairs all subsequent queries of that client.

``simulate(routing_table=...)`` threads this through the serving
simulator: a direct hit skips the coordinator barrier, a miss pays it.
The hit/fallback/refresh counters are the benchmark headline —
direct-hit rate under chaos quantifies how much coordinator capacity the
tables save while liveness churns.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.distsys.cluster import Cluster


@dataclasses.dataclass
class RoutingTable:
    """One client's cached snapshot of (scheme, liveness).

    ``max_age_us`` bounds staleness: a lookup first refreshes when the
    snapshot is older.  ``version`` counts refreshes (exposed so tests
    and benchmarks can assert refresh behavior); the counters make the
    direct-vs-fallback split observable.
    """

    cluster: Cluster
    max_age_us: float = 50_000.0
    # snapshot state (filled by refresh)
    mask: np.ndarray | None = None
    shard: np.ndarray | None = None
    alive: np.ndarray | None = None
    fetched_at_us: float = -np.inf
    version: int = 0
    # counters
    lookups: int = 0
    direct_hits: int = 0
    fallbacks: int = 0
    refreshes: int = 0

    def __post_init__(self):
        self.refresh(0.0)

    def refresh(self, now_us: float) -> None:
        """Pull a fresh snapshot from the authoritative cluster state."""
        self.mask = np.asarray(self.cluster.scheme.mask, bool).copy()
        self.shard = np.asarray(self.cluster.scheme.shard, np.int64).copy()
        self.alive = np.asarray(
            [s.alive for s in self.cluster.servers], bool
        )
        self.fetched_at_us = float(now_us)
        self.version += 1
        self.refreshes += 1

    def maybe_refresh(self, now_us: float) -> bool:
        """Staleness-bounded refresh; True if the snapshot was re-pulled."""
        if now_us - self.fetched_at_us > self.max_age_us:
            self.refresh(now_us)
            return True
        return False

    def route_root(self, obj: int) -> int:
        """The snapshot's server pick for a query rooted at ``obj``.

        Snapshot-failover semantics (mirrors the executor's
        ``failover_home`` against the *cached* view): the home server
        when the snapshot believes it alive, else the lowest-id
        snapshot-alive holder, else -1 (the snapshot knows of no live
        copy — the caller must take the coordinator path).
        """
        home = int(self.shard[obj])
        if home < len(self.alive) and self.alive[home]:
            return home
        # a snapshot taken before a scale-out is narrower than the live
        # cluster: only the width both views share can be consulted
        w = min(self.mask.shape[1], len(self.alive))
        holders = np.nonzero(self.mask[obj, :w] & self.alive[:w])[0]
        return int(holders[0]) if len(holders) else -1

    def lookup(self, obj: int, now_us: float) -> tuple[int, bool]:
        """Route a query root; validate against live truth.

        Returns ``(server, direct)``: with ``direct=True`` the snapshot's
        pick is live-valid (alive and actually holding the object) and
        the query goes direct-to-shard, skipping the coordinator hop.
        Otherwise the snapshot missed — the miss is counted, the table
        force-refreshed (one miss repairs the client's future lookups),
        and the caller routes through the coordinator.
        """
        self.maybe_refresh(now_us)
        self.lookups += 1
        target = self.route_root(int(obj))
        if target >= 0 and self.cluster.servers[target].alive and bool(
            self.cluster.scheme.mask[obj, target]
        ):
            self.direct_hits += 1
            return target, True
        self.fallbacks += 1
        self.refresh(now_us)
        return target, False

    def summary(self) -> dict:
        return {
            "lookups": self.lookups,
            "direct_hits": self.direct_hits,
            "fallbacks": self.fallbacks,
            "refreshes": self.refreshes,
            "direct_hit_rate": (
                self.direct_hits / self.lookups if self.lookups else 0.0
            ),
            "version": self.version,
        }
