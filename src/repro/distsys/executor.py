"""Distributed query executor with a calibrated RPC latency model (§2, §3.1).

Execution follows the paper's subquery-shipping model: a query is routed to
the home server of its root; each subsequent access is local when a copy
exists at the current server (Eqn 1), otherwise a nested RPC ships the
subquery to the home server of the next object.  Parallel sibling paths
overlap; the query completes when its slowest root-to-leaf path completes
(Def 4.3), plus a result-gathering barrier at the coordinator.

Latency model.  The paper's measurements (Fig 2a, Fig 6b) show latency
linear in the number of distributed traversals on the critical path, with
local accesses 20-100x faster than remote ones.  We model

    latency(path) = a * n_local_accesses + b * n_distributed_traversals

with defaults a = 2 microseconds (in-memory lookup + marshalling) and
b = 60 microseconds (Gigabit RTT + handler), b/a = 30x, matching the
paper's "2-hop local is 30X faster than 8-node distributed" citation.
Both parameters are configurable; a small lognormal jitter produces the
tail the paper plots (p99).

The access-function walk itself is ``repro.engine``'s: the executor packs
the liveness-filtered mask, asks the engine for the per-position access
trace (visited server + locality under Eqn 1 with fail-over homes), and
merely decorates those outputs with the RPC latency model and per-server
load counters.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.paths import PathSet
from repro.core.replication import ReplicationScheme
from repro.distsys.cluster import Cluster
from repro.engine import pack_bool_mask, to_device
from repro.engine.backends import access_trace


@dataclasses.dataclass(frozen=True)
class LatencyModel:
    local_us: float = 2.0
    remote_us: float = 60.0
    jitter_sigma: float = 0.15  # lognormal sigma on each term
    coordinator_us: float = 4.0  # result gathering / aggregation

    def sample(
        self, n_local: np.ndarray, n_remote: np.ndarray, rng: np.random.Generator
    ) -> np.ndarray:
        jit_l = rng.lognormal(0.0, self.jitter_sigma, size=n_local.shape)
        jit_r = rng.lognormal(0.0, self.jitter_sigma, size=n_remote.shape)
        return (
            self.local_us * n_local * jit_l
            + self.remote_us * n_remote * jit_r
            + self.coordinator_us
        )


@dataclasses.dataclass
class ExecutionReport:
    """Aggregate statistics of one workload execution."""

    query_latency_us: np.ndarray      # [n_queries]
    query_traversals: np.ndarray      # [n_queries] critical-path traversals
    per_server_local: np.ndarray      # [S]
    per_server_rpcs: np.ndarray       # [S]
    throughput_qps: float

    def percentile(self, q: float) -> float:
        return float(np.percentile(self.query_latency_us, q))

    @property
    def mean_us(self) -> float:
        return float(self.query_latency_us.mean())

    @property
    def p99_us(self) -> float:
        return self.percentile(99.0)

    def summary(self) -> dict:
        return {
            "mean_us": self.mean_us,
            "p50_us": self.percentile(50),
            "p95_us": self.percentile(95),
            "p99_us": self.p99_us,
            "max_traversals": int(self.query_traversals.max(initial=0)),
            "mean_traversals": float(self.query_traversals.mean())
            if len(self.query_traversals)
            else 0.0,
            "throughput_qps": self.throughput_qps,
        }


def _path_costs(
    pathset: PathSet, scheme: ReplicationScheme, alive: np.ndarray
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Engine-backed access walk (Eqn 1) with liveness, plus counters.

    Returns (n_local [P], n_remote [P], local_per_server [S], rpc_per_server [S]).
    A dead server's copies are unavailable; originals of dead servers are
    served by the lowest-id alive replica holder (fail-over), else the
    access is charged as remote to a random alive server (degraded read).
    """
    P, L = pathset.objects.shape
    S = scheme.n_servers
    mask = scheme.mask & alive[None, :]
    # fail-over home: original if alive, else first alive copy, else -1
    orig_alive = alive[scheme.shard]
    first_alive = np.where(
        mask.any(axis=1), mask.argmax(axis=1), -1
    ).astype(np.int32)
    home = np.where(orig_alive, scheme.shard, first_alive).astype(np.int32)

    # the walk itself is the engine's (packed upload, 32x below bool):
    servers, local = access_trace(
        to_device(np.asarray(pathset.objects, np.int32)),
        to_device(np.asarray(pathset.lengths, np.int32)),
        to_device(pack_bool_mask(mask)),
        to_device(home),
    )
    servers = np.asarray(servers)
    local = np.asarray(local)

    valid = pathset.objects >= 0
    remote = valid & ~local  # only positions >= 1 can be remote
    n_local = local.sum(axis=1).astype(np.int64)
    n_remote = remote.sum(axis=1).astype(np.int64)

    srv_c = np.maximum(servers, 0)
    local_srv = np.bincount(srv_c[local], minlength=S).astype(np.int64)
    rpc_srv = np.bincount(srv_c[remote], minlength=S).astype(np.int64)
    return n_local, n_remote, local_srv, rpc_srv


def execute_workload(
    cluster: Cluster,
    pathset: PathSet,
    model: LatencyModel | None = None,
    seed: int = 0,
    hedge_replicas: bool = False,
) -> ExecutionReport:
    """Execute a workload; per-query latency = slowest path + coordination.

    ``hedge_replicas``: straggler mitigation — when a remote hop has >1
    alive copy, the executor issues hedged requests and takes the faster
    jitter draw (min of two lognormals), a direct secondary benefit of the
    replication scheme.
    """
    model = model or LatencyModel()
    rng = np.random.default_rng(seed)
    alive = np.asarray([s.alive for s in cluster.servers], bool)
    n_local, n_remote, local_srv, rpc_srv = _path_costs(
        pathset, cluster.scheme, alive
    )

    lat = model.sample(n_local.astype(np.float64), n_remote.astype(np.float64), rng)
    if hedge_replicas:
        # hedging halves the effective tail of the remote term where copies
        # exist; approximate with a second draw on the remote component.
        alt = model.sample(
            n_local.astype(np.float64), n_remote.astype(np.float64), rng
        )
        n_copies = cluster.scheme.mask[np.maximum(pathset.objects, 0)].sum(-1)
        hedgeable = (n_copies.max(axis=1) > 1)
        lat = np.where(hedgeable, np.minimum(lat, alt), lat)

    nq = pathset.n_queries
    q_lat = np.zeros(nq, np.float64)
    q_trav = np.zeros(nq, np.int64)
    np.maximum.at(q_lat, pathset.query_ids, lat)
    np.maximum.at(q_trav, pathset.query_ids, n_remote)

    for s in cluster.servers:
        s.local_accesses += int(local_srv[s.server_id])
        s.remote_rpcs_in += int(rpc_srv[s.server_id])

    # throughput model: per-server service capacity is shared; the
    # bottleneck server's work bounds qps (open-loop approximation).
    work_us = local_srv * model.local_us + rpc_srv * model.remote_us
    busiest = work_us.max() if work_us.size else 1.0
    qps = nq / (busiest / 1e6) if busiest > 0 else float("inf")
    return ExecutionReport(
        query_latency_us=q_lat,
        query_traversals=q_trav,
        per_server_local=local_srv,
        per_server_rpcs=rpc_srv,
        throughput_qps=qps,
    )
