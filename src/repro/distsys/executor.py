"""Distributed query executor with a calibrated RPC latency model (§2, §3.1).

Execution follows the paper's subquery-shipping model: a query is routed to
the home server of its root (or to a replica holder picked by a
``Router`` policy); each subsequent access is local when a copy exists at
the current server (Eqn 1), otherwise a nested RPC ships the subquery to
the home server of the next object.  Parallel sibling paths overlap; the
query completes when its slowest root-to-leaf path completes (Def 4.3),
plus a result-gathering barrier at the coordinator.

Latency model.  The paper's measurements (Fig 2a, Fig 6b) show latency
linear in the number of distributed traversals on the critical path, with
local accesses 20-100x faster than remote ones.  We model

    latency(path) = a * n_local_accesses + b * n_distributed_traversals

with defaults a = 2 microseconds (in-memory lookup + marshalling) and
b = 60 microseconds (Gigabit RTT + handler), b/a = 30x, matching the
paper's "2-hop local is 30X faster than 8-node distributed" citation.
Both parameters are configurable; a small lognormal jitter produces the
tail the paper plots (p99).

The access-function walk itself is ``repro.engine``'s: the executor packs
the liveness-filtered mask, asks the engine for the per-position access
trace (visited server + locality under Eqn 1 with fail-over homes), and
merely decorates those outputs with the RPC latency model and per-server
load counters.

Failure semantics: an access whose object has *no alive copy* routes to
server -1.  The executor keeps serving the rest of the batch and surfaces
those queries in ``ExecutionReport.query_failed`` (their partial-walk
latency is still reported); it never crashes.  A ``Router`` with the
``hedged`` policy makes the executor race the primary and backup
coordinator picks per query and keep the min-latency completion.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.paths import PathSet
from repro.core.replication import ReplicationScheme
from repro.distsys.cluster import Cluster
from repro.distsys.router import Router
from repro.engine import pack_bool_mask, to_device
from repro.engine.backends import access_trace


@dataclasses.dataclass(frozen=True)
class LatencyModel:
    local_us: float = 2.0
    remote_us: float = 60.0
    jitter_sigma: float = 0.15  # lognormal sigma on each term
    coordinator_us: float = 4.0  # result gathering / aggregation
    # per-dispatch overhead (marshalling + engine/RPC launch): paid once
    # per access in per-query serving, once per *batch* under the batched
    # dispatch plane (repro.serve.batching) — the cost batching amortizes.
    # 0.0 keeps every pre-batching number bit-identical.
    dispatch_us: float = 0.0

    def sample(
        self, n_local: np.ndarray, n_remote: np.ndarray, rng: np.random.Generator
    ) -> np.ndarray:
        jit_l = rng.lognormal(0.0, self.jitter_sigma, size=n_local.shape)
        jit_r = rng.lognormal(0.0, self.jitter_sigma, size=n_remote.shape)
        return (
            self.local_us * n_local * jit_l
            + self.remote_us * n_remote * jit_r
            + self.coordinator_us
        )


@dataclasses.dataclass
class ExecutionReport:
    """Aggregate statistics of one workload execution."""

    query_latency_us: np.ndarray      # [n_queries]
    query_traversals: np.ndarray      # [n_queries] critical-path traversals
    per_server_local: np.ndarray      # [S]
    per_server_rpcs: np.ndarray       # [S]
    throughput_qps: float
    query_failed: np.ndarray | None = None  # [n_queries] no-alive-copy hit

    def percentile(self, q: float) -> float:
        return float(np.percentile(self.query_latency_us, q))

    @property
    def mean_us(self) -> float:
        return float(self.query_latency_us.mean())

    @property
    def p99_us(self) -> float:
        return self.percentile(99.0)

    @property
    def n_failed(self) -> int:
        return int(self.query_failed.sum()) if self.query_failed is not None else 0

    def summary(self) -> dict:
        return {
            "mean_us": self.mean_us,
            "p50_us": self.percentile(50),
            "p95_us": self.percentile(95),
            "p99_us": self.p99_us,
            "max_traversals": int(self.query_traversals.max(initial=0)),
            "mean_traversals": float(self.query_traversals.mean())
            if len(self.query_traversals)
            else 0.0,
            "throughput_qps": self.throughput_qps,
            "failed_queries": self.n_failed,
        }


def failover_home(scheme: ReplicationScheme, alive: np.ndarray) -> np.ndarray:
    """Per-object routing target under liveness (executor + simulator).

    Original if its server is alive, else the lowest-id alive copy holder,
    else -1 (object unavailable — the access fails).
    """
    mask = scheme.mask & alive[None, :]
    orig_alive = alive[scheme.shard]
    first_alive = np.where(mask.any(axis=1), mask.argmax(axis=1), -1).astype(
        np.int32
    )
    return np.where(orig_alive, scheme.shard, first_alive).astype(np.int32)


def trace_paths(
    pathset: PathSet,
    scheme: ReplicationScheme,
    alive: np.ndarray,
    start: np.ndarray | None = None,
    policy=None,
    load: np.ndarray | None = None,
) -> tuple[np.ndarray, np.ndarray]:
    """Engine-backed access walk (Eqn 1) under liveness.

    Returns (servers int32 [P, L], local bool [P, L]); ``start`` optionally
    sets the per-path start server (a router's coordinator picks).  Visited
    server -1 means the access had no alive copy to go to.

    ``policy`` (str | ``repro.engine.routing.RoutingPolicy``) selects the
    remote-hop target rule — the fail-over home under ``home_first``, a
    holder pick from the alive-masked replica words under
    ``nearest_copy``/``queue_aware`` (``load`` = live queue depths).  The
    holder words are liveness-filtered, so the policy walk subsumes both
    the fail-over map and the scalar ``Router.route_hop``.
    """
    mask = scheme.mask & alive[None, :]
    home = failover_home(scheme, alive)
    kw = {}
    if start is not None:
        kw["start"] = to_device(np.asarray(start, np.int32))
    servers, local = access_trace(
        to_device(np.asarray(pathset.objects, np.int32)),
        to_device(np.asarray(pathset.lengths, np.int32)),
        to_device(pack_bool_mask(mask)),
        to_device(home),
        policy=policy,
        load=load,
        **kw,
    )
    return np.asarray(servers), np.asarray(local)


def trace_paths_batched(
    pathset: PathSet,
    scheme: ReplicationScheme,
    alive: np.ndarray,
    batches: list[tuple[np.ndarray, np.ndarray | None]],
    policy=None,
    load: np.ndarray | None = None,
) -> list[tuple[np.ndarray, np.ndarray]]:
    """One engine dispatch for MANY batches of paths (amortized launch).

    ``batches`` is a list of ``(path_idx, start)`` pairs: the member path
    rows of each batch and their optional per-path start servers (a
    coordinator pick; ``None`` = home start).  The path subsets are
    concatenated into a single ``access_trace`` call — one mask pack, one
    device upload, one kernel launch — and the outputs are split back per
    batch.  Row-for-row identical to calling :func:`trace_paths` once per
    batch: the walk is per-path, so concatenation cannot change any row.

    This is the engine entry point of the batched dispatch plane: the
    serving layer coalesces same-window queries and pays the dispatch
    overhead once per batch instead of once per query.
    """
    if not batches:
        return []
    objects = np.asarray(pathset.objects, np.int32)
    lengths = np.asarray(pathset.lengths, np.int32)
    idx_all = []
    starts_all = []
    any_start = any(st is not None for _, st in batches)
    for idx, st in batches:
        idx = np.asarray(idx, np.int64)
        idx_all.append(idx)
        if any_start:
            starts_all.append(
                np.full(len(idx), -1, np.int32)
                if st is None
                else np.asarray(st, np.int32)
            )
    cat = np.concatenate(idx_all)
    sub = PathSet(
        objects[cat],
        lengths[cat],
        np.arange(len(cat), dtype=np.int32),
    )
    start = np.concatenate(starts_all) if any_start else None
    if start is not None and (start < 0).any():
        # mixed home/coordinator starts: access_trace's start is all-or-
        # nothing, so fill holes with the fail-over home of each root
        home = failover_home(scheme, alive)
        roots = np.maximum(objects[cat, 0], 0)
        start = np.where(start >= 0, start, home[roots]).astype(np.int32)
    servers, local = trace_paths(sub, scheme, alive, start, policy, load)
    out = []
    off = 0
    for idx in idx_all:
        out.append((servers[off: off + len(idx)], local[off: off + len(idx)]))
        off += len(idx)
    return out


def _path_costs(
    pathset: PathSet,
    scheme: ReplicationScheme,
    alive: np.ndarray,
    start: np.ndarray | None = None,
    policy=None,
    load: np.ndarray | None = None,
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Access walk + counters.

    Returns (n_local [P], n_remote [P], local_per_server [S],
    rpc_per_server [S], dead [P], servers [P, L], local [P, L]).  A dead
    server's copies are unavailable; originals of dead servers are served
    by the lowest-id alive replica holder (fail-over).  ``dead[p]`` marks
    paths that hit an object with no alive copy at all (visited server -1).
    """
    S = scheme.n_servers
    servers, local = trace_paths(pathset, scheme, alive, start, policy, load)

    valid = pathset.objects >= 0
    remote = valid & ~local  # only positions >= 1 can be remote
    dead = ((servers < 0) & valid).any(axis=1)
    n_local = local.sum(axis=1).astype(np.int64)
    n_remote = remote.sum(axis=1).astype(np.int64)

    srv_c = np.maximum(servers, 0)
    local_srv = np.bincount(srv_c[local], minlength=S).astype(np.int64)
    rpc_srv = np.bincount(srv_c[remote], minlength=S).astype(np.int64)
    return n_local, n_remote, local_srv, rpc_srv, dead, servers, local


def _query_roots(pathset: PathSet) -> np.ndarray:
    """Root object per query (the root is shared by all the query's paths)."""
    roots = np.zeros(pathset.n_queries, np.int64)
    np.maximum.at(
        roots, np.asarray(pathset.query_ids), np.maximum(pathset.objects[:, 0], 0)
    )
    return roots


def _emit_structural_spans(
    trace, pathset, servers, local, model, q_lat, q_dead
) -> None:
    """Record the closed-form walk into a ``repro.obs.Tracer``.

    Shared prefixes across a query's paths execute once (Def 4.1) and
    emit one span each, exactly like the simulator's trie-deduped trees;
    times are cumulative jitter-free model constants with zero queue wait.
    """
    qids = np.asarray(pathset.query_ids)
    lengths = np.asarray(pathset.lengths)
    objects = np.asarray(pathset.objects)
    seen: dict[int, set] = {}
    for p in range(pathset.n_paths):
        q = int(qids[p])
        prefixes = seen.setdefault(q, set())
        t = 0.0
        prefix: tuple = ()
        for x in range(int(lengths[p])):
            obj = int(objects[p, x])
            prefix = prefix + (obj,)
            lc = bool(local[p, x])
            cost = model.local_us if lc else model.remote_us
            if prefix not in prefixes:
                prefixes.add(prefix)
                trace.record(q, obj, int(servers[p, x]), lc, t, t, t + cost)
            t += cost
    for q in range(len(q_lat)):
        trace.finalize(q, 0.0, float(q_lat[q]), failed=bool(q_dead[q]))


def execute_workload(
    cluster: Cluster,
    pathset: PathSet,
    model: LatencyModel | None = None,
    seed: int = 0,
    hedge_replicas: bool = False,
    router: Router | None = None,
    policy=None,
    trace=None,
) -> ExecutionReport:
    """Execute a workload; per-query latency = slowest path + coordination.

    ``router``: replica-aware coordinator selection.  ``replica_lb`` starts
    each query at the least-loaded alive copy holder of its root (seeded
    with the cluster's live queue depths); ``hedged`` additionally races a
    backup coordinator and keeps the per-query min-latency completion
    (counters are charged to the primary — the backup's work is the price
    of hedging and is reflected in its latency draw, not double-counted
    into throughput).

    ``policy``: per-hop routing policy (``repro.engine.routing``) for the
    batched walk itself — ``home_first`` (default, Eqn 1 verbatim),
    ``nearest_copy``, or ``queue_aware`` (holders ranked by the cluster's
    live queue depths).  Orthogonal to ``router``, which only picks each
    query's *coordinator*.

    ``hedge_replicas``: per-hop straggler mitigation — when a remote hop
    has >1 alive copy, the executor issues hedged requests and takes the
    faster jitter draw (min of two lognormals), a direct secondary benefit
    of the replication scheme.

    ``trace``: a :class:`repro.obs.Tracer` collecting *structural* spans —
    one per unique access of each query's shared-prefix walk (hop order,
    object, server, local/remote), timed with the jitter-free model
    constants and no queueing (enqueue == start).  The executor prices
    queries in isolation, so span times decompose the modeled walk, not
    the sampled latency; the simulator's spans are the ones whose
    queue/service split sums to real latency.
    """
    model = model or LatencyModel()
    rng = np.random.default_rng(seed)
    alive = np.asarray([s.alive for s in cluster.servers], bool)
    load = cluster.queue_depths()
    nq = pathset.n_queries
    qids = np.asarray(pathset.query_ids)

    start = backup_start = None
    coord = None
    has_backup = None
    if router is not None and router.policy != "home":
        roots = _query_roots(pathset)
        if router.policy == "hedged":
            coord, backup = router.route_roots_hedged(
                roots, alive, seed=seed, load=cluster.queue_depths()
            )
            has_backup = backup >= 0
            if has_backup.any():
                backup_start = np.where(has_backup, backup, coord)[qids]
        else:
            coord = router.route_roots(
                roots, alive, seed=seed, load=cluster.queue_depths()
            )
        start = coord[qids]

    n_local, n_remote, local_srv, rpc_srv, dead, w_servers, w_local = (
        _path_costs(pathset, cluster.scheme, alive, start, policy, load)
    )

    lat = model.sample(n_local.astype(np.float64), n_remote.astype(np.float64), rng)
    if hedge_replicas:
        # hedging halves the effective tail of the remote term where copies
        # exist; approximate with a second draw on the remote component.
        alt = model.sample(
            n_local.astype(np.float64), n_remote.astype(np.float64), rng
        )
        n_copies = cluster.scheme.mask[np.maximum(pathset.objects, 0)].sum(-1)
        hedgeable = (n_copies.max(axis=1) > 1)
        lat = np.where(hedgeable, np.minimum(lat, alt), lat)

    q_lat = np.zeros(nq, np.float64)
    q_trav = np.zeros(nq, np.int64)
    q_dead = np.zeros(nq, bool)
    np.maximum.at(q_lat, qids, lat)
    np.maximum.at(q_trav, qids, n_remote)
    np.maximum.at(q_dead, qids, dead)

    if backup_start is not None:
        # race the backup coordinator pick: independent walk + jitter draw,
        # keep the faster completion per query (min of two path-maxima).
        b_local, b_remote, _, _, b_dead, _, _ = _path_costs(
            pathset, cluster.scheme, alive, backup_start, policy, load
        )
        b_lat = model.sample(
            b_local.astype(np.float64), b_remote.astype(np.float64), rng
        )
        bq_lat = np.zeros(nq, np.float64)
        bq_trav = np.zeros(nq, np.int64)
        bq_dead = np.zeros(nq, bool)
        np.maximum.at(bq_lat, qids, b_lat)
        np.maximum.at(bq_trav, qids, b_remote)
        np.maximum.at(bq_dead, qids, b_dead)
        # only queries with a real backup pick get the min-of-two; a lone
        # copy holder has nothing to hedge against (its second walk would
        # just be a free extra jitter draw)
        faster = (bq_lat < q_lat) & has_backup
        q_lat = np.where(faster, bq_lat, q_lat)
        q_trav = np.where(faster, bq_trav, q_trav)
        q_dead = q_dead & bq_dead  # failed only if both picks hit a dead end

    for s in cluster.servers:
        s.local_accesses += int(local_srv[s.server_id])
        s.remote_rpcs_in += int(rpc_srv[s.server_id])
    if coord is not None:
        counts = np.bincount(
            np.maximum(coord, 0)[coord >= 0], minlength=cluster.n_servers
        )
        for s in cluster.servers:
            s.queries_coordinated += int(counts[s.server_id])

    if trace is not None:
        if policy is not None:
            trace.policy = getattr(policy, "name", str(policy))
        _emit_structural_spans(
            trace, pathset, w_servers, w_local, model, q_lat, q_dead
        )

    # throughput model: per-server service capacity is shared; the
    # bottleneck server's work bounds qps (open-loop approximation).
    work_us = local_srv * model.local_us + rpc_srv * model.remote_us
    busiest = work_us.max() if work_us.size else 1.0
    qps = nq / (busiest / 1e6) if busiest > 0 else float("inf")
    return ExecutionReport(
        query_latency_us=q_lat,
        query_traversals=q_trav,
        per_server_local=local_srv,
        per_server_rpcs=rpc_srv,
        throughput_qps=qps,
        query_failed=q_dead,
    )
