"""Simulated distributed query-execution system + fault tolerance substrate."""
from repro.distsys.cluster import Cluster, ServerState
from repro.distsys.executor import (
    ExecutionReport,
    LatencyModel,
    execute_workload,
    failover_home,
    trace_paths,
)
from repro.distsys.router import Router
from repro.distsys.checkpoint import CheckpointManager
from repro.distsys.faults import Event, apply_event, event_schedule, run_schedule

__all__ = [
    "Cluster",
    "ServerState",
    "ExecutionReport",
    "LatencyModel",
    "execute_workload",
    "failover_home",
    "trace_paths",
    "Router",
    "CheckpointManager",
    "Event",
    "apply_event",
    "event_schedule",
    "run_schedule",
]
