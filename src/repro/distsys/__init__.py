"""Simulated distributed query-execution system + fault tolerance substrate."""
from repro.distsys.cluster import Cluster, ServerState
from repro.distsys.executor import (
    ExecutionReport,
    LatencyModel,
    execute_workload,
    failover_home,
    trace_paths,
)
from repro.distsys.router import Router
from repro.distsys.routing_table import RoutingTable
from repro.distsys.checkpoint import CheckpointManager
from repro.distsys.faults import (
    ChaosEvent,
    Event,
    apply_event,
    chaos_schedule,
    event_schedule,
    run_schedule,
    time_to_repair,
    violation_windows,
)

__all__ = [
    "Cluster",
    "ServerState",
    "ExecutionReport",
    "LatencyModel",
    "execute_workload",
    "failover_home",
    "trace_paths",
    "Router",
    "RoutingTable",
    "CheckpointManager",
    "ChaosEvent",
    "Event",
    "apply_event",
    "chaos_schedule",
    "event_schedule",
    "run_schedule",
    "time_to_repair",
    "violation_windows",
]
