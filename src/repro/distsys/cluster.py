"""Simulated distributed query-execution cluster (paper §3.1 system model).

Each server has a data store (which objects it holds: originals per the
sharding function + replicas per the replication scheme) and a query
executor.  The simulation tracks storage consumption against capacities
M_s and exposes the state the router/executor need.  It is the stand-in
for the paper's six r5d.4xlarge servers; all quantities the paper measures
(traversal counts, storage overheads, load imbalance) are exact, and
wall-clock latency comes from the calibrated RPC model in ``executor``.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.replication import ReplicationScheme


@dataclasses.dataclass
class ServerState:
    server_id: int
    alive: bool = True
    # counters maintained by the executor
    local_accesses: int = 0
    remote_rpcs_in: int = 0
    queries_coordinated: int = 0
    # live queueing state maintained by the serving simulator
    # (repro.serve.simulator): outstanding requests + in-service count.
    queue_depth: int = 0
    busy: int = 0


@dataclasses.dataclass
class Cluster:
    """A set of servers + the current replication scheme."""

    scheme: ReplicationScheme
    f: np.ndarray | None = None
    capacity: np.ndarray | None = None
    servers: list[ServerState] = dataclasses.field(default_factory=list)

    def __post_init__(self):
        if not self.servers:
            self.servers = [
                ServerState(s) for s in range(self.scheme.n_servers)
            ]

    @property
    def n_servers(self) -> int:
        return self.scheme.n_servers

    def alive_servers(self) -> np.ndarray:
        return np.asarray([s.server_id for s in self.servers if s.alive])

    def holds(self, obj: int, server: int) -> bool:
        return bool(self.scheme.mask[obj, server]) and self.servers[server].alive

    def storage_report(self) -> dict:
        load = self.scheme.storage_per_server(self.f)
        mean = load.mean() if load.size else 0.0
        return {
            "per_server": load.tolist(),
            "total": float(load.sum()),
            "imbalance": float(load.max() / mean - 1.0) if mean > 0 else 0.0,
            "overhead": self.scheme.replication_overhead(self.f),
            "capacity_ok": (
                bool(np.all(load <= self.capacity + 1e-9))
                if self.capacity is not None
                else True
            ),
        }

    def queue_depths(self) -> np.ndarray:
        """Live outstanding work per server (queue-aware routing input)."""
        return np.asarray(
            [s.queue_depth + s.busy for s in self.servers], np.int64
        )

    def apply_scheme_delta(self, objects, servers) -> None:
        """Apply a monotone replica-addition delta to the live scheme.

        This is the controller's hot path: the delta produced by
        ``repro.core.greedy.replicate_delta`` lands on the serving cluster
        as plain 0->1 mask flips — no scheme rebuild, no re-routing pause.
        Negative pairs (failed routing sentinels) are ignored.
        """
        obj = np.asarray(objects)
        srv = np.asarray(servers)
        ok = (obj >= 0) & (srv >= 0)
        if ok.any():
            self.scheme.add(obj[ok], srv[ok])

    def fail_server(self, server: int) -> None:
        self.servers[server].alive = False

    def recover_server(self, server: int) -> None:
        self.servers[server].alive = True

    def reset_counters(self) -> None:
        for s in self.servers:
            s.local_accesses = 0
            s.remote_rpcs_in = 0
            s.queries_coordinated = 0
            s.queue_depth = 0
            s.busy = 0
