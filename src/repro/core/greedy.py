"""Vectorized greedy latency-bound replication (paper Alg 1 + Alg 2).

TPU/JAX adaptation of the paper's lock-free 64-thread implementation
(§6.1): paths are processed in *batches*; every path in a batch evaluates
its candidate subsets against the same snapshot of the replication scheme,
and all chosen additions are applied with one scatter-OR.  Replica additions
are monotone 0->1 flips, and Thm 5.3 (latency-robustness) guarantees that
additions made concurrently for other paths can never break a bound that an
earlier UPDATE established — the exact argument the paper uses to justify
its lock-free races.  The only effect is a mild over-estimate of candidate
costs inside a batch (same approximation class as the paper's threads),
which can make the result slightly more expensive, never infeasible.

Per batch, for each path we compute
  * the server-local subpath structure under d (Def 5.1),
  * for every candidate retained-set (precomputed C(h, t) tables), the
    upward-replication + latency-robustness additions (Alg 2 lines 11-19)
    as a [positions x subpaths] interval mask,
  * the marginal cost of each candidate against the snapshot,
  * optionally the per-candidate marginal server loads for the capacity /
    balance constraints (Alg 2 line 20),
and apply the argmin candidate's additions.

Paths whose subpath count exceeds the enumeration budget fall back to the
exact sequential implementation (``repro.core.reference``).

Latency constraints are **vector-valued** (paper Def 4.4 is per query):
``t`` may be an int, a per-query vector, or an
:class:`~repro.core.slo.SLOSpec`.  Paths are bucketed by distinct budget
(tightest first) — each budget class gets its own C(h, t) candidate
tables and vectorized/sequential split, and the batch kernel gates
additions on each path's own ``t_q`` — with the scalar case degenerating
to one class, bit-identical to the historical scalar driver.
"""
from __future__ import annotations

import dataclasses
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import combi
from repro.core.paths import PathSet
from repro.core.replication import ReplicationScheme, subpath_structure
from repro.core.reference import update_exact
from repro.engine import LatencyEngine, PackedScheme, to_device
from repro.engine.packed import scatter_or_pairs, test_bits

_INF = jnp.float32(1e30)


def _update_batch_core(
    words: jnp.ndarray,      # uint32 [(n+1), W] — packed scheme, sacrificial row
    objects: jnp.ndarray,    # int32 [B, L]
    lengths: jnp.ndarray,    # int32 [B]
    shard: jnp.ndarray,      # int32 [n]
    f: jnp.ndarray,          # float32 [n]
    tables: jnp.ndarray,     # bool [H+1, C, H+1]
    counts: jnp.ndarray,     # int32 [H+1]
    t: jnp.ndarray,          # int32 [B] per-path latency budgets t_q
    h_routed: jnp.ndarray,   # int32 [B] routed path latency vs the snapshot
    load: jnp.ndarray,       # float32 [S] current storage per server
    capacity: jnp.ndarray,   # float32 [S] (ignored unless check_capacity)
    epsilon: jnp.ndarray,    # float32 scalar
    check_capacity: bool,
    routed_gate: bool,
):
    B, L = objects.shape
    Hp1 = tables.shape[2]
    C = tables.shape[1]
    S = load.shape[0]

    home, seg, h = subpath_structure(objects, lengths, shard)
    valid = seg >= 0
    h_cl = jnp.clip(h, 0, Hp1 - 1)

    # server of each subpath: all positions of a subpath share one home.
    seg_cl = jnp.clip(seg, 0, Hp1 - 1)
    b_idx = jnp.arange(B)[:, None].repeat(L, 1)
    srv = (
        jnp.zeros((B, Hp1), jnp.int32)
        .at[b_idx, seg_cl]
        .max(jnp.where(valid, home + 1, 0))
        - 1
    )  # [B, Hp1]; -1 for absent subpaths

    # first object of each subpath (representative u for the resharding map)
    big = jnp.int32(2**30)
    first_pos = (
        jnp.full((B, Hp1), big, jnp.int32)
        .at[b_idx, seg_cl]
        .min(jnp.where(valid, jnp.arange(L)[None, :], big))
    )
    first_obj = jnp.take_along_axis(
        objects, jnp.clip(first_pos, 0, L - 1), axis=1
    )  # [B, Hp1] (garbage where absent; masked later)

    # candidate tables for each path's h: sel [B, C, Hp1]
    sel = tables[h_cl]
    n_cand = counts[h_cl]  # [B]

    # prev_sel[b, c, k] = largest selected subpath index <= k
    idx = jnp.where(sel, jnp.arange(Hp1)[None, None, :], -1)
    prev_sel = jax.lax.cummax(idx, axis=2)  # [B, C, Hp1]

    # per-position selected-predecessor j(seg_x): gather over k = seg_x
    seg_e = jnp.clip(seg, 0, Hp1 - 1)[:, None, :].repeat(C, 1)  # [B, C, L]
    j_of_x = jnp.take_along_axis(prev_sel, seg_e, axis=2)  # [B, C, L]

    # interval mask: additions (x -> subpath k) iff j(seg_x) <= k < seg_x
    k_r = jnp.arange(Hp1)[None, None, None, :]
    window = (k_r >= j_of_x[..., None]) & (k_r < seg_e[..., None])  # [B,C,L,Hp1]
    window = (
        window
        & valid[:, None, :, None]
        & (h > t)[:, None, None, None]  # each path vs its OWN budget t_q
    )
    if routed_gate:
        # policy-aware pricing: a path the *routed* walk already serves
        # within its budget (h(p, r, rho; policy) <= t_q against the same
        # snapshot the candidates are costed on) buys no replicas at all
        window = window & (h_routed > t)[:, None, None, None]
        skipped = (h > t) & (h_routed <= t)
    else:
        skipped = jnp.zeros_like(t, dtype=jnp.bool_)

    # needed(x, k): no copy of objects[x] at srv[k] yet — a bit-test against
    # the engine's device-resident packed snapshot (snapshot semantics)
    safe_obj = jnp.maximum(objects, 0)
    safe_srv = jnp.maximum(srv, 0)
    present = test_bits(
        words, safe_obj[:, :, None], safe_srv[:, None, :]
    )  # [B, L, Hp1]
    needed = (~present) & (srv[:, None, :] >= 0) & valid[:, :, None]

    fx = f[safe_obj] * valid.astype(jnp.float32)  # [B, L]
    add = window & needed[:, None, :, :]  # [B, C, L, Hp1]
    cost = jnp.einsum("bclk,bl->bc", add.astype(jnp.float32), fx)

    cand_valid = jnp.arange(C)[None, :] < n_cand[:, None]
    cost_m = jnp.where(cand_valid, cost, _INF)

    if check_capacity:
        # marginal load per candidate per server: scatter f over srv[k]
        contrib = jnp.einsum("bclk,bl->bck", add.astype(jnp.float32), fx)
        marg = (
            jnp.zeros((B, C, S + 1), jnp.float32)
            .at[
                jnp.arange(B)[:, None, None],
                jnp.arange(C)[None, :, None],
                jnp.clip(safe_srv, 0, S)[:, None, :],
            ]
            .add(contrib)
        )[..., :S]
        # NOTE: snapshot load; within-batch interactions ignored (lock-free
        # semantics).  Feasibility is re-validated exactly by the driver.
        new_load = load[None, None, :] + marg
        ok_cap = jnp.all(new_load <= capacity[None, None, :] + 1e-6, axis=-1)
        mean = jnp.mean(new_load, axis=-1)
        ok_bal = jnp.max(new_load, axis=-1) <= (1.0 + epsilon) * mean + 1e-6
        cost_m = jnp.where(ok_cap & ok_bal, cost_m, _INF)

    best = jnp.argmin(cost_m, axis=1)  # [B] ties -> lowest index (determinism)
    best_cost = jnp.take_along_axis(cost_m, best[:, None], axis=1)[:, 0]
    no_solution = best_cost >= _INF

    chosen = jnp.take_along_axis(add, best[:, None, None, None], axis=1)[:, 0]
    chosen = chosen & ~no_solution[:, None, None]  # [B, L, Hp1]

    # on-device scatter-OR into the packed words; masked-out writes are
    # routed to the sacrificial row by scatter_or_pairs.
    obj_w = jnp.where(chosen, safe_obj[:, :, None], -1)
    srv_w = jnp.broadcast_to(safe_srv[:, None, :], chosen.shape)
    words = scatter_or_pairs(words, obj_w, srv_w)

    applied_cost = jnp.where(no_solution, 0.0, best_cost)
    # Maintain the per-server load incrementally: every applied (x, k)
    # addition contributes f(v_x) to server srv[k].  NOTE this ignores
    # within-batch duplicate (v, s) pairs across different paths (lock-free
    # snapshot semantics) — the driver recomputes the exact load from the
    # mask whenever capacity checking is enabled.
    new_load = load + jnp.einsum(
        "blk,bl,bks->s",
        chosen.astype(jnp.float32),
        fx,
        jax.nn.one_hot(jnp.clip(safe_srv, 0, S - 1), S, dtype=jnp.float32)
        * (srv >= 0).astype(jnp.float32)[..., None],
    )
    return words, applied_cost, no_solution, chosen, first_obj, srv, new_load, skipped


# Back-compat separate-dispatch entry point: the PR-5 pipeline (gate as its
# own host-driven dispatch per batch, stats read back per batch).  The fused
# driver path below replaces it; kept as the benchmark baseline + parity
# anchor.
_update_batch = functools.partial(
    jax.jit,
    static_argnames=("check_capacity", "routed_gate"),
    donate_argnums=(0,),
)(_update_batch_core)


def _first_obj_of_subpaths(objects, lengths, shard, Hp1):
    """[B, Hp1] first object of each subpath (resharding-map representative);
    same ops as the core (garbage where the subpath is absent)."""
    B, L = objects.shape
    _, seg, _ = subpath_structure(objects, lengths, shard)
    valid = seg >= 0
    seg_cl = jnp.clip(seg, 0, Hp1 - 1)
    b_idx = jnp.arange(B)[:, None].repeat(L, 1)
    big = jnp.int32(2**30)
    first_pos = (
        jnp.full((B, Hp1), big, jnp.int32)
        .at[b_idx, seg_cl]
        .min(jnp.where(valid, jnp.arange(L)[None, :], big))
    )
    return jnp.take_along_axis(objects, jnp.clip(first_pos, 0, L - 1), axis=1)


@functools.partial(
    jax.jit,
    static_argnames=("check_capacity", "pol", "use_pallas"),
    donate_argnums=(0, 1),
)
def _fused_update_batch(
    words: jnp.ndarray,      # uint32 [(n+1), W] — donated packed snapshot
    acc: jnp.ndarray,        # float32 [3] — donated [cost, failed, skipped] sums
    objects: jnp.ndarray,    # int32 [B, L]
    lengths: jnp.ndarray,    # int32 [B]
    shard: jnp.ndarray,      # int32 [n]
    f: jnp.ndarray,          # float32 [n]
    tables: jnp.ndarray,     # bool [H+1, C, H+1]
    counts: jnp.ndarray,     # int32 [H+1]
    t: jnp.ndarray,          # int32 [B]
    rank: jnp.ndarray,       # float32 [W*32] gate holder-rank (queue load)
    load: jnp.ndarray,       # float32 [S]
    capacity: jnp.ndarray,   # float32 [S]
    epsilon: jnp.ndarray,    # float32 scalar
    check_capacity: bool,
    pol,                     # resolved non-home-first policy or None (static)
    use_pallas: bool,
):
    """One *fused* UPDATE round: gate + candidate scoring + bit-test +
    scatter-OR in a single dispatch, batch statistics reduced on device
    into ``acc`` (read back once per budget class, not once per batch).

    The routed gate h(p, r, rho; policy) is computed *inside* this jit via
    ``backends.gate_counts`` against the very words snapshot the
    candidates are priced on — no host round trip between gate and UPDATE.
    With ``use_pallas`` the whole round runs as the
    ``kernels.provision_update`` megakernel (capacity checking falls back
    to the jnp core: the marginal-load einsum needs the full [B, C, S]
    plane the lane kernel deliberately never materializes).

    Pad rows (length 0, t 0) are inert in every statistic — h = 0 means
    the empty window costs 0 and C(0, t)'s first candidate accepts — so
    ``acc`` sums the whole padded batch without slicing.
    """
    from repro.engine.backends import gate_counts  # lazy: no cycle at import

    if use_pallas and not check_capacity:
        from repro.kernels.provision_update import fused_update_pallas

        words, costs, failed, chosen, srv, skipped = fused_update_pallas(
            words, objects, lengths, shard, f, tables, counts, t, rank,
            pol=pol,
        )
        first_obj = _first_obj_of_subpaths(
            objects, lengths, shard, tables.shape[2]
        )
        new_load = load
    else:
        if pol is None:
            h_routed = jnp.zeros_like(t)
        else:
            h_routed = gate_counts(
                objects, lengths, words, shard, pol, rank,
                backend="pallas" if use_pallas else "jnp",
            )
        (
            words, costs, failed, chosen, first_obj, srv, new_load, skipped
        ) = _update_batch_core(
            words, objects, lengths, shard, f, tables, counts, t, h_routed,
            load, capacity, epsilon, check_capacity, pol is not None,
        )
    acc = acc + jnp.stack(
        [
            jnp.sum(costs),
            jnp.sum(failed.astype(jnp.float32)),
            jnp.sum(skipped.astype(jnp.float32)),
        ]
    )
    return words, acc, new_load, chosen, first_obj, srv


@dataclasses.dataclass
class GreedyStats:
    total_cost: float = 0.0
    failed_paths: int = 0
    paths_processed: int = 0
    fallback_paths: int = 0
    replicas: int = 0
    runtime_s: float = 0.0
    rm: list | None = None
    # paths the routed walk already served within budget (policy-aware
    # greedy only): structurally infeasible under d, zero replicas bought
    routed_skips: int = 0
    # replicas dropped by the driver's final same-policy prune sweep
    # (policy-aware from-scratch runs with policy_prune=True)
    pruned_replicas: int = 0
    # paths still over budget under the routed policy after the bounded
    # revalidation rounds (receding-horizon pathology the rounds could
    # not repair) — 0 means the returned scheme is routed-feasible for
    # every path the driver processed
    routed_violations: int = 0
    # streamed ingestion (replicate_stream): largest number of paths ever
    # host-resident at once — the residency contract the provisioning-scale
    # benchmark asserts stays below the total path count
    peak_resident_paths: int = 0
    # streamed ingestion: host seconds of chunk materialization hidden
    # behind in-flight device compute (the double-buffer pipeline's win)
    ingest_overlap_s: float = 0.0
    # candidate-table residency: the largest host-resident block of
    # C(h, t) selection rows ever built at once, and the total candidate
    # rows shipped to device.  When a budget class's table would exceed
    # ``_TABLE_STREAM_ROWS`` rows the construction streams through
    # bounded chunks, so peak stays at the chunk size while total grows
    # with C(H, t) — the residency contract replicate_stream surfaces in
    # its StreamStats
    table_peak_rows: int = 0
    table_total_rows: int = 0
    # per-budget-class provisioning telemetry (obs-gated; None when the
    # telemetry plane is disabled): dicts of {budget, n_vec, n_seq,
    # n_candidates, routed_skips} in processing order
    timeline: list | None = None
    # dirty-scoped revalidation: path rows the bounded routed-revalidation
    # rounds did NOT have to re-walk (sum over rounds of
    # n_paths - |dirty set|); 0 when revalidation never ran or fell back
    # to full re-evaluation
    revalidate_rows_saved: int = 0
    # k-resilience enforcement (replicate_workload(resilience=...)):
    # (loss case, path) pairs still over budget after the bounded repair
    # rounds — 0 means the returned scheme survives every loss case —
    # and the number of masked repair rounds that actually ran
    resilient_violations: int = 0
    resilience_rounds: int = 0


class DeviceStatsAcc:
    """Deferred device-side stat accumulation across UPDATE passes.

    The fused UPDATE accumulates (cost, failed, skipped) in a device
    f32[3]; reading it back per class blocks dispatch and breaks the
    streamed-ingestion pipeline.  Holding the accumulator here instead
    carries it across :func:`replicate_delta` calls — chunk ``i + 1``'s
    host work proceeds while chunk ``i`` still computes — and
    :meth:`drain` performs the one blocking readback at stream end.
    While deferred, per-chunk stats report these components as 0; the
    caller adds the drained totals once.
    """

    def __init__(self):
        self.acc = None

    def drain(self, stats: "GreedyStats") -> None:
        """One blocking readback; folds the totals into ``stats``."""
        if self.acc is None:
            return
        a = np.asarray(self.acc)
        stats.total_cost += float(a[0])
        stats.failed_paths += int(a[1])
        stats.routed_skips += int(a[2])
        self.acc = None
        if obs.enabled():
            obs.REGISTRY.counter("repro.greedy.stat_readbacks").inc()


def _obs_record_class(stats, b, n_vec, n_seq, counts, n_skip) -> None:
    """Per-budget-class provisioning telemetry (no-op when obs is off)."""
    if not obs.enabled():
        return
    n_cand = int(np.asarray(counts).sum()) if counts is not None else 0
    if stats.timeline is None:
        stats.timeline = []
    stats.timeline.append({
        "budget": int(b),
        "n_vec": int(n_vec),
        "n_seq": int(n_seq),
        "n_candidates": n_cand,
        "routed_skips": int(n_skip),
    })
    reg = obs.REGISTRY
    reg.counter("repro.greedy.classes").inc()
    reg.counter("repro.greedy.vec_paths").inc(n_vec)
    reg.counter("repro.greedy.seq_paths").inc(n_seq)
    reg.counter("repro.greedy.candidates").inc(n_cand)
    reg.counter("repro.greedy.routed_skips").inc(n_skip)


def _run_update_batches(
    packed: PackedScheme,
    vec_objects: np.ndarray,
    vec_lengths: np.ndarray,
    shard_j,
    f_arr: np.ndarray,
    f_j,
    tables,
    counts,
    t_vec: np.ndarray,
    load,
    cap_j,
    eps_j,
    check_capacity: bool,
    batch_size: int,
    stats: GreedyStats,
    track_rm: bool,
    collect_additions: bool = False,
    routed_fn=None,
    fused: bool = False,
    pol=None,
    rank=None,
    use_pallas: bool = False,
    put=None,
    acc_holder: DeviceStatsAcc | None = None,
):
    """The batched UPDATE loop over vectorizable paths (shared by the
    from-scratch driver and the incremental delta driver).

    ``t_vec`` is the int32 per-path budget vector (one entry per row of
    ``vec_objects``); the candidate ``tables`` must have been enumerated
    for these budgets (one budget class per call — see the drivers).

    ``routed_fn`` (policy-aware greedy, separate-dispatch path) maps a
    host (objects, lengths) batch to its routed path latencies against
    the *current* packed snapshot; paths within budget under the routed
    walk are gated out of the UPDATE (they buy nothing), re-checked per
    batch so mid-class additions keep shrinking the bill.

    ``fused`` replaces the per-batch (host gate dispatch -> UPDATE
    dispatch -> three blocking stat readbacks) round trip with one
    ``_fused_update_batch`` step per batch: the gate runs inside the same
    jit (``pol`` + ``rank``), stats accumulate in a device vector read
    once at the end, and ``use_pallas`` lowers the round to the
    ``kernels.provision_update`` megakernel.  ``put`` overrides the
    host->device upload (the sharded driver installs a mesh-aware put so
    batches land path-sharded across devices).  ``acc_holder`` defers
    even that one end-of-call readback: the device stat vector is carried
    in the holder across calls (streamed ingestion) and drained once by
    the caller — the stat components stay 0 in ``stats`` until then.

    Mutates ``packed`` (donated words) and ``stats``; returns the final
    device load and, when ``collect_additions``, the applied (object,
    server) pairs as two int64 arrays.
    """
    add_obj: list[np.ndarray] = []
    add_srv: list[np.ndarray] = []
    nb = len(vec_objects)
    put = to_device if put is None else put
    if fused:
        if acc_holder is not None and acc_holder.acc is not None:
            acc = acc_holder.acc
        else:
            acc = jnp.zeros((3,), jnp.float32)
        if rank is None:
            rank = jnp.zeros((packed.words.shape[1] * 32,), jnp.float32)
    for i in range(0, nb, batch_size):
        o = vec_objects[i : i + batch_size]
        l = vec_lengths[i : i + batch_size]
        tq = t_vec[i : i + batch_size]
        # payload = the real rows; pad rows added below cross the bus too
        # but are booked as TRANSFER.padded_bytes, not workload data
        pb_o, pb_l, pb_t = o.nbytes, l.nbytes, tq.nbytes
        if o.shape[0] < batch_size:  # pad batch to a fixed shape
            padn = batch_size - o.shape[0]
            o = np.concatenate([o, np.full((padn, o.shape[1]), -1, np.int32)])
            l = np.concatenate([l, np.zeros((padn,), np.int32)])
            tq = np.concatenate([tq, np.zeros((padn,), np.int32)])
        k = min(batch_size, nb - i)
        if fused:
            packed.words, acc, load, chosen, first_obj, srv = _fused_update_batch(
                packed.words,
                acc,
                put(o, payload_bytes=pb_o),
                put(l, payload_bytes=pb_l),
                shard_j,
                f_j,
                tables,
                counts,
                put(tq, payload_bytes=pb_t),
                rank,
                load,
                cap_j,
                eps_j,
                check_capacity,
                pol,
                use_pallas,
            )
        else:
            if routed_fn is not None:
                # routed latency against the snapshot the batch prices on
                h_rt = np.asarray(routed_fn(o, l), np.int32)
            else:
                h_rt = np.zeros_like(tq)
            packed.words, costs, failed, chosen, first_obj, srv, load, skipped = _update_batch(
                packed.words,
                to_device(o, payload_bytes=pb_o),
                to_device(l, payload_bytes=pb_l),
                shard_j,
                f_j,
                tables,
                counts,
                to_device(tq, payload_bytes=pb_t),
                to_device(h_rt, payload_bytes=h_rt[:k].nbytes),
                load,
                cap_j,
                eps_j,
                check_capacity,
                routed_fn is not None,
            )
            stats.total_cost += float(np.asarray(costs)[:k].sum())
            stats.failed_paths += int(np.asarray(failed)[:k].sum())
            stats.routed_skips += int(np.asarray(skipped)[:k].sum())
        if check_capacity:
            # exact load from the packed words, computed on device (the
            # incremental estimate can over-count duplicate additions
            # within a batch) — no host round trip of the mask.
            load = jnp.asarray(
                packed.storage_per_server(f_arr).astype(np.float32)
            )
        if track_rm or collect_additions:
            ch = np.asarray(chosen)[:k]
            sv = np.asarray(srv)[:k]
            bb, xx, kk = np.nonzero(ch)
            if collect_additions:
                add_obj.append(o[bb, xx].astype(np.int64))
                add_srv.append(sv[bb, kk].astype(np.int64))
            if track_rm:
                fo = np.asarray(first_obj)[:k]
                for b, x, kk_ in zip(bb, xx, kk):
                    stats.rm.append(
                        (int(fo[b, kk_]), int(o[b, x]), int(sv[b, kk_]))
                    )
    if fused:
        if acc_holder is not None:
            # deferred: keep the stats on device, drained at stream end
            acc_holder.acc = acc
        else:
            # one device->host readback for the whole class (pad rows are
            # inert in every component, see _fused_update_batch)
            a = np.asarray(acc)
            stats.total_cost += float(a[0])
            stats.failed_paths += int(a[1])
            stats.routed_skips += int(a[2])
            if obs.enabled():
                obs.REGISTRY.counter("repro.greedy.stat_readbacks").inc()
    additions = (
        (
            np.concatenate(add_obj) if add_obj else np.zeros(0, np.int64),
            np.concatenate(add_srv) if add_srv else np.zeros(0, np.int64),
        )
        if collect_additions
        else None
    )
    return load, additions


# host-residency bound on candidate-table construction: a budget class
# whose padded C(h, t) table holds more rows than this is assembled on
# device from streamed chunks instead of one host materialization
_TABLE_STREAM_ROWS = 2048


def _tables_to_device(H: int, b: int, stats: "GreedyStats | None" = None):
    """Device candidate tables for budget b, streaming when they are big.

    Small tables (padded row count <= ``_TABLE_STREAM_ROWS``) take the
    cached :func:`combi.stacked_tables` host build — bit-identical to the
    historical path.  Bigger tables are assembled *on device*: start from
    ``jnp.ones`` (the same inert all-True padding the host build uses) and
    scatter bounded row chunks from :func:`combi.iter_comb_rows` into
    place, so host residency peaks at one chunk regardless of C(H, t).
    The two constructions produce identical device arrays by design.
    """
    counts_np = np.array(
        [combi.n_candidates(h, b) for h in range(H + 1)], np.int32
    )
    c_max = int(counts_np.max())
    if c_max <= _TABLE_STREAM_ROWS:
        tables_np, counts_full = combi.stacked_tables(H, b)
        if stats is not None:
            rows = (H + 1) * c_max  # the whole padded table is host-built
            stats.table_peak_rows = max(stats.table_peak_rows, rows)
            stats.table_total_rows += int(counts_np.sum())
        return to_device(tables_np), to_device(counts_full)
    tables = jnp.ones((H + 1, c_max, H + 1), dtype=bool)
    peak = 0
    total = 0
    for h in range(H + 1):
        r0 = 0
        for chunk in combi.iter_comb_rows(h, b, _TABLE_STREAM_ROWS):
            rows = chunk.shape[0]
            tables = tables.at[h, r0 : r0 + rows, : h + 1].set(
                to_device(chunk)
            )
            r0 += rows
            peak = max(peak, rows)
            total += rows
    if stats is not None:
        stats.table_peak_rows = max(stats.table_peak_rows, peak)
        stats.table_total_rows += total
    return tables, to_device(counts_np)


def _budget_class_plan(
    ps: PathSet,
    t_path: np.ndarray,
    shard_j,
    max_candidates: int,
    skip_tables: bool = False,
    stats: "GreedyStats | None" = None,
):
    """Bucket paths by distinct latency budget (ascending, tightest first).

    The candidate enumeration tables C(h, t) and the vectorizable/sequential
    split both depend on t, so each distinct budget gets its own tables and
    its own H_vec.  Yields ``(budget, class_pathset, vec_idx, seq_idx,
    h_all, tables, counts)`` per class; with a uniform budget vector this
    is one class covering every path in workload order — bit-identical to
    the old scalar driver.  Processing tightest budgets first lets looser
    paths reuse the replicas the tight ones forced (sound by Thm 5.3:
    existing replicas only lower candidate costs).

    ``skip_tables`` (policy-aware drivers) yields None tables/counts: the
    routed class filter rebuilds them on the surviving paths anyway, so
    building+uploading them here would be dead work.
    """
    plan = []
    for b in np.unique(t_path):
        b = int(b)
        idx = np.nonzero(t_path == b)[0]
        cls = ps.select(idx)
        _, _, h_all = subpath_structure(
            jnp.asarray(cls.objects), jnp.asarray(cls.lengths), shard_j
        )
        h_all = np.asarray(h_all)
        H_needed = int(h_all.max()) if cls.n_paths else 0
        H_vec = combi.max_h_within_budget(b, max_candidates, H_needed)
        vec_idx = np.nonzero(h_all <= H_vec)[0]
        seq_idx = np.nonzero(h_all > H_vec)[0]
        if skip_tables:
            tables = counts = None
        else:
            tables, counts = _tables_to_device(max(H_vec, b, 1), b, stats)
        plan.append((b, cls, vec_idx, seq_idx, h_all, tables, counts))
    return plan


def _routed_violation_idx(routed_fn, ps: PathSet, t_path: np.ndarray):
    """Indices of paths over budget under the routed policy (one eval)."""
    h_rt = np.asarray(
        routed_fn(
            np.asarray(ps.objects, np.int32), np.asarray(ps.lengths, np.int32)
        ),
        np.int64,
    )
    return np.nonzero(h_rt > t_path)[0]


def _routed_eval_rows(routed_fn, ps, rows: np.ndarray) -> np.ndarray:
    """Routed h for a compacted subset of ``ps``'s rows (128-row buckets).

    Pads the gathered block up to a 128-row quantum (-1 objects / 0
    lengths — empty paths, h = 0) so varying dirty-set sizes hit a
    bounded set of jit traces, exactly the incremental evaluator's
    padding discipline.
    """
    D = len(rows)
    Db = -(-max(D, 1) // 128) * 128
    o = np.full((Db, ps.objects.shape[1]), -1, np.int32)
    ln = np.zeros(Db, np.int32)
    o[:D] = np.asarray(ps.objects, np.int32)[rows]
    ln[:D] = np.asarray(ps.lengths, np.int32)[rows]
    return np.asarray(routed_fn(o, ln), np.int64)[:D]


def _revalidate_routed(routed_fn, ps, t_path, run_classes, stats,
                       index=None) -> None:
    """Bounded re-validation after a policy-aware pass.

    Receding-horizon walks are not monotone under foreign replica
    additions, so a path gated out early can regress by the end of the
    pass: re-run UPDATE over the violating paths for up to
    ``_POLICY_REVALIDATE`` rounds and record whatever residue survives in
    ``stats.routed_violations`` (0 = the scheme is routed-feasible for
    every processed path; callers must not assume feasibility otherwise).

    With ``index`` (a :class:`~repro.engine.incremental.PathIndex` over
    ``ps``) each round after an UPDATE re-walks only the *dirty* rows:
    the UPDATE adds copies solely of objects on the paths it processed,
    and a routed walk reads only its own objects' replica rows, so paths
    outside ``index.dirty_paths(ps.objects[viol])`` provably kept their
    latency — the per-round saving lands in
    ``stats.revalidate_rows_saved``.
    """
    viol = _routed_violation_idx(routed_fn, ps, t_path)
    for _ in range(_POLICY_REVALIDATE):
        if not len(viol):
            break
        run_classes(ps.select(viol), t_path[viol])
        if index is not None:
            cand = index.dirty_paths(np.asarray(ps.objects)[viol])
            stats.revalidate_rows_saved += int(ps.n_paths - len(cand))
            h = _routed_eval_rows(routed_fn, ps, cand)
            viol = cand[h > t_path[cand]]
        else:
            viol = _routed_violation_idx(routed_fn, ps, t_path)
    stats.routed_violations = int(len(viol))


def _routed_gate_fn(packed: PackedScheme, pol, backend: str, block: int = 128,
                    load=None):
    """Routed-latency evaluator over the evolving packed snapshot.

    Returns ``fn(objects, lengths) -> int32 [B]`` computing
    h(p, r, rho; policy) against ``packed``'s *current* words, or None
    when no gating is wanted (``pol`` is None / home_first — the closed
    form the UPDATE already prices).  ``backend`` picks the
    implementation: ``jnp`` (vectorized scan), ``pallas`` (the
    policy-parameterized routed-walk kernel), or ``reference`` (the
    pure-python oracle against a per-call readback — the parity anchor).
    ``load`` is the forecast per-server load profile a ``queue_aware``
    policy prices the gate with (ignored by load-blind policies).
    """
    if pol is None:
        return None
    if backend == "reference":
        from repro.core.reference import (  # lazy: no cycle at import
            routed_path_latencies_reference,
        )

        def fn(objects, lengths):
            return routed_path_latencies_reference(
                np.asarray(objects, np.int32),
                np.asarray(lengths, np.int32),
                packed.unpack(),
                np.asarray(packed.shard),
                policy=pol,
                load=load,
            )

        return fn
    if backend not in ("jnp", "pallas"):
        raise ValueError(
            f"unknown policy_backend {backend!r}; use reference | jnp | pallas"
        )
    from repro.engine import backends as _backends

    if backend == "pallas":

        def fn(objects, lengths):
            return np.asarray(
                _backends.pallas_routed_eval(
                    to_device(np.asarray(objects, np.int32)),
                    to_device(np.asarray(lengths, np.int32)),
                    packed.words,
                    packed.shard,
                    pol,
                    load=load,
                    block=block,
                )
            )

        return fn

    def fn(objects, lengths):
        return np.asarray(
            _backends.routed_counts(
                to_device(np.asarray(objects, np.int32)),
                to_device(np.asarray(lengths, np.int32)),
                packed.words,
                packed.shard,
                pol,
                load=load,
            )
        )

    return fn


def _routed_class_filter(
    cls: PathSet, b: int, h_all: np.ndarray, routed_fn, max_candidates: int,
    stats: "GreedyStats | None" = None,
):
    """Rebuild one budget class's plan on the routed walk.

    Evaluates the class's paths under the routed policy against the
    current snapshot, drops the ones already within budget (the expensive
    enumeration fallbacks included), and re-derives H_vec + the C(h, t)
    tables from the *surviving* paths only.  Returns
    ``(vec_idx, seq_idx, tables, counts, n_skipped)``.
    """
    h_rt = np.asarray(
        routed_fn(
            np.asarray(cls.objects, np.int32), np.asarray(cls.lengths, np.int32)
        ),
        np.int64,
    )
    kept = np.nonzero(h_rt > b)[0]
    # only structurally-infeasible paths the routed walk rescued count as
    # skips (h <= b paths were no-ops under the closed form too)
    n_skipped = int(((h_all > b) & (h_rt <= b)).sum())
    H_needed = int(h_all[kept].max()) if len(kept) else 0
    H_vec = combi.max_h_within_budget(b, max_candidates, H_needed)
    vec_idx = kept[h_all[kept] <= H_vec]
    seq_idx = kept[h_all[kept] > H_vec]
    tables, counts = _tables_to_device(max(H_vec, b, 1), b, stats)
    return vec_idx, seq_idx, tables, counts, n_skipped


def _fused_setup(packed: PackedScheme, pol, load, fused: bool, mesh,
                 batch_size: int):
    """Shared fused-driver preamble: the gate holder-rank vector, the
    (optionally mesh-sharded) batch upload, and the batch size rounded to
    a device-count multiple.  With a mesh the packed words are replicated
    across devices here — the single device-resident truth every sharded
    batch reads and the scatter-OR updates in place.
    """
    if not fused:
        if mesh is not None:
            raise ValueError("mesh= requires fused=True")
        return None, None, batch_size
    from repro.engine.backends import _load_vector  # lazy: no cycle at import

    rank = _load_vector(
        load if (pol is not None and pol.uses_load) else None, packed.words
    )
    put = None
    if mesh is not None:
        from repro.engine import sharding as _sharding

        packed.words = _sharding.replicate(packed.words, mesh)
        rank = _sharding.replicate(rank, mesh)
        put = _sharding.batch_put(mesh)
        nd = int(np.prod(list(mesh.shape.values())))
        batch_size = -(-batch_size // nd) * nd
    return rank, put, batch_size


def _capacity_arrays(n_servers: int, capacity, epsilon):
    check = capacity is not None or epsilon is not None
    cap_arr = np.full((n_servers,), np.inf, np.float32)
    if capacity is not None:
        cap_arr = np.broadcast_to(
            np.asarray(capacity, np.float32), (n_servers,)
        ).copy()
    eps = np.float32(epsilon if epsilon is not None else np.inf)
    return check, jnp.asarray(cap_arr), jnp.asarray(eps)


# routed-feasibility re-validation rounds after a policy-aware pass: the
# receding-horizon walks are not strictly monotone under foreign replica
# additions, so a path gated out early is re-checked against the final
# scheme and re-run through UPDATE if it regressed (rare; each round only
# touches the violating paths)
_POLICY_REVALIDATE = 2

# masked-repair rounds for the k-resilience gate: with rotation-failover
# homes the home_first masked walk is monotone per loss case (one round
# closes each case for good — Thm 5.3 applies case-by-case), so extra
# rounds only serve the receding-horizon policies, mirroring
# _POLICY_REVALIDATE
_RESILIENCE_ROUNDS = 3


def _resilient_eval(packed: PackedScheme, ps: PathSet, cases, homes,
                    pol, policy_backend: str, load) -> np.ndarray:
    """h per (loss case, path) against ``packed``'s current words.

    The gate's masked re-walk: loss case d clears its servers' holder
    bits and walks under the rotation-failover homes ``homes[d]``.
    ``policy_backend`` keeps the three-way parity discipline — the jnp
    path batches all cases into one vmapped dispatch, pallas lowers each
    case to the routed-walk kernels, reference loops the pure-python
    oracle over per-case host masks.
    """
    objects = np.asarray(ps.objects, np.int32)
    lengths = np.asarray(ps.lengths, np.int32)
    if policy_backend == "reference":
        from repro.core.reference import (  # lazy: no cycle at import
            path_latencies_reference,
            routed_path_latencies_reference,
        )

        mask = packed.unpack()
        rows = []
        for c, fs in zip(cases, homes):
            m = mask.copy()
            m[:, np.asarray(c)] = False
            if pol is None:
                rows.append(path_latencies_reference(objects, lengths, m, fs))
            else:
                rows.append(routed_path_latencies_reference(
                    objects, lengths, m, fs, policy=pol, load=load
                ))
        return np.stack(rows).astype(np.int64)
    from repro.engine import backends as _backends  # lazy: no cycle
    from repro.engine.resilience import case_word_mask  # lazy: no cycle

    W = int(packed.words.shape[1])
    case_masks = np.stack([case_word_mask(c, W) for c in cases])
    out = _backends.resilient_counts(
        to_device(objects),
        to_device(lengths),
        packed.words,
        to_device(case_masks),
        to_device(np.stack(homes).astype(np.int32)),
        policy=pol,
        load=load,
        backend=policy_backend,
    )
    return np.asarray(out).astype(np.int64)


def _repair_loss_case(
    packed: PackedScheme,
    sub_ps: PathSet,
    t_sub: np.ndarray,
    fshard: np.ndarray,
    cmask_words: np.ndarray,
    orphans: np.ndarray,
    pol,
    policy_backend: str,
    f_arr: np.ndarray,
    f_j,
    capacity,
    epsilon,
    cap_j,
    eps_j,
    check_capacity: bool,
    batch_size: int,
    max_candidates: int,
    stats: GreedyStats,
    load,
    fused: bool,
    track_rm: bool,
):
    """One masked UPDATE pass: provision ``sub_ps`` as if the loss case
    had already happened.

    Builds a temporary :class:`PackedScheme` view — the live words with
    the lost servers' holder bits cleared, sharded by the case's
    rotation-failover homes — and runs the same batched UPDATE machinery
    (routed gate included) against it.  ``orphans`` are the violating
    paths' objects whose home the case took down and whose failover home
    holds no copy yet: they are **re-homed first** (a copy provisioned at
    the rotation target), because the UPDATE's closed-form cost model
    prices every object as free at its own home — an assumption the
    masked scheme breaks exactly at the orphans (and the assumption a
    real system restores by resharding off a dead server; re-homing is
    also what makes the data itself survive the case).  Every candidate
    server is a failover home, hence alive under the case by
    construction; capacity is checked on the masked load, which equals
    the live load on every surviving server.  Returns the applied
    (object, server) additions — orphan re-homes included — for the
    caller to replay into the live scheme (Thm 5.3: replaying them can
    only lower latencies of the unmasked walk too).
    """
    from repro.engine.backends import mask_case_words  # lazy: no cycle

    masked = PackedScheme(
        words=mask_case_words(packed.words, to_device(cmask_words)),
        shard=to_device(np.asarray(fshard, np.int32)),
        n_servers=packed.n_servers,
    )
    if len(orphans):
        masked.add(orphans, np.asarray(fshard)[orphans])
    routed_fn = _routed_gate_fn(masked, pol, policy_backend, load=load)
    fused_c = fused and policy_backend != "reference"
    use_pallas = fused_c and policy_backend == "pallas"
    rank, put, bsz = _fused_setup(masked, pol, load, fused_c, None, batch_size)
    srv_load = jnp.asarray(masked.storage_per_server(f_arr).astype(np.float32))
    host_scheme: ReplicationScheme | None = None
    add_obj: list[np.ndarray] = []
    add_srv: list[np.ndarray] = []
    if len(orphans):
        add_obj.append(np.asarray(orphans, np.int64))
        add_srv.append(np.asarray(fshard, np.int64)[orphans])
    for b, cls, vec_idx, seq_idx, h_all, tables, counts in _budget_class_plan(
        sub_ps, t_sub, masked.shard, max_candidates,
        skip_tables=routed_fn is not None, stats=stats,
    ):
        if routed_fn is not None and cls.n_paths:
            vec_idx, seq_idx, tables, counts, n_skip = _routed_class_filter(
                cls, b, h_all, routed_fn, max_candidates, stats=stats
            )
            stats.routed_skips += n_skip
        srv_load, additions = _run_update_batches(
            masked,
            cls.objects[vec_idx],
            cls.lengths[vec_idx],
            masked.shard,
            f_arr,
            f_j,
            tables,
            counts,
            np.full(len(vec_idx), b, np.int32),
            srv_load,
            cap_j,
            eps_j,
            check_capacity,
            bsz,
            stats,
            track_rm,
            collect_additions=True,
            routed_fn=None if fused_c else routed_fn,
            fused=fused_c,
            pol=pol,
            rank=rank,
            use_pallas=use_pallas,
            put=put,
        )
        add_obj.append(additions[0])
        add_srv.append(additions[1])
        if len(seq_idx):
            # exact fallback against the masked host view; additions are
            # replayed into the masked words so later classes see them
            if host_scheme is None:
                host_scheme = ReplicationScheme(
                    masked.unpack(), np.asarray(fshard, np.int32)
                )
            else:
                host_scheme.mask = masked.unpack()
            fb_obj: list[int] = []
            fb_srv: list[int] = []
            for i in seq_idx:
                res = update_exact(
                    host_scheme, cls.path(int(i)), b, f_arr, capacity,
                    epsilon, policy=pol, load=load,
                )
                stats.fallback_paths += 1
                if res.feasible:
                    stats.total_cost += res.cost
                    fb_obj.extend(v for v, _ in res.additions)
                    fb_srv.extend(s for _, s in res.additions)
                    if track_rm:
                        stats.rm.extend(res.rm_entries)
                else:
                    stats.failed_paths += 1
            if fb_obj:
                masked.add(np.asarray(fb_obj), np.asarray(fb_srv))
                add_obj.append(np.asarray(fb_obj, np.int64))
                add_srv.append(np.asarray(fb_srv, np.int64))
                if check_capacity:
                    srv_load = jnp.asarray(
                        masked.storage_per_server(f_arr).astype(np.float32)
                    )
    return (
        np.concatenate(add_obj) if add_obj else np.zeros(0, np.int64),
        np.concatenate(add_srv) if add_srv else np.zeros(0, np.int64),
    )


def _enforce_resilience(
    packed: PackedScheme,
    ps: PathSet,
    t_path: np.ndarray,
    res,
    pol,
    policy_backend: str,
    f_arr: np.ndarray,
    f_j,
    capacity,
    epsilon,
    cap_j,
    eps_j,
    check_capacity: bool,
    batch_size: int,
    max_candidates: int,
    stats: GreedyStats,
    load,
    fused: bool,
    track_rm: bool,
):
    """The k-resilience gate: repair every loss case until none violates.

    Per bounded round: evaluate h under every loss case of ``res`` (one
    batched masked re-walk), then for each violating case run the masked
    UPDATE over its violating paths and scatter-OR the chosen additions
    into the LIVE words — so later cases and rounds price against them.
    The surviving (case, path) violations land in
    ``stats.resilient_violations``; 0 means the returned scheme stays
    latency-feasible under the loss of any single server / fault domain
    combination the constraint names.  Returns the applied (object,
    server) additions.
    """
    from repro.engine.resilience import (  # lazy: no cycle at import
        case_word_mask,
        failover_shard,
    )

    n_servers = packed.n_servers
    shard_host = np.asarray(packed.shard)
    cases = res.loss_cases(n_servers)
    homes = [failover_shard(shard_host, c, n_servers) for c in cases]
    W = int(packed.words.shape[1])
    all_obj: list[np.ndarray] = []
    all_srv: list[np.ndarray] = []
    for rnd in range(_RESILIENCE_ROUNDS + 1):
        h_cases = _resilient_eval(
            packed, ps, cases, homes, pol, policy_backend, load
        )
        viol = h_cases > t_path[None, :]
        total = int(viol.sum())
        if total == 0 or rnd == _RESILIENCE_ROUNDS:
            stats.resilient_violations = total
            break
        stats.resilience_rounds += 1
        mask_host = packed.unpack()
        for d, c in enumerate(cases):
            idx = np.nonzero(viol[d])[0]
            if not len(idx):
                continue
            # objects the case orphans: homed on a lost server, no copy
            # at the rotation failover home yet — re-homed by the repair
            vobj = np.unique(np.asarray(ps.objects)[idx])
            vobj = vobj[vobj >= 0]
            dead = np.zeros(n_servers, bool)
            dead[np.asarray(c)] = True
            orphans = vobj[
                dead[shard_host[vobj]] & ~mask_host[vobj, homes[d][vobj]]
            ]
            obj, srv = _repair_loss_case(
                packed, ps.select(idx), t_path[idx], homes[d],
                case_word_mask(c, W), orphans, pol, policy_backend,
                f_arr, f_j, capacity, epsilon, cap_j, eps_j,
                check_capacity, batch_size, max_candidates, stats, load,
                fused, track_rm,
            )
            if len(obj):
                # replay into the live scheme: monotone adds, all targets
                # alive under the case (failover homes by construction)
                packed.add(obj, srv)
                mask_host[obj, srv] = True  # keep later cases' orphan filter exact
                all_obj.append(obj)
                all_srv.append(srv)
    return (
        np.concatenate(all_obj) if all_obj else np.zeros(0, np.int64),
        np.concatenate(all_srv) if all_srv else np.zeros(0, np.int64),
    )


def replicate_workload(
    pathset: PathSet,
    shard: np.ndarray,
    n_servers: int,
    t,
    f: np.ndarray | None = None,
    capacity: np.ndarray | float | None = None,
    epsilon: float | None = None,
    batch_size: int = 256,
    max_candidates: int = 2048,
    prune: bool = True,
    track_rm: bool = False,
    return_engine: bool = False,
    policy=None,
    policy_backend: str = "jnp",
    policy_prune: bool = True,
    load: np.ndarray | None = None,
    fused: bool = False,
    mesh=None,
    resilience=None,
):
    """Alg 1 over a workload with the vectorized batched UPDATE.

    Args mirror Def 4.4: ``t`` is the latency constraint — an int (every
    query shares one bound), a per-query int vector, or an
    :class:`~repro.core.slo.SLOSpec` (per-tenant budgets); ``f`` the
    storage cost function, ``capacity`` M_s, ``epsilon`` the load imbalance
    bound.  ``track_rm`` additionally accumulates the §5.4 resharding map
    entries (u, v, s).

    Vector budgets bucket paths into budget classes (tightest first); each
    class runs the same batched UPDATE with its own candidate tables, so
    ``replicate_workload(ps, ..., t=k)`` and
    ``replicate_workload(ps, ..., t=SLOSpec.uniform(k, nq))`` produce
    bit-identical schemes.

    ``policy`` (str | ``repro.engine.routing.RoutingPolicy``) prices every
    candidate under that *routed* walk instead of the home-first closed
    form: per budget class the C(h, t) tables are rebuilt on the paths the
    routed walk cannot already serve, and every batch gates additions on
    h(p, r, rho; policy) <= t_q against the same snapshot it costs
    candidates on — a path existing replicas already serve buys nothing
    (``stats.routed_skips`` counts them).  After the main pass the routed
    feasibility of the whole workload is re-validated and any regressed
    paths re-run (bounded rounds).  ``policy="home_first"`` / ``None`` is
    the historical driver, bit-identical.  ``policy_backend`` selects the
    gate's evaluator: ``jnp`` | ``pallas`` (the policy-parameterized
    routed-walk kernel) | ``reference`` (pure-python oracle).

    The gate only prices a path against the replicas of *earlier*
    batches (lock-free snapshot semantics — within one batch every path
    still pays home-first style), so with ``policy_prune=True`` (the
    default for policy runs) the driver finishes with one
    :func:`~repro.core.replication.prune_scheme_replicas` sweep under the
    same policy, dropping the within-batch redundancy the snapshot could
    not see; ``stats.pruned_replicas`` counts the drops and the returned
    scheme/engine reflect them.

    The evolving scheme lives on device as the engine's packed uint32
    bitmask; every batch bit-tests candidates against that snapshot and
    applies the chosen additions with one on-device scatter-OR — the
    unpacked bool mask is read back once per budget class that needs the
    exact fallback, plus once at the end.  With ``return_engine=True`` the
    returned tuple gains a ``LatencyEngine`` that still holds the final
    scheme device-resident, so follow-up feasibility sweeps skip the
    re-upload entirely.

    ``load`` is a forecast per-server load profile: a ``queue_aware``
    policy prices the gate (and the exact fallbacks, the revalidation
    rounds, and the final prune) with it instead of the static zero-load
    default — provision-time load awareness.  Load-blind policies ignore
    it.

    ``fused`` replaces the separate-dispatch pipeline (host-driven gate
    eval + UPDATE + per-batch stat readbacks) with one fused jit step per
    batch — gate + candidate scoring + bit-test + scatter-OR in a single
    dispatch, statistics reduced on device (``policy_backend="pallas"``
    lowers the step to the ``kernels.provision_update`` megakernel) — and
    prices the final prune sweep with the batched independent-group
    plan.  Bit-identical to ``fused=False`` by construction (asserted
    across the full policy x backend matrix in
    tests/test_provision_scale.py).  ``mesh`` (a ``jax.sharding.Mesh``
    from ``repro.engine.sharding.provisioning_mesh``) additionally shards
    every batch across devices on the path axis while the packed words
    stay replicated (requires ``fused=True``).

    ``resilience`` (int k | :class:`~repro.engine.KResilient` | None)
    adds the k-resilience gate: after the ordinary pass (and the policy
    prune — pruning decides on the non-resilient criterion, so it must
    not run after the resilience replicas land) every loss case of the
    constraint is evaluated as a masked re-walk — the lost servers'
    holder bits cleared, homes remapped by rotation failover — batched
    across cases in the same fused UPDATE machinery, and each violating
    (case, path) pair is re-run through UPDATE against the masked
    snapshot.  The additions are replayed into the live scheme (sound by
    Thm 5.3).  ``stats.resilient_violations == 0`` certifies the
    returned scheme stays latency-feasible under the loss of any single
    server / any k fault domains.
    """
    from repro.core.slo import normalize_path_budgets  # local: no cycle at import
    from repro.engine.resilience import resolve_resilience  # local: no cycle
    from repro.engine.routing import resolve_policy  # local: no cycle at import

    t0 = time.perf_counter()
    n = shard.shape[0]
    pol = resolve_policy(policy)
    pol = None if pol.name == "home_first" else pol
    res = resolve_resilience(resilience)
    t_path = normalize_path_budgets(t, pathset)
    if prune:
        # the budget joins the §5.3 dedup key: a tight-budget path must not
        # be merged into a loose-budget duplicate (constraint would vanish)
        ps, keep = pathset.prune_redundant(
            shard, extra_key=t_path, return_index=True
        )
        t_path = t_path[keep]
    else:
        ps = pathset
    scheme = ReplicationScheme.from_sharding(shard, n_servers)
    stats = GreedyStats(rm=[] if track_rm else None)
    stats.paths_processed = ps.n_paths
    if ps.n_paths == 0:
        stats.runtime_s = time.perf_counter() - t0
        if return_engine:
            return scheme, stats, LatencyEngine(scheme)
        return scheme, stats

    f_arr = np.ones((n,), np.float32) if f is None else f.astype(np.float32)
    packed = PackedScheme.from_sharding(scheme.shard, n_servers)
    shard_j = packed.shard
    f_j = to_device(f_arr)

    check_capacity, cap_j, eps_j = _capacity_arrays(n_servers, capacity, epsilon)
    srv_load = jnp.asarray(scheme.storage_per_server(f_arr).astype(np.float32))
    routed_fn = _routed_gate_fn(packed, pol, policy_backend, load=load)
    fused = fused and policy_backend != "reference"
    use_pallas = policy_backend == "pallas"
    rank, put, batch_size = _fused_setup(
        packed, pol, load, fused, mesh, batch_size
    )

    def run_classes(ps_run: PathSet, t_run: np.ndarray) -> None:
        nonlocal srv_load
        for b, cls, vec_idx, seq_idx, h_all, tables, counts in _budget_class_plan(
            ps_run, t_run, shard_j, max_candidates,
            skip_tables=routed_fn is not None, stats=stats,
        ):
            n_skip = 0
            if routed_fn is not None and cls.n_paths:
                vec_idx, seq_idx, tables, counts, n_skip = _routed_class_filter(
                    cls, b, h_all, routed_fn, max_candidates, stats=stats
                )
                stats.routed_skips += n_skip
            _obs_record_class(stats, b, len(vec_idx), len(seq_idx), counts, n_skip)
            srv_load, _ = _run_update_batches(
                packed,
                cls.objects[vec_idx],
                cls.lengths[vec_idx],
                shard_j,
                f_arr,
                f_j,
                tables,
                counts,
                np.full(len(vec_idx), b, np.int32),
                srv_load,
                cap_j,
                eps_j,
                check_capacity,
                batch_size,
                stats,
                track_rm,
                routed_fn=None if fused else routed_fn,
                fused=fused,
                pol=pol,
                rank=rank,
                use_pallas=use_pallas,
                put=put,
            )

            # Exact fallback for enumeration-heavy paths (processed after
            # the class's vectorized paths; order is immaterial to
            # correctness by Thm 5.3).  Additions run against a freshly
            # synced host mask and are replayed into the packed words so
            # later classes see them.
            if len(seq_idx):
                scheme.mask = packed.unpack()
                fb_obj: list[int] = []
                fb_srv: list[int] = []
                for i in seq_idx:
                    res = update_exact(
                        scheme, cls.path(int(i)), b, f_arr, capacity,
                        epsilon, policy=pol, load=load,
                    )
                    stats.fallback_paths += 1
                    if res.feasible:
                        stats.total_cost += res.cost
                        fb_obj.extend(v for v, _ in res.additions)
                        fb_srv.extend(s for _, s in res.additions)
                        if track_rm:
                            stats.rm.extend(res.rm_entries)
                    else:
                        stats.failed_paths += 1
                if fb_obj:
                    packed.add(np.asarray(fb_obj), np.asarray(fb_srv))
                    if check_capacity:
                        srv_load = jnp.asarray(
                            packed.storage_per_server(f_arr).astype(np.float32)
                        )

    run_classes(ps, t_path)
    if routed_fn is not None:
        from repro.engine.incremental import PathIndex  # lazy: no cycle

        _revalidate_routed(
            routed_fn, ps, t_path, run_classes, stats,
            index=PathIndex(np.asarray(ps.objects), packed.n_objects),
        )

    # single host readback of the packed words (vs. per-batch bool mask);
    # fallback additions were replayed into the words, so the packed state
    # stays the source of truth and return_engine never loses residency.
    scheme.mask = packed.unpack()

    if pol is not None and policy_prune and stats.paths_processed:
        from repro.core.replication import (  # lazy: no cycle at import
            prune_scheme_replicas,
        )

        stats.pruned_replicas, _ = prune_scheme_replicas(
            scheme, pathset, t, policy=pol, f=f_arr, load=load, fused=fused
        )
        if stats.pruned_replicas:
            # removals are not monotone: the packed words are stale
            packed = PackedScheme.from_mask(scheme.mask, scheme.shard)

    if res is not None and ps.n_paths:
        _enforce_resilience(
            packed, ps, t_path, res, pol, policy_backend, f_arr, f_j,
            capacity, epsilon, cap_j, eps_j, check_capacity, batch_size,
            max_candidates, stats, load, fused, track_rm,
        )
        scheme.mask = packed.unpack()

    stats.replicas = scheme.replica_count()
    stats.runtime_s = time.perf_counter() - t0
    if return_engine:
        return scheme, stats, LatencyEngine(scheme, packed=packed)
    return scheme, stats


def replicate_delta(
    pathset: PathSet,
    engine: LatencyEngine,
    t,
    f: np.ndarray | None = None,
    capacity: np.ndarray | float | None = None,
    epsilon: float | None = None,
    batch_size: int = 256,
    max_candidates: int = 2048,
    prune: bool = True,
    track_rm: bool = False,
    policy=None,
    policy_backend: str = "jnp",
    load: np.ndarray | None = None,
    fused: bool = False,
    mesh=None,
    collect_additions: bool = True,
    stats_acc: DeviceStatsAcc | None = None,
    sync_host: bool = True,
    resilience=None,
):
    """Warm-start incremental UPDATE over *delta* paths (online serving).

    Runs the same batched Alg 2 UPDATE loop as :func:`replicate_workload`,
    but against the scheme an existing :class:`LatencyEngine` already holds
    device-resident — no from-scratch rebuild, no re-upload.  The additions
    are scatter-ORed into the engine's ``PackedScheme`` on device and
    mirrored into the engine's host scheme (when it has one), so a live
    ``Cluster`` sharing that scheme object sees the delta immediately.

    ``t`` is an int, a per-query vector, or an
    :class:`~repro.core.slo.SLOSpec` aligned with ``pathset`` — vector
    budgets run one UPDATE pass per budget class (tightest first), exactly
    like the from-scratch driver.

    ``policy`` prices the delta under the routed walk, exactly as in
    :func:`replicate_workload`: delta paths the resident scheme already
    serves under the policy buy nothing — a controller that scores
    violations under ``nearest_copy`` repairs with the same policy it
    triggered on, instead of over-paying home-first bytes.

    By Thm 5.3 (latency-robustness) the existing replicas can only lower
    candidate costs, never invalidate previously established bounds, so
    warm-starting over a path delta is exactly as sound as processing those
    paths later in a longer from-scratch run — with batch boundaries
    aligned, the two produce identical schemes (see tests/test_serve.py).

    ``load`` / ``fused`` / ``mesh`` mirror :func:`replicate_workload`:
    forecast load pricing for ``queue_aware`` gates, the fused
    single-dispatch UPDATE step, and multi-device path sharding.

    Returns ``(stats, (objects, servers))`` — the greedy stats for the
    delta and the applied replica additions as two int64 arrays (the
    scheme delta a controller ships to the cluster / replays on restart).
    With ``collect_additions=False`` (streamed ingestion: the caller only
    wants the evolving scheme, not the delta) the per-batch chosen-mask
    readbacks are skipped entirely and the returned arrays are empty; the
    engine's host mask, when present, is refreshed from the packed words
    once per class instead of per-pair.

    ``stats_acc`` (fused runs) keeps the device stat accumulator live
    across calls instead of reading it back before returning — the
    returned stats' cost/failed/skipped components stay 0 until the
    caller :meth:`DeviceStatsAcc.drain`\\ s the holder.  ``sync_host=False``
    additionally skips the end-of-call host-mask refresh (the other
    per-call sync point).  Together they make a fused, non-policy call
    fully asynchronous — what :func:`replicate_stream`'s double-buffered
    pipeline needs to overlap chunk ingestion with device compute.

    ``resilience`` mirrors :func:`replicate_workload`: after the delta
    pass the loss cases are re-walked over the delta paths and repaired;
    the resilience additions join the returned delta (a controller
    repairing a failure passes the dead set as a one-domain
    ``KResilient`` to provision survivable copies in the same call).
    """
    from repro.core.slo import normalize_path_budgets  # local: no cycle at import
    from repro.engine.resilience import resolve_resilience  # local: no cycle
    from repro.engine.routing import resolve_policy  # local: no cycle at import

    t0 = time.perf_counter()
    if engine.packed is None:
        engine.packed = PackedScheme.from_mask(
            engine.scheme.mask, engine.scheme.shard
        )
    packed = engine.packed
    shard = engine.host_shard()
    n = packed.n_objects
    n_servers = packed.n_servers
    pol = resolve_policy(policy)
    pol = None if pol.name == "home_first" else pol
    res = resolve_resilience(resilience)
    t_path = normalize_path_budgets(t, pathset)
    if prune:
        ps, keep = pathset.prune_redundant(
            shard, extra_key=t_path, return_index=True
        )
        t_path = t_path[keep]
    else:
        ps = pathset
    stats = GreedyStats(rm=[] if track_rm else None)
    stats.paths_processed = ps.n_paths
    empty = (np.zeros(0, np.int64), np.zeros(0, np.int64))
    if ps.n_paths == 0:
        stats.runtime_s = time.perf_counter() - t0
        return stats, empty

    f_arr = np.ones((n,), np.float32) if f is None else f.astype(np.float32)
    f_j = to_device(f_arr)
    shard_j = packed.shard

    check_capacity, cap_j, eps_j = _capacity_arrays(n_servers, capacity, epsilon)
    srv_load = jnp.asarray(packed.storage_per_server(f_arr).astype(np.float32))
    routed_fn = _routed_gate_fn(packed, pol, policy_backend, load=load)
    fused = fused and policy_backend != "reference"
    use_pallas = policy_backend == "pallas"
    rank, put, batch_size = _fused_setup(
        packed, pol, load, fused, mesh, batch_size
    )

    add_obj = np.zeros(0, np.int64)
    add_srv = np.zeros(0, np.int64)

    def run_classes(ps_run: PathSet, t_run: np.ndarray) -> None:
        nonlocal srv_load, add_obj, add_srv
        for b, cls, vec_idx, seq_idx, h_all, tables, counts in _budget_class_plan(
            ps_run, t_run, shard_j, max_candidates,
            skip_tables=routed_fn is not None, stats=stats,
        ):
            n_skip = 0
            if routed_fn is not None and cls.n_paths:
                vec_idx, seq_idx, tables, counts, n_skip = _routed_class_filter(
                    cls, b, h_all, routed_fn, max_candidates, stats=stats
                )
                stats.routed_skips += n_skip
            _obs_record_class(stats, b, len(vec_idx), len(seq_idx), counts, n_skip)
            srv_load, additions = _run_update_batches(
                packed,
                cls.objects[vec_idx],
                cls.lengths[vec_idx],
                shard_j,
                f_arr,
                f_j,
                tables,
                counts,
                np.full(len(vec_idx), b, np.int32),
                srv_load,
                cap_j,
                eps_j,
                check_capacity,
                batch_size,
                stats,
                track_rm,
                collect_additions=collect_additions,
                routed_fn=None if fused else routed_fn,
                fused=fused,
                pol=pol,
                rank=rank,
                use_pallas=use_pallas,
                put=put,
                acc_holder=stats_acc if fused else None,
            )

            # Mirror the vectorized additions into the host scheme FIRST:
            # the exact fallback below prices candidates against the host
            # mask, which must reflect what this class already
            # scatter-ORed into the words (and later classes' fallbacks
            # price against this class).
            if collect_additions:
                cls_obj, cls_srv = additions
                if engine.scheme is not None and len(cls_obj):
                    engine.scheme.mask[cls_obj, cls_srv] = True
                add_obj = np.concatenate([add_obj, cls_obj])
                add_srv = np.concatenate([add_srv, cls_srv])
            elif engine.scheme is not None and len(seq_idx):
                # no per-pair readback requested: the exact fallback below
                # prices against the host mask, so refresh it from the
                # packed truth (one readback) right before it is consumed
                engine.scheme.mask = packed.unpack()
                if obs.enabled():
                    obs.REGISTRY.counter("repro.greedy.mask_syncs").inc()

            # Exact fallback for enumeration-heavy delta paths: run against
            # a host scheme and replay the additions into the
            # device-resident words.
            if len(seq_idx):
                host = (
                    engine.scheme
                    if engine.scheme is not None
                    else engine.to_scheme()
                )
                fb_obj: list[int] = []
                fb_srv: list[int] = []
                for i in seq_idx:
                    res = update_exact(
                        host, cls.path(int(i)), b, f_arr, capacity,
                        epsilon, policy=pol, load=load,
                    )
                    stats.fallback_paths += 1
                    if res.feasible:
                        stats.total_cost += res.cost
                        fb_obj.extend(v for v, _ in res.additions)
                        fb_srv.extend(s for _, s in res.additions)
                        if track_rm:
                            stats.rm.extend(res.rm_entries)
                    else:
                        stats.failed_paths += 1
                if fb_obj:
                    packed.add(np.asarray(fb_obj), np.asarray(fb_srv))
                    if collect_additions:
                        add_obj = np.concatenate(
                            [add_obj, np.asarray(fb_obj, np.int64)]
                        )
                        add_srv = np.concatenate(
                            [add_srv, np.asarray(fb_srv, np.int64)]
                        )
                    if check_capacity:
                        srv_load = jnp.asarray(
                            packed.storage_per_server(f_arr).astype(np.float32)
                        )

    run_classes(ps, t_path)
    if routed_fn is not None:
        from repro.engine.incremental import PathIndex  # lazy: no cycle

        _revalidate_routed(
            routed_fn, ps, t_path, run_classes, stats,
            index=PathIndex(np.asarray(ps.objects), packed.n_objects),
        )

    if res is not None:
        r_obj, r_srv = _enforce_resilience(
            packed, ps, t_path, res, pol, policy_backend, f_arr, f_j,
            capacity, epsilon, cap_j, eps_j, check_capacity, batch_size,
            max_candidates, stats, load, fused, track_rm,
        )
        if len(r_obj):
            if engine.scheme is not None:
                engine.scheme.mask[r_obj, r_srv] = True
            add_obj = np.concatenate([add_obj, r_obj])
            add_srv = np.concatenate([add_srv, r_srv])

    # the UPDATE loop scatter-ORs into packed.words inside jits, bypassing
    # engine.add_replicas — report the touched objects so the engine's
    # incremental latency cache invalidates its exact dirty set.  The
    # additions are copies of objects on the processed paths, so with the
    # per-batch readbacks off the conservative superset is all of them.
    if collect_additions:
        engine.note_changed(add_obj)
    else:
        engine.note_changed(np.asarray(ps.objects))

    if not collect_additions and sync_host and engine.scheme is not None:
        # keep the engine's host mirror consistent at return (the per-pair
        # incremental mirror is what collect_additions=False skipped);
        # sync_host=False defers even this to the caller (streamed
        # ingestion syncs once at stream end)
        engine.scheme.mask = packed.unpack()
        if obs.enabled():
            obs.REGISTRY.counter("repro.greedy.mask_syncs").inc()

    # Dedupe (a batch can choose the same (v, s) for several paths; the
    # scatter-OR is idempotent, but the returned delta is the exact set of
    # new copies — the bytes a controller actually ships).
    if len(add_obj):
        pairs = np.unique(np.stack([add_obj, add_srv], axis=1), axis=0)
        add_obj, add_srv = pairs[:, 0], pairs[:, 1]

    stats.replicas = int(len(add_obj))
    stats.runtime_s = time.perf_counter() - t0
    return stats, (add_obj, add_srv)


def replicate_stream(
    stream,
    shard: np.ndarray,
    n_servers: int,
    t=None,
    f: np.ndarray | None = None,
    capacity: np.ndarray | float | None = None,
    epsilon: float | None = None,
    batch_size: int = 256,
    max_candidates: int = 2048,
    prune: bool = True,
    policy=None,
    policy_backend: str = "jnp",
    load: np.ndarray | None = None,
    fused: bool = True,
    mesh=None,
    return_engine: bool = False,
):
    """Alg 1 over a *streamed* workload — the full path set is never
    host-resident.

    ``stream`` is a :class:`~repro.engine.streaming.PathStream` (or any
    iterable of ``PathSet`` chunks / ``(PathSet, budgets)`` tuples, which
    is wrapped in one): the producer builds each chunk on demand and
    drops it after the yield, so host residency peaks at one chunk
    (``stats.peak_resident_paths`` — the contract
    ``benchmarks/provisioning_scale.py`` asserts).  Each chunk runs the
    warm-started incremental UPDATE (:func:`replicate_delta`) against the
    single device-resident packed scheme; by Thm 5.3 replica additions
    are monotone, so chunked provisioning is exactly as sound as one long
    run with different batch boundaries (paths duplicated across chunks
    re-enter UPDATE, find themselves already served, and buy nothing).

    ``t`` is the default budget for chunks yielded without one; chunks
    yielded as ``(PathSet, budgets)`` override it per chunk.  ``fused``
    defaults on (this is the provisioning-scale entry point) and, with
    ``collect_additions`` off internally, no per-batch readback ever
    crosses the bus.

    Ingestion is **double-buffered** (the engine's ``stream_chunks``
    pipeline shape, applied to provisioning): each chunk's UPDATE passes
    are dispatched with the stat readback *deferred* to a device
    accumulator (:class:`DeviceStatsAcc`) and the host-mask sync skipped,
    so while chunk ``i``'s batches compute on device, the producer
    generator is already materializing chunk ``i + 1`` on the host.  The
    overlapped producer seconds are reported in
    ``stats.ingest_overlap_s`` (and, when the telemetry plane is on, the
    ``repro.stream.ingest_overlap_s`` gauge).  Policy-aware runs
    (``policy=``) still sync per chunk inside the routed gate; the
    pipeline degrades gracefully rather than breaking.

    Returns ``(scheme, stats)``; ``return_engine=True`` appends the
    device-resident :class:`LatencyEngine`.
    """
    from repro.engine.streaming import PathStream, double_buffer  # lazy: no cycle

    t0 = time.perf_counter()
    if not isinstance(stream, PathStream):
        stream = PathStream(stream)
    scheme = ReplicationScheme.from_sharding(shard, n_servers)
    engine = LatencyEngine(scheme)
    stats = GreedyStats()
    fused = fused and policy_backend != "reference"
    acc_holder = DeviceStatsAcc() if fused else None

    def dispatch(item):
        ps, t_chunk = item
        budgets = t if t_chunk is None else t_chunk
        if budgets is None:
            raise ValueError(
                "no latency budget: pass t= or stream (PathSet, t) tuples"
            )
        cstats, _ = replicate_delta(
            ps, engine, budgets, f=f, capacity=capacity, epsilon=epsilon,
            batch_size=batch_size, max_candidates=max_candidates,
            prune=prune, policy=policy, policy_backend=policy_backend,
            load=load, fused=fused, mesh=mesh, collect_additions=False,
            stats_acc=acc_holder, sync_host=False,
        )
        # cost/failed/skipped live in the deferred device accumulator
        # (fused) and drain once after the stream; the host-side components
        # accumulate per chunk as before
        stats.total_cost += cstats.total_cost
        stats.failed_paths += cstats.failed_paths
        stats.paths_processed += cstats.paths_processed
        stats.fallback_paths += cstats.fallback_paths
        stats.routed_skips += cstats.routed_skips
        stats.routed_violations += cstats.routed_violations
        stats.table_peak_rows = max(
            stats.table_peak_rows, cstats.table_peak_rows
        )
        stats.table_total_rows += cstats.table_total_rows
        if cstats.timeline:
            stats.timeline = (stats.timeline or []) + cstats.timeline

    overlap_s = double_buffer(stream, dispatch)
    if acc_holder is not None:
        acc_holder.drain(stats)
    stats.ingest_overlap_s = stream.stats.ingest_overlap_s = overlap_s
    if engine.packed is not None:
        # the one end-of-stream host sync the per-chunk sync_host=False
        # deferred (keeps scheme and the engine's host mirror consistent)
        scheme.mask = engine.packed.unpack()
    stats.replicas = scheme.replica_count()
    stats.peak_resident_paths = stream.stats.peak_resident_paths
    stream.stats.peak_resident_table_rows = stats.table_peak_rows
    stream.stats.total_table_rows = stats.table_total_rows
    stats.runtime_s = time.perf_counter() - t0
    if obs.enabled():
        obs.REGISTRY.gauge("repro.stream.ingest_overlap_s").set(overlap_s)
        obs.REGISTRY.gauge("repro.stream.peak_resident_paths").set(
            stats.peak_resident_paths
        )
        obs.REGISTRY.counter("repro.stream.chunks").inc(stream.stats.chunks)
    if return_engine:
        return scheme, stats, engine
    return scheme, stats
