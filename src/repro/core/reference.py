"""Exact sequential implementation of Alg 1 + Alg 2 (paper §5.3).

This is the line-by-line faithful transcription of the paper's pseudocode,
including the two-pass cost-then-feasibility iteration order described in
"Performance optimizations".  It is the correctness oracle for the
vectorized implementation in ``repro.core.greedy`` and is used directly for
small workloads in tests/benchmarks.

It also hosts the pure-python path-latency oracle that backs
``repro.engine.LatencyEngine(backend="reference")``
(:func:`path_latencies_reference`).
"""
from __future__ import annotations

import dataclasses
import itertools

import numpy as np

from repro.core.paths import PathSet
from repro.core.replication import ReplicationScheme


@dataclasses.dataclass
class UpdateResult:
    feasible: bool
    cost: float
    additions: list[tuple[int, int]]            # (object, server) pairs added
    rm_entries: list[tuple[int, int, int]]      # (u, v, server) resharding map


def path_latencies_reference(
    objects: np.ndarray, lengths: np.ndarray, mask: np.ndarray, shard: np.ndarray
) -> np.ndarray:
    """Engine ``reference`` backend: the Eqn 1-2 walk, one path at a time.

    ``objects`` int32 [P, L] (-1 padded), ``lengths`` int32 [P]; returns
    int32 [P] distributed-traversal counts.  Deliberately scalar python —
    this is the oracle the vectorized backends are proven against.
    """
    from repro.core.replication import path_latency_reference

    P = objects.shape[0]
    out = np.zeros((P,), dtype=np.int32)
    for i in range(P):
        path = objects[i, : lengths[i]].tolist()
        out[i] = path_latency_reference(path, mask, shard)
    return out


def routed_trace_reference(
    objects: np.ndarray,
    lengths: np.ndarray,
    mask: np.ndarray,
    home: np.ndarray,
    start: np.ndarray | None = None,
    policy="home_first",
    load: np.ndarray | None = None,
) -> tuple[np.ndarray, np.ndarray]:
    """Policy-routed access-walk oracle (``repro.engine.routing``).

    One path at a time, one access at a time: a hop is local when the
    current server holds a copy (Eqn 1); a remote hop's target comes from
    the policy — ``home[obj]`` under ``home_first``, the
    :func:`~repro.engine.routing.pick_holder_host` holder pick under
    ``nearest_copy``/``queue_aware`` (``load`` ranks holders for the
    latter).  Returns (servers int32 [P, L], local bool [P, L]) with
    position 0 local when the path is non-empty — exactly the contract of
    ``repro.engine.backends.access_trace``, which is parity-tested
    against this function.
    """
    from repro.engine.routing import (
        dp_suffix_scores,
        pick_holder_host,
        pick_holder_scored,
        resolve_policy,
    )

    pol = resolve_policy(policy)
    lv = load if pol.uses_load else None
    P, L = objects.shape
    servers = np.zeros((P, L), np.int32)
    local = np.zeros((P, L), bool)
    home = np.asarray(home, np.int64)
    for i in range(P):
        n = int(lengths[i])
        if n == 0:
            continue
        dp = (
            dp_suffix_scores(objects[i, :n], mask, pol.depth)
            if pol.name == "nearest_copy_dp"
            else None
        )
        cur = int(start[i]) if start is not None else int(home[objects[i, 0]])
        servers[i, 0] = cur
        local[i, 0] = True
        for x in range(1, n):
            v = int(objects[i, x])
            if cur >= 0 and mask[v, cur]:
                local[i, x] = True
            elif pol.name == "home_first":
                cur = int(home[v])
            elif dp is not None:
                # score each holder by the optimal cost-to-go over the
                # next `depth` accesses when the hop lands there
                cur = pick_holder_scored(mask[v], int(home[v]), dp[x, :-1])
            else:
                la = None
                if pol.lookahead and x + 1 < n:
                    la = mask[int(objects[i, x + 1])]
                cur = pick_holder_host(mask[v], int(home[v]), lv, la)
            servers[i, x] = cur
        servers[i, n:] = cur
    return servers, local


def routed_path_latencies_reference(
    objects, lengths, mask, home, policy="nearest_copy", load=None
) -> np.ndarray:
    """Distributed-traversal counts under a routing policy (oracle)."""
    _, local = routed_trace_reference(
        objects, lengths, mask, home, policy=policy, load=load
    )
    valid = np.arange(objects.shape[1])[None, :] < np.asarray(lengths)[:, None]
    return (valid & ~local).sum(axis=1).astype(np.int32)


def server_local_subpaths(path: list[int], shard: np.ndarray) -> list[list[int]]:
    """G_{p,d}: maximal runs of the path local to one server under d."""
    if not path:
        return []
    groups: list[list[int]] = [[path[0]]]
    for v in path[1:]:
        if shard[v] == shard[groups[-1][-1]]:
            groups[-1].append(v)
        else:
            groups.append([v])
    return groups


def update_exact(
    scheme: ReplicationScheme,
    path: list[int],
    t: int,
    f: np.ndarray | None = None,
    capacity: np.ndarray | float | None = None,
    epsilon: float | None = None,
    apply: bool = True,
    policy=None,
    load: np.ndarray | None = None,
) -> UpdateResult:
    """Alg 2: one UPDATE(r, p) call.  Mutates ``scheme`` in place if feasible.

    Follows the pseudocode exactly: enumerate candidate retained-subpath
    sets, merge every non-selected subpath into the preceding selected one
    with upward replication + latency-robustness, cost it against the
    current scheme, filter by storage capacity / load balance, and apply the
    cheapest feasible candidate.

    ``policy`` (str | ``repro.engine.routing.RoutingPolicy``) prices the
    path under that *routed* walk first: when the path's routed latency
    against the current scheme is already within ``t`` — the serving path
    can reach existing replicas the home-first closed form cannot — the
    UPDATE is a free no-op (the policy-aware greedy's skip, oracle form).
    ``load`` is the forecast per-server load profile a ``queue_aware``
    policy ranks holders with (ignored by load-blind policies).
    """
    shard = scheme.shard
    fv = (lambda v: 1.0) if f is None else (lambda v: float(f[v]))
    groups = server_local_subpaths(path, shard)
    h = len(groups) - 1
    if h <= t:
        return UpdateResult(True, 0.0, [], [])
    if policy is not None:
        from repro.engine.routing import resolve_policy  # lazy: no cycle

        pol = resolve_policy(policy)
        if pol.name != "home_first":
            h_rt = int(
                routed_path_latencies_reference(
                    np.asarray([path], np.int32),
                    np.asarray([len(path)], np.int32),
                    scheme.mask,
                    scheme.shard,
                    policy=pol,
                    load=load,
                )[0]
            )
            if h_rt <= t:
                return UpdateResult(True, 0.0, [], [])

    group_server = [int(shard[g[0]]) for g in groups]
    base_load = scheme.storage_per_server(f)

    best: tuple[float, list[tuple[int, int]], list[tuple[int, int, int]]] | None = None
    # Pass 1 computes costs; pass 2 (sorted by cost) checks feasibility and
    # stops at the first feasible candidate (paper "Performance
    # optimizations").  We fuse both passes by collecting candidates and
    # sorting, which is equivalent.
    candidates = []
    for subset in itertools.combinations(range(1, h + 1), t):
        delta = {0, *subset}
        added: list[tuple[int, int]] = []
        rm: list[tuple[int, int, int]] = []
        added_set: set[tuple[int, int]] = set()
        cost = 0.0
        for i in range(1, h + 1):
            if i in delta:
                continue
            j = max(x for x in delta if x < i)
            for v in groups[i]:
                for k in range(j, i):
                    s = group_server[k]
                    if scheme.mask[v, s] or (v, s) in added_set:
                        continue
                    added_set.add((v, s))
                    added.append((v, s))
                    # the representative u for the resharding map (§5.4):
                    # first original object of subpath k hosted at s.
                    rm.append((groups[k][0], v, s))
                    cost += fv(v)
        candidates.append((cost, added, rm))

    for cost, added, rm in sorted(candidates, key=lambda c: c[0]):
        if capacity is not None or epsilon is not None:
            load = base_load.copy()
            for v, s in added:
                load[s] += fv(v)
            if capacity is not None:
                cap = np.broadcast_to(
                    np.asarray(capacity, dtype=np.float64), load.shape
                )
                if np.any(load > cap + 1e-9):
                    continue
            if epsilon is not None:
                mean = load.mean()
                if mean > 0 and load.max() > (1.0 + epsilon) * mean + 1e-9:
                    continue
        if apply and added:
            vs = np.asarray([a[0] for a in added])
            ss = np.asarray([a[1] for a in added])
            scheme.add(vs, ss)
        return UpdateResult(True, cost, added, rm)

    return UpdateResult(False, float("inf"), [], [])


def replicate_workload_exact(
    pathset: PathSet,
    shard: np.ndarray,
    n_servers: int,
    t: int,
    f: np.ndarray | None = None,
    capacity: np.ndarray | float | None = None,
    epsilon: float | None = None,
    prune: bool = True,
    policy=None,
    load: np.ndarray | None = None,
) -> tuple[ReplicationScheme, dict]:
    """Alg 1 with the exact UPDATE; returns (scheme, stats).

    ``policy`` makes every UPDATE price its path under the routed walk
    first (see :func:`update_exact`) — the sequential oracle of
    ``repro.core.greedy.replicate_workload(policy=...)``.  Because the
    receding-horizon walks are not strictly monotone under foreign
    replica additions, a skipped path can regress by the end of the
    sweep; like the batched driver, bounded re-validation sweeps re-run
    UPDATE on any path the routed walk no longer serves.
    """
    if policy is not None:
        from repro.engine.routing import resolve_policy  # lazy: no cycle

        pol = resolve_policy(policy)
        policy = None if pol.name == "home_first" else pol
    ps = pathset.prune_redundant(shard) if prune else pathset
    scheme = ReplicationScheme.from_sharding(shard, n_servers)
    total_cost = 0.0
    failed = 0
    rm: list[tuple[int, int, int]] = []

    def sweep(indices) -> list[int]:
        nonlocal total_cost, failed
        for i in indices:
            res = update_exact(
                scheme, ps.path(int(i)), t, f, capacity, epsilon,
                policy=policy, load=load,
            )
            if res.feasible:
                total_cost += res.cost
                rm.extend(res.rm_entries)
            else:
                failed += 1
        if policy is None:
            return []
        h_rt = routed_path_latencies_reference(
            np.asarray(ps.objects), np.asarray(ps.lengths),
            scheme.mask, scheme.shard, policy=policy, load=load,
        )
        return np.nonzero(h_rt > t)[0].tolist()

    viol = sweep(range(ps.n_paths))
    if policy is not None:
        from repro.core.greedy import _POLICY_REVALIDATE  # lazy: no cycle

        for _ in range(_POLICY_REVALIDATE):
            if not viol:
                break
            viol = sweep(viol)
    stats = {
        "total_cost": total_cost,
        "failed_paths": failed,
        "replicas": scheme.replica_count(),
        "paths_processed": ps.n_paths,
        "rm": rm,
        # paths still over budget under the routed policy after the
        # bounded revalidation sweeps (0 whenever policy is None)
        "routed_violations": len(viol),
    }
    return scheme, stats
