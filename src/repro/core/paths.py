"""Causal access paths (paper §3.1, Def 4.1).

A *causal access path* is a sequence of object ids whose accesses are
causally ordered (each access happens-before the next).  A *query* is a set
of root-to-leaf paths; its latency is the max latency over its paths
(Def 4.3).  We store a whole workload's paths as one padded int32 matrix so
that latency evaluation and the greedy replication algorithm are plain
vectorized array programs.

Layout
------
``objects``   int32 [n_paths, max_len]   object ids, ``-1`` padding
``lengths``   int32 [n_paths]            number of valid entries per row
``query_ids`` int32 [n_paths]            owning query (for per-query latency)

All builders are host-side (numpy); the arrays are then used from JAX.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np

PAD = -1


@dataclasses.dataclass(frozen=True)
class PathSet:
    """A padded batch of causal access paths."""

    objects: np.ndarray   # int32 [P, L]
    lengths: np.ndarray   # int32 [P]
    query_ids: np.ndarray  # int32 [P]

    def __post_init__(self):
        assert self.objects.ndim == 2
        assert self.lengths.shape == (self.objects.shape[0],)
        assert self.query_ids.shape == (self.objects.shape[0],)

    @property
    def n_paths(self) -> int:
        return int(self.objects.shape[0])

    @property
    def max_len(self) -> int:
        return int(self.objects.shape[1])

    @property
    def n_queries(self) -> int:
        return int(self.query_ids.max()) + 1 if self.n_paths else 0

    def __len__(self) -> int:
        return self.n_paths

    def path(self, i: int) -> list[int]:
        return self.objects[i, : self.lengths[i]].tolist()

    def select(self, idx: np.ndarray) -> "PathSet":
        return PathSet(self.objects[idx], self.lengths[idx], self.query_ids[idx])

    def select_queries(self, lo: int, hi: int) -> "PathSet":
        """Paths of queries with id in [lo, hi), query ids rebased to 0.

        The serving layer uses this to feed a workload to the simulator /
        controller in arrival-order batches.
        """
        keep = (self.query_ids >= lo) & (self.query_ids < hi)
        idx = np.nonzero(keep)[0]
        return PathSet(
            self.objects[idx],
            self.lengths[idx],
            (self.query_ids[idx] - lo).astype(np.int32),
        )

    def max_objects_touched(self) -> int:
        return int(self.objects.max()) + 1

    @staticmethod
    def from_lists(
        paths: Sequence[Sequence[int]],
        query_ids: Sequence[int] | None = None,
        max_len: int | None = None,
    ) -> "PathSet":
        """Build a PathSet from python lists of object-id sequences."""
        n = len(paths)
        lengths = np.asarray([len(p) for p in paths], dtype=np.int32)
        L = int(max_len if max_len is not None else (lengths.max() if n else 1))
        L = max(L, 1)
        objects = np.full((n, L), PAD, dtype=np.int32)
        for i, p in enumerate(paths):
            objects[i, : len(p)] = np.asarray(p, dtype=np.int32)
        if query_ids is None:
            qids = np.arange(n, dtype=np.int32)
        else:
            qids = np.asarray(query_ids, dtype=np.int32)
        return PathSet(objects, lengths, qids)

    @staticmethod
    def concatenate(sets: Iterable["PathSet"]) -> "PathSet":
        sets = list(sets)
        L = max(s.max_len for s in sets)
        objs, lens, qids = [], [], []
        qoff = 0
        for s in sets:
            o = np.full((s.n_paths, L), PAD, dtype=np.int32)
            o[:, : s.max_len] = s.objects
            objs.append(o)
            lens.append(s.lengths)
            qids.append(s.query_ids + qoff)
            qoff += s.n_queries
        return PathSet(
            np.concatenate(objs, 0),
            np.concatenate(lens, 0),
            np.concatenate(qids, 0),
        )

    # ------------------------------------------------------------------
    # §5.3 pruning: "If two paths have root accesses occurring at the same
    # server and are identical except from their root, then any replication
    # scheme that is feasible for one path is feasible also for the other".
    # ------------------------------------------------------------------
    def prune_redundant(
        self,
        shard: np.ndarray,
        extra_key: np.ndarray | None = None,
        return_index: bool = False,
    ):
        """Drop paths equivalent under the paper's §5.3 pruning rule.

        ``shard`` is the sharding function d as an int array [n_objects].
        Two paths are redundant iff the server of the root matches and the
        tails (``objects[1:]``) are identical.  NOTE: pruning is sound for
        *feasibility*; we keep query_ids of survivors for latency reporting.

        ``extra_key`` (int [n_paths]) joins the dedup key: paths that only
        differ in it are NOT merged.  The vector-t greedy passes each
        path's latency budget here — merging a tight-budget path into a
        loose-budget duplicate would silently drop the tighter constraint.
        A constant ``extra_key`` (the scalar-t case) prunes identically to
        no key at all.  ``return_index=True`` additionally returns the
        surviving row indices (for slicing per-path side arrays).
        """
        if self.n_paths == 0:
            idx0 = np.zeros(0, np.int64)
            return (self, idx0) if return_index else self
        root_srv = shard[np.maximum(self.objects[:, 0], 0)].astype(np.int64)
        # Build a dedup key: root server + tail bytes.
        tails = self.objects[:, 1:].copy()
        cols = [root_srv[:, None], self.lengths[:, None].astype(np.int64), tails]
        if extra_key is not None:
            cols.append(np.asarray(extra_key, np.int64)[:, None])
        key = np.concatenate(cols, axis=1)
        _, first_idx = np.unique(key, axis=0, return_index=True)
        first_idx = np.sort(first_idx)
        pruned = self.select(first_idx)
        return (pruned, first_idx) if return_index else pruned

    def pad_to(self, n_paths: int | None = None, max_len: int | None = None) -> "PathSet":
        """Pad path count / length (padding paths have length 0)."""
        P = n_paths if n_paths is not None else self.n_paths
        L = max_len if max_len is not None else self.max_len
        objects = np.full((P, L), PAD, dtype=np.int32)
        objects[: self.n_paths, : self.max_len] = self.objects
        lengths = np.zeros((P,), dtype=np.int32)
        lengths[: self.n_paths] = self.lengths
        qids = np.zeros((P,), dtype=np.int32)
        qids[: self.n_paths] = self.query_ids
        return PathSet(objects, lengths, qids)


def paths_from_tree(root: int, adjacency: dict[int, list[int]], max_depth: int) -> list[list[int]]:
    """Enumerate root-to-leaf paths of a (small) access tree — test helper."""
    out: list[list[int]] = []

    def rec(node: int, prefix: list[int], depth: int):
        children = adjacency.get(node, []) if depth < max_depth else []
        if not children:
            out.append(prefix + [node])
            return
        for c in children:
            rec(c, prefix + [node], depth + 1)

    rec(root, [], 0)
    return out
