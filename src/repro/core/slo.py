"""Per-query / per-tenant latency constraints t_Q (paper Def 4.4).

The paper's feasibility definition is *per query*: a replication scheme is
feasible when every query Q finishes within **its own** latency constraint
t_Q.  The implementation historically collapsed that vector to one scalar
``t``; :class:`SLOSpec` restores the general form — a per-query budget
vector plus a query->tenant map — with scalar broadcast as the degenerate
case (``SLOSpec.uniform(t, nq)`` behaves bit-identically to ``t``).

A *tenant* is a query family sharing one SLO (a workload analyzer, a
product surface, a customer): the serve layer monitors feasibility and
wall-clock p99 per tenant and arbitrates between tenants when their
repairs compete for the same capacity headroom.

This module depends only on numpy so every layer (core, engine, serve,
workload) can import it without cycles.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant's serving contract.

    Attributes:
      name: stable tenant identifier (query family / customer).
      t_q: default latency budget in distributed traversals (Def 4.4).
      p99_slo_us: optional wall-clock p99 SLO for the serve-layer monitor.
      weight: priority weight for the controller's capacity arbitration —
        a triggered tenant's repair is ranked by *weighted*
        bytes-per-violation (estimated bytes / weight), so a weight-10
        tenant wins a contended round against an equal-cost weight-1
        tenant.  Arbitration aging still outranks any weight (a deferred
        tenant wins the next contended round), so low-weight tenants
        cannot starve.  Must be > 0.
    """

    name: str
    t_q: int
    p99_slo_us: float | None = None
    weight: float = 1.0

    def __post_init__(self):
        if not self.weight > 0:
            raise ValueError("tenant weight must be > 0")


@dataclasses.dataclass(frozen=True)
class SLOSpec:
    """Vector latency constraints: per-query budgets + query->tenant map.

    Attributes:
      t_q: int32 [n_queries] — latency budget per query (traversals).
      tenant_of: int32 [n_queries] — index into ``tenants`` per query.
      tenants: the tenant table (index = tenant id).
    """

    t_q: np.ndarray
    tenant_of: np.ndarray
    tenants: tuple[TenantSpec, ...]

    def __post_init__(self):
        object.__setattr__(self, "t_q", np.asarray(self.t_q, np.int32))
        object.__setattr__(
            self, "tenant_of", np.asarray(self.tenant_of, np.int32)
        )
        assert self.t_q.ndim == 1
        assert self.tenant_of.shape == self.t_q.shape
        assert np.all(self.t_q >= 0), "latency budgets must be >= 0"
        if len(self.t_q):
            assert int(self.tenant_of.max()) < len(self.tenants)

    # -- constructors ------------------------------------------------------
    @classmethod
    def uniform(
        cls,
        t: int,
        n_queries: int,
        tenant: str = "default",
        p99_slo_us: float | None = None,
    ) -> "SLOSpec":
        """Scalar broadcast: every query gets budget ``t`` (degenerate case)."""
        return cls(
            t_q=np.full(n_queries, int(t), np.int32),
            tenant_of=np.zeros(n_queries, np.int32),
            tenants=(TenantSpec(tenant, int(t), p99_slo_us),),
        )

    @classmethod
    def from_tenants(
        cls, tenants: Sequence[TenantSpec], tenant_of: np.ndarray
    ) -> "SLOSpec":
        """Budgets from each query's tenant default (``tenant_of`` ids)."""
        tenant_of = np.asarray(tenant_of, np.int32)
        defaults = np.asarray([ts.t_q for ts in tenants], np.int32)
        return cls(
            t_q=defaults[tenant_of],
            tenant_of=tenant_of,
            tenants=tuple(tenants),
        )

    @staticmethod
    def concat(specs: Iterable["SLOSpec"]) -> "SLOSpec":
        """Concatenate specs in query order (mirrors PathSet.concatenate).

        Tenant tables are merged by name (first occurrence wins) so two
        sections of the same tenant share one id.
        """
        specs = list(specs)
        table: list[TenantSpec] = []
        index: dict[str, int] = {}
        t_q, tenant_of = [], []
        for sp in specs:
            remap = np.zeros(max(len(sp.tenants), 1), np.int32)
            for i, ts in enumerate(sp.tenants):
                if ts.name not in index:
                    index[ts.name] = len(table)
                    table.append(ts)
                remap[i] = index[ts.name]
            t_q.append(sp.t_q)
            tenant_of.append(remap[sp.tenant_of])
        return SLOSpec(
            t_q=np.concatenate(t_q) if t_q else np.zeros(0, np.int32),
            tenant_of=(
                np.concatenate(tenant_of)
                if tenant_of
                else np.zeros(0, np.int32)
            ),
            tenants=tuple(table),
        )

    # -- views -------------------------------------------------------------
    @property
    def n_queries(self) -> int:
        return int(self.t_q.shape[0])

    @property
    def is_uniform(self) -> bool:
        """True when every query shares one budget (the scalar case)."""
        return len(self.t_q) == 0 or bool(
            np.all(self.t_q == self.t_q[0])
        )

    def scalar(self) -> int:
        """The single budget of a uniform spec (errors otherwise)."""
        if not self.is_uniform:
            raise ValueError("SLOSpec is not uniform; no scalar t exists")
        return int(self.t_q[0]) if len(self.t_q) else 0

    def max_t(self) -> int:
        return int(self.t_q.max()) if len(self.t_q) else 0

    def path_budgets(self, pathset) -> np.ndarray:
        """Per-path budgets: each path inherits its owning query's t_Q."""
        qids = np.asarray(pathset.query_ids)
        assert self.n_queries >= (int(qids.max()) + 1 if len(qids) else 0), (
            "SLOSpec covers fewer queries than the pathset references"
        )
        return self.t_q[qids]

    def select_queries(self, lo: int, hi: int) -> "SLOSpec":
        """Spec slice for queries [lo, hi) (PathSet.select_queries twin).

        NOTE the twin is not exact when trailing queries of the range have
        zero paths: ``PathSet.select_queries`` reports ``max(qid) + 1``
        queries while this slice keeps ``hi - lo`` budgets.  Re-align with
        :meth:`align_to` before pairing the two (``PathSet.concatenate``
        offsets by the *pathset's* count, so a misaligned pair would shift
        every later section's budgets).
        """
        return SLOSpec(self.t_q[lo:hi], self.tenant_of[lo:hi], self.tenants)

    def align_to(self, pathset) -> "SLOSpec":
        """Truncate to ``pathset.n_queries`` (drops trailing budgets of
        queries that contributed no paths; errors if the spec is short)."""
        nq = pathset.n_queries
        if self.n_queries < nq:
            raise ValueError(
                f"SLOSpec covers {self.n_queries} queries, pathset has {nq}"
            )
        if self.n_queries == nq:
            return self
        return SLOSpec(self.t_q[:nq], self.tenant_of[:nq], self.tenants)

    def tenant_id(self, name: str) -> int:
        for i, ts in enumerate(self.tenants):
            if ts.name == name:
                return i
        raise KeyError(name)

    def tenant_queries(self, name: str) -> np.ndarray:
        """Query ids belonging to ``name``."""
        return np.nonzero(self.tenant_of == self.tenant_id(name))[0]


def normalize_query_budgets(t, n_queries: int) -> np.ndarray:
    """int | per-query array | SLOSpec -> int32 [n_queries] budget vector."""
    if isinstance(t, SLOSpec):
        assert t.n_queries == n_queries, (
            f"SLOSpec covers {t.n_queries} queries, workload has {n_queries}"
        )
        return t.t_q
    arr = np.asarray(t)
    if arr.ndim == 0:
        return np.full(n_queries, int(arr), np.int32)
    assert arr.shape == (n_queries,), (
        f"budget vector shape {arr.shape} != ({n_queries},)"
    )
    return arr.astype(np.int32)


def normalize_path_budgets(t, pathset) -> np.ndarray:
    """int | per-query array | SLOSpec -> int32 [n_paths] per-path budgets."""
    if isinstance(t, SLOSpec):
        return t.path_budgets(pathset)
    arr = np.asarray(t)
    if arr.ndim == 0:
        return np.full(pathset.n_paths, int(arr), np.int32)
    qids = np.asarray(pathset.query_ids)
    assert arr.shape == (pathset.n_queries,), (
        f"budget vector shape {arr.shape} != ({pathset.n_queries},)"
    )
    return arr.astype(np.int32)[qids]
