"""NP-hardness gadget (paper Thm 4.5 / Appendix A.1).

The reduction builds, from a graph G with 2n vertices, an instance LS(G) of
the latency-storage feasible problem such that LS(G) is feasible iff G has
a *min-bridge bisection* with at most K bridge vertices per side.  We
implement the construction so tests can verify the equivalence by brute
force on small 3-regular graphs — executable evidence for the paper's
hardness proof.

Construction (Appendix A.1, step 1):
  * objects: for each vertex v of G, a marker object v_m (cost 1) and a
    regular object v_o (cost 1/(2n));
  * queries:  for each v, paths  v_m -> v_o -> u_o  for every u in N(v)
    (and the bare path v_m -> v_o when N(v) is empty);
  * servers:  s1, s2 hold the markers (half each); s1 holds the regular
    objects whose markers are on s2 and vice versa (so marker and regular
    copies of the same vertex always start on different servers);
  * capacities: M_{s1} = M_{s2} = n + 1/2 (already full),
    M_{s3} = M_{s4} = n + 1/2 + K/(2n);
  * latency bound t = 0 for all queries; epsilon = +inf.
"""
from __future__ import annotations

import dataclasses
import itertools

import numpy as np

from repro.core.paths import PathSet
from repro.core.replication import ReplicationScheme, path_latencies


@dataclasses.dataclass(frozen=True)
class LSInstance:
    """A latency-storage feasibility instance produced by the reduction."""

    pathset: PathSet
    shard: np.ndarray          # d
    f: np.ndarray              # storage cost function
    capacity: np.ndarray       # M_s per server
    n_servers: int
    t: int
    # bookkeeping for tests
    marker_of: np.ndarray      # vertex -> marker object id
    regular_of: np.ndarray     # vertex -> regular object id


def build_ls_instance(adjacency: list[list[int]], K: int) -> LSInstance:
    """Build LS(G) for a graph given as adjacency lists over 2n vertices."""
    n2 = len(adjacency)
    assert n2 % 2 == 0, "G must have an even number of vertices"
    n = n2 // 2
    marker_of = np.arange(n2, dtype=np.int32)            # objects 0..2n-1
    regular_of = np.arange(n2, 2 * n2, dtype=np.int32)   # objects 2n..4n-1

    f = np.concatenate(
        [np.ones((n2,), np.float64), np.full((n2,), 1.0 / n2, np.float64)]
    )

    # Sharding: markers of first half -> s0; second half -> s1.
    # Regular objects go to the *opposite* marker server.
    shard = np.zeros((2 * n2,), dtype=np.int32)
    shard[marker_of[:n]] = 0
    shard[marker_of[n:]] = 1
    shard[regular_of[:n]] = 1
    shard[regular_of[n:]] = 0

    paths: list[list[int]] = []
    qids: list[int] = []
    for v in range(n2):
        nbrs = adjacency[v]
        if not nbrs:
            paths.append([int(marker_of[v]), int(regular_of[v])])
            qids.append(v)
        for u in nbrs:
            paths.append(
                [int(marker_of[v]), int(regular_of[v]), int(regular_of[u])]
            )
            qids.append(v)

    capacity = np.asarray(
        [n + 0.5, n + 0.5, n + 0.5 + K / n2, n + 0.5 + K / n2], np.float64
    )
    return LSInstance(
        pathset=PathSet.from_lists(paths, qids),
        shard=shard,
        f=f,
        capacity=capacity,
        n_servers=4,
        t=0,
        marker_of=marker_of,
        regular_of=regular_of,
    )


def scheme_from_bisection(
    inst: LSInstance, adjacency: list[list[int]], side: np.ndarray
) -> ReplicationScheme:
    """The feasible scheme from a bisection (Appendix A.1, 'if' direction).

    ``side[v]`` in {0, 1}: vertices with side 0 replicate to s3, side 1 to
    s4.  Markers + regular objects of each side move to its server; regular
    objects of *neighbors* too; bridge vertices' regular objects are
    replicated on both sides.
    """
    scheme = ReplicationScheme.from_sharding(inst.shard, inst.n_servers)
    for v in range(len(adjacency)):
        s = 2 + int(side[v])
        scheme.mask[inst.marker_of[v], s] = True
        scheme.mask[inst.regular_of[v], s] = True
        for u in adjacency[v]:
            scheme.mask[inst.regular_of[u], s] = True
    return scheme


def is_feasible_ls(inst: LSInstance, scheme: ReplicationScheme) -> bool:
    """Latency bound t=0 on all queries + storage capacities respected.

    Queries are routed to the server of their (replicated) marker: the
    reduction argues markers must be replicated to s3/s4 and queries start
    there.  We check feasibility the way the definition does: the latency
    under the access function must be 0 for every path, where the root is
    routed to any server holding a copy of the root marker (best case).
    """
    # Best-case routing: for each path, try every server holding the root.
    objs = inst.pathset.objects
    lens = inst.pathset.lengths
    for i in range(inst.pathset.n_paths):
        path = objs[i, : lens[i]].tolist()
        root = path[0]
        ok = False
        for s in np.nonzero(scheme.mask[root])[0]:
            server, cost = int(s), 0
            for v in path[1:]:
                if not scheme.mask[v, server]:
                    server = int(inst.shard[v])
                    cost += 1
            if cost <= inst.t:
                ok = True
                break
        if not ok:
            return False
    load = scheme.storage_per_server(inst.f)
    return bool(np.all(load <= inst.capacity + 1e-9))


def brute_force_min_bridge_bisection(adjacency: list[list[int]]) -> int:
    """Min over bisections of the max #bridge vertices per side (small G)."""
    n2 = len(adjacency)
    n = n2 // 2
    best = n2
    for half in itertools.combinations(range(n2), n):
        side = np.ones((n2,), np.int8)
        side[list(half)] = 0
        bridges = [0, 0]
        for v in range(n2):
            if any(side[u] != side[v] for u in adjacency[v]):
                bridges[side[v]] += 1
        best = min(best, max(bridges))
    return best


def brute_force_feasible(inst: LSInstance, adjacency: list[list[int]]) -> bool:
    """Existence of a feasible scheme, via the bisection characterization."""
    n2 = len(adjacency)
    K_budget = round((inst.capacity[2] - (n2 / 2 + 0.5)) * n2)
    return brute_force_min_bridge_bisection(adjacency) <= K_budget
