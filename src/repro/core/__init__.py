"""The paper's primary contribution: latency-bound replication.

Public API:
  PathSet                     — causal access paths (padded batches)
  ReplicationScheme           — replication scheme r with storage accounting
  SLOSpec / TenantSpec        — per-query / per-tenant latency constraints
        t_Q (Def 4.4's vector form; scalar broadcast is the degenerate
        case) — accepted by the greedy drivers, the engine's feasibility
        path, and the serve-layer controller
  path_latencies / query_latencies / query_slacks / is_latency_feasible
        — Eqns 1-3,
        thin wrappers over the unified ``repro.engine.LatencyEngine``
        (backend-dispatched: reference | jnp | pallas; device-resident
        packed bitmask)
  replicate_workload          — vectorized greedy Alg 1 + Alg 2 (the UPDATE
        loop bit-tests and scatter-ORs the engine's packed device state);
        ``resilience=KResilient(k=...)`` adds the k-resilience gate
        (feasible under the loss of any k fault domains, repaired via
        batched masked re-walks under rotation-failover homes)
  replicate_workload_exact    — faithful sequential Alg 1 + Alg 2
  single_site_oracle          — Fig 2d baseline
  dangling_edge_replication   — Table 3 baseline
  evaluate_baseline           — engine-backed baseline metrics
  ReshardingMap / apply_reshard / drain_server — §5.4 incremental updates
  build_ls_instance           — Thm 4.5 hardness gadget
"""
from repro.core.paths import PathSet, paths_from_tree
from repro.core.replication import (
    ReplicationScheme,
    is_latency_feasible,
    path_latencies,
    path_latency_reference,
    prune_scheme_replicas,
    query_latencies,
    query_slacks,
    subpath_structure,
)
from repro.core.slo import SLOSpec, TenantSpec
from repro.engine.resilience import KResilient
from repro.core.greedy import (
    GreedyStats,
    replicate_delta,
    replicate_stream,
    replicate_workload,
)
from repro.core.reference import (
    path_latencies_reference,
    replicate_workload_exact,
    server_local_subpaths,
    update_exact,
)
from repro.core.baselines import (
    dangling_edge_replication,
    evaluate_baseline,
    single_site_oracle,
)
from repro.core.reshard import (
    ReshardingMap,
    ReshardReport,
    apply_reshard,
    drain_server,
    repair_paths,
)
from repro.core.hardness import (
    LSInstance,
    brute_force_feasible,
    brute_force_min_bridge_bisection,
    build_ls_instance,
    is_feasible_ls,
    scheme_from_bisection,
)

__all__ = [
    "PathSet",
    "paths_from_tree",
    "ReplicationScheme",
    "SLOSpec",
    "TenantSpec",
    "is_latency_feasible",
    "path_latencies",
    "path_latency_reference",
    "query_latencies",
    "query_slacks",
    "prune_scheme_replicas",
    "subpath_structure",
    "GreedyStats",
    "KResilient",
    "replicate_delta",
    "replicate_stream",
    "replicate_workload",
    "replicate_workload_exact",
    "path_latencies_reference",
    "server_local_subpaths",
    "update_exact",
    "dangling_edge_replication",
    "evaluate_baseline",
    "single_site_oracle",
    "ReshardingMap",
    "ReshardReport",
    "apply_reshard",
    "drain_server",
    "repair_paths",
    "LSInstance",
    "brute_force_feasible",
    "brute_force_min_bridge_bisection",
    "build_ls_instance",
    "is_feasible_ls",
    "scheme_from_bisection",
]
