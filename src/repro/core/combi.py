"""Candidate-set enumeration tables for Alg 2 (paper §5.3).

Alg 2 enumerates all C(h, t) subsets of the h non-root server-local
subpaths of which t are *retained*; subpath 0 is always retained (the first
access is routed by the sharding function).  For vectorization we precompute,
for every h in [0, H], the candidate selection table as a boolean matrix and
stack them padded to the max candidate count.  Low-latency queries have short
paths, so C(h, t) stays small (paper: "relatively small for low-latency
queries"); longer paths fall back to the exact sequential implementation.
"""
from __future__ import annotations

import functools
import itertools
import math

import numpy as np


@functools.lru_cache(maxsize=None)
def comb_table(h: int, t: int) -> np.ndarray:
    """Selection table bool [C, h+1]; column 0 (root subpath) always True.

    For h <= t there is a single all-selected candidate (no replication
    needed; Alg 2 line 4 gate).  For h > t, rows enumerate the subsets of
    {1..h} of size t (Alg 2 line 5), each augmented with subpath 0.
    """
    if h <= t:
        return np.ones((1, h + 1), dtype=bool)
    rows = []
    for subset in itertools.combinations(range(1, h + 1), t):
        sel = np.zeros((h + 1,), dtype=bool)
        sel[0] = True
        sel[list(subset)] = True
        rows.append(sel)
    return np.stack(rows, axis=0)


@functools.lru_cache(maxsize=None)
def stacked_tables(H: int, t: int) -> tuple[np.ndarray, np.ndarray]:
    """Stack comb_table(h, t) for h = 0..H.

    Returns:
      tables: bool [H+1, C_max, H+1]; invalid candidate rows are all-True
        (all-selected => no additions => they are also harmless if selected,
        but they are additionally masked out by ``counts``).
      counts: int32 [H+1]; number of valid candidates for each h.
    """
    per_h = [comb_table(h, t) for h in range(H + 1)]
    c_max = max(tbl.shape[0] for tbl in per_h)
    tables = np.ones((H + 1, c_max, H + 1), dtype=bool)
    counts = np.zeros((H + 1,), dtype=np.int32)
    for h, tbl in enumerate(per_h):
        c = tbl.shape[0]
        tables[h, :c, : h + 1] = tbl
        # pad selection over subpaths > h with True (inert)
        counts[h] = c
    return tables, counts


def iter_comb_rows(h: int, t: int, chunk_rows: int):
    """Yield :func:`comb_table`'s rows in bounded chunks, lazily.

    Same rows in the same order as ``comb_table(h, t)``, but the host only
    ever materializes ``chunk_rows`` of them at once — the streamed table
    construction for deep-path provisioning, where C(h, t) alone would
    dwarf the per-chunk path residency :func:`~repro.core.greedy.replicate_stream`
    otherwise bounds.  The combinations iterator is consumed on demand, so
    producing chunk ``i + 1`` only starts after chunk ``i`` is handed off
    (and, on device, scattered into the padded table and droppable).
    """
    if chunk_rows < 1:
        raise ValueError("chunk_rows must be >= 1")
    if h <= t:
        yield np.ones((1, h + 1), dtype=bool)
        return
    it = itertools.combinations(range(1, h + 1), t)
    while True:
        block = list(itertools.islice(it, chunk_rows))
        if not block:
            return
        chunk = np.zeros((len(block), h + 1), dtype=bool)
        chunk[:, 0] = True
        for r, subset in enumerate(block):
            chunk[r, list(subset)] = True
        yield chunk


def n_candidates(h: int, t: int) -> int:
    if h <= t:
        return 1
    return math.comb(h, t)


def max_h_within_budget(t: int, max_candidates: int, h_needed: int) -> int:
    """Largest H <= h_needed with C(H, t) <= max_candidates."""
    H = 0
    for h in range(h_needed + 1):
        if n_candidates(h, t) <= max_candidates:
            H = h
        else:
            break
    return H
