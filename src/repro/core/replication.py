"""Replication schemes and the latency/access function (paper §4).

A replication scheme ``r`` maps each object to the set of servers holding a
copy; the original copy placed by the sharding function ``d`` is always
included.  We represent ``r`` as a boolean matrix ``[n_objects, n_servers]``
(uint8 on host, bool in JAX).  Monotone 0->1 updates mirror the paper's
lock-free bit-vector implementation (§6.1); batched scatter-ORs are the
SIMD analogue of their 64-thread races, justified by Thm 5.3.

The *access function* rho (Eqn 1) and the path latency h(p, r, rho)
(Eqn 2) are evaluated with a vectorized ``lax.scan`` along the path axis;
``repro.kernels.path_latency`` provides the Pallas TPU kernel for the same
computation (this module is its jnp oracle).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.paths import PAD, PathSet


@dataclasses.dataclass
class ReplicationScheme:
    """Boolean replication matrix with storage accounting.

    Attributes:
      mask: bool [n_objects, n_servers]; ``mask[v, s]`` == object v has a copy
        at server s.  Always a superset of the sharding function.
      shard: int32 [n_objects]; the sharding function d (home server).
    """

    mask: np.ndarray
    shard: np.ndarray

    @staticmethod
    def from_sharding(shard: np.ndarray, n_servers: int) -> "ReplicationScheme":
        n = shard.shape[0]
        mask = np.zeros((n, n_servers), dtype=bool)
        mask[np.arange(n), shard] = True
        return ReplicationScheme(mask, shard.astype(np.int32))

    @property
    def n_objects(self) -> int:
        return self.mask.shape[0]

    @property
    def n_servers(self) -> int:
        return self.mask.shape[1]

    def copy(self) -> "ReplicationScheme":
        return ReplicationScheme(self.mask.copy(), self.shard)

    def add(self, objects: np.ndarray, servers: np.ndarray) -> None:
        """Monotone in-place addition of replicas (0->1 flips only)."""
        self.mask[objects, servers] = True

    def replica_count(self) -> int:
        """Number of *replica* copies (total copies minus originals)."""
        return int(self.mask.sum()) - self.n_objects

    def storage_per_server(self, f: np.ndarray | None = None) -> np.ndarray:
        """f_r(s) = sum of f(v) over v with s in r(v) (paper notation)."""
        if f is None:
            return self.mask.sum(axis=0).astype(np.float64)
        return f.astype(np.float64) @ self.mask

    def replication_overhead(self, f: np.ndarray | None = None) -> float:
        """Replicated bytes / original bytes (the paper's Fig 2d/6 metric)."""
        if f is None:
            total = float(self.mask.sum())
            orig = float(self.n_objects)
        else:
            total = float(self.storage_per_server(f).sum())
            orig = float(f.sum())
        return (total - orig) / orig

    def is_feasible(
        self,
        f: np.ndarray | None = None,
        capacity: np.ndarray | float | None = None,
        epsilon: float | None = None,
    ) -> bool:
        """Check storage capacity M_s and the eps load-imbalance constraint."""
        cost = self.storage_per_server(f)
        if capacity is not None:
            cap = np.broadcast_to(np.asarray(capacity, dtype=np.float64), cost.shape)
            if np.any(cost > cap + 1e-9):
                return False
        if epsilon is not None:
            mean = cost.mean()
            if mean > 0 and cost.max() > (1.0 + epsilon) * mean + 1e-9:
                return False
        return True

    def pack(self) -> np.ndarray:
        """Pack to uint32 bit-words [n_objects, ceil(S/32)] (kernel input)."""
        S = self.n_servers
        W = (S + 31) // 32
        padded = np.zeros((self.n_objects, W * 32), dtype=bool)
        padded[:, :S] = self.mask
        bits = padded.reshape(self.n_objects, W, 32).astype(np.uint32)
        weights = (np.uint32(1) << np.arange(32, dtype=np.uint32))[None, None, :]
        return (bits * weights).sum(axis=2).astype(np.uint32)


# ---------------------------------------------------------------------------
# Subpath decomposition (Def 5.1) under the *sharding* function d.
# Alg 2 line 2 enumerates server-local subpaths of p under d (no replicas).
# ---------------------------------------------------------------------------
def subpath_structure(objects: jnp.ndarray, lengths: jnp.ndarray, shard: jnp.ndarray):
    """Segment each path into server-local subpaths under d.

    Args:
      objects: int32 [P, L] padded paths.
      lengths: int32 [P].
      shard:   int32 [n_objects] sharding function.

    Returns:
      home: int32 [P, L]  home server per position (PAD positions -> -1)
      seg:  int32 [P, L]  subpath index per position (0-based)
      h:    int32 [P]     number of distributed traversals under d
                          (= #subpaths - 1)
    """
    P, L = objects.shape
    valid = jnp.arange(L)[None, :] < lengths[:, None]
    safe = jnp.maximum(objects, 0)
    home = jnp.where(valid, shard[safe], -1).astype(jnp.int32)
    prev = jnp.concatenate([jnp.full((P, 1), -2, jnp.int32), home[:, :-1]], axis=1)
    boundary = valid & (jnp.arange(L)[None, :] > 0) & (home != prev)
    seg = jnp.cumsum(boundary.astype(jnp.int32), axis=1)
    seg = jnp.where(valid, seg, -1)
    last = jnp.maximum(lengths - 1, 0)
    h = jnp.take_along_axis(seg, last[:, None], axis=1)[:, 0]
    h = jnp.where(lengths > 0, h, 0)
    return home, seg, h


# ---------------------------------------------------------------------------
# Latency of paths under a replication scheme (Eqns 1-3).
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=())
def _path_latencies_jit(objects, lengths, mask, shard):
    P, L = objects.shape
    valid = jnp.arange(L)[None, :] < lengths[:, None]
    safe = jnp.maximum(objects, 0)
    home = jnp.where(valid, shard[safe], 0).astype(jnp.int32)
    # replica membership rows per position: [P, L, S]
    rloc = mask[safe]

    def step(server, xs):
        home_t, rloc_t, valid_t = xs
        # is a copy of v available at the current server? (Eqn 1)
        local = jnp.take_along_axis(rloc_t, server[:, None], axis=1)[:, 0]
        nxt = jnp.where(local, server, home_t)
        cost = (~local) & valid_t
        nxt = jnp.where(valid_t, nxt, server)
        return nxt, cost

    server0 = home[:, 0]
    xs = (
        jnp.moveaxis(home[:, 1:], 1, 0),
        jnp.moveaxis(rloc[:, 1:], 1, 0),
        jnp.moveaxis(valid[:, 1:], 1, 0),
    )
    _, costs = jax.lax.scan(step, server0, xs)
    return jnp.sum(costs.astype(jnp.int32), axis=0)


def path_latencies(
    pathset: PathSet, scheme: ReplicationScheme, chunk: int = 8192
) -> np.ndarray:
    """h(p, r, rho) for every path: #distributed traversals (Def 4.2)."""
    objects = pathset.objects
    lengths = pathset.lengths
    mask = jnp.asarray(scheme.mask)
    shard = jnp.asarray(scheme.shard)
    outs = []
    for i in range(0, pathset.n_paths, chunk):
        o = jnp.asarray(objects[i : i + chunk])
        l = jnp.asarray(lengths[i : i + chunk])
        outs.append(np.asarray(_path_latencies_jit(o, l, mask, shard)))
    if not outs:
        return np.zeros((0,), dtype=np.int32)
    return np.concatenate(outs, axis=0)


def query_latencies(pathset: PathSet, scheme: ReplicationScheme) -> np.ndarray:
    """l_Q = max over the query's paths (Def 4.3); int array [n_queries]."""
    pl = path_latencies(pathset, scheme)
    nq = pathset.n_queries
    out = np.zeros((nq,), dtype=np.int32)
    np.maximum.at(out, pathset.query_ids, pl)
    return out


def path_latency_reference(path: list[int], mask: np.ndarray, shard: np.ndarray) -> int:
    """Pure-python oracle for a single path (used by tests)."""
    if not path:
        return 0
    server = int(shard[path[0]])
    cost = 0
    for v in path[1:]:
        if mask[v, server]:
            continue  # local replica: stay (Eqn 1 first case)
        server = int(shard[v])  # distributed traversal to the original copy
        cost += 1
    return cost


def is_latency_feasible(
    pathset: PathSet, scheme: ReplicationScheme, t: int | np.ndarray
) -> bool:
    """All queries within their latency constraint t_Q (Def 4.4 constraint 1)."""
    lq = query_latencies(pathset, scheme)
    return bool(np.all(lq <= np.asarray(t)))
