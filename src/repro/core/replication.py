"""Replication schemes and the latency/access function (paper §4).

A replication scheme ``r`` maps each object to the set of servers holding a
copy; the original copy placed by the sharding function ``d`` is always
included.  We represent ``r`` as a boolean matrix ``[n_objects, n_servers]``
(uint8 on host, bool in JAX).  Monotone 0->1 updates mirror the paper's
lock-free bit-vector implementation (§6.1); batched scatter-ORs are the
SIMD analogue of their 64-thread races, justified by Thm 5.3.

The *access function* rho (Eqn 1) and the path latency h(p, r, rho)
(Eqn 2) are evaluated by ``repro.engine.LatencyEngine`` — the shared
backend-dispatched core (reference | jnp | pallas) with the packed uint32
bitmask as its device-resident source of truth.  The module-level
functions below are thin conveniences that build a transient engine per
call; stateful consumers (the greedy driver, benchmarks) hold an engine
to keep the scheme device-resident across calls.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.paths import PAD, PathSet
from repro.engine import LatencyEngine, pack_bool_mask


@dataclasses.dataclass
class ReplicationScheme:
    """Boolean replication matrix with storage accounting.

    Attributes:
      mask: bool [n_objects, n_servers]; ``mask[v, s]`` == object v has a copy
        at server s.  Always a superset of the sharding function.
      shard: int32 [n_objects]; the sharding function d (home server).
    """

    mask: np.ndarray
    shard: np.ndarray

    @staticmethod
    def from_sharding(shard: np.ndarray, n_servers: int) -> "ReplicationScheme":
        n = shard.shape[0]
        mask = np.zeros((n, n_servers), dtype=bool)
        mask[np.arange(n), shard] = True
        return ReplicationScheme(mask, shard.astype(np.int32))

    @property
    def n_objects(self) -> int:
        return self.mask.shape[0]

    @property
    def n_servers(self) -> int:
        return self.mask.shape[1]

    def copy(self) -> "ReplicationScheme":
        return ReplicationScheme(self.mask.copy(), self.shard)

    def add(self, objects: np.ndarray, servers: np.ndarray) -> None:
        """Monotone in-place addition of replicas (0->1 flips only)."""
        self.mask[objects, servers] = True

    def replica_count(self) -> int:
        """Number of *replica* copies (total copies minus originals)."""
        return int(self.mask.sum()) - self.n_objects

    def storage_per_server(self, f: np.ndarray | None = None) -> np.ndarray:
        """f_r(s) = sum of f(v) over v with s in r(v) (paper notation)."""
        if f is None:
            return self.mask.sum(axis=0).astype(np.float64)
        return f.astype(np.float64) @ self.mask

    def replication_overhead(self, f: np.ndarray | None = None) -> float:
        """Replicated bytes / original bytes (the paper's Fig 2d/6 metric)."""
        if f is None:
            total = float(self.mask.sum())
            orig = float(self.n_objects)
        else:
            total = float(self.storage_per_server(f).sum())
            orig = float(f.sum())
        return (total - orig) / orig

    def is_feasible(
        self,
        f: np.ndarray | None = None,
        capacity: np.ndarray | float | None = None,
        epsilon: float | None = None,
    ) -> bool:
        """Check storage capacity M_s and the eps load-imbalance constraint."""
        cost = self.storage_per_server(f)
        if capacity is not None:
            cap = np.broadcast_to(np.asarray(capacity, dtype=np.float64), cost.shape)
            if np.any(cost > cap + 1e-9):
                return False
        if epsilon is not None:
            mean = cost.mean()
            if mean > 0 and cost.max() > (1.0 + epsilon) * mean + 1e-9:
                return False
        return True

    def pack(self) -> np.ndarray:
        """Pack to uint32 bit-words [n_objects, ceil(S/32)] (kernel input)."""
        return pack_bool_mask(self.mask)


# ---------------------------------------------------------------------------
# Subpath decomposition (Def 5.1) under the *sharding* function d.
# Alg 2 line 2 enumerates server-local subpaths of p under d (no replicas).
# ---------------------------------------------------------------------------
def subpath_structure(objects: jnp.ndarray, lengths: jnp.ndarray, shard: jnp.ndarray):
    """Segment each path into server-local subpaths under d.

    Args:
      objects: int32 [P, L] padded paths.
      lengths: int32 [P].
      shard:   int32 [n_objects] sharding function.

    Returns:
      home: int32 [P, L]  home server per position (PAD positions -> -1)
      seg:  int32 [P, L]  subpath index per position (0-based)
      h:    int32 [P]     number of distributed traversals under d
                          (= #subpaths - 1)
    """
    P, L = objects.shape
    valid = jnp.arange(L)[None, :] < lengths[:, None]
    safe = jnp.maximum(objects, 0)
    home = jnp.where(valid, shard[safe], -1).astype(jnp.int32)
    prev = jnp.concatenate([jnp.full((P, 1), -2, jnp.int32), home[:, :-1]], axis=1)
    boundary = valid & (jnp.arange(L)[None, :] > 0) & (home != prev)
    seg = jnp.cumsum(boundary.astype(jnp.int32), axis=1)
    seg = jnp.where(valid, seg, -1)
    last = jnp.maximum(lengths - 1, 0)
    h = jnp.take_along_axis(seg, last[:, None], axis=1)[:, 0]
    h = jnp.where(lengths > 0, h, 0)
    return home, seg, h


# ---------------------------------------------------------------------------
# Latency of paths under a replication scheme (Eqns 1-3) — engine-backed.
# ---------------------------------------------------------------------------
def path_latencies(
    pathset: PathSet,
    scheme: ReplicationScheme,
    chunk: int = 8192,
    backend: str = "jnp",
    policy=None,
) -> np.ndarray:
    """h(p, r, rho) for every path: #distributed traversals (Def 4.2).

    Convenience wrapper: builds a transient ``LatencyEngine`` (one packed
    upload) per call.  Hold an engine yourself for repeated evaluation
    against an evolving scheme.  ``policy`` scores the walk under a
    ``repro.engine.routing`` hop policy (default ``home_first``).
    """
    eng = LatencyEngine(scheme, backend=backend, chunk=chunk)
    return eng.path_latencies(pathset, policy=policy)


def query_latencies(
    pathset: PathSet,
    scheme: ReplicationScheme,
    path_lats: np.ndarray | None = None,
) -> np.ndarray:
    """l_Q = max over the query's paths (Def 4.3); int array [n_queries].

    ``path_lats`` lets callers that already hold per-path latencies skip
    the full re-scan.
    """
    if path_lats is None:
        path_lats = path_latencies(pathset, scheme)
    nq = pathset.n_queries
    out = np.zeros((nq,), dtype=np.int32)
    np.maximum.at(out, pathset.query_ids, path_lats)
    return out


def path_latency_reference(path: list[int], mask: np.ndarray, shard: np.ndarray) -> int:
    """Pure-python oracle for a single path (used by tests)."""
    if not path:
        return 0
    server = int(shard[path[0]])
    cost = 0
    for v in path[1:]:
        if mask[v, server]:
            continue  # local replica: stay (Eqn 1 first case)
        server = int(shard[v])  # distributed traversal to the original copy
        cost += 1
    return cost


def query_slacks(
    pathset: PathSet,
    scheme: ReplicationScheme,
    t,
    path_lats: np.ndarray | None = None,
    policy=None,
) -> np.ndarray:
    """Per-query slack t_Q - l_Q (negative = violating its constraint).

    ``t`` is an int (broadcast), a per-query budget vector, or an
    :class:`~repro.core.slo.SLOSpec`.  ``policy`` scores the walk under a
    hop-routing policy (ignored when ``path_lats`` is given).
    Convenience wrapper; stateful consumers use
    ``LatencyEngine.query_slack`` to stay device-resident.
    """
    if path_lats is None:
        path_lats = path_latencies(pathset, scheme, policy=policy)
    lq = query_latencies(pathset, scheme, path_lats=path_lats)
    t_q = getattr(t, "t_q", t)
    return (
        np.broadcast_to(np.asarray(t_q, np.int64), lq.shape) - lq
    ).astype(np.int64)


def is_latency_feasible(
    pathset: PathSet,
    scheme: ReplicationScheme,
    t,
    path_lats: np.ndarray | None = None,
    policy=None,
) -> bool:
    """All queries within their latency constraint t_Q (Def 4.4 constraint 1).

    ``t``: int | per-query vector | :class:`~repro.core.slo.SLOSpec`.
    Pass ``path_lats`` (per-path traversal counts) when already computed —
    the check then skips the full Eqn 1-2 re-scan entirely.  ``policy``
    scores feasibility under a hop-routing policy (``nearest_copy`` /
    ``nearest_copy_dp`` are the paper-faithful tighter readings).
    """
    return bool(
        np.all(
            query_slacks(pathset, scheme, t, path_lats=path_lats, policy=policy)
            >= 0
        )
    )


_PRUNE_GROUP_MAX = 512     # candidates per fused prune dispatch
_PRUNE_ROW_BUCKET = 1024   # affected-row padding quantum (bounds jit shapes)


@functools.partial(
    jax.jit,
    static_argnames=("pol", "backend", "G"),
    donate_argnums=(0,),
)
def _prune_group_step(
    words, gobj, gsrv, robj, rlen, rt, rcand, shard, rank, pol, backend, G
):
    """One fused prune round over an independent candidate group.

    Clears all ``G`` candidate bits at once, re-walks every affected row
    under the policy in the same jit, scatter-maxes per-row violations
    back onto their owning candidate, and restores exactly the infeasible
    candidates' bits — a single dispatch replacing ~3 per candidate.
    Row/candidate padding uses index -1 (violations land in a trash slot,
    restores in the sacrificial row).
    """
    from repro.engine.backends import gate_counts  # lazy: no cycle at import
    from repro.engine.packed import scatter_clear_pairs, scatter_or_pairs

    words = scatter_clear_pairs(words, gobj, gsrv)
    h = gate_counts(robj, rlen, words, shard, pol, rank, backend=backend)
    viol = h > rt  # pad rows: length 0 -> h = 0 <= rt = 0, never violating
    slot = jnp.where(rcand >= 0, rcand, G)
    bad = jnp.zeros((G + 1,), jnp.bool_).at[slot].max(viol)[:G]
    words = scatter_or_pairs(words, jnp.where(bad, gobj, -1), gsrv)
    return words, bad


def _independent_groups(order, vs, affected, n_paths, group_max):
    """Partition prune candidates into serially-equivalent batches.

    Two candidates are independent iff no path touches both objects —
    then neither's keep/drop decision can change what the other's
    affected walks read.  Greedy sweep in the serial (descending-f)
    order with *deferral closure*: once a candidate is deferred, its
    affected rows block every later candidate from joining the current
    group, so no candidate is ever evaluated against a snapshot that
    differs from the serial sweep's.
    """
    remaining = list(order)
    groups = []
    while remaining:
        used = np.zeros(n_paths, bool)
        group: list[int] = []
        deferred: list[int] = []
        for i in remaining:
            rows = affected(int(vs[i]))
            if len(group) < group_max and not used[rows].any():
                group.append(i)
            else:
                deferred.append(i)
            used[rows] = True
        groups.append(group)
        remaining = deferred
    return groups


def prune_scheme_replicas(
    scheme: ReplicationScheme,
    pathset: PathSet,
    t,
    policy="nearest_copy",
    f: np.ndarray | None = None,
    backend: str = "jnp",
    fused: bool = False,
    load: np.ndarray | None = None,
    group_max: int = _PRUNE_GROUP_MAX,
) -> tuple[int, float]:
    """Drop replicas a policy-routed walk doesn't need for feasibility.

    The greedy driver provisions against the ``home_first`` walk (every
    remote hop pays the trip to the object's home); when the serving path
    routes hops replica-aware (``nearest_copy`` — the paper-faithful
    "any co-located copy counts" reading of Eqn 1), some of those bytes
    are redundant.  This post-pass visits the scheme's replicas
    (non-originals) largest-``f`` first, tentatively removes each, and
    keeps the removal when the workload stays feasible under ``policy``
    scoring.  Mutates ``scheme`` in place; returns
    ``(n_dropped, bytes_saved)``.

    The feasibility re-check is *incremental*: a walk only reads the
    replica words of its own path's objects, so removing the copy
    (v, s) can only change paths that contain ``v`` — each tentative
    removal clears one membership bit on device
    (``LatencyEngine.remove_replicas``) and re-walks just the affected
    paths against their own budgets, instead of re-packing the scheme and
    re-scanning the workload per candidate (the previous implementation;
    ~50x slower at benchmark scale).

    One greedy sweep, not an optimal set cover — the measured bytes are
    a lower bound on the over-provisioning.

    ``fused=True`` batches the sweep: candidates whose objects never
    co-occur on any path are independent (neither decision changes the
    rows the other's walks read), so each independent group is cleared,
    re-validated, and selectively restored in ONE jit dispatch
    (``_prune_group_step``) instead of ~3 per candidate — decision-
    for-decision identical to the serial sweep by the deferral-closure
    grouping (see :func:`_independent_groups`).  Falls back to the serial
    sweep under ``backend="reference"`` (the oracle has no traceable
    gate).  ``load`` is the forecast per-server load a ``queue_aware``
    policy prices the walks with (ignored by load-blind policies).
    """
    from repro.core.slo import normalize_path_budgets  # local: no cycle
    from repro.engine import backends as _backends
    from repro.engine import to_device
    from repro.engine.routing import resolve_policy

    pol = resolve_policy(policy)
    engine = LatencyEngine(scheme, backend=backend)
    objects = np.asarray(pathset.objects, np.int32)
    lengths = np.asarray(pathset.lengths, np.int32)
    t_path = normalize_path_budgets(t, pathset).astype(np.int64)
    h0 = np.asarray(
        engine.path_latencies(pathset, policy=pol, load=load), np.int64
    )
    if pathset.n_paths == 0 or np.any(h0 > t_path):
        return 0, 0.0
    fv = (
        np.ones(scheme.n_objects, np.float64)
        if f is None
        else np.asarray(f, np.float64)
    )

    # object -> rows of the paths that touch it (built once; same CSR the
    # engine's incremental dirty-set cache uses)
    from repro.engine.incremental import PathIndex  # lazy: no cycle

    index = PathIndex(objects, scheme.n_objects)
    affected = index.paths_of

    L = objects.shape[1]

    def subset_ok(idx: np.ndarray) -> bool:
        """h under the policy for the affected rows, vs their budgets."""
        if not len(idx):
            return True
        if backend == "reference":
            from repro.core.reference import (
                routed_path_latencies_reference,
            )

            h = routed_path_latencies_reference(
                objects[idx], lengths[idx], scheme.mask, scheme.shard,
                policy=pol, load=load,
            )
            return bool(np.all(h <= t_path[idx]))
        # pad the row count to a bucket so jit traces stay bounded
        P = len(idx)
        Pb = -(-P // 128) * 128
        o = np.full((Pb, L), -1, np.int32)
        o[:P] = objects[idx]
        ln = np.zeros(Pb, np.int32)
        ln[:P] = lengths[idx]
        if backend == "pallas":
            h = _backends.pallas_routed_eval(
                to_device(o), to_device(ln),
                engine.packed.words, engine.packed.shard, pol, load=load,
            )
        else:
            h = _backends.routed_counts(
                to_device(o), to_device(ln),
                engine.packed.words, engine.packed.shard, pol, load=load,
            )
        return bool(np.all(np.asarray(h)[:P] <= t_path[idx]))

    repl = scheme.mask.copy()
    repl[np.arange(scheme.n_objects), scheme.shard] = False
    vs, ss = np.nonzero(repl)
    order = np.argsort(-fv[vs], kind="stable")
    n_dropped = 0
    bytes_saved = 0.0

    if fused and backend != "reference" and len(order):
        rank = _backends._load_vector(
            load if pol.uses_load else None, engine.packed.words
        )
        shard_j = engine.packed.shard
        for group in _independent_groups(
            order, vs, affected, pathset.n_paths, group_max
        ):
            G = group_max  # fixed group shape -> one jit trace
            gobj = np.full(G, -1, np.int32)
            gsrv = np.full(G, -1, np.int32)
            gobj[: len(group)] = vs[group]
            gsrv[: len(group)] = ss[group]
            rows = [affected(int(vs[i])) for i in group]
            R = max(1, sum(len(r) for r in rows))
            Rb = -(-R // _PRUNE_ROW_BUCKET) * _PRUNE_ROW_BUCKET
            robj = np.full((Rb, L), -1, np.int32)
            rlen = np.zeros(Rb, np.int32)
            rt = np.zeros(Rb, np.int32)
            rcand = np.full(Rb, -1, np.int32)
            at = 0
            for c, r in enumerate(rows):
                robj[at : at + len(r)] = objects[r]
                rlen[at : at + len(r)] = lengths[r]
                rt[at : at + len(r)] = t_path[r]
                rcand[at : at + len(r)] = c
                at += len(r)
            engine.packed.words, bad = _prune_group_step(
                engine.packed.words,
                to_device(gobj), to_device(gsrv),
                to_device(robj), to_device(rlen), to_device(rt),
                to_device(rcand),
                shard_j, rank, pol, backend, G,
            )
            keep = ~np.asarray(bad)[: len(group)]
            if keep.any():
                gi = np.asarray(group)[keep]
                n_dropped += int(keep.sum())
                bytes_saved += float(fv[vs[gi]].sum())
                scheme.mask[vs[gi], ss[gi]] = False
        return n_dropped, bytes_saved

    for i in order:
        v, s = int(vs[i]), int(ss[i])
        engine.remove_replicas([v], [s])
        if subset_ok(affected(v)):
            n_dropped += 1
            bytes_saved += float(fv[v])
        else:
            engine.add_replicas([v], [s])
    return n_dropped, bytes_saved
