"""Incremental replication-scheme updates under resharding (paper §5.4).

The UPDATE function records, for every replica it adds, a *resharding map*
entry RM: (u, v) meaning "a replica of v was co-located with the original
copy of u".  A *reference count* RC(v, s) counts how many distinct original
objects sharded to s the replica v is associated with.

When the system reshards (elastic scaling, server loss, sharding change)
and moves the original copy of u from s to s', the incremental algorithm:
  * places a copy of every v with (u, v) in RM at s' (unless present),
  * increments RC(v, s'), decrements RC(v, s),
  * deletes the replica v from s when its count drops below one (and no
    other association keeps it there), keeping storage bounded.

The resulting scheme remains latency-feasible and latency-robust because
Alg 2 co-locates replicas with *original copies of specific objects*,
independently of where the sharding function places those originals
(paper §5.4 closing argument).  Tests verify feasibility end-to-end.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict

import numpy as np

from repro.core.replication import ReplicationScheme


@dataclasses.dataclass
class ReshardingMap:
    """RM + RC bookkeeping produced alongside a replication scheme."""

    # u -> set of v replica-objects co-located with u's original copy
    rm: dict[int, set[int]]
    # (v, s) -> count of distinct originals at s that v is associated with
    rc: dict[tuple[int, int], int]

    @staticmethod
    def from_entries(
        entries: list[tuple[int, int, int]], shard: np.ndarray
    ) -> "ReshardingMap":
        """Build from the (u, v, s) triples emitted by the UPDATE functions.

        Each triple says: replica of v added at s because the original copy
        of u lives at s (Alg 2 line 18 instrumented).  Entries whose server
        disagrees with d(u) are still counted at the recorded server — the
        paper ties the replica to the *original object* u, so on reshard
        the replica follows u.
        """
        rm: dict[int, set[int]] = defaultdict(set)
        rc: dict[tuple[int, int], int] = defaultdict(int)
        seen: set[tuple[int, int, int]] = set()
        for u, v, s in entries:
            key = (int(u), int(v), int(s))
            if key in seen:
                continue
            seen.add(key)
            if int(v) not in rm[int(u)]:
                rm[int(u)].add(int(v))
            rc[(int(v), int(s))] += 1
        return ReshardingMap(dict(rm), dict(rc))

    def n_entries(self) -> int:
        return sum(len(vs) for vs in self.rm.values())


@dataclasses.dataclass
class ReshardReport:
    moved_originals: int = 0
    replicas_transferred: int = 0
    replicas_deleted: int = 0
    bytes_transferred: float = 0.0


def apply_reshard(
    scheme: ReplicationScheme,
    rmap: ReshardingMap,
    moves: dict[int, int],
    f: np.ndarray | None = None,
) -> ReshardReport:
    """Apply original-object moves {u: new_server} incrementally (§5.4).

    Mutates ``scheme`` (mask + shard) and ``rmap`` (RC counts) in place;
    returns transfer statistics.  The replica set of each moved original
    follows it; replicas whose refcount at the old server reaches zero are
    dropped there (unless that server still holds the object's original).
    """
    rep = ReshardReport()
    fv = (lambda v: 1.0) if f is None else (lambda v: float(f[v]))
    for u, s_new in moves.items():
        s_old = int(scheme.shard[u])
        if s_old == s_new:
            continue
        rep.moved_originals += 1
        # Move the original copy itself.
        scheme.mask[u, s_old] = False
        scheme.mask[u, s_new] = True
        scheme.shard[u] = s_new
        rep.bytes_transferred += fv(u)
        for v in rmap.rm.get(int(u), ()):
            # Transfer the associated replica to s_new if absent.
            if not scheme.mask[v, s_new]:
                scheme.mask[v, s_new] = True
                rep.replicas_transferred += 1
                rep.bytes_transferred += fv(v)
            rmap.rc[(v, s_new)] = rmap.rc.get((v, s_new), 0) + 1
            # Decrement at the old server; delete if no association left.
            old = rmap.rc.get((v, s_old), 0) - 1
            rmap.rc[(v, s_old)] = max(old, 0)
            if old < 1 and scheme.shard[v] != s_old and scheme.mask[v, s_old]:
                scheme.mask[v, s_old] = False
                rep.replicas_deleted += 1
    return rep


def drain_server(
    scheme: ReplicationScheme,
    rmap: ReshardingMap,
    server: int,
    f: np.ndarray | None = None,
    strategy: str = "single",
) -> tuple[dict[int, int], ReshardReport]:
    """Plan + apply the moves that evacuate ``server`` (fault handling).

    Strategies:
      * ``single``      — move the whole partition to the least-loaded
        survivor.  This is *partition-preserving*: server-local subpaths
        under d can only merge, never split, so the §5.4 RM-transfer alone
        keeps every path feasible (the setting the paper's closing
        argument covers).
      * ``round_robin`` — scatter originals over survivors.  This can
        SPLIT previously server-local subpaths (objects that were co-homed
        are separated), which RM entries cannot anticipate — the caller
        must follow with :func:`repair_paths` to restore the bound.  We
        surface this distinction because the paper's §5.4 claim implicitly
        assumes partition-preserving reshards (see DESIGN.md §9).
    Returns (moves, report).
    """
    remaining = [s for s in range(scheme.n_servers) if s != server]
    assert remaining, "cannot drain the last server"
    load = scheme.storage_per_server(f)
    order = sorted(remaining, key=lambda s: load[s])
    victims = np.nonzero(scheme.shard == server)[0]
    moves: dict[int, int] = {}
    if strategy == "single":
        tgt = order[0]
        moves = {int(u): tgt for u in victims}
    elif strategy == "round_robin":
        for i, u in enumerate(victims):
            moves[int(u)] = order[i % len(order)]
    else:
        raise ValueError(strategy)
    report = apply_reshard(scheme, rmap, moves, f)
    # The drained server keeps no copies.
    dropped = int(scheme.mask[:, server].sum())
    scheme.mask[:, server] = False
    report.replicas_deleted += dropped
    return moves, report


def repair_paths(
    scheme: ReplicationScheme,
    rmap: ReshardingMap,
    pathset,
    t: int,
    f: np.ndarray | None = None,
    capacity: np.ndarray | float | None = None,
    epsilon: float | None = None,
) -> dict:
    """Incrementally re-establish the latency bound after a scatter reshard.

    Finds the paths that violate the bound under the *new* sharding (one
    vectorized latency scan — no workload re-analysis) and re-runs the
    exact UPDATE on just those.  The additions are recorded into ``rmap``
    so subsequent reshards keep working.  Returns repair statistics; this
    is the quantity the paper's §6 'incremental update with a moderate
    replication cost' evaluation reports.
    """
    from repro.core.reference import update_exact  # local import (cycle)
    from repro.core.replication import path_latencies

    lat = path_latencies(pathset, scheme)
    bad = np.nonzero(lat > t)[0]
    cost = 0.0
    failed = 0
    for i in bad:
        res = update_exact(scheme, pathset.path(int(i)), t, f, capacity, epsilon)
        if res.feasible:
            cost += res.cost
            for u, v, s in res.rm_entries:
                rmap.rm.setdefault(int(u), set()).add(int(v))
                rmap.rc[(int(v), int(s))] = rmap.rc.get((int(v), int(s)), 0) + 1
        else:
            failed += 1
    return {
        "repaired_paths": int(len(bad)) - failed,
        "failed_paths": failed,
        "repair_cost": cost,
    }
