"""Baseline replication schemes from the paper's evaluation (§2, §6.2).

Two baselines:

* **Single-site oracle** (Fig 2d): replays the workload with perfect
  knowledge and, for each query, replicates exactly the objects it accesses
  to the server its root is routed to, so every query executes locally
  (t = 0 with minimal oracle replication).  Equivalent to running our
  greedy algorithm with t = 0 but stated independently as the paper does.

* **Dangling-edge replication** (Table 3 / Fig 7d): structure-only scheme
  used by Wukong [34] and DistDGL [42] — replicate the immediate remote
  neighbors of every vertex (k = 0), optionally including the neighbor's
  adjacency list (k = 1), which enforces t = floor(n/2) for n-hop queries.
  It is workload-UNaware: it replicates along every cut edge whether or
  not any query traverses it.
"""
from __future__ import annotations

import numpy as np

from repro.core.paths import PathSet
from repro.core.replication import ReplicationScheme
from repro.engine import LatencyEngine


def evaluate_baseline(
    pathset: PathSet,
    scheme: ReplicationScheme,
    f: np.ndarray | None = None,
    backend: str = "jnp",
) -> dict:
    """Engine-backed evaluation of a baseline scheme (Fig 2/Table 3 rows).

    One packed upload; returns the per-query latency distribution plus the
    storage metrics the paper reports for every baseline.
    """
    eng = LatencyEngine(scheme, backend=backend)
    pl = eng.path_latencies(pathset)
    lq = eng.query_latencies(pathset, pl)
    return {
        "path_latencies": pl,
        "query_latencies": lq,
        "max_latency": int(lq.max(initial=0)),
        "mean_latency": float(lq.mean()) if len(lq) else 0.0,
        "replicas": scheme.replica_count(),
        "overhead": scheme.replication_overhead(f),
    }


def single_site_oracle(
    pathset: PathSet, shard: np.ndarray, n_servers: int
) -> ReplicationScheme:
    """Perfect-knowledge single-site replication (paper Fig 2d).

    Each query is routed to the home server of the root of its first path;
    every object accessed by any path of the query is replicated there.
    """
    scheme = ReplicationScheme.from_sharding(shard, n_servers)
    if pathset.n_paths == 0:
        return scheme
    # Route each query to the home server of its (first path's) root.
    nq = pathset.n_queries
    route = np.full((nq,), -1, dtype=np.int64)
    roots = shard[np.maximum(pathset.objects[:, 0], 0)]
    # first path of each query wins
    for i in range(pathset.n_paths - 1, -1, -1):
        route[pathset.query_ids[i]] = roots[i]
    # Replicate all accessed objects of the query at the routed server.
    objs = pathset.objects  # [P, L]
    valid = objs >= 0
    srv_per_path = route[pathset.query_ids]  # [P]
    vv = objs[valid]
    ss = np.broadcast_to(srv_per_path[:, None], objs.shape)[valid]
    scheme.mask[vv, ss] = True
    return scheme


def dangling_edge_replication(
    indptr: np.ndarray,
    indices: np.ndarray,
    shard: np.ndarray,
    n_servers: int,
    k: int = 1,
) -> ReplicationScheme:
    """Structure-based halo replication (paper Table 3; [34, 42]).

    k = 0: for every cut edge (u, w) replicate w's *vertex object* at
    d(u) (removes the dangling edge but a further hop from w is remote).
    k = 1: additionally treat the replica as holding w's adjacency list,
    and replicate w's neighbors' vertex objects at d(u) as well, enforcing
    t = floor(n/2) on n-hop traversals (the variant we compare against,
    as the paper does).
    """
    scheme = ReplicationScheme.from_sharding(shard, n_servers)
    n = shard.shape[0]
    src = np.repeat(np.arange(n), np.diff(indptr))
    dst = indices
    cut = shard[src] != shard[dst]
    scheme.mask[dst[cut], shard[src[cut]]] = True
    if k >= 1:
        # neighbors of the replicated vertex also land at d(u)
        cut_dst = dst[cut]
        cut_home = shard[src[cut]]
        counts = (indptr[cut_dst + 1] - indptr[cut_dst]).astype(np.int64)
        rep_home = np.repeat(cut_home, counts)
        gather = np.concatenate(
            [indices[indptr[v] : indptr[v + 1]] for v in cut_dst]
        ) if len(cut_dst) else np.zeros((0,), dtype=indices.dtype)
        if len(gather):
            scheme.mask[gather, rep_home] = True
    return scheme
