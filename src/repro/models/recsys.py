"""MIND multi-interest recsys model [1904.08030] — pure JAX.

Components:
  * **EmbeddingBag** — JAX has no native EmbeddingBag; we implement it with
    ``jnp.take`` + ``jax.ops.segment_sum`` (sum/mean pooling over ragged
    bags flattened to (indices, offsets)), per the assignment note.  The
    fixed-shape batched variant (take + masked mean) is used inside the
    model; the ragged variant is exercised by tests and the embedding
    Pallas kernel.
  * **Capsule multi-interest extractor** — behavior-to-interest (B2I)
    dynamic routing, ``capsule_iters`` rounds, squash nonlinearity.
  * **Label-aware attention** for training; sampled-softmax loss with
    in-batch negatives.
  * **Retrieval scoring** — score 1M candidates against the K interests
    with one einsum + max-over-interests (no loops).

The item table is the replication target for the paper's algorithm
(hot rows = heavy-hitter zipf lookups; see repro.workload.recsys).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class MINDConfig:
    name: str = "mind"
    n_items: int = 100_000
    n_user_feats: int = 10_000
    embed_dim: int = 64
    n_interests: int = 4
    capsule_iters: int = 3
    hist_len: int = 50
    user_feat_len: int = 8
    d_hidden: int = 128
    dtype: Any = jnp.float32

    def validate(self) -> None:
        assert self.n_interests >= 1 and self.capsule_iters >= 1


# ---------------------------------------------------------------------------
# EmbeddingBag (the substrate op)
# ---------------------------------------------------------------------------
def embedding_bag(
    table: jnp.ndarray,
    indices: jnp.ndarray,
    offsets: jnp.ndarray,
    mode: str = "mean",
) -> jnp.ndarray:
    """Ragged EmbeddingBag: pool ``table[indices]`` into per-bag vectors.

    indices: int32 [nnz] flattened bag contents;
    offsets: int32 [n_bags] start of each bag (ascending, last bag runs to
    nnz) — the torch.nn.EmbeddingBag layout.
    """
    nnz = indices.shape[0]
    n_bags = offsets.shape[0]
    rows = jnp.take(table, indices, axis=0)
    # bag id of each nnz position: searchsorted over offsets
    bag_ids = jnp.searchsorted(offsets, jnp.arange(nnz), side="right") - 1
    out = jax.ops.segment_sum(rows, bag_ids, num_segments=n_bags)
    if mode == "mean":
        cnt = jax.ops.segment_sum(jnp.ones((nnz,), jnp.float32), bag_ids,
                                  num_segments=n_bags)
        out = out / jnp.maximum(cnt, 1.0)[:, None]
    return out


def embedding_bag_dense(table, ids, mask, mode="mean"):
    """Fixed-shape bag: ids [B, L], mask [B, L] -> [B, d]."""
    rows = jnp.take(table, jnp.maximum(ids, 0), axis=0)
    m = mask.astype(rows.dtype)[..., None]
    s = (rows * m).sum(axis=1)
    if mode == "mean":
        s = s / jnp.maximum(m.sum(axis=1), 1.0)
    return s


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------
def shapes(cfg: MINDConfig) -> dict:
    t = cfg.dtype
    d = cfg.embed_dim
    return {
        "item_embed": ((cfg.n_items, d), t),
        "user_embed": ((cfg.n_user_feats, d), t),
        "bilinear": ((d, d), t),
        "w_hidden": ((2 * d, cfg.d_hidden), t),
        "b_hidden": ((cfg.d_hidden,), t),
        "w_out": ((cfg.d_hidden, d), t),
        "b_out": ((d,), t),
    }


def _is_shape_leaf(x) -> bool:
    return isinstance(x, tuple) and len(x) == 2 and isinstance(x[0], tuple)


def init_abstract(cfg: MINDConfig) -> dict:
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s[0], s[1]),
                        shapes(cfg), is_leaf=_is_shape_leaf)


def init(cfg: MINDConfig, rng: jax.Array) -> dict:
    tree = shapes(cfg)
    flat, _ = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=_is_shape_leaf)
    keys = jax.random.split(rng, len(flat))
    leaves = []
    for (path, (shape, dt)), k in zip(flat, keys):
        name = path[-1].key
        if name.startswith("b_"):
            leaves.append(jnp.zeros(shape, dt))
        else:
            std = 0.1 if "embed" in name else 1.0 / np.sqrt(shape[0])
            leaves.append(
                (jax.random.normal(k, shape, jnp.float32) * std).astype(dt))
    return jax.tree.unflatten(
        jax.tree.structure(tree, is_leaf=_is_shape_leaf), leaves)


def param_specs(cfg: MINDConfig, dp=("data",), tp="model", tp_size=16) -> dict:
    """Embedding tables row-sharded over the TP axis (the canonical recsys
    placement); small dense layers replicated."""
    return {
        "item_embed": P(tp, None),
        "user_embed": P(tp, None),
        "bilinear": P(None, None),
        "w_hidden": P(None, None),
        "b_hidden": P(None),
        "w_out": P(None, None),
        "b_out": P(None),
    }


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------
def squash(x, axis=-1):
    n2 = jnp.sum(x * x, axis=axis, keepdims=True)
    return (n2 / (1.0 + n2)) * x / jnp.sqrt(n2 + 1e-9)


def multi_interest(params, behav_emb, mask, cfg: MINDConfig) -> jnp.ndarray:
    """B2I dynamic routing.  behav_emb [B,H,d], mask [B,H] -> [B,K,d]."""
    B, H, d = behav_emb.shape
    K = cfg.n_interests
    e_hat = behav_emb @ params["bilinear"]                 # [B,H,d]
    # fixed (non-trainable, deterministic) routing-logit init as in MIND
    binit = jnp.sin(jnp.arange(K * H, dtype=jnp.float32) * 12.9898)
    b = jnp.broadcast_to(binit.reshape(1, K, H), (B, K, H))
    neg = (~mask.astype(bool))[:, None, :]
    u = None
    for _ in range(cfg.capsule_iters):
        w = jax.nn.softmax(jnp.where(neg, -1e30, b), axis=1)  # over K
        z = jnp.einsum("bkh,bhd->bkd", w, e_hat)
        u = squash(z)
        b = b + jnp.einsum("bkd,bhd->bkh", u, e_hat)
    return u                                                # [B,K,d]


def user_tower(params, batch, cfg: MINDConfig) -> jnp.ndarray:
    """-> interests [B, K, d] (profile-feature conditioned)."""
    behav = jnp.take(params["item_embed"], jnp.maximum(batch["hist"], 0), 0)
    behav = behav * batch["hist_mask"][..., None].astype(behav.dtype)
    interests = multi_interest(params, behav, batch["hist_mask"], cfg)
    profile = embedding_bag_dense(
        params["user_embed"], batch["user_feats"],
        jnp.ones_like(batch["user_feats"]), mode="mean")     # [B,d]
    B, K, d = interests.shape
    h = jnp.concatenate(
        [interests, jnp.broadcast_to(profile[:, None], (B, K, d))], -1)
    h = jax.nn.relu(h @ params["w_hidden"] + params["b_hidden"])
    return h @ params["w_out"] + params["b_out"]             # [B,K,d]


def label_aware_attention(interests, target_emb, p: float = 2.0):
    """MIND label-aware attention: pow-softmax over interests."""
    s = jnp.einsum("bkd,bd->bk", interests, target_emb)
    w = jax.nn.softmax((jnp.abs(s) + 1e-9) ** p * jnp.sign(s), axis=-1)
    return jnp.einsum("bk,bkd->bd", w, interests)


def loss_fn(params, batch, cfg: MINDConfig) -> jnp.ndarray:
    """Sampled softmax with in-batch negatives."""
    interests = user_tower(params, batch, cfg)               # [B,K,d]
    tgt = jnp.take(params["item_embed"], batch["target"], 0)  # [B,d]
    user_vec = label_aware_attention(interests, tgt)          # [B,d]
    logits = user_vec @ tgt.T                                 # [B,B] in-batch
    labels = jnp.arange(logits.shape[0])
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], -1)[..., 0]
    return jnp.mean(logz - gold)


def serve_score(params, batch, cfg: MINDConfig) -> jnp.ndarray:
    """Online scoring: users x their candidate lists.

    batch: hist/hist_mask/user_feats + candidates [B, C] item ids.
    Returns scores [B, C] = max over interests of dot products.
    """
    interests = user_tower(params, batch, cfg)                # [B,K,d]
    cand = jnp.take(params["item_embed"], batch["candidates"], 0)  # [B,C,d]
    s = jnp.einsum("bkd,bcd->bkc", interests, cand)
    return s.max(axis=1)                                      # [B,C]


def retrieval_score(params, batch, cfg: MINDConfig) -> jnp.ndarray:
    """Retrieval: one (or few) users against the whole candidate corpus.

    batch: hist/hist_mask/user_feats [B=1,...] + candidate_ids [N] —
    batched-dot (einsum) over N=1e6, no loop.
    """
    interests = user_tower(params, batch, cfg)                # [B,K,d]
    cand = jnp.take(params["item_embed"], batch["candidate_ids"], 0)  # [N,d]
    s = jnp.einsum("bkd,nd->bkn", interests, cand)
    return s.max(axis=1)                                      # [B,N]
