"""Model definitions: transformer LM family, GNN family, MIND recsys."""
from repro.models import gnn, recsys, transformer

__all__ = ["transformer", "gnn", "recsys"]
