"""Pure-JAX transformer LM family covering all five assigned LM archs.

One config class expresses dense (qwen2-7b, h2o-danube-3-4b, chatglm3-6b)
and MoE (qwen3-moe-235b-a22b, deepseek-v2-236b) decoders:

  * GQA attention with RoPE (full or partial rotary — chatglm 2d RoPE),
    optional QKV bias (qwen2), optional sliding window (danube);
  * MLA (deepseek-v2): low-rank compressed KV (kv_lora) with decoupled
    RoPE dims; attention uses the *absorbed* formulation so the KV cache
    stores only the 512-dim compressed stream + 64-dim rope keys;
  * MoE: token-choice top-k routing with per-expert capacity via a
    sort-based static-shape dispatch (TPU-friendly: no ragged shapes),
    optional shared experts; deepseek's leading dense layers are a
    separate scan stack so no dead compute is lowered;
  * scan-over-layers with stacked params (small HLO, O(1) compile in L)
    and selectable rematerialization;
  * blockwise (memory-efficient) attention for long sequences so 32k
    prefill lowers with bounded live memory;
  * KV-cache prefill + single-token decode (ring buffer for SWA).

Everything is functional: params are pytrees of jnp arrays; abstract
initialization (ShapeDtypeStruct) mirrors real init exactly, which is what
the multi-pod dry-run lowers against.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str = "lm"
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 2
    d_ff: int = 1024                 # dense-MLP hidden
    vocab: int = 1024
    head_dim: int | None = None      # default d_model // n_heads
    max_seq: int = 2048
    # --- MoE ---
    n_experts: int = 0               # 0 = dense
    top_k: int = 0
    moe_d_ff: int = 0                # routed-expert hidden
    n_shared_experts: int = 0
    shared_d_ff: int = 0
    n_dense_layers: int = 0          # leading dense layers (deepseek)
    capacity_factor: float = 1.25
    moe_chunk: int = 32768           # tokens per dispatch round (bounds the
                                     # [E, C, d] buffer: C ~ chunk*K/E*cf)
    # --- MLA (deepseek) ---
    mla_kv_lora: int = 0             # 0 = standard GQA
    mla_q_lora: int = 0
    mla_rope_dim: int = 64
    mla_nope_dim: int = 128
    mla_v_dim: int = 128
    # --- attention variants ---
    sliding_window: int = 0          # 0 = full attention
    qkv_bias: bool = False
    rotary_pct: float = 1.0          # chatglm: 0.5 (2d RoPE)
    rope_theta: float = 1e4
    # --- numerics / execution ---
    dtype: Any = jnp.bfloat16
    remat: bool = True
    scan_unroll: int = 1             # layers per scan iteration (cost-
                                     # analysis correction uses 2; see
                                     # repro.analysis.corrected)
    remat_block: int = 1             # layers per checkpoint block: the
                                     # train scan saves one [B,S,d] carry
                                     # per BLOCK (L/K saves instead of L)
    # activation sharding constraints (maxtext-style).  Empty act_dp
    # disables constraints (single-device smoke tests).  Set by the
    # family's shardings()/step_fn() per mesh; requires jax.set_mesh.
    act_dp: tuple = ()               # data axes for batch/token dims
    act_tp: str = "model"            # tensor axis for heads/hidden/experts
    act_seq: bool = False            # seq-shard the saved layer carries
                                     # over act_tp (16x smaller checkpoint
                                     # stacks; +1 gather per layer)
    tp_size: int = 16                # size of act_tp (divisibility checks)
    attn_block_q: int = 1024         # blockwise attention chunk
    blockwise_from: int = 8192       # use blockwise attention above this S
    loss_chunk: int = 0              # tokens per CE-loss chunk (0 = off):
                                     # bounds live logits to chunk x vocab
    use_flash_prefill: bool = False  # Pallas flash kernel for full-seq
                                     # attention (TPU path; interpret on
                                     # CPU — tests only)
    norm_eps: float = 1e-6

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def is_mla(self) -> bool:
        return self.mla_kv_lora > 0

    @property
    def n_moe_layers(self) -> int:
        return self.n_layers - self.n_dense_layers if self.is_moe else 0

    def validate(self) -> None:
        assert self.n_heads % self.n_kv_heads == 0
        if self.is_moe:
            assert 0 < self.top_k <= self.n_experts
            assert 0 <= self.n_dense_layers < self.n_layers


# ---------------------------------------------------------------------------
# Parameter construction.  `shapes()` is the single source of truth; both
# abstract (dry-run) and concrete (smoke-test) init derive from it.
# ---------------------------------------------------------------------------
def _attn_shapes(cfg: TransformerConfig) -> dict[str, tuple[tuple[int, ...], Any]]:
    d, hd, H, KV = cfg.d_model, cfg.hd, cfg.n_heads, cfg.n_kv_heads
    t = cfg.dtype
    sh: dict[str, tuple[tuple[int, ...], Any]] = {
        "ln_attn": ((d,), t),
        "ln_mlp": ((d,), t),
        "wo": ((H * (cfg.mla_v_dim if cfg.is_mla else hd), d), t),
    }
    if cfg.is_mla:
        qd = cfg.mla_nope_dim + cfg.mla_rope_dim
        if cfg.mla_q_lora:
            sh["w_dq"] = ((d, cfg.mla_q_lora), t)
            sh["w_uq"] = ((cfg.mla_q_lora, H * qd), t)
        else:
            sh["wq"] = ((d, H * qd), t)
        sh["w_dkv"] = ((d, cfg.mla_kv_lora + cfg.mla_rope_dim), t)
        sh["w_uk"] = ((cfg.mla_kv_lora, H * cfg.mla_nope_dim), t)
        sh["w_uv"] = ((cfg.mla_kv_lora, H * cfg.mla_v_dim), t)
    else:
        sh["wq"] = ((d, H * hd), t)
        sh["wk"] = ((d, KV * hd), t)
        sh["wv"] = ((d, KV * hd), t)
        if cfg.qkv_bias:
            sh["bq"] = ((H * hd,), t)
            sh["bk"] = ((KV * hd,), t)
            sh["bv"] = ((KV * hd,), t)
    return sh


def _layer_shapes(cfg: TransformerConfig, kind: str) -> dict:
    """kind: 'dense' (SwiGLU MLP) or 'moe' (routed experts [+ shared])."""
    d, t = cfg.d_model, cfg.dtype
    sh = _attn_shapes(cfg)
    if kind == "dense":
        sh["w1"] = ((d, cfg.d_ff), t)
        sh["w3"] = ((d, cfg.d_ff), t)
        sh["w2"] = ((cfg.d_ff, d), t)
    else:
        sh["router"] = ((d, cfg.n_experts), jnp.float32)
        sh["we1"] = ((cfg.n_experts, d, cfg.moe_d_ff), t)
        sh["we3"] = ((cfg.n_experts, d, cfg.moe_d_ff), t)
        sh["we2"] = ((cfg.n_experts, cfg.moe_d_ff, d), t)
        if cfg.n_shared_experts:
            sff = cfg.shared_d_ff or cfg.n_shared_experts * cfg.moe_d_ff
            sh["ws1"] = ((d, sff), t)
            sh["ws3"] = ((d, sff), t)
            sh["ws2"] = ((sff, d), t)
    return sh


def _stack(sh: dict, n: int) -> dict:
    return {k: ((n, *shape), dt) for k, (shape, dt) in sh.items()}


def shapes(cfg: TransformerConfig) -> dict:
    """Full parameter shape tree: scan stacks + embeddings."""
    out = {
        "embed": ((cfg.vocab, cfg.d_model), cfg.dtype),
        "ln_f": ((cfg.d_model,), cfg.dtype),
        "lm_head": ((cfg.d_model, cfg.vocab), cfg.dtype),
    }
    if cfg.is_moe:
        if cfg.n_dense_layers:
            out["dense_layers"] = _stack(
                _layer_shapes(cfg, "dense"), cfg.n_dense_layers)
        out["layers"] = _stack(_layer_shapes(cfg, "moe"), cfg.n_moe_layers)
    else:
        out["layers"] = _stack(_layer_shapes(cfg, "dense"), cfg.n_layers)
    return out


def _is_shape_leaf(x) -> bool:
    return isinstance(x, tuple) and len(x) == 2 and isinstance(x[0], tuple)


def init_abstract(cfg: TransformerConfig) -> dict:
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s[0], s[1]), shapes(cfg),
        is_leaf=_is_shape_leaf)


def init(cfg: TransformerConfig, rng: jax.Array) -> dict:
    """Concrete init (reduced configs / smoke tests only)."""
    tree = shapes(cfg)
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=_is_shape_leaf)
    keys = jax.random.split(rng, len(flat))
    out = []
    for (path, (shape, dt)), k in zip(flat, keys):
        name = path[-1].key
        if name.startswith("ln_"):
            out.append(jnp.ones(shape, dt))
        elif name.startswith("b"):
            out.append(jnp.zeros(shape, dt))
        else:
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            std = 1.0 / np.sqrt(max(fan_in, 1))
            out.append((jax.random.normal(k, shape, jnp.float32) * std).astype(dt))
    return jax.tree.unflatten(jax.tree.structure(tree, is_leaf=_is_shape_leaf), out)


def param_specs(cfg: TransformerConfig, dp: tuple[str, ...] = ("data",),
                tp: str = "model", tp_size: int = 16,
                dp_size: int = 16, fsdp: bool = True) -> dict:
    """PartitionSpecs mirroring the shapes tree.

    Megatron-style TP on the head/hidden output dims + (default) FSDP-style
    sharding of the *other* big dim over the data axes — required for the
    MoE archs, whose 230-450 GB of parameters plus f32 optimizer moments
    cannot live 16-way-sharded on 16 GB chips.  GSPMD inserts the layer
    all-gathers (fwd) and reduce-scatters (grads) this implies.
    """
    d_ok = fsdp and cfg.d_model % dp_size == 0
    fs = dp if d_ok else None          # the FSDP shard of dim d_model

    def attn_specs() -> dict:
        s: dict[str, P] = {
            "ln_attn": P(None, None),
            "ln_mlp": P(None, None),
            "wo": P(None, tp, fs),
        }
        if cfg.is_mla:
            if cfg.mla_q_lora:
                s["w_dq"] = P(None, fs,
                              tp if cfg.mla_q_lora % tp_size == 0 else None)
                s["w_uq"] = P(None, fs if cfg.mla_q_lora % dp_size == 0
                              else None, tp)
            else:
                s["wq"] = P(None, fs, tp)
            kvl = cfg.mla_kv_lora + cfg.mla_rope_dim
            s["w_dkv"] = P(None, fs, tp if kvl % tp_size == 0 else None)
            lora_fs = fs if cfg.mla_kv_lora % dp_size == 0 else None
            s["w_uk"] = P(None, lora_fs, tp)
            s["w_uv"] = P(None, lora_fs, tp)
        else:
            # head-aligned TP only: sharding a projection whose head count
            # does not divide the axis splits head_dim (a contracting dim
            # under RoPE/attention) and GSPMD degrades to replication —
            # measured 5x temp blowup; see EXPERIMENTS.md §Perf.
            q_ok = cfg.n_heads % tp_size == 0
            kv_ok = cfg.n_kv_heads % tp_size == 0
            s["wq"] = P(None, fs, tp if q_ok else None)
            kv = P(None, fs, tp if kv_ok else None)
            s["wk"] = kv
            s["wv"] = kv
            s["wo"] = P(None, tp if q_ok else None, fs)
            if cfg.qkv_bias:
                s["bq"] = P(None, tp if q_ok else None)
                s["bk"] = P(None, tp) if kv_ok else P(None, None)
                s["bv"] = P(None, tp) if kv_ok else P(None, None)
        return s

    ff_fs = fs if cfg.d_ff % max(dp_size, 1) == 0 else None
    dense = {**attn_specs(), "w1": P(None, fs, tp), "w3": P(None, fs, tp),
             "w2": P(None, tp, fs)}
    out = {
        "embed": P(tp, fs),
        "ln_f": P(None),
        "lm_head": P(fs, tp),
    }
    if cfg.is_moe:
        moe = {**attn_specs(), "router": P(None, None, None),
               "we1": P(None, tp, fs, None), "we3": P(None, tp, fs, None),
               "we2": P(None, tp, None, fs)}
        if cfg.n_shared_experts:
            moe["ws1"] = P(None, fs, tp)
            moe["ws3"] = P(None, fs, tp)
            moe["ws2"] = P(None, tp, fs)
        if cfg.n_dense_layers:
            out["dense_layers"] = dense
        out["layers"] = moe
    else:
        out["layers"] = dense
    return out


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------
def _wsc(x: jnp.ndarray, cfg, *spec) -> jnp.ndarray:
    """Activation sharding constraint (no-op when act_dp is unset).

    GSPMD propagation alone loses the batch sharding at the embedding
    gather (the table is sharded over (tp, dp); the gather output adopts
    the table's d-sharding and drops batch) — measured 100x temp blowup at
    train_4k.  Explicit constraints at layer boundaries pin the intended
    activation layout; see EXPERIMENTS.md §Perf iteration 0.
    """
    if not cfg.act_dp:
        return x
    return jax.lax.with_sharding_constraint(x, P(*spec))


def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
         rotary_dim: int | None = None) -> jnp.ndarray:
    """Rotary embedding on the last dim; partial rotary for chatglm 2d.

    x: [..., S, n, hd]; positions broadcastable to [..., S].
    """
    hd = x.shape[-1]
    rd = rotary_dim or hd
    rot, rest = x[..., :rd], x[..., rd:]
    half = rd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None, None].astype(jnp.float32) * freqs
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = rot[..., :half], rot[..., half:]
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    out = jnp.concatenate([r1, r2], axis=-1).astype(x.dtype)
    return jnp.concatenate([out, rest], axis=-1) if rd < hd else out


def _attn_mask(q_pos, k_pos, window: int) -> jnp.ndarray:
    m = k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        m &= (q_pos[:, None] - k_pos[None, :]) < window
    return m


def attention(q, k, v, q_pos, k_pos, window: int = 0,
              block_q: int = 1024, blockwise_from: int = 8192) -> jnp.ndarray:
    """GQA attention.  q: [B,S,H,hd], k/v: [B,T,KV,hd].  Output [B,S,H,hd].

    lax.map over query blocks when S is large, so the [S,T] score matrix
    never fully materializes (memory-efficient attention; the Pallas
    flash-decode kernel is the TPU-optimized sibling for serving).
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = 1.0 / np.sqrt(hd)
    qg = q.reshape(B, S, KV, G, hd)

    def blk(qb, qpb):
        s = jnp.einsum("bqkgh,btkh->bkgqt", qb, k,
                       preferred_element_type=jnp.float32) * scale
        mask = _attn_mask(qpb, k_pos, window)
        s = jnp.where(mask[None, None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
        return jnp.einsum("bkgqt,btkh->bqkgh", p, v,
                          preferred_element_type=jnp.float32)

    if S <= blockwise_from or S % block_q != 0:
        out = blk(qg, q_pos)
    else:
        nb = S // block_q
        qb = qg.reshape(B, nb, block_q, KV, G, hd).swapaxes(0, 1)
        pb = q_pos.reshape(nb, block_q)
        out = jax.lax.map(lambda args: blk(*args), (qb, pb))
        out = out.swapaxes(0, 1).reshape(B, S, KV, G, hd)
    return out.reshape(B, S, H, hd).astype(q.dtype)


def swiglu(x, w1, w3, w2):
    h = jax.nn.silu(x @ w1) * (x @ w3)
    return h @ w2


# ---------------------------------------------------------------------------
# MoE: token-choice top-k with static-shape sort-based dispatch.
# ---------------------------------------------------------------------------
def moe_ffn(x: jnp.ndarray, lp: dict, cfg: TransformerConfig,
            bs: tuple[int, int] | None = None) -> jnp.ndarray:
    """x: [T, d] -> [T, d].  Chunked dispatch: capacity is derived from the
    chunk size, so the routed buffer is O(chunk * K * d) no matter how many
    tokens the global batch has (microbatched MoE, standard at scale).

    Chunking slices the SEQUENCE dim (bs = (B, S)): the lax.map loop axis
    must be unsharded, and chunking the flat token dim put the dp-sharded
    batch on the loop axis — GSPMD all-gathered all tokens in f32 (112 GiB
    at qwen3 train_4k; EXPERIMENTS.md §Perf iter 3).  Slicing S keeps the
    batch sharding inside every chunk.
    """
    T, d = x.shape
    chunk = cfg.moe_chunk
    if not chunk or T <= chunk or bs is None:
        return _moe_ffn_chunk(x, lp, cfg)
    B, S = bs
    s_ck = max(chunk // B, 1)
    if S % s_ck != 0:
        return _moe_ffn_chunk(x, lp, cfg)
    n = S // s_ck
    xs = x.reshape(B, n, s_ck, d).swapaxes(0, 1)       # [n, B, s_ck, d]

    # checkpoint the chunk body: without it the map's backward stacks
    # every chunk's [E, C, d] dispatch buffers as residuals
    # (n_chunks x buffers; EXPERIMENTS.md §Perf qwen3-moe iter 1)
    def body(xc):
        flat = xc.reshape(B * s_ck, d)
        return _moe_ffn_chunk(flat, lp, cfg).reshape(B, s_ck, d)

    body = jax.checkpoint(
        body, policy=jax.checkpoint_policies.nothing_saveable)
    ys = jax.lax.map(body, xs)                          # [n, B, s_ck, d]
    return ys.swapaxes(0, 1).reshape(T, d)


def _moe_ffn_chunk(x: jnp.ndarray, lp: dict, cfg: TransformerConfig) -> jnp.ndarray:
    """x: [T, d] -> [T, d].  Static shapes; overflow past capacity drops."""
    T, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    C = max(int(T * K / E * cfg.capacity_factor), 1)
    if T <= 256:
        # decode / tiny batches: capacity covers the worst case (every
        # token on one expert) so serving never drops tokens
        C = max(C, T)
    logits = x.astype(jnp.float32) @ lp["router"]
    gates = jax.nn.softmax(logits, axis=-1)                       # [T, E]
    top_g, top_e = jax.lax.top_k(gates, K)                        # [T, K]
    top_g = top_g / jnp.clip(top_g.sum(-1, keepdims=True), 1e-9)

    flat_e = top_e.reshape(-1)                                    # [T*K]
    flat_g = top_g.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T), K)
    order = jnp.argsort(flat_e, stable=True)
    e_sorted = flat_e[order]
    t_sorted = flat_t[order]
    g_sorted = flat_g[order]
    counts = jnp.bincount(flat_e, length=E)
    starts = jnp.cumsum(counts) - counts
    pos_in_e = jnp.arange(T * K) - starts[e_sorted]
    keep = pos_in_e < C

    buf = jnp.zeros((E, C, d), x.dtype)
    buf = buf.at[
        jnp.where(keep, e_sorted, 0), jnp.where(keep, pos_in_e, 0)
    ].add(jnp.where(keep[:, None], x[t_sorted], 0).astype(x.dtype))
    # expert-parallel dispatch: the routed buffer lives expert-sharded on
    # the tp axis (GSPMD inserts the token all-to-all)
    buf = _wsc(buf, cfg, cfg.act_tp, None, None)

    h = jnp.einsum("ecd,edf->ecf", buf, lp["we1"])
    h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", buf, lp["we3"])
    y_e = jnp.einsum("ecf,efd->ecd", h, lp["we2"])                # [E, C, d]
    y_e = _wsc(y_e, cfg, cfg.act_tp, None, None)

    contrib = y_e[jnp.where(keep, e_sorted, 0), jnp.where(keep, pos_in_e, 0)]
    contrib = contrib * (g_sorted * keep).astype(contrib.dtype)[:, None]
    y = jnp.zeros((T, d), contrib.dtype).at[t_sorted].add(contrib)

    if cfg.n_shared_experts:
        y = y + swiglu(x, lp["ws1"], lp["ws3"], lp["ws2"])
    return y.astype(x.dtype)


def _ffn(x2d: jnp.ndarray, lp: dict, cfg: TransformerConfig,
         bs: tuple[int, int] | None = None) -> jnp.ndarray:
    if "we1" in lp:
        return moe_ffn(x2d, lp, cfg, bs)
    return swiglu(x2d, lp["w1"], lp["w3"], lp["w2"])


# ---------------------------------------------------------------------------
# Layer body (shared by train forward and prefill)
# ---------------------------------------------------------------------------
def _qkv_gqa(x, lp, cfg, positions, tp_size: int = 16):
    B, S, d = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = x @ lp["wq"]
    k = x @ lp["wk"]
    v = x @ lp["wv"]
    if cfg.qkv_bias:
        q, k, v = q + lp["bq"], k + lp["bk"], v + lp["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, KV, hd)
    v = v.reshape(B, S, KV, hd)
    dp, tp = cfg.act_dp, cfg.act_tp
    # head-parallel q when heads divide the axis; otherwise
    # sequence-parallel q (context parallelism) so attention compute is
    # still sharded over tp for archs like qwen2 (28 heads).
    if H % tp_size == 0:
        q = _wsc(q, cfg, dp, None, tp, None)
    elif S % tp_size == 0:
        q = _wsc(q, cfg, dp, tp, None, None)
    kv_spec = (dp, None, tp, None) if KV % tp_size == 0 else (
        dp, None, None, None)
    k = _wsc(k, cfg, *kv_spec)
    v = _wsc(v, cfg, *kv_spec)
    rd = int(cfg.rotary_pct * hd)
    q = rope(q, positions, cfg.rope_theta, rd)
    k = rope(k, positions, cfg.rope_theta, rd)
    return q, k, v


def _qkv_mla(x, lp, cfg, positions):
    """MLA projections -> (q_nope, q_rope, c_kv, k_rope); the latter two
    form the cacheable compressed stream."""
    B, S, d = x.shape
    H = cfg.n_heads
    nd, rd = cfg.mla_nope_dim, cfg.mla_rope_dim
    if cfg.mla_q_lora:
        q = (x @ lp["w_dq"]) @ lp["w_uq"]
    else:
        q = x @ lp["wq"]
    q = q.reshape(B, S, H, nd + rd)
    q = _wsc(q, cfg, cfg.act_dp, None, cfg.act_tp, None)
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    ckv = x @ lp["w_dkv"]
    ckv = _wsc(ckv, cfg, cfg.act_dp, None, None)
    c_kv, k_rope = ckv[..., : cfg.mla_kv_lora], ckv[..., cfg.mla_kv_lora:]
    k_rope = rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return q_nope, q_rope, c_kv, k_rope


def _mla_attention(q_nope, q_rope, c_kv, k_rope, lp, cfg, q_pos, k_pos,
                   k_valid=None, block_q=1024, blockwise_from=8192):
    """Absorbed MLA attention over the compressed stream.

      score = (q_nope @ W_uk^T) . c_kv + q_rope . k_rope
      out_h = softmax(score) . c_kv @ W_uv_h

    so the KV cache is [B,T,kv_lora] + [B,T,rope] only.
    """
    B, S, H, nd = q_nope.shape
    Lr = cfg.mla_kv_lora
    w_uk = lp["w_uk"].reshape(Lr, H, nd)
    q_abs = jnp.einsum("bshn,lhn->bshl", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))
    scale = 1.0 / np.sqrt(nd + cfg.mla_rope_dim)

    def blk(qa, qr, qpb):
        s = jnp.einsum("bshl,btl->bhst", qa.astype(c_kv.dtype), c_kv,
                       preferred_element_type=jnp.float32)
        s = s + jnp.einsum("bshr,btr->bhst", qr, k_rope,
                           preferred_element_type=jnp.float32)
        s = s * scale
        mask = _attn_mask(qpb, k_pos, cfg.sliding_window)
        if k_valid is not None:
            mask = mask & k_valid[None, :]
        s = jnp.where(mask[None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1).astype(c_kv.dtype)
        return jnp.einsum("bhst,btl->bshl", p, c_kv,
                          preferred_element_type=jnp.float32)

    if S <= blockwise_from or S % block_q != 0:
        ctx = blk(q_abs, q_rope, q_pos)
    else:
        nb = S // block_q
        qa = q_abs.reshape(B, nb, block_q, H, Lr).swapaxes(0, 1)
        qr = q_rope.reshape(B, nb, block_q, H, cfg.mla_rope_dim).swapaxes(0, 1)
        pb = q_pos.reshape(nb, block_q)
        ctx = jax.lax.map(lambda a: blk(*a), (qa, qr, pb))
        ctx = ctx.swapaxes(0, 1).reshape(B, S, H, Lr)
    w_uv = lp["w_uv"].reshape(Lr, H, cfg.mla_v_dim)
    out = jnp.einsum("bshl,lhv->bshv", ctx, w_uv.astype(jnp.float32))
    return out.astype(cfg.dtype)


def layer_fwd(x, lp, cfg: TransformerConfig, positions):
    """One decoder layer, full-sequence (training / prefill forward)."""
    B, S, d = x.shape
    # gather the (possibly seq-sharded) carry for this layer's compute
    x = _wsc(x, cfg, cfg.act_dp, None, None)
    h = rms_norm(x, lp["ln_attn"], cfg.norm_eps)
    if cfg.is_mla:
        qn, qr, ckv, kr = _qkv_mla(h, lp, cfg, positions)
        attn = _mla_attention(qn, qr, ckv, kr, lp, cfg, positions, positions,
                              None, cfg.attn_block_q, cfg.blockwise_from)
    else:
        q, k, v = _qkv_gqa(h, lp, cfg, positions)
        if cfg.use_flash_prefill and S % 128 == 0:
            from repro.kernels import ops as _kops

            KV = cfg.n_kv_heads
            qg = q.reshape(B, S, KV, cfg.n_heads // KV, cfg.hd)
            attn = _kops.flash_prefill(qg, k, v,
                                       window=cfg.sliding_window)
        else:
            attn = attention(q, k, v, positions, positions,
                             cfg.sliding_window, cfg.attn_block_q,
                             cfg.blockwise_from)
    x = x + attn.reshape(B, S, -1) @ lp["wo"]
    x = _wsc(x, cfg, cfg.act_dp, None, None)
    h = rms_norm(x, lp["ln_mlp"], cfg.norm_eps)
    y = _ffn(h.reshape(B * S, d), lp, cfg, (B, S)).reshape(B, S, d)
    out = x + y
    if cfg.act_seq and S % cfg.tp_size == 0:
        # the scan saves this carry: keep it sequence-sharded over tp so
        # the checkpoint stack is 1/tp_size the size (Megatron-SP-style)
        return _wsc(out, cfg, cfg.act_dp, cfg.act_tp, None)
    return _wsc(out, cfg, cfg.act_dp, None, None)


def _layer_body_specs(cfg, stack_key: str) -> dict:
    """Per-layer weight specs with the FSDP (dp) dim dropped: constraining
    the scan-body slice to these forces the FSDP all-gather INSIDE the
    loop (per layer) instead of the loop-invariant full-stack gather XLA
    hoists otherwise (measured: 28-layer hoisted gather = 13 GiB/chip at
    qwen2 train_4k; per-layer = 0.5 GiB; EXPERIMENTS.md §Perf)."""
    sp = param_specs(cfg, dp=(), tp=cfg.act_tp, tp_size=cfg.tp_size,
                     dp_size=1, fsdp=False)[stack_key]
    return {k: P(*v[1:]) for k, v in sp.items()}


def _gather_layer(lp: dict, cfg, stack_key: str) -> dict:
    if not cfg.act_dp:
        return lp
    specs = _layer_body_specs(cfg, stack_key)
    return {k: jax.lax.with_sharding_constraint(v, specs[k])
            for k, v in lp.items()}


def _scan_stack(x, stack, cfg, positions, stack_key: str = "layers"):
    n = jax.tree.leaves(stack)[0].shape[0]
    # block remat: one checkpointed scan step covers `bk` layers, so the
    # scan saves n/bk carries instead of n (the dominant train-memory term
    # at 4k x 256; see EXPERIMENTS.md §Perf).
    bk = max(k for k in range(1, min(cfg.remat_block, n) + 1) if n % k == 0)

    # hierarchical remat: the outer checkpoint makes the scan save one
    # carry per BLOCK; the inner per-layer checkpoint keeps the block's
    # backward working set at one layer's transients (without it the
    # block recompute holds bk layers' intermediates live at once).
    inner = layer_fwd
    if cfg.remat and bk > 1:
        inner = jax.checkpoint(
            layer_fwd, policy=jax.checkpoint_policies.nothing_saveable,
            static_argnums=(2,))

    def block_fwd(carry, lps, cfg, positions):
        for i in range(bk):
            lp = jax.tree.map(lambda a: a[i], lps)
            lp = _gather_layer(lp, cfg, stack_key)
            carry = inner(carry, lp, cfg, positions)
        return carry

    body = block_fwd
    if cfg.remat:
        body = jax.checkpoint(
            block_fwd, policy=jax.checkpoint_policies.nothing_saveable,
            static_argnums=(2,))

    def scan_body(carry, lps):
        return body(carry, lps, cfg, positions), None

    blocked = jax.tree.map(
        lambda a: a.reshape(n // bk, bk, *a.shape[1:]), stack)
    x, _ = jax.lax.scan(scan_body, x, blocked,
                        unroll=max(1, min(cfg.scan_unroll, n // bk)))
    return x


def forward(params: dict, tokens: jnp.ndarray, cfg: TransformerConfig,
            positions: jnp.ndarray | None = None) -> jnp.ndarray:
    """Logits [B, S, vocab] with scan-over-layers (+ optional remat)."""
    x = hidden_states(params, tokens, cfg, positions)
    logits = (x @ params["lm_head"]).astype(jnp.float32)
    return _wsc(logits, cfg, cfg.act_dp, None, cfg.act_tp)


def _ce_terms(logits, labels):
    """(sum nll, count) for one block of [N, V] logits.

    Gold logit via a masked reduction over the vocab axis: with a
    vocab-sharded lm_head this is a local select + tiny all-reduce,
    whereas take_along_axis(labels) gathers the FULL logits (measured
    37 GiB/chip at train_4k; see EXPERIMENTS.md §Perf)."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                          logits.ndim - 1)
    sel = vocab_iota == jnp.maximum(labels, 0)[..., None]
    gold = jnp.sum(jnp.where(sel, logits, 0.0), axis=-1)
    mask = labels >= 0
    return jnp.sum((logz - gold) * mask), mask.sum()


def loss_fn(params, tokens, labels, cfg) -> jnp.ndarray:
    x = hidden_states(params, tokens, cfg)
    B, S, d = x.shape
    T = B * S
    xt = x.reshape(T, d)
    lt = labels.reshape(T)
    ck = cfg.loss_chunk
    if ck and T > ck and T % ck == 0:
        # chunked CE head: the backward recomputes each chunk's logits, so
        # live logits are [chunk, V] instead of [T, V] (the CE backward
        # held ~13 full-logit buffers live; EXPERIMENTS.md §Perf).
        # Gather the FSDP-sharded lm_head ONCE outside the chunk map —
        # inside the checkpointed body it would re-gather per chunk
        # (64 x 74 MB x fwd/bwd at qwen3 train_4k; §Perf iter 2).
        lm_head = _wsc(params["lm_head"], cfg, None, cfg.act_tp)

        def chunk_loss(args):
            xc, lc = args
            logits = (xc @ lm_head).astype(jnp.float32)
            logits = _wsc(logits, cfg, cfg.act_dp, cfg.act_tp)
            return _ce_terms(logits, lc)

        chunk_loss = jax.checkpoint(
            chunk_loss, policy=jax.checkpoint_policies.nothing_saveable)
        xs = (xt.reshape(T // ck, ck, d), lt.reshape(T // ck, ck))
        nll, cnt = jax.lax.map(chunk_loss, xs)
        return nll.sum() / jnp.maximum(cnt.sum(), 1)
    logits = (xt @ params["lm_head"]).astype(jnp.float32)
    logits = _wsc(logits, cfg, cfg.act_dp, cfg.act_tp)
    nll, cnt = _ce_terms(logits, lt)
    return nll / jnp.maximum(cnt, 1)


def hidden_states(params, tokens, cfg, positions=None) -> jnp.ndarray:
    """Final-norm hidden states [B, S, d] (the pre-lm_head forward)."""
    B, S = tokens.shape
    x = params["embed"][tokens].astype(cfg.dtype)
    x = _wsc(x, cfg, cfg.act_dp, None, None)
    pos = positions if positions is not None else jnp.arange(S)
    if "dense_layers" in params:
        x = _scan_stack(x, params["dense_layers"], cfg, pos, "dense_layers")
    x = _scan_stack(x, params["layers"], cfg, pos, "layers")
    return rms_norm(x, params["ln_f"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# Serving: prefill + decode with KV cache.
# ---------------------------------------------------------------------------
def cache_shapes(cfg: TransformerConfig, batch: int, max_len: int) -> dict:
    L = cfg.n_layers
    if cfg.is_mla:
        return {
            "c_kv": ((L, batch, max_len, cfg.mla_kv_lora), cfg.dtype),
            "k_rope": ((L, batch, max_len, cfg.mla_rope_dim), cfg.dtype),
            "index": ((), jnp.int32),
        }
    eff = min(cfg.sliding_window, max_len) if cfg.sliding_window else max_len
    return {
        "k": ((L, batch, eff, cfg.n_kv_heads, cfg.hd), cfg.dtype),
        "v": ((L, batch, eff, cfg.n_kv_heads, cfg.hd), cfg.dtype),
        "index": ((), jnp.int32),
    }


def cache_abstract(cfg, batch, max_len) -> dict:
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s[0], s[1]),
        cache_shapes(cfg, batch, max_len), is_leaf=_is_shape_leaf)


def cache_init(cfg, batch, max_len) -> dict:
    return jax.tree.map(
        lambda s: jnp.zeros(s[0], s[1]),
        cache_shapes(cfg, batch, max_len), is_leaf=_is_shape_leaf)


def cache_specs(cfg: TransformerConfig, batch: int, dp=("data",), tp="model",
                dp_size: int = 16) -> dict:
    """KV cache sharding: batch over dp when divisible; positions over tp
    (kv-head counts rarely divide the model axis, the position axis does)."""
    b = dp if batch % max(dp_size, 1) == 0 else None
    if cfg.is_mla:
        return {"c_kv": P(None, b, tp, None), "k_rope": P(None, b, tp, None),
                "index": P()}
    d5 = P(None, b, tp, None, None)
    return {"k": d5, "v": d5, "index": P()}


def decode_step(params, cache, tokens, cfg: TransformerConfig):
    """One-token decode: tokens [B] -> (new_cache, logits [B, vocab]).

    Writes the new KV at the ring slot (index % cache_len for SWA) and
    attends over the cache with position-validity masking.  MoE/dense
    stacks are scanned just like the training forward.
    """
    B = tokens.shape[0]
    x = params["embed"][tokens][:, None, :].astype(cfg.dtype)  # [B,1,d]
    idx = cache["index"]
    win = cfg.sliding_window
    T = cache["c_kv"].shape[2] if cfg.is_mla else cache["k"].shape[2]
    slot = idx % T
    pos_now = jnp.full((B, 1), idx, jnp.int32)

    slots = jnp.arange(T)
    # global position stored in each ring slot (largest p <= idx, p%T==s)
    k_pos_global = idx - ((idx - slots) % T)
    k_valid = (k_pos_global >= 0) & (k_pos_global <= idx)
    if win > 0:
        k_valid &= (idx - k_pos_global) < win

    def body(carry, lp, layer_cache):
        x = carry
        x = _wsc(x, cfg, cfg.act_dp, None, None)
        h = rms_norm(x, lp["ln_attn"], cfg.norm_eps)
        if cfg.is_mla:
            c_prev, r_prev = layer_cache
            qn, qr, ckv_new, kr_new = _qkv_mla(h, lp, cfg, pos_now)
            c_l = jax.lax.dynamic_update_index_in_dim(
                c_prev, ckv_new[:, 0], slot, axis=1)
            r_l = jax.lax.dynamic_update_index_in_dim(
                r_prev, kr_new[:, 0], slot, axis=1)
            c_l = _wsc(c_l, cfg, cfg.act_dp, cfg.act_tp, None)
            r_l = _wsc(r_l, cfg, cfg.act_dp, cfg.act_tp, None)
            w_uk = lp["w_uk"].reshape(cfg.mla_kv_lora, cfg.n_heads,
                                      cfg.mla_nope_dim)
            q_abs = jnp.einsum("bshn,lhn->bshl", qn.astype(jnp.float32),
                               w_uk.astype(jnp.float32))
            s = jnp.einsum("bshl,btl->bhst", q_abs.astype(c_l.dtype), c_l,
                           preferred_element_type=jnp.float32)
            s = s + jnp.einsum("bshr,btr->bhst", qr, r_l,
                               preferred_element_type=jnp.float32)
            s = s / np.sqrt(cfg.mla_nope_dim + cfg.mla_rope_dim)
            s = jnp.where(k_valid[None, None, None, :], s, -1e30)
            p = jax.nn.softmax(s, axis=-1).astype(c_l.dtype)
            ctx = jnp.einsum("bhst,btl->bshl", p, c_l,
                             preferred_element_type=jnp.float32)
            w_uv = lp["w_uv"].reshape(cfg.mla_kv_lora, cfg.n_heads,
                                      cfg.mla_v_dim)
            attn = jnp.einsum("bshl,lhv->bshv", ctx,
                              w_uv.astype(jnp.float32)).astype(cfg.dtype)
            new_slices = (c_l, r_l)
        else:
            k_prev, v_prev = layer_cache
            q, k_new, v_new = _qkv_gqa(h, lp, cfg, pos_now)
            k_l = jax.lax.dynamic_update_index_in_dim(
                k_prev, k_new[:, 0], slot, axis=1)
            v_l = jax.lax.dynamic_update_index_in_dim(
                v_prev, v_new[:, 0], slot, axis=1)
            k_l = _wsc(k_l, cfg, cfg.act_dp, cfg.act_tp, None, None)
            v_l = _wsc(v_l, cfg, cfg.act_dp, cfg.act_tp, None, None)
            KV, G = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
            qg = q.reshape(B, 1, KV, G, cfg.hd)
            s = jnp.einsum("bqkgh,btkh->bkgqt", qg, k_l,
                           preferred_element_type=jnp.float32) / np.sqrt(cfg.hd)
            s = jnp.where(k_valid[None, None, None, None, :], s, -1e30)
            p = jax.nn.softmax(s, axis=-1).astype(v_l.dtype)
            o = jnp.einsum("bkgqt,btkh->bqkgh", p, v_l,
                           preferred_element_type=jnp.float32)
            attn = o.astype(cfg.dtype)
            new_slices = (k_l, v_l)
        x = x + attn.reshape(B, 1, -1) @ lp["wo"]
        h2 = rms_norm(x, lp["ln_mlp"], cfg.norm_eps)
        y = _ffn(h2.reshape(B, -1), lp, cfg).reshape(B, 1, -1)
        return x + y, new_slices

    nd = cfg.n_dense_layers if (cfg.is_moe and "dense_layers" in params) else 0
    if cfg.is_mla:
        caches = (cache["c_kv"], cache["k_rope"])
    else:
        caches = (cache["k"], cache["v"])

    def run_stack(x, stack, cache_slice, stack_key):
        def scan_body(carry, sl):
            lp = _gather_layer(sl[0], cfg, stack_key)
            return body(carry, lp, sl[1])
        n = jax.tree.leaves(stack)[0].shape[0]
        return jax.lax.scan(scan_body, x, (stack, cache_slice),
                            unroll=max(1, min(cfg.scan_unroll, n)))

    if nd:
        head = tuple(c[:nd] for c in caches)
        tail = tuple(c[nd:] for c in caches)
        x, new_head = run_stack(x, params["dense_layers"], head,
                                "dense_layers")
        x, new_tail = run_stack(x, params["layers"], tail, "layers")
        new_cols = tuple(
            jnp.concatenate([h, t], axis=0) for h, t in zip(new_head, new_tail))
    else:
        x, new_cols = run_stack(x, params["layers"], caches, "layers")

    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = (x[:, 0] @ params["lm_head"]).astype(jnp.float32)
    if cfg.is_mla:
        new_cache = {"c_kv": new_cols[0], "k_rope": new_cols[1],
                     "index": idx + 1}
    else:
        new_cache = {"k": new_cols[0], "v": new_cols[1], "index": idx + 1}
    return new_cache, logits


def prefill(params, tokens, cfg: TransformerConfig, max_len: int):
    """Run the prompt, building the KV cache.  tokens [B, S]."""
    B, S = tokens.shape
    x = params["embed"][tokens].astype(cfg.dtype)
    pos = jnp.arange(S)
    win = cfg.sliding_window
    eff = min(win, max_len) if win > 0 else max_len
    take = min(S, eff)
    # ring layout: slot of position p is p % eff; a roll by (S % eff)
    # places the last `take` positions correctly when S >= eff.
    roll = S % eff if S >= eff else 0

    def stash_ring(full):  # full: [B, S, ...] -> [B, eff, ...]
        lastk = full[:, S - take:]
        buf = jnp.zeros((B, eff) + full.shape[2:], full.dtype)
        buf = buf.at[:, :take].set(lastk)
        buf = jnp.roll(buf, roll, axis=1) if roll else buf
        extra = (None,) * (buf.ndim - 2)
        return _wsc(buf, cfg, cfg.act_dp, cfg.act_tp, *extra)

    def body(carry, lp):
        x = carry
        h = rms_norm(x, lp["ln_attn"], cfg.norm_eps)
        if cfg.is_mla:
            qn, qr, ckv, kr = _qkv_mla(h, lp, cfg, pos)
            attn = _mla_attention(qn, qr, ckv, kr, lp, cfg, pos, pos,
                                  None, cfg.attn_block_q, cfg.blockwise_from)
            stash = (stash_ring(ckv), stash_ring(kr))
        else:
            q, k, v = _qkv_gqa(h, lp, cfg, pos)
            attn = attention(q, k, v, pos, pos, win,
                             cfg.attn_block_q, cfg.blockwise_from)
            stash = (stash_ring(k), stash_ring(v))
        x = x + attn.reshape(B, S, -1) @ lp["wo"]
        h2 = rms_norm(x, lp["ln_mlp"], cfg.norm_eps)
        y = _ffn(h2.reshape(B * S, -1), lp, cfg, (B, S)).reshape(B, S, -1)
        return x + y, stash

    if "dense_layers" in params:
        x, stash_d = jax.lax.scan(
            lambda c, lp: body(c, _gather_layer(lp, cfg, "dense_layers")),
            x, params["dense_layers"])
        x, stash_m = jax.lax.scan(
            lambda c, lp: body(c, _gather_layer(lp, cfg, "layers")),
            x, params["layers"])
        stash = tuple(jnp.concatenate([d, m], 0)
                      for d, m in zip(stash_d, stash_m))
    else:
        x, stash = jax.lax.scan(
            lambda c, lp: body(c, _gather_layer(lp, cfg, "layers")),
            x, params["layers"])
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = (x[:, -1] @ params["lm_head"]).astype(jnp.float32)
    if cfg.is_mla:
        cache = {"c_kv": stash[0], "k_rope": stash[1], "index": jnp.int32(S)}
    else:
        cache = {"k": stash[0], "v": stash[1], "index": jnp.int32(S)}
    return cache, logits
