"""GNN model family: EGNN, SchNet, GraphSAGE, GraphCast — pure JAX.

Message passing is implemented with ``jax.ops.segment_sum`` over an
edge-index (src, dst) representation — JAX has no SpMM beyond BCOO, so the
edge-scatter formulation IS the substrate (kernel_taxonomy §GNN regime 1),
and it shards naturally: edge arrays over the data axis, hidden dims over
the model axis where divisible.

Batch formats (see ``repro.configs``):
  * full graph   — {x:[N,F], senders:[E], receivers:[E], (pos:[N,3]),
                    labels:[N]}
  * molecules    — same arrays with a leading batch axis, vmapped
  * minibatch    — {seed_x:[B,F], layer_x: per-hop [B, W_h, F]} blocks from
                   the fan-out sampler; the regular fan-out makes
                   aggregation a reshape-mean (TPU-native; no ragged ops)

Per-arch notes:
  * EGNN  [2102.09844]: E(n)-equivariant; messages from (h_i, h_j,
    ||x_i - x_j||^2); coordinate updates along (x_i - x_j).
  * SchNet [1706.08566]: continuous-filter convolutions; RBF-expanded
    distances -> filter MLP; interaction blocks.
  * GraphSAGE [1706.02216]: mean aggregator + concat + dense.
  * GraphCast [2212.12794]: encoder-processor-decoder; the processor is a
    deep stack of interaction networks (edge MLP + node MLP with sum
    aggregation).  The grid<->mesh remapping is adapted to the provided
    graph (encoder/decoder are per-node MLPs; see DESIGN.md §9).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str = "gnn"
    arch: str = "graphsage"          # egnn | schnet | graphsage | graphcast
    n_layers: int = 2
    d_hidden: int = 128
    d_in: int = 128                  # input feature dim
    n_classes: int = 64              # classification head width
    aggregator: str = "mean"         # graphsage: mean; graphcast: sum
    # schnet
    n_rbf: int = 300
    cutoff: float = 10.0
    # graphcast
    d_edge: int = 4                  # raw edge-feature dim (displacement+len)
    dtype: Any = jnp.float32
    remat: bool = False              # rematerialize layer bodies (big graphs)
    scan_unroll: int = 1             # layers per scan iteration
    # distributed-aggregation controls (set by the family per mesh/shape):
    # shard_map aggregation computes per-chip partial segment-sums over the
    # local edge shard and reduce-scatters node rows — GSPMD's scatter
    # fallback all-gathers the full [E, d] message tensor instead
    # (29.5 GiB/chip at schnet x ogb_products; EXPERIMENTS.md §Perf).
    agg_axes: tuple = ()             # mesh axes the edge arrays shard over
    node_axes: tuple = ()            # mesh axes node arrays shard over
    min_tp_dim: int = 512            # only tp-shard hidden dims >= this

    def validate(self) -> None:
        assert self.arch in ("egnn", "schnet", "graphsage", "graphcast")


def _mlp_shapes(d_in, d_hidden, d_out, t, depth=2):
    if depth == 1:
        return {"w0": ((d_in, d_out), t), "b0": ((d_out,), t)}
    return {
        "w0": ((d_in, d_hidden), t), "b0": ((d_hidden,), t),
        "w1": ((d_hidden, d_out), t), "b1": ((d_out,), t),
    }


def _mlp(p, x, act=jax.nn.silu):
    h = x @ p["w0"] + p["b0"]
    if "w1" in p:
        h = act(h) @ p["w1"] + p["b1"]
    return h


# ---------------------------------------------------------------------------
# Shapes
# ---------------------------------------------------------------------------
def shapes(cfg: GNNConfig) -> dict:
    t = cfg.dtype
    d = cfg.d_hidden
    L = cfg.n_layers
    out: dict = {"encoder": _mlp_shapes(cfg.d_in, d, d, t)}
    if cfg.arch == "egnn":
        layer = {
            "phi_e": _mlp_shapes(2 * d + 1, d, d, t),
            "phi_x": _mlp_shapes(d, d, 1, t),
            "phi_h": _mlp_shapes(2 * d, d, d, t),
        }
    elif cfg.arch == "schnet":
        layer = {
            "filter": _mlp_shapes(cfg.n_rbf, d, d, t),
            "in_dense": _mlp_shapes(d, d, d, t, depth=1),
            "out_dense": _mlp_shapes(d, d, d, t),
        }
    elif cfg.arch == "graphsage":
        layer = {"w_self": ((d, d), t), "w_nbr": ((d, d), t), "b": ((d,), t)}
    else:  # graphcast interaction network
        layer = {
            "edge_mlp": _mlp_shapes(3 * d, d, d, t),
            "node_mlp": _mlp_shapes(2 * d, d, d, t),
        }
    out["layers"] = {k: ((L, *s), dt) for k, (s, dt) in _flatten2(layer).items()}
    out["decoder"] = _mlp_shapes(d, d, cfg.n_classes, t)
    if cfg.arch == "graphcast":
        out["edge_encoder"] = _mlp_shapes(cfg.d_edge, d, d, t)
    return out


def _flatten2(nested: dict) -> dict:
    """{'phi_e': {'w0': ...}} -> {'phi_e/w0': ...} (keeps stacks simple)."""
    out = {}
    for k, v in nested.items():
        if isinstance(v, dict):
            for k2, v2 in v.items():
                out[f"{k}/{k2}"] = v2
        else:
            out[k] = v
    return out


def _unflatten2(flat: dict) -> dict:
    out: dict = {}
    for k, v in flat.items():
        if "/" in k:
            a, b = k.split("/", 1)
            out.setdefault(a, {})[b] = v
        else:
            out[k] = v
    return out


def _is_shape_leaf(x) -> bool:
    return isinstance(x, tuple) and len(x) == 2 and isinstance(x[0], tuple)


def init_abstract(cfg: GNNConfig) -> dict:
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s[0], s[1]),
                        shapes(cfg), is_leaf=_is_shape_leaf)


def init(cfg: GNNConfig, rng: jax.Array) -> dict:
    tree = shapes(cfg)
    flat, _ = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=_is_shape_leaf)
    keys = jax.random.split(rng, len(flat))
    leaves = []
    for (path, (shape, dt)), k in zip(flat, keys):
        name = path[-1].key
        if name.startswith("b"):
            leaves.append(jnp.zeros(shape, dt))
        else:
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            leaves.append((jax.random.normal(k, shape, jnp.float32)
                           / np.sqrt(max(fan_in, 1))).astype(dt))
    return jax.tree.unflatten(
        jax.tree.structure(tree, is_leaf=_is_shape_leaf), leaves)


def param_specs(cfg: GNNConfig, dp=("data",), tp="model", tp_size=16) -> dict:
    """Shard the last (output) dim over tp when divisible AND large enough
    (feature-sharding a 64-wide hidden gives 4 floats/chip and forces
    involuntary full rematerializations against edge-sharded tensors);
    stacked layer params keep their leading layer dim whole."""

    def spec_for(shape: tuple, stacked: bool) -> P:
        dims = list(shape)
        spec = [None] * len(dims)
        if (dims and dims[-1] % tp_size == 0
                and dims[-1] >= cfg.min_tp_dim):
            spec[-1] = tp
        if stacked:
            spec[0] = None
        return P(*spec)

    tree = shapes(cfg)

    def rec(sub, stacked):
        out = {}
        for k, v in sub.items():
            if isinstance(v, dict):
                out[k] = rec(v, stacked or k == "layers")
            else:
                out[k] = spec_for(v[0], stacked)
        return out

    return rec(tree, False)


# ---------------------------------------------------------------------------
# Message-passing primitives
# ---------------------------------------------------------------------------
def _agg_dense(messages, receivers, n_nodes, kind="sum"):
    s = jax.ops.segment_sum(messages, receivers, num_segments=n_nodes)
    if kind == "mean":
        cnt = jax.ops.segment_sum(jnp.ones_like(receivers, jnp.float32),
                                  receivers, num_segments=n_nodes)
        s = s / jnp.maximum(cnt, 1.0)[:, None]
    return s


def _unroll(cfg: GNNConfig) -> int:
    return max(1, min(cfg.scan_unroll, cfg.n_layers))


def make_agg(cfg: GNNConfig):
    """Aggregation op: shard_map partial-sum + psum_scatter when the mesh
    layout is known (see GNNConfig.agg_axes), else plain segment_sum."""
    if not cfg.agg_axes:
        return _agg_dense

    from functools import partial

    from jax.experimental.shard_map import shard_map

    axes = tuple(cfg.agg_axes)
    n_ax = tuple(cfg.node_axes)

    def agg(messages, receivers, n_nodes, kind="sum"):
        mesh = jax.sharding.get_abstract_mesh()
        world = 1
        for a in axes:
            world *= mesh.shape[a]
        if n_nodes % world != 0:
            return _agg_dense(messages, receivers, n_nodes, kind)

        @partial(shard_map, mesh=mesh,
                 in_specs=(P(axes, None), P(axes)),
                 out_specs=P(axes, None), check_rep=False)
        def inner(m_local, r_local):
            # per-chip partial segment-sum over the local edge shard,
            # then ring reduce-scatter of node rows over all chips
            psum = jax.ops.segment_sum(m_local, r_local,
                                       num_segments=n_nodes)
            cnt = None
            if kind == "mean":
                cnt = jax.ops.segment_sum(
                    jnp.ones_like(r_local, jnp.float32), r_local,
                    num_segments=n_nodes)
                psum = jnp.concatenate([psum, cnt[:, None]], axis=1)
            out = psum
            for a in axes:  # scatter over each axis in turn
                out = jax.lax.psum_scatter(out, a, scatter_dimension=0,
                                           tiled=True)
            return out

        out = inner(messages, receivers)
        if kind == "mean":
            out, cnt = out[:, :-1], out[:, -1]
            out = out / jnp.maximum(cnt, 1.0)[:, None]
        # node arrays live on node_axes downstream
        return jax.lax.with_sharding_constraint(out, P(n_ax or None, None))

    return agg


_agg = _agg_dense  # default used by the layer bodies below


def rbf_expand(dist, n_rbf, cutoff):
    centers = jnp.linspace(0.0, cutoff, n_rbf)
    gamma = 10.0 / cutoff
    return jnp.exp(-gamma * (dist[:, None] - centers) ** 2)


# ---------------------------------------------------------------------------
# Per-arch layer bodies (x/h: [N, d]; senders/receivers: [E])
# ---------------------------------------------------------------------------
def egnn_layer(lp, h, pos, senders, receivers, agg=_agg_dense):
    n = h.shape[0]
    diff = pos[senders] - pos[receivers]
    d2 = jnp.sum(diff * diff, axis=-1, keepdims=True)
    m = _mlp(lp["phi_e"], jnp.concatenate([h[senders], h[receivers], d2], -1))
    coef = _mlp(lp["phi_x"], m)
    # normalized coordinate update keeps equivariance + numerics
    upd = agg(diff * coef / jnp.sqrt(d2 + 1.0), receivers, n, "mean")
    pos = pos + upd
    magg = agg(m, receivers, n, "sum")
    h = h + _mlp(lp["phi_h"], jnp.concatenate([h, magg], -1))
    return h, pos


def schnet_layer(lp, h, pos, senders, receivers, n_rbf, cutoff,
                 agg=_agg_dense):
    n = h.shape[0]
    dist = jnp.sqrt(jnp.sum((pos[senders] - pos[receivers]) ** 2, -1) + 1e-9)
    w = _mlp(lp["filter"], rbf_expand(dist, n_rbf, cutoff))
    x = _mlp(lp["in_dense"], h)
    m = x[senders] * w
    out = agg(m, receivers, n, "sum")
    return h + _mlp(lp["out_dense"], out), pos


def graphsage_layer(lp, h, senders, receivers, kind="mean",
                    agg=_agg_dense):
    n = h.shape[0]
    nbr = agg(h[senders], receivers, n, kind)
    return jax.nn.relu(h @ lp["w_self"] + nbr @ lp["w_nbr"] + lp["b"])


def graphcast_layer(lp, h, e, senders, receivers, agg=_agg_dense):
    n = h.shape[0]
    e = e + _mlp(lp["edge_mlp"],
                 jnp.concatenate([e, h[senders], h[receivers]], -1))
    out = agg(e, receivers, n, "sum")
    h = h + _mlp(lp["node_mlp"], jnp.concatenate([h, out], -1))
    return h, e


# ---------------------------------------------------------------------------
# Full-graph forward
# ---------------------------------------------------------------------------
def forward(params: dict, batch: dict, cfg: GNNConfig) -> jnp.ndarray:
    """Node logits [N, n_classes] for a (full or sampled-flat) graph."""
    x = batch["x"].astype(cfg.dtype)
    senders = batch["senders"]
    receivers = batch["receivers"]
    h = _mlp(params["encoder"], x)
    agg = make_agg(cfg)

    def wrap(body):
        if cfg.remat:
            return jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        return body

    if cfg.arch == "egnn":
        pos = batch["pos"].astype(cfg.dtype)

        @wrap
        def body(carry, lp):
            h, pos = carry
            return egnn_layer(_unflatten2(lp), h, pos, senders, receivers, agg), None

        (h, pos), _ = jax.lax.scan(body, (h, pos), params["layers"],
                                   unroll=_unroll(cfg))
    elif cfg.arch == "schnet":
        pos = batch["pos"].astype(cfg.dtype)

        @wrap
        def body(carry, lp):
            h, pos = carry
            return schnet_layer(_unflatten2(lp), h, pos, senders, receivers,
                                cfg.n_rbf, cfg.cutoff, agg), None

        (h, pos), _ = jax.lax.scan(body, (h, pos), params["layers"],
                                   unroll=_unroll(cfg))
    elif cfg.arch == "graphsage":
        @wrap
        def body(carry, lp):
            return graphsage_layer(lp, carry, senders, receivers,
                                   cfg.aggregator, agg), None

        h, _ = jax.lax.scan(body, h, params["layers"], unroll=_unroll(cfg))
    else:  # graphcast
        if "edge_feat" in batch:
            ef = batch["edge_feat"].astype(cfg.dtype)
        else:
            ef = jnp.zeros((senders.shape[0], cfg.d_edge), cfg.dtype)
        e = _mlp(params["edge_encoder"], ef)

        @wrap
        def body(carry, lp):
            h, e = carry
            return graphcast_layer(_unflatten2(lp), h, e, senders,
                                   receivers, agg), None

        (h, e), _ = jax.lax.scan(body, (h, e), params["layers"],
                                 unroll=_unroll(cfg))

    return _mlp(params["decoder"], h)


def forward_minibatch(params: dict, batch: dict, cfg: GNNConfig) -> jnp.ndarray:
    """Fan-out minibatch forward (GraphSAGE-style; regular blocks).

    batch: seed_x [B, F]; layer_x: list of [B, W_h, F] with W_h =
    prod(fanouts[:h+1]); mask: list of [B, W_h] validity.  Aggregation
    bottom-up: hop H-1 aggregates hop H by reshape-mean over the fan-out —
    no ragged ops, MXU-friendly.
    """
    hops = [batch["seed_x"]] + list(batch["layer_x"])
    masks = [None] + list(batch.get("layer_mask", [None] * (len(hops) - 1)))
    hs = [_mlp(params["encoder"], h.astype(cfg.dtype)) for h in hops]
    layers = params["layers"]
    L = len(hops) - 1
    for li in range(L):
        lp = {k: v[li] for k, v in layers.items()}
        new_hs = []
        for depth in range(len(hs) - 1):
            cur, child = hs[depth], hs[depth + 1]
            B = cur.shape[0]
            W_cur = 1 if cur.ndim == 2 else cur.shape[1]
            child3 = child.reshape(B, W_cur, -1, child.shape[-1])
            m = masks[depth + 1]
            if m is not None:
                m3 = m.reshape(B, W_cur, -1, 1).astype(cfg.dtype)
                nbr = (child3 * m3).sum(2) / jnp.maximum(m3.sum(2), 1.0)
            else:
                nbr = child3.mean(2)
            if cur.ndim == 2:
                nbr = nbr[:, 0]
            h_new = jax.nn.relu(
                cur @ lp["w_self"] + nbr @ lp["w_nbr"] + lp["b"])
            new_hs.append(h_new)
        hs = new_hs
    return _mlp(params["decoder"], hs[0])


def loss_fn(params, batch, cfg: GNNConfig) -> jnp.ndarray:
    if "seed_x" in batch:
        logits = forward_minibatch(params, batch, cfg)
        labels = batch["labels"]
    elif batch["x"].ndim == 3:  # batched small graphs (molecule)
        logits = jax.vmap(lambda b: forward(params, b, cfg))(
            {k: batch[k] for k in batch if k != "labels"})
        logits = logits.mean(axis=1)  # graph-level readout
        labels = batch["labels"]
    else:
        logits = forward(params, batch, cfg)
        labels = batch["labels"]
    if labels.dtype in (jnp.float32, jnp.bfloat16, jnp.float16):
        # regression (molecule targets)
        return jnp.mean((logits[..., 0] - labels) ** 2)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[..., None],
                               -1)[..., 0]
    mask = labels >= 0
    return jnp.sum((logz - gold) * mask) / jnp.maximum(mask.sum(), 1)
