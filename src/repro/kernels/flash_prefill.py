"""Pallas TPU kernel: causal flash attention for prefill.

The §Roofline analysis shows every prefill cell memory-bound on
attention-score HBM round-trips in the jnp blockwise fallback
(EXPERIMENTS.md): scores [bq, S] are written + read per block.  This
kernel keeps them in VMEM — the classic flash pattern, with the kv-block
loop innermost so the online-softmax state never leaves scratch:

  q     [B, S, KV, G, hd]    grouped queries (GQA layout)
  k, v  [B, S, KV, hd]
  out   [B, S, KV, G, hd]

Grid (B, KV, n_q_blocks, n_kv_blocks); causal masking prunes nothing at
the grid level (simplicity) but masks in-kernel; the q-block loop carries
(m, l, acc) scratch like kernels/decode_attention.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, block_q: int, block_k: int, n_kv: int, window: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, :, 0].astype(jnp.float32)       # [bq, G, hd]
    k = k_ref[0, :, 0].astype(jnp.float32)       # [bk, hd]
    v = v_ref[0, :, 0].astype(jnp.float32)       # [bk, hd]
    hd = q.shape[-1]

    q_pos = qi * block_q + jax.lax.iota(jnp.int32, block_q)
    k_pos = ki * block_k + jax.lax.iota(jnp.int32, block_k)

    s = jnp.einsum("qgh,th->gqt", q, k) / (hd ** 0.5)   # [G, bq, bk]
    mask = k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        mask &= (q_pos[:, None] - k_pos[None, :]) < window
    s = jnp.where(mask[None], s, -1e30)

    m_prev = m_ref[...]                                  # [G, bq]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[..., None])                    # [G, bq, bk]
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[..., None] + jnp.einsum(
        "gqt,th->gqh", p, v)
    m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _final():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[..., None]
        o_ref[0, :, 0] = out.transpose(1, 0, 2).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_q", "block_k",
                                             "window", "interpret"))
def flash_prefill_pallas(
    q: jnp.ndarray,        # [B, S, KV, G, hd]
    k: jnp.ndarray,        # [B, S, KV, hd]
    v: jnp.ndarray,        # [B, S, KV, hd]
    block_q: int = 128,
    block_k: int = 128,
    window: int = 0,
    interpret: bool = True,
) -> jnp.ndarray:
    B, S, KV, G, hd = q.shape
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    n_q, n_kv = S // block_q, S // block_k

    grid = (B, KV, n_q, n_kv)
    kernel = functools.partial(_kernel, block_q=block_q, block_k=block_k,
                               n_kv=n_kv, window=window)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, G, hd),
                         lambda b, h, qi, ki: (b, qi, h, 0, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda b, h, qi, ki: (b, ki, h, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda b, h, qi, ki: (b, ki, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, G, hd),
                               lambda b, h, qi, ki: (b, qi, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, KV, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, block_q), jnp.float32),       # running max
            pltpu.VMEM((G, block_q), jnp.float32),       # running sum
            pltpu.VMEM((G, block_q, hd), jnp.float32),   # accumulator
        ],
        interpret=interpret,
    )(q, k, v)
    return out
