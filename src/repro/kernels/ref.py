"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def path_latency_ref(home: jnp.ndarray, masks: jnp.ndarray,
                     lengths: jnp.ndarray) -> jnp.ndarray:
    """Oracle for kernels.path_latency: same packed-mask semantics.

    home [P, L] int32; masks [P, L, W] uint32; lengths [P] -> int32 [P].
    """
    P, L = home.shape

    def step(carry, xs):
        server, cost, i = carry
        home_i, mask_i = xs          # [P], [P, W]
        valid = (i < lengths) & (lengths > 0)
        widx = server // 32
        bit = (server % 32).astype(jnp.uint32)
        word = jnp.take_along_axis(mask_i, widx[:, None], axis=1)[:, 0]
        local = ((word >> bit) & jnp.uint32(1)).astype(bool)
        nxt = jnp.where(local, server, jnp.maximum(home_i, 0))
        nxt = jnp.where(valid, nxt, server)
        cost = cost + (valid & ~local).astype(jnp.int32)
        return (nxt, cost, i + 1), None

    server0 = jnp.maximum(home[:, 0], 0)
    init = (server0, jnp.zeros((P,), jnp.int32), jnp.int32(1))
    (_, cost, _), _ = jax.lax.scan(
        step, init, (home[:, 1:].swapaxes(0, 1), masks[:, 1:].swapaxes(0, 1)))
    return cost


def decode_attention_ref(q, k, v, lengths):
    """Oracle for kernels.decode_attention (plain masked softmax).

    q [B, KV, G, hd]; k/v [B, T, KV, hd]; lengths [B] -> [B, KV, G, hd].
    """
    B, KV, G, hd = q.shape
    T = k.shape[1]
    s = jnp.einsum("bkgh,btkh->bkgt", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (hd ** 0.5)
    mask = jnp.arange(T)[None, :] < lengths[:, None]       # [B, T]
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,btkh->bkgh", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def embedding_bag_ref(table, ids, mode="mean"):
    """Oracle for kernels.embedding_bag.  ids [B, L] (-1 pad) -> [B, d]."""
    rows = jnp.take(table, jnp.maximum(ids, 0), axis=0)    # [B, L, d]
    m = (ids >= 0).astype(jnp.float32)[..., None]
    s = (rows.astype(jnp.float32) * m).sum(axis=1)
    if mode == "mean":
        s = s / jnp.maximum(m.sum(axis=1), 1.0)
    return s


def flash_prefill_ref(q, k, v, window: int = 0):
    """Oracle for kernels.flash_prefill: causal (optionally windowed)
    attention.  q [B,S,KV,G,hd]; k/v [B,S,KV,hd] -> [B,S,KV,G,hd]."""
    B, S, KV, G, hd = q.shape
    s_ = jnp.einsum("bqkgh,btkh->bkgqt", q.astype(jnp.float32),
                    k.astype(jnp.float32)) / (hd ** 0.5)
    qp = jnp.arange(S)
    mask = qp[None, :] >= qp[:, None]  # k_pos <= q_pos (transposed below)
    mask = qp[:, None] >= qp[None, :]
    if window > 0:
        mask &= (qp[:, None] - qp[None, :]) < window
    s_ = jnp.where(mask[None, None, None], s_, -1e30)
    p = jax.nn.softmax(s_, axis=-1)
    out = jnp.einsum("bkgqt,btkh->bqkgh", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
