"""Pallas TPU kernel: EmbeddingBag (recsys lookup hot path).

The TPU TBE pattern: a scalar-prefetched index array drives the BlockSpec
index_map of the table operand, so each grid step DMAs exactly the one
table row it needs from HBM into VMEM (no host gather, no [B*L, d]
materialization).  Grid = (bags, bag_len); the output block is revisited
across the bag_len dimension and accumulates in place; a VMEM scratch
carries the per-bag valid-count for mean pooling.

  ids    int32 [B * L]   flattened bag members (-1 = padding slot)
  table  f32   [N, d]
  out    f32   [B, d]    sum- or mean-pooled rows
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(ids_ref, row_ref, o_ref, cnt_ref, *, bag_len: int, mean: bool):
    b = pl.program_id(0)
    l = pl.program_id(1)

    @pl.when(l == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    idx = ids_ref[b * bag_len + l]
    valid = (idx >= 0).astype(jnp.float32)
    o_ref[...] += row_ref[...].astype(jnp.float32) * valid
    cnt_ref[...] += valid

    if mean:
        @pl.when(l == bag_len - 1)
        def _norm():
            o_ref[...] = o_ref[...] / jnp.maximum(cnt_ref[...], 1.0)


@functools.partial(jax.jit, static_argnames=("mode", "interpret"))
def embedding_bag_pallas(
    table: jnp.ndarray,   # [N, d]
    ids: jnp.ndarray,     # int32 [B, L]  (-1 padding)
    mode: str = "mean",
    interpret: bool = True,
) -> jnp.ndarray:
    B, L = ids.shape
    N, d = table.shape
    flat = ids.reshape(-1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, L),
        in_specs=[
            # one table row per grid step, row chosen by the prefetched ids
            pl.BlockSpec(
                (1, d), lambda b, l, ids_ref: (jnp.maximum(
                    ids_ref[b * L + l], 0), 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda b, l, ids_ref: (b, 0)),
        scratch_shapes=[pltpu.VMEM((1, 1), jnp.float32)],
    )
    kernel = functools.partial(_kernel, bag_len=L, mean=(mode == "mean"))
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, d), jnp.float32),
        interpret=interpret,
    )(flat, table)
    return out
