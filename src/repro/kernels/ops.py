"""Jit'd public wrappers for the Pallas kernels.

Selects interpret mode automatically (Pallas executes the kernel body in
Python on CPU; compiled Mosaic on TPU), and adapts framework-level data
structures (PathSet + ReplicationScheme) to kernel inputs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.embedding_bag import embedding_bag_pallas
from repro.kernels.flash_prefill import flash_prefill_pallas
from repro.kernels.path_latency import path_latency_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def path_latency(pathset, scheme, block: int = 128) -> np.ndarray:
    """Kernel-backed h(p, r, rho) for a PathSet + ReplicationScheme."""
    packed = scheme.pack()                       # [n_obj, W] uint32
    objs = np.maximum(pathset.objects, 0)
    home = np.where(pathset.objects >= 0,
                    scheme.shard[objs], -1).astype(np.int32)
    masks = packed[objs]                         # [P, L, W]
    out = path_latency_pallas(
        jnp.asarray(home), jnp.asarray(masks),
        jnp.asarray(pathset.lengths), block=block,
        interpret=not _on_tpu())
    return np.asarray(out)


def decode_attention(q, k, v, lengths, block_t: int = 256):
    """Flash-decode GQA attention (see kernels.decode_attention)."""
    return decode_attention_pallas(
        q, k, v, lengths, block_t=block_t, interpret=not _on_tpu())


def embedding_bag(table, ids, mode: str = "mean"):
    """TBE-style embedding bag (see kernels.embedding_bag)."""
    return embedding_bag_pallas(table, ids, mode=mode,
                                interpret=not _on_tpu())


def flash_prefill(q, k, v, block_q: int = 128, block_k: int = 128,
                  window: int = 0):
    """Causal flash attention for prefill (see kernels.flash_prefill)."""
    return flash_prefill_pallas(q, k, v, block_q=block_q, block_k=block_k,
                                window=window, interpret=not _on_tpu())
