"""Jit'd public wrappers for the Pallas kernels.

Selects interpret mode automatically (Pallas executes the kernel body in
Python on CPU; compiled Mosaic on TPU), and adapts framework-level data
structures (PathSet + ReplicationScheme) to kernel inputs.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.embedding_bag import embedding_bag_pallas
from repro.kernels.flash_prefill import flash_prefill_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def path_latency(pathset, scheme, block: int = 128) -> np.ndarray:
    """Kernel-backed h(p, r, rho) for a PathSet + ReplicationScheme.

    Thin wrapper over the unified engine's ``pallas`` backend: the packed
    scheme is uploaded once and the kernel inputs (home servers + replica
    words per position) are gathered on device, instead of the former
    host-side ``[P, L, W]`` gather + transfer.
    """
    from repro.engine import LatencyEngine  # lazy: keep kernels importable alone

    eng = LatencyEngine(scheme, backend="pallas", block=block)
    return eng.path_latencies(pathset)


def decode_attention(q, k, v, lengths, block_t: int = 256):
    """Flash-decode GQA attention (see kernels.decode_attention)."""
    return decode_attention_pallas(
        q, k, v, lengths, block_t=block_t, interpret=not _on_tpu())


def embedding_bag(table, ids, mode: str = "mean"):
    """TBE-style embedding bag (see kernels.embedding_bag)."""
    return embedding_bag_pallas(table, ids, mode=mode,
                                interpret=not _on_tpu())


def flash_prefill(q, k, v, block_q: int = 128, block_k: int = 128,
                  window: int = 0):
    """Causal flash attention for prefill (see kernels.flash_prefill)."""
    return flash_prefill_pallas(q, k, v, block_q=block_q, block_k=block_k,
                                window=window, interpret=not _on_tpu())
