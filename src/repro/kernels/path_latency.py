"""Pallas TPU kernel: path latency h(p, r, rho) (paper Eqns 1-2).

This is the replication algorithm's analysis hot loop: the paper's Table 4
runtimes are dominated by evaluating the latency of millions-to-billions of
causal access paths against the current replication scheme.  The kernel
evaluates a block of paths per grid step entirely in VMEM.

Layout (TPU-native):  the *path* dimension is the 128-wide lane axis, so
every op in the position loop is a full-width vector op:

  home  int32  [L, bP]     home server of the object at each position
                           (-1 padded); bP = 128-aligned path block
  masks uint32 [L, W, bP]  packed replica-location words per position
                           (W = ceil(S/32) words, bit s of word w set iff
                           a copy lives on server 32w+s)
  lens  int32  [bP]        path lengths
  out   int32  [bP]        distributed traversals per path

Per position i (fori_loop, vectorized across the 128 path lanes):
  local  = bit test of masks[i] at the current server
  server = local ? server : home[i]
  cost  += valid(i) & ~local

The word select is a W-way static unroll of lane-wise `where` — no
gather needed, and W <= 16 for 512 servers.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK = 128


def _kernel(home_ref, mask_ref, len_ref, out_ref):
    L = home_ref.shape[0]
    W = mask_ref.shape[1]
    home = home_ref[...]          # [L, bP]
    masks = mask_ref[...]         # [L, W, bP]
    lens = len_ref[...]           # [bP]

    server0 = jnp.maximum(home[0], 0)

    def body(i, carry):
        server, cost = carry
        valid = (i < lens) & (lens > 0)
        widx = server // 32
        bit = (server % 32).astype(jnp.uint32)
        word = jnp.zeros_like(masks[0, 0])
        for w in range(W):        # static unroll (W small)
            word = jnp.where(widx == w, masks[i, w], word)
        local = ((word >> bit) & jnp.uint32(1)).astype(jnp.bool_)
        nxt = jnp.where(local, server, jnp.maximum(home[i], 0))
        nxt = jnp.where(valid, nxt, server)
        cost = cost + (valid & ~local).astype(jnp.int32)
        return nxt, cost

    _, cost = jax.lax.fori_loop(
        1, L, body, (server0, jnp.zeros_like(server0)))
    out_ref[...] = cost


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def path_latency_pallas(
    home: jnp.ndarray,    # int32 [P, L]  home server per position (-1 pad)
    masks: jnp.ndarray,   # uint32 [P, L, W]  packed replica words
    lengths: jnp.ndarray,  # int32 [P]
    block: int = DEFAULT_BLOCK,
    interpret: bool = True,
) -> jnp.ndarray:
    """Distributed-traversal count per path; see module docstring.

    Host-side API keeps the natural [P, L] layout; the kernel uses the
    lane-transposed layout.  ``interpret=True`` for CPU validation; on TPU
    pass False.
    """
    P, L = home.shape
    W = masks.shape[2]
    pad = (-P) % block
    if pad:
        home = jnp.pad(home, ((0, pad), (0, 0)), constant_values=-1)
        masks = jnp.pad(masks, ((0, pad), (0, 0), (0, 0)))
        lengths = jnp.pad(lengths, (0, pad))
    Pp = P + pad
    home_t = home.T                          # [L, Pp]
    masks_t = jnp.transpose(masks, (1, 2, 0))  # [L, W, Pp]

    grid = (Pp // block,)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((L, block), lambda p: (0, p)),
            pl.BlockSpec((L, W, block), lambda p: (0, 0, p)),
            pl.BlockSpec((block,), lambda p: (p,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda p: (p,)),
        out_shape=jax.ShapeDtypeStruct((Pp,), jnp.int32),
        interpret=interpret,
    )(home_t, masks_t, lengths)
    return out[:P]
