"""Pallas TPU megakernel: one fused greedy-UPDATE round (Alg 2 hot loop).

One ``pallas_call`` evaluates, per 128-path lane block, everything the
separate-dispatch driver used to round-trip through four kernels:

  1. the policy-routed gate walk h(p, r, rho; policy) against the packed
     snapshot (the ``kernels.routed_walk`` body, inlined),
  2. the server-local subpath structure under d (Def 5.1),
  3. every C(h, t) candidate's upward-replication interval mask, bit-tested
     against the holder words (which additions are actually *needed*),
  4. the per-candidate marginal cost + running argmin (ties -> lowest
     candidate index, the driver's determinism rule).

The chosen additions leave the kernel as an ``[L, H+1]`` plane per path;
the wrapper applies them with the engine's ``scatter_or_pairs`` in the
same jit (the scatter's per-bit dynamic updates are XLA's strength and a
lane-parallel kernel's weakness — a per-lane scatter would serialize into
scalar stores on TPU).  Cost / infeasibility / gate-skip statistics reduce
on device; the driver reads one tiny accumulator per budget class instead
of three arrays per batch.

Layout (TPU-native, as in ``routed_walk``): paths on the 128-wide lane
axis; holder bits unpack to ``[W*32, bP]`` planes; all candidate logic is
full-width vector ops over the lanes.  ``interpret=True`` on CPU.

Bit-identity contract: every intermediate mirrors
``repro.core.greedy._update_batch_core`` op-for-op (same clipping, same
scatter-max subpath servers, same strict-argmin tie rule), and the gate
walk reuses ``routed_walk``'s ``_pick`` — the three-backend parity matrix
of ``tests/test_provision_scale.py`` pins fused == separate == reference
on every routing policy.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.routed_walk import _pick, _unpack

DEFAULT_BLOCK = 128
_INF = 1e30  # plain float: a jnp scalar here would be a captured kernel constant


def _make_kernel(
    L: int,
    W: int,
    Hc: int,
    C: int,
    Hp1: int,
    gate_mode: str,   # "none" | "routed" | "scored"
    lookahead: bool,
):
    Sp = W * 32

    def kernel(home_ref, mask_ref, len_ref, t_ref, f_ref, start_ref,
               rank_ref, tab_ref, cnt_ref,
               chosen_ref, srv_ref, cost_ref, nosol_ref, skip_ref):
        home = home_ref[...]          # int32 [L, bP] (-1 at pad positions)
        lens = len_ref[...]           # int32 [bP]
        t = t_ref[...]                # int32 [bP]
        fpos = f_ref[...]             # f32 [L, bP] (0 at pad positions)
        bP = lens.shape[0]
        iota_l = jnp.arange(L, dtype=jnp.int32)[:, None]      # [L, 1]
        iota_s = jnp.arange(Sp, dtype=jnp.int32)[:, None]     # [Sp, 1]
        valid = iota_l < lens[None, :]                        # [L, bP]

        # ---- subpath structure under d (Def 5.1) ----
        prev = jnp.concatenate(
            [jnp.full((1, bP), -2, jnp.int32), home[:-1]], axis=0
        )
        boundary = valid & (iota_l > 0) & (home != prev)
        seg = jnp.cumsum(boundary.astype(jnp.int32), axis=0)
        seg = jnp.where(valid, seg, -1)
        h = jnp.max(jnp.where(valid, seg, 0), axis=0)         # [bP]
        h_cl = jnp.clip(h, 0, Hp1 - 1)
        seg_cl = jnp.clip(seg, 0, Hp1 - 1)

        # server of each subpath (scatter-max twin: positions of a subpath
        # share one home; absent subpaths -> -1)
        srv = jnp.stack(
            [
                jnp.max(
                    jnp.where(valid & (seg == k), home + 1, 0), axis=0
                ) - 1
                for k in range(Hp1)
            ]
        )  # int32 [Hp1, bP]

        # ---- policy-routed gate walk (the routed_walk body, inlined) ----
        if gate_mode == "none":
            h_routed = jnp.zeros_like(h)
        else:
            start = start_ref[...]
            server0 = jnp.where(lens > 0, start, 0).astype(jnp.int32)
            if gate_mode == "routed":
                rank = rank_ref[...]          # f32 [Sp]

            def gate_body(i, carry):
                server, cnt = carry
                v = i < lens
                bits = _unpack(mask_ref[i])   # [Sp, bP]
                srv_oh = iota_s == jnp.maximum(server, 0)[None, :]
                local = (bits & srv_oh).any(axis=0) & (server >= 0)
                if gate_mode == "scored":
                    tgt, any_c = _pick(bits, home[i], rank_ref[i], iota_s)
                    tgt = jnp.where(any_c, tgt, -1)
                else:
                    tgt, any_c = _pick(bits, home[i], rank, iota_s)
                    tgt = jnp.where(any_c, tgt, -1)
                    if lookahead:
                        nxt_ok = (i + 1) < lens
                        nbits = _unpack(mask_ref[jnp.minimum(i + 1, L - 1)])
                        la = bits & nbits & nxt_ok[None, :]
                        la_tgt, la_any = _pick(la, home[i], rank, iota_s)
                        tgt = jnp.where(la_any, la_tgt, tgt)
                nxt = jnp.where(local, server, tgt).astype(jnp.int32)
                nxt = jnp.where(v, nxt, server)
                cnt = cnt + ((~local) & v).astype(jnp.int32)
                return nxt, cnt

            _, h_routed = jax.lax.fori_loop(
                1, L, gate_body, (server0, jnp.zeros_like(lens))
            )

        over = h > t
        if gate_mode == "none":
            gate_ok = over
            skipped = jnp.zeros_like(over)
        else:
            gate_ok = over & (h_routed > t)
            skipped = over & (h_routed <= t)

        # ---- needed(x, k): no copy of objects[x] at srv[k] yet ----
        masks_all = mask_ref[...]             # uint32 [L, W, bP]
        srv_c = jnp.maximum(srv, 0)
        w_idx = srv_c // 32                   # [Hp1, bP]
        b_idx = (srv_c % 32).astype(jnp.uint32)
        word = jnp.zeros((L, Hp1, bP), jnp.uint32)
        for w in range(W):
            word = jnp.where(
                (w_idx == w)[None, :, :], masks_all[:, w][:, None, :], word
            )
        present = ((word >> b_idx[None, :, :]) & jnp.uint32(1)).astype(
            jnp.bool_
        )
        needed = (~present) & (srv >= 0)[None, :, :] & valid[:, None, :]

        # ---- candidate loop: running strict argmin (ties -> lowest c) ----
        onehot_h = (
            jnp.arange(Hc, dtype=jnp.int32)[:, None] == h_cl[None, :]
        )  # [Hc, bP]
        n_cand = jnp.sum(
            jnp.where(onehot_h, cnt_ref[...][:, None], 0), axis=0
        )  # int32 [bP]
        tab = tab_ref[...]                    # int32 [Hc, C, Hp1]
        k_r = jnp.arange(Hp1, dtype=jnp.int32)[None, :, None]

        def cand_body(c, carry):
            best_cost, chosen = carry
            tab_c = jax.lax.dynamic_index_in_dim(
                tab, c, axis=1, keepdims=False
            )  # [Hc, Hp1]
            sel = (
                jnp.sum(
                    tab_c[:, :, None] * onehot_h[:, None, :].astype(jnp.int32),
                    axis=0,
                )
                > 0
            )  # [Hp1, bP]
            run = jnp.full((bP,), -1, jnp.int32)
            prev_sel = []
            for k in range(Hp1):
                run = jnp.where(sel[k], k, run)
                prev_sel.append(run)
            j_of_x = jnp.zeros((L, bP), jnp.int32)
            for k in range(Hp1):
                j_of_x = jnp.where(seg_cl == k, prev_sel[k][None, :], j_of_x)
            window = (
                (k_r >= j_of_x[:, None, :])
                & (k_r < seg_cl[:, None, :])
                & valid[:, None, :]
                & gate_ok[None, None, :]
            )
            add = window & needed             # [L, Hp1, bP]
            cost_c = jnp.sum(
                add.astype(jnp.float32) * fpos[:, None, :], axis=(0, 1)
            )
            cost_c = jnp.where(c < n_cand, cost_c, _INF)
            better = cost_c < best_cost
            chosen = jnp.where(better[None, None, :], add, chosen)
            best_cost = jnp.where(better, cost_c, best_cost)
            return best_cost, chosen

        best_cost, chosen = jax.lax.fori_loop(
            0,
            C,
            cand_body,
            (
                jnp.full((bP,), _INF, jnp.float32),
                jnp.zeros((L, Hp1, bP), jnp.bool_),
            ),
        )
        no_sol = best_cost >= _INF
        chosen = chosen & ~no_sol[None, None, :]

        chosen_ref[...] = chosen.astype(jnp.int32)
        srv_ref[...] = srv
        cost_ref[...] = best_cost
        nosol_ref[...] = no_sol.astype(jnp.int32)
        skip_ref[...] = skipped.astype(jnp.int32)

    return kernel


def fused_update_pallas(
    words: jnp.ndarray,    # uint32 [(n+1), W] — packed scheme snapshot
    objects: jnp.ndarray,  # int32 [B, L] (-1 padded)
    lengths: jnp.ndarray,  # int32 [B]
    shard: jnp.ndarray,    # int32 [n]
    f: jnp.ndarray,        # float32 [n]
    tables: jnp.ndarray,   # bool [Hc, C, Hp1] candidate retained-sets
    counts: jnp.ndarray,   # int32 [Hc]
    t: jnp.ndarray,        # int32 [B] per-path budgets
    rank: jnp.ndarray,     # float32 [W*32] holder-rank (queue_aware load)
    pol=None,              # resolved RoutingPolicy or None (jit static)
    block: int = DEFAULT_BLOCK,
    interpret: bool = True,
):
    """One fused UPDATE round; traceable (callers jit + donate ``words``).

    Returns ``(words, applied_cost [B], no_solution [B], chosen
    [B, L, Hp1], srv [B, Hp1], skipped [B])`` — the
    ``_update_batch_core`` contract minus capacity/load bookkeeping
    (the driver falls back to the jnp core when capacity checking is on).
    """
    B, L = objects.shape
    W = words.shape[1]
    Hc, C, Hp1 = tables.shape
    Sp = W * 32

    valid = jnp.arange(L)[None, :] < lengths[:, None]
    safe = jnp.maximum(objects, 0)
    home = jnp.where(valid, shard[safe], -1).astype(jnp.int32)
    wrows = words[safe]                                   # [B, L, W]
    fpos = f[safe] * valid.astype(jnp.float32)
    start = shard[jnp.maximum(objects[:, 0], 0)].astype(jnp.int32)

    if pol is None:
        gate_mode, lookahead = "none", False
        rank_in = rank
        rank_spec = pl.BlockSpec((Sp,), lambda p: (0,))
    elif pol.name == "nearest_copy_dp":
        from repro.engine.backends import _dp_depth, _dp_score_tables

        gate_mode, lookahead = "scored", False
        rank_in = _dp_score_tables(objects, lengths, words, _dp_depth(pol))
        rank_spec = pl.BlockSpec((L, Sp, block), lambda p: (0, 0, p))
    else:
        gate_mode, lookahead = "routed", bool(pol.lookahead)
        rank_in = rank
        rank_spec = pl.BlockSpec((Sp,), lambda p: (0,))

    pad = (-B) % block
    if pad:
        home = jnp.pad(home, ((0, pad), (0, 0)), constant_values=-1)
        wrows = jnp.pad(wrows, ((0, pad), (0, 0), (0, 0)))
        lengths = jnp.pad(lengths, (0, pad))
        t = jnp.pad(t, (0, pad))
        fpos = jnp.pad(fpos, ((0, pad), (0, 0)))
        start = jnp.pad(start, (0, pad))
        if gate_mode == "scored":
            rank_in = jnp.pad(rank_in, ((0, pad), (0, 0), (0, 0)))
    Bp = B + pad

    home_t = home.T                                       # [L, Bp]
    masks_t = jnp.transpose(wrows, (1, 2, 0))             # [L, W, Bp]
    fpos_t = fpos.T                                       # [L, Bp]
    if gate_mode == "scored":
        rank_in = jnp.transpose(rank_in, (1, 2, 0))       # [L, Sp, Bp]

    grid = (Bp // block,)
    chosen, srv, cost, nosol, skip = pl.pallas_call(
        _make_kernel(L, W, Hc, C, Hp1, gate_mode, lookahead),
        grid=grid,
        in_specs=[
            pl.BlockSpec((L, block), lambda p: (0, p)),
            pl.BlockSpec((L, W, block), lambda p: (0, 0, p)),
            pl.BlockSpec((block,), lambda p: (p,)),
            pl.BlockSpec((block,), lambda p: (p,)),
            pl.BlockSpec((L, block), lambda p: (0, p)),
            pl.BlockSpec((block,), lambda p: (p,)),
            rank_spec,
            pl.BlockSpec((Hc, C, Hp1), lambda p: (0, 0, 0)),
            pl.BlockSpec((Hc,), lambda p: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((L, Hp1, block), lambda p: (0, 0, p)),
            pl.BlockSpec((Hp1, block), lambda p: (0, p)),
            pl.BlockSpec((block,), lambda p: (p,)),
            pl.BlockSpec((block,), lambda p: (p,)),
            pl.BlockSpec((block,), lambda p: (p,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((L, Hp1, Bp), jnp.int32),
            jax.ShapeDtypeStruct((Hp1, Bp), jnp.int32),
            jax.ShapeDtypeStruct((Bp,), jnp.float32),
            jax.ShapeDtypeStruct((Bp,), jnp.int32),
            jax.ShapeDtypeStruct((Bp,), jnp.int32),
        ],
        interpret=interpret,
    )(home_t, masks_t, lengths, t, fpos_t, start, rank_in,
      tables.astype(jnp.int32), counts)

    chosen = jnp.transpose(chosen, (2, 0, 1))[:B].astype(bool)  # [B, L, Hp1]
    srv = srv.T[:B]                                             # [B, Hp1]
    cost = cost[:B]
    no_solution = nosol[:B].astype(bool)
    skipped = skip[:B].astype(bool)

    # scatter-OR in the same jit: XLA's bit-sliced dynamic-update rounds,
    # not a per-lane kernel scatter (which would serialize on TPU)
    from repro.engine.packed import scatter_or_pairs

    obj_w = jnp.where(chosen, jnp.maximum(objects, 0)[:, :, None], -1)
    srv_w = jnp.broadcast_to(jnp.maximum(srv, 0)[:, None, :], chosen.shape)
    words = scatter_or_pairs(words, obj_w, srv_w)

    applied_cost = jnp.where(no_solution, 0.0, cost)
    return words, applied_cost, no_solution, chosen, srv, skipped


@functools.partial(
    jax.jit, static_argnames=("pol", "block", "interpret"), donate_argnums=(0,)
)
def fused_update_jit(
    words, objects, lengths, shard, f, tables, counts, t, rank,
    pol=None, block: int = DEFAULT_BLOCK, interpret: bool = True,
):
    """Jitted standalone wrapper (tests / micro-benchmarks); the greedy
    driver uses its own enclosing jit with stat accumulators instead."""
    return fused_update_pallas(
        words, objects, lengths, shard, f, tables, counts, t, rank,
        pol=pol, block=block, interpret=interpret,
    )
