"""Pallas TPU kernel: policy-routed access walk (Eqn 1 + RoutingPolicy).

The twin of ``repro.kernels.path_latency`` for the policy-parameterized
walk (``repro.engine.routing``): instead of hardcoding ``home[obj]`` as
every remote hop's target, the kernel picks the target from the object's
packed holder words — least-loaded alive copy holder within the preferred
candidate class (holders of the *next* object first when ``lookahead``),
home winning ties, then lowest id.  It also returns the full per-position
trace (visited server + locality), which the serving layers decorate.

Layout (TPU-native, as in ``path_latency``): the *path* dimension is the
128-wide lane axis.

  home  int32  [L, bP]     per-position routing target (-1 padded)
  masks uint32 [L, W, bP]  packed replica-location words per position
  lens  int32  [bP]        path lengths
  start int32  [bP]        per-path start server
  load  f32    [Sp]        per-server queue depths, Sp = W*32 (bits past
                           n_servers are never set, so the pad is inert)
  out   int32  [L, bP] x2  visited server / locality per position

Per position the holder bits are unpacked to an [Sp, bP] plane and the
candidate argmin reduces over the sublane axis — every op is a full-width
vector op across the path lanes.  ``interpret=True`` on CPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK = 128


def _unpack(words):
    """[W, bP] uint32 -> [W*32, bP] bool holder bits."""
    W, bP = words.shape
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (words[:, None, :] >> shifts[None, :, None]) & jnp.uint32(1)
    return bits.reshape(W * 32, bP).astype(jnp.bool_)


def _pick(cand, home, load, iota_s):
    """Best-scoring candidate per lane; home wins ties, then lowest id.

    ``cand`` bool [Sp, bP], ``home`` int32 [bP], ``load`` f32 [Sp] (one
    shared rank per server) or f32 [Sp, bP] (a per-lane score plane — the
    DP cost-to-go of ``nearest_copy_dp``).  Returns (target int32 [bP] —
    garbage where no candidate —, any bool [bP]); the scalar twins are
    ``repro.engine.routing.pick_holder_host`` / ``pick_holder_scored``.
    """
    any_c = cand.any(axis=0)
    lv = jnp.where(cand, load[:, None] if load.ndim == 1 else load, jnp.inf)
    m = jnp.min(lv, axis=0)
    best = cand & (lv <= m[None, :])
    home_oh = iota_s == jnp.maximum(home, 0)[None, :]
    home_ok = (best & home_oh).any(axis=0) & (home >= 0)
    first = jnp.argmax(best, axis=0).astype(jnp.int32)
    return jnp.where(home_ok, home, first), any_c


def _make_kernel(L: int, W: int, lookahead: bool, home_first: bool):
    Sp = W * 32

    def kernel(home_ref, mask_ref, len_ref, start_ref, load_ref,
               srv_ref, loc_ref):
        home = home_ref[...]      # [L, bP]
        lens = len_ref[...]       # [bP]
        start = start_ref[...]    # [bP]
        load = load_ref[...]      # [Sp]
        iota_s = jnp.arange(Sp, dtype=jnp.int32)[:, None]
        iota_l = jnp.arange(L, dtype=jnp.int32)

        valid0 = lens > 0
        server0 = jnp.where(valid0, start, 0).astype(jnp.int32)
        srv_acc = jnp.broadcast_to(server0[None, :], (L, start.shape[0]))
        loc_acc = jnp.zeros((L, start.shape[0]), jnp.bool_)
        loc_acc = jnp.where((iota_l == 0)[:, None], valid0[None, :], loc_acc)

        def body(i, carry):
            server, srv_acc, loc_acc = carry
            valid = i < lens
            bits = _unpack(mask_ref[i])           # [Sp, bP]
            srv_oh = iota_s == jnp.maximum(server, 0)[None, :]
            local = (bits & srv_oh).any(axis=0) & (server >= 0)
            h_i = home[i]
            if home_first:
                tgt = h_i
            else:
                tgt, any_c = _pick(bits, h_i, load, iota_s)
                tgt = jnp.where(any_c, tgt, -1)
                if lookahead:
                    nxt_ok = (i + 1) < lens
                    nbits = _unpack(mask_ref[jnp.minimum(i + 1, L - 1)])
                    la = bits & nbits & nxt_ok[None, :]
                    la_tgt, la_any = _pick(la, h_i, load, iota_s)
                    tgt = jnp.where(la_any, la_tgt, tgt)
            nxt = jnp.where(local, server, tgt).astype(jnp.int32)
            nxt = jnp.where(valid, nxt, server)
            row = (iota_l == i)[:, None]
            srv_acc = jnp.where(row, nxt[None, :], srv_acc)
            loc_acc = jnp.where(row, (local & valid)[None, :], loc_acc)
            return nxt, srv_acc, loc_acc

        _, srv_acc, loc_acc = jax.lax.fori_loop(
            1, L, body, (server0, srv_acc, loc_acc)
        )
        srv_ref[...] = srv_acc
        loc_ref[...] = loc_acc.astype(jnp.int32)

    return kernel


@functools.partial(
    jax.jit,
    static_argnames=("block", "interpret", "lookahead", "home_first"),
)
def routed_walk_pallas(
    home: jnp.ndarray,     # int32 [P, L]  per-position target (-1 pad)
    masks: jnp.ndarray,    # uint32 [P, L, W]  packed replica words
    lengths: jnp.ndarray,  # int32 [P]
    start: jnp.ndarray,    # int32 [P]  start server per path
    load: jnp.ndarray,     # float32 [W*32]  per-server queue depths
    block: int = DEFAULT_BLOCK,
    interpret: bool = True,
    lookahead: bool = True,
    home_first: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(servers int32 [P, L], local bool [P, L]); see module docstring."""
    P, L = home.shape
    W = masks.shape[2]
    pad = (-P) % block
    if pad:
        home = jnp.pad(home, ((0, pad), (0, 0)), constant_values=-1)
        masks = jnp.pad(masks, ((0, pad), (0, 0), (0, 0)))
        lengths = jnp.pad(lengths, (0, pad))
        start = jnp.pad(start, (0, pad))
    Pp = P + pad
    home_t = home.T                            # [L, Pp]
    masks_t = jnp.transpose(masks, (1, 2, 0))  # [L, W, Pp]
    Sp = W * 32

    grid = (Pp // block,)
    srv, loc = pl.pallas_call(
        _make_kernel(L, W, lookahead, home_first),
        grid=grid,
        in_specs=[
            pl.BlockSpec((L, block), lambda p: (0, p)),
            pl.BlockSpec((L, W, block), lambda p: (0, 0, p)),
            pl.BlockSpec((block,), lambda p: (p,)),
            pl.BlockSpec((block,), lambda p: (p,)),
            pl.BlockSpec((Sp,), lambda p: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((L, block), lambda p: (0, p)),
            pl.BlockSpec((L, block), lambda p: (0, p)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((L, Pp), jnp.int32),
            jax.ShapeDtypeStruct((L, Pp), jnp.int32),
        ],
        interpret=interpret,
    )(home_t, masks_t, lengths, start, load)
    return srv.T[:P], loc.T[:P].astype(bool)


def _make_scored_kernel(L: int, W: int):
    """Score-parameterized walk: the ``nearest_copy_dp`` kernel twin.

    Identical to the routed kernel except the remote-hop pick ranks
    holders by a per-(position, server, path) score plane (the suffix-DP
    cost-to-go, precomputed on device) instead of a shared load vector.
    """
    Sp = W * 32

    def kernel(home_ref, mask_ref, len_ref, start_ref, score_ref,
               srv_ref, loc_ref):
        home = home_ref[...]      # [L, bP]
        lens = len_ref[...]       # [bP]
        start = start_ref[...]    # [bP]
        iota_s = jnp.arange(Sp, dtype=jnp.int32)[:, None]
        iota_l = jnp.arange(L, dtype=jnp.int32)

        valid0 = lens > 0
        server0 = jnp.where(valid0, start, 0).astype(jnp.int32)
        srv_acc = jnp.broadcast_to(server0[None, :], (L, start.shape[0]))
        loc_acc = jnp.zeros((L, start.shape[0]), jnp.bool_)
        loc_acc = jnp.where((iota_l == 0)[:, None], valid0[None, :], loc_acc)

        def body(i, carry):
            server, srv_acc, loc_acc = carry
            valid = i < lens
            bits = _unpack(mask_ref[i])           # [Sp, bP]
            srv_oh = iota_s == jnp.maximum(server, 0)[None, :]
            local = (bits & srv_oh).any(axis=0) & (server >= 0)
            tgt, any_c = _pick(bits, home[i], score_ref[i], iota_s)
            tgt = jnp.where(any_c, tgt, -1)
            nxt = jnp.where(local, server, tgt).astype(jnp.int32)
            nxt = jnp.where(valid, nxt, server)
            row = (iota_l == i)[:, None]
            srv_acc = jnp.where(row, nxt[None, :], srv_acc)
            loc_acc = jnp.where(row, (local & valid)[None, :], loc_acc)
            return nxt, srv_acc, loc_acc

        _, srv_acc, loc_acc = jax.lax.fori_loop(
            1, L, body, (server0, srv_acc, loc_acc)
        )
        srv_ref[...] = srv_acc
        loc_ref[...] = loc_acc.astype(jnp.int32)

    return kernel


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def scored_walk_pallas(
    home: jnp.ndarray,     # int32 [P, L]  per-position target (-1 pad)
    masks: jnp.ndarray,    # uint32 [P, L, W]  packed replica words
    lengths: jnp.ndarray,  # int32 [P]
    start: jnp.ndarray,    # int32 [P]  start server per path
    scores: jnp.ndarray,   # float32 [P, L, W*32]  per-position hop scores
    block: int = DEFAULT_BLOCK,
    interpret: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(servers int32 [P, L], local bool [P, L]); scored-pick walk."""
    P, L = home.shape
    W = masks.shape[2]
    pad = (-P) % block
    if pad:
        home = jnp.pad(home, ((0, pad), (0, 0)), constant_values=-1)
        masks = jnp.pad(masks, ((0, pad), (0, 0), (0, 0)))
        lengths = jnp.pad(lengths, (0, pad))
        start = jnp.pad(start, (0, pad))
        scores = jnp.pad(scores, ((0, pad), (0, 0), (0, 0)))
    Pp = P + pad
    home_t = home.T                              # [L, Pp]
    masks_t = jnp.transpose(masks, (1, 2, 0))    # [L, W, Pp]
    scores_t = jnp.transpose(scores, (1, 2, 0))  # [L, Sp, Pp]
    Sp = W * 32

    grid = (Pp // block,)
    srv, loc = pl.pallas_call(
        _make_scored_kernel(L, W),
        grid=grid,
        in_specs=[
            pl.BlockSpec((L, block), lambda p: (0, p)),
            pl.BlockSpec((L, W, block), lambda p: (0, 0, p)),
            pl.BlockSpec((block,), lambda p: (p,)),
            pl.BlockSpec((block,), lambda p: (p,)),
            pl.BlockSpec((L, Sp, block), lambda p: (0, 0, p)),
        ],
        out_specs=[
            pl.BlockSpec((L, block), lambda p: (0, p)),
            pl.BlockSpec((L, block), lambda p: (0, p)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((L, Pp), jnp.int32),
            jax.ShapeDtypeStruct((L, Pp), jnp.int32),
        ],
        interpret=interpret,
    )(home_t, masks_t, lengths, start, scores_t)
    return srv.T[:P], loc.T[:P].astype(bool)
