"""Pallas TPU kernel: GQA flash-decode attention.

Serving hot spot for the ``decode_32k`` / ``long_500k`` cells: one query
token attends over a long KV cache.  Grid is (batch, kv_head, kv_blocks)
with the kv-block dimension innermost so the online-softmax state lives in
VMEM scratch across blocks:

  q     [B, KV, G, hd]   G = query heads per kv head (GQA group)
  k, v  [B, T, KV, hd]   KV cache (T positions)
  lens  [B]              valid cache length per sequence
  out   [B, KV, G, hd]

Per kv block: s = q @ k_blk^T  ->  online max/sum accumulation  ->
acc = acc*alpha + exp(s - m_new) @ v_blk; the final block normalizes.
Block sizes: bT x hd tiles are MXU-aligned for hd in {64, 128}.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, block_t: int, n_blocks: int):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)          # [G, hd]
    k = k_ref[0, :, 0].astype(jnp.float32)       # [bT, hd]
    v = v_ref[0, :, 0].astype(jnp.float32)       # [bT, hd]
    length = len_ref[0]

    scale = 1.0 / (q.shape[-1] ** 0.5)
    s = (q @ k.T) * scale                        # [G, bT]
    pos = t * block_t + jax.lax.iota(jnp.int32, block_t)
    s = jnp.where((pos < length)[None, :], s, -1e30)

    m_prev = m_ref[...]                          # [G, 1]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                       # [G, bT]
    l_new = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + p @ v  # [G, hd]
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(t == n_blocks - 1)
    def _final():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(
            o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_t", "interpret"))
def decode_attention_pallas(
    q: jnp.ndarray,        # [B, KV, G, hd]
    k: jnp.ndarray,        # [B, T, KV, hd]
    v: jnp.ndarray,        # [B, T, KV, hd]
    lengths: jnp.ndarray,  # int32 [B]
    block_t: int = 256,
    interpret: bool = True,
) -> jnp.ndarray:
    B, KV, G, hd = q.shape
    T = k.shape[1]
    pad = (-T) % block_t
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Tp = T + pad
    n_blocks = Tp // block_t

    grid = (B, KV, n_blocks)
    kernel = functools.partial(_kernel, block_t=block_t, n_blocks=n_blocks)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, t: (b,)),
            pl.BlockSpec((1, 1, G, hd), lambda b, h, t: (b, h, 0, 0)),
            pl.BlockSpec((1, block_t, 1, hd), lambda b, h, t: (b, t, h, 0)),
            pl.BlockSpec((1, block_t, 1, hd), lambda b, h, t: (b, t, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, t: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),    # running max m
            pltpu.VMEM((G, 1), jnp.float32),    # running sum l
            pltpu.VMEM((G, hd), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(lengths, q, k, v)
    return out
