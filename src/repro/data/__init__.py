"""Synthetic sharded data pipelines."""
from repro.data.pipeline import Prefetcher, gnn_batch_fn, lm_batch_fn, shard_batch

__all__ = ["Prefetcher", "lm_batch_fn", "gnn_batch_fn", "shard_batch"]
