"""Synthetic sharded data pipeline with host prefetch.

Production shape without external deps: a deterministic generator produces
global batches (seeded per step — any host can regenerate any step, which
is what makes restart-from-checkpoint exact), a background thread prefetches
``prefetch`` batches ahead, and ``shard_batch`` places each global batch
onto the mesh with the training input shardings (device_put with
NamedSharding so the train step never blocks on host->device copies).
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator

import jax
import numpy as np
from jax.sharding import NamedSharding


def lm_batch_fn(vocab: int, batch: int, seq: int) -> Callable[[int], dict]:
    """Deterministic synthetic LM batches (seeded by step)."""

    def make(step: int) -> dict:
        rng = np.random.default_rng(step)
        toks = rng.integers(0, vocab, (batch, seq + 1), dtype=np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    return make


def gnn_batch_fn(graph, fanouts, batch_nodes: int, d_feat: int,
                 n_classes: int) -> Callable[[int], dict]:
    """Sampled-minibatch batches via the real neighbor sampler."""
    from repro.graph.sampler import minibatch_sampler

    def make(step: int) -> dict:
        rng = np.random.default_rng(step)
        seeds = rng.integers(0, graph.n_nodes, (batch_nodes,))
        mb = minibatch_sampler(graph, seeds, fanouts, seed=step)
        feat = lambda ids: rng.standard_normal(
            (*ids.shape, d_feat)).astype(np.float32)
        return {
            "seed_x": feat(mb.seeds),
            "layer_x": [feat(l) for l in mb.layer_nodes],
            "layer_mask": [(l >= 0) for l in mb.layer_nodes],
            "labels": rng.integers(0, n_classes, mb.seeds.shape).astype(np.int32),
        }

    return make


class Prefetcher:
    """Background-thread prefetch of ``make_batch(step)`` results."""

    def __init__(self, make_batch: Callable[[int], dict], start_step: int = 0,
                 prefetch: int = 2):
        self._make = make_batch
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            batch = self._make(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator[tuple[int, dict]]:
        while True:
            yield self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)


def shard_batch(batch: dict, mesh, specs: dict):
    """Place a host batch onto the mesh per the input PartitionSpecs."""
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), batch, specs)
