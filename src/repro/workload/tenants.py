"""Tenant registry: each workload family is one serving tenant.

The analyzers (``snb``/``gnn``/``recsys``) each declare a
:class:`~repro.core.slo.TenantSpec` with a distinct default latency budget
t_Q; this module stitches per-family workloads into one multi-tenant
workload — a concatenated :class:`~repro.core.paths.PathSet` plus the
aligned :class:`~repro.core.slo.SLOSpec` the greedy drivers, the engine's
feasibility path, and the serve-layer controller all consume.
"""
from __future__ import annotations

from typing import Mapping, Sequence

from repro.core.paths import PathSet
from repro.core.slo import SLOSpec, TenantSpec
from repro.workload import gnn, recsys, snb

FAMILY_TENANTS: dict[str, TenantSpec] = {
    "snb": snb.TENANT,
    "gnn": gnn.TENANT,
    "recsys": recsys.TENANT,
}


def tenant_spec(
    family: str,
    t_q: int | None = None,
    p99_slo_us: float | None = None,
) -> TenantSpec:
    """The family's declared tenant, optionally re-budgeted."""
    base = FAMILY_TENANTS[family]
    return TenantSpec(
        base.name,
        base.t_q if t_q is None else int(t_q),
        base.p99_slo_us if p99_slo_us is None else p99_slo_us,
    )


def multi_tenant_workload(
    parts: Sequence[tuple[str, PathSet]],
    budgets: Mapping[str, int] | None = None,
) -> tuple[PathSet, SLOSpec]:
    """Concatenate per-family workloads into (PathSet, aligned SLOSpec).

    ``parts`` is a sequence of (family, pathset); every query of a part is
    tagged with that family's tenant and gets the tenant's default t_Q
    (overridable per family via ``budgets``).  Query-id offsets of the
    returned spec match ``PathSet.concatenate``'s.
    """
    budgets = budgets or {}
    sections = []
    for family, ps in parts:
        ts = tenant_spec(family, budgets.get(family))
        sections.append(SLOSpec.uniform(ts.t_q, ps.n_queries, ts.name,
                                        ts.p99_slo_us))
    return (
        PathSet.concatenate([ps for _, ps in parts]),
        SLOSpec.concat(sections),
    )
