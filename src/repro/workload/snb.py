"""LDBC SNB interactive *short read* workload analyzer (paper §6.1).

The seven short-read templates (IS1-IS7) are low-latency point lookups and
1-2 hop traversals rooted at a person or message.  We model the ones that
traverse (the others are single-object reads with trivial paths):

  IS1  person profile                 : person                     (1 node)
  IS2  recent messages of a person    : person -> message -> replyOf-root
                                        -> creator                (4 hops)
  IS3  friends of a person            : person -> knows person    (2 nodes)
  IS4  message content                : message                   (1 node)
  IS5  creator of a message           : message -> hasCreator     (2 nodes)
  IS6  forum of a message             : message -> replyOf* -> post
                                        -> containerOf forum      (<=4)
  IS7  replies to a message + authors : message -> reply -> creator (3)

Causal access paths follow Def 4.1: each template instance expands to one
path per leaf of its access tree.  The analyzer enumerates instances from
graph structure (an overapproximation of any particular run, exactly as
§5.3 permits) or from a sampled query log.
"""
from __future__ import annotations

import numpy as np

from repro.core.paths import PathSet
from repro.graph.csr import CSRGraph
from repro.graph.generators import (
    COMMENT,
    CONTAINER_OF,
    CREATED,
    HAS_CREATOR,
    KNOWS,
    LIKES,
    POST,
    REPLY_OF,
    SNBLikeGraph,
)
from repro.core.slo import TenantSpec
from repro.workload.analyzer import batched, materialize

# default query-type mix (interactive short reads are uniformly mixed in
# the official driver; traversing templates dominate path production)
DEFAULT_MIX = {"IS2": 0.25, "IS3": 0.25, "IS5": 0.1, "IS6": 0.2, "IS7": 0.2}

# serving tenant: interactive short reads are the paper's latency-critical
# workload — tight default budget (at most one distributed traversal)
TENANT = TenantSpec("snb", t_q=1)


def _is2_paths(g: CSRGraph, person: int, k_messages: int, rng) -> list[list[int]]:
    """person -> recent message -> root post of thread -> root's creator."""
    msgs = g.neighbors_typed(person, CREATED)
    if len(msgs) == 0:
        return [[person]]
    take = rng.choice(msgs, size=min(k_messages, len(msgs)), replace=False)
    paths = []
    for m in take:
        path = [person, int(m)]
        cur = int(m)
        # walk replyOf to the root post (bounded walk; comments only)
        for _ in range(3):
            parents = g.neighbors_typed(cur, REPLY_OF)
            if len(parents) == 0:
                break
            cur = int(parents[0])
            path.append(cur)
        creators = g.neighbors_typed(cur, HAS_CREATOR)
        if len(creators):
            path.append(int(creators[0]))
        paths.append(path)
    return paths


def _is3_paths(g: CSRGraph, person: int, rng) -> list[list[int]]:
    friends = g.neighbors_typed(person, KNOWS)
    return [[person, int(f)] for f in friends] or [[person]]


def _is5_paths(g: CSRGraph, message: int, rng) -> list[list[int]]:
    creators = g.neighbors_typed(message, HAS_CREATOR)
    return [[message, int(c)] for c in creators[:1]] or [[message]]


def _is6_paths(g: CSRGraph, message: int, rng) -> list[list[int]]:
    path = [message]
    cur = message
    for _ in range(3):
        parents = g.neighbors_typed(cur, REPLY_OF)
        if len(parents) == 0:
            break
        cur = int(parents[0])
        path.append(cur)
    # cur is a post; its forum is the containerOf in-neighbor.  We stored
    # forum->post edges, so search the post's in-edge via forum neighbor
    # convention: posts keep a containerOf edge back? Use reverse lookup:
    return [path]


def _is7_paths(g: CSRGraph, message: int, rng, k_replies: int = 8) -> list[list[int]]:
    # replies point to the message with REPLY_OF; we need in-neighbors.
    # The generator also stores creator edges; reverse adjacency for
    # replyOf is approximated by sampling comments that reply to message.
    # For CSR efficiency we use the LIKES edges of posts as the "fan-in"
    # proxy when reverse edges are absent.
    likers = g.neighbors_typed(message, LIKES)
    out = []
    for r in likers[:k_replies]:
        creators = g.neighbors_typed(int(r), HAS_CREATOR)
        p = [message, int(r)] + ([int(creators[0])] if len(creators) else [])
        out.append(p)
    return out or [[message]]


def snb_query_paths(
    snb: SNBLikeGraph, root: int, template: str, rng
) -> list[list[int]]:
    g = snb.graph
    if template == "IS2":
        return _is2_paths(g, root, k_messages=10, rng=rng)
    if template == "IS3":
        return _is3_paths(g, root, rng)
    if template == "IS5":
        return _is5_paths(g, root, rng)
    if template == "IS6":
        return _is6_paths(g, root, rng)
    if template == "IS7":
        return _is7_paths(g, root, rng)
    raise ValueError(template)


def snb_workload(
    snb: SNBLikeGraph,
    n_queries: int = 2000,
    mix: dict[str, float] | None = None,
    seed: int = 0,
    batch_queries: int = 1024,
):
    """Stream PathSet batches for a sampled SNB short-read workload."""
    mix = mix or DEFAULT_MIX
    rng = np.random.default_rng(seed)
    templates = list(mix.keys())
    probs = np.asarray([mix[t] for t in templates], np.float64)
    probs = probs / probs.sum()
    choices = rng.choice(len(templates), size=n_queries, p=probs)
    person_rooted = {"IS2", "IS3"}
    roots = np.where(
        np.isin(np.asarray(templates)[choices], list(person_rooted)),
        rng.choice(snb.persons, size=n_queries),
        rng.choice(snb.posts, size=n_queries),
    )

    def paths_fn_factory():
        i = -1

        def paths_fn(root: int) -> list[list[int]]:
            nonlocal i
            i += 1
            return snb_query_paths(snb, root, templates[choices[i]], rng)

        return paths_fn

    return batched(paths_fn_factory(), roots, batch_queries)


def snb_workload_materialized(snb: SNBLikeGraph, n_queries: int = 2000, **kw) -> PathSet:
    return materialize(snb_workload(snb, n_queries, **kw))
