"""MoE expert-dispatch workload analyzer (beyond-paper application).

Token -> expert dispatch in expert-parallel serving is a 1-hop causal
access: the token's activations (at its data-parallel home) must reach the
servers holding its top-k experts.  Modeling experts as dataset objects and
dispatches as 1-hop paths lets the paper's algorithm decide *expert
replication*: hot experts get replicas on more servers, bounding the tail
number of remote dispatches per token — the same heavy-hitter effect
production MoE serving exploits with expert replication.

Object-id layout: [0, n_token_groups) are token-group objects (home =
their data shard); [n_token_groups, n_token_groups + n_experts) are expert
objects (home = expert-parallel shard).
"""
from __future__ import annotations

import numpy as np

from repro.core.paths import PathSet
from repro.workload.analyzer import batched, materialize


def moe_workload(
    n_token_groups: int,
    n_experts: int,
    top_k: int,
    n_queries: int = 2000,
    zipf_a: float = 1.2,
    seed: int = 0,
    batch_queries: int = 512,
):
    """Stream 1-hop dispatch paths: token_group -> expert (top-k)."""
    rng = np.random.default_rng(seed)
    groups = rng.integers(0, n_token_groups, size=n_queries)

    def paths_fn(group: int) -> list[list[int]]:
        # zipf-skewed expert popularity (router collapse in practice)
        experts = np.unique(rng.zipf(zipf_a, size=top_k) % n_experts)
        return [[group, int(n_token_groups + e)] for e in experts]

    return batched(paths_fn, groups, batch_queries)


def moe_workload_materialized(n_token_groups, n_experts, top_k, **kw) -> PathSet:
    return materialize(moe_workload(n_token_groups, n_experts, top_k, **kw))


def expert_shard(
    n_token_groups: int, n_experts: int, n_servers: int
) -> np.ndarray:
    """Default sharding: token groups round-robin; experts round-robin."""
    d = np.empty((n_token_groups + n_experts,), np.int32)
    d[:n_token_groups] = np.arange(n_token_groups) % n_servers
    d[n_token_groups:] = np.arange(n_experts) % n_servers
    return d
