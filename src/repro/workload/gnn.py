"""GNN node-wise neighborhood-sampling workload analyzer (paper §6.1).

"Sampling queries require no more than 2 hops since the vertices in the
3rd-hop can be sampled from the adjacency list of the 2nd-hop vertex."

The causal access tree of one sampling query rooted at seed s with fan-outs
(f1, f2, f3):  s -> v1 (25 of them) -> v2 (10 each); the 3rd hop reads v2's
adjacency list which is part of v2's object.  Root-to-leaf causal access
paths are the chains s -> v1 -> v2.

The analyzer enumerates an overapproximation: for each seed it emits paths
through *all* neighbors up to a cap (replication must cover any random
draw), or through sampled draws when ``exact_draws`` is set (matching one
concrete epoch as the paper's trace-based analyzer does).
"""
from __future__ import annotations

import numpy as np

from repro.core.paths import PathSet
from repro.core.slo import TenantSpec
from repro.graph.csr import CSRGraph
from repro.workload.analyzer import batched, materialize

# serving tenant: sampling feeds training throughput, not an interactive
# user — loosest default budget of the three families
TENANT = TenantSpec("gnn", t_q=2)


def gnn_query_paths(
    g: CSRGraph,
    seed_node: int,
    fanouts: tuple[int, ...] = (25, 10),
    rng: np.random.Generator | None = None,
    cap_per_hop: tuple[int, ...] | None = None,
) -> list[list[int]]:
    """Paths of one sampling query (2 causal hops, per the paper)."""
    caps = cap_per_hop or fanouts
    paths: list[list[int]] = []
    nbr1 = g.neighbors(seed_node)
    if rng is not None and len(nbr1) > fanouts[0]:
        nbr1 = rng.choice(nbr1, size=fanouts[0], replace=False)
    else:
        nbr1 = nbr1[: caps[0]]
    if len(nbr1) == 0:
        return [[seed_node]]
    if len(fanouts) == 1:
        return [[seed_node, int(v)] for v in nbr1]
    for v1 in nbr1:
        nbr2 = g.neighbors(int(v1))
        if rng is not None and len(nbr2) > fanouts[1]:
            nbr2 = rng.choice(nbr2, size=fanouts[1], replace=False)
        else:
            nbr2 = nbr2[: caps[1]]
        if len(nbr2) == 0:
            paths.append([seed_node, int(v1)])
        else:
            paths.extend([seed_node, int(v1), int(v2)] for v2 in nbr2)
    return paths


def gnn_workload(
    g: CSRGraph,
    seeds: np.ndarray,
    fanouts: tuple[int, ...] = (25, 10),
    seed: int = 0,
    exact_draws: bool = True,
    batch_queries: int = 256,
):
    """Stream PathSet batches for node-wise sampling rooted at ``seeds``."""
    rng = np.random.default_rng(seed) if exact_draws else None

    def paths_fn(root: int) -> list[list[int]]:
        return gnn_query_paths(g, root, fanouts, rng)

    return batched(paths_fn, np.asarray(seeds), batch_queries)


def gnn_workload_materialized(
    g: CSRGraph, seeds: np.ndarray, fanouts=(25, 10), **kw
) -> PathSet:
    return materialize(gnn_workload(g, seeds, fanouts, **kw))
