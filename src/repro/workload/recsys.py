"""RecSys embedding-lookup workload analyzer (beyond-paper application).

MIND-style retrieval reads sharded embedding tables: a request touches the
user row, the rows of the user's recent behaviors (variable-length bag),
and candidate item rows scored against the extracted interests.  The
causal structure is

    user_row -> behavior_row_i            (bag gather: parallel paths)
    user_row -> behavior_row_i -> cand_j  (interest-conditioned scoring)

so each request yields 1-2-hop causal access paths over "objects" = table
rows, and the paper's algorithm bounds the tail number of remote lookups —
exactly the embedding-placement problem of production recsys serving.
Row popularity follows a zipf, giving the heavy-hitter skew replication
exploits.
"""
from __future__ import annotations

import numpy as np

from repro.core.paths import PathSet
from repro.core.slo import TenantSpec
from repro.workload.analyzer import batched, materialize

# serving tenant: embedding fetch sits inside a strict end-to-end ranking
# budget — tightest default (all rows co-located with the request's
# coordinator, the paper's t=0 single-site regime)
TENANT = TenantSpec("recsys", t_q=0)


def recsys_request_paths(
    user_row: int,
    behavior_rows: np.ndarray,
    candidate_rows: np.ndarray,
) -> list[list[int]]:
    paths = []
    for b in behavior_rows:
        if len(candidate_rows):
            paths.extend([user_row, int(b), int(c)] for c in candidate_rows)
        else:
            paths.append([user_row, int(b)])
    return paths or [[user_row]]


def recsys_workload(
    n_users: int,
    n_items: int,
    n_requests: int = 2000,
    behaviors_per_req: int = 6,
    candidates_per_req: int = 4,
    zipf_a: float = 1.3,
    seed: int = 0,
    batch_queries: int = 512,
):
    """Stream PathSet batches of embedding-lookup requests.

    Object-id layout: rows [0, n_users) are user rows; [n_users,
    n_users + n_items) are item rows (one global id space = one dataset D).
    """
    rng = np.random.default_rng(seed)
    users = rng.integers(0, n_users, size=n_requests)

    def paths_fn(user: int) -> list[list[int]]:
        beh = n_users + (rng.zipf(zipf_a, size=behaviors_per_req) % n_items)
        cand = n_users + (rng.zipf(zipf_a, size=candidates_per_req) % n_items)
        return recsys_request_paths(user, np.unique(beh), np.unique(cand))

    return batched(paths_fn, users, batch_queries)


def recsys_workload_materialized(n_users, n_items, **kw) -> PathSet:
    return materialize(recsys_workload(n_users, n_items, **kw))
