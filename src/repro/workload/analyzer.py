"""Workload analyzers (paper §5.3 'Workload analysis').

"We implemented workload analyzers that take a dataset and a set of query
types as input and enumerate all the paths in the workload.  Its output can
be an overapproximation: it only has to include all the paths that actually
occur in the workload.  The greedy algorithm materializes only the paths
currently processed by the UPDATE function."

We mirror that contract: an analyzer is an iterator of ``PathSet`` batches
so workloads far larger than memory stream through the greedy algorithm.
``materialize`` concatenates for small benchmark workloads.
"""
from __future__ import annotations

from typing import Callable, Iterable, Iterator

import numpy as np

from repro.core.paths import PathSet

PathBatchIter = Iterator[PathSet]


def materialize(batches: Iterable[PathSet]) -> PathSet:
    sets = list(batches)
    if not sets:
        return PathSet.from_lists([])
    return PathSet.concatenate(sets)


def batched(
    paths_fn: Callable[[int], list[list[int]]],
    roots: np.ndarray,
    batch_queries: int = 1024,
) -> PathBatchIter:
    """Stream PathSet batches; query ids are globally consistent."""
    buf_paths: list[list[int]] = []
    buf_qids: list[int] = []
    emitted_q = 0

    def flush(local_paths, local_qids, qbase):
        return PathSet.from_lists(
            local_paths, [q - qbase for q in local_qids]
        )

    qbase = 0
    for qi, root in enumerate(roots):
        ps = paths_fn(int(root))
        buf_paths.extend(ps)
        buf_qids.extend([qi] * len(ps))
        if qi - qbase + 1 >= batch_queries:
            yield flush(buf_paths, buf_qids, qbase)
            buf_paths, buf_qids = [], []
            qbase = qi + 1
    if buf_paths or qbase == 0:
        yield flush(buf_paths, buf_qids, qbase)


def trace_objects(pathset: PathSet) -> list[np.ndarray]:
    """Co-access traces (hyperedges) per query — hypergraph sharding input."""
    out: dict[int, list[int]] = {}
    for i in range(pathset.n_paths):
        q = int(pathset.query_ids[i])
        out.setdefault(q, []).extend(pathset.path(i))
    return [np.unique(np.asarray(v, np.int64)) for v in out.values()]
