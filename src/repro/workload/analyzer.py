"""Workload analyzers (paper §5.3 'Workload analysis').

"We implemented workload analyzers that take a dataset and a set of query
types as input and enumerate all the paths in the workload.  Its output can
be an overapproximation: it only has to include all the paths that actually
occur in the workload.  The greedy algorithm materializes only the paths
currently processed by the UPDATE function."

We mirror that contract: an analyzer is an iterator of ``PathSet`` batches
so workloads far larger than memory stream through the greedy algorithm.
``materialize`` concatenates for small benchmark workloads, and
``stream_latencies`` / ``workload_latency_summary`` push the batches
through one device-resident ``LatencyEngine`` — the scheme is uploaded
(packed) exactly once no matter how many batches stream by.
"""
from __future__ import annotations

from typing import Callable, Iterable, Iterator

import numpy as np

from repro import obs
from repro.core.paths import PathSet

PathBatchIter = Iterator[PathSet]


def stream_latencies(
    batches: Iterable[PathSet], scheme, backend: str = "jnp", policy=None
) -> Iterator[tuple[PathSet, np.ndarray]]:
    """Yield (batch, per-path h(p, r, rho)) for each streamed batch.

    ``scheme`` is a ``ReplicationScheme`` or an already-built
    ``LatencyEngine`` (reused as-is, keeping the scheme device-resident).
    ``policy`` optionally scores the walk under a
    ``repro.engine.routing`` hop policy (e.g. ``nearest_copy``).
    """
    from repro.engine import LatencyEngine

    eng = scheme if isinstance(scheme, LatencyEngine) else LatencyEngine(
        scheme, backend=backend
    )
    for ps in batches:
        yield ps, eng.path_latencies(ps, policy=policy)


def workload_latency_summary(
    batches: Iterable[PathSet], scheme, t: int | None = None,
    backend: str = "jnp", slo=None, policy=None,
) -> dict:
    """Streamed workload analysis: latency histogram + feasibility.

    With the scalar ``t`` this is the historical report (histogram +
    ``worst <= t``).  With ``slo`` (an :class:`repro.core.slo.SLOSpec`
    covering the stream's queries in order) the report is additionally
    *per tenant*: each streamed batch consumes the next
    ``batch.n_queries`` budgets of the spec, every query is judged
    against its own t_Q, and the summary carries streaming per-tenant
    slack/violation fractions — without ever materializing the workload.
    ``policy`` scores h under a routing policy (e.g. ``nearest_copy``,
    the paper-faithful reading) for both reports.
    """
    counts: dict[int, int] = {}
    n_paths = 0
    n_queries = 0
    worst = 0
    per_tenant: dict[str, dict] = {}
    if slo is not None:
        for ts in slo.tenants:
            per_tenant[ts.name] = {
                "queries": 0, "violations": 0,
                "min_slack": None, "slack_sum": 0,
            }
    offset = 0
    for ps, pl in stream_latencies(batches, scheme, backend, policy):
        n_paths += len(pl)
        vals, cnt = np.unique(pl, return_counts=True)
        for v, c in zip(vals.tolist(), cnt.tolist()):
            counts[int(v)] = counts.get(int(v), 0) + int(c)
        if len(pl):
            worst = max(worst, int(pl.max()))
            if obs.enabled():
                # mirror the exact int histogram into the shared plane so
                # one registry snapshot names the workload's h-distribution
                # next to every other subsystem's counters
                obs.REGISTRY.histogram(
                    "repro.workload.path_traversals"
                ).record_many(pl)
        nq = ps.n_queries
        n_queries += nq
        if slo is not None and nq:
            bslo = slo.select_queries(offset, offset + nq)
            qids = np.asarray(ps.query_ids)
            ql = np.zeros((nq,), np.int32)
            np.maximum.at(ql, qids, pl)
            slack = bslo.t_q - ql
            for tid, ts in enumerate(bslo.tenants):
                sel = bslo.tenant_of == tid
                if not sel.any():
                    continue
                acc = per_tenant[ts.name]
                acc["queries"] += int(sel.sum())
                acc["violations"] += int((slack[sel] < 0).sum())
                lo = int(slack[sel].min())
                acc["min_slack"] = (
                    lo if acc["min_slack"] is None
                    else min(acc["min_slack"], lo)
                )
                acc["slack_sum"] += int(slack[sel].sum())
        offset += nq
    out = {
        "n_paths": n_paths,
        "max_traversals": worst,
        "histogram": dict(sorted(counts.items())),
        "feasible": (worst <= t) if t is not None else None,
    }
    if slo is not None:
        total_viol = 0
        for name, acc in per_tenant.items():
            q = acc.pop("slack_sum")
            acc["mean_slack"] = q / acc["queries"] if acc["queries"] else None
            acc["violation_frac"] = (
                acc["violations"] / acc["queries"] if acc["queries"] else 0.0
            )
            total_viol += acc["violations"]
        out["per_tenant"] = per_tenant
        out["feasible"] = total_viol == 0
    return out


def materialize(batches: Iterable[PathSet]) -> PathSet:
    sets = list(batches)
    if not sets:
        return PathSet.from_lists([])
    return PathSet.concatenate(sets)


def batched(
    paths_fn: Callable[[int], list[list[int]]],
    roots: np.ndarray,
    batch_queries: int = 1024,
) -> PathBatchIter:
    """Stream PathSet batches; query ids are globally consistent."""
    buf_paths: list[list[int]] = []
    buf_qids: list[int] = []
    emitted_q = 0

    def flush(local_paths, local_qids, qbase):
        return PathSet.from_lists(
            local_paths, [q - qbase for q in local_qids]
        )

    qbase = 0
    for qi, root in enumerate(roots):
        ps = paths_fn(int(root))
        buf_paths.extend(ps)
        buf_qids.extend([qi] * len(ps))
        if qi - qbase + 1 >= batch_queries:
            yield flush(buf_paths, buf_qids, qbase)
            buf_paths, buf_qids = [], []
            qbase = qi + 1
    if buf_paths or qbase == 0:
        yield flush(buf_paths, buf_qids, qbase)


def trace_objects(pathset: PathSet) -> list[np.ndarray]:
    """Co-access traces (hyperedges) per query — hypergraph sharding input."""
    out: dict[int, list[int]] = {}
    for i in range(pathset.n_paths):
        q = int(pathset.query_ids[i])
        out.setdefault(q, []).extend(pathset.path(i))
    return [np.unique(np.asarray(v, np.int64)) for v in out.values()]
