"""Workload analyzers: causal-access-path enumeration per query family."""
from repro.workload.analyzer import (
    batched,
    materialize,
    stream_latencies,
    trace_objects,
    workload_latency_summary,
)
from repro.workload.snb import snb_workload, snb_workload_materialized, snb_query_paths
from repro.workload.gnn import gnn_workload, gnn_workload_materialized, gnn_query_paths
from repro.workload.recsys import recsys_workload, recsys_workload_materialized
from repro.workload.moe import expert_shard, moe_workload, moe_workload_materialized
from repro.workload.tenants import (
    FAMILY_TENANTS,
    multi_tenant_workload,
    tenant_spec,
)

__all__ = [
    "FAMILY_TENANTS",
    "multi_tenant_workload",
    "tenant_spec",
    "batched",
    "materialize",
    "stream_latencies",
    "workload_latency_summary",
    "trace_objects",
    "snb_workload",
    "snb_workload_materialized",
    "snb_query_paths",
    "gnn_workload",
    "gnn_workload_materialized",
    "gnn_query_paths",
    "recsys_workload",
    "recsys_workload_materialized",
    "expert_shard",
    "moe_workload",
    "moe_workload_materialized",
]
