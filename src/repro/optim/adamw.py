"""AdamW + schedules + clipping (no optax in this environment).

Functional optimizer matching the optax contract: ``init(params)`` builds
the state pytree (m, v in float32 regardless of param dtype — bf16 params
keep full-precision statistics), ``update`` applies one step.  Because the
state mirrors the param tree leaf-for-leaf, sharding the state is just
reusing the parameter PartitionSpecs (ZeRO-style: specs shard the big
tensors over the TP axis; the data axis keeps them replicated, with
gradient all-reduce handled by GSPMD from the loss).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray      # int32 scalar
    m: Any                 # pytree like params (float32)
    v: Any                 # pytree like params (float32)


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float | Callable[[jnp.ndarray], jnp.ndarray] = 1e-3
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0          # global-norm clip; 0 disables

    def init(self, params) -> AdamWState:
        zeros = lambda p: jnp.zeros(jnp.shape(p), jnp.float32)
        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            m=jax.tree.map(zeros, params),
            v=jax.tree.map(zeros, params),
        )

    def state_specs(self, param_specs) -> AdamWState:
        """PartitionSpecs for the state, mirroring the parameter specs."""
        from jax.sharding import PartitionSpec as P

        return AdamWState(
            step=P(), m=param_specs, v=param_specs)

    def update(self, grads, state: AdamWState, params):
        step = state.step + 1
        lr = self.lr(step) if callable(self.lr) else self.lr

        g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if self.grad_clip > 0:
            gnorm = global_norm(g32)
            scale = jnp.minimum(1.0, self.grad_clip / (gnorm + 1e-9))
            g32 = jax.tree.map(lambda g: g * scale, g32)
        else:
            gnorm = global_norm(g32)

        m = jax.tree.map(lambda m_, g: self.b1 * m_ + (1 - self.b1) * g,
                         state.m, g32)
        v = jax.tree.map(lambda v_, g: self.b2 * v_ + (1 - self.b2) * g * g,
                         state.v, g32)
        bc1 = 1 - self.b1 ** step.astype(jnp.float32)
        bc2 = 1 - self.b2 ** step.astype(jnp.float32)

        def upd(p, m_, v_):
            mhat = m_ / bc1
            vhat = v_ / bc2
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            delta = delta + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

        new_params = jax.tree.map(upd, params, m, v)
        return new_params, AdamWState(step=step, m=m, v=v), gnorm


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(
        sum(jnp.sum(x.astype(jnp.float32) ** 2) for x in jax.tree.leaves(tree))
    )


def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    floor: float = 0.1) -> Callable:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)

    return lr
