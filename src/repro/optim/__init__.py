"""Optimizers and distributed-optimization utilities."""
from repro.optim.adamw import AdamW, AdamWState, cosine_schedule, global_norm
from repro.optim.compress import (
    Compressed,
    compress,
    compressed_psum,
    decompress,
)

__all__ = [
    "AdamW",
    "AdamWState",
    "cosine_schedule",
    "global_norm",
    "Compressed",
    "compress",
    "decompress",
    "compressed_psum",
]
