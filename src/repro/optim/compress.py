"""Gradient compression for cross-pod all-reduce (distributed-optimization
trick; see DESIGN.md §5).

Cross-pod links (DCN) are an order of magnitude slower than intra-pod ICI,
so pod-boundary gradient traffic dominates at multi-pod scale.  We provide
int8 block-quantized compression:

  * per-block scale (max-abs / 127) over flattened 1024-element blocks,
  * stochastic rounding (optional) to keep the estimator unbiased,
  * decompress -> float32.

Usage pattern at the framework level: with pjit, gradients are reduced by
GSPMD automatically; to exploit compression the launcher can run the pod
axis with ``shard_map`` and do  compress -> psum(int32) -> decompress
explicitly.  ``compressed_psum`` implements that collective; the dry-run
exercises it on the pod axis and tests validate quantization error bounds.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class Compressed(NamedTuple):
    q: jnp.ndarray        # int8 [padded]
    scale: jnp.ndarray    # float32 [n_blocks]
    n: int                # original element count (static)


BLOCK = 1024


def compress(x: jnp.ndarray, stochastic: bool = False,
             key: jax.Array | None = None) -> Compressed:
    flat = x.astype(jnp.float32).reshape(-1)
    n = flat.shape[0]
    pad = (-n) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1) / 127.0
    safe = jnp.maximum(scale, 1e-12)
    y = blocks / safe[:, None]
    if stochastic:
        assert key is not None
        y = y + jax.random.uniform(key, y.shape, minval=-0.5, maxval=0.5)
    q = jnp.clip(jnp.round(y), -127, 127).astype(jnp.int8)
    return Compressed(q=q.reshape(-1), scale=scale, n=n)


def decompress(c: Compressed, shape, dtype=jnp.float32) -> jnp.ndarray:
    blocks = c.q.reshape(-1, BLOCK).astype(jnp.float32)
    out = (blocks * c.scale[:, None]).reshape(-1)[: c.n]
    return out.reshape(shape).astype(dtype)


def compressed_psum(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """int8-compressed all-reduce over ``axis_name`` (shard_map context).

    Quantizes locally, widens to int32 for the ring reduction (so the sum
    cannot overflow for <= 2^23 participants), reduces, and rescales with
    the max participant scale (scales are psum-maxed).  The result is an
    unbiased-ish approximation whose error is bounded by one quantization
    step per participant — tested in tests/test_optim.py.
    """
    c = compress(x)
    scale_max = jax.lax.pmax(c.scale, axis_name)
    # requantize against the shared scale so the integer sum is coherent
    rel = c.scale / jnp.maximum(scale_max, 1e-12)
    q_shared = jnp.round(
        c.q.reshape(-1, BLOCK).astype(jnp.float32) * rel[:, None]
    ).astype(jnp.int32)
    total = jax.lax.psum(q_shared, axis_name)
    out = (total.astype(jnp.float32) * scale_max[:, None]).reshape(-1)[: c.n]
    return out.reshape(x.shape).astype(x.dtype)
