"""Asyncio multi-worker serving harness: `simulate()` against a real clock.

The discrete-event simulator *prices* queueing; this module *runs* it.
``harness_simulate()`` takes the same inputs as
:func:`repro.serve.simulator.simulate` (cluster, pathset, latency model,
arrival process, batching config) and serves the same routed access trees
through **real** concurrency primitives on a wall clock:

* every server is an ``asyncio.Semaphore(concurrency)`` — real contention,
  real FIFO-ish waiting, no modeled queues;
* every access is a real ``asyncio.sleep`` of its service time scaled by
  ``time_scale`` (real seconds per model microsecond, default ``5e-4``:
  a 60 us remote hop sleeps 30 ms, so event-loop scheduling slop of ~1 ms
  is ~2 us of model time — small against the latencies being validated);
* batched dispatch is a real per-server collector task: the first pending
  access arms a window timer, the flush takes a ladder rung and serves the
  whole batch under ONE semaphore slot with one amortized ``dispatch_us``
  — the same plane the simulator models, backed by actual tasks.

The harness returns the same :class:`~repro.serve.simulator.SimReport`,
so ``benchmarks/serve_tail.py`` can diff simulator percentiles against
wall-clock measurements directly — the validation the ROADMAP calls for
(measured p99 within a stated error band of the simulator on the low-load
regime, and the batched-vs-per-query win demonstrated on real time).

What is validated is the *model*, not the random draws: the harness uses
the same arrival process and jitter distributions under the same seed,
but service completion order emerges from the live event loop, so
agreement is distributional (p50/p99 bands), not per-query.
"""
from __future__ import annotations

import asyncio

import numpy as np

from repro.core.paths import PathSet
from repro.distsys.cluster import Cluster
from repro.distsys.executor import LatencyModel
from repro.serve.batching import BatchingConfig, BatchStats
from repro.serve.simulator import SimReport, _build_variant

__all__ = ["harness_simulate"]


def harness_simulate(
    cluster: Cluster,
    pathset: PathSet,
    rate_qps: float = 1e4,
    model: LatencyModel | None = None,
    arrivals_us: np.ndarray | None = None,
    concurrency: int = 32,
    seed: int = 0,
    slo=None,
    policy=None,
    batching: BatchingConfig | None = None,
    time_scale: float = 5e-4,
) -> SimReport:
    """Serve the workload on a real asyncio clock; same report as simulate().

    ``time_scale`` converts model microseconds to real seconds.  Larger
    values run slower but drown event-loop scheduling slop (the harness's
    measurement noise floor) further below the service times; the default
    ``5e-4`` keeps a ~1 ms slop at ~2 us of model time.

    Open-loop only: arrivals keep their schedule (Poisson at ``rate_qps``
    under ``seed``, or the explicit ``arrivals_us`` trace) no matter how
    slow the system is — the coordinated-omission-free measurement mode.
    """
    from repro.engine.routing import resolve_policy

    model = model or LatencyModel()
    rng = np.random.default_rng(seed)
    alive = np.asarray([s.alive for s in cluster.servers], bool)
    S = cluster.n_servers
    nq = pathset.n_queries
    hop_policy = resolve_policy(policy)
    hop_load = cluster.queue_depths() if hop_policy.uses_load else None
    tenant_of = None
    tenant_names: tuple[str, ...] = ()
    if slo is not None:
        assert slo.n_queries == nq
        tenant_of = np.asarray(slo.tenant_of, np.int32)
        tenant_names = tuple(ts.name for ts in slo.tenants)
    if nq == 0:
        return SimReport(
            latency_us=np.zeros(0), arrival_us=np.zeros(0),
            query_failed=np.zeros(0, bool), busy_us=np.zeros(S),
            queue_wait_us=0.0, duration_us=0.0, offered_qps=rate_qps,
            concurrency=concurrency, tenant_of=tenant_of,
            tenant_names=tenant_names, policy=hop_policy.name,
        )

    trees, dead = _build_variant(
        pathset, cluster, model, alive, None, hop_policy, hop_load
    )
    if arrivals_us is None:
        arrivals_us = np.cumsum(rng.exponential(1e6 / rate_qps, size=nq))
    else:
        arrivals_us = np.asarray(arrivals_us, np.float64)
        assert arrivals_us.shape == (nq,)

    scale = float(time_scale)
    busy_us = np.zeros(S, np.float64)
    completion = np.full(nq, -1.0)
    n_waits = 0
    wait_us = 0.0
    batch_stats = BatchStats() if batching is not None else None

    def jitter() -> float:
        return rng.lognormal(0.0, model.jitter_sigma)

    async def _run() -> None:
        nonlocal n_waits, wait_us
        loop = asyncio.get_running_loop()
        sems = [asyncio.Semaphore(concurrency) for _ in range(S)]
        t0 = loop.time()

        def now_us() -> float:
            return (loop.time() - t0) / scale

        # --- batched dispatch: per-server collector --------------------
        pending: list[list] = [[] for _ in range(S)]
        serve_tasks: set = set()

        async def _serve_batch(s: int, members: list) -> None:
            nonlocal n_waits, wait_us
            tq0 = now_us()
            async with sems[s]:
                n_waits += 1
                wait_us += now_us() - tq0
                svc = (
                    model.dispatch_us + sum(b for _, b in members)
                ) * jitter()
                busy_us[s] += svc
                await asyncio.sleep(svc * scale)
            for fut, _ in members:
                if not fut.done():
                    fut.set_result(None)

        async def _flush_later(s: int) -> None:
            await asyncio.sleep(batching.window_us * scale)
            while pending[s]:
                take = batching.ladder.pick(len(pending[s]))
                members = pending[s][:take]
                del pending[s][:take]
                batch_stats.observe(len(members))
                task = asyncio.ensure_future(_serve_batch(s, members))
                serve_tasks.add(task)
                task.add_done_callback(serve_tasks.discard)

        def submit(s: int, base: float):
            fut = loop.create_future()
            pending[s].append((fut, base))
            if len(pending[s]) == 1:
                task = asyncio.ensure_future(_flush_later(s))
                serve_tasks.add(task)
                task.add_done_callback(serve_tasks.discard)
            return fut

        # --- the routed walk, one coroutine per access-tree node -------
        async def run_node(q: int, nodes: list, i: int) -> None:
            nonlocal n_waits, wait_us
            s, base, _obj, children = nodes[i]
            if s < 0:
                # no alive copy: degraded completion, no queueing
                await asyncio.sleep(model.remote_us * scale)
            elif batching is not None:
                await submit(s, base)
            else:
                tq0 = now_us()
                async with sems[s]:
                    n_waits += 1
                    wait_us += now_us() - tq0
                    svc = (base + model.dispatch_us) * jitter()
                    busy_us[s] += svc
                    await asyncio.sleep(svc * scale)
            if children:
                await asyncio.gather(
                    *(run_node(q, nodes, c) for c in children)
                )

        async def run_query(q: int) -> None:
            target = t0 + arrivals_us[q] * scale
            delay = target - loop.time()
            if delay > 0:
                await asyncio.sleep(delay)
            nodes, roots = trees[q]
            if roots:
                await asyncio.gather(*(run_node(q, nodes, r) for r in roots))
            completion[q] = now_us() + model.coordinator_us

        await asyncio.gather(*(run_query(q) for q in range(nq)))
        if serve_tasks:
            await asyncio.gather(*serve_tasks)

    asyncio.run(_run())

    assert (completion >= 0).all(), "harness leaked queries"
    return SimReport(
        latency_us=completion - arrivals_us,
        arrival_us=arrivals_us,
        query_failed=dead,
        busy_us=busy_us,
        queue_wait_us=wait_us / n_waits if n_waits else 0.0,
        duration_us=float(completion.max() - arrivals_us.min()),
        offered_qps=rate_qps,
        concurrency=concurrency,
        tenant_of=tenant_of,
        tenant_names=tenant_names,
        policy=hop_policy.name,
        batch_stats=batch_stats,
    )
