"""Discrete-event serving simulator: the paper's latency model under load.

The closed-form executor (``repro.distsys.executor``) prices each query in
isolation — latency is the critical path's access costs plus jitter, with
no queueing.  Under traffic that is exactly the regime where tail latency
is decided: requests contend for per-server service capacity, and the p99
the paper tunes (Fig 6b) emerges from queueing delay on the hottest
server, not from the RPC constants.  This module adds the time dimension:

* **open-loop arrivals** — Poisson at an offered ``rate_qps``, or an
  explicit per-query arrival-time trace (replay / drift phases);
* **closed-loop client pool** — alternatively ``clients=N`` serves the
  workload from N clients that each issue a query, wait for its
  completion plus an exponential think time, then issue the next one.
  Closed-loop runs measure *saturation throughput* (offered load adapts
  to service capacity, so ``achieved_qps`` is the system's ceiling) and
  make coordinated omission visible: a closed-loop client stops issuing
  while the system is slow, so its latencies systematically understate
  what an open-loop arrival process (which keeps its schedule) would
  measure — compare the two modes at equal throughput to quantify it;
* **per-hop routing policies** — ``policy`` routes every remote hop of
  the access walk through a ``repro.engine.routing`` policy:
  ``home_first`` (Eqn 1 verbatim), ``nearest_copy`` (holder that keeps
  the walk local longest), or ``queue_aware`` (least-loaded holder,
  seeded from the cluster's live queue depths and refreshed mid-run
  every ``reroute_every`` arrivals — or, with ``hop_feedback=True``,
  re-picked per remote hop at dispatch time — so hop targets react to
  the queues the traffic itself builds up);
* **per-server FIFO queues** — each server serves at most ``concurrency``
  accesses at once (default 32, two hardware threads per vCPU on the
  paper's 16-vCPU r5d.4xlarge servers); excess accesses wait in FIFO
  order;
* **queries as routed hop sequences** — each query's paths come from the
  engine's access trace (Eqn 1 under liveness fail-over, the same walk the
  executor decorates), so a path is a sequence of (server, service-time)
  stages: local accesses cost ``local_us`` at the current server, each
  distributed traversal costs ``remote_us`` at the hop's target server.
  Sibling paths of a query run in parallel; the query completes when its
  slowest path does, plus the coordinator barrier (Def 4.3);
* **router integration** — ``replica_lb`` picks, per arrival, whichever of
  the router's primary/backup coordinators has the shorter live queue
  (queue-aware routing through ``Cluster.queue_depths``-style state);
  ``hedged`` launches both and keeps the first completion (the loser's
  stages still occupy servers — hedging's capacity price is modeled, not
  assumed away);
* **hop-level span tracing** — ``trace`` (a :class:`repro.obs.Tracer`)
  records one span per served access: hop order, object, server,
  local/remote, and the FIFO queue-wait vs service split, tail-biased
  sampled (a query that violated its wall-clock t_Q budget is never
  dropped).  Along a linear walk the span queue+service durations plus
  the coordinator barrier sum exactly to the query's simulated latency,
  so a violation decomposes into named hops on named servers — the input
  to ``repro.obs.attribute_burn``'s per-tenant blame tables.

At utilization -> 0 queueing delay vanishes and the simulator's mean
latency converges to the closed-form model (same access counts, same
service constants, same lognormal jitter mean) — ``benchmarks/serve_tail``
checks the two agree within 10%.  Accesses whose object has no alive copy
(visited server -1) complete degraded after ``remote_us`` without queueing
and mark the query failed rather than crashing the run.
"""
from __future__ import annotations

import dataclasses
import heapq
from collections import deque

import numpy as np

from repro import obs
from repro.core.paths import PathSet
from repro.distsys.cluster import Cluster
from repro.distsys.executor import LatencyModel, _query_roots, trace_paths
from repro.distsys.router import Router
from repro.serve.batching import (
    AdmissionConfig,
    BatchingConfig,
    BatchStats,
    HedgePolicy,
)


@dataclasses.dataclass
class SimReport:
    """Statistics of one simulated serving run (all times microseconds)."""

    latency_us: np.ndarray        # [n_queries] completion - arrival
    arrival_us: np.ndarray        # [n_queries]
    query_failed: np.ndarray      # [n_queries] hit an object with no copy
    busy_us: np.ndarray           # [S] total service time per server
    queue_wait_us: float          # mean FIFO wait per stage
    duration_us: float            # makespan (last completion)
    offered_qps: float
    concurrency: int
    # tenant tags: every job of query q carried tenant_of[q] through the
    # event loop, so latencies histogram per tenant (multi-tenant SLOs)
    tenant_of: np.ndarray | None = None      # [n_queries] tenant id
    tenant_names: tuple[str, ...] = ()
    # closed-loop mode: N clients with think time instead of an open-loop
    # arrival process; achieved_qps is then the saturation throughput
    closed_loop: bool = False
    n_clients: int = 0
    policy: str = "home_first"               # per-hop routing policy
    reroutes: int = 0                        # mid-run hop-target refreshes
    # per-hop load feedback: remote-hop targets picked at dispatch time
    # against the queue state the batch itself built up
    hop_feedback: bool = False
    # deadline-aware admission: True where the query was shed (fail-fast)
    # instead of served; shed queries are excluded from surviving stats
    query_shed: np.ndarray | None = None
    # mixed open/closed-loop runs: True where the query was served by the
    # closed-loop client pool (None for pure open/closed runs)
    closed_mask: np.ndarray | None = None
    # batched dispatch: ladder occupancy accounting (None = per-query)
    batch_stats: BatchStats | None = None
    # SLO-driven hedging accounting (slo_hedging marks the mode active)
    slo_hedging: bool = False
    hedges_fired: int = 0
    hedge_wins: int = 0          # fired hedges whose backup completed first
    hedges_cancelled: int = 0    # queued work skipped after first completion
    # chaos injection: the (t_us, kind, server) liveness flips applied
    # mid-run (empty for chaos-free runs)
    chaos_events: list = dataclasses.field(default_factory=list)
    # client-side routing table: direct-vs-fallback counters (None when
    # every query took the coordinator path)
    routing: dict | None = None

    def percentile(self, q: float) -> float:
        return float(np.percentile(self.latency_us, q))

    def surviving_latencies(self) -> np.ndarray:
        """Latencies of queries that were actually served (not shed)."""
        if self.query_shed is None:
            return self.latency_us
        return self.latency_us[~self.query_shed]

    @property
    def shed_frac(self) -> float:
        if self.query_shed is None or not len(self.query_shed):
            return 0.0
        return float(self.query_shed.mean())

    def tenant_latencies(self, name: str) -> np.ndarray:
        """Sojourn latencies of one tenant's queries."""
        if self.tenant_of is None:
            raise ValueError("run was not tenant-tagged (pass slo=)")
        tid = self.tenant_names.index(name)
        return self.latency_us[self.tenant_of == tid]

    @property
    def mean_us(self) -> float:
        return float(self.latency_us.mean())

    @property
    def p50_us(self) -> float:
        return self.percentile(50.0)

    @property
    def p99_us(self) -> float:
        return self.percentile(99.0)

    @property
    def p999_us(self) -> float:
        return self.percentile(99.9)

    @property
    def achieved_qps(self) -> float:
        if len(self.latency_us) == 0:
            return 0.0
        if self.duration_us <= 0:
            return float("inf")
        return len(self.latency_us) / (self.duration_us / 1e6)

    def utilization(self) -> np.ndarray:
        """Busy fraction per server (of duration x concurrency)."""
        if self.duration_us <= 0:
            return np.zeros_like(self.busy_us)
        return self.busy_us / (self.duration_us * self.concurrency)

    def summary(self) -> dict:
        util = self.utilization()
        n_done = int(self.latency_us.size)
        out = {
            # an empty run (clients=0, or a zero-query workload) has no
            # latency distribution: stats are None, never NaN/garbage
            "mean_us": self.mean_us if n_done else None,
            "p50_us": self.p50_us if n_done else None,
            "p99_us": self.p99_us if n_done else None,
            "p999_us": self.p999_us if n_done else None,
            "offered_qps": self.offered_qps,
            "achieved_qps": self.achieved_qps,
            "completed_queries": n_done,
            "max_utilization": float(util.max()) if util.size else 0.0,
            "mean_queue_wait_us": self.queue_wait_us,
            "failed_queries": int(self.query_failed.sum()),
            "mode": "closed_loop" if self.closed_loop else "open_loop",
            "policy": self.policy,
        }
        if self.closed_loop:
            # in closed loop the offered rate is endogenous: achieved_qps
            # IS the saturation throughput at this client count.  With no
            # completed jobs (clients=0) or a degenerate zero-length run
            # there is no throughput to report: None, not a division by
            # zero or +inf
            out["n_clients"] = self.n_clients
            out["saturation_qps"] = (
                self.achieved_qps
                if n_done and self.duration_us > 0
                else None
            )
        if self.hop_feedback:
            out["hop_feedback"] = True
        if self.reroutes:
            out["reroutes"] = self.reroutes
        if self.closed_mask is not None and 0 < self.closed_mask.sum() < len(
            self.closed_mask
        ):
            # mixed run: split the latency distribution per loop so the
            # closed-loop foreground's tail is visible against the
            # open-loop background it contends with
            out["mode"] = "mixed_loop"
            for label, m in (
                ("closed_loop_split", self.closed_mask),
                ("open_loop_split", ~self.closed_mask),
            ):
                lat = self.latency_us[m]
                out[label] = {
                    "n_queries": int(lat.size),
                    "p50_us": float(np.percentile(lat, 50.0)) if lat.size else None,
                    "p99_us": float(np.percentile(lat, 99.0)) if lat.size else None,
                }
        if self.query_shed is not None:
            surv = self.surviving_latencies()
            adm = {
                "n_shed": int(self.query_shed.sum()),
                "shed_frac": self.shed_frac,
                "surviving_p50_us": (
                    float(np.percentile(surv, 50.0)) if surv.size else None
                ),
                "surviving_p99_us": (
                    float(np.percentile(surv, 99.0)) if surv.size else None
                ),
            }
            if self.tenant_of is not None:
                adm["per_tenant_shed_frac"] = {
                    name: float(self.query_shed[self.tenant_of == tid].mean())
                    for tid, name in enumerate(self.tenant_names)
                    if (self.tenant_of == tid).any()
                }
            out["admission"] = adm
        if self.batch_stats is not None:
            out["batching"] = self.batch_stats.summary()
        if self.chaos_events:
            out["chaos"] = {
                "events": [
                    {"at_us": t, "kind": k, "server": s}
                    for t, k, s in self.chaos_events
                ],
                "kills": sum(1 for _, k, _ in self.chaos_events if k == "kill"),
            }
        if self.routing is not None:
            out["routing_table"] = self.routing
        if self.slo_hedging:
            out["hedging"] = {
                "fired": self.hedges_fired,
                "wins": self.hedge_wins,
                "cancelled": self.hedges_cancelled,
                "hedge_frac": (
                    self.hedges_fired / len(self.latency_us)
                    if len(self.latency_us)
                    else 0.0
                ),
            }
        if self.tenant_of is not None:
            per = {}
            for tid, name in enumerate(self.tenant_names):
                lat = self.latency_us[self.tenant_of == tid]
                if not lat.size:
                    continue
                per[name] = {
                    "n_queries": int(lat.size),
                    "mean_us": float(lat.mean()),
                    "p50_us": float(np.percentile(lat, 50.0)),
                    "p99_us": float(np.percentile(lat, 99.0)),
                }
            out["per_tenant"] = per
        return out


def _build_variant(
    pathset: PathSet,
    cluster: Cluster,
    model: LatencyModel,
    alive: np.ndarray,
    start: np.ndarray | None,
    policy=None,
    load: np.ndarray | None = None,
):
    """Precompute one routing variant's per-query access trees.

    A query's root-to-leaf paths share prefixes (they enumerate one access
    tree, Def 4.1); each shared access executes *once* and fans out — the
    same structure the closed-form model prices with its max-over-paths.
    Returns (trees_per_query, dead_per_query) where a tree is
    ``(nodes, roots)``: ``nodes[i] = [server, base_service_us, object,
    children]`` and ``roots`` the indices dispatched at arrival.

    ``policy``/``load`` route every remote hop through a
    ``repro.engine.routing`` policy against the given queue-depth
    snapshot (``queue_aware``); the tree's node servers are the policy's
    picks.
    """
    servers, local = trace_paths(
        pathset, cluster.scheme, alive, start, policy, load
    )
    nq = pathset.n_queries
    trees: list[tuple[list, list[int]]] = [([], []) for _ in range(nq)]
    tries: list[dict] = [dict() for _ in range(nq)]
    dead = np.zeros(nq, bool)
    qids = np.asarray(pathset.query_ids)
    lengths = np.asarray(pathset.lengths)
    objects = np.asarray(pathset.objects)
    for p in range(pathset.n_paths):
        q = int(qids[p])
        n = int(lengths[p])
        if n == 0:
            continue
        nodes, roots = trees[q]
        trie = tries[q]
        prefix: tuple = ()
        parent = -1
        for x in range(n):
            prefix = prefix + (int(objects[p, x]),)
            idx = trie.get(prefix)
            if idx is None:
                s = int(servers[p, x])
                if s < 0:
                    dead[q] = True
                cost = (
                    model.local_us if bool(local[p, x]) else model.remote_us
                )
                idx = len(nodes)
                nodes.append([s, cost, int(objects[p, x]), []])
                trie[prefix] = idx
                if parent < 0:
                    roots.append(idx)
                else:
                    nodes[parent][3].append(idx)
            parent = idx
    return trees, dead


def _build_dynamic_trees(pathset: PathSet):
    """Per-query access trees with UNRESOLVED hop targets (hop feedback).

    Same shared-prefix trie as :func:`_build_variant`, but a node is
    ``[object, children]`` — the visited server and service cost are
    resolved at *dispatch time* against the live queue state, so every
    remote hop reacts to the congestion accumulated within the batch.
    """
    nq = pathset.n_queries
    trees: list[tuple[list, list[int]]] = [([], []) for _ in range(nq)]
    tries: list[dict] = [dict() for _ in range(nq)]
    qids = np.asarray(pathset.query_ids)
    lengths = np.asarray(pathset.lengths)
    objects = np.asarray(pathset.objects)
    for p in range(pathset.n_paths):
        q = int(qids[p])
        n = int(lengths[p])
        if n == 0:
            continue
        nodes, roots = trees[q]
        trie = tries[q]
        prefix: tuple = ()
        parent = -1
        for x in range(n):
            prefix = prefix + (int(objects[p, x]),)
            idx = trie.get(prefix)
            if idx is None:
                idx = len(nodes)
                nodes.append([int(objects[p, x]), []])
                trie[prefix] = idx
                if parent < 0:
                    roots.append(idx)
                else:
                    nodes[parent][1].append(idx)
            parent = idx
    return trees


def _tree_floors(trees) -> list[tuple[float, list[float]]]:
    """Jitter-free critical-path floor per query and per tree node.

    ``floors[q] = (root_floor, node_floors)`` where ``node_floors[i]`` is
    the cost of node ``i``'s subtree critical path (its own access cost
    plus the max over child subtrees) and ``root_floor`` the max over the
    query's roots — the cheapest the query can possibly finish under the
    active routing (excluding the coordinator barrier), the quantity
    deadline-aware admission compares against the remaining slack.
    Children are appended after their parent in ``_build_variant``, so one
    reverse sweep resolves the recursion.
    """
    out: list[tuple[float, list[float]]] = []
    for nodes, roots in trees:
        nf = [0.0] * len(nodes)
        for i in range(len(nodes) - 1, -1, -1):
            best = 0.0
            for c in nodes[i][3]:
                if nf[c] > best:
                    best = nf[c]
            nf[i] = nodes[i][1] + best
        out.append((max((nf[r] for r in roots), default=0.0), nf))
    return out


def simulate(
    cluster: Cluster,
    pathset: PathSet,
    rate_qps: float = 1e4,
    model: LatencyModel | None = None,
    arrivals_us: np.ndarray | None = None,
    concurrency: int = 32,
    router: Router | None = None,
    seed: int = 0,
    slo=None,
    policy=None,
    reroute_every: int | None = None,
    hop_feedback: bool = False,
    clients: int | None = None,
    think_time_us: float = 0.0,
    trace=None,
    batching: BatchingConfig | None = None,
    admission: AdmissionConfig | None = None,
    hedge: HedgePolicy | None = None,
    closed_queries: np.ndarray | None = None,
    chaos=None,
    routing_table=None,
) -> SimReport:
    """Serve ``pathset``'s queries through per-server FIFO queues.

    Queries arrive open-loop (Poisson at ``rate_qps``, or at the explicit
    ``arrivals_us`` times) in query-id order; each executes its routed hop
    sequence against the live cluster state.  Returns per-query sojourn
    latencies and per-server occupancy — the quantities the controller's
    sliding window and the tail benchmarks consume.

    ``clients`` switches to a *closed-loop* client pool instead: N
    clients each issue one query (in id order from a shared backlog),
    wait for its completion plus an exponential think time of mean
    ``think_time_us``, then issue the next — the mode that measures
    saturation throughput and makes coordinated omission observable
    (see module docstring).  ``rate_qps``/``arrivals_us`` are ignored.

    ``policy`` routes every remote hop of the walk through a
    ``repro.engine.routing`` policy (``home_first`` default;
    ``queue_aware`` ranks holders by the cluster's live queue depths —
    the state the previous batch left in ``Cluster.queue_depths()``).
    With ``reroute_every=K`` (requires ``router=None``) the hop targets
    are re-picked mid-run every K arrivals against the simulator's own
    live queue state, so routing reacts to the congestion the batch
    itself builds; in-flight queries finish on their old routes.

    ``hop_feedback=True`` (requires a load-aware policy and
    ``router=None``; mutually exclusive with ``reroute_every``) goes one
    step further: hop targets are not precomputed at all — every remote
    access picks its server at *dispatch time* from the alive copy
    holders ranked by the instantaneous ``busy + queued`` depth (the
    scalar ``pick_holder_host`` oracle), so routing consumes the queue
    depths accumulated *within* the batch, per hop, not per
    ``reroute_every`` window.  ``SimReport.reroutes`` then counts the
    load-ranked remote picks.

    ``slo`` (an :class:`repro.core.slo.SLOSpec` aligned with the pathset's
    queries) tags every job with its query's tenant, so the report carries
    per-tenant latency histograms (``summary()["per_tenant"]``) — the
    per-tenant p99s the multi-tenant controller monitors.

    ``trace`` (a :class:`repro.obs.Tracer`) records a hop-level span per
    served access — queue-wait vs service split on the serving server —
    finalized per query in completion order against the tracer's
    wall-clock ``budget_us``; violating queries' traces are always kept
    (tail-biased sampling).  ``trace=None`` (the default) costs one
    pointer check per access.

    The batched dispatch plane (``repro.serve.batching``):

    ``batching`` (a :class:`BatchingConfig`) coalesces accesses targeting
    the same server within ``window_us`` into one dispatch of a
    ladder-quantized size; the batch occupies a single concurrency slot
    for the members' summed service time plus **one** ``dispatch_us``
    (amortized engine-dispatch overhead — per-query mode pays it per
    access).  Requires ``hop_feedback=False`` (batch members' routes are
    fixed at collection time).

    ``admission`` (an :class:`AdmissionConfig`) sheds queries whose
    jitter-free floor under the active routing can no longer meet their
    wall-clock deadline — at arrival, at every hop dispatch, and at FIFO
    pop (elapsed queue wait counts against the slack).  Shed queries
    complete degraded at the shed instant, are marked in
    ``SimReport.query_shed``, and excluded from surviving-tail stats.

    ``hedge`` (a :class:`HedgePolicy`, requires ``router=None``) races a
    backup coordinator pick only for queries still incomplete when their
    elapsed time crosses the tenant's learned latency quantile; the
    first completion wins and the loser's queued work is skipped
    (``hedges_cancelled``).  Completions feed the policy's per-tenant
    histograms online, so thresholds adapt within the run.

    ``closed_queries`` (requires ``clients=``) selects the subset of
    query ids served by the closed-loop client pool while the rest
    arrive open-loop at ``rate_qps`` — one run with an open-loop
    background and a closed-loop foreground (interference studies);
    ``summary()`` then splits per-loop percentiles.

    ``chaos`` (a list of :class:`repro.distsys.faults.ChaosEvent`,
    requires ``router=None`` and ``hedge=None``) kills and revives
    servers mid-run: at each event's ``at_us`` the server's liveness
    flips (mirrored into ``cluster.servers``, so a controller observing
    between segments sees it), hop targets are re-resolved for every
    query that has NOT arrived yet (``reroute_pending`` — in-flight
    queries keep their old routes and a killed server drains its queue
    gracefully, modeling a crash whose in-flight RPCs time out on the
    old routes), and under ``hop_feedback`` the liveness-masked holder
    arrays are recomputed so the very next dispatch routes around the
    loss.  A killed server's replicas stay on disk and serve again on
    revive.  ``SimReport.chaos_events`` logs the applied flips; feed the
    report to ``repro.distsys.faults.violation_windows`` to score the
    outage.

    ``routing_table`` (a :class:`repro.distsys.RoutingTable` over this
    cluster) models coordinator-free client routing: per arrival the
    query's root is looked up in the client's cached snapshot; a
    live-valid pick goes **direct-to-shard** and skips the
    ``coordinator_us`` barrier, a miss (stale snapshot: target dead or
    replica moved) pays the coordinator hop and force-refreshes the
    table.  ``SimReport.routing`` carries the hit/fallback/refresh
    counters.
    """
    from repro.engine.routing import pick_holder_host, resolve_policy

    model = model or LatencyModel()
    rng = np.random.default_rng(seed)
    alive = np.asarray([s.alive for s in cluster.servers], bool)
    S = cluster.n_servers
    nq = pathset.n_queries
    hop_policy = resolve_policy(policy)
    hop_load = cluster.queue_depths() if hop_policy.uses_load else None
    closed = clients is not None
    if batching is not None and hop_feedback:
        raise ValueError(
            "batching requires hop_feedback=False: batch members' routes "
            "are fixed when the batch is collected"
        )
    if admission is not None and hop_feedback:
        raise ValueError(
            "admission requires hop_feedback=False: floor latencies need "
            "precomputed access trees"
        )
    if hedge is not None:
        if router is not None:
            raise ValueError(
                "hedge= requires router=None (the policy builds its own "
                "primary/backup coordinator variants)"
            )
        if hop_feedback or reroute_every is not None:
            raise ValueError(
                "hedge= is incompatible with hop_feedback/reroute_every"
            )
    if chaos and (router is not None or hedge is not None):
        raise ValueError(
            "chaos= requires router=None and hedge=None: coordinator "
            "variants are built once at entry and would go stale across "
            "liveness flips"
        )
    # mixed open/closed loop: closed_queries picks the client-pool subset
    is_closed: np.ndarray | None = None
    closed_ids: np.ndarray | None = None
    if closed_queries is not None:
        if not closed or int(clients) <= 0:
            raise ValueError("closed_queries requires clients >= 1")
        closed_ids = np.unique(np.asarray(closed_queries, np.int64))
        if len(closed_ids) and (
            closed_ids[0] < 0 or closed_ids[-1] >= nq
        ):
            raise ValueError("closed_queries out of range")
        is_closed = np.zeros(nq, bool)
        is_closed[closed_ids] = True
    elif closed:
        closed_ids = np.arange(nq, dtype=np.int64)
        is_closed = np.ones(nq, bool)
    mixed = is_closed is not None and 0 < len(closed_ids) < nq
    if hop_feedback:
        if router is not None:
            raise ValueError("hop_feedback requires router=None")
        if reroute_every is not None:
            raise ValueError(
                "pass either reroute_every or hop_feedback, not both"
            )
        if not hop_policy.uses_load:
            raise ValueError(
                "hop_feedback only makes sense for a load-aware policy "
                "(queue_aware): load-blind policies pick the same targets"
            )
    tenant_of = None
    tenant_names: tuple[str, ...] = ()
    if slo is not None:
        assert slo.n_queries == nq
        tenant_of = np.asarray(slo.tenant_of, np.int32)
        tenant_names = tuple(ts.name for ts in slo.tenants)
    if nq == 0 or (closed and int(clients) <= 0):
        # nothing to serve (or nobody to serve it): an empty report, with
        # zero-length latency arrays — summary() reports None stats, not
        # NaN percentiles / infinite saturation throughput
        return SimReport(
            latency_us=np.zeros(0), arrival_us=np.zeros(0),
            query_failed=np.zeros(0, bool), busy_us=np.zeros(S),
            queue_wait_us=0.0, duration_us=0.0,
            offered_qps=0.0 if closed else rate_qps,
            concurrency=concurrency,
            tenant_of=tenant_of, tenant_names=tenant_names,
            closed_loop=closed, n_clients=int(clients or 0),
            policy=hop_policy.name, hop_feedback=hop_feedback,
        )

    # --- routing variants -------------------------------------------------
    coord_policy = router.policy if router is not None else "home"
    if hedge is not None:
        # SLO-driven hedging builds the same primary/backup variants as
        # the router's unconditional hedged race, but launches the backup
        # from a learned-quantile timer instead of at arrival
        coord_policy = "hedge_slo"
    if hop_feedback:
        from repro.distsys.executor import failover_home

        coord_policy = "home"
        mask_alive = cluster.scheme.mask & alive[None, :]
        fo_home = failover_home(cluster.scheme, alive)
        variants_trees = [_build_dynamic_trees(pathset)]
        variants_dead = [np.zeros(nq, bool)]
        coords = [None]
    elif coord_policy in ("replica_lb", "hedged", "hedge_slo"):
        hrouter = (
            router if router is not None else Router(cluster.scheme, "hedged")
        )
        roots = _query_roots(pathset)
        primary, backup = hrouter.route_roots_hedged(roots, alive, seed=seed)
        qids = np.asarray(pathset.query_ids)
        v1, d1 = _build_variant(
            pathset, cluster, model, alive, primary[qids],
            hop_policy, hop_load,
        )
        has_b = backup >= 0
        v2, d2 = _build_variant(
            pathset, cluster, model, alive,
            np.where(has_b, backup, primary)[qids],
            hop_policy, hop_load,
        )
        variants_trees = [v1, v2]
        variants_dead = [d1, d2]
        coords = [primary, np.where(has_b, backup, -1)]
    else:
        coord_policy = "home"
        v0, d0 = _build_variant(
            pathset, cluster, model, alive, None, hop_policy, hop_load
        )
        variants_trees = [v0]
        variants_dead = [d0]
        coords = [None]
    if reroute_every is not None:
        if coord_policy != "home":
            raise ValueError("reroute_every requires router=None")
        if not hop_policy.uses_load:
            raise ValueError(
                "reroute_every only makes sense for a load-aware policy "
                "(queue_aware): load-blind policies re-pick identical routes"
            )

    # --- event loop -------------------------------------------------------
    if mixed:
        # open-loop background keeps its schedule; the closed-loop
        # foreground's times are filled at issue by the client pool
        open_ids = np.nonzero(~is_closed)[0]
        if arrivals_us is None:
            arr = np.zeros(nq, np.float64)
            arr[open_ids] = np.cumsum(
                rng.exponential(1e6 / rate_qps, size=len(open_ids))
            )
            arrivals_us = arr
        else:
            arrivals_us = np.asarray(arrivals_us, np.float64).copy()
            assert arrivals_us.shape == (nq,)
    elif closed:
        arrivals_us = np.zeros(nq, np.float64)  # filled at issue time
    elif arrivals_us is None:
        arrivals_us = np.cumsum(
            rng.exponential(1e6 / rate_qps, size=nq)
        )
    else:
        arrivals_us = np.asarray(arrivals_us, np.float64)
        assert arrivals_us.shape == (nq,)

    queues: list[deque] = [deque() for _ in range(S)]
    busy = np.zeros(S, np.int64)
    busy_us = np.zeros(S, np.float64)
    completion = np.full(nq, -1.0)
    failed = np.zeros(nq, bool)
    n_waits = 0
    wait_us = 0.0

    # per-query coordinator barrier: a routing-table direct hit skips it
    coord_barrier = np.full(nq, model.coordinator_us, np.float64)
    roots_all = _query_roots(pathset) if routing_table is not None else None
    chaos_log: list[tuple[float, str, int]] = []

    # --- batched dispatch plane state ------------------------------------
    # admission: per-variant jitter-free floors + wall-clock deadlines
    query_shed = np.zeros(nq, bool) if admission is not None else None
    deadlines = floors = None
    if admission is not None:
        deadlines = admission.deadlines(slo, model, pathset)
        floors = [_tree_floors(v) for v in variants_trees]
    # batching: per-server pending lists awaiting a window flush
    pending: list[list] = [[] for _ in range(S)] if batching is not None else []
    batch_stats = BatchStats() if batching is not None else None
    obs_batch_hist = (
        obs.REGISTRY.histogram("repro.serve.batch_occupancy")
        if batching is not None and obs.enabled()
        else None
    )
    # hedging: fired flags + win/cancel accounting
    hedge_fired = np.zeros(nq, bool) if hedge is not None else None
    hedges_fired = 0
    hedge_wins = 0
    hedges_cancelled = 0

    # a "job" is one access-tree node instance of one (query, variant)
    # launch: job = (query, variant, node_idx, server, base_service_us,
    # object, t_dispatch), with (server, base) resolved at dispatch time —
    # from the precomputed tree in the static modes, from the live queue
    # state under hop feedback; per-(query, variant) remaining-node
    # counters decide completion (all accesses done = slowest chain done).
    remaining: dict[tuple[int, int], int] = {}

    heap: list[tuple[float, int, str, object]] = []
    seq = 0
    reroutes = 0

    def push(t, kind, data):
        nonlocal seq
        heapq.heappush(heap, (t, seq, kind, data))
        seq += 1

    def jitter():
        return rng.lognormal(0.0, model.jitter_sigma)

    def resolve(q, v, i, parent):
        """(server, base_service_us, object) of one access.

        ``parent`` is the landing server of the node's parent (-2 for a
        root).  Static modes read the precomputed tree node; hop
        feedback applies Eqn 1 live: local at the parent's server when a
        copy is there, otherwise the least-loaded alive holder by the
        instantaneous busy+queued depth (home wins ties).
        """
        nonlocal reroutes
        node = variants_trees[v][q][0][i]
        if not hop_feedback:
            return node[0], node[1], node[2]
        obj = node[0]
        if parent == -2:
            return int(fo_home[obj]), model.local_us, obj
        if parent >= 0 and mask_alive[obj, parent]:
            return parent, model.local_us, obj
        live = np.asarray(
            [busy[s] + len(queues[s]) for s in range(S)], np.float64
        )
        reroutes += 1
        return (
            pick_holder_host(mask_alive[obj], int(fo_home[obj]), live),
            model.remote_us,
            obj,
        )

    # span staging: a flat stride-3 list of job, t_start, t_end — the job
    # tuple already carries (query, variant, node, server, base, object,
    # t_dispatch), so recording a span is three appends of objects that
    # already exist (zero allocation, zero garbage) through a pre-bound
    # method; the Tracer groups, decodes, and samples lazily, off the
    # run's clock
    t_stage = trace.begin_run(nq).append if trace is not None else None

    def start_service(t, s, job):
        busy[s] += 1
        if job[0] == "batch":
            # one concurrency slot serves the whole batch: the members'
            # summed base cost plus a SINGLE amortized dispatch overhead
            # (per-query mode pays dispatch_us once per access)
            svc = job[3] * jitter()
            busy_us[s] += svc
            te = t + svc
            if t_stage is not None:
                for m in job[2]:
                    t_stage(m)
                    t_stage(t)
                    t_stage(te)
            push(te, "done", (s, job))
            return
        svc = (job[4] + model.dispatch_us) * jitter()
        busy_us[s] += svc
        te = t + svc
        if t_stage is not None:
            t_stage(job)
            t_stage(t)
            t_stage(te)
        push(te, "done", (s, job))

    def dispatch(t, q, v, i, parent):
        if query_shed is not None and query_shed[q]:
            return
        if hedge is not None and completion[q] >= 0:
            return
        s, base, obj = resolve(q, v, i, parent)
        job = (q, v, i, s, base, obj, t)
        if s < 0:
            # no alive copy anywhere: degraded completion, no queueing
            if hop_feedback:
                failed[q] = True
            push(t + model.remote_us, "advance", job)
            return
        if query_shed is not None and completion[q] < 0:
            # remaining slack check at every hop: elapsed sojourn plus
            # the subtree's jitter-free floor plus the barrier
            if (
                (t - arrivals_us[q]) + floors[v][q][1][i]
                + model.coordinator_us > deadlines[q]
            ):
                shed_query(q, t)
                return
        if batching is not None:
            pend = pending[s]
            pend.append(job)
            if len(pend) == 1:
                # first pending access arms the server's window
                push(t + batching.window_us, "flush", s)
            return
        if busy[s] < concurrency:
            start_service(t, s, job)
        else:
            queues[s].append((t, job))

    next_ci = 0
    cur_variant = 0
    since_reroute = 0
    think = float(think_time_us)

    def client_next(q, t_free):
        nonlocal next_ci
        if closed and is_closed[q] and next_ci < len(closed_ids):
            # the freed client thinks, then issues the next query
            delay = rng.exponential(think) if think > 0 else 0.0
            push(t_free + delay, "arrive", int(closed_ids[next_ci]))
            next_ci += 1

    def complete(q, t, v=0):
        nonlocal hedge_wins
        completion[q] = t + coord_barrier[q]
        if hedge is not None:
            tid = int(tenant_of[q]) if tenant_of is not None else 0
            hedge.observe(tid, completion[q] - arrivals_us[q])
            if hedge_fired[q] and v == 1:
                hedge_wins += 1
        client_next(q, completion[q])

    def shed_query(q, t):
        # fail-fast: the query completes degraded at the shed instant;
        # nothing below it dispatches, queued work is skipped at pop,
        # and a closed-loop client is freed to issue its next query
        query_shed[q] = True
        completion[q] = t
        client_next(q, t)

    def skip_job(job, t):
        """Lazily drop queued work that no longer needs serving."""
        nonlocal hedges_cancelled
        if job[0] == "batch":
            return False  # batch cost was committed at flush time
        q = job[0]
        if query_shed is not None:
            if query_shed[q]:
                return True
            if completion[q] < 0 and (
                (t - arrivals_us[q]) + floors[job[1]][q][1][job[2]]
                + model.coordinator_us > deadlines[q]
            ):
                # the FIFO wait ate the slack: shed at pop time
                shed_query(q, t)
                return True
        if hedge is not None and completion[q] >= 0:
            hedges_cancelled += 1
            return True
        return False

    def advance(t, job):
        nonlocal hedges_cancelled
        q, v, i, s = job[0], job[1], job[2], job[3]
        shed_q = query_shed is not None and query_shed[q]
        won = hedge is not None and completion[q] >= 0 and not shed_q
        if shed_q or won:
            # cancellation-on-first-completion / fail-fast: the subtree
            # below a dead attempt never dispatches (the router's
            # unconditional ``hedged`` mode keeps racing both — hedging's
            # capacity price — only the SLO-driven policy cancels)
            if won:
                hedges_cancelled += len(variants_trees[v][q][0][i][-1])
        else:
            for child in variants_trees[v][q][0][i][-1]:
                dispatch(t, q, v, child, s)
        remaining[(q, v)] -= 1
        if remaining[(q, v)] == 0 and completion[q] < 0:
            complete(q, t, v)

    def launch(t, q, v):
        """Dispatch one (query, variant); False = refused by admission."""
        if query_shed is not None:
            if query_shed[q]:
                return False
            if completion[q] < 0 and (
                (t - arrivals_us[q]) + floors[v][q][0]
                + model.coordinator_us > deadlines[q]
            ):
                return False
        nodes, roots = variants_trees[v][q]
        remaining[(q, v)] = len(nodes)
        if not nodes:
            if completion[q] < 0:
                complete(q, t, v)
            return True
        for i in roots:
            dispatch(t, q, v, i, -2)
        return True

    if closed:
        for _ in range(min(int(clients), len(closed_ids))):
            push(0.0, "arrive", int(closed_ids[next_ci]))
            next_ci += 1
        if mixed:
            for q in open_ids:
                push(float(arrivals_us[q]), "arrive", int(q))
    else:
        for q in range(nq):
            push(float(arrivals_us[q]), "arrive", q)
    if chaos:
        for ev in chaos:
            push(float(ev.at_us), "chaos", ev)

    arrivals_left = nq
    arrived_flag = np.zeros(nq, bool)
    qids_all = np.asarray(pathset.query_ids)
    live_depth = np.zeros(S, np.int64)
    live_busy = np.zeros(S, np.int64)

    def reroute_pending(live):
        """Re-pick hop targets for the queries that have NOT arrived yet.

        Already-arrived queries keep their old variant (in-flight work
        never re-routes), so each rebuild traces only the shrinking
        pending suffix instead of the whole pathset.
        """
        pending = np.nonzero(~arrived_flag)[0]
        vt: list = [([], [])] * nq
        vd = np.zeros(nq, bool)
        if len(pending):
            idx = np.nonzero(~arrived_flag[qids_all])[0]
            sub = PathSet(
                np.asarray(pathset.objects)[idx],
                np.asarray(pathset.lengths)[idx],
                np.searchsorted(pending, qids_all[idx]).astype(np.int32),
            )
            vt_sub, vd_sub = _build_variant(
                sub, cluster, model, alive, None, hop_policy, live
            )
            for li, g in enumerate(pending[: len(vt_sub)]):
                vt[int(g)] = vt_sub[li]
                vd[int(g)] = bool(vd_sub[li])
        variants_trees.append(vt)
        variants_dead.append(vd)
        if floors is not None:
            floors.append(_tree_floors(vt))
        return len(variants_trees) - 1

    while heap:
        t, _, kind, data = heapq.heappop(heap)
        if kind == "arrive":
            q = data
            if closed and is_closed[q]:
                arrivals_us[q] = t
            arrivals_left -= 1
            if arrivals_left == 0:
                # snapshot queueing state while traffic is still in flight
                # (the drained end state is always empty) — this is what
                # Cluster.queue_depths() hands the router between batches
                live_depth = np.asarray([len(qu) for qu in queues], np.int64)
                live_busy = busy.copy()
            if reroute_every is not None:
                since_reroute += 1
                if since_reroute >= int(reroute_every):
                    # re-pick hop targets against the simulator's own live
                    # queue state; the arriving query is still pending, so
                    # it launches on the fresh routes
                    since_reroute = 0
                    reroutes += 1
                    live = np.asarray(
                        [busy[s] + len(queues[s]) for s in range(S)],
                        np.int64,
                    )
                    cur_variant = reroute_pending(live)
            arrived_flag[q] = True
            if routing_table is not None:
                # client-side snapshot lookup: a live-valid pick goes
                # direct-to-shard and skips the coordinator barrier
                _, direct = routing_table.lookup(int(roots_all[q]), t)
                if direct:
                    coord_barrier[q] = 0.0
            if coord_policy == "hedged":
                # race both coordinator picks; first completion wins
                ok0 = launch(t, q, 0)
                ok1 = launch(t, q, 1) if coords[1][q] >= 0 else False
                if ok0 or ok1:
                    d0 = bool(variants_dead[0][q]) if ok0 else True
                    d1 = bool(variants_dead[1][q]) if ok1 else True
                    failed[q] = d0 and d1
                elif completion[q] < 0:
                    shed_query(q, t)
            elif coord_policy == "hedge_slo":
                # primary only; the backup fires from a learned-quantile
                # timer if the query is still incomplete by then
                if launch(t, q, 0):
                    failed[q] = variants_dead[0][q]
                    if coords[1][q] >= 0:
                        tid = (
                            int(tenant_of[q]) if tenant_of is not None else 0
                        )
                        th = hedge.threshold_us(tid)
                        if th is not None:
                            push(t + th, "hedge", q)
                elif completion[q] < 0:
                    shed_query(q, t)
            elif coord_policy == "replica_lb":
                # queue-aware: per arrival, the less-loaded coordinator
                c1, c2 = int(coords[0][q]), int(coords[1][q])
                v = 0
                if c2 >= 0 and c1 >= 0:
                    l1 = busy[c1] + len(queues[c1])
                    l2 = busy[c2] + len(queues[c2])
                    v = 1 if l2 < l1 else 0
                if launch(t, q, v):
                    failed[q] = variants_dead[v][q]
                elif completion[q] < 0:
                    shed_query(q, t)
            else:
                if launch(t, q, cur_variant):
                    # OR, not assignment: a hop-feedback launch may already
                    # have flagged the query dead at dispatch time
                    failed[q] = failed[q] or bool(
                        variants_dead[cur_variant][q]
                    )
                elif completion[q] < 0:
                    shed_query(q, t)
        elif kind == "done":
            s, job = data
            busy[s] -= 1
            while queues[s]:
                t_enq, nxt = queues[s].popleft()
                if skip_job(nxt, t):
                    continue
                n_waits += 1
                wait_us += t - t_enq
                start_service(t, s, nxt)
                break
            if job[0] == "batch":
                for m in job[2]:
                    advance(t, m)
            else:
                advance(t, job)
        elif kind == "flush":
            s = data
            pend = pending[s]
            if not pend:
                continue
            live = [j for j in pend if not skip_job(j, t)]
            take = batching.ladder.pick(len(live)) if live else 0
            members = live[:take]
            pending[s] = live[take:]
            if pending[s]:
                # leftovers flush immediately at the next ladder rung —
                # a deep backlog drains in rung-sized chunks without
                # re-arming the collection window
                push(t, "flush", s)
            if not members:
                continue
            total = model.dispatch_us + sum(j[4] for j in members)
            wrapper = ("batch", s, tuple(members), total)
            batch_stats.observe(len(members))
            if obs_batch_hist is not None:
                obs_batch_hist.record(float(len(members)))
            if busy[s] < concurrency:
                start_service(t, s, wrapper)
            else:
                queues[s].append((t, wrapper))
        elif kind == "hedge":
            q = data
            if completion[q] >= 0 or (
                query_shed is not None and query_shed[q]
            ):
                continue  # completed (or shed) before the timer: no hedge
            if hedges_fired >= hedge.max_hedges_frac * nq:
                continue  # capacity guard
            if launch(t, q, 1):
                hedges_fired += 1
                hedge_fired[q] = True
                failed[q] = failed[q] and bool(variants_dead[1][q])
        elif kind == "chaos":
            ev = data
            want = ev.kind == "revive"
            if alive[ev.server] != want:
                alive[ev.server] = want
                cluster.servers[ev.server].alive = want
                chaos_log.append((t, ev.kind, ev.server))
                if hop_feedback:
                    # next dispatch routes around the loss immediately
                    mask_alive = cluster.scheme.mask & alive[None, :]
                    fo_home = failover_home(cluster.scheme, alive)
                else:
                    # pending (not-yet-arrived) queries re-trace against
                    # the new liveness; in-flight work keeps its routes
                    live = np.asarray(
                        [busy[s] + len(queues[s]) for s in range(S)],
                        np.int64,
                    )
                    cur_variant = reroute_pending(live)
        else:  # "advance" (degraded hop completion)
            job = data
            if t_stage is not None and job[3] < 0:
                # no alive copy: the hop "served" nowhere — the span keeps
                # server -1 so the trace still accounts the lost time
                t_stage(job)
                t_stage(job[6])
                t_stage(t)
            advance(t, job)

    done = completion >= 0
    assert done.all(), "simulator leaked queries"
    duration = float(completion.max() - arrivals_us.min()) if nq else 0.0

    # expose the in-flight queueing state (sampled at the last arrival)
    # through the cluster's queue-aware hooks
    for s in cluster.servers:
        s.queue_depth = int(live_depth[s.server_id])
        s.busy = int(live_busy[s.server_id])

    if trace is not None:
        trace.policy = hop_policy.name
        # hand over the verdict arrays; decoding, per-query finalize, and
        # head/ring/violator sampling all happen lazily on first access,
        # so none of it is billed to the simulated run's wall clock
        trace.end_run(arrivals_us, completion, tenant_of, failed,
                      model.local_us, shed=query_shed)
    if obs.enabled():
        obs.REGISTRY.histogram("repro.serve.latency_us").record_many(
            completion - arrivals_us
        )
        obs.REGISTRY.counter("repro.serve.queries").inc(nq)
        obs.REGISTRY.counter("repro.serve.reroutes").inc(reroutes)
        obs.REGISTRY.gauge("repro.serve.mean_queue_wait_us").set(
            wait_us / n_waits if n_waits else 0.0
        )
        if query_shed is not None:
            obs.REGISTRY.counter("repro.serve.shed").inc(
                int(query_shed.sum())
            )
        if hedge is not None:
            obs.REGISTRY.counter("repro.serve.hedges_fired").inc(
                hedges_fired
            )
            obs.REGISTRY.counter("repro.serve.hedge_wins").inc(hedge_wins)

    return SimReport(
        latency_us=completion - arrivals_us,
        arrival_us=arrivals_us,
        query_failed=failed,
        busy_us=busy_us,
        queue_wait_us=wait_us / n_waits if n_waits else 0.0,
        duration_us=duration,
        offered_qps=0.0 if closed else rate_qps,
        concurrency=concurrency,
        tenant_of=tenant_of,
        tenant_names=tenant_names,
        closed_loop=closed,
        n_clients=int(clients or 0),
        policy=hop_policy.name,
        reroutes=reroutes,
        hop_feedback=hop_feedback,
        query_shed=query_shed,
        closed_mask=is_closed if mixed else None,
        batch_stats=batch_stats,
        slo_hedging=hedge is not None,
        hedges_fired=hedges_fired,
        hedge_wins=hedge_wins,
        hedges_cancelled=hedges_cancelled,
        chaos_events=chaos_log,
        routing=routing_table.summary() if routing_table is not None else None,
    )
