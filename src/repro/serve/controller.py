"""Adaptive replication controller: close the loop around the greedy core.

The paper's algorithm is offline — analyze the workload, replicate once,
serve.  Under drift the hotspot moves and the scheme silently stops being
feasible; rebuilding from scratch re-prices every path and re-ships the
whole replica set.  This controller instead watches **per-tenant sliding
windows** of served queries and, on violation, repairs *incrementally*:

  1. **monitor** — every completed batch feeds per-query traversal counts
     (from the resident ``LatencyEngine``, one streamed evaluation) and,
     when available, simulated wall-clock latencies into each tenant's own
     window; each query is judged against *its own* budget t_Q (an
     ``SLOSpec``, scalar config broadcast as the degenerate case).  The
     trigger is per tenant: a feasibility violation (> ``violation_frac``
     of the tenant's windowed queries exceed their t_Q) or that tenant's
     wall-clock p99 SLO breach;
  2. **arbitrate** — when several tenants trigger in the same step *and*
     capacity / load-balance headroom is finite, their repairs compete for
     the same bytes: the tenant with the cheapest estimated *weighted*
     marginal-bytes-per-violation (estimated bytes divided by
     ``TenantSpec.weight``, so paying tenants outrank) wins this round,
     the losers are *deferred* (named in the report; their windows still
     violate, so they re-trigger on a later step — and a deferred tenant
     outranks any weight on the next contended round, so low-weight
     tenants cannot starve).  With unbounded headroom all triggered
     tenants repair together in one vector-budget pass;
  3. **repair** — the *violating paths observed in the windows* (a tiny
     delta, not the workload) go through
     :func:`repro.core.greedy.replicate_delta` with their per-path budget
     vector: the batched Alg 2 UPDATE warm-started against the engine's
     device-resident ``PackedScheme`` (bit-tests + scatter-OR adds, no
     rebuild, sound by Thm 5.3);
  4. **apply** — the returned (object, server) delta lands on the live
     ``Cluster`` via ``apply_scheme_delta`` (monotone mask flips) and its
     resharding-map entries are recorded, so later reshards still work;
  5. **evict** — when storage pressure exceeds capacity, replicas that
     have been cold (untouched by any windowed path) for
     ``demote_after`` *consecutive eviction checks* — demotion
     hysteresis, preventing add/evict thrash on an oscillating hotspot —
     *and* are unreferenced by the §5.4 resharding map (RC == 0) are
     dropped, largest first, until the cluster fits.  Eviction re-packs
     the engine (removals are not monotone).

The controller never blocks serving: observe() is one engine evaluation
plus (rarely) one warm-started greedy pass over a few hundred paths.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque

import numpy as np

from repro.core.greedy import replicate_delta
from repro.core.paths import PathSet
from repro.core.reshard import ReshardingMap
from repro.core.slo import SLOSpec, TenantSpec
from repro.distsys.cluster import Cluster
from repro.engine import KResilient, LatencyEngine
from repro.obs import attribute_burn


@dataclasses.dataclass
class ControllerConfig:
    t: int | None = None                    # scalar budget (single-tenant)
    window: int = 1024                      # queries kept per tenant window
    violation_frac: float = 0.01            # windowed infeasible-query frac
    p99_slo_us: float | None = None         # wall-clock p99 SLO fallback
    capacity: np.ndarray | float | None = None
    epsilon: float | None = None
    min_queries: int = 64                   # don't trigger on tiny windows
    demote_after: int = 1                   # consecutive cold checks before
    #                                         a replica may be evicted
    tenants: tuple[TenantSpec, ...] = ()    # known tenants (budgets + SLOs)
    # per-tenant cap on cumulative repair bytes: a scalar applies to every
    # tenant, a dict maps tenant name -> cap (missing names are uncapped).
    # The cap shapes *arbitration*, not repair itself: on a contended round
    # a tenant over its quota ranks behind every under-quota competitor,
    # so one tenant's runaway hotspot cannot monopolize the shared
    # capacity/epsilon headroom round after round.  Aging still dominates —
    # a tenant deferred for >= ``quota_grace`` consecutive steps is
    # "starving" and wins the round outright even over quota, so a capped
    # tenant with a persistent violation is delayed, never denied.
    tenant_quota_bytes: float | dict | None = None
    quota_grace: int = 3                    # deferred steps before starving
    # routing policy h is scored under for triggers / window re-checks
    # AND the policy repairs are priced under (replicate_delta(policy=)):
    # "home_first" (historical) or "nearest_copy" (the paper-faithful
    # any-co-located-replica reading — tighter, so fewer false triggers
    # and no bytes bought for paths the routed walk already serves)
    score_policy: str = "home_first"
    # route window evaluations (observe() scoring + post-repair window
    # re-checks) through the engine's persistent dirty-set cache
    # (``path_latencies(incremental=True)``): after a repair, only the
    # windowed paths touching the delta's objects are re-walked.
    # Bit-identical to full re-evaluation; off reproduces the historical
    # evaluate-everything profile
    incremental_recheck: bool = True

    def __post_init__(self):
        if self.t is None and not self.tenants:
            raise ValueError("ControllerConfig needs a scalar t or tenants")

    def default_slo(self, n_queries: int) -> SLOSpec:
        """Spec for batches observed without an explicit SLOSpec."""
        if self.t is not None:
            return SLOSpec.uniform(
                self.t, n_queries, tenant="default",
                p99_slo_us=self.p99_slo_us,
            )
        if len(self.tenants) == 1:
            return SLOSpec.from_tenants(
                self.tenants, np.zeros(n_queries, np.int32)
            )
        raise ValueError(
            "multi-tenant config: observe() needs the batch's SLOSpec"
        )


@dataclasses.dataclass
class AdaptationReport:
    """What one repair did (the benchmark's bytes-replicated accounting)."""

    step: int
    trigger: str          # "feasibility" | "p99_slo" | "forecast" | "liveness"
    paths_repaired: int
    replicas_added: int
    bytes_added: float
    replicas_evicted: int
    bytes_evicted: float
    feasible_after: bool
    runtime_s: float
    tenants: tuple[str, ...] = ("default",)   # whose violations were repaired
    deferred: tuple[str, ...] = ()            # arbitration losers this round
    additions: tuple[np.ndarray, np.ndarray] = dataclasses.field(
        default=(np.zeros(0, np.int64), np.zeros(0, np.int64)), repr=False
    )
    # why the repair triggered, per repaired tenant: burn rate over the
    # traced window plus the per-server blame decomposition (which server's
    # queues ate the violators' budgets) — present when observe() was
    # handed the serving run's span trace
    blame: dict | None = None


@dataclasses.dataclass
class _Entry:
    """One observed batch, restricted to one tenant's paths/queries."""

    pathset: PathSet          # tenant's paths (batch-local query ids)
    path_lats: np.ndarray     # int32 per path
    path_budgets: np.ndarray  # int32 per path (each path's own t_q)
    n_queries: int            # tenant queries in the batch
    n_bad: int                # tenant queries whose l_Q exceeded their t_Q
    latency_us: np.ndarray | None  # tenant queries' wall-clock latencies


@dataclasses.dataclass
class _TenantWindow:
    spec: TenantSpec
    entries: deque = dataclasses.field(default_factory=deque)
    n_queries: int = 0
    last_seen_step: int = 0     # step of the newest observed entry
    last_repair_step: int = -1  # step this tenant was last repaired at

    def violation_frac(self) -> float:
        if not self.n_queries:
            return 0.0
        return sum(e.n_bad for e in self.entries) / self.n_queries

    def p99_us(self) -> float | None:
        lats = [e.latency_us for e in self.entries if e.latency_us is not None]
        if not lats:
            return None
        return float(np.percentile(np.concatenate(lats), 99.0))


def evict_cold_replicas(
    cluster: Cluster,
    rmap: ReshardingMap,
    active_objects: np.ndarray,
    f: np.ndarray | None = None,
    capacity: np.ndarray | float | None = None,
    cold_streak: dict[tuple[int, int], int] | None = None,
    min_streak: int = 1,
) -> tuple[int, float]:
    """Drop cold, RM-unreferenced replicas until every server fits.

    Cost-aware in the §5.4 sense: only replicas with ``RC(v, s) == 0`` are
    candidates — the resharding map holds no association that would have to
    be re-transferred after an original-copy move — and originals and
    window-active objects are never touched.  Within a server, largest
    ``f(v)`` goes first (frees the most bytes per eviction).

    ``cold_streak`` adds demotion hysteresis: a replica is only eligible
    once it has been observed cold ``min_streak`` consecutive times (the
    controller maintains the streak counters); evicted pairs are removed
    from the dict.  Without it every cold replica is immediately eligible
    (the pre-hysteresis behavior).
    """
    scheme = cluster.scheme
    if capacity is None:
        return 0, 0.0
    fv = (
        np.ones(scheme.n_objects, np.float64)
        if f is None
        else np.asarray(f, np.float64)
    )
    cap = np.broadcast_to(
        np.asarray(capacity, np.float64), (scheme.n_servers,)
    )
    load = scheme.storage_per_server(fv)
    active = np.zeros(scheme.n_objects, bool)
    active[np.asarray(active_objects, np.int64)] = True
    n_evicted = 0
    bytes_evicted = 0.0
    for s in np.argsort(-(load - cap)):
        if load[s] <= cap[s]:
            continue
        cands = np.nonzero(
            scheme.mask[:, s] & (scheme.shard != s) & ~active
        )[0]
        cands = [
            int(v) for v in cands if rmap.rc.get((int(v), int(s)), 0) == 0
        ]
        if cold_streak is not None:
            cands = [
                v for v in cands
                if cold_streak.get((v, int(s)), 0) >= min_streak
            ]
        cands.sort(key=lambda v: -fv[v])
        for v in cands:
            if load[s] <= cap[s]:
                break
            scheme.mask[v, s] = False
            load[s] -= fv[v]
            n_evicted += 1
            bytes_evicted += float(fv[v])
            if cold_streak is not None:
                cold_streak.pop((v, int(s)), None)
    return n_evicted, bytes_evicted


class AdaptiveController:
    """Per-tenant sliding-window monitor + incremental repair over a live
    cluster.

    The controller shares the cluster's ``ReplicationScheme`` object with
    its ``LatencyEngine``, so the engine's device-resident packed words,
    the host mask, and the cluster's routing state stay one source of
    truth: warm-start additions scatter-OR into the packed words and flip
    the same host mask the router reads.

    Each observed batch may carry its own :class:`SLOSpec` (per-query
    budgets + query->tenant map); without one the config's scalar ``t``
    broadcasts to a single "default" tenant — the degenerate case that
    reproduces the original scalar controller exactly.
    """

    def __init__(
        self,
        cluster: Cluster,
        config: ControllerConfig,
        f: np.ndarray | None = None,
        engine: LatencyEngine | None = None,
        rmap: ReshardingMap | None = None,
    ):
        self.cluster = cluster
        self.config = config
        self.f = None if f is None else np.asarray(f, np.float32)
        self.engine = engine or LatencyEngine(cluster.scheme)
        assert self.engine.scheme is cluster.scheme, (
            "controller engine must wrap the cluster's live scheme"
        )
        self.rmap = rmap or ReshardingMap({}, {})
        self._tenants: dict[str, _TenantWindow] = {}
        # demotion hysteresis: (object, server) -> consecutive cold checks
        self._cold_streak: dict[tuple[int, int], int] = {}
        # arbitration aging: tenant -> step it was first deferred at; a
        # deferred tenant wins the next contended round outright (oldest
        # first), so a persistently-cheap tenant can't starve the rest
        self._deferred_since: dict[str, int] = {}
        # cumulative repair bytes attributed per tenant (quota accounting)
        self._tenant_bytes: dict[str, float] = {}
        self.step = 0
        self.reports: list[AdaptationReport] = []

    # -- monitoring --------------------------------------------------------
    def window_feasible_frac(self) -> float:
        """1 - fraction of windowed queries exceeding their t_Q (all
        tenants pooled; diagnostics)."""
        total = sum(w.n_queries for w in self._tenants.values())
        if not total:
            return 1.0
        bad = sum(
            e.n_bad for w in self._tenants.values() for e in w.entries
        )
        return 1.0 - bad / total

    def tenant_stats(self) -> dict[str, dict]:
        """Per-tenant window diagnostics (violation frac, p99, size)."""
        return {
            name: {
                "violation_frac": w.violation_frac(),
                "p99_us": w.p99_us(),
                "window_queries": w.n_queries,
                "t_q": w.spec.t_q,
                "repair_bytes": self._tenant_bytes.get(name, 0.0),
                "quota_bytes": self._quota_of(name),
            }
            for name, w in self._tenants.items()
        }

    def _quota_of(self, name: str) -> float | None:
        q = self.config.tenant_quota_bytes
        if q is None:
            return None
        if isinstance(q, dict):
            v = q.get(name)
            return None if v is None else float(v)
        return float(q)

    def _over_quota(self, name: str) -> bool:
        cap = self._quota_of(name)
        return cap is not None and self._tenant_bytes.get(name, 0.0) >= cap

    def _window_of(self, spec: TenantSpec) -> _TenantWindow:
        w = self._tenants.get(spec.name)
        if w is None:
            w = _TenantWindow(spec=spec)
            self._tenants[spec.name] = w
        else:
            w.spec = spec  # newest spec wins (budgets may be re-tuned live)
        return w

    def observe(
        self,
        pathset: PathSet,
        latency_us: np.ndarray | None = None,
        slo: SLOSpec | None = None,
        trace=None,
        forecast: PathSet | None = None,
        forecast_slo: SLOSpec | None = None,
    ) -> AdaptationReport | None:
        """Feed one served batch; repair and return a report on violation.

        ``pathset`` is the batch's observed access paths (what the serving
        layer routed); ``latency_us`` the simulator's per-query sojourn
        times for the optional wall-clock SLO trigger; ``slo`` the batch's
        per-query budgets + tenant map (defaults to the config's scalar
        ``t`` under a single "default" tenant); ``trace`` the serving
        run's :class:`repro.obs.Tracer` — when given, a repair's report
        carries ``blame``: per repaired tenant, the SLO burn rate and the
        per-server decomposition of where the violators' budgets went.

        ``forecast`` is a PathSet delta the caller expects to start
        serving soon (e.g. the next :class:`~repro.serve.drift.PhaseDelta`
        observed upstream before its violations land): the controller
        *pre-warms* a repair for the forecast paths that are already over
        budget under the live scheme, so the phase flip arrives against a
        scheme provisioned for it.  Cheap by construction — the forecast's
        dirty set is small, and the warm-started delta pass prices only
        its infeasible paths.  ``forecast_slo`` carries the forecast's
        budgets (defaults like ``slo``).  A reactive repair, if one also
        triggered this step, takes precedence in the returned report; the
        forecast report is appended to :attr:`reports` either way.
        """
        self.step += 1
        slo = slo if slo is not None else self.config.default_slo(
            pathset.n_queries
        )
        assert slo.n_queries == pathset.n_queries
        pl = self.engine.path_latencies(
            pathset, policy=self.config.score_policy,
            incremental=self.config.incremental_recheck,
        )
        qids = np.asarray(pathset.query_ids)
        ql = self.engine.query_latencies(pathset, pl)
        bad_q = ql > slo.t_q  # each query vs its OWN budget
        t_path = slo.t_q[qids] if len(qids) else np.zeros(0, np.int32)

        for tid, ts in enumerate(slo.tenants):
            q_sel = slo.tenant_of == tid
            if not q_sel.any():
                continue
            p_sel = q_sel[qids] if len(qids) else np.zeros(0, bool)
            p_idx = np.nonzero(p_sel)[0]
            w = self._window_of(ts)
            w.entries.append(
                _Entry(
                    # single-tenant batches (the degenerate case) are kept
                    # by reference, not copied
                    pathset=(
                        pathset if p_sel.all() else pathset.select(p_idx)
                    ),
                    path_lats=pl if p_sel.all() else pl[p_idx],
                    path_budgets=(
                        t_path if p_sel.all() else t_path[p_idx]
                    ),
                    n_queries=int(q_sel.sum()),
                    n_bad=int(bad_q[q_sel].sum()),
                    latency_us=(
                        np.asarray(latency_us)[q_sel]
                        if latency_us is not None
                        else None
                    ),
                )
            )
            w.n_queries += int(q_sel.sum())
            w.last_seen_step = self.step
            while w.n_queries > self.config.window and len(w.entries) > 1:
                w.n_queries -= w.entries.popleft().n_queries

        triggered = self._triggered_tenants()
        # a deferral only keeps its aging claim while the tenant's
        # violation persists — if it cleared on its own (e.g. another
        # tenant's repair covered the shared paths), the stale entry must
        # not grant arbitration priority on some much later round
        names = {name for name, _ in triggered}
        self._deferred_since = {
            k: v for k, v in self._deferred_since.items() if k in names
        }
        if not triggered:
            if forecast is not None:
                return self._prewarm(forecast, forecast_slo)
            return None

        contended = (
            self.config.capacity is not None
            or self.config.epsilon is not None
        ) and len(triggered) > 1
        if contended:
            # arbitration: repairs compete for the same capacity/epsilon
            # headroom — cheapest estimated *weighted* marginal-byte-per-
            # violation wins this round (estimated bytes / tenant weight,
            # so a paying tenant's violations buy proportionally more
            # bytes), everyone else is deferred (their windows still
            # violate, so they re-trigger on a later observe()).  Quota
            # caps rank an over-budget tenant behind every under-quota
            # competitor; aging breaks starvation two ways: a *starving*
            # tenant (deferred >= quota_grace consecutive steps) wins the
            # round outright — even over quota — and among the rest an
            # earlier deferral outranks any weight or score.
            scored = sorted(
                (
                    not (
                        self.step
                        - self._deferred_since.get(name, self.step)
                        >= self.config.quota_grace
                    ),
                    self._over_quota(name),
                    self._deferred_since.get(name, self.step),
                    self._repair_score(name)
                    / self._tenants[name].spec.weight,
                    name,
                    trig,
                )
                for name, trig in triggered
            )
            repair = [(scored[0][4], scored[0][5])]
            deferred = tuple(name for *_, name, _ in scored[1:])
            for name in deferred:
                self._deferred_since.setdefault(name, self.step)
        else:
            repair = triggered
            deferred = ()
        for name, _ in repair:
            self._deferred_since.pop(name, None)
        report = self._adapt(repair, deferred)
        if trace is not None:
            burn = attribute_burn(
                trace,
                tenant_names=tuple(ts.name for ts in slo.tenants),
                allowed_frac=self.config.violation_frac,
            )
            report.blame = {
                name: burn[name].summary()
                for name in report.tenants
                if name in burn.tenants
            }
        if forecast is not None:
            # the reactive repair ran first; the pre-warm tops it up for
            # the forecast paths it did not cover (and is a cheap no-op
            # when the forecast is already feasible)
            self._prewarm(forecast, forecast_slo)
        return report

    def _triggered_tenants(self) -> list[tuple[str, str]]:
        out = []
        for name, w in self._tenants.items():
            if w.n_queries < self.config.min_queries:
                continue
            # a repair attempt (even one that couldn't fix anything, e.g.
            # fully capacity-blocked) re-arms only on NEW evidence for this
            # tenant — otherwise an unrepairable window would re-fire a
            # full no-op repair on every later observe() of anyone's
            # traffic (the old global window aged such entries out)
            if w.last_seen_step <= w.last_repair_step:
                continue
            if w.violation_frac() > self.config.violation_frac:
                out.append((name, "feasibility"))
                continue
            p99_slo = (
                w.spec.p99_slo_us
                if w.spec.p99_slo_us is not None
                else self.config.p99_slo_us
            )
            if p99_slo is not None:
                p99 = w.p99_us()
                if p99 is not None and p99 > p99_slo:
                    out.append((name, "p99_slo"))
        return out

    # -- repair ------------------------------------------------------------
    def _violating(self, name: str):
        """(violating-path PathSets, per-part per-path budgets) of a tenant."""
        parts, budgets = [], []
        for e in self._tenants[name].entries:
            idx = np.nonzero(e.path_lats > e.path_budgets)[0]
            if len(idx):
                parts.append(e.pathset.select(idx))
                budgets.append(e.path_budgets[idx])
        return parts, budgets

    def _repair_score(self, name: str) -> float:
        """Estimated marginal bytes per violating query (arbitration key).

        Upper-bound estimate, priced against the engine's device-resident
        snapshot: replicate every non-root object of each violating path
        to the path's coordinator (the root's home server) — the t=0-style
        candidate that dominates all of Alg 2's cheaper merges.
        """
        parts, _ = self._violating(name)
        if not parts:
            return float("inf")
        shard = self.engine.host_shard()
        est = 0.0
        n_viol = 0
        for part in parts:
            tails = np.asarray(part.objects[:, 1:], np.int32)
            if tails.size == 0:
                continue
            root_home = shard[np.maximum(part.objects[:, 0], 0)]
            srv = np.broadcast_to(root_home[:, None], tails.shape)
            est += float(
                np.sum(self.engine.margin_costs(tails, srv, self.f))
            )
            n_viol += int(np.unique(np.asarray(part.query_ids)).size)
        return est / max(n_viol, 1)

    def _active_objects(self) -> np.ndarray:
        objs = [
            np.asarray(e.pathset.objects).ravel()
            for w in self._tenants.values()
            for e in w.entries
        ]
        cat = np.concatenate(objs) if objs else np.zeros(0, np.int64)
        return np.unique(cat[cat >= 0])

    def _reeval_windows(self, repaired_names: set) -> bool:
        """Re-judge every windowed entry against the live scheme.

        The stored per-path latencies are stale after any scheme change
        and would re-trigger forever.  With ``incremental_recheck`` each
        entry's evaluation goes through the engine's dirty-set cache, so
        only the windowed paths touching the delta's objects are actually
        re-walked — the steady-state cost of a repair round scales with
        the delta, not the window.  Wall-clock latencies are dropped only
        for REPAIRED tenants (theirs were measured against the pre-repair
        scheme; a deferred tenant keeps its p99 evidence — it must win
        the next arbitration round).  Returns whether every repaired
        tenant's window is feasible after the change.
        """
        inc = self.config.incremental_recheck
        feasible = True
        for name, w in self._tenants.items():
            for e in w.entries:
                e.path_lats = self.engine.path_latencies(
                    e.pathset, policy=self.config.score_policy,
                    incremental=inc,
                )
                qids = np.asarray(e.pathset.query_ids)
                if len(qids):
                    ql = self.engine.query_latencies(e.pathset, e.path_lats)
                    slack_bad = ql[qids] > e.path_budgets
                    e.n_bad = int(np.unique(qids[slack_bad]).size)
                else:
                    e.n_bad = 0
                if name in repaired_names:
                    e.latency_us = None
                    if e.n_bad:
                        feasible = False
            if name in repaired_names:
                w.last_repair_step = self.step
        return feasible

    def _prewarm(
        self, forecast: PathSet, forecast_slo: SLOSpec | None
    ) -> AdaptationReport:
        """Repair a *forecast* PathSet delta before its violations land.

        Evaluates the forecast against the live scheme (through the
        dirty-set cache when enabled — repeated forecasts of the same
        PathSet cost only their dirty fraction), selects the paths
        already over their budgets, and warm-starts the same
        ``replicate_delta`` pass a reactive repair would run — so when
        the drift phase actually flips, the scheme is already provisioned
        for it and the violation window the reactive loop would have
        served through never opens.  Feasible forecasts are near-free: a
        gather-compacted evaluation plus no-op repair.
        """
        t0 = time.perf_counter()
        slo = (
            forecast_slo
            if forecast_slo is not None
            else self.config.default_slo(forecast.n_queries)
        )
        inc = self.config.incremental_recheck
        pl = self.engine.path_latencies(
            forecast, policy=self.config.score_policy, incremental=inc
        )
        qids = np.asarray(forecast.query_ids)
        t_path = slo.t_q[qids] if len(qids) else np.zeros(0, np.int32)
        idx = np.nonzero(pl > t_path)[0]
        add_obj = np.zeros(0, np.int64)
        add_srv = np.zeros(0, np.int64)
        n_paths = int(len(idx))
        if n_paths:
            bad = forecast.select(idx)
            tq_q = np.full(bad.n_queries, np.int32(0))
            tq_q[np.asarray(bad.query_ids)] = t_path[idx]
            bad_slo = SLOSpec(
                tq_q,
                np.zeros(bad.n_queries, np.int32),
                (TenantSpec("forecast", 0),),
            )
            stats, (add_obj, add_srv) = replicate_delta(
                bad,
                self.engine,
                bad_slo,
                f=self.f,
                capacity=self.config.capacity,
                epsilon=self.config.epsilon,
                track_rm=True,
                policy=self.config.score_policy,
            )
            self.cluster.apply_scheme_delta(add_obj, add_srv)
            for u, v, s in stats.rm or ():
                self.rmap.rm.setdefault(int(u), set()).add(int(v))
                self.rmap.rc[(int(v), int(s))] = (
                    self.rmap.rc.get((int(v), int(s)), 0) + 1
                )
            # windows were scored against the pre-warm scheme: re-judge
            # (dirty-scoped), without re-arming any tenant's repair state
            self._reeval_windows(set())
        fv = (
            np.ones(len(add_obj)) if self.f is None else self.f[add_obj]
        )
        report = AdaptationReport(
            step=self.step,
            trigger="forecast",
            paths_repaired=n_paths,
            replicas_added=int(len(add_obj)),
            bytes_added=float(np.sum(fv)) if len(add_obj) else 0.0,
            replicas_evicted=0,
            bytes_evicted=0.0,
            feasible_after=bool(
                self.engine.is_feasible(
                    forecast, slo, policy=self.config.score_policy,
                    incremental=inc,
                )
            ),
            runtime_s=time.perf_counter() - t0,
            tenants=("forecast",),
            additions=(add_obj, add_srv),
        )
        self.reports.append(report)
        return report

    def _update_cold_streaks(self, active_objects: np.ndarray) -> None:
        """Advance the per-replica cold streak counters (hysteresis).

        A replica is "cold" when no windowed path touched its object.  A
        streak survives only while the pair stays cold on *consecutive*
        checks; touching the object (or losing the replica) resets it.
        """
        scheme = self.cluster.scheme
        repl = scheme.mask.copy()
        repl[np.arange(scheme.n_objects), scheme.shard] = False
        act = np.zeros(scheme.n_objects, bool)
        act[active_objects] = True
        vs, ss = np.nonzero(repl & ~act[:, None])
        fresh: dict[tuple[int, int], int] = {}
        for v, s in zip(vs.tolist(), ss.tolist()):
            fresh[(v, s)] = self._cold_streak.get((v, s), 0) + 1
        self._cold_streak = fresh

    def _adapt(
        self, repair: list[tuple[str, str]], deferred: tuple[str, ...]
    ) -> AdaptationReport:
        t0 = time.perf_counter()
        # one vector-budget delta pass over every repaired tenant's
        # violating paths: each path keeps its own t_q
        parts: list[PathSet] = []
        part_tq: list[np.ndarray] = []
        part_tenant: list[np.ndarray] = []
        table: list[TenantSpec] = []
        for name, _ in repair:
            tid = len(table)
            table.append(self._tenants[name].spec)
            t_parts, t_budgets = self._violating(name)
            for part, pb in zip(t_parts, t_budgets):
                nq_p = part.n_queries
                tq_q = np.full(nq_p, table[tid].t_q, np.int32)
                tq_q[np.asarray(part.query_ids)] = pb
                parts.append(part)
                part_tq.append(tq_q)
                part_tenant.append(np.full(nq_p, tid, np.int32))
        if parts:
            bad = PathSet.concatenate(parts)
            bad_slo = SLOSpec(
                np.concatenate(part_tq),
                np.concatenate(part_tenant),
                tuple(table) or (TenantSpec("default", 0),),
            )
        else:
            bad = PathSet.from_lists([])
            bad_slo = SLOSpec.uniform(0, 0)

        # repair under the SAME policy the violations were scored with:
        # a nearest_copy-scored trigger is repaired by the policy-aware
        # delta pass, so the controller never buys home-first bytes the
        # serving walk will not use (score_policy="home_first" keeps the
        # historical pricing, bit-identical)
        stats, (add_obj, add_srv) = replicate_delta(
            bad,
            self.engine,
            bad_slo,
            f=self.f,
            capacity=self.config.capacity,
            epsilon=self.config.epsilon,
            track_rm=True,
            policy=self.config.score_policy,
        )
        # the engine already flipped the shared host mask; this records the
        # delta through the cluster's own hook (idempotent monotone flips)
        self.cluster.apply_scheme_delta(add_obj, add_srv)
        for u, v, s in stats.rm or ():
            self.rmap.rm.setdefault(int(u), set()).add(int(v))
            self.rmap.rc[(int(v), int(s))] = (
                self.rmap.rc.get((int(v), int(s)), 0) + 1
            )

        n_ev = 0
        bytes_ev = 0.0
        if self.config.capacity is not None:
            active = self._active_objects()
            self._update_cold_streaks(active)
            n_ev, bytes_ev = evict_cold_replicas(
                self.cluster, self.rmap, active, self.f,
                self.config.capacity,
                cold_streak=self._cold_streak,
                min_streak=self.config.demote_after,
            )
            if n_ev:
                self.engine.refresh()  # removals are not monotone: re-pack

        fv = (
            np.ones(len(add_obj))
            if self.f is None
            else self.f[add_obj]
        )
        # quota accounting: the vector-budget pass does not attribute
        # individual replicas to tenants, so a shared round splits its
        # bytes evenly; contended rounds repair exactly one tenant, and
        # there the attribution is exact
        if len(add_obj) and repair:
            share = float(np.sum(fv)) / len(repair)
            for name, _ in repair:
                self._tenant_bytes[name] = (
                    self._tenant_bytes.get(name, 0.0) + share
                )
        # re-evaluate every window against the repaired scheme: the stored
        # per-path latencies are stale and would re-trigger forever.  The
        # wall-clock latencies are dropped only for the REPAIRED tenants —
        # theirs were measured against the pre-repair scheme, and keeping
        # them would make a queueing-only p99 breach re-fire no-op repairs
        # until the batch ages out (the p99 trigger re-arms on fresh
        # measurements).  A deferred tenant keeps its p99 evidence: nothing
        # was repaired for it, and wiping it would erase the very violation
        # that must win the next arbitration round.
        repaired_names = {name for name, _ in repair}
        feasible = self._reeval_windows(repaired_names)

        triggers = [trig for _, trig in repair]
        report = AdaptationReport(
            step=self.step,
            trigger=(
                "feasibility" if "feasibility" in triggers else triggers[0]
            ),
            paths_repaired=bad.n_paths,
            replicas_added=int(len(add_obj)),
            bytes_added=float(np.sum(fv)) if len(add_obj) else 0.0,
            replicas_evicted=n_ev,
            bytes_evicted=bytes_ev,
            feasible_after=feasible,
            runtime_s=time.perf_counter() - t0,
            tenants=tuple(name for name, _ in repair),
            deferred=deferred,
            additions=(add_obj, add_srv),
        )
        self.reports.append(report)
        return report

    def on_liveness_change(
        self, pathset: PathSet, slo: SLOSpec | None = None
    ) -> AdaptationReport | None:
        """React to a liveness change: provision around the dead set.

        The serving layer routes around dead servers (``failover_home``),
        but routed-around queries pay extra distributed traversals the
        greedy bound never priced — the chaos violation window.  This
        closes it proactively: the currently-dead servers become a single
        loss case (``KResilient(k=1, domains=(dead,))``), and one
        ``replicate_delta`` pass provisions replicas *on survivors* until
        every path meets its budget with the dead set masked out — the
        same masked re-walk machinery the k-resilient gate uses at
        provisioning time, warm-started from the live scheme.

        No-op (returns None) when every server is alive; safe to call on
        every kill *and* revive — a revive shrinks the dead set, and the
        remaining dead servers still get their loss case repaired.  The
        additions are monotone, so a later revive never invalidates them
        (Thm 5.3); they simply become standing k-resilience headroom.
        """
        t0 = time.perf_counter()
        alive = np.asarray([s.alive for s in self.cluster.servers], bool)
        dead = np.nonzero(~alive)[0]
        if not len(dead):
            return None
        slo = (
            slo if slo is not None
            else self.config.default_slo(pathset.n_queries)
        )
        res = KResilient(k=1, domains=(tuple(int(s) for s in dead),))
        stats, (add_obj, add_srv) = replicate_delta(
            pathset,
            self.engine,
            slo,
            f=self.f,
            capacity=self.config.capacity,
            epsilon=self.config.epsilon,
            track_rm=True,
            policy=self.config.score_policy,
            resilience=res,
        )
        self.cluster.apply_scheme_delta(add_obj, add_srv)
        for u, v, s in stats.rm or ():
            self.rmap.rm.setdefault(int(u), set()).add(int(v))
            self.rmap.rc[(int(v), int(s))] = (
                self.rmap.rc.get((int(v), int(s)), 0) + 1
            )
        # windows were scored against the pre-repair scheme: re-judge
        # (dirty-scoped), without re-arming any tenant's repair state
        self._reeval_windows(set())
        fv = np.ones(len(add_obj)) if self.f is None else self.f[add_obj]
        report = AdaptationReport(
            step=self.step,
            trigger="liveness",
            paths_repaired=pathset.n_paths,
            replicas_added=int(len(add_obj)),
            bytes_added=float(np.sum(fv)) if len(add_obj) else 0.0,
            replicas_evicted=0,
            bytes_evicted=0.0,
            feasible_after=bool(
                self.engine.is_resilient_feasible(
                    pathset, slo.t_q, res,
                    policy=self.config.score_policy,
                )
            ),
            runtime_s=time.perf_counter() - t0,
            tenants=("liveness",),
            additions=(add_obj, add_srv),
        )
        self.reports.append(report)
        return report
