"""Adaptive replication controller: close the loop around the greedy core.

The paper's algorithm is offline — analyze the workload, replicate once,
serve.  Under drift the hotspot moves and the scheme silently stops being
feasible; rebuilding from scratch re-prices every path and re-ships the
whole replica set.  This controller instead watches a **sliding window**
of served queries and, on violation, repairs *incrementally*:

  1. **monitor** — every completed batch feeds per-query traversal counts
     (from the resident ``LatencyEngine``, one streamed evaluation) and,
     when available, simulated wall-clock latencies into the window; the
     trigger is either a feasibility violation (> ``violation_frac`` of
     windowed queries exceed ``t`` traversals) or a wall-clock p99 SLO
     breach;
  2. **repair** — the *violating paths observed in the window* (a tiny
     delta, not the workload) go through
     :func:`repro.core.greedy.replicate_delta`: the batched Alg 2 UPDATE
     warm-started against the engine's device-resident ``PackedScheme``
     (bit-tests + scatter-OR adds, no rebuild, sound by Thm 5.3);
  3. **apply** — the returned (object, server) delta lands on the live
     ``Cluster`` via ``apply_scheme_delta`` (monotone mask flips) and its
     resharding-map entries are recorded, so later reshards still work;
  4. **evict** — when storage pressure exceeds capacity, replicas that are
     cold (not touched by any windowed path) *and* unreferenced by the
     §5.4 resharding map (RC == 0 — evicting them cannot strand a future
     incremental reshard) are dropped, largest first, until the cluster
     fits.  Eviction re-packs the engine (removals are not monotone).

The controller never blocks serving: observe() is one engine evaluation
plus (rarely) one warm-started greedy pass over a few hundred paths.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque

import numpy as np

from repro.core.greedy import replicate_delta
from repro.core.paths import PathSet
from repro.core.reshard import ReshardingMap
from repro.distsys.cluster import Cluster
from repro.engine import LatencyEngine


@dataclasses.dataclass
class ControllerConfig:
    t: int                                  # latency bound (traversals)
    window: int = 1024                      # queries kept in the window
    violation_frac: float = 0.01            # windowed infeasible-query frac
    p99_slo_us: float | None = None         # optional wall-clock p99 SLO
    capacity: np.ndarray | float | None = None
    epsilon: float | None = None
    min_queries: int = 64                   # don't trigger on tiny windows


@dataclasses.dataclass
class AdaptationReport:
    """What one repair did (the benchmark's bytes-replicated accounting)."""

    step: int
    trigger: str                   # "feasibility" | "p99_slo"
    paths_repaired: int
    replicas_added: int
    bytes_added: float
    replicas_evicted: int
    bytes_evicted: float
    feasible_after: bool
    runtime_s: float
    additions: tuple[np.ndarray, np.ndarray] = dataclasses.field(
        default=(np.zeros(0, np.int64), np.zeros(0, np.int64)), repr=False
    )


def evict_cold_replicas(
    cluster: Cluster,
    rmap: ReshardingMap,
    active_objects: np.ndarray,
    f: np.ndarray | None = None,
    capacity: np.ndarray | float | None = None,
) -> tuple[int, float]:
    """Drop cold, RM-unreferenced replicas until every server fits.

    Cost-aware in the §5.4 sense: only replicas with ``RC(v, s) == 0`` are
    candidates — the resharding map holds no association that would have to
    be re-transferred after an original-copy move — and originals and
    window-active objects are never touched.  Within a server, largest
    ``f(v)`` goes first (frees the most bytes per eviction).
    """
    scheme = cluster.scheme
    if capacity is None:
        return 0, 0.0
    fv = (
        np.ones(scheme.n_objects, np.float64)
        if f is None
        else np.asarray(f, np.float64)
    )
    cap = np.broadcast_to(
        np.asarray(capacity, np.float64), (scheme.n_servers,)
    )
    load = scheme.storage_per_server(fv)
    active = np.zeros(scheme.n_objects, bool)
    active[np.asarray(active_objects, np.int64)] = True
    n_evicted = 0
    bytes_evicted = 0.0
    for s in np.argsort(-(load - cap)):
        if load[s] <= cap[s]:
            continue
        cands = np.nonzero(
            scheme.mask[:, s] & (scheme.shard != s) & ~active
        )[0]
        cands = [
            int(v) for v in cands if rmap.rc.get((int(v), int(s)), 0) == 0
        ]
        cands.sort(key=lambda v: -fv[v])
        for v in cands:
            if load[s] <= cap[s]:
                break
            scheme.mask[v, s] = False
            load[s] -= fv[v]
            n_evicted += 1
            bytes_evicted += float(fv[v])
    return n_evicted, bytes_evicted


class AdaptiveController:
    """Sliding-window monitor + incremental repair over a live cluster.

    The controller shares the cluster's ``ReplicationScheme`` object with
    its ``LatencyEngine``, so the engine's device-resident packed words,
    the host mask, and the cluster's routing state stay one source of
    truth: warm-start additions scatter-OR into the packed words and flip
    the same host mask the router reads.
    """

    def __init__(
        self,
        cluster: Cluster,
        config: ControllerConfig,
        f: np.ndarray | None = None,
        engine: LatencyEngine | None = None,
        rmap: ReshardingMap | None = None,
    ):
        self.cluster = cluster
        self.config = config
        self.f = None if f is None else np.asarray(f, np.float32)
        self.engine = engine or LatencyEngine(cluster.scheme)
        assert self.engine.scheme is cluster.scheme, (
            "controller engine must wrap the cluster's live scheme"
        )
        self.rmap = rmap or ReshardingMap({}, {})
        # window: deque of (pathset, path_lats, n_queries, latency_us|None,
        # n_queries_over_t) — the violation count is cached per entry so the
        # per-batch monitoring path stays O(batch), not O(window)
        self._window: deque = deque()
        self._window_queries = 0
        self.step = 0
        self.reports: list[AdaptationReport] = []

    # -- monitoring --------------------------------------------------------
    def _count_bad(self, ps: PathSet, pl: np.ndarray, nq: int) -> int:
        """Queries of one batch whose slowest path exceeds t."""
        ql = np.zeros(nq, np.int32)
        np.maximum.at(ql, np.asarray(ps.query_ids), pl)
        return int((ql > self.config.t).sum())

    def _window_stats(self, want_p99: bool = True) -> tuple[float, float | None]:
        bad = 0
        total = 0
        lats: list[np.ndarray] = []
        for _, _, nq, lat_us, n_bad in self._window:
            bad += n_bad
            total += nq
            if want_p99 and lat_us is not None:
                lats.append(lat_us)
        frac = bad / total if total else 0.0
        p99 = (
            float(np.percentile(np.concatenate(lats), 99.0)) if lats else None
        )
        return frac, p99

    def window_feasible_frac(self) -> float:
        """1 - fraction of windowed queries exceeding t (diagnostics)."""
        frac, _ = self._window_stats()
        return 1.0 - frac

    def observe(
        self,
        pathset: PathSet,
        latency_us: np.ndarray | None = None,
    ) -> AdaptationReport | None:
        """Feed one served batch; repair and return a report on violation.

        ``pathset`` is the batch's observed access paths (what the serving
        layer routed); ``latency_us`` the simulator's per-query sojourn
        times for the optional wall-clock SLO trigger.
        """
        self.step += 1
        pl = self.engine.path_latencies(pathset)
        nq = pathset.n_queries
        self._window.append(
            (pathset, pl, nq, latency_us, self._count_bad(pathset, pl, nq))
        )
        self._window_queries += nq
        while (
            self._window_queries > self.config.window
            and len(self._window) > 1
        ):
            self._window_queries -= self._window.popleft()[2]

        if self._window_queries < self.config.min_queries:
            return None
        # the percentile over the windowed latencies is the only O(window)
        # part of monitoring — skip it unless a wall-clock SLO is configured
        frac, p99 = self._window_stats(
            want_p99=self.config.p99_slo_us is not None
        )
        trigger = None
        if frac > self.config.violation_frac:
            trigger = "feasibility"
        elif (
            self.config.p99_slo_us is not None
            and p99 is not None
            and p99 > self.config.p99_slo_us
        ):
            trigger = "p99_slo"
        if trigger is None:
            return None
        return self._adapt(trigger)

    # -- repair ------------------------------------------------------------
    def _violating_paths(self) -> PathSet:
        parts = []
        for ps, pl, _, _, _ in self._window:
            idx = np.nonzero(pl > self.config.t)[0]
            if len(idx):
                parts.append(ps.select(idx))
        if not parts:
            return PathSet.from_lists([])
        return PathSet.concatenate(parts)

    def _active_objects(self) -> np.ndarray:
        objs = [
            np.asarray(ps.objects).ravel() for ps, _, _, _, _ in self._window
        ]
        cat = np.concatenate(objs) if objs else np.zeros(0, np.int64)
        return np.unique(cat[cat >= 0])

    def _adapt(self, trigger: str) -> AdaptationReport:
        t0 = time.perf_counter()
        bad = self._violating_paths()
        stats, (add_obj, add_srv) = replicate_delta(
            bad,
            self.engine,
            self.config.t,
            f=self.f,
            capacity=self.config.capacity,
            epsilon=self.config.epsilon,
            track_rm=True,
        )
        # the engine already flipped the shared host mask; this records the
        # delta through the cluster's own hook (idempotent monotone flips)
        self.cluster.apply_scheme_delta(add_obj, add_srv)
        for u, v, s in stats.rm or ():
            self.rmap.rm.setdefault(int(u), set()).add(int(v))
            self.rmap.rc[(int(v), int(s))] = (
                self.rmap.rc.get((int(v), int(s)), 0) + 1
            )

        n_ev, bytes_ev = evict_cold_replicas(
            self.cluster, self.rmap, self._active_objects(), self.f,
            self.config.capacity,
        )
        if n_ev:
            self.engine.refresh()  # removals are not monotone: re-pack

        fv = (
            np.ones(len(add_obj))
            if self.f is None
            else self.f[add_obj]
        )
        # re-evaluate the window against the repaired scheme: the stored
        # per-path latencies are stale and would re-trigger forever, and the
        # wall-clock latencies were measured against the pre-repair scheme —
        # keeping them would make a queueing-only p99 breach re-fire no-op
        # repairs until the batch ages out, so they are dropped too (the
        # p99 trigger re-arms on fresh measurements).
        feasible = True
        fresh: deque = deque()
        for ps, _, nq, _, _ in self._window:
            pl = self.engine.path_latencies(ps)
            n_bad = self._count_bad(ps, pl, nq)
            fresh.append((ps, pl, nq, None, n_bad))
            if n_bad:
                feasible = False
        self._window = fresh
        report = AdaptationReport(
            step=self.step,
            trigger=trigger,
            paths_repaired=bad.n_paths,
            replicas_added=int(len(add_obj)),
            bytes_added=float(np.sum(fv)) if len(add_obj) else 0.0,
            replicas_evicted=n_ev,
            bytes_evicted=bytes_ev,
            feasible_after=feasible,
            runtime_s=time.perf_counter() - t0,
            additions=(add_obj, add_srv),
        )
        self.reports.append(report)
        return report
