"""Online serving layer: simulator, drifting workloads, adaptive control.

The first time-dimensioned layer of the system (ROADMAP: "serves heavy
traffic from millions of users").  Three pieces:

  simulator   — discrete-event serving simulator: open-loop Poisson/trace
                arrivals, per-server FIFO queues, queries as routed hop
                sequences from the engine's access trace; p50/p99/p999,
                per-server utilization, throughput-vs-offered-load
  drift       — time-phased query mixes + rotating root hotspots over the
                SNB/GNN/recsys workloads, emitting PathSet deltas
  controller  — per-tenant sliding-window monitor + incremental repair:
                each query judged against its own t_Q (``SLOSpec``),
                warm-started vector-budget greedy (``replicate_delta``)
                against the resident PackedScheme, capacity-headroom
                arbitration between competing tenant repairs
                (cheapest-marginal-byte-per-violation wins, loser
                deferred), scheme deltas applied to the live Cluster,
                RM-aware cold-replica eviction with demotion hysteresis
"""
from repro.serve.batching import (
    AdmissionConfig,
    BatchLadder,
    BatchStats,
    BatchingConfig,
    HedgePolicy,
    derive_deadlines,
)
from repro.serve.simulator import SimReport, simulate
from repro.serve.harness import harness_simulate
from repro.serve.drift import (
    DriftPhase,
    PhaseDelta,
    drift_stream,
    gnn_drift,
    hotspot_phases,
    path_delta,
    recsys_drift,
    snb_drift,
)
from repro.serve.controller import (
    AdaptationReport,
    AdaptiveController,
    ControllerConfig,
    evict_cold_replicas,
)

__all__ = [
    "AdmissionConfig",
    "BatchLadder",
    "BatchStats",
    "BatchingConfig",
    "HedgePolicy",
    "derive_deadlines",
    "SimReport",
    "simulate",
    "harness_simulate",
    "DriftPhase",
    "PhaseDelta",
    "drift_stream",
    "path_delta",
    "hotspot_phases",
    "snb_drift",
    "gnn_drift",
    "recsys_drift",
    "AdaptationReport",
    "AdaptiveController",
    "ControllerConfig",
    "evict_cold_replicas",
]
