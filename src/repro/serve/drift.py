"""Drifting workload generator: time-phased query mixes + hotspot shifts.

A static replication scheme is tuned for the workload it was built from;
the paper's feasibility guarantee says nothing once the query mix or the
root hotspot moves.  This module generates exactly that stress: a sequence
of *phases*, each a (PathSet, offered-rate, duration) triple, where the
hot region of the root distribution rotates between phases and the query
mix re-weights — and reports, per transition, the **PathSet delta** (paths
that appeared / disappeared), which is the unit the adaptive controller's
incremental greedy consumes.

Works over all three workload families (the same analyzers the greedy
driver uses):

  ``snb_drift``     — SNB short reads with a rotating hot person/post set
                      and a per-phase template-mix rotation
  ``gnn_drift``     — GNN sampling with a rotating hot seed-node region
  ``recsys_drift``  — embedding lookups with a rotating hot item block
                      (the GeoLayer-style "popular partition moved" case)

All generators are deterministic in ``seed``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterator

import numpy as np

from repro.core.paths import PathSet
from repro.workload.analyzer import batched, materialize


@dataclasses.dataclass(frozen=True)
class DriftPhase:
    """One phase of a drifting workload."""

    name: str
    pathset: PathSet
    rate_qps: float
    duration_s: float
    hot_roots: np.ndarray  # the phase's hot root set (diagnostics)


@dataclasses.dataclass(frozen=True)
class PhaseDelta:
    """A phase plus its path-level diff against the previous phase."""

    phase: int
    name: str
    pathset: PathSet        # the full phase workload
    added: PathSet          # paths present now but not in the previous phase
    n_removed: int          # paths of the previous phase that disappeared
    rate_qps: float
    duration_s: float


def _path_keys(ps: PathSet) -> np.ndarray:
    """Row key per path: (length, objects...) — padding is canonical (-1)."""
    return np.concatenate(
        [ps.lengths[:, None].astype(np.int64), ps.objects.astype(np.int64)],
        axis=1,
    )


def path_delta(prev: PathSet | None, cur: PathSet) -> tuple[PathSet, int]:
    """(added paths of ``cur``, count of ``prev`` paths that vanished).

    Paths are compared structurally (object sequence), not by query id —
    a re-arrival of an identical path is not workload drift.
    """
    if prev is None or prev.n_paths == 0:
        return cur, 0
    if cur.n_paths == 0:
        return cur, prev.n_paths
    L = max(prev.max_len, cur.max_len)
    pk = _path_keys(prev.pad_to(max_len=L))
    ck = _path_keys(cur.pad_to(max_len=L))
    prev_set = {row.tobytes() for row in pk}
    cur_rows = [row.tobytes() for row in ck]
    new_idx = np.asarray(
        [i for i, r in enumerate(cur_rows) if r not in prev_set], np.int64
    )
    n_removed = len(prev_set - set(cur_rows))
    added = cur.select(new_idx) if len(new_idx) else PathSet.from_lists([])
    return added, n_removed


def drift_stream(phases: list[DriftPhase]) -> Iterator[PhaseDelta]:
    """Yield each phase with its path delta against the previous one."""
    prev: PathSet | None = None
    for i, ph in enumerate(phases):
        added, n_removed = path_delta(prev, ph.pathset)
        yield PhaseDelta(
            phase=i,
            name=ph.name,
            pathset=ph.pathset,
            added=added,
            n_removed=n_removed,
            rate_qps=ph.rate_qps,
            duration_s=ph.duration_s,
        )
        prev = ph.pathset


def hotspot_phases(
    paths_fn_for_phase: Callable[[int, np.random.Generator], Callable[[int], list[list[int]]]],
    root_pool: np.ndarray,
    n_phases: int = 3,
    queries_per_phase: int = 500,
    hot_frac: float = 0.1,
    hot_prob: float = 0.8,
    rate_qps: float = 1e4,
    duration_s: float = 1.0,
    seed: int = 0,
    name: str = "phase",
) -> list[DriftPhase]:
    """Generic rotating-hotspot phase builder.

    The root pool is permuted once; phase ``k`` declares the ``k``-th
    contiguous slice (``hot_frac`` of the pool) *hot* and samples each
    query's root from it with probability ``hot_prob`` (uniform over the
    rest otherwise).  ``paths_fn_for_phase(k, rng)`` returns the
    root -> paths expander for phase ``k``, letting the query mix shift
    alongside the hotspot.
    """
    rng = np.random.default_rng(seed)
    pool = rng.permutation(np.asarray(root_pool))
    n_hot = max(1, int(len(pool) * hot_frac))
    phases: list[DriftPhase] = []
    for k in range(n_phases):
        prng = np.random.default_rng(seed * 7919 + k)
        lo = (k * n_hot) % len(pool)
        hot = np.take(pool, np.arange(lo, lo + n_hot), mode="wrap")
        pick_hot = prng.random(queries_per_phase) < hot_prob
        roots = np.where(
            pick_hot,
            prng.choice(hot, size=queries_per_phase),
            prng.choice(pool, size=queries_per_phase),
        )
        ps = materialize(
            batched(paths_fn_for_phase(k, prng), roots, queries_per_phase)
        )
        phases.append(
            DriftPhase(
                name=f"{name}{k}",
                pathset=ps,
                rate_qps=rate_qps,
                duration_s=duration_s,
                hot_roots=hot,
            )
        )
    return phases


# ---------------------------------------------------------------------------
# Family-specific drifts
# ---------------------------------------------------------------------------
def snb_drift(
    snb,
    n_phases: int = 3,
    queries_per_phase: int = 500,
    hot_frac: float = 0.1,
    hot_prob: float = 0.8,
    rate_qps: float = 1e4,
    duration_s: float = 1.0,
    seed: int = 0,
) -> list[DriftPhase]:
    """SNB short reads: rotating hot person set + rotating template mix."""
    from repro.workload.snb import DEFAULT_MIX, snb_query_paths

    templates = sorted(DEFAULT_MIX)

    def for_phase(k: int, rng: np.random.Generator):
        # rotate the mix so each phase emphasizes a different template
        weights = np.asarray(
            [DEFAULT_MIX[t] for t in templates], np.float64
        )
        weights = np.roll(weights, k)
        weights /= weights.sum()

        def paths_fn(root: int) -> list[list[int]]:
            tmpl = templates[int(rng.choice(len(templates), p=weights))]
            if tmpl in ("IS2", "IS3"):
                # person-rooted templates need a person root; remap
                root = int(snb.persons[root % len(snb.persons)])
            else:
                root = int(snb.posts[root % len(snb.posts)])
            return snb_query_paths(snb, root, tmpl, rng)

        return paths_fn

    pool = np.arange(len(snb.persons) + len(snb.posts))
    return hotspot_phases(
        for_phase, pool, n_phases, queries_per_phase, hot_frac, hot_prob,
        rate_qps, duration_s, seed, name="snb",
    )


def gnn_drift(
    g,
    n_phases: int = 3,
    queries_per_phase: int = 300,
    fanouts: tuple[int, ...] = (5, 3),
    hot_frac: float = 0.05,
    hot_prob: float = 0.8,
    rate_qps: float = 1e4,
    duration_s: float = 1.0,
    seed: int = 0,
) -> list[DriftPhase]:
    """GNN sampling with a rotating hot seed-node region."""
    from repro.workload.gnn import gnn_query_paths

    def for_phase(k: int, rng: np.random.Generator):
        def paths_fn(root: int) -> list[list[int]]:
            return gnn_query_paths(g, int(root), fanouts, rng)

        return paths_fn

    return hotspot_phases(
        for_phase, np.arange(g.n_nodes), n_phases, queries_per_phase,
        hot_frac, hot_prob, rate_qps, duration_s, seed, name="gnn",
    )


def recsys_drift(
    n_users: int,
    n_items: int,
    n_phases: int = 3,
    queries_per_phase: int = 400,
    behaviors_per_req: int = 6,
    candidates_per_req: int = 4,
    hot_frac: float = 0.05,
    hot_prob: float = 0.8,
    rate_qps: float = 1e4,
    duration_s: float = 1.0,
    seed: int = 0,
) -> list[DriftPhase]:
    """Embedding lookups with a rotating hot item block.

    Object-id layout matches ``repro.workload.recsys``: rows
    ``[0, n_users)`` are users, ``[n_users, n_users + n_items)`` items.
    Each request is user -> behavior items -> candidate items; behavior and
    candidate items are drawn from the phase's hot item block with
    ``hot_prob``.
    """

    rng0 = np.random.default_rng(seed)
    item_perm = rng0.permutation(n_items)
    n_hot = max(1, int(n_items * hot_frac))

    def for_phase(k: int, rng: np.random.Generator):
        hot = np.take(
            item_perm, np.arange(k * n_hot, (k + 1) * n_hot), mode="wrap"
        )

        def draw_items(count):
            pick_hot = rng.random(count) < hot_prob
            uni = rng.integers(0, n_items, count)
            hot_pick = rng.choice(hot, size=count)
            return np.where(pick_hot, hot_pick, uni) + n_users

        def paths_fn(root: int) -> list[list[int]]:
            user = int(root) % n_users
            behaviors = draw_items(behaviors_per_req)
            cands = draw_items(candidates_per_req)
            return [
                [user, int(b), int(c)] for b in behaviors for c in cands[:1]
            ] + [[user, int(c)] for c in cands]

        return paths_fn

    return hotspot_phases(
        for_phase, np.arange(n_users), n_phases, queries_per_phase,
        hot_frac, hot_prob, rate_qps, duration_s, seed, name="recsys",
    )
