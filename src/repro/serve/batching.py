"""Batched-dispatch serving policies: ladders, admission, SLO hedging.

The simulator (and the asyncio harness behind the same interface) prices
every access dispatch individually; a real serving plane does not.  This
module holds the three policy objects the batched dispatch plane is
configured with — all plain data, consumed by ``simulate()`` /
``harness_simulate()``:

* :class:`BatchLadder` + :class:`BatchingConfig` — queries targeting the
  same server within a collection window coalesce into **one** engine
  dispatch.  The ladder quantizes the batch size to a fixed rung (default
  1/2/4/8/16, the shapes a jit cache can hold) picked from the
  instantaneous pending depth, so dispatch overhead (``dispatch_us``) is
  paid once per batch instead of once per access and the device sees a
  bounded set of batch shapes;
* :class:`AdmissionConfig` — deadline-aware admission/shedding.  At
  enqueue time the remaining slack is the query's wall-clock deadline
  (derived from its ``SLOSpec`` budget t_Q) minus the elapsed queue wait;
  a query whose *floor* latency under the active routing policy (the
  jitter-free critical path of its precomputed access tree) can no longer
  meet the deadline is shed — fail fast instead of poisoning the FIFO for
  the queries behind it;
* :class:`HedgePolicy` — SLO-driven request hedging.  Instead of racing
  primary+backup unconditionally at arrival (the simulator's ``hedged``
  router mode), the policy fires the backup dispatch only when the
  query's elapsed time crosses a per-tenant latency quantile *learned
  online* from completions (a ``repro.obs`` log-bucketed
  :class:`~repro.obs.metrics.Histogram` per tenant), with
  cancellation-on-first-completion accounting — the tail-latency
  playbook's "defer hedging to the p95 mark" at ~5% extra load.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.obs.metrics import Histogram

__all__ = [
    "AdmissionConfig",
    "BatchLadder",
    "BatchStats",
    "BatchingConfig",
    "HedgePolicy",
    "derive_deadlines",
]


@dataclasses.dataclass(frozen=True)
class BatchLadder:
    """Quantized batch sizes: the rung picked from instantaneous depth.

    ``pick(depth)`` returns the largest rung <= ``max(depth, 1)`` — a
    lone straggler ships as a batch of 1 (never waits for peers that are
    not coming), a deep backlog ships at the top rung.  Rungs must be
    positive, strictly increasing, and start at 1 so every depth has a
    feasible rung.
    """

    rungs: tuple[int, ...] = (1, 2, 4, 8, 16)

    def __post_init__(self):
        if not self.rungs or self.rungs[0] != 1:
            raise ValueError("ladder must start at rung 1 (stragglers)")
        if any(b <= a for a, b in zip(self.rungs, self.rungs[1:])):
            raise ValueError("ladder rungs must be strictly increasing")

    @property
    def max_rung(self) -> int:
        return self.rungs[-1]

    def pick(self, depth: int) -> int:
        """Largest rung not exceeding the pending depth (min rung 1)."""
        depth = max(int(depth), 1)
        best = self.rungs[0]
        for r in self.rungs:
            if r > depth:
                break
            best = r
        return best


@dataclasses.dataclass(frozen=True)
class BatchingConfig:
    """Per-server batch collection: window + size ladder.

    ``window_us`` is how long the first pending access of a server waits
    for peers before the batch flushes (one dispatch).  A flush takes the
    ladder rung for the pending depth; leftovers flush immediately after
    (same timestamp, next rung) so a deep backlog drains in ladder-sized
    chunks rather than re-arming the window.
    """

    window_us: float = 50.0
    ladder: BatchLadder = dataclasses.field(default_factory=BatchLadder)

    def __post_init__(self):
        if self.window_us < 0:
            raise ValueError("window_us must be >= 0")


@dataclasses.dataclass
class BatchStats:
    """Occupancy accounting of one batched run (SimReport.batch_stats)."""

    n_batches: int = 0
    batched_jobs: int = 0     # accesses served through a batch dispatch
    max_occupancy: int = 0

    def observe(self, occupancy: int) -> None:
        self.n_batches += 1
        self.batched_jobs += occupancy
        if occupancy > self.max_occupancy:
            self.max_occupancy = occupancy

    @property
    def mean_occupancy(self) -> float:
        return self.batched_jobs / self.n_batches if self.n_batches else 0.0

    def summary(self) -> dict:
        return {
            "n_batches": self.n_batches,
            "batched_jobs": self.batched_jobs,
            "mean_occupancy": self.mean_occupancy,
            "max_occupancy": self.max_occupancy,
        }


def derive_deadlines(slo, model, pathset) -> np.ndarray:
    """Wall-clock deadline per query from its SLOSpec traversal budget.

    Def 4.4's budget t_Q counts *distributed traversals*; its wall-clock
    reading under the latency model is the cost of the longest path walked
    with exactly t_Q remote hops and the rest local:

        deadline_q = coordinator_us + local_us * max_path_len_q
                     + remote_us * t_q

    A query whose scheme keeps it within budget has a jitter-free floor
    at or below this number, so at zero load nothing is shed; a
    zero-budget query (t_q = 0) must complete fully local to be admitted.
    """
    nq = pathset.n_queries
    maxlen = np.zeros(nq, np.int64)
    np.maximum.at(
        maxlen, np.asarray(pathset.query_ids), np.asarray(pathset.lengths)
    )
    t_q = np.asarray(slo.t_q, np.float64)
    return (
        model.coordinator_us
        + model.local_us * maxlen.astype(np.float64)
        + model.remote_us * t_q
    )


@dataclasses.dataclass(frozen=True)
class AdmissionConfig:
    """Deadline-aware admission control (fail-fast shedding).

    ``deadline_us`` — explicit wall-clock deadline(s): a scalar applied
    to every query, or a per-query array.  ``None`` derives deadlines
    from the run's ``SLOSpec`` via :func:`derive_deadlines` (requires
    ``slo=``).  ``stretch`` scales the derived/explicit deadlines
    (stretch 2.0 = "shed only when twice the budget is gone") — the knob
    that trades shed fraction against surviving-query tail.

    Shedding points: (a) at arrival, when the access tree's jitter-free
    floor already exceeds the deadline (a zero-budget query with any
    remote hop sheds here); (b) at every hop dispatch and FIFO pop, when
    elapsed sojourn + the remaining subtree floor + the coordinator
    barrier can no longer meet it.  A shed query completes degraded at
    the shed instant, dispatches nothing further, and its already-queued
    work is skipped when popped — the point of shedding is that doomed
    work stops consuming capacity.
    """

    deadline_us: float | np.ndarray | None = None
    stretch: float = 1.0

    def __post_init__(self):
        if self.stretch <= 0:
            raise ValueError("stretch must be > 0")

    def deadlines(self, slo, model, pathset) -> np.ndarray:
        """Resolved per-query wall-clock deadlines [n_queries]."""
        nq = pathset.n_queries
        if self.deadline_us is not None:
            d = np.asarray(self.deadline_us, np.float64)
            d = np.full(nq, float(d), np.float64) if d.ndim == 0 else d
            if d.shape != (nq,):
                raise ValueError(
                    f"deadline_us shape {d.shape} != ({nq},)"
                )
        else:
            if slo is None:
                raise ValueError(
                    "AdmissionConfig without explicit deadline_us needs "
                    "slo= to derive deadlines from t_Q budgets"
                )
            d = derive_deadlines(slo, model, pathset)
        return d * self.stretch


class HedgePolicy:
    """Fire a backup dispatch when elapsed time crosses a learned quantile.

    Per tenant, completions feed a log-bucketed streaming histogram; once
    ``min_samples`` completions are in, ``threshold_us(tenant)`` returns
    the ``quantile``-th percentile and arrivals schedule a hedge timer at
    ``arrival + threshold``.  A query that completes before its timer
    never hedges (that is the point: only the tail pays the hedge), and a
    fired hedge is cancelled the instant either attempt completes.

    The learned thresholds adapt within a run: early completions warm the
    histograms, so a load shift moves the hedge point without restarts.
    ``max_hedges_frac`` caps the fraction of queries allowed to hedge
    (capacity guard: hedging at p95 costs ~5% extra load by construction,
    but a threshold learned on a calm phase can over-fire on a hot one).
    """

    def __init__(
        self,
        quantile: float = 95.0,
        min_samples: int = 64,
        max_hedges_frac: float = 0.25,
        growth: float = 1.05,
    ):
        if not 0.0 < quantile < 100.0:
            raise ValueError("quantile must be in (0, 100)")
        if min_samples < 1:
            raise ValueError("min_samples must be >= 1")
        self.quantile = float(quantile)
        self.min_samples = int(min_samples)
        self.max_hedges_frac = float(max_hedges_frac)
        self._growth = float(growth)
        self._hists: dict[int, Histogram] = {}

    def _hist(self, tenant: int) -> Histogram:
        h = self._hists.get(tenant)
        if h is None:
            h = Histogram(
                f"hedge.tenant{tenant}.latency_us", lo=1.0,
                growth=self._growth,
            )
            self._hists[tenant] = h
        return h

    def observe(self, tenant: int, latency_us: float) -> None:
        """Feed one completion into the tenant's latency distribution."""
        self._hist(tenant).record(float(latency_us))

    def threshold_us(self, tenant: int) -> float | None:
        """Hedge-fire delay for the tenant; None while under-sampled."""
        h = self._hists.get(tenant)
        if h is None or h.n < self.min_samples:
            return None
        return h.percentile(self.quantile)

    def snapshot(self) -> dict:
        """Per-tenant learned thresholds (None = still warming up)."""
        return {
            t: self.threshold_us(t) for t in sorted(self._hists)
        }
