"""k-resilient feasibility: latency bounds that survive server loss.

The paper's sufficient conditions guarantee ``h(p, r, rho) <= t_q`` only
while every replica is reachable — a path served through exactly one copy
is one crash away from violating its budget.  This module defines the
*loss cases* a k-resilient scheme must survive and the deterministic
failover sharding the resilient walk is evaluated under:

* a **loss case** is the union of ``k`` fault domains (default: one
  domain per server, so k=1 enumerates every single-server loss);
* the resilient latency of a path under a case is the ordinary policy
  walk with the lost servers' holder bits cleared from the packed words
  and every lost home remapped by **rotation failover**: the next alive
  server in fixed cyclic order ``home+1, home+2, ... (mod S)``.

Rotation failover is *scheme-independent* on purpose: the failover home
of an object depends only on the sharding function and the loss case,
never on which replicas currently exist.  That keeps the masked
``home_first`` walk monotone under replica additions (Thm 5.3 applies
per case), so the greedy repair rounds converge; a holder-derived
failover map (the executor's serving-time behavior) would move homes as
repairs add copies and re-open bounds the previous round closed.  The
serving plane routes around failures at least as well as the rotation
walk wherever the rotation target holds a copy — which the repair
guarantees for every access the masked walk needed.

This module sits in the engine layer (numpy only, no ``repro.core``
imports) so both the backends and the greedy drivers can share it.
"""
from __future__ import annotations

import dataclasses
import itertools

import numpy as np


@dataclasses.dataclass(frozen=True)
class KResilient:
    """Resilience constraint: feasible under any loss of ``k`` domains.

    ``domains`` partitions (or just covers) the servers into fault
    domains — racks, zones — each a tuple of server ids; ``None`` means
    one singleton domain per server (classic k-server resilience).
    Frozen and hashable so it can ride through jit-static plumbing like
    the routing policies do.
    """

    k: int = 1
    domains: tuple[tuple[int, ...], ...] | None = None

    def __post_init__(self):
        if self.k < 1:
            raise ValueError(f"KResilient.k must be >= 1, got {self.k}")
        if self.domains is not None:
            norm = tuple(
                tuple(int(s) for s in dom) for dom in self.domains
            )
            if not norm or any(len(d) == 0 for d in norm):
                raise ValueError("domains must be non-empty server groups")
            object.__setattr__(self, "domains", norm)

    def loss_cases(self, n_servers: int) -> list[np.ndarray]:
        """Every set of servers a k-combination of domains can take down.

        Returns a list of sorted int64 arrays, one per case, in
        deterministic (lexicographic) order.  A case that would take down
        *every* server is rejected — no scheme can survive it.
        """
        doms = self.domains
        if doms is None:
            doms = tuple((s,) for s in range(n_servers))
        for dom in doms:
            for s in dom:
                if not (0 <= s < n_servers):
                    raise ValueError(
                        f"fault domain server {s} out of range [0, {n_servers})"
                    )
        cases = []
        for combo in itertools.combinations(doms, self.k):
            lost = np.unique(np.concatenate([np.asarray(d, np.int64) for d in combo]))
            if len(lost) >= n_servers:
                raise ValueError(
                    "a loss case covers every server; no scheme is resilient"
                )
            cases.append(lost)
        return cases


def resolve_resilience(resilience) -> KResilient | None:
    """None | int k | KResilient -> KResilient | None."""
    if resilience is None:
        return None
    if isinstance(resilience, KResilient):
        return resilience
    if isinstance(resilience, (int, np.integer)):
        return KResilient(k=int(resilience))
    raise ValueError(
        f"resilience must be None, an int k, or KResilient, got {resilience!r}"
    )


def failover_shard(
    shard: np.ndarray, lost: np.ndarray, n_servers: int
) -> np.ndarray:
    """Rotation-failover sharding under a loss case (scheme-independent).

    Objects homed on a surviving server keep their home; objects homed on
    a lost server move to the next surviving server in fixed cyclic order
    ``home+1, home+2, ... (mod S)``.  Deterministic and independent of
    the replica mask — see the module docstring for why that matters.
    """
    shard = np.asarray(shard, np.int64)
    dead = np.zeros(n_servers, bool)
    dead[np.asarray(lost, np.int64)] = True
    out = shard.copy()
    need = dead[out]
    for off in range(1, n_servers):
        if not need.any():
            break
        cand = (shard + off) % n_servers
        take = need & ~dead[cand]
        out[take] = cand[take]
        need &= ~take
    return out.astype(np.int32)


def case_word_mask(lost: np.ndarray, n_words: int) -> np.ndarray:
    """uint32 [W] bit-mask of a loss case's servers (for ``words & ~mask``)."""
    out = np.zeros(n_words, np.uint32)
    for s in np.asarray(lost, np.int64):
        out[s // 32] |= np.uint32(1) << np.uint32(s % 32)
    return out
