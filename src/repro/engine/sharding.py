"""Multi-device layout for provisioning-scale greedy (jax.sharding).

The fused UPDATE step is embarrassingly parallel over paths: every path
in a batch prices its candidates against the same packed-words snapshot
and the scatter-OR union of the chosen additions is order-free (Thm 5.3
monotonicity — the same argument that justifies the lock-free batch).
So the layout is the simplest one GSPMD supports:

  * packed scheme words, shard map, f, C(h, t) tables — **replicated**
    (``PartitionSpec()``): every device holds the full snapshot, exactly
    like every thread of the paper's 64-thread UPDATE reads the full
    scheme;
  * batch arrays (objects / lengths / budgets) — **sharded on the path
    axis** (``PartitionSpec("paths")``): each device gates + scores its
    slice of the batch;
  * the per-batch scatter-OR and stat sums are cross-device reductions
    XLA inserts automatically (bitwise-OR of the replicated words'
    per-device updates, psum of the stat vector).

``shard_map`` was considered and rejected: the scatter-OR needs a
bitwise-OR collective over uint32 words, which the manual-collective API
does not provide — under plain ``jit`` + ``NamedSharding`` GSPMD lowers
the same program to an all-gather of each device's chosen additions,
which is tiny (the chosen planes, not the words).

CPU note: the test/CI environment exposes one device;
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` splits the host
into N devices (tests/test_provision_scale.py runs the sharded-equality
check in a subprocess with that flag, and skips in-process when only one
device is visible).
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.engine.streaming import TRANSFER

PATH_AXIS = "paths"


def device_count() -> int:
    return len(jax.devices())


def round_up_rows(n: int, align: int = 128) -> int:
    """Round a batch row count up to ``align`` x the visible device count.

    The incremental dirty-set evaluator (``repro.engine.incremental``)
    pads its compacted dirty blocks with this quantum: the ``align``
    factor bounds how many jit shapes a varying dirty-set size can
    produce (the same 128-row bucketing the prune sweep uses), and the
    device factor keeps the padded block divisible across a path-sharded
    mesh — a dirty batch that lands on 8 devices must carry a row
    multiple of 8 x ``align`` or GSPMD pads it per device anyway, off the
    books.  Always returns at least one full quantum.
    """
    q = max(1, int(align)) * max(1, device_count())
    return max(q, -(-int(n) // q) * q)


def provisioning_mesh(n_devices: int | None = None) -> Mesh:
    """1-D device mesh over the path axis (all visible devices by default)."""
    devs = jax.devices()
    if n_devices is not None:
        devs = devs[:n_devices]
    return Mesh(np.asarray(devs), (PATH_AXIS,))


def path_sharding(mesh: Mesh) -> NamedSharding:
    """Leading axis split across devices (batch rows = paths)."""
    return NamedSharding(mesh, PartitionSpec(PATH_AXIS))


def replicated(mesh: Mesh) -> NamedSharding:
    """Full copy on every device (scheme words, tables, f, shard map)."""
    return NamedSharding(mesh, PartitionSpec())


def replicate(x, mesh: Mesh):
    """Place ``x`` fully replicated on the mesh (no byte accounting: the
    words/tables are already device-resident; this is a device-to-device
    broadcast, not host traffic)."""
    return jax.device_put(x, replicated(mesh))


def batch_put(mesh: Mesh):
    """Counted host->device upload landing path-sharded on the mesh.

    Drop-in for ``streaming.to_device`` in the greedy batch loop — books
    the same TRANSFER bytes (each row goes to exactly one device, so the
    payload crosses the bus once, same as the single-device path).
    """
    sh = path_sharding(mesh)

    def put(x, payload_bytes: int | None = None):
        a = np.asarray(x)
        payload = a.nbytes if payload_bytes is None else int(payload_bytes)
        TRANSFER.h2d_bytes += payload
        TRANSFER.padded_bytes += a.nbytes - payload
        TRANSFER.h2d_calls += 1
        return jax.device_put(a, sh)

    return put
