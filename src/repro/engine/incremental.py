"""Incremental dirty-set latency evaluation: index, cache, compacted re-walks.

Every adaptation-loop consumer — controller window re-checks, greedy
revalidation rounds, prune verdict walks — re-evaluates h(p, r, rho) over
an entire path set even when a scheme delta touched a handful of objects.
But under every shipped routing policy h(p, r, rho) depends only on rho
restricted to the objects *on p*: ``home_first`` reads the replica rows
of the path's own objects, ``nearest_copy``/``queue_aware`` pick holders
of the path's (current and next) objects, and the ``nearest_copy_dp``
suffix scores are functions of the path-suffix objects' holder sets.  So
the exact set of paths whose latency a scheme delta can change is the
union of an object->path inverted index's rows over the changed objects —
everything else is cache-hit.

Three pieces, owned per :class:`~repro.engine.engine.LatencyEngine`:

  :class:`PathIndex`        CSR object->path inverted index of one
                            PathSet, built once (``starts``/``rows``,
                            the same construction the prune sweep used
                            inline; it now shares this class).
  :class:`IncrementalEval`  the persistent per-path latency cache.  One
                            entry per PathSet (weakref-guarded — window
                            eviction frees the entry), holding the index,
                            the path block *pinned on device* (uploaded
                            once, padded to a
                            :func:`~repro.engine.sharding.round_up_rows`
                            quantum), and one cached h-vector per
                            (policy, load-fingerprint) slot.  Scheme
                            mutations (``add_replicas`` /
                            ``remove_replicas`` / ``note_changed``)
                            invalidate by exact dirty set; ``refresh``
                            drops everything (a host-mask rewrite has no
                            delta to reason about).
  the gather-compact step   dirty rows are shipped as one small padded
                            int32 index vector (booked under
                            ``TRANSFER.gathered_bytes``), the ``[D, L]``
                            dirty block is gathered *on device* from the
                            pinned paths (:func:`gather_rows`), walked by
                            the same backend kernel the full evaluation
                            uses (``words_scan`` / ``routed_counts`` /
                            the Pallas routed-walk), and scattered back
                            into the cached vector.

Bit-identity is structural, not approximate: each path's walk is an
independent lane of the batched kernels, so evaluating a gathered subset
runs the exact integer ops of the full evaluation on those lanes — the
property ``tests/test_incremental.py`` pins across all four policies,
all three backends, and add/remove/mixed deltas.

Host/device split: the CSR arrays stay host-side (dirty-set union is
variable-length slicing, a numpy strength), while the indexed path block
— the data the re-walk actually reads — is device-resident; the only
per-re-walk upload is the compacted index vector itself.
"""
from __future__ import annotations

import dataclasses
import weakref

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.engine import backends
from repro.engine.routing import resolve_policy
from repro.engine.sharding import round_up_rows
from repro.engine.streaming import TRANSFER, to_device


class PathIndex:
    """CSR object->path inverted index of a padded path matrix.

    ``rows[starts[v] : starts[v + 1]]`` are the path rows containing
    object ``v`` (with multiplicity when a path visits ``v`` twice).
    Built once per PathSet in O(nnz log nnz); both the prune sweep's
    per-candidate ``affected`` lookups and the cache's dirty-set unions
    read it.
    """

    def __init__(self, objects: np.ndarray, n_objects: int):
        objects = np.asarray(objects)
        self.n_objects = int(n_objects)
        self.n_paths = int(objects.shape[0])
        valid = objects >= 0
        flat_v = objects[valid].astype(np.int64)
        flat_p = np.repeat(
            np.arange(self.n_paths), objects.shape[1]
        )[valid.ravel()]
        order = np.argsort(flat_v, kind="stable")
        self.rows = flat_p[order].astype(np.int32)
        self.starts = np.searchsorted(
            flat_v[order], np.arange(self.n_objects + 1)
        )

    @classmethod
    def from_pathset(cls, pathset, n_objects: int) -> "PathIndex":
        return cls(np.asarray(pathset.objects), n_objects)

    def paths_of(self, v: int) -> np.ndarray:
        """Unique path rows containing object ``v`` (sorted)."""
        return np.unique(self.rows[self.starts[v] : self.starts[v + 1]])

    def dirty_paths(self, changed_objects) -> np.ndarray:
        """Unique path rows touching ANY changed object (sorted int64).

        The exact dirty set of a scheme delta: a path absent from every
        changed object's row slice reads none of the flipped replica
        bits, so its walk — under any shipped policy — is unchanged.
        Object ids outside ``[0, n_objects)`` are ignored (the engines'
        negative-pair masking).
        """
        v = np.unique(np.asarray(changed_objects, np.int64).ravel())
        v = v[(v >= 0) & (v < self.n_objects)]
        if v.size == 0:
            return np.zeros(0, np.int64)
        cnt = self.starts[v + 1] - self.starts[v]
        total = int(cnt.sum())
        if total == 0:
            return np.zeros(0, np.int64)
        # multi-slice gather: absolute position of each slice element
        base = np.repeat(
            self.starts[v] - np.concatenate([[0], np.cumsum(cnt)[:-1]]), cnt
        )
        return np.unique(self.rows[base + np.arange(total)]).astype(np.int64)


@jax.jit
def gather_rows(objects, lengths, idx):
    """Compact the dirty block on device: ``[P, L]`` x ``[Db]`` -> ``[Db, L]``.

    ``idx`` is the padded dirty-row index vector (-1 pad lanes); pad
    lanes come out as empty paths (objects -1, length 0), which every
    backend walk scores as h = 0 and the scatter-back discards.
    """
    ok = idx >= 0
    safe = jnp.maximum(idx, 0)
    o = jnp.where(ok[:, None], objects[safe], -1).astype(jnp.int32)
    ln = jnp.where(ok, lengths[safe], 0).astype(jnp.int32)
    return o, ln


@dataclasses.dataclass
class _Slot:
    """One cached h-vector: a (policy, load-fingerprint) evaluation."""

    h: np.ndarray       # int32 [P] per-path latencies
    dirty: np.ndarray   # bool [P]; True = stale since last evaluation


class _PathSetCache:
    """Index + pinned device block + value slots for one PathSet."""

    def __init__(self, pathset, n_objects: int, block: int, device: bool):
        self.ref = weakref.ref(pathset)
        self.n_paths = pathset.n_paths
        self.index = PathIndex.from_pathset(pathset, n_objects)
        self.slots: dict[tuple, _Slot] = {}
        self.objects_host = np.asarray(pathset.objects, np.int32)
        self.lengths_host = np.asarray(pathset.lengths, np.int32)
        self.dev_objects = None
        self.dev_lengths = None
        if device:
            # pin once, padded to a fixed quantum so repeated full
            # evaluations of differently-sized windows share jit traces
            P, L = self.objects_host.shape
            Pb = round_up_rows(P, block)
            o = np.full((Pb, L), -1, np.int32)
            o[:P] = self.objects_host
            ln = np.zeros(Pb, np.int32)
            ln[:P] = self.lengths_host
            self.dev_objects = to_device(
                o, payload_bytes=self.objects_host.nbytes
            )
            self.dev_lengths = to_device(
                ln, payload_bytes=self.lengths_host.nbytes
            )


class IncrementalEval:
    """The persistent latency cache of one :class:`LatencyEngine`.

    Entries are keyed by PathSet identity (weakref-checked, so a freed
    window entry cannot alias a recycled id) and invalidated by exact
    dirty set on every scheme mutation the engine observes.  Evaluation
    returns a defensive copy of the cached vector.
    """

    def __init__(self, engine):
        self.engine = engine
        self.caches: dict[int, _PathSetCache] = {}

    # -- invalidation ------------------------------------------------------
    def invalidate_objects(self, objects) -> None:
        """Mark paths touching any of ``objects`` dirty in every entry."""
        changed = np.unique(np.asarray(objects, np.int64).ravel())
        changed = changed[changed >= 0]
        if changed.size == 0:
            return
        dead = []
        for key, cache in self.caches.items():
            if cache.ref() is None:
                dead.append(key)
                continue
            rows = cache.index.dirty_paths(changed)
            if len(rows):
                for slot in cache.slots.values():
                    slot.dirty[rows] = True
        for key in dead:
            self.caches.pop(key, None)

    def invalidate_all(self) -> None:
        self.caches.clear()

    # -- evaluation --------------------------------------------------------
    def _n_objects(self) -> int:
        eng = self.engine
        if eng.packed is not None:
            return eng.packed.n_objects
        return eng.scheme.mask.shape[0]

    def _cache_of(self, pathset) -> _PathSetCache:
        key = id(pathset)
        cache = self.caches.get(key)
        if cache is not None and cache.ref() is not pathset:
            # id was recycled by a dead PathSet: this entry is not ours
            self.caches.pop(key)
            cache = None
        if cache is None:
            cache = _PathSetCache(
                pathset,
                self._n_objects(),
                self.engine.block,
                device=self.engine.backend != "reference",
            )
            self.caches[key] = cache
        return cache

    def _slot_key(self, pol, load) -> tuple:
        # queue_aware latencies are a function of the load vector too:
        # a different load profile is a different cached value
        fp = None
        if pol.uses_load and load is not None:
            fp = np.asarray(load, np.float32).tobytes()
        return (pol, fp)

    def _eval_block(self, objects_d, lengths_d, pol, load):
        """Backend dispatch over a device-resident block (same kernels as
        the engine's full evaluation — bit-identity is by construction)."""
        eng = self.engine
        words, shard = eng._device_words()
        if pol.name == "home_first":
            if eng.backend == "pallas":
                return backends.pallas_eval(
                    objects_d, lengths_d, words, shard, block=eng.block
                )
            return backends.words_scan(objects_d, lengths_d, words, shard)
        if eng.backend == "pallas":
            return backends.pallas_routed_eval(
                objects_d, lengths_d, words, shard, pol, load,
                block=eng.block,
            )
        return backends.routed_counts(
            objects_d, lengths_d, words, shard, pol, load
        )

    def _eval_rows_host(self, cache, rows, pol, load) -> np.ndarray:
        """Reference-backend subset re-walk (host oracle, no device)."""
        eng = self.engine
        mask, shard = eng.host_mask(), eng.host_shard()
        o = cache.objects_host[rows]
        ln = cache.lengths_host[rows]
        if pol.name == "home_first":
            return np.asarray(
                backends.reference_eval(o, ln, mask, shard), np.int32
            )
        from repro.core.reference import (  # lazy: no cycle
            routed_path_latencies_reference,
        )

        return np.asarray(
            routed_path_latencies_reference(
                o, ln, mask, shard, policy=pol, load=load
            ),
            np.int32,
        )

    def _full_eval(self, cache, pol, load) -> np.ndarray:
        eng = self.engine
        P = cache.n_paths
        if eng.backend == "reference":
            return self._eval_rows_host(cache, np.arange(P), pol, load)
        out = self._eval_block(
            cache.dev_objects, cache.dev_lengths, pol, load
        )
        return np.asarray(out)[:P].astype(np.int32)

    def _rewalk_rows(self, cache, rows, pol, load) -> np.ndarray:
        """Gather-compacted re-walk of ``rows`` against the live scheme."""
        eng = self.engine
        if eng.backend == "reference":
            return self._eval_rows_host(cache, rows, pol, load)
        D = len(rows)
        Db = round_up_rows(D, eng.block)
        idx = np.full(Db, -1, np.int32)
        idx[:D] = rows
        # the only host->device traffic of the re-walk: the compacted
        # index vector (the [D, L] block is gathered from the pinned
        # device paths) — broken out as TRANSFER.gathered_bytes so the
        # savings vs a full path re-upload stay visible in perf_iterate
        payload = int(np.asarray(rows, np.int32).nbytes) if D else 0
        idx_d = to_device(idx, payload_bytes=payload)
        TRANSFER.gathered_bytes += payload
        o, ln = gather_rows(cache.dev_objects, cache.dev_lengths, idx_d)
        out = self._eval_block(o, ln, pol, load)
        return np.asarray(out)[:D].astype(np.int32)

    def path_latencies(self, pathset, policy=None, load=None) -> np.ndarray:
        pol = resolve_policy(policy)
        if pathset.n_paths == 0:
            return np.zeros((0,), np.int32)
        cache = self._cache_of(pathset)
        key = self._slot_key(pol, load)
        slot = cache.slots.get(key)
        if slot is None:
            h = self._full_eval(cache, pol, load)
            cache.slots[key] = _Slot(
                h=h, dirty=np.zeros(cache.n_paths, bool)
            )
            if obs.enabled():
                obs.REGISTRY.counter("repro.engine.inc_cache_misses").inc()
            return h.copy()
        rows = np.nonzero(slot.dirty)[0]
        if obs.enabled():
            obs.REGISTRY.gauge("repro.engine.inc_dirty_fraction").set(
                len(rows) / cache.n_paths
            )
            if len(rows):
                obs.REGISTRY.counter("repro.engine.inc_dirty_rewalks").inc()
                obs.REGISTRY.counter("repro.engine.inc_dirty_rows").inc(
                    len(rows)
                )
            else:
                obs.REGISTRY.counter("repro.engine.inc_cache_hits").inc()
        if len(rows):
            slot.h[rows] = self._rewalk_rows(cache, rows, pol, load)
            slot.dirty[rows] = False
        return slot.h.copy()
