"""LatencyEngine: one backend-dispatched evaluation core for h(p, r, rho).

The paper's whole algorithm family reduces to evaluating the latency of
many paths against an evolving replication scheme; this class is the single
implementation every consumer (greedy UPDATE driver, exact reference,
baselines, the distsys executor, the workload analyzer, and all
benchmarks) routes through.

  engine = LatencyEngine(scheme, backend="pallas")
  h  = engine.path_latencies(pathset)        # int32 [n_paths]
  lq = engine.query_latencies(pathset, h)    # int32 [n_queries]
  ok = engine.is_feasible(pathset, t, path_lats=h)
  dc = engine.margin_costs(cand_objs, cand_srvs, f)   # vs device snapshot
  engine.add_replicas(objs, srvs)            # on-device scatter-OR

State model: by default (``resident=True``) the scheme lives on device as
a :class:`~repro.engine.packed.PackedScheme` — one packed upload at
construction, incremental scatter-OR updates afterwards, and chunked
evaluation streams only the int32 path chunks (double-buffered, see
``streaming``).  ``resident=False`` reproduces the seed implementation's
transfer profile (bool mask re-uploaded every call) and exists for the
perf benchmarks and regression comparisons.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.engine import backends
from repro.engine.packed import PackedScheme, pack_bool_mask
from repro.engine.routing import resolve_policy
from repro.engine.streaming import stream_chunks, to_device

DEFAULT_CHUNK = 8192


@dataclasses.dataclass
class RawScheme:
    """Lightweight mask + shard scheme (the engine's minimal input contract).

    Anything with ``.mask`` (bool [n, S]) and ``.shard`` (int32 [n]) can
    back a :class:`LatencyEngine`; this is the canonical minimal carrier —
    used by :meth:`LatencyEngine.from_arrays` and anywhere a full
    ``repro.core.ReplicationScheme`` (with its storage accounting) would be
    overkill.  Mutable on purpose: ``add_replicas`` flips its mask bits in
    place like any other scheme.
    """

    mask: np.ndarray
    shard: np.ndarray

    def __post_init__(self):
        self.mask = np.asarray(self.mask, bool)
        self.shard = np.asarray(self.shard, np.int32)
        assert self.mask.ndim == 2
        assert self.shard.shape == (self.mask.shape[0],)


def _budget_vector(t, n_queries: int) -> np.ndarray:
    """int | per-query array | SLOSpec (duck-typed ``.t_q``) -> int32 [nq].

    Duck typing keeps ``repro.engine`` free of ``repro.core`` imports
    (core sits above the engine in the layering).
    """
    t = getattr(t, "t_q", t)
    return np.broadcast_to(
        np.asarray(t, np.int32), (n_queries,)
    )


class DevicePaths:
    """A PathSet pinned to the device (uploaded once, reused per call)."""

    def __init__(self, pathset):
        self.n_paths = pathset.n_paths
        self.n_queries = pathset.n_queries
        self.query_ids = np.asarray(pathset.query_ids)
        self.objects = to_device(np.asarray(pathset.objects, np.int32))
        self.lengths = to_device(np.asarray(pathset.lengths, np.int32))


class LatencyEngine:
    """Backend-dispatched latency evaluation over a replication scheme.

    Args:
      scheme: anything with ``.mask`` (bool [n, S]) and ``.shard``
        (int [n]) — typically ``repro.core.ReplicationScheme`` — or None
        when ``packed`` is given directly.
      backend: "reference" | "jnp" | "pallas".
      chunk: paths per evaluation chunk (streaming granularity).
      block: Pallas path-block (lane) size.
      resident: keep the packed scheme device-resident (default).  When
        False the engine re-uploads the unpacked bool mask on every
        ``path_latencies`` call, mimicking the seed implementation.
    """

    def __init__(
        self,
        scheme=None,
        *,
        packed: PackedScheme | None = None,
        backend: str = "jnp",
        chunk: int = DEFAULT_CHUNK,
        block: int = 128,
        resident: bool = True,
    ):
        if backend not in backends.BACKENDS:
            raise ValueError(f"unknown backend {backend!r}; use {backends.BACKENDS}")
        if scheme is None and packed is None:
            raise ValueError("need a scheme or a PackedScheme")
        self.backend = backend
        self.chunk = int(chunk)
        self.block = int(block)
        self.resident = resident or packed is not None
        self.scheme = scheme
        self.packed: PackedScheme | None = packed
        if self.packed is None and self.resident:
            self.packed = PackedScheme.from_mask(scheme.mask, scheme.shard)
        # lazy incremental dirty-set evaluation plane (engine.incremental)
        self._inc = None

    # -- classmethods -----------------------------------------------------
    @classmethod
    def from_arrays(cls, mask: np.ndarray, shard: np.ndarray, **kw) -> "LatencyEngine":
        return cls(RawScheme(mask, shard), **kw)

    # -- state ------------------------------------------------------------
    @property
    def n_servers(self) -> int:
        if self.packed is not None:
            return self.packed.n_servers
        return self.scheme.mask.shape[1]

    def host_mask(self) -> np.ndarray:
        """Current bool mask on host (readback when device-resident)."""
        if self.packed is not None:
            return self.packed.unpack()
        return np.asarray(self.scheme.mask, bool)

    def host_shard(self) -> np.ndarray:
        if self.packed is not None:
            return np.asarray(self.packed.shard)
        return np.asarray(self.scheme.shard, np.int32)

    @property
    def incremental(self):
        """The engine's :class:`~repro.engine.incremental.IncrementalEval`.

        Created on first use; scheme mutations routed through this engine
        (:meth:`add_replicas` / :meth:`remove_replicas` /
        :meth:`note_changed` / :meth:`refresh`) keep it exact.
        """
        if self._inc is None:
            from repro.engine.incremental import IncrementalEval  # lazy

            self._inc = IncrementalEval(self)
        return self._inc

    def note_changed(self, objects) -> None:
        """Invalidate cached incremental latencies of paths touching
        ``objects``.

        :meth:`add_replicas` / :meth:`remove_replicas` call this
        automatically; callers that mutate ``packed.words`` directly
        (the fused greedy UPDATE jits) must call it themselves with the
        objects they touched — a superset is safe, a miss is not.
        """
        if self._inc is not None:
            self._inc.invalidate_objects(objects)

    def refresh(self, objects=None) -> None:
        """Re-pack after the host scheme's mask was mutated directly.

        ``objects`` — when the caller knows the exact set of objects whose
        replica rows changed (a §5.4 drain's dirty set) — invalidates only
        the cached latencies of paths touching them, keeping the rest of
        the incremental cache warm; without it every cached vector is
        dropped (the safe call for layout changes like scale-out).
        """
        if self.scheme is not None and self.resident:
            self.packed = PackedScheme.from_mask(self.scheme.mask, self.scheme.shard)
        if self._inc is not None:
            if objects is None:
                # no delta to reason about: drop every cached latency vector
                self._inc.invalidate_all()
            else:
                self._inc.invalidate_objects(objects)

    def add_replicas(self, objects, servers) -> None:
        """Monotone additions, applied on device (and to the host scheme).

        Pairs with a negative object or server are ignored, matching the
        packed scatter-OR semantics (negative indices must not wrap).
        """
        obj = np.asarray(objects)
        srv = np.asarray(servers)
        ok = (obj >= 0) & (srv >= 0)
        obj, srv = obj[ok], srv[ok]
        if obj.size == 0:
            return
        if self.packed is not None:
            self.packed.add(obj, srv)
        if self.scheme is not None:
            self.scheme.mask[obj, srv] = True
        self.note_changed(obj)

    def remove_replicas(self, objects, servers) -> None:
        """Drop replicas, applied on device (and to the host scheme).

        The inverse of :meth:`add_replicas` (same negative-pair masking),
        used by the policy prune sweep.  Removals are not monotone: the
        caller owns the feasibility re-check.
        """
        obj = np.asarray(objects)
        srv = np.asarray(servers)
        ok = (obj >= 0) & (srv >= 0)
        obj, srv = obj[ok], srv[ok]
        if obj.size == 0:
            return
        if self.packed is not None:
            self.packed.remove(obj, srv)
        if self.scheme is not None:
            self.scheme.mask[obj, srv] = False
        self.note_changed(obj)

    def prepare(self, pathset) -> DevicePaths:
        """Pin a PathSet on device for repeated evaluation (one upload)."""
        return DevicePaths(pathset)

    def to_scheme(self):
        from repro.core.replication import ReplicationScheme  # lazy: no cycle

        return ReplicationScheme(self.host_mask(), self.host_shard())

    # -- evaluation -------------------------------------------------------
    def path_latencies(
        self,
        pathset,
        chunk: int | None = None,
        policy=None,
        load: np.ndarray | None = None,
        incremental: bool = False,
    ) -> np.ndarray:
        """h(p, r, rho) per path: #distributed traversals (Def 4.2).

        ``policy`` (str | ``RoutingPolicy``; default ``home_first``)
        scores the walk under a hop-routing policy: ``home_first`` is the
        historical Eqn 1 walk (bit-identical to calling without a
        policy); ``nearest_copy``/``queue_aware`` pick remote-hop targets
        from the replica holders (``load`` ranks holders for the
        latter).  All three backends implement every policy.

        ``incremental=True`` routes through the engine's persistent
        per-path latency cache (:attr:`incremental`): the first call for
        a PathSet evaluates fully, later calls re-walk only the paths
        whose latency a scheme delta since then could have changed — the
        exact dirty set of the object->path index.  Bit-identical to
        ``incremental=False`` as long as every scheme mutation is routed
        through the engine (or reported via :meth:`note_changed`).
        """
        pol = resolve_policy(policy)
        if pathset.n_paths == 0:
            return np.zeros((0,), dtype=np.int32)
        if incremental and not isinstance(pathset, DevicePaths):
            return self.incremental.path_latencies(
                pathset, policy=pol, load=load
            )
        if self.backend == "reference":
            if pol.name == "home_first":
                return backends.reference_eval(
                    np.asarray(pathset.objects),
                    np.asarray(pathset.lengths),
                    self.host_mask(),
                    self.host_shard(),
                )
            from repro.core.reference import (  # lazy: no cycle
                routed_path_latencies_reference,
            )

            return routed_path_latencies_reference(
                np.asarray(pathset.objects),
                np.asarray(pathset.lengths),
                self.host_mask(),
                self.host_shard(),
                policy=pol,
                load=load,
            )
        chunk = int(chunk or self.chunk)
        if pol.name == "home_first":
            compute = (
                self._eval_chunk_resident
                if self.resident
                else self._make_nonresident_compute()
            )
        else:
            compute = self._make_policy_compute(pol, load)
        if isinstance(pathset, DevicePaths):
            out = compute(pathset.objects, pathset.lengths)
            return np.asarray(out)[: pathset.n_paths].astype(np.int32)
        n = pathset.n_paths
        outs = stream_chunks(
            [np.asarray(pathset.objects, np.int32), np.asarray(pathset.lengths, np.int32)],
            n,
            chunk,
            compute,
            pad_values=[-1, 0],
            align=self.block,
        )
        host = [np.asarray(o) for o in outs]
        return np.concatenate(host, axis=0)[:n].astype(np.int32)

    def _eval_chunk_resident(self, objects, lengths):
        if self.backend == "pallas":
            return backends.pallas_eval(
                objects, lengths, self.packed.words, self.packed.shard,
                block=self.block,
            )
        return backends.words_scan(
            objects, lengths, self.packed.words, self.packed.shard
        )

    def _make_nonresident_compute(self):
        mask_host = np.asarray(self.scheme.mask, bool)
        shard_host = np.asarray(self.scheme.shard, np.int32)
        if self.backend == "pallas":
            words_host = np.concatenate(
                [pack_bool_mask(mask_host),
                 np.zeros((1, (mask_host.shape[1] + 31) // 32), np.uint32)],
                axis=0,
            )

            def compute(objects, lengths):
                return backends.pallas_eval(
                    objects, lengths, to_device(words_host),
                    to_device(shard_host), block=self.block,
                )

            return compute

        def compute(objects, lengths):
            return backends.bool_scan(
                objects, lengths, to_device(mask_host), to_device(shard_host)
            )

        return compute

    def _device_words(self):
        """(words, shard) on device — packed view of the current scheme.

        Resident engines reuse the live ``PackedScheme``; non-resident
        ones pack the host mask per call (the legacy transfer profile).
        """
        if self.packed is not None:
            return self.packed.words, self.packed.shard
        mask_host = np.asarray(self.scheme.mask, bool)
        words_host = np.concatenate(
            [pack_bool_mask(mask_host),
             np.zeros((1, (mask_host.shape[1] + 31) // 32), np.uint32)],
            axis=0,
        )
        return to_device(words_host), to_device(
            np.asarray(self.scheme.shard, np.int32)
        )

    def _make_policy_compute(self, pol, load):
        """Chunk-compute closure for a non-home-first routing policy."""
        words, shard = self._device_words()
        if self.backend == "pallas":

            def compute(objects, lengths):
                return backends.pallas_routed_eval(
                    objects, lengths, words, shard, pol, load,
                    block=self.block,
                )

            return compute

        def compute(objects, lengths):
            return backends.routed_counts(
                objects, lengths, words, shard, pol, load
            )

        return compute

    def access_trace(
        self,
        pathset,
        start: np.ndarray | None = None,
        policy=None,
        load: np.ndarray | None = None,
    ) -> tuple[np.ndarray, np.ndarray]:
        """Policy-routed access walk against the engine's scheme.

        Remote hops target the object's home under ``home_first`` (the
        historical walk, bit-identical), or the policy's holder pick
        (``nearest_copy``/``queue_aware``; ``load`` = per-server queue
        depths).  ``start`` optionally overrides the per-path start
        server.  Returns host arrays (servers int32 [P, L], local bool
        [P, L]) — the trace the distsys executor and the serving
        simulator decorate with their latency models.
        """
        pol = resolve_policy(policy)
        # a prepare()d DevicePaths reuses its pinned device arrays: the
        # batched serving plane re-traces the same workload under many
        # start/policy variants, and re-uploading objects/lengths each
        # call would tax exactly the dispatch path batching amortizes
        pinned = isinstance(pathset, DevicePaths)
        if self.backend == "reference":
            from repro.core.reference import routed_trace_reference  # lazy

            return routed_trace_reference(
                np.asarray(pathset.objects, np.int32),
                np.asarray(pathset.lengths, np.int32),
                self.host_mask(), self.host_shard(),
                start=start, policy=pol, load=load,
            )
        words, shard = self._device_words()
        obj_d = (
            pathset.objects if pinned
            else to_device(np.asarray(pathset.objects, np.int32))
        )
        len_d = (
            pathset.lengths if pinned
            else to_device(np.asarray(pathset.lengths, np.int32))
        )
        kw = {}
        if start is not None:
            kw["start"] = to_device(np.asarray(start, np.int32))
        if self.backend == "pallas" and pol.name != "home_first":
            servers, local = backends.pallas_routed_trace(
                obj_d, len_d, words, shard,
                pol, load, block=self.block, **kw,
            )
        else:
            servers, local = backends.access_trace(
                obj_d, len_d, words, shard,
                policy=pol, load=load, **kw,
            )
        return np.asarray(servers), np.asarray(local)

    def query_latencies(self, pathset, path_lats: np.ndarray | None = None) -> np.ndarray:
        """l_Q = max over the query's paths (Def 4.3)."""
        if path_lats is None:
            path_lats = self.path_latencies(pathset)
        nq = pathset.n_queries
        out = np.zeros((nq,), dtype=np.int32)
        np.maximum.at(out, np.asarray(pathset.query_ids), path_lats)
        return out

    def query_slack(
        self,
        pathset,
        t,
        path_lats: np.ndarray | None = None,
        policy=None,
        load: np.ndarray | None = None,
        incremental: bool = False,
    ) -> np.ndarray:
        """t_Q - l_Q per query, computed on device (int32 [n_queries]).

        ``t`` is an int (scalar broadcast), a per-query budget vector, or
        an ``SLOSpec``.  The per-query max and the subtraction run on
        device against the budget vector (``backends.query_slack``); only
        the slack vector crosses back.  Negative entries mark violating
        queries — the serve layer's per-tenant triggers consume this.
        ``policy`` scores the walk under a hop-routing policy
        (``nearest_copy`` is the paper-faithful Eqn 1 reading and yields
        slack >= the ``home_first`` default wherever replicas help).
        ``incremental=True`` sources the path latencies from the
        persistent dirty-set cache (see :meth:`path_latencies`).
        """
        if path_lats is None:
            path_lats = self.path_latencies(
                pathset, policy=policy, load=load, incremental=incremental
            )
        nq = pathset.n_queries
        t_q = _budget_vector(t, nq)
        if nq == 0:
            return np.zeros((0,), np.int32)
        out = backends.query_slack(
            to_device(np.asarray(path_lats, np.int32)),
            to_device(np.asarray(pathset.query_ids, np.int32)),
            to_device(t_q),
        )
        return np.asarray(out)

    def is_feasible(
        self,
        pathset,
        t,
        path_lats: np.ndarray | None = None,
        policy=None,
        load: np.ndarray | None = None,
        incremental: bool = False,
    ) -> bool:
        """All queries within their own t_Q (Def 4.4).

        ``t``: int | per-query vector | ``SLOSpec``.  Reuses precomputed
        ``path_lats`` when given.  ``policy="nearest_copy"`` checks
        feasibility under the paper-faithful any-co-located-replica
        routing, a weaker (tighter-scoring) condition than the
        ``home_first`` default.  ``incremental=True`` sources the path
        latencies from the persistent dirty-set cache.
        """
        return bool(
            np.all(
                self.query_slack(
                    pathset, t, path_lats, policy, load,
                    incremental=incremental,
                )
                >= 0
            )
        )

    def resilient_path_latencies(
        self,
        pathset,
        resilience,
        policy=None,
        load: np.ndarray | None = None,
    ) -> np.ndarray:
        """h per (loss case, path) under ``resilience``: int32 [D, P].

        Row d is the policy walk with loss case d's servers down — their
        holder bits cleared from the packed words and every lost home
        remapped by rotation failover (``repro.engine.resilience``).  A
        path is k-resilient iff every row keeps it within budget; the
        max over rows is the resilient latency the greedy gate enforces.
        All three backends implement the masked re-walk (the ``jnp``
        path batches all D cases into one vmapped dispatch).
        """
        from repro.engine.resilience import (
            case_word_mask,
            failover_shard,
            resolve_resilience,
        )

        res = resolve_resilience(resilience)
        if res is None:
            raise ValueError("resilient_path_latencies needs a resilience spec")
        S = self.n_servers
        cases = res.loss_cases(S)
        P = pathset.n_paths
        if P == 0:
            return np.zeros((len(cases), 0), np.int32)
        pol = resolve_policy(policy)
        shard_host = self.host_shard()
        homes = np.stack([failover_shard(shard_host, c, S) for c in cases])
        if self.backend == "reference":
            from repro.core.reference import (  # lazy: no cycle
                path_latencies_reference,
                routed_path_latencies_reference,
            )

            mask = self.host_mask()
            objects = np.asarray(pathset.objects)
            lengths = np.asarray(pathset.lengths)
            rows = []
            for c, fs in zip(cases, homes):
                m = mask.copy()
                m[:, c] = False
                if pol.name == "home_first":
                    rows.append(path_latencies_reference(objects, lengths, m, fs))
                else:
                    rows.append(routed_path_latencies_reference(
                        objects, lengths, m, fs, policy=pol, load=load
                    ))
            return np.stack(rows).astype(np.int32)
        words, _ = self._device_words()
        W = int(words.shape[1])
        case_masks = np.stack([case_word_mask(c, W) for c in cases])
        out = backends.resilient_counts(
            to_device(np.asarray(pathset.objects, np.int32)),
            to_device(np.asarray(pathset.lengths, np.int32)),
            words,
            to_device(case_masks),
            to_device(homes.astype(np.int32)),
            policy=pol,
            load=load,
            backend=self.backend,
            block=self.block,
        )
        return np.asarray(out).astype(np.int32)

    def is_resilient_feasible(
        self,
        pathset,
        t,
        resilience,
        policy=None,
        load: np.ndarray | None = None,
    ) -> bool:
        """Every query within its t_Q under EVERY loss case (Def 4.4 + k).

        The resilient strengthening of :meth:`is_feasible`: the per-query
        latency is maxed over the query's paths *and* over all loss cases
        of ``resilience`` before the budget comparison.
        """
        h = self.resilient_path_latencies(
            pathset, resilience, policy=policy, load=load
        )
        if h.shape[1] == 0:
            return True
        t_q = _budget_vector(t, pathset.n_queries)
        qids = np.asarray(pathset.query_ids)
        worst = h.max(axis=0)  # [P] max over loss cases
        lq = np.zeros(pathset.n_queries, np.int32)
        np.maximum.at(lq, qids, worst)
        return bool(np.all(lq <= t_q))

    def margin_costs(
        self, objects, servers, f: np.ndarray | None = None
    ) -> np.ndarray:
        """Marginal storage cost of candidate additions vs the snapshot.

        ``objects``/``servers`` are int arrays of identical shape
        ``[..., K]``; negative entries are ignored.  Returns float32
        ``[...]`` — the sum of ``f[v]`` over pairs not already replicated.
        """
        packed = self.packed
        if packed is None:
            packed = PackedScheme.from_mask(self.scheme.mask, self.scheme.shard)
        n = packed.n_objects
        fv = np.ones((n,), np.float32) if f is None else np.asarray(f, np.float32)
        out = backends.margin_cost(
            packed.words,
            to_device(fv),
            to_device(np.asarray(objects, np.int32)),
            to_device(np.asarray(servers, np.int32)),
        )
        return np.asarray(out)
