"""Device-resident packed replication state (the engine's source of truth).

The replication scheme is stored on device as uint32 bit-words
``words[v, w]``: bit ``s % 32`` of word ``s // 32`` is set iff object ``v``
has a copy at server ``s``.  All engine backends evaluate the access
function (paper Eqn 1) against these words; monotone 0->1 updates are
applied on-device with donated buffers (``scatter_or_pairs``), so the
unpacked ``[n_objects, n_servers]`` bool mask never crosses the host
boundary after construction.

Layout notes
------------
``words`` carries one *sacrificial* extra row (index ``n_objects``):
vectorized callers route masked-out updates there instead of predicating,
mirroring the padded-row trick the greedy UPDATE kernel uses.  Packing is
little-endian within a word (server ``32w`` is bit 0 of word ``w``), the
same layout ``repro.kernels.path_latency`` consumes.

This module intentionally depends only on numpy/JAX (no ``repro.core``
imports) so it can sit below both the core algorithms and the kernels.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine.streaming import TRANSFER, to_device


def n_words(n_servers: int) -> int:
    """Number of uint32 words needed for ``n_servers`` membership bits."""
    return (n_servers + 31) // 32


def pack_bool_mask(mask: np.ndarray) -> np.ndarray:
    """Host-side pack: bool [R, S] -> uint32 [R, ceil(S/32)]."""
    R, S = mask.shape
    W = n_words(S)
    padded = np.zeros((R, W * 32), dtype=bool)
    padded[:, :S] = mask
    bits = padded.reshape(R, W, 32).astype(np.uint32)
    weights = (np.uint32(1) << np.arange(32, dtype=np.uint32))[None, None, :]
    return (bits * weights).sum(axis=2).astype(np.uint32)


def unpack_words(words: np.ndarray, n_servers: int) -> np.ndarray:
    """Host-side unpack: uint32 [R, W] -> bool [R, n_servers]."""
    R, W = words.shape
    shifts = np.arange(32, dtype=np.uint32)
    bits = (words[:, :, None] >> shifts[None, None, :]) & np.uint32(1)
    return bits.reshape(R, W * 32)[:, :n_servers].astype(bool)


# ---------------------------------------------------------------------------
# Traceable primitives (usable inside other jits, e.g. the greedy UPDATE).
# ---------------------------------------------------------------------------
def test_bits(words: jnp.ndarray, objects: jnp.ndarray, servers: jnp.ndarray):
    """Membership bit-test against the packed words (traceable).

    ``objects`` and ``servers`` broadcast against each other; both must be
    pre-clamped to valid ranges.  Returns bool of the broadcast shape.
    """
    word = words[objects, servers // 32]
    bit = (servers % 32).astype(jnp.uint32)
    return ((word >> bit) & jnp.uint32(1)).astype(jnp.bool_)


def scatter_or_pairs(
    words: jnp.ndarray, objects: jnp.ndarray, servers: jnp.ndarray
) -> jnp.ndarray:
    """Monotone scatter-OR of (object, server) pairs into the packed words.

    Deterministic under duplicate pairs (OR is idempotent): the update is
    bit-sliced into 32 static rounds; within a round every duplicate write
    to a cell carries the identical value.  Pairs with a negative object or
    server — and the sacrificial row itself — are routed to the sacrificial
    last row, so callers can mask by index instead of compacting.
    """
    pad_row = words.shape[0] - 1
    ok = (objects >= 0) & (servers >= 0) & (objects < pad_row)
    obj = jnp.where(ok, objects, pad_row).reshape(-1)
    srv = jnp.where(ok, servers, 0).reshape(-1)
    w_idx = srv // 32
    b_idx = srv % 32
    for b in range(32):
        sel = b_idx == b
        o = jnp.where(sel, obj, pad_row)
        w = jnp.where(sel, w_idx, 0)
        old = words[o, w]
        words = words.at[o, w].set(old | jnp.uint32(1 << b))
    return words


@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter_or_jit(words, objects, servers):
    return scatter_or_pairs(words, objects, servers)


def scatter_clear_pairs(
    words: jnp.ndarray, objects: jnp.ndarray, servers: jnp.ndarray
) -> jnp.ndarray:
    """Clear (object, server) membership bits (the prune-sweep inverse).

    Same masking/bit-slicing discipline as :func:`scatter_or_pairs`:
    negative pairs are routed to the sacrificial row, duplicates are
    idempotent.  Removals are NOT monotone — callers that cached derived
    state (bool masks, engines) must refresh it.
    """
    pad_row = words.shape[0] - 1
    ok = (objects >= 0) & (servers >= 0) & (objects < pad_row)
    obj = jnp.where(ok, objects, pad_row).reshape(-1)
    srv = jnp.where(ok, servers, 0).reshape(-1)
    w_idx = srv // 32
    b_idx = srv % 32
    for b in range(32):
        sel = b_idx == b
        o = jnp.where(sel, obj, pad_row)
        w = jnp.where(sel, w_idx, 0)
        old = words[o, w]
        words = words.at[o, w].set(old & ~jnp.uint32(1 << b))
    return words


@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter_clear_jit(words, objects, servers):
    return scatter_clear_pairs(words, objects, servers)


@jax.jit
def _unpack_load_jit(words, f):
    """f_r(s) per server from packed words, entirely on device."""
    n = f.shape[0]
    W = words.shape[1]
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (words[:n, :, None] >> shifts[None, None, :]) & jnp.uint32(1)
    mask = bits.reshape(n, W * 32).astype(jnp.float32)
    return f @ mask  # [W * 32]; caller slices [:n_servers]


@jax.jit
def _popcount_jit(words):
    n_rows = words.shape[0]
    v = words[: n_rows - 1]
    # SWAR popcount per word, summed.
    v = v - ((v >> 1) & jnp.uint32(0x55555555))
    v = (v & jnp.uint32(0x33333333)) + ((v >> 2) & jnp.uint32(0x33333333))
    v = (v + (v >> 4)) & jnp.uint32(0x0F0F0F0F)
    return jnp.sum((v * jnp.uint32(0x01010101)) >> 24)


@dataclasses.dataclass
class PackedScheme:
    """Incrementally maintained device-resident replication scheme.

    Attributes:
      words: uint32 [n_objects + 1, W] on device (sacrificial last row).
      shard: int32 [n_objects] on device (the sharding function d).
      n_servers: membership bits in use per row.
    """

    words: jax.Array
    shard: jax.Array
    n_servers: int

    @property
    def n_objects(self) -> int:
        return self.words.shape[0] - 1

    @property
    def n_words(self) -> int:
        return self.words.shape[1]

    @classmethod
    def from_mask(cls, mask: np.ndarray, shard: np.ndarray) -> "PackedScheme":
        """One host-side pack + one (32x smaller) transfer."""
        n, S = mask.shape
        host = np.zeros((n + 1, n_words(S)), dtype=np.uint32)
        host[:n] = pack_bool_mask(np.asarray(mask, dtype=bool))
        return cls(
            words=to_device(host),
            shard=to_device(np.asarray(shard, dtype=np.int32)),
            n_servers=S,
        )

    @classmethod
    def from_sharding(cls, shard: np.ndarray, n_servers: int) -> "PackedScheme":
        n = shard.shape[0]
        host = np.zeros((n + 1, n_words(n_servers)), dtype=np.uint32)
        s = np.asarray(shard, dtype=np.int64)
        host[np.arange(n), s // 32] = np.uint32(1) << (s % 32).astype(np.uint32)
        return cls(
            words=to_device(host),
            shard=to_device(np.asarray(shard, dtype=np.int32)),
            n_servers=n_servers,
        )

    def add(self, objects, servers) -> None:
        """On-device monotone scatter-OR (donated buffer; words reassigned)."""
        self.words = _scatter_or_jit(
            self.words,
            to_device(np.asarray(objects, dtype=np.int32)),
            to_device(np.asarray(servers, dtype=np.int32)),
        )

    def remove(self, objects, servers) -> None:
        """On-device membership-bit clear (the prune sweep's inverse).

        NOT monotone: any derived state (unpacked masks, downstream
        engines built from this scheme) is stale after a remove.
        """
        self.words = _scatter_clear_jit(
            self.words,
            to_device(np.asarray(objects, dtype=np.int32)),
            to_device(np.asarray(servers, dtype=np.int32)),
        )

    def unpack(self) -> np.ndarray:
        """Host readback of the full bool mask (one d2h of packed words)."""
        host = np.asarray(self.words[: self.n_objects])
        TRANSFER.d2h_bytes += host.nbytes
        return unpack_words(host, self.n_servers)

    def storage_per_server(self, f: np.ndarray | None = None) -> np.ndarray:
        n = self.n_objects
        fv = np.ones((n,), np.float32) if f is None else np.asarray(f, np.float32)
        load = _unpack_load_jit(self.words, to_device(fv))
        return np.asarray(load)[: self.n_servers].astype(np.float64)

    def replica_count(self) -> int:
        return int(_popcount_jit(self.words)) - self.n_objects
