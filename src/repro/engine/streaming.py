"""Host<->device transfer accounting + double-buffered chunk streaming.

Every host->device transfer the engine performs goes through ``to_device``
so the byte counter (``TRANSFER``) reflects real traffic; the perf
benchmarks (``benchmarks/perf_iterate.py engine`` and
``benchmarks/engine_backends.py``) read it to track the packed-resident
path's transfer advantage over the legacy per-call bool-mask uploads.
``h2d_bytes`` counts *payload* bytes only — alignment padding a caller
appends to hit a fixed jit shape is tracked separately in
``padded_bytes`` (it rides the same copy, but it is not workload data, and
folding it into the payload counter made the final partial chunk look more
expensive than the data it carried).

``stream_chunks`` is the engine's evaluation pipeline: while chunk ``i``
computes on device (JAX dispatch is asynchronous), chunk ``i + 1``'s
host->device copy is already enqueued — a two-deep software pipeline that
replaces the old synchronous per-chunk ``jnp.asarray`` + ``np.asarray``
round trip.  The final chunk is padded to the full chunk shape so every
step hits the same jit cache entry.

``PathStream`` is the provisioning-scale ingestion contract: a host
generator of :class:`~repro.core.paths.PathSet` chunks, consumed once,
with peak-residency accounting — the greedy driver
(``repro.core.greedy.replicate_stream``) provisions against it without
the full path set ever being host-resident.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Callable, Iterable, Iterator, Sequence

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class TransferStats:
    h2d_bytes: int = 0
    h2d_calls: int = 0
    d2h_bytes: int = 0
    # alignment-pad bytes appended by callers to hit a fixed jit shape;
    # they cross the bus but carry no workload data (kept out of
    # h2d_bytes so the perf benchmarks' byte assertions stay exact)
    padded_bytes: int = 0
    # bytes uploaded for incremental dirty-set evaluation (the compacted
    # dirty-row index vectors of ``repro.engine.incremental``): a subset
    # of h2d_bytes, broken out so the incremental path's transfer savings
    # are visible next to what a full re-upload would have cost
    gathered_bytes: int = 0

    def reset(self) -> None:
        self.h2d_bytes = 0
        self.h2d_calls = 0
        self.d2h_bytes = 0
        self.padded_bytes = 0
        self.gathered_bytes = 0

    def snapshot(self) -> dict:
        return {
            "h2d_bytes": self.h2d_bytes,
            "h2d_calls": self.h2d_calls,
            "d2h_bytes": self.d2h_bytes,
            "padded_bytes": self.padded_bytes,
            "gathered_bytes": self.gathered_bytes,
        }

    @contextlib.contextmanager
    def scope(self):
        """Isolate a region's transfer accounting, preserving outer totals.

        On entry the counters reset to zero, so assertions inside the
        block see only the block's own traffic; on exit the pre-entry
        values are added back, so the process-level totals equal
        outer + inner as if the scope had never existed.  Nests cleanly —
        each level isolates its own deltas.  This replaces the old
        reset-around-every-test fixture: a benchmark ENTRY or a test gets
        clean counters without silently zeroing someone else's.
        """
        saved = self.snapshot()
        self.reset()
        try:
            yield self
        finally:
            self.h2d_bytes += saved["h2d_bytes"]
            self.h2d_calls += saved["h2d_calls"]
            self.d2h_bytes += saved["d2h_bytes"]
            self.padded_bytes += saved["padded_bytes"]
            self.gathered_bytes += saved["gathered_bytes"]


TRANSFER = TransferStats()


def to_device(x, payload_bytes: int | None = None) -> jnp.ndarray:
    """Counted host->device transfer (the only upload path in the engine).

    ``payload_bytes`` marks how many of the array's bytes are real data;
    the remainder (alignment padding) is booked under
    ``TRANSFER.padded_bytes`` instead of ``h2d_bytes``.
    """
    a = np.asarray(x)
    payload = a.nbytes if payload_bytes is None else int(payload_bytes)
    TRANSFER.h2d_bytes += payload
    TRANSFER.padded_bytes += a.nbytes - payload
    TRANSFER.h2d_calls += 1
    return jnp.asarray(a)


def stream_chunks(
    arrays: Sequence[np.ndarray],
    n: int,
    chunk: int,
    compute: Callable,
    pad_values: Sequence[int],
    align: int = 128,
) -> list:
    """Double-buffered map of ``compute`` over row-chunks of ``arrays``.

    ``arrays`` are host arrays sharing leading dimension ``n``.  Full
    chunks have exactly ``chunk`` rows; the final partial chunk is padded
    up to a multiple of ``align`` with ``pad_values`` (one per array), so
    a call compiles at most two shapes.  Pad rows are accounted as
    ``TRANSFER.padded_bytes``, not payload.  Returns the list of *device*
    outputs (callers concatenate / read back once at the end, keeping
    dispatch async).
    """
    if n == 0:
        return []

    def put(start: int):
        stop = min(start + chunk, n)
        rows = stop - start
        target = chunk if rows == chunk else -(-rows // align) * align
        out = []
        for a, pv in zip(arrays, pad_values):
            piece = a[start:stop]
            payload = piece.nbytes
            if rows < target:
                pad = np.full((target - rows,) + a.shape[1:], pv, a.dtype)
                piece = np.concatenate([piece, pad], axis=0)
            out.append(to_device(piece, payload_bytes=payload))
        return tuple(out)

    starts = list(range(0, n, chunk))
    outs = []
    nxt = put(starts[0])
    for i, start in enumerate(starts):
        cur = nxt
        out = compute(*cur)  # async dispatch; device starts computing
        if i + 1 < len(starts):
            nxt = put(starts[i + 1])  # upload overlaps the in-flight compute
        outs.append(out)
    return outs


def double_buffer(items: Iterable, dispatch: Callable) -> float:
    """Two-deep pipeline over a lazy producer: overlap ingest with compute.

    ``dispatch(item)`` must *enqueue* device work and return without
    blocking (JAX dispatch is asynchronous as long as nothing reads a
    device value back).  While that work is in flight, the next item is
    pulled from ``items`` — so a generator producer materializes chunk
    ``i + 1`` on the host during chunk ``i``'s device compute, the same
    pipeline shape as :func:`stream_chunks` but for callers that own
    their dispatch (``repro.core.greedy.replicate_stream``).

    Returns the host seconds of producer work that overlapped in-flight
    device work (the pipeline's win over a strict pull-then-dispatch
    loop); the first item's materialization has nothing to hide behind
    and is not counted.
    """
    it = iter(items)
    try:
        cur = next(it)
    except StopIteration:
        return 0.0
    overlap_s = 0.0
    while True:
        dispatch(cur)
        t0 = time.perf_counter()
        try:
            cur = next(it)  # producer runs while the device computes
        except StopIteration:
            return overlap_s
        overlap_s += time.perf_counter() - t0


@dataclasses.dataclass
class StreamStats:
    """Residency accounting of one :class:`PathStream` consumption."""

    total_paths: int = 0
    chunks: int = 0
    peak_resident_paths: int = 0
    # host seconds of chunk materialization hidden behind device compute
    # (filled by pipelined consumers; 0.0 for a strict pull-then-compute)
    ingest_overlap_s: float = 0.0
    # candidate-table residency (filled by replicate_stream): the largest
    # host block of C(h, t) selection rows ever materialized at once vs.
    # the total rows shipped — peak < total proves the deep-path table
    # construction streamed instead of landing whole on the host
    peak_resident_table_rows: int = 0
    total_table_rows: int = 0


class PathStream:
    """Streamed PathSet ingestion from a host generator (consumed once).

    Wraps an iterable of :class:`~repro.core.paths.PathSet` chunks — or
    ``(PathSet, per_path_budgets)`` tuples when the latency constraint
    varies within the stream — and records how many paths were ever
    host-resident at once (``stats.peak_resident_paths``): the contract
    the provisioning-scale benchmark asserts (peak < total for a genuine
    stream).  Iteration yields normalized ``(PathSet, budgets_or_None)``
    pairs; generators are consumed lazily, so the producer can build each
    chunk on demand and drop it after the yield.
    """

    def __init__(self, chunks: Iterable):
        self._chunks = chunks
        self._consumed = False
        self.stats = StreamStats()

    def __iter__(self) -> Iterator[tuple]:
        if self._consumed:
            raise RuntimeError("PathStream is single-use; build a new one")
        self._consumed = True
        for item in self._chunks:
            ps, t = item if isinstance(item, tuple) else (item, None)
            if ps.n_paths == 0:
                continue
            self.stats.total_paths += ps.n_paths
            self.stats.chunks += 1
            self.stats.peak_resident_paths = max(
                self.stats.peak_resident_paths, ps.n_paths
            )
            yield ps, t
