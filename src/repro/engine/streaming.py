"""Host<->device transfer accounting + double-buffered chunk streaming.

Every host->device transfer the engine performs goes through ``to_device``
so the byte counter (``TRANSFER``) reflects real traffic; the perf
benchmarks (``benchmarks/perf_iterate.py engine`` and
``benchmarks/engine_backends.py``) read it to track the packed-resident
path's transfer advantage over the legacy per-call bool-mask uploads.

``stream_chunks`` is the engine's evaluation pipeline: while chunk ``i``
computes on device (JAX dispatch is asynchronous), chunk ``i + 1``'s
host->device copy is already enqueued — a two-deep software pipeline that
replaces the old synchronous per-chunk ``jnp.asarray`` + ``np.asarray``
round trip.  The final chunk is padded to the full chunk shape so every
step hits the same jit cache entry.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class TransferStats:
    h2d_bytes: int = 0
    h2d_calls: int = 0
    d2h_bytes: int = 0

    def reset(self) -> None:
        self.h2d_bytes = 0
        self.h2d_calls = 0
        self.d2h_bytes = 0

    def snapshot(self) -> dict:
        return {
            "h2d_bytes": self.h2d_bytes,
            "h2d_calls": self.h2d_calls,
            "d2h_bytes": self.d2h_bytes,
        }


TRANSFER = TransferStats()


def to_device(x) -> jnp.ndarray:
    """Counted host->device transfer (the only upload path in the engine)."""
    a = np.asarray(x)
    TRANSFER.h2d_bytes += a.nbytes
    TRANSFER.h2d_calls += 1
    return jnp.asarray(a)


def stream_chunks(
    arrays: Sequence[np.ndarray],
    n: int,
    chunk: int,
    compute: Callable,
    pad_values: Sequence[int],
    align: int = 128,
) -> list:
    """Double-buffered map of ``compute`` over row-chunks of ``arrays``.

    ``arrays`` are host arrays sharing leading dimension ``n``.  Full
    chunks have exactly ``chunk`` rows; the final partial chunk is padded
    up to a multiple of ``align`` with ``pad_values`` (one per array), so
    a call compiles at most two shapes.  Returns the list of *device*
    outputs (callers concatenate / read back once at the end, keeping
    dispatch async).
    """
    if n == 0:
        return []

    def put(start: int):
        stop = min(start + chunk, n)
        rows = stop - start
        target = chunk if rows == chunk else -(-rows // align) * align
        out = []
        for a, pv in zip(arrays, pad_values):
            piece = a[start:stop]
            if rows < target:
                pad = np.full((target - rows,) + a.shape[1:], pv, a.dtype)
                piece = np.concatenate([piece, pad], axis=0)
            out.append(to_device(piece))
        return tuple(out)

    starts = list(range(0, n, chunk))
    outs = []
    nxt = put(starts[0])
    for i, start in enumerate(starts):
        cur = nxt
        out = compute(*cur)  # async dispatch; device starts computing
        if i + 1 < len(starts):
            nxt = put(starts[i + 1])  # upload overlaps the in-flight compute
        outs.append(out)
    return outs
