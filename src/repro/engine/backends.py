"""The three latency-evaluation backends behind ``LatencyEngine``.

All backends compute the same quantity — h(p, r, rho), the number of
distributed traversals of a path under the access function (paper
Eqns 1-2) — with identical integer semantics:

  ``reference``  pure-python oracle (``repro.core.reference``), host mask.
  ``jnp``        vectorized ``lax.scan`` over the packed device words.
  ``pallas``     ``repro.kernels.path_latency`` TPU kernel (interpret mode
                 on CPU); inputs are gathered on device from the packed
                 words, so only the int32 path chunk crosses the host
                 boundary.

The legacy unpacked-bool scan (the old ``core.replication``
``_path_latencies_jit``) is retained as ``bool_scan`` for the
``resident=False`` compatibility/benchmark mode that re-uploads the bool
mask per call the way the seed implementation did.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine.packed import test_bits
from repro.engine.routing import resolve_policy
from repro.kernels.path_latency import path_latency_pallas

BACKENDS = ("reference", "jnp", "pallas")


def _valid_home(objects, lengths, shard, fill):
    L = objects.shape[1]
    valid = jnp.arange(L)[None, :] < lengths[:, None]
    safe = jnp.maximum(objects, 0)
    home = jnp.where(valid, shard[safe], fill).astype(jnp.int32)
    return valid, safe, home


@jax.jit
def words_scan(objects, lengths, words, shard):
    """Packed-words ``lax.scan`` walk of the access function."""
    valid, safe, home = _valid_home(objects, lengths, shard, 0)
    rows = words[safe]  # [P, L, W] uint32

    def step(server, xs):
        home_t, rows_t, valid_t = xs
        # rows_t is [P, W]; word select + bit test per lane (Eqn 1):
        widx = server // 32
        bit = (server % 32).astype(jnp.uint32)
        word = jnp.take_along_axis(rows_t, widx[:, None], axis=1)[:, 0]
        local = ((word >> bit) & jnp.uint32(1)).astype(jnp.bool_)
        nxt = jnp.where(local, server, home_t)
        cost = (~local) & valid_t
        nxt = jnp.where(valid_t, nxt, server)
        return nxt, cost

    server0 = home[:, 0]
    xs = (
        jnp.moveaxis(home[:, 1:], 1, 0),
        jnp.moveaxis(rows[:, 1:], 1, 0),
        jnp.moveaxis(valid[:, 1:], 1, 0),
    )
    _, costs = jax.lax.scan(step, server0, xs)
    return jnp.sum(costs.astype(jnp.int32), axis=0)


@jax.jit
def bool_scan(objects, lengths, mask, shard):
    """Legacy unpacked-bool walk (seed ``_path_latencies_jit`` semantics)."""
    valid, safe, home = _valid_home(objects, lengths, shard, 0)
    rloc = mask[safe]  # [P, L, S] bool

    def step(server, xs):
        home_t, rloc_t, valid_t = xs
        local = jnp.take_along_axis(rloc_t, server[:, None], axis=1)[:, 0]
        nxt = jnp.where(local, server, home_t)
        cost = (~local) & valid_t
        nxt = jnp.where(valid_t, nxt, server)
        return nxt, cost

    server0 = home[:, 0]
    xs = (
        jnp.moveaxis(home[:, 1:], 1, 0),
        jnp.moveaxis(rloc[:, 1:], 1, 0),
        jnp.moveaxis(valid[:, 1:], 1, 0),
    )
    _, costs = jax.lax.scan(step, server0, xs)
    return jnp.sum(costs.astype(jnp.int32), axis=0)


@jax.jit
def pallas_prep(objects, lengths, words, shard):
    """Gather the kernel's (home, masks) inputs on device from the words."""
    valid, safe, home = _valid_home(objects, lengths, shard, -1)
    masks = words[safe]  # [P, L, W]
    return home, masks


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def pallas_eval(objects, lengths, words, shard, block: int = 128):
    """Pallas-backed chunk evaluation; stays on device end to end."""
    home, masks = pallas_prep(objects, lengths, words, shard)
    return path_latency_pallas(
        home, masks, lengths, block=block, interpret=not _on_tpu()
    )


def reference_eval(objects, lengths, mask, shard) -> np.ndarray:
    """Pure-python oracle over a host mask (``repro.core.reference``)."""
    from repro.core.reference import path_latencies_reference  # lazy: no cycle

    return path_latencies_reference(objects, lengths, mask, shard)


# ---------------------------------------------------------------------------
# Access trace (executor decoration): per-position visited server + locality.
# ---------------------------------------------------------------------------
@jax.jit
def _access_trace_impl(objects, lengths, words, home, start):
    P, L = objects.shape
    valid = jnp.arange(L)[None, :] < lengths[:, None]
    safe = jnp.maximum(objects, 0)
    hrows = home[safe]  # [P, L]
    wrows = words[safe]  # [P, L, W]

    server0 = jnp.where(valid[:, 0], start, 0).astype(jnp.int32)

    def step(server, xs):
        h_t, w_t, v_t = xs
        srv_c = jnp.maximum(server, 0)
        word = jnp.take_along_axis(w_t, (srv_c // 32)[:, None], axis=1)[:, 0]
        bit = (srv_c % 32).astype(jnp.uint32)
        has_local = ((word >> bit) & jnp.uint32(1)).astype(jnp.bool_)
        has_local = has_local & (server >= 0)
        nxt = jnp.where(has_local, server, h_t).astype(jnp.int32)
        nxt = jnp.where(v_t, nxt, server)
        return nxt, (nxt, has_local & v_t)

    xs = (
        jnp.moveaxis(hrows[:, 1:], 1, 0),
        jnp.moveaxis(wrows[:, 1:], 1, 0),
        jnp.moveaxis(valid[:, 1:], 1, 0),
    )
    _, (srv_rest, loc_rest) = jax.lax.scan(step, server0, xs)
    servers = jnp.concatenate(
        [server0[:, None], jnp.moveaxis(srv_rest, 0, 1)], axis=1
    )
    local = jnp.concatenate(
        [valid[:, :1], jnp.moveaxis(loc_rest, 0, 1)], axis=1
    )
    return servers, local


@jax.jit
def _root_home(objects, home):
    return home[jnp.maximum(objects[:, 0], 0)].astype(jnp.int32)


# ---------------------------------------------------------------------------
# Policy-parameterized walk: the per-hop target is a vectorized function of
# (current server, object words, home, load) instead of the constant
# ``home[obj]``.  See ``repro.engine.routing`` for the policy semantics.
# ---------------------------------------------------------------------------
def _unpack_rows(w):
    """[P, W] uint32 -> [P, W*32] bool holder bits (little-endian words)."""
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (w[:, :, None] >> shifts[None, None, :]) & jnp.uint32(1)
    return bits.reshape(w.shape[0], -1).astype(jnp.bool_)


def _pick_targets(cand, home, load):
    """Best-scoring holder per lane; home wins ties, then lowest id.

    ``cand`` bool [P, Sp] candidate holders, ``home`` int32 [P] (may be
    -1), ``load`` float32 [Sp] (one shared score per server — the
    queue-depth rank) or float32 [P, Sp] (a per-lane score plane — the
    DP cost-to-go of ``nearest_copy_dp``).  Returns int32 [P]; -1 when a
    lane has no candidate.  The scalar twins are
    ``routing.pick_holder_host`` / ``routing.pick_holder_scored``.
    """
    any_c = cand.any(axis=1)
    lv = jnp.where(cand, jnp.broadcast_to(load, cand.shape), jnp.inf)
    m = jnp.min(lv, axis=1)
    best = cand & (lv <= m[:, None])
    hc = jnp.maximum(home, 0)
    home_ok = (home >= 0) & jnp.take_along_axis(best, hc[:, None], axis=1)[:, 0]
    first = jnp.argmax(best, axis=1).astype(jnp.int32)
    tgt = jnp.where(home_ok, home.astype(jnp.int32), first)
    return jnp.where(any_c, tgt, jnp.int32(-1))


@functools.partial(jax.jit, static_argnames=("home_first", "lookahead"))
def _routed_trace_impl(
    objects, lengths, words, home, start, load, home_first, lookahead
):
    """Generalized access walk: hop targets picked by a routing policy.

    With ``home_first=True`` the hop target is ``home[obj]`` — the same
    ops as ``_access_trace_impl`` (bit-identical, asserted in tests).
    Otherwise the target is the holder pick of ``_pick_targets`` over the
    object's packed words (``load`` = zeros gives ``nearest_copy``, live
    queue depths give ``queue_aware``), optionally preferring holders of
    the path's *next* object (``lookahead``).
    """
    P, L = objects.shape
    valid = jnp.arange(L)[None, :] < lengths[:, None]
    safe = jnp.maximum(objects, 0)
    hrows = home[safe]  # [P, L]
    wrows = words[safe]  # [P, L, W]
    # holder words of the NEXT object per step (zeros when x+1 is padding);
    # [P, L-1, W] to match the scan inputs — for L == 1 the scan runs zero
    # steps and the lookahead rows are empty too
    if L > 1:
        wnext = jnp.concatenate(
            [wrows[:, 2:], jnp.zeros_like(wrows[:, :1])], axis=1
        )
        vnext = jnp.concatenate(
            [valid[:, 2:], jnp.zeros_like(valid[:, :1])], axis=1
        )
        wnext = jnp.where(vnext[:, :, None], wnext, jnp.uint32(0))
    else:
        wnext = wrows[:, 1:]

    server0 = jnp.where(valid[:, 0], start, 0).astype(jnp.int32)

    def step(server, xs):
        h_t, w_t, wn_t, v_t = xs
        srv_c = jnp.maximum(server, 0)
        word = jnp.take_along_axis(w_t, (srv_c // 32)[:, None], axis=1)[:, 0]
        bit = (srv_c % 32).astype(jnp.uint32)
        has_local = ((word >> bit) & jnp.uint32(1)).astype(jnp.bool_)
        has_local = has_local & (server >= 0)
        if home_first:
            tgt = h_t
        else:
            cand = _unpack_rows(w_t)
            tgt = _pick_targets(cand, h_t, load)
            if lookahead:
                la = cand & _unpack_rows(wn_t)
                pref = _pick_targets(la, h_t, load)
                tgt = jnp.where(la.any(axis=1), pref, tgt)
        nxt = jnp.where(has_local, server, tgt).astype(jnp.int32)
        nxt = jnp.where(v_t, nxt, server)
        return nxt, (nxt, has_local & v_t)

    xs = (
        jnp.moveaxis(hrows[:, 1:], 1, 0),
        jnp.moveaxis(wrows[:, 1:], 1, 0),
        jnp.moveaxis(wnext, 1, 0),
        jnp.moveaxis(valid[:, 1:], 1, 0),
    )
    _, (srv_rest, loc_rest) = jax.lax.scan(step, server0, xs)
    servers = jnp.concatenate(
        [server0[:, None], jnp.moveaxis(srv_rest, 0, 1)], axis=1
    )
    local = jnp.concatenate(
        [valid[:, :1], jnp.moveaxis(loc_rest, 0, 1)], axis=1
    )
    return servers, local


# ---------------------------------------------------------------------------
# Depth-k suffix DP (``nearest_copy_dp``): score every server by the optimal
# paid-hop count over the next k accesses, then walk with the scored pick.
# ---------------------------------------------------------------------------
def _unpack_positions(wrows):
    """[P, L, W] uint32 -> [P, L, W*32] bool holder bits per position."""
    P, L, W = wrows.shape
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (wrows[:, :, :, None] >> shifts[None, None, None, :]) & jnp.uint32(1)
    return bits.reshape(P, L, W * 32).astype(jnp.bool_)


def _dp_score_tables(objects, lengths, words, depth: int):
    """``E[p, pos, s]``: optimal paid hops over the next ``depth`` accesses.

    The batched twin of ``routing.dp_suffix_scores`` (the dead -1 state is
    tracked in a separate ``D`` plane instead of an extra column).  A hop
    may land on any holder of the hopped-to object; an object with no
    holder sends the walk to the dead state, from which nothing is local
    but later hops still revive.  ``depth < 0`` scores the whole suffix
    (one backward scan); ``depth >= 0`` runs ``depth`` window-widening
    sweeps (each a vectorized position shift).  Returns float32
    ``[P, L, W*32]``.
    """
    P, L = objects.shape
    valid = jnp.arange(L)[None, :] < lengths[:, None]
    safe = jnp.maximum(objects, 0)
    hold = _unpack_positions(words[safe]) & valid[:, :, None]  # [P, L, Sp]
    Sp = hold.shape[2]
    if L == 1:
        return jnp.zeros((P, L, Sp), jnp.float32)

    def hop_cost(hold_next, V_next, D_next):
        lv = jnp.where(hold_next, V_next, jnp.inf)
        vmin = jnp.min(lv, axis=-1)
        any_h = hold_next.any(axis=-1)
        return 1.0 + jnp.where(any_h, vmin, D_next)

    if depth < 0:
        # full suffix: one backward scan, carry = (V at pos+1, dead value)
        def step(carry, xs):
            Vn, Dn = carry
            hold_next, v_next = xs
            hop = hop_cost(hold_next, Vn, Dn)
            V = jnp.where(
                v_next[:, None],
                jnp.where(hold_next, Vn, hop[:, None]),
                0.0,
            )
            D = jnp.where(v_next, hop, 0.0)
            return (V, D), V

        xs = (
            jnp.moveaxis(hold[:, 1:], 1, 0),
            jnp.moveaxis(valid[:, 1:], 1, 0),
        )
        init = (jnp.zeros((P, Sp), jnp.float32), jnp.zeros((P,), jnp.float32))
        _, Vs = jax.lax.scan(step, init, xs, reverse=True)
        return jnp.concatenate(
            [jnp.moveaxis(Vs, 0, 1), jnp.zeros((P, 1, Sp), jnp.float32)],
            axis=1,
        )

    # window-widening sweeps: E_m[pos] from E_{m-1}[pos + 1] (position shift)
    E = jnp.zeros((P, L, Sp), jnp.float32)
    D = jnp.zeros((P, L), jnp.float32)
    hold_next = jnp.concatenate(
        [hold[:, 1:], jnp.zeros((P, 1, Sp), jnp.bool_)], axis=1
    )
    v_next = jnp.concatenate(
        [valid[:, 1:], jnp.zeros((P, 1), jnp.bool_)], axis=1
    )
    for _ in range(depth):
        E_next = jnp.concatenate(
            [E[:, 1:], jnp.zeros((P, 1, Sp), jnp.float32)], axis=1
        )
        D_next = jnp.concatenate(
            [D[:, 1:], jnp.zeros((P, 1), jnp.float32)], axis=1
        )
        hop = hop_cost(hold_next, E_next, D_next)  # [P, L]
        E = jnp.where(
            v_next[:, :, None],
            jnp.where(hold_next, E_next, hop[:, :, None]),
            0.0,
        )
        D = jnp.where(v_next, hop, 0.0)
    return E


def _scored_walk(objects, lengths, words, home, start, scores):
    """The access walk with per-(position, server) hop scores.

    Same scan as ``_routed_trace_impl`` but the remote-hop pick ranks
    holders by ``scores[:, i, :]`` (the DP cost-to-go of landing at each
    server for the hop at position ``i``) instead of a shared load
    vector; home wins ties, then the lowest id.
    """
    P, L = objects.shape
    valid = jnp.arange(L)[None, :] < lengths[:, None]
    safe = jnp.maximum(objects, 0)
    hrows = home[safe]
    wrows = words[safe]

    server0 = jnp.where(valid[:, 0], start, 0).astype(jnp.int32)

    def step(server, xs):
        h_t, w_t, sc_t, v_t = xs
        srv_c = jnp.maximum(server, 0)
        word = jnp.take_along_axis(w_t, (srv_c // 32)[:, None], axis=1)[:, 0]
        bit = (srv_c % 32).astype(jnp.uint32)
        has_local = ((word >> bit) & jnp.uint32(1)).astype(jnp.bool_)
        has_local = has_local & (server >= 0)
        cand = _unpack_rows(w_t)
        tgt = _pick_targets(cand, h_t, sc_t)
        nxt = jnp.where(has_local, server, tgt).astype(jnp.int32)
        nxt = jnp.where(v_t, nxt, server)
        return nxt, (nxt, has_local & v_t)

    xs = (
        jnp.moveaxis(hrows[:, 1:], 1, 0),
        jnp.moveaxis(wrows[:, 1:], 1, 0),
        jnp.moveaxis(scores[:, 1:], 1, 0),
        jnp.moveaxis(valid[:, 1:], 1, 0),
    )
    _, (srv_rest, loc_rest) = jax.lax.scan(step, server0, xs)
    servers = jnp.concatenate(
        [server0[:, None], jnp.moveaxis(srv_rest, 0, 1)], axis=1
    )
    local = jnp.concatenate(
        [valid[:, :1], jnp.moveaxis(loc_rest, 0, 1)], axis=1
    )
    return servers, local


@functools.partial(jax.jit, static_argnames=("depth",))
def _dp_trace_impl(objects, lengths, words, home, start, depth):
    scores = _dp_score_tables(objects, lengths, words, depth)
    return _scored_walk(objects, lengths, words, home, start, scores)


@functools.partial(jax.jit, static_argnames=("depth",))
def _dp_scores_jit(objects, lengths, words, depth):
    return _dp_score_tables(objects, lengths, words, depth)


def _dp_depth(pol) -> int:
    return -1 if pol.depth is None else int(pol.depth)


def _load_vector(load, words) -> jnp.ndarray:
    """Pad a per-server load vector to the words' W*32 bit width.

    Bits past ``n_servers`` are never set in the packed words, so the pad
    value is irrelevant for correctness (padded servers are never
    candidates); zeros keep the array cheap.
    """
    width = words.shape[1] * 32
    out = np.zeros(width, np.float32)
    if load is not None:
        lv = np.asarray(load, np.float32)
        out[: lv.shape[0]] = lv
    return jnp.asarray(out)


def access_trace(objects, lengths, words, home, start=None, policy=None,
                 load=None):
    """Walk Eqn 1 recording the visited server and locality per position.

    ``home`` is a per-object routing target (the sharding function, or the
    executor's fail-over map; may be -1 when no alive copy exists).
    ``start`` optionally overrides the per-path start server (int32 [P]) —
    the router's coordinator pick when it differs from ``home[root]``
    (replica_lb / hedged routing); default is ``home[root]``.

    ``policy`` (str | ``repro.engine.routing.RoutingPolicy``; default
    ``home_first``) selects the remote-hop target rule; ``load`` is the
    per-server queue-depth vector a ``queue_aware`` policy ranks holders
    by (ignored otherwise).

    Returns (servers int32 [P, L], local bool [P, L]); position 0 counts as
    local when the path is non-empty, matching the executor's accounting.
    The distributed-traversal count is ``(valid[:, 1:] & ~local[:, 1:]).sum``.
    """
    pol = resolve_policy(policy)
    if start is None:
        start = _root_home(objects, home)
    if pol.name == "home_first":
        return _access_trace_impl(objects, lengths, words, home, start)
    if pol.name == "nearest_copy_dp":
        return _dp_trace_impl(
            objects, lengths, words, home, start, depth=_dp_depth(pol)
        )
    return _routed_trace_impl(
        objects, lengths, words, home, start,
        _load_vector(load if pol.uses_load else None, words),
        home_first=False, lookahead=pol.lookahead,
    )


@functools.partial(jax.jit, static_argnames=("lookahead",))
def _routed_counts_impl(objects, lengths, words, home, start, load, lookahead):
    _, local = _routed_trace_impl(
        objects, lengths, words, home, start, load,
        home_first=False, lookahead=lookahead,
    )
    L = objects.shape[1]
    valid = jnp.arange(L)[None, :] < lengths[:, None]
    return jnp.sum((valid & ~local).astype(jnp.int32), axis=1)


@functools.partial(jax.jit, static_argnames=("depth",))
def _dp_counts_impl(objects, lengths, words, home, start, depth):
    _, local = _dp_trace_impl(objects, lengths, words, home, start, depth)
    L = objects.shape[1]
    valid = jnp.arange(L)[None, :] < lengths[:, None]
    return jnp.sum((valid & ~local).astype(jnp.int32), axis=1)


def routed_counts(objects, lengths, words, shard, policy, load=None):
    """h(p, r, rho) per path under a non-home-first routing policy."""
    pol = resolve_policy(policy)
    if pol.name == "nearest_copy_dp":
        return _dp_counts_impl(
            objects, lengths, words, shard, _root_home(objects, shard),
            depth=_dp_depth(pol),
        )
    return _routed_counts_impl(
        objects, lengths, words, shard, _root_home(objects, shard),
        _load_vector(load if pol.uses_load else None, words),
        lookahead=pol.lookahead,
    )


def gate_counts(objects, lengths, words, shard, pol, rank, backend="jnp",
                block: int = 128):
    """Traceable routed-gate latencies — callable inside an enclosing jit.

    The fused greedy UPDATE (``repro.core.greedy``) and the batched prune
    sweep compute the policy gate h(p, r, rho; policy) in the *same* jit
    step as candidate scoring and the scatter-OR, against the same words
    snapshot.  ``pol`` must be a resolved, non-home-first policy (a jit
    static — frozen dataclasses hash); ``rank`` the already-padded
    ``[W*32]`` float32 holder-rank vector (``_load_vector`` of the live
    queue depths for ``queue_aware``, zeros otherwise — callers own that
    normalization because this function must stay trace-transparent).
    Dispatch mirrors :func:`routed_counts` / :func:`pallas_routed_eval`
    bit-for-bit, so gating fused vs separate cannot diverge.
    """
    start = _root_home(objects, shard)
    if backend == "pallas":
        from repro.kernels.routed_walk import (  # lazy import
            routed_walk_pallas,
            scored_walk_pallas,
        )

        home, masks = pallas_prep(objects, lengths, words, shard)
        if pol.name == "nearest_copy_dp":
            scores = _dp_score_tables(objects, lengths, words, _dp_depth(pol))
            _, local = scored_walk_pallas(
                home, masks, lengths, start, scores,
                block=block, interpret=not _on_tpu(),
            )
        else:
            _, local = routed_walk_pallas(
                home, masks, lengths, start, rank,
                block=block, interpret=not _on_tpu(),
                lookahead=pol.lookahead, home_first=pol.name == "home_first",
            )
        L = objects.shape[1]
        valid = jnp.arange(L)[None, :] < lengths[:, None]
        return jnp.sum((valid & ~local.astype(bool)).astype(jnp.int32), axis=1)
    if pol.name == "nearest_copy_dp":
        return _dp_counts_impl(
            objects, lengths, words, shard, start, depth=_dp_depth(pol)
        )
    return _routed_counts_impl(
        objects, lengths, words, shard, start, rank, lookahead=pol.lookahead
    )


# ---------------------------------------------------------------------------
# k-resilient evaluation: the masked re-walk over every loss case.
# ---------------------------------------------------------------------------
@jax.jit
def mask_case_words(words, case_mask):
    """Clear one loss case's holder bits: ``words & ~case_mask`` per row."""
    return words & ~case_mask[None, :]


@jax.jit
def _resilient_home_vmap(objects, lengths, words, case_masks, case_homes):
    def one(cmask, home):
        return words_scan(objects, lengths, words & ~cmask[None, :], home)

    return jax.vmap(one)(case_masks, case_homes)


@functools.partial(jax.jit, static_argnames=("lookahead",))
def _resilient_routed_vmap(
    objects, lengths, words, case_masks, case_homes, load, lookahead
):
    def one(cmask, home):
        w = words & ~cmask[None, :]
        return _routed_counts_impl(
            objects, lengths, w, home, _root_home(objects, home), load,
            lookahead=lookahead,
        )

    return jax.vmap(one)(case_masks, case_homes)


@functools.partial(jax.jit, static_argnames=("depth",))
def _resilient_dp_vmap(objects, lengths, words, case_masks, case_homes, depth):
    def one(cmask, home):
        w = words & ~cmask[None, :]
        return _dp_counts_impl(
            objects, lengths, w, home, _root_home(objects, home), depth=depth
        )

    return jax.vmap(one)(case_masks, case_homes)


def resilient_counts(
    objects, lengths, words, case_masks, case_homes, policy=None, load=None,
    backend: str = "jnp", block: int = 128,
):
    """h(p, r - case, rho; policy) per (loss case, path): int32 [D, P].

    The k-resilience gate's masked re-walk, batched across loss cases:
    for each case the lost servers' holder bits are cleared from the
    packed words (``case_masks`` uint32 [D, W]) and the walk runs under
    the case's rotation-failover homes (``case_homes`` int32 [D, n]) —
    see ``repro.engine.resilience``.  The ``jnp`` backend vmaps all D
    cases into one dispatch; ``pallas`` lowers each case's walk to the
    existing path-latency / routed-walk kernels over the masked words
    (the masking itself is one trivial AND, so kernel parity is inherited
    rather than re-implemented).  The reference oracle loops live in
    ``LatencyEngine.resilient_path_latencies`` (they need the host mask).
    """
    pol = resolve_policy(policy)
    if backend == "pallas":
        outs = []
        for d in range(case_masks.shape[0]):
            w = mask_case_words(words, case_masks[d])
            if pol.name == "home_first":
                outs.append(pallas_eval(objects, lengths, w, case_homes[d],
                                        block=block))
            else:
                outs.append(pallas_routed_eval(objects, lengths, w,
                                               case_homes[d], pol, load=load,
                                               block=block))
        return jnp.stack(outs)
    if backend != "jnp":
        raise ValueError(f"resilient_counts backend must be jnp | pallas, got {backend!r}")
    if pol.name == "home_first":
        return _resilient_home_vmap(objects, lengths, words, case_masks, case_homes)
    if pol.name == "nearest_copy_dp":
        return _resilient_dp_vmap(
            objects, lengths, words, case_masks, case_homes, depth=_dp_depth(pol)
        )
    return _resilient_routed_vmap(
        objects, lengths, words, case_masks, case_homes,
        _load_vector(load if pol.uses_load else None, words),
        lookahead=pol.lookahead,
    )


def pallas_routed_trace(
    objects, lengths, words, shard, policy, load=None, block: int = 128,
    start=None,
):
    """Policy-routed walk via the Pallas kernel; (servers, local) arrays."""
    from repro.kernels.routed_walk import (  # lazy import
        routed_walk_pallas,
        scored_walk_pallas,
    )

    pol = resolve_policy(policy)
    home, masks = pallas_prep(objects, lengths, words, shard)
    if start is None:
        start = _root_home(objects, shard)
    if pol.name == "nearest_copy_dp":
        # the score tables are a plain jnp precompute (device-resident);
        # the kernel is the score-parameterized walk over them
        scores = _dp_scores_jit(objects, lengths, words, _dp_depth(pol))
        return scored_walk_pallas(
            home, masks, lengths, start, scores,
            block=block, interpret=not _on_tpu(),
        )
    return routed_walk_pallas(
        home, masks, lengths, start,
        _load_vector(load if pol.uses_load else None, words),
        block=block, interpret=not _on_tpu(),
        lookahead=pol.lookahead, home_first=pol.name == "home_first",
    )


def pallas_routed_eval(
    objects, lengths, words, shard, policy, load=None, block: int = 128
):
    """Distributed-traversal counts from the Pallas policy-routed walk."""
    _, local = pallas_routed_trace(
        objects, lengths, words, shard, policy, load, block=block
    )
    L = objects.shape[1]
    valid = jnp.arange(L)[None, :] < lengths[:, None]
    return jnp.sum((valid & ~local).astype(jnp.int32), axis=1)


@jax.jit
def query_slack(path_lats, query_ids, t_q):
    """Per-query slack t_Q - l_Q against a device-resident budget vector.

    ``path_lats`` int32 [P] (h per path), ``query_ids`` int32 [P],
    ``t_q`` int32 [nq].  l_Q is the max over the query's paths (Def 4.3);
    queries with no paths in the batch have l_Q = 0 (slack = budget).
    Negative slack marks a violating query (Def 4.4 constraint 1).
    """
    nq = t_q.shape[0]
    lq = (
        jnp.zeros((nq,), jnp.int32)
        .at[query_ids]
        .max(path_lats.astype(jnp.int32))
    )
    return t_q - lq


@jax.jit
def margin_cost(words, f, objects, servers):
    """Marginal storage cost of candidate (object, server) additions.

    Snapshot semantics against the device-resident words: each pair whose
    bit is not yet set contributes ``f[v]``; duplicate pairs count once per
    occurrence (the greedy UPDATE's lock-free estimate).  Pairs with a
    negative object or server are ignored.  Reduces over the last axis.
    """
    ok = (objects >= 0) & (servers >= 0)
    o = jnp.maximum(objects, 0)
    s = jnp.maximum(servers, 0)
    present = test_bits(words, o, s)
    need = ok & ~present
    return jnp.sum(jnp.where(need, f[o], 0.0), axis=-1)
