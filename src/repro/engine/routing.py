"""Pluggable hop-target routing policies for the batched access walk.

The paper's latency model (Eqn 1 / Def 4.3) counts an access as local
whenever *any* replica of the next object is co-located with the current
server; when it is not, the walk must pick a remote target.  Eqn 1's
second case nominally sends the hop to the object's home server, but the
model is indifferent to *which* copy holder serves a remote hop — and the
choice matters twice over: the landing server decides whether *later*
accesses of the path are local (a holder of the next object keeps the
walk local one hop longer), and under traffic it decides which queue the
RPC waits in.  This module makes that choice a first-class, swappable
policy consumed by ``repro.engine.backends.access_trace`` and every layer
above it (engine -> distsys executor -> serve simulator/controller):

  ``home_first``    Eqn 1 verbatim: remote hops go to the object's home
                    (or the caller's fail-over map).  Bit-identical to the
                    historical hardcoded walk.
  ``nearest_copy``  stay local when possible; a remote hop prefers an
                    alive copy holder that *also* holds the path's next
                    object (one-step locality lookahead), then the home
                    server, then the lowest id.  The paper-faithful
                    "any co-located replica counts" reading of Eqn 1 —
                    h under ``nearest_copy`` is what ``is_feasible`` can
                    optionally be scored against.
  ``queue_aware``   ``nearest_copy``'s candidate preference, tie-broken by
                    a per-server load vector (live queue depths): within
                    the preferred candidate class the least-loaded holder
                    serves the hop, the home server winning ties — the
                    batched generalization of ``Router.route_hop``.
  ``nearest_copy_dp(k)``  the depth-``k`` generalization of the locality
                    lookahead: a remote hop scores every alive holder by
                    the *optimal* number of paid hops over the next ``k``
                    accesses of the path (a DP over the path suffix,
                    recomputed against the live replica state) and picks
                    the best-scoring holder, home winning ties, then the
                    lowest id.  ``k=0`` reduces to ``home_first`` and
                    ``k=1`` to ``nearest_copy`` **bit-identically** (the
                    one-step score is exactly "does this holder keep the
                    next access local"); ``depth=None`` scores the whole
                    remaining suffix, i.e. executes the *optimal*
                    replica-aware walk — the latency it reports
                    pathwise-dominates every other policy and is monotone
                    under replica additions (the two properties
                    ``tests/test_policy_properties.py`` pins).  For
                    intermediate ``k`` the walk is receding-horizon:
                    better in aggregate as ``k`` grows, but not pathwise
                    (a deeper-but-still-myopic pick can lose to a lucky
                    shallow one on an adversarial path).

Policies are frozen dataclasses (hashable, usable as jit static args);
the device implementations live in ``repro.engine.backends`` and a Pallas
kernel twin in ``repro.kernels.routed_walk``.  :func:`pick_holder_host`
and :func:`pick_holder_scored` are the scalar numpy twins shared by
``Router.route_hop`` and the ``reference`` backend oracle, so all three
implementations pin one semantics.
"""
from __future__ import annotations

import dataclasses

import numpy as np

POLICIES = ("home_first", "nearest_copy", "queue_aware", "nearest_copy_dp")


@dataclasses.dataclass(frozen=True)
class RoutingPolicy:
    """Base marker: how the batched walk picks a remote hop's target."""

    name = "home_first"
    uses_load = False
    lookahead = False


@dataclasses.dataclass(frozen=True)
class HomeFirst(RoutingPolicy):
    """Eqn 1 second case verbatim: remote hops go to ``home[obj]``."""

    name = "home_first"


@dataclasses.dataclass(frozen=True)
class NearestCopy(RoutingPolicy):
    """Locality-greedy holder pick: lookahead class, then home, then id.

    ``lookahead=False`` drops the one-step locality preference, reducing
    the pick to "home if it holds a copy, else lowest-id holder".
    """

    name = "nearest_copy"
    lookahead: bool = True


@dataclasses.dataclass(frozen=True)
class QueueAware(NearestCopy):
    """``nearest_copy`` tie-broken by a per-server load vector.

    Within the preferred candidate class (lookahead holders when any,
    else all holders) the least-loaded server wins; ties prefer the home
    server, then the lowest id.  With no lookahead candidates this is
    exactly ``Router.route_hop``'s queue-aware scalar pick, batched.
    """

    name = "queue_aware"
    uses_load = True


@dataclasses.dataclass(frozen=True)
class NearestCopyDP(RoutingPolicy):
    """Depth-``k`` locality lookahead: a DP over the path suffix.

    A remote hop scores every holder ``s'`` by the optimal paid-hop count
    over the next ``depth`` accesses when the walk lands at ``s'`` (the
    suffix DP of ``repro.engine.backends._dp_score_tables``); the
    best-scoring holder serves the hop, the home server winning ties,
    then the lowest id.  ``depth=None`` scores the entire remaining
    suffix — the *optimal* replica-aware walk, the strongest reading of
    Eqn 1's "any co-located copy counts".  ``depth=0`` is ``home_first``
    and ``depth=1`` is ``nearest_copy``, bit-identically.
    """

    name = "nearest_copy_dp"
    depth: int | None = None

    def __post_init__(self):
        if self.depth is not None and self.depth < 0:
            raise ValueError("nearest_copy_dp depth must be >= 0 or None")


def nearest_copy_dp(depth: int | None = None) -> NearestCopyDP:
    """The depth-``k`` DP lookahead policy (``None`` = full suffix)."""
    return NearestCopyDP(depth=depth)


def resolve_policy(policy) -> RoutingPolicy:
    """str | RoutingPolicy | None -> RoutingPolicy (None = home_first)."""
    if policy is None:
        return HomeFirst()
    if isinstance(policy, RoutingPolicy):
        return policy
    if policy == "home_first":
        return HomeFirst()
    if policy == "nearest_copy":
        return NearestCopy()
    if policy == "queue_aware":
        return QueueAware()
    if policy == "nearest_copy_dp":
        return NearestCopyDP()
    raise ValueError(f"unknown routing policy {policy!r}; use {POLICIES}")


def pick_holder_host(
    holders: np.ndarray,
    home: int,
    load: np.ndarray | None = None,
    lookahead: np.ndarray | None = None,
) -> int:
    """Scalar oracle of the remote-hop holder pick (one access).

    ``holders`` bool [S] — alive copy holders of the hopped-to object;
    ``home`` the object's home server (may be -1 when no alive copy
    exists — it then never wins a tie); ``load`` optional per-server
    queue depths (None = unloaded, the ``nearest_copy`` case);
    ``lookahead`` optional bool [S] — holders of the *next* object on the
    path (the preferred candidate class when it intersects ``holders``).

    Returns the picked server id, or -1 when ``holders`` is empty.  The
    vectorized jnp walk and the Pallas kernel are parity-tested against
    this function.
    """
    holders = np.asarray(holders, bool)
    cand = holders
    if lookahead is not None:
        both = holders & np.asarray(lookahead, bool)
        if both.any():
            cand = both
    ids = np.nonzero(cand)[0]
    if len(ids) == 0:
        return -1
    lv = (
        np.zeros(len(ids))
        if load is None
        else np.asarray(load, np.float64)[ids]
    )
    m = lv.min()
    best = ids[lv <= m]
    if home in best:
        return int(home)
    return int(best[0])


def pick_holder_scored(
    holders: np.ndarray, home: int, scores: np.ndarray
) -> int:
    """Scalar oracle of the scored holder pick (``nearest_copy_dp``).

    ``holders`` bool [S] — alive copy holders of the hopped-to object;
    ``home`` the object's home server (never wins a tie when -1);
    ``scores`` float/int [S] — per-server cost-to-go (lower is better).
    Among the minimum-score holders the home wins, then the lowest id;
    returns -1 when ``holders`` is empty.  The batched jnp walk and the
    scored Pallas kernel are parity-tested against this function.
    """
    holders = np.asarray(holders, bool)
    ids = np.nonzero(holders)[0]
    if len(ids) == 0:
        return -1
    sc = np.asarray(scores, np.float64)[ids]
    m = sc.min()
    best = ids[sc <= m]
    if home in best:
        return int(home)
    return int(best[0])


def dp_suffix_scores(
    objs: np.ndarray, mask: np.ndarray, depth: int | None
) -> "np.ndarray":
    """Suffix-DP score table for one path (the scalar oracle).

    ``E[pos, s]`` = minimal number of paid hops over the next ``depth``
    accesses of the path (``objs[pos + 1 :]``, clipped at the path end)
    when the walk sits at server ``s`` after access ``pos``; a hop may go
    to any holder of the hopped-to object (``mask``), and an object with
    no holder sends the walk to the dead server -1 (from which nothing is
    local but later hops can still revive to a real holder).  The last
    row ``E[pos, S]`` is that dead-state value.  ``depth=None`` scores
    the whole suffix (the optimal cost-to-go).  Returns float64
    ``[n, S + 1]``.
    """
    objs = [int(v) for v in objs]
    n = len(objs)
    S = mask.shape[1]
    k = n if depth is None else min(int(depth), n)
    # E[m] rows roll over positions; build bottom-up over the window size m
    E = np.zeros((n, S + 1), np.float64)
    for _ in range(k):
        nxt = np.zeros((n, S + 1), np.float64)
        for pos in range(n - 1):
            v = objs[pos + 1]
            hold = mask[v]
            if hold.any():
                hop = 1.0 + E[pos + 1, :S][hold].min()
            else:
                hop = 1.0 + E[pos + 1, S]
            nxt[pos, :S] = np.where(hold, E[pos + 1, :S], hop)
            nxt[pos, S] = hop
        E = nxt
    return E
