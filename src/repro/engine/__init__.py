"""Unified device-resident latency-evaluation engine.

One backend-dispatched implementation of the paper's hot primitive —
h(p, r, rho), the distributed-traversal count of a path under a
replication scheme (Eqns 1-3) — shared by the greedy UPDATE driver, the
exact reference, the baselines, the distsys executor, the workload
analyzer, and every benchmark.

  LatencyEngine  — path_latencies / query_latencies / query_slack /
                   is_feasible / margin_costs behind
                   "reference" | "jnp" | "pallas"; latency constraints are
                   vector-valued (per-query t_Q, scalar broadcast as the
                   degenerate case)
  RawScheme      — minimal mask+shard scheme carrier (from_arrays input)
  PackedScheme   — the device-resident packed uint32 bitmask state
  RoutingPolicy  — pluggable remote-hop target selection for the batched
                   access walk (home_first | nearest_copy | queue_aware |
                   nearest_copy_dp(k), the suffix-DP lookahead family);
                   consumed by access_trace / path_latencies(policy=)
                   and the policy-aware greedy provisioning gate
  TRANSFER       — host<->device transfer accounting (perf benchmarks)
  PathStream     — streamed PathSet ingestion from a host generator with
                   peak-residency accounting (provisioning at scale);
                   consumed by ``repro.core.greedy.replicate_stream``
  PathIndex      — CSR object->path inverted index; backs the engine's
                   persistent dirty-set latency cache
                   (``path_latencies(..., incremental=True)``) and the
                   prune sweep's affected-path lookups
  KResilient     — k-resilience constraint (loss cases over servers or
                   fault domains); consumed by
                   ``LatencyEngine.resilient_path_latencies`` /
                   ``is_resilient_feasible`` and the greedy gate
                   (``replicate_workload(resilience=...)``)
"""
from repro.engine.engine import DevicePaths, LatencyEngine, RawScheme
from repro.engine.incremental import IncrementalEval, PathIndex
from repro.engine.resilience import (
    KResilient,
    case_word_mask,
    failover_shard,
    resolve_resilience,
)
from repro.engine.sharding import round_up_rows
from repro.engine.packed import PackedScheme, pack_bool_mask, unpack_words
from repro.engine.routing import (
    POLICIES,
    HomeFirst,
    NearestCopy,
    NearestCopyDP,
    QueueAware,
    RoutingPolicy,
    nearest_copy_dp,
    resolve_policy,
)
from repro.engine.streaming import (
    TRANSFER,
    PathStream,
    StreamStats,
    double_buffer,
    to_device,
)
from repro.engine.backends import BACKENDS

__all__ = [
    "PathStream",
    "StreamStats",
    "LatencyEngine",
    "DevicePaths",
    "RawScheme",
    "PackedScheme",
    "pack_bool_mask",
    "unpack_words",
    "TRANSFER",
    "to_device",
    "double_buffer",
    "BACKENDS",
    "POLICIES",
    "RoutingPolicy",
    "HomeFirst",
    "NearestCopy",
    "NearestCopyDP",
    "QueueAware",
    "nearest_copy_dp",
    "resolve_policy",
    "PathIndex",
    "IncrementalEval",
    "round_up_rows",
    "KResilient",
    "case_word_mask",
    "failover_shard",
    "resolve_resilience",
]
