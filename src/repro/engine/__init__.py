"""Unified device-resident latency-evaluation engine.

One backend-dispatched implementation of the paper's hot primitive —
h(p, r, rho), the distributed-traversal count of a path under a
replication scheme (Eqns 1-3) — shared by the greedy UPDATE driver, the
exact reference, the baselines, the distsys executor, the workload
analyzer, and every benchmark.

  LatencyEngine  — path_latencies / query_latencies / is_feasible /
                   margin_costs behind "reference" | "jnp" | "pallas"
  PackedScheme   — the device-resident packed uint32 bitmask state
  TRANSFER       — host<->device transfer accounting (perf benchmarks)
"""
from repro.engine.engine import DevicePaths, LatencyEngine
from repro.engine.packed import PackedScheme, pack_bool_mask, unpack_words
from repro.engine.streaming import TRANSFER, to_device
from repro.engine.backends import BACKENDS

__all__ = [
    "LatencyEngine",
    "DevicePaths",
    "PackedScheme",
    "pack_bool_mask",
    "unpack_words",
    "TRANSFER",
    "to_device",
    "BACKENDS",
]
