"""repro: latency-bound replication for distributed queries (Ng, Le,
Serafini 2022) as a first-class placement layer of a multi-pod JAX
training/inference framework.

Subpackages:
  core      — the paper's algorithms (causal paths, greedy replication,
              latency-robustness, baselines, NP-hardness gadget, §5.4)
  graph     — CSR storage, generators, partitioners, neighbor sampling
  workload  — causal-access-path analyzers per query family
  distsys   — simulated cluster, executor + RPC latency model, faults,
              checkpointing
  models    — transformer LM family, GNN family, MIND recsys
  optim     — AdamW, schedules, gradient compression
  data      — synthetic sharded pipelines with prefetch
  kernels   — Pallas TPU kernels (+ jnp oracles)
  configs   — the 10 assigned architectures
  launch    — meshes, dry-run, train/serve drivers, elasticity
  analysis  — roofline terms + HLO collective parsing
"""

__version__ = "1.0.0"
