"""Roofline + HLO analysis of compiled dry-run artifacts."""
from repro.analysis.hlo import CollectiveStats, collective_stats, op_census
from repro.analysis.roofline import (
    DCN_BW,
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS_BF16,
    Roofline,
    analyze,
    gnn_model_flops,
    lm_model_flops,
    lm_param_count,
    mind_model_flops,
)

__all__ = [
    "CollectiveStats",
    "collective_stats",
    "op_census",
    "Roofline",
    "analyze",
    "lm_model_flops",
    "lm_param_count",
    "gnn_model_flops",
    "mind_model_flops",
    "PEAK_FLOPS_BF16",
    "HBM_BW",
    "ICI_BW",
    "DCN_BW",
]
