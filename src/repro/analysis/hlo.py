"""HLO-text analysis: collective bytes + op census.

``cost_analysis()`` reports FLOPs and memory traffic but NOT collective
bytes, so we parse the (stable-)HLO text of the lowered/compiled module
and sum operand sizes of every communication op:

  all-gather, all-reduce, reduce-scatter, all-to-all, collective-permute
  (+ their -start async forms).

Shapes are parsed from the HLO result type of the op.  Bytes counted are
the op *output* bytes (the data each collective materializes), the
standard first-order proxy for link traffic; ring-algorithm multipliers
are applied in the roofline layer where they belong.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

# e.g.  %ag = bf16[4,128,16]{2,1,0} all-gather(%x), replica_groups=...
_COLLECTIVE_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|((?:[a-z0-9]+)\[[^\]]*\](?:\{[^}]*\})?))\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: dict
    count_by_kind: dict

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    def summary(self) -> dict:
        return {
            "total_bytes": self.total_bytes,
            **{f"{k}_bytes": v for k, v in sorted(self.bytes_by_kind.items())},
            **{f"{k}_count": v for k, v in sorted(self.count_by_kind.items())},
        }


def collective_stats(hlo_text: str) -> CollectiveStats:
    """Sum output bytes of every collective in an HLO module text.

    Works on ``lowered.as_text()`` (StableHLO is first converted by the
    caller via ``compiled.as_text()``; prefer the compiled text — it is
    post-SPMD-partitioning, so collectives are explicit).
    """
    by_bytes: dict[str, int] = defaultdict(int)
    by_count: dict[str, int] = defaultdict(int)
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        tuple_shapes, single_shape, kind = m.group(1), m.group(2), m.group(3)
        # async pairs appear as op-start + op-done; count -start only
        # (the regex strips the suffix, so dedupe by span of "-done(")
        tail = hlo_text[m.end() - 1 - len("("):m.end()]
        if "-done" in hlo_text[m.start():m.end()]:
            continue
        shape_str = tuple_shapes if tuple_shapes else single_shape
        # all-gather-start tuples carry (input, output); output dominates
        b = _shape_bytes(shape_str or "")
        by_bytes[kind] += b
        by_count[kind] += 1
    return CollectiveStats(dict(by_bytes), dict(by_count))


def op_census(hlo_text: str, ops=("fusion", "dot", "convolution", "scatter",
                                  "gather", "sort", "while")) -> dict:
    """Rough op-count census — used to spot remat recompute and redundant
    collectives when hillclimbing (duplicate op names = recompute)."""
    out = {}
    for op in ops:
        out[op] = len(re.findall(rf"\b{op}\(", hlo_text))
    return out
