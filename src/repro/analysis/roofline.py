"""Three-term roofline analysis from a compiled dry-run artifact.

Per (arch x shape x mesh) cell:

  compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
  memory term     = HLO_bytes / (chips * HBM_bw)
  collective term = collective_bytes / (chips * link_bw)

Sources: ``compiled.cost_analysis()`` for FLOPs and bytes accessed;
``compiled.as_text()`` (post-SPMD) parsed by ``repro.analysis.hlo`` for
collective bytes.  Hardware constants: TPU v5e.

IMPORTANT semantics (verified empirically in EXPERIMENTS.md §Dry-run):
the compiled artifact is the per-chip SPMD program, so cost_analysis
FLOPs/bytes and the parsed collective bytes are all PER-CHIP quantities.
The roofline divisions by `chips` above are therefore already folded in:
  t_compute = flops_per_chip / peak;  global HLO_FLOPs = flops * chips.

The dominant term is the bottleneck; MODEL_FLOPS / HLO_FLOPs measures how
much of the compiled compute is useful (catches remat and routing waste).
"""
from __future__ import annotations

import dataclasses

from repro.analysis.hlo import CollectiveStats, collective_stats, op_census

# TPU v5e per chip
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW = 50e9                   # B/s per link (~per-chip injection proxy)
DCN_BW = 25e9                   # B/s per chip across pods (conservative)


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float            # PER-CHIP program FLOPs (post-SPMD)
    hlo_bytes: float            # PER-CHIP bytes accessed
    collective_bytes: float     # PER-CHIP collective bytes
    model_flops: float          # analytic useful FLOPs (global, 6ND etc.)
    peak_memory_per_chip: float
    collectives: dict
    ops: dict

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        # collective bytes are from the per-chip SPMD program: each chip
        # moves ~these bytes through its links
        return self.collective_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_fraction(self) -> float:
        """MODEL_FLOPS / (per-chip HLO FLOPs x chips)."""
        total = self.hlo_flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """max(terms) vs the compute term: how close the step is to being
        compute-bound at peak (1.0 = compute-bound at roofline)."""
        t = max(self.t_compute, self.t_memory, self.t_collective)
        return self.t_compute / t if t > 0 else 0.0

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "hlo_flops": self.hlo_flops,
            "useful_frac": self.useful_fraction,
            "roofline_frac": self.roofline_fraction,
            "peak_mem_gb": self.peak_memory_per_chip / 2**30,
        }


def analyze(arch: str, shape: str, mesh_name: str, chips: int,
            compiled, model_flops: float) -> Roofline:
    cost = compiled.cost_analysis()
    # jax cpu/tpu cost analysis key variants
    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))
    try:
        mem = compiled.memory_analysis()
        peak = float(
            getattr(mem, "temp_size_in_bytes", 0)
            + getattr(mem, "argument_size_in_bytes", 0)
            + getattr(mem, "output_size_in_bytes", 0)
            - getattr(mem, "alias_size_in_bytes", 0))
    except Exception:
        peak = 0.0
    text = compiled.as_text()
    coll = collective_stats(text)
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_flops=flops, hlo_bytes=bytes_accessed,
        collective_bytes=float(coll.total_bytes),
        model_flops=model_flops,
        peak_memory_per_chip=peak,
        collectives=coll.summary(),
        ops=op_census(text),
    )


# ---------------------------------------------------------------------------
# Analytic MODEL_FLOPS per family (6*N*D dense / 6*N_active*D MoE; GNN and
# recsys counted from their dominant einsums).
# ---------------------------------------------------------------------------
def lm_param_count(cfg, active_only: bool = False) -> float:
    d, hd, H, KV, L, V = (cfg.d_model, cfg.hd, cfg.n_heads, cfg.n_kv_heads,
                          cfg.n_layers, cfg.vocab)
    if cfg.is_mla:
        qd = cfg.mla_nope_dim + cfg.mla_rope_dim
        attn = (d * cfg.mla_q_lora + cfg.mla_q_lora * H * qd
                if cfg.mla_q_lora else d * H * qd)
        attn += d * (cfg.mla_kv_lora + cfg.mla_rope_dim)
        attn += cfg.mla_kv_lora * H * (cfg.mla_nope_dim + cfg.mla_v_dim)
        attn += H * cfg.mla_v_dim * d
    else:
        attn = d * H * hd + 2 * d * KV * hd + H * hd * d
    if cfg.is_moe:
        n_routed = cfg.top_k if active_only else cfg.n_experts
        ffn = 3 * d * cfg.moe_d_ff * n_routed
        if cfg.n_shared_experts:
            sff = cfg.shared_d_ff or cfg.n_shared_experts * cfg.moe_d_ff
            ffn += 3 * d * sff
        moe_layers = cfg.n_layers - cfg.n_dense_layers
        body = moe_layers * (attn + ffn) + cfg.n_dense_layers * (
            attn + 3 * d * cfg.d_ff)
    else:
        body = L * (attn + 3 * d * cfg.d_ff)
    return float(body + 2 * V * d)


def lm_model_flops(cfg, tokens: int, kind: str, kv_len: int = 0) -> float:
    """6*N*D for training; 2*N*D + attention for inference steps.

    The per-head kv dim is hd for GQA and kv_lora+rope for absorbed MLA;
    sliding-window attention caps the effective kv length."""
    n_active = lm_param_count(cfg, active_only=True)
    eff_hd = (cfg.mla_kv_lora + cfg.mla_rope_dim) if cfg.is_mla else cfg.hd
    win = cfg.sliding_window or 0
    if kind == "train":
        S = kv_len or 1
        S_eff = min(S, 2 * win) if win else S  # causal avg vs window
        flops = 6.0 * n_active * tokens
        flops += 6.0 * cfg.n_layers * cfg.n_heads * eff_hd * S_eff * tokens
        return flops
    if kind == "prefill":
        S_eff = min(kv_len, 2 * win) if win else kv_len
        return (2.0 * n_active * tokens
                + 2.0 * cfg.n_layers * cfg.n_heads * eff_hd * S_eff * tokens)
    # decode: per generated token
    S_eff = min(kv_len, win) if win else kv_len
    return (2.0 * n_active * tokens
            + 4.0 * cfg.n_layers * cfg.n_heads * eff_hd * S_eff * tokens)


def gnn_model_flops(cfg, n_nodes: int, n_edges: int, kind="train") -> float:
    d = cfg.d_hidden
    if cfg.arch == "egnn":
        per_edge = 2 * (2 * d + 1) * d + 2 * d * d + 2 * d * 1
        per_node = 2 * (2 * d) * d + 2 * d * d
    elif cfg.arch == "schnet":
        per_edge = 2 * cfg.n_rbf * d + 2 * d * d + d
        per_node = 2 * d * d * 2
    elif cfg.arch == "graphsage":
        per_edge = d  # mean agg adds
        per_node = 2 * 2 * d * d
    else:  # graphcast
        per_edge = 2 * (3 * d) * d + 2 * d * d
        per_node = 2 * (2 * d) * d + 2 * d * d
    fwd = cfg.n_layers * (per_edge * n_edges + per_node * n_nodes)
    fwd += 2 * n_nodes * cfg.d_in * d + 2 * n_nodes * d * cfg.n_classes
    return float(3.0 * fwd if kind == "train" else fwd)


def mind_model_flops(cfg, batch: int, n_cand: int, kind="train") -> float:
    d = cfg.embed_dim
    route = cfg.capsule_iters * 2 * batch * cfg.n_interests * cfg.hist_len * d
    tower = 2 * batch * cfg.n_interests * (2 * d * cfg.d_hidden
                                           + cfg.d_hidden * d)
    bil = 2 * batch * cfg.hist_len * d * d
    score = 2 * batch * cfg.n_interests * n_cand * d
    fwd = route + tower + bil + score
    return float(3.0 * fwd if kind == "train" else fwd)
