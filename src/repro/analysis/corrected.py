"""Scan-corrected roofline terms (the §Roofline methodology).

XLA's HloCostAnalysis counts a while-loop body ONCE, regardless of trip
count (verified empirically: a 16-iteration scan of 128^3 matmuls reports
one matmul).  Every layer-scanned model therefore under-reports FLOPs,
bytes, and (static-text) collective bytes by up to the layer count.  We
correct with an **unroll-delta** measurement:

  f1 = terms(L'=2 layers, scan unroll=1)   -> base + 1 x layer
  f2 = terms(L'=2 layers, scan unroll=2)   -> base + 2 x layer  (no while)
  layer = f2 - f1;  base = f1 - layer
  corrected(L) = base + L_scan x layer  (+ inner-loop residuals)

Inner loops (the MoE dispatch map, blockwise-attention map, chunked-loss
map) are *also* counted once inside each layer/base instance; their
residuals are added from standalone compiles of the single-chunk op:

  + L x (n_moe_chunks - 1)   x moe_chunk_terms
  + L x (n_attn_blocks - 1)  x attn_block_terms      (blockwise cells)
  +     (n_loss_chunks - 1)  x loss_chunk_terms      (train cells)

All compiles run at the cell's true global shapes (2-layer configs are
cheap), so no batch/seq extrapolation is involved.  MIND has no scans and
needs no correction.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis.hlo import collective_stats


@dataclasses.dataclass
class Terms:
    flops: float = 0.0
    bytes: float = 0.0
    coll: float = 0.0

    def __add__(self, o):
        return Terms(self.flops + o.flops, self.bytes + o.bytes,
                     self.coll + o.coll)

    def __sub__(self, o):
        return Terms(self.flops - o.flops, self.bytes - o.bytes,
                     self.coll - o.coll)

    def __mul__(self, k):
        return Terms(self.flops * k, self.bytes * k, self.coll * k)

    __rmul__ = __mul__

    def clamp(self):
        return Terms(max(self.flops, 0.0), max(self.bytes, 0.0),
                     max(self.coll, 0.0))


def _named(mesh, tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))


def measure(step, args, in_specs, out_specs, mesh) -> Terms:
    with jax.set_mesh(mesh):
        compiled = jax.jit(
            step, in_shardings=_named(mesh, in_specs),
            out_shardings=(None if out_specs is None
                           else _named(mesh, out_specs)),
        ).lower(*args).compile()
    cost = compiled.cost_analysis()
    coll = collective_stats(compiled.as_text()).total_bytes
    return Terms(float(cost.get("flops", 0.0)),
                 float(cost.get("bytes accessed", 0.0)), float(coll))


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------
def _lm_block(cfg) -> int:
    """Effective remat-block size of the real config (the scan iterates
    blocks, so the unroll-delta must operate at block granularity)."""
    L_scan = cfg.n_moe_layers if cfg.is_moe else cfg.n_layers
    return max(k for k in range(1, min(cfg.remat_block, L_scan) + 1)
               if L_scan % k == 0)


def _lm_small_cfg(cfg, unroll: int):
    bk = _lm_block(cfg)
    L_small = cfg.n_dense_layers + 2 * bk if cfg.is_moe else 2 * bk
    return dataclasses.replace(cfg, n_layers=L_small, scan_unroll=unroll)


def _lm_cell_measured(bundle, shape_id, cfg_small, multi_pod):
    """Measure the cell's step with a reduced-layer config."""
    from repro.configs import lm_family as F
    from repro.models import transformer as T

    cell = bundle.cells[shape_id]
    saved = bundle.config
    try:
        bundle.config = cfg_small
        args = bundle.abstract_args(shape_id, multi_pod)
        in_s, out_s = bundle.shardings(shape_id, multi_pod)
        step = bundle.step_fn(shape_id, multi_pod)
    finally:
        bundle.config = saved
    return args, in_s, out_s, step


def _moe_chunk_terms(cfg_act, mesh, with_bwd: bool = True) -> Terms:
    """Standalone single-dispatch-chunk measurement (fwd [+ bwd])."""
    from repro.models import transformer as T

    d = cfg_act.d_model
    chunk = cfg_act.moe_chunk
    lp_shapes = {
        "router": ((d, cfg_act.n_experts), jnp.float32),
        "we1": ((cfg_act.n_experts, d, cfg_act.moe_d_ff), cfg_act.dtype),
        "we3": ((cfg_act.n_experts, d, cfg_act.moe_d_ff), cfg_act.dtype),
        "we2": ((cfg_act.n_experts, cfg_act.moe_d_ff, d), cfg_act.dtype),
    }
    if cfg_act.n_shared_experts:
        sff = cfg_act.shared_d_ff or cfg_act.n_shared_experts * cfg_act.moe_d_ff
        lp_shapes.update({"ws1": ((d, sff), cfg_act.dtype),
                          "ws3": ((d, sff), cfg_act.dtype),
                          "ws2": ((sff, d), cfg_act.dtype)})
    lp_abs = {k: jax.ShapeDtypeStruct(s, dt) for k, (s, dt) in
              lp_shapes.items()}
    x_abs = jax.ShapeDtypeStruct((chunk, d), cfg_act.dtype)
    dp = cfg_act.act_dp or None
    tp = cfg_act.act_tp
    lp_specs = {k: P(*((tp,) + (None,) * (len(s) - 1))
                     if k.startswith("we") else (None,) * len(s))
                for k, (s, dt) in lp_shapes.items()}
    in_specs = (x_abs_spec := P(dp, None), lp_specs)

    def op(x, lp):
        from repro.models.transformer import _moe_ffn_chunk

        y = _moe_ffn_chunk(x, lp, cfg_act)
        return jnp.sum(y.astype(jnp.float32))

    fn = jax.value_and_grad(op) if with_bwd else op
    return measure(fn, (x_abs, lp_abs), in_specs, None, mesh)


def _attn_block_terms(cfg_act, B, S, mesh, with_bwd: bool = True) -> Terms:
    """Standalone one-q-block attention measurement (fwd [+ bwd])."""
    from repro.models import transformer as T

    bq = cfg_act.attn_block_q
    H, KV, hd = cfg_act.n_heads, cfg_act.n_kv_heads, cfg_act.hd
    if cfg_act.is_mla:
        KV_eff, hd_eff = 1, cfg_act.mla_kv_lora
        q_abs = jax.ShapeDtypeStruct((B, bq, H, hd_eff), cfg_act.dtype)
        k_abs = jax.ShapeDtypeStruct((B, S, hd_eff), cfg_act.dtype)

        def op(q, k):
            s = jnp.einsum("bqhl,btl->bhqt", q, k,
                           preferred_element_type=jnp.float32)
            p = jax.nn.softmax(s, -1).astype(k.dtype)
            o = jnp.einsum("bhqt,btl->bqhl", p, k,
                           preferred_element_type=jnp.float32)
            return jnp.sum(o)
    else:
        q_abs = jax.ShapeDtypeStruct((B, bq, KV, H // KV, hd), cfg_act.dtype)
        k_abs = jax.ShapeDtypeStruct((B, S, KV, hd), cfg_act.dtype)

        def op(q, k):
            s = jnp.einsum("bqkgh,btkh->bkgqt", q, k,
                           preferred_element_type=jnp.float32)
            p = jax.nn.softmax(s, -1).astype(k.dtype)
            o = jnp.einsum("bkgqt,btkh->bqkgh", p, k,
                           preferred_element_type=jnp.float32)
            return jnp.sum(o)

    dp = cfg_act.act_dp or None
    # in-program q blocks are sequence-parallel over tp; mirror that here
    # or the standalone block over-counts bytes by ~tp_size
    tp = cfg_act.act_tp if (cfg_act.act_dp and
                            bq % cfg_act.tp_size == 0) else None
    q_spec = (P(dp, tp, None, None) if cfg_act.is_mla
              else P(dp, tp, None, None, None))
    k_spec = (P(dp, None, None) if cfg_act.is_mla
              else P(dp, None, None, None))
    grad_op = jax.value_and_grad(op, argnums=(0, 1)) if with_bwd else op
    return measure(grad_op, (q_abs, k_abs), (q_spec, k_spec), None, mesh)


def _loss_chunk_terms(cfg_act, mesh) -> Terms:
    d, V = cfg_act.d_model, cfg_act.vocab
    ck = cfg_act.loss_chunk
    x_abs = jax.ShapeDtypeStruct((ck, d), cfg_act.dtype)
    w_abs = jax.ShapeDtypeStruct((d, V), cfg_act.dtype)
    l_abs = jax.ShapeDtypeStruct((ck,), jnp.int32)
    dp = cfg_act.act_dp or None
    tp = cfg_act.act_tp if cfg_act.act_dp else None

    def op(x, w, labels):
        from repro.models.transformer import _ce_terms

        logits = (x @ w).astype(jnp.float32)
        nll, cnt = _ce_terms(logits, labels)
        return nll

    grad_op = jax.value_and_grad(op, argnums=(0, 1))
    return measure(grad_op, (x_abs, w_abs, l_abs),
                   (P(dp, None), P(None, tp), P(dp)), None, mesh)


def corrected_lm_cell(arch: str, shape_id: str, multi_pod=False) -> dict:
    from repro.configs import get_arch
    from repro.configs.lm_family import _act_cfg
    from repro.launch.mesh import make_production_mesh

    bundle = get_arch(arch)
    cfg = bundle.config
    cell = bundle.cells[shape_id]
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg_act = _act_cfg(bundle, shape_id, multi_pod)

    results = {}
    for u in (1, 2):
        cfg_small = _lm_small_cfg(cfg, u)
        args, in_s, out_s, step = _lm_cell_measured(
            bundle, shape_id, cfg_small, multi_pod)
        results[u] = measure(step, args, in_s, out_s, mesh)
    block = (results[2] - results[1]).clamp()   # one remat block (bk layers)
    base = (results[1] - block).clamp()
    bk = _lm_block(cfg)
    L_scan = cfg.n_moe_layers if cfg.is_moe else cfg.n_layers
    n_blocks_scan = L_scan // bk
    layer = block * (1.0 / bk)
    total = base + n_blocks_scan * block

    B, S = cell.meta["batch"], cell.meta["seq"]
    notes = []
    # inner-loop residuals
    with_bwd = cell.kind == "train"
    if cell.kind in ("train", "prefill") and cfg.is_moe and cfg.moe_chunk:
        # seq-dim chunking: tokens per chunk = B * s_ck
        s_ck = max(cfg.moe_chunk // B, 1)
        n_chunks = max(S // s_ck, 1) if S % s_ck == 0 else 1
        if n_chunks > 1:
            ct = _moe_chunk_terms(dataclasses.replace(
                cfg_act, n_layers=2), mesh, with_bwd)
            total = total + (L_scan * (n_chunks - 1)) * ct
            notes.append(f"moe_chunks={n_chunks}")
    if cell.kind in ("train", "prefill") and S > cfg.blockwise_from:
        n_blocks = S // cfg.attn_block_q
        if n_blocks > 1:
            at = _attn_block_terms(cfg_act, B, S, mesh, with_bwd)
            total = total + (cfg.n_layers * (n_blocks - 1)) * at
            notes.append(f"attn_blocks={n_blocks}")
    if cell.kind == "train" and cfg.loss_chunk:
        n_lc = max((B * S) // cfg.loss_chunk, 1)
        if n_lc > 1:
            lt = _loss_chunk_terms(cfg_act, mesh)
            total = total + (n_lc - 1) * lt
            notes.append(f"loss_chunks={n_lc}")
    return {"flops": total.flops, "bytes": total.bytes,
            "coll_bytes": total.coll, "notes": ",".join(notes),
            "layer_flops": layer.flops, "base_flops": base.flops}


def corrected_gnn_cell(arch: str, shape_id: str, multi_pod=False) -> dict:
    from repro.configs import get_arch
    from repro.launch.mesh import make_production_mesh

    bundle = get_arch(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = bundle.config
    results = {}
    for u in (1, 2):
        saved = bundle.config
        try:
            bundle.config = dataclasses.replace(cfg, n_layers=2,
                                                scan_unroll=u)
            args = bundle.abstract_args(shape_id, multi_pod)
            in_s, out_s = bundle.shardings(shape_id, multi_pod)
            step = bundle.step_fn(shape_id, multi_pod)
        finally:
            bundle.config = saved
        results[u] = measure(step, args, in_s, out_s, mesh)
    layer = (results[2] - results[1]).clamp()
    base = (results[1] - layer).clamp()
    total = base + cfg.n_layers * layer
    return {"flops": total.flops, "bytes": total.bytes,
            "coll_bytes": total.coll, "notes": "",
            "layer_flops": layer.flops, "base_flops": base.flops}


def corrected_cell(arch: str, shape_id: str, multi_pod=False) -> dict:
    from repro.configs import get_arch

    bundle = get_arch(arch)
    if bundle.family == "lm":
        return corrected_lm_cell(arch, shape_id, multi_pod)
    if bundle.family == "gnn":
        return corrected_gnn_cell(arch, shape_id, multi_pod)
    return None  # recsys: no scans; raw terms are already exact
