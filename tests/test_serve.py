"""Serving layer: simulator queueing, drift deltas, adaptive controller."""
import numpy as np
import pytest

from repro.core import (
    is_latency_feasible,
    replicate_delta,
    replicate_workload,
)
from repro.core.paths import PathSet
from repro.distsys import Cluster, LatencyModel, Router, execute_workload
from repro.engine import LatencyEngine
from repro.serve import (
    AdaptiveController,
    ControllerConfig,
    drift_stream,
    hotspot_phases,
    path_delta,
    simulate,
)
from tests.conftest import random_workload


# ---------------------------------------------------------------------------
# fixtures
# ---------------------------------------------------------------------------
def synthetic_phases(n_phases=2, n_obj=300, n_srv=5, queries=120, seed=0):
    """Small drifting workload: 3-hop chains rooted in a rotating hot set."""

    def for_phase(k, rng):
        def paths_fn(root):
            a = int(rng.integers(0, n_obj))
            b = int(rng.integers(0, n_obj))
            return [[int(root) % n_obj, a, b]]

        return paths_fn

    return hotspot_phases(
        for_phase,
        np.arange(n_obj),
        n_phases=n_phases,
        queries_per_phase=queries,
        hot_frac=0.08,
        hot_prob=0.9,
        seed=seed,
    )


# ---------------------------------------------------------------------------
# simulator
# ---------------------------------------------------------------------------
def test_sim_lowload_matches_closed_form(rng):
    ps, shard = random_workload(rng, n_paths=300, n_queries=200)
    scheme, _ = replicate_workload(ps, shard, 5, t=1)
    model = LatencyModel()
    sim = simulate(Cluster(scheme), ps, rate_qps=200, model=model, seed=2)
    closed = execute_workload(Cluster(scheme), ps, model, seed=2)
    assert abs(sim.mean_us - closed.mean_us) / closed.mean_us < 0.10


def test_sim_p99_grows_with_offered_load(rng):
    ps, shard = random_workload(rng, n_paths=400, n_queries=250)
    scheme, _ = replicate_workload(ps, shard, 5, t=2)
    cl = Cluster(scheme)
    lo = simulate(cl, ps, rate_qps=500, seed=3, concurrency=2)
    hi = simulate(cl, ps, rate_qps=500_000, seed=3, concurrency=2)
    assert hi.p99_us > lo.p99_us * 1.5
    assert hi.utilization().max() > lo.utilization().max()
    assert hi.queue_wait_us > lo.queue_wait_us


def test_sim_routing_policies_and_failure(rng):
    ps, shard = random_workload(rng, n_paths=200, n_queries=120)
    scheme, _ = replicate_workload(ps, shard, 5, t=0)
    cl = Cluster(scheme)
    for policy in ("replica_lb", "hedged"):
        rep = simulate(
            cl, ps, rate_qps=5_000, router=Router(scheme, policy), seed=4
        )
        assert np.isfinite(rep.latency_us).all()
        assert len(rep.latency_us) == ps.n_queries
    # all servers of some object dead -> failed queries surface, no crash
    cl.fail_server(0)
    cl.fail_server(1)
    rep = simulate(cl, ps, rate_qps=5_000, seed=4)
    assert np.isfinite(rep.latency_us).all()


# ---------------------------------------------------------------------------
# drift
# ---------------------------------------------------------------------------
def test_drift_phases_produce_path_deltas():
    phases = synthetic_phases(n_phases=3, seed=1)
    deltas = list(drift_stream(phases))
    assert deltas[0].added.n_paths == phases[0].pathset.n_paths
    for d in deltas[1:]:
        # the hotspot moved: a substantial share of paths is new
        assert d.added.n_paths > 0
        assert d.n_removed > 0
    # hot root sets rotate between phases
    assert not np.intersect1d(
        phases[0].hot_roots, phases[1].hot_roots
    ).size == len(phases[0].hot_roots)


def test_path_delta_identity_and_disjoint():
    ps = PathSet.from_lists([[0, 1], [2, 3]])
    added, removed = path_delta(ps, ps)
    assert added.n_paths == 0 and removed == 0
    other = PathSet.from_lists([[4, 5]])
    added, removed = path_delta(ps, other)
    assert added.n_paths == 1 and removed == 2


# ---------------------------------------------------------------------------
# controller
# ---------------------------------------------------------------------------
def test_controller_converges_after_drift():
    phases = synthetic_phases(n_phases=2, queries=150, seed=5)
    n_obj, n_srv = 300, 5
    rng = np.random.default_rng(0)
    shard = rng.integers(0, n_srv, n_obj).astype(np.int32)
    scheme, _, eng = replicate_workload(
        phases[0].pathset, shard, n_srv, t=1, return_engine=True
    )
    assert is_latency_feasible(phases[0].pathset, scheme, 1)
    cluster = Cluster(scheme)
    ctl = AdaptiveController(
        cluster,
        ControllerConfig(t=1, window=300, min_queries=20),
        engine=eng,
    )
    # the drifted phase violates the bound; the controller repairs it
    drifted = phases[1].pathset
    assert not is_latency_feasible(drifted, scheme, 1)
    report = ctl.observe(drifted)
    assert report is not None and report.trigger == "feasibility"
    assert report.replicas_added > 0
    assert report.feasible_after
    assert is_latency_feasible(drifted, cluster.scheme, 1)
    # engine (packed) and cluster scheme stayed in sync
    assert np.array_equal(eng.host_mask(), cluster.scheme.mask)
    # quiet stream afterwards: no further adaptation
    assert ctl.observe(drifted) is None


def test_incremental_matches_rebuild_on_aligned_batches():
    """replicate_delta == tail batches of one from-scratch greedy run."""
    rng = np.random.default_rng(7)
    n_obj, n_srv, bs = 90, 4, 64
    mk = lambda n: [
        rng.integers(0, n_obj, rng.integers(2, 6)).tolist() for _ in range(n)
    ]
    a, b = mk(bs), mk(40)
    shard = rng.integers(0, n_srv, n_obj).astype(np.int32)
    psa = PathSet.from_lists(a, max_len=6)
    psb = PathSet.from_lists(b, max_len=6)
    psab = PathSet.from_lists(a + b, max_len=6)

    _, _, eng = replicate_workload(
        psa, shard, n_srv, t=1, prune=False, batch_size=bs,
        return_engine=True,
    )
    stats, (objs, srvs) = replicate_delta(
        psb, eng, t=1, prune=False, batch_size=bs
    )
    full, _ = replicate_workload(
        psab, shard, n_srv, t=1, prune=False, batch_size=bs
    )
    assert np.array_equal(eng.host_mask(), full.mask)
    assert is_latency_feasible(psab, eng.to_scheme(), 1)
    # the returned delta is exactly the new copies
    delta_mask = np.zeros_like(full.mask)
    delta_mask[objs, srvs] = True
    before = replicate_workload(
        psa, shard, n_srv, t=1, prune=False, batch_size=bs
    )[0].mask
    assert np.array_equal(full.mask & ~before, delta_mask)


def test_controller_p99_trigger_rearms_on_fresh_latencies():
    """A queueing-only p99 breach must not re-fire no-op repairs forever."""
    n_obj, n_srv = 40, 3
    rng = np.random.default_rng(11)
    shard = rng.integers(0, n_srv, n_obj).astype(np.int32)
    ps = PathSet.from_lists([[i, (i + 1) % n_obj] for i in range(n_obj)])
    scheme, _, eng = replicate_workload(
        ps, shard, n_srv, t=2, return_engine=True
    )
    assert is_latency_feasible(ps, scheme, 2)  # no feasibility violation
    ctl = AdaptiveController(
        Cluster(scheme),
        ControllerConfig(t=2, window=200, min_queries=10, p99_slo_us=100.0),
        engine=eng,
    )
    slow = np.full(ps.n_queries, 500.0)  # queueing pushed p99 over the SLO
    report = ctl.observe(ps, latency_us=slow)
    assert report is not None and report.trigger == "p99_slo"
    # stale pre-repair latencies were dropped: the same feasible window
    # must not re-trigger until fresh measurements breach the SLO again
    assert ctl.observe(ps) is None
    fast = np.full(ps.n_queries, 50.0)
    assert ctl.observe(ps, latency_us=fast) is None


def test_controller_eviction_respects_capacity():
    from repro.serve import evict_cold_replicas
    from repro.core import ReshardingMap, ReplicationScheme

    shard = np.zeros(6, np.int32)
    scheme = ReplicationScheme.from_sharding(shard, 3)
    scheme.mask[:, 1] = True  # replicas of everything at server 1
    cluster = Cluster(scheme)
    rmap = ReshardingMap({}, {(0, 1): 1})  # object 0's replica is RM-pinned
    n, b = evict_cold_replicas(
        cluster, rmap, active_objects=np.asarray([1]), capacity=2.0
    )
    load = scheme.storage_per_server()
    assert load[1] <= 2.0
    assert n > 0 and b > 0
    assert scheme.mask[0, 1]  # RM-referenced replica survived
    assert scheme.mask[1, 1]  # window-active replica survived
    assert scheme.mask[:, 0].all()  # originals untouched


def _square_wave_evictions(min_streak: int, flips: int = 6) -> int:
    """Harness: two replica groups whose hotness alternates per window.

    Mirrors the controller's eviction loop (streak update -> evict ->
    re-add what the returning hot phase would force back), counting
    evictions across ``flips`` windows of a square-wave hotspot.
    """
    from repro.core import ReshardingMap, ReplicationScheme
    from repro.serve import evict_cold_replicas
    from repro.serve.controller import AdaptiveController, ControllerConfig

    shard = np.zeros(4, np.int32)
    scheme = ReplicationScheme.from_sharding(shard, 2)
    scheme.mask[:, 1] = True  # group A = {0, 1}, group B = {2, 3} at s1
    cluster = Cluster(scheme)
    ctl = AdaptiveController(
        cluster, ControllerConfig(t=0, demote_after=min_streak)
    )
    rmap = ReshardingMap({}, {})
    groups = (np.asarray([0, 1]), np.asarray([2, 3]))
    total = 0
    for k in range(flips):
        active = groups[k % 2]
        scheme.mask[active, 1] = True  # the hot phase re-adds its replicas
        ctl._update_cold_streaks(active)
        n, _ = evict_cold_replicas(
            cluster, rmap, active, capacity=3.0,
            cold_streak=ctl._cold_streak, min_streak=min_streak,
        )
        total += n
    return total


def test_eviction_hysteresis_square_wave():
    """K consecutive cold windows gate demotion: an oscillating hotspot
    must not add/evict-thrash the off-phase replicas."""
    # K=1 (no hysteresis): every flip evicts the off-phase group, which the
    # returning phase immediately re-adds — sustained thrash
    assert _square_wave_evictions(min_streak=1) >= 5
    # K=2: a group is cold for only one window before its phase returns
    # and resets the streak -> zero evictions across the whole wave
    assert _square_wave_evictions(min_streak=2) == 0


def test_eviction_hysteresis_fires_on_sustained_cold():
    """Hysteresis delays demotion; it must not block it: a replica cold
    for K consecutive windows is evicted."""
    from repro.core import ReshardingMap, ReplicationScheme
    from repro.serve import evict_cold_replicas
    from repro.serve.controller import AdaptiveController, ControllerConfig

    shard = np.zeros(3, np.int32)
    scheme = ReplicationScheme.from_sharding(shard, 2)
    scheme.mask[:, 1] = True
    cluster = Cluster(scheme)
    ctl = AdaptiveController(
        cluster, ControllerConfig(t=0, demote_after=2)
    )
    rmap = ReshardingMap({}, {})
    active = np.asarray([0])  # objects 1, 2 stay cold throughout
    counts = []
    for _ in range(3):
        ctl._update_cold_streaks(active)
        n, _ = evict_cold_replicas(
            cluster, rmap, active, capacity=1.0,
            cold_streak=ctl._cold_streak, min_streak=2,
        )
        counts.append(n)
    assert counts[0] == 0        # first cold window: streak 1 < 2
    assert counts[1] > 0         # second consecutive: demotion fires
    assert scheme.storage_per_server()[1] <= 1.0


def test_controller_demote_after_wiring():
    """ControllerConfig.demote_after gates the adapt-path eviction.

    Server 1 starts over its capacity, so every repair candidate is
    capacity-blocked until the eviction pass frees cold replicas — the
    repair-fails -> demote -> retry-succeeds loop.  ``demote_after``
    decides on which observation the demotion (and hence the successful
    repair) happens.
    """
    from repro.core import ReplicationScheme

    def run(demote_after):
        shard = np.asarray([0, 0, 0, 0, 0, 1], np.int32)
        scheme = ReplicationScheme.from_sharding(shard, 2)
        scheme.mask[:, 1] = True  # pre-existing (non-RM) replicas at s1
        ctl = AdaptiveController(
            Cluster(scheme),
            ControllerConfig(
                # s0 has room for the repair; s1 starts over capacity
                t=0, min_queries=1, capacity=np.asarray([6.0, 4.0]),
                demote_after=demote_after,
            ),
        )
        # [0, 5] crosses s0 -> s1: violates t=0; the repair (replicate 5
        # to s0) stays blocked while s1 is over capacity; cold replicas
        # {1, 2, 3, 4} at s1 are the demotion candidates
        reports = [
            ctl.observe(PathSet.from_lists([[0, 5]])) for _ in range(3)
        ]
        return reports

    r = run(1)  # immediate demotion (pre-hysteresis behavior)
    assert r[0].replicas_evicted > 0
    assert r[1].feasible_after and r[1].replicas_added > 0
    r = run(2)  # demotion waits for the second consecutive cold check
    assert r[0].replicas_evicted == 0 and not r[0].feasible_after
    assert r[1].replicas_evicted > 0
    assert r[2].feasible_after and r[2].replicas_added > 0


# ---------------------------------------------------------------------------
# closed-loop client pool (PR 4)
# ---------------------------------------------------------------------------
def _closed_loop_setup(rng):
    from repro.core import ReplicationScheme

    ps, shard = random_workload(
        rng, n_obj=200, n_srv=4, n_paths=400, n_queries=200
    )
    return ps, ReplicationScheme.from_sharding(shard, 4)


def test_closed_loop_serves_all_and_reports(rng):
    ps, scheme = _closed_loop_setup(rng)
    rep = simulate(Cluster(scheme), ps, clients=8, think_time_us=100.0,
                   seed=1, concurrency=4)
    assert rep.closed_loop and rep.n_clients == 8
    assert len(rep.latency_us) == ps.n_queries
    assert (rep.latency_us > 0).all()
    s = rep.summary()
    assert s["mode"] == "closed_loop"
    assert s["n_clients"] == 8
    assert s["saturation_qps"] == rep.achieved_qps > 0


def test_closed_loop_throughput_saturates(rng):
    """More clients raise throughput until service capacity saturates;
    past the knee extra clients only deepen queues (ROADMAP open item)."""
    ps, scheme = _closed_loop_setup(rng)

    def qps(n):
        return simulate(
            Cluster(scheme), ps, clients=n, seed=1, concurrency=4
        ).achieved_qps

    q4, q16, q64, q128 = qps(4), qps(16), qps(64), qps(128)
    assert q16 > 1.5 * q4          # below the knee: near-linear scaling
    assert q128 < 1.15 * q64       # past the knee: saturation plateau
    # at saturation the bottleneck server is essentially always busy
    rep = simulate(Cluster(scheme), ps, clients=64, seed=1, concurrency=4)
    assert float(rep.utilization().max()) > 0.9


def test_closed_loop_think_time_throttles(rng):
    ps, scheme = _closed_loop_setup(rng)
    fast = simulate(Cluster(scheme), ps, clients=4, seed=1, concurrency=4)
    slow = simulate(Cluster(scheme), ps, clients=4, think_time_us=500.0,
                    seed=1, concurrency=4)
    assert slow.achieved_qps < 0.5 * fast.achieved_qps
    # thinking clients leave the queues emptier: lower tail
    assert slow.p99_us <= fast.p99_us


# ---------------------------------------------------------------------------
# reroute_every x closed loop, hop feedback, and SimReport edge cases (PR 5)
# ---------------------------------------------------------------------------
def test_reroute_closed_loop_counts_exactly_and_orphans_nothing(rng):
    """Mid-run re-picks with a closed-loop client pool.

    Every query arrives exactly once (after its client's think time), so
    with ``reroute_every=K`` the rebuild fires exactly ``nq // K`` times;
    think-time jobs whose arrive events were scheduled before a rebuild
    must still find their (rebuilt) trees — nothing is orphaned and every
    query completes.
    """
    ps, scheme = _closed_loop_setup(rng)
    nq = ps.n_queries
    for k in (7, 64):
        rep = simulate(
            Cluster(scheme.copy()), ps, clients=6, think_time_us=50.0,
            seed=3, concurrency=4, policy="queue_aware", reroute_every=k,
        )
        assert rep.reroutes == nq // k
        assert len(rep.latency_us) == nq          # nothing orphaned
        assert (rep.latency_us > 0).all()
        assert rep.closed_loop and rep.policy == "queue_aware"


def test_saturation_qps_none_when_no_jobs(rng):
    """clients=0 / zero-query runs must report None, not 1/0 garbage."""
    ps, scheme = _closed_loop_setup(rng)
    rep = simulate(Cluster(scheme.copy()), ps, clients=0)
    s = rep.summary()
    assert s["saturation_qps"] is None
    assert s["p99_us"] is None and s["mean_us"] is None
    assert s["completed_queries"] == 0
    assert rep.achieved_qps == 0.0

    rep2 = simulate(
        Cluster(scheme.copy()), PathSet.from_lists([]), clients=4
    )
    assert rep2.summary()["saturation_qps"] is None
    # open-loop zero-query run keeps reporting its offered rate
    rep3 = simulate(Cluster(scheme.copy()), PathSet.from_lists([]))
    assert rep3.summary()["saturation_qps"] is None if rep3.closed_loop else True
    assert rep3.summary()["p99_us"] is None


def test_hop_feedback_contract(rng):
    """Per-hop load feedback: live picks, validation, and completion."""
    ps, shard = random_workload(
        rng, n_obj=150, n_srv=5, n_paths=250, n_queries=120
    )
    from repro.core import ReplicationScheme

    mask = np.zeros((150, 5), bool)
    mask[np.arange(150), shard] = True
    mask |= rng.random((150, 5)) < 0.3
    scheme = ReplicationScheme(mask, shard)

    rep = simulate(
        Cluster(scheme.copy()), ps, rate_qps=3e4, seed=2,
        policy="queue_aware", hop_feedback=True,
    )
    assert rep.hop_feedback
    assert rep.reroutes > 0                      # load-ranked remote picks
    assert len(rep.latency_us) == ps.n_queries
    assert (rep.latency_us > 0).all()
    assert rep.summary()["hop_feedback"] is True

    with pytest.raises(ValueError):
        simulate(Cluster(scheme.copy()), ps, policy="queue_aware",
                 hop_feedback=True, reroute_every=4)
    with pytest.raises(ValueError):
        simulate(Cluster(scheme.copy()), ps, policy="nearest_copy",
                 hop_feedback=True)
    with pytest.raises(ValueError):
        simulate(Cluster(scheme.copy()), ps, policy="queue_aware",
                 hop_feedback=True,
                 router=Router(scheme, "replica_lb"))


def test_hop_feedback_closed_loop_serves_all(rng):
    ps, scheme = _closed_loop_setup(rng)
    rep = simulate(
        Cluster(scheme.copy()), ps, clients=6, think_time_us=25.0, seed=4,
        concurrency=4, policy="queue_aware", hop_feedback=True,
    )
    assert len(rep.latency_us) == ps.n_queries
    assert rep.summary()["saturation_qps"] is not None


def test_controller_repairs_under_score_policy():
    """score_policy threads into replicate_delta: the repair prices its
    candidates under the same routed walk the trigger scored, and the
    post-repair windows are feasible under that policy."""
    phases = synthetic_phases(n_phases=2, queries=150, seed=5)
    ps0 = phases[0].pathset
    n_obj, n_srv, t = 300, 5, 1
    shard = (np.arange(n_obj) % n_srv).astype(np.int32)
    scheme, _ = replicate_workload(ps0, shard, n_srv, t=t)
    cluster = Cluster(scheme)
    ctl = AdaptiveController(
        cluster,
        ControllerConfig(t=t, window=600, min_queries=32,
                         score_policy="nearest_copy"),
    )
    report = None
    drifted = phases[1].pathset
    for lo in range(0, drifted.n_queries, 50):
        batch = drifted.select_queries(lo, min(lo + 50, drifted.n_queries))
        r = ctl.observe(batch)
        report = r or report
    assert report is not None, "drifted phase should have triggered"
    assert report.feasible_after
    eng = LatencyEngine(cluster.scheme)
    # every windowed entry is feasible under the scoring policy
    for w in ctl._tenants.values():
        for e in w.entries:
            lats = eng.path_latencies(e.pathset, policy="nearest_copy")
            assert (lats <= e.path_budgets).all()
