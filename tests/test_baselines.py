"""Baselines: single-site oracle (Fig 2d) + dangling edges (Table 3)."""
import numpy as np

from repro.core import (
    dangling_edge_replication,
    query_latencies,
    replicate_workload_exact,
    single_site_oracle,
)
from repro.graph import hash_partition, snb_like
from repro.workload import snb_workload_materialized
from tests.conftest import random_workload


def test_oracle_achieves_single_site(rng):
    ps, shard = random_workload(rng)
    scheme = single_site_oracle(ps, shard, 5)
    assert query_latencies(ps, scheme).max(initial=0) == 0


def test_oracle_more_expensive_than_relaxed_greedy(rng):
    """Fig 1/6: t=0 (single-site) costs more than a relaxed bound."""
    ps, shard = random_workload(rng, n_paths=300)
    oracle = single_site_oracle(ps, shard, 5)
    relaxed, _ = replicate_workload_exact(ps, shard, 5, t=2)
    assert oracle.replica_count() > relaxed.replica_count()


def test_greedy_t0_no_worse_than_2x_oracle(rng):
    """Greedy at t=0 is within a small factor of the oracle (the oracle
    replicates exactly the accessed objects; greedy adds robustness
    copies)."""
    ps, shard = random_workload(rng, n_paths=150)
    oracle = single_site_oracle(ps, shard, 5)
    greedy, _ = replicate_workload_exact(ps, shard, 5, t=0)
    assert greedy.replica_count() <= 2.0 * max(oracle.replica_count(), 1)


def test_dangling_edges_structure_only():
    snb = snb_like(1, seed=0)
    g = snb.graph
    shard = hash_partition(g.n_nodes, 4)
    k0 = dangling_edge_replication(g.indptr, g.indices, shard, 4, k=0)
    k1 = dangling_edge_replication(g.indptr, g.indices, shard, 4, k=1)
    assert k1.replica_count() >= k0.replica_count() > 0
    # k=0 removes all dangling edges: every cut edge's target replicated
    src = np.repeat(np.arange(g.n_nodes), np.diff(g.indptr))
    cut = shard[src] != shard[g.indices]
    assert k0.mask[g.indices[cut], shard[src[cut]]].all()


def test_workload_aware_cheaper_than_dangling(rng):
    """Paper Fig 7d / Table 3: the greedy algorithm, being workload-aware,
    replicates less than structure-based dangling-edge replication at a
    comparable latency guarantee."""
    snb = snb_like(1, seed=1)
    g = snb.graph
    shard = hash_partition(g.n_nodes, 6)
    ps = snb_workload_materialized(snb, n_queries=300, seed=1)
    f = g.object_sizes()
    greedy, _ = replicate_workload_exact(
        ps, shard, 6, t=1, f=f)
    dangling = dangling_edge_replication(g.indptr, g.indices, shard, 6, k=1)
    assert (greedy.replication_overhead(f)
            < dangling.replication_overhead(f))
