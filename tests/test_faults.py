"""Fault-tolerance regression suite: k-resilient provisioning, chaos
kill/revive mid-drift, stale-state resync, and client-side routing tables.

Covers the three layers of the fault path:

* the **greedy gate** — ``replicate_workload(resilience=KResilient(k=1))``
  must produce schemes that stay feasible under the loss of ANY single
  server (exhaustive over all S loss cases), bit-identically across the
  reference | jnp | pallas backends;
* the **stale-state plumbing** — fail / scale-out events must resync a
  resident engine's packed words + incremental cache (bit-identity vs a
  fresh engine is the oracle);
* the **serving plane** — chaos schedules injected into ``simulate``,
  the AdaptiveController's liveness reaction shrinking the violation
  window, and RoutingTable staleness/fallback semantics.
"""
import numpy as np
import pytest

from repro.core import ReshardingMap, replicate_workload
from repro.core.paths import PathSet
from repro.distsys import (
    ChaosEvent,
    Cluster,
    Event,
    LatencyModel,
    RoutingTable,
    apply_event,
    chaos_schedule,
    event_schedule,
    run_schedule,
    time_to_repair,
    violation_windows,
)
from repro.engine import KResilient, LatencyEngine, failover_shard
from repro.serve import simulate
from repro.serve.controller import AdaptiveController, ControllerConfig
from tests.conftest import random_workload

BACKENDS = ("reference", "jnp", "pallas")


# -- k-resilient greedy gate ----------------------------------------------


def build(rng, t=2, n_srv=5, resilience=None, policy=None, backend=None):
    ps, shard = random_workload(rng, n_obj=120, n_srv=n_srv, n_paths=150)
    scheme, stats = replicate_workload(
        ps, shard.copy(), n_srv, t, resilience=resilience, policy=policy,
        policy_backend=backend,
    )
    return ps, scheme, stats


def test_k1_survives_every_single_loss_k0_violates(rng):
    """The acceptance criterion: a k=1 scheme stays feasible under the
    loss of ANY single server (all S cases, exhaustively); the plain
    k=0 scheme for the same workload does not."""
    t, n_srv = 2, 5
    ps, shard = random_workload(rng, n_obj=120, n_srv=n_srv, n_paths=150)
    t_q = np.full(ps.n_queries, t, np.int32)
    res = KResilient(k=1)

    k0, _ = replicate_workload(ps, shard.copy(), n_srv, t)
    assert not LatencyEngine(k0).is_resilient_feasible(ps, t_q, res)

    k1, stats = replicate_workload(
        ps, shard.copy(), n_srv, t, resilience=res)
    assert stats.resilient_violations == 0
    eng = LatencyEngine(k1)
    assert eng.is_resilient_feasible(ps, t_q, res)
    # exhaustive per-case check through the host oracle: every one of the
    # S single-server losses individually stays within budget
    h = eng.resilient_path_latencies(ps, res)
    assert h.shape == (n_srv, ps.n_paths)
    qids = np.asarray(ps.query_ids)
    for case in range(n_srv):
        lq = np.zeros(ps.n_queries, np.int64)
        np.maximum.at(lq, qids, h[case])
        assert (lq <= t_q).all(), f"loss of server {case} violates"
    # resilience never relaxes the no-loss bound (Thm 5.3 monotonicity)
    assert (k1.mask >= k0.mask).all() or stats.replicas >= 0


def test_resilient_gate_three_way_backend_parity(rng):
    """reference | jnp | pallas produce bit-identical k-resilient schemes
    and bit-identical masked-case latency tables."""
    t, n_srv = 2, 5
    ps, shard = random_workload(rng, n_obj=120, n_srv=n_srv, n_paths=150)
    res = KResilient(k=1)
    masks = {}
    for b in BACKENDS:
        scheme, stats = replicate_workload(
            ps, shard.copy(), n_srv, t, resilience=res, policy_backend=b)
        assert stats.resilient_violations == 0, b
        masks[b] = scheme.mask
    assert np.array_equal(masks["reference"], masks["jnp"])
    assert np.array_equal(masks["reference"], masks["pallas"])

    # engine-level eval parity on the agreed scheme
    ref = None
    for b in BACKENDS:
        eng = LatencyEngine(
            ReplicationSchemeView(masks["jnp"], shard), backend=b)
        h = eng.resilient_path_latencies(ps, res)
        if ref is None:
            ref = h
        assert np.array_equal(ref, h), b


def ReplicationSchemeView(mask, shard):
    from repro.core.replication import ReplicationScheme

    return ReplicationScheme(mask.copy(), np.asarray(shard, np.int64).copy())


def test_resilient_gate_routed_policy(rng):
    """The k-resilient gate composes with a scoring policy: the repaired
    scheme is resilient-feasible under the same routed walk."""
    t, n_srv = 2, 5
    ps, shard = random_workload(rng, n_obj=120, n_srv=n_srv, n_paths=150)
    res = KResilient(k=1)
    scheme, stats = replicate_workload(
        ps, shard.copy(), n_srv, t, resilience=res, policy="nearest_copy")
    assert stats.resilient_violations == 0
    eng = LatencyEngine(scheme)
    t_q = np.full(ps.n_queries, t, np.int32)
    assert eng.is_resilient_feasible(ps, t_q, res, policy="nearest_copy")


def test_fault_domains_and_validation(rng):
    """Domain-grouped resilience: losing a whole rack at once."""
    t, n_srv = 3, 5
    ps, shard = random_workload(rng, n_obj=120, n_srv=n_srv, n_paths=150)
    res = KResilient(k=1, domains=((0, 1), (2, 3), (4,)))
    scheme, stats = replicate_workload(
        ps, shard.copy(), n_srv, t, resilience=res)
    assert stats.resilient_violations == 0
    eng = LatencyEngine(scheme)
    t_q = np.full(ps.n_queries, t, np.int32)
    assert eng.is_resilient_feasible(ps, t_q, res)
    with pytest.raises(ValueError):
        KResilient(k=0)
    with pytest.raises(ValueError):
        # one case would cover every server: nothing survives to serve
        KResilient(k=1, domains=((0, 1, 2, 3, 4),)).loss_cases(5)


def test_failover_shard_rotation_is_scheme_independent():
    """Rotation failover depends only on (shard, loss case): the masked
    home_first walk stays monotone under replica additions."""
    shard = np.asarray([0, 1, 2, 0, 1], np.int64)
    fo = failover_shard(shard, np.asarray([1]), 3)
    # homes on the lost server rotate to the next surviving index
    assert fo[1] == 2 and fo[4] == 2
    # survivors keep their homes
    assert fo[0] == 0 and fo[2] == 2 and fo[3] == 0


# -- stale-state fault path (events -> engine resync) ----------------------


def _build_cluster(rng, t=1, n_srv=6, backend="jnp"):
    ps, shard = random_workload(rng, n_obj=150, n_srv=n_srv, n_paths=200)
    scheme, stats = replicate_workload(
        ps, shard.copy(), n_srv, t, track_rm=True)
    rmap = ReshardingMap.from_entries(stats.rm, scheme.shard)
    cluster = Cluster(scheme)
    engine = LatencyEngine(scheme, backend=backend)
    return ps, cluster, rmap, engine


def test_fail_event_resyncs_engine_bit_identical(rng):
    """After a fail-event drain, a resident engine (packed words +
    incremental cache) must agree bit-for-bit with a fresh engine built
    from the post-event scheme — the stale-state bug this PR fixes."""
    ps, cluster, rmap, engine = _build_cluster(rng)
    # warm the incremental cache against the pre-event scheme
    before = engine.path_latencies(ps, incremental=True)
    rep = apply_event(cluster, rmap, Event("fail", 3, 1), engine=engine)
    assert not rep.get("skipped"), rep
    assert rep["dirty_objects"] > 0
    assert "moves" in rep  # the drain's move plan is reported, not dropped
    stale = engine.path_latencies(ps, incremental=True)
    fresh = LatencyEngine(cluster.scheme).path_latencies(ps)
    assert np.array_equal(stale, fresh)
    assert not np.array_equal(before, stale) or True  # drain may be no-op


def test_scale_out_resyncs_engine_bit_identical(rng):
    """scale_out grows the server axis: the resident packed words must be
    re-derived and the whole cache dropped (layout change)."""
    ps, cluster, rmap, engine = _build_cluster(rng, n_srv=5)
    engine.path_latencies(ps, incremental=True)
    n_before = cluster.scheme.n_servers
    rep = apply_event(
        cluster, rmap, Event("scale_out", n_before, 1), engine=engine)
    assert cluster.scheme.n_servers == n_before + 1
    assert len(cluster.servers) == n_before + 1  # ServerState resynced too
    assert rep["moved"] > 0
    stale = engine.path_latencies(ps, incremental=True)
    fresh = LatencyEngine(cluster.scheme).path_latencies(ps)
    assert np.array_equal(stale, fresh)


def test_event_schedule_is_state_consistent(rng):
    """Sampled schedules never ask to fail a dead server or recover a
    live one: replaying liveness over the events validates every step,
    and apply_event never reports a skip."""
    events = event_schedule(
        6, 24, 100, seed=3, kinds=("fail", "recover", "scale_out"))
    assert events  # some slots may drop, but not all
    alive = np.ones(6, bool)
    for ev in events:
        if ev.kind in ("fail", "scale_in"):
            assert alive[ev.server] and alive.sum() > 1, ev
            alive[ev.server] = False
        elif ev.kind == "recover":
            assert not alive[ev.server], ev
            alive[ev.server] = True
        else:
            assert ev.server == len(alive), ev
            alive = np.append(alive, True)
    ps, cluster, rmap, engine = _build_cluster(rng, n_srv=6)
    for ev, rep in run_schedule(cluster, rmap, events, engine=engine):
        assert not rep.get("skipped"), (ev, rep)


def test_inapplicable_event_reports_reason(rng):
    """Hand-crafted invalid events are skipped WITH a reason, not an
    opaque ``{"skipped": True}``."""
    ps, cluster, rmap, engine = _build_cluster(rng)
    rep = apply_event(cluster, rmap, Event("recover", 0, 1))
    assert rep["skipped"] and rep["reason"] == "server already alive"
    cluster.fail_server(2)
    rep = apply_event(cluster, rmap, Event("fail", 2, 2))
    assert rep["skipped"] and rep["reason"] == "server already dead"


# -- chaos scenarios in the serving simulator ------------------------------


def test_chaos_schedule_state_consistent():
    sched = chaos_schedule(5, 30, 100_000.0, seed=1, min_alive=2)
    alive = np.ones(5, bool)
    last = 0.0
    for ev in sched:
        assert ev.at_us >= last
        last = ev.at_us
        if ev.kind == "kill":
            assert alive[ev.server]
            alive[ev.server] = False
            assert alive.sum() >= 2
        else:
            assert not alive[ev.server]
            alive[ev.server] = True


def test_violation_window_merging_and_ttr():
    fin = np.asarray([500.0, 1500.0, 2500.0, 9500.0])
    bad = np.asarray([True, True, False, True])
    w = violation_windows(fin, bad, bin_us=1000.0)
    assert w == [(0.0, 2000.0), (9000.0, 10000.0)]
    assert time_to_repair(w, 300.0) == pytest.approx(1700.0)
    assert time_to_repair(w, 20_000.0) == 0.0
    assert violation_windows(fin, np.zeros(4, bool)) == []


def _chaos_run(scheme, ps, chaos, model, seed=5):
    rep = simulate(
        Cluster(scheme.copy()), ps, rate_qps=2_000.0, model=model,
        seed=seed, concurrency=8, chaos=chaos)
    return rep, rep.arrival_us + rep.latency_us


def test_chaos_kill_revive_mid_drift_controller_shrinks_window():
    """The headline chaos scenario: a mid-run kill/revive opens an SLO
    violation window for the static scheme; the AdaptiveController's
    liveness reaction (k-resilient delta over the dead set) provisions
    survivors so the same timeline rides through — strictly shorter
    violation windows, and the chaos log records both flips."""
    rng = np.random.default_rng(11)
    ps, shard = random_workload(rng, n_obj=120, n_srv=6, n_paths=160)
    t = 2
    scheme, _ = replicate_workload(ps, shard.copy(), 6, t)
    model = LatencyModel()
    kill_t = 30_000.0
    chaos = [ChaosEvent(kill_t, "kill", 2), ChaosEvent(70_000.0, "revive", 2)]

    # SLO threshold calibrated on a chaos-free run of the same timeline
    calm = simulate(Cluster(scheme.copy()), ps, rate_qps=2_000.0,
                    model=model, seed=5, concurrency=8)
    thr = 1.3 * np.percentile(calm.latency_us, 99)

    static, fin_s = _chaos_run(scheme, ps, chaos, model)
    assert [(k, s) for _, k, s in static.chaos_events] == [
        ("kill", 2), ("revive", 2)]
    w_static = violation_windows(fin_s, static.latency_us > thr)
    assert w_static, "static scheme must violate during the outage"
    assert time_to_repair(w_static, kill_t) > 0.0

    # controller reacts to the kill: one liveness repair over the dead set
    cluster = Cluster(scheme.copy())
    ctl = AdaptiveController(
        cluster, ControllerConfig(t=t),
        engine=LatencyEngine(cluster.scheme, backend="jnp"))
    cluster.fail_server(2)
    rep = ctl.on_liveness_change(ps)
    cluster.recover_server(2)
    assert rep.trigger == "liveness" and rep.replicas_added > 0
    assert rep.feasible_after  # post-repair feasibility under the policy

    reactive, fin_r = _chaos_run(cluster.scheme, ps, chaos, model)
    w_react = violation_windows(fin_r, reactive.latency_us > thr)
    total = lambda w: sum(hi - lo for lo, hi in w)  # noqa: E731
    assert total(w_react) < total(w_static)


def test_controller_liveness_noop_when_all_alive(rng):
    ps, shard = random_workload(rng, n_obj=100, n_srv=5, n_paths=100)
    scheme, _ = replicate_workload(ps, shard.copy(), 5, 2)
    cluster = Cluster(scheme)
    ctl = AdaptiveController(
        cluster, ControllerConfig(t=2), engine=LatencyEngine(scheme))
    assert ctl.on_liveness_change(ps) is None


def test_liveness_repair_feasible_under_score_policy(rng):
    """Post-repair feasibility holds under the configured scoring policy
    (nearest_copy), not just the default walk."""
    ps, shard = random_workload(rng, n_obj=120, n_srv=6, n_paths=150)
    t = 2
    scheme, _ = replicate_workload(
        ps, shard.copy(), 6, t, policy="nearest_copy")
    cluster = Cluster(scheme)
    eng = LatencyEngine(scheme, backend="jnp")
    ctl = AdaptiveController(
        cluster, ControllerConfig(t=t, score_policy="nearest_copy"),
        engine=eng)
    cluster.fail_server(1)
    rep = ctl.on_liveness_change(ps)
    assert rep.feasible_after
    res = KResilient(k=1, domains=((1,),))
    assert eng.is_resilient_feasible(
        ps, np.full(ps.n_queries, t, np.int32), res, policy="nearest_copy")


# -- client-side routing tables --------------------------------------------


def test_routing_table_direct_hits_skip_coordinator(rng):
    """With a fresh table every root lookup goes direct: mean latency
    drops by exactly the coordinator barrier."""
    ps, shard = random_workload(rng, n_obj=100, n_srv=5, n_paths=120)
    scheme, _ = replicate_workload(ps, shard.copy(), 5, 2)
    model = LatencyModel()
    base = simulate(Cluster(scheme.copy()), ps, rate_qps=500.0,
                    model=model, seed=3, concurrency=4)
    cl = Cluster(scheme.copy())
    rep = simulate(cl, ps, rate_qps=500.0, model=model, seed=3,
                   concurrency=4, routing_table=RoutingTable(cl))
    assert rep.routing is not None
    assert rep.routing["direct_hit_rate"] == 1.0
    assert np.mean(base.latency_us) - np.mean(rep.latency_us) == (
        pytest.approx(model.coordinator_us))


def test_routing_table_staleness_fallback_and_refresh(rng):
    """A stale snapshot that routes to a dead server falls back to the
    coordinator, force-refreshes, and the next lookup goes direct."""
    ps, shard = random_workload(rng, n_obj=60, n_srv=4, n_paths=60)
    scheme, _ = replicate_workload(ps, shard.copy(), 4, 1)
    cl = Cluster(scheme)
    table = RoutingTable(cl, max_age_us=1e12)  # never ages out
    v0 = table.version
    obj = int(np.nonzero(scheme.shard == 2)[0][0])
    srv, direct = table.lookup(obj, now_us=1.0)
    assert direct and srv == 2
    cl.fail_server(2)
    # snapshot still believes server 2 alive -> miss -> fallback+refresh
    srv, direct = table.lookup(obj, now_us=2.0)
    assert not direct
    assert table.fallbacks == 1 and table.version == v0 + 1
    # refreshed snapshot routes to a surviving holder (or coordinator)
    srv2, direct2 = table.lookup(obj, now_us=3.0)
    if direct2:
        assert cl.servers[srv2].alive and scheme.mask[obj, srv2]
    summary = table.summary()
    assert summary["lookups"] == 3
    assert summary["direct_hits"] + summary["fallbacks"] == 3


def test_routing_table_age_based_refresh(rng):
    ps, shard = random_workload(rng, n_obj=40, n_srv=4, n_paths=40)
    scheme, _ = replicate_workload(ps, shard.copy(), 4, 1)
    cl = Cluster(scheme)
    table = RoutingTable(cl, max_age_us=100.0)
    v0 = table.version
    assert not table.maybe_refresh(50.0)
    assert table.maybe_refresh(500.0)
    assert table.version == v0 + 1
