"""Model-level tests: LM variants, GNNs, MIND."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import gnn as G, recsys as R, transformer as T


def lm_cfg(**kw):
    base = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                vocab=97, dtype=jnp.float32, remat=False)
    base.update(kw)
    return T.TransformerConfig(**base)


LM_VARIANTS = {
    "dense": lm_cfg(),
    "bias": lm_cfg(qkv_bias=True),
    "swa": lm_cfg(sliding_window=8, n_kv_heads=4),
    "partial_rope": lm_cfg(rotary_pct=0.5),
    "moe": lm_cfg(n_layers=3, n_experts=8, top_k=2, moe_d_ff=96),
    "mla_moe": lm_cfg(n_layers=3, n_experts=8, top_k=2, moe_d_ff=96,
                      n_shared_experts=1, n_dense_layers=1,
                      mla_kv_lora=32, mla_q_lora=24, mla_rope_dim=8,
                      mla_nope_dim=16, mla_v_dim=16, n_kv_heads=4),
}


@pytest.mark.slow
@pytest.mark.parametrize("name", list(LM_VARIANTS))
def test_lm_train_and_serve(name, rng):
    cfg = LM_VARIANTS[name]
    params = T.init(cfg, jax.random.key(0))
    B, S = 2, 16
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    loss = T.loss_fn(params, toks, toks, cfg)
    assert np.isfinite(float(loss))
    grads = jax.grad(lambda p: T.loss_fn(p, toks, toks, cfg))(params)
    gn = sum(float(jnp.sum(g.astype(jnp.float32) ** 2))
             for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0
    cache, lg_pre = T.prefill(params, toks, cfg, max_len=S + 4)
    full = T.forward(params, toks, cfg)
    np.testing.assert_allclose(np.asarray(lg_pre), np.asarray(full[:, -1]),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("name", [
    "dense", "swa",
    pytest.param("mla_moe", marks=pytest.mark.slow),  # heaviest compile
])
def test_lm_decode_consistency(name, rng):
    """prefill(S) + decode(token S) logits == forward(S+1) last logits."""
    cfg = LM_VARIANTS[name]
    params = T.init(cfg, jax.random.key(1))
    B, S = 2, 12
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S + 1)), jnp.int32)
    cache, _ = T.prefill(params, toks[:, :S], cfg, max_len=S + 4)
    _, lg_dec = T.decode_step(params, cache, toks[:, S], cfg)
    full = T.forward(params, toks, cfg)
    np.testing.assert_allclose(np.asarray(lg_dec), np.asarray(full[:, -1]),
                               atol=2e-3, rtol=2e-3)


@pytest.mark.slow
def test_swa_ring_buffer_decode(rng):
    """Decode far past the window: ring cache must match full forward."""
    cfg = lm_cfg(sliding_window=8, n_kv_heads=4)
    params = T.init(cfg, jax.random.key(2))
    B, S_total = 1, 24
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S_total)), jnp.int32)
    prefix = 10
    cache, _ = T.prefill(params, toks[:, :prefix], cfg, max_len=S_total)
    for i in range(prefix, S_total):
        cache, lg = T.decode_step(params, cache, toks[:, i], cfg)
    full = T.forward(params, jnp.concatenate(
        [toks, jnp.zeros((B, 0), jnp.int32)], 1), cfg)
    # logits at the last decoded position vs forward at S_total-1... decode
    # step i consumed token i and predicts i+1; last call consumed token
    # S_total-1 == forward position S_total-1
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, -1]),
                               atol=2e-3, rtol=2e-3)


def test_moe_chunking_equivalent(rng):
    kw = dict(n_layers=2, n_experts=8, top_k=2, moe_d_ff=96,
              capacity_factor=8.0)
    c_off = lm_cfg(**kw, moe_chunk=0)
    c_on = lm_cfg(**kw, moe_chunk=16)
    params = T.init(c_off, jax.random.key(0))
    toks = jnp.asarray(rng.integers(0, 97, (4, 16)), jnp.int32)
    np.testing.assert_allclose(
        np.asarray(T.forward(params, toks, c_off)),
        np.asarray(T.forward(params, toks, c_on)), atol=1e-5, rtol=1e-4)


def test_remat_block_equivalent(rng):
    c1 = lm_cfg(n_layers=4, remat=True, remat_block=1)
    c2 = lm_cfg(n_layers=4, remat=True, remat_block=2)
    params = T.init(c1, jax.random.key(0))
    toks = jnp.asarray(rng.integers(0, 97, (2, 8)), jnp.int32)
    g1 = jax.grad(lambda p: T.loss_fn(p, toks, toks, c1))(params)
    g2 = jax.grad(lambda p: T.loss_fn(p, toks, toks, c2))(params)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-6, rtol=1e-5)


def test_loss_chunk_equivalent(rng):
    c1 = lm_cfg(loss_chunk=0)
    c2 = lm_cfg(loss_chunk=8)
    params = T.init(c1, jax.random.key(0))
    toks = jnp.asarray(rng.integers(0, 97, (4, 8)), jnp.int32)
    np.testing.assert_allclose(float(T.loss_fn(params, toks, toks, c1)),
                               float(T.loss_fn(params, toks, toks, c2)),
                               atol=1e-5, rtol=1e-5)


def test_blockwise_attention_equivalent(rng):
    c1 = lm_cfg(blockwise_from=1 << 30)
    c2 = lm_cfg(blockwise_from=8, attn_block_q=8)
    params = T.init(c1, jax.random.key(0))
    toks = jnp.asarray(rng.integers(0, 97, (2, 32)), jnp.int32)
    np.testing.assert_allclose(
        np.asarray(T.forward(params, toks, c1)),
        np.asarray(T.forward(params, toks, c2)), atol=1e-4, rtol=1e-4)


# --- GNNs ------------------------------------------------------------------
@pytest.mark.parametrize("arch", ["egnn", "schnet", "graphsage", "graphcast"])
def test_gnn_train(arch, rng):
    cfg = G.GNNConfig(arch=arch, n_layers=2, d_hidden=24, d_in=10,
                      n_classes=5, n_rbf=16)
    params = G.init(cfg, jax.random.key(0))
    N, E = 30, 80
    batch = {
        "x": jnp.asarray(rng.normal(size=(N, 10)), jnp.float32),
        "senders": jnp.asarray(rng.integers(0, N, E), jnp.int32),
        "receivers": jnp.asarray(rng.integers(0, N, E), jnp.int32),
        "pos": jnp.asarray(rng.normal(size=(N, 3)), jnp.float32),
        "labels": jnp.asarray(rng.integers(0, 5, N), jnp.int32),
    }
    loss = G.loss_fn(params, batch, cfg)
    grads = jax.grad(lambda p: G.loss_fn(p, batch, cfg))(params)
    gn = sum(float(jnp.sum(g ** 2)) for g in jax.tree.leaves(grads))
    assert np.isfinite(float(loss)) and np.isfinite(gn)


def test_egnn_equivariance(rng):
    cfg = G.GNNConfig(arch="egnn", n_layers=3, d_hidden=16, d_in=6,
                      n_classes=4)
    params = G.init(cfg, jax.random.key(0))
    N, E = 20, 50
    batch = {
        "x": jnp.asarray(rng.normal(size=(N, 6)), jnp.float32),
        "senders": jnp.asarray(rng.integers(0, N, E), jnp.int32),
        "receivers": jnp.asarray(rng.integers(0, N, E), jnp.int32),
        "pos": jnp.asarray(rng.normal(size=(N, 3)), jnp.float32),
        "labels": jnp.asarray(rng.integers(0, 4, N), jnp.int32),
    }
    out1 = G.forward(params, batch, cfg)
    th = 0.5
    Rm = jnp.asarray([[np.cos(th), -np.sin(th), 0],
                      [np.sin(th), np.cos(th), 0], [0, 0, 1.0]], jnp.float32)
    batch2 = dict(batch, pos=batch["pos"] @ Rm.T + jnp.asarray([3., -1., 2.]))
    out2 = G.forward(params, batch2, cfg)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               atol=1e-4, rtol=1e-4)


def test_gnn_minibatch_blocks(rng):
    cfg = G.GNNConfig(arch="graphsage", n_layers=2, d_hidden=16, d_in=8,
                      n_classes=4)
    params = G.init(cfg, jax.random.key(0))
    B, f1, f2 = 6, 4, 3
    batch = {
        "seed_x": jnp.asarray(rng.normal(size=(B, 8)), jnp.float32),
        "layer_x": [jnp.asarray(rng.normal(size=(B, f1, 8)), jnp.float32),
                    jnp.asarray(rng.normal(size=(B, f1 * f2, 8)),
                                jnp.float32)],
        "layer_mask": [jnp.ones((B, f1), bool),
                       jnp.ones((B, f1 * f2), bool)],
        "labels": jnp.asarray(rng.integers(0, 4, B), jnp.int32),
    }
    loss = G.loss_fn(params, batch, cfg)
    assert np.isfinite(float(loss))


def test_gnn_isolated_nodes_no_nan(rng):
    """Mean aggregation over zero-degree nodes must not NaN."""
    cfg = G.GNNConfig(arch="graphsage", n_layers=2, d_hidden=8, d_in=4,
                      n_classes=3)
    params = G.init(cfg, jax.random.key(0))
    batch = {
        "x": jnp.asarray(rng.normal(size=(5, 4)), jnp.float32),
        "senders": jnp.asarray([0, 1], jnp.int32),
        "receivers": jnp.asarray([1, 0], jnp.int32),  # nodes 2-4 isolated
        "labels": jnp.asarray([0, 1, 2, 0, 1], jnp.int32),
    }
    out = G.forward(params, batch, cfg)
    assert np.isfinite(np.asarray(out)).all()


# --- MIND ------------------------------------------------------------------
@pytest.mark.slow
def test_mind_training_reduces_loss(rng):
    cfg = R.MINDConfig(n_items=200, n_user_feats=20, embed_dim=16,
                       n_interests=2, capsule_iters=2, hist_len=8,
                       user_feat_len=3, d_hidden=32)
    params = R.init(cfg, jax.random.key(0))
    from repro.optim import AdamW

    opt = AdamW(lr=0.05, weight_decay=0.0)
    state = opt.init(params)
    B = 16
    batch = {
        "hist": jnp.asarray(rng.integers(0, 200, (B, 8)), jnp.int32),
        "hist_mask": jnp.ones((B, 8), bool),
        "user_feats": jnp.asarray(rng.integers(0, 20, (B, 3)), jnp.int32),
        "target": jnp.asarray(rng.integers(0, 200, (B,)), jnp.int32),
    }
    losses = []
    for _ in range(20):
        loss, grads = jax.value_and_grad(
            lambda p: R.loss_fn(p, batch, cfg))(params)
        params, state, _ = opt.update(grads, state, params)
        losses.append(float(loss))
    assert losses[-1] < losses[0]


def test_mind_interest_diversity(rng):
    """Different interests extract different vectors (capsules separate)."""
    cfg = R.MINDConfig(n_items=100, n_user_feats=10, embed_dim=16,
                       n_interests=4, capsule_iters=3, hist_len=12,
                       user_feat_len=2, d_hidden=32)
    params = R.init(cfg, jax.random.key(3))
    B = 4
    batch = {
        "hist": jnp.asarray(rng.integers(0, 100, (B, 12)), jnp.int32),
        "hist_mask": jnp.ones((B, 12), bool),
        "user_feats": jnp.asarray(rng.integers(0, 10, (B, 2)), jnp.int32),
    }
    interests = R.user_tower(params, batch, cfg)
    flat = np.asarray(interests.reshape(B * 4, -1))
    # not all interests identical
    assert np.std(flat, axis=0).max() > 1e-4


def test_embedding_bag_ragged_vs_dense(rng):
    table = jnp.asarray(rng.normal(size=(50, 8)), jnp.float32)
    ids = jnp.asarray([[1, 2, 3], [4, 5, -1]], jnp.int32)
    mask = ids >= 0
    dense = R.embedding_bag_dense(table, ids, mask, "mean")
    flat_ids = jnp.asarray([1, 2, 3, 4, 5], jnp.int32)
    offsets = jnp.asarray([0, 3], jnp.int32)
    ragged = R.embedding_bag(table, flat_ids, offsets, "mean")
    np.testing.assert_allclose(np.asarray(dense), np.asarray(ragged),
                               atol=1e-6)
