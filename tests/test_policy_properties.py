"""Property / metamorphic suite for the routing-policy family (PR 5).

Pins the contracts the policy-aware greedy provisioning rests on:

  * **reduction** — ``nearest_copy_dp(0)`` IS ``home_first`` and
    ``nearest_copy_dp(1)`` IS ``nearest_copy``, bit-identically (servers
    and locality), on all three backends;
  * **dominance** — the full-suffix DP walk (``nearest_copy_dp()``,
    depth=None: optimal replica-aware routing) pathwise-dominates every
    executed policy, including every finite-depth receding-horizon walk
    (finite depths only dominate in aggregate — a deeper-but-myopic pick
    can lose pathwise, which is exactly why the greedy driver re-validates);
  * **monotonicity** — adding any replica never increases the optimal
    routed latency of any path (more copies = more routing options);
  * **prune-then-reevaluate** — ``prune_scheme_replicas`` preserves
    ``is_feasible`` under the pruning policy;
  * **greedy parity** — the policy-aware greedy inner loop produces the
    same scheme whichever backend evaluates the routed gate
    (reference | jnp | pallas), ``policy="home_first"`` stays bit-identical
    to the pre-refactor driver, and a scalar-budget ``SLOSpec`` broadcast
    equals the int budget bit-identically through the policy-aware path.

All generators are seeded numpy (deterministic in CI); when ``hypothesis``
is installed the same properties additionally run over generated inputs.
"""
import numpy as np
import pytest

from repro.core.paths import PathSet
from repro.core.replication import (
    ReplicationScheme,
    prune_scheme_replicas,
)
from repro.core.greedy import replicate_workload
from repro.core.slo import SLOSpec
from repro.engine import (
    BACKENDS,
    LatencyEngine,
    nearest_copy_dp,
    resolve_policy,
)
from repro.engine.routing import (
    NearestCopyDP,
    dp_suffix_scores,
    pick_holder_scored,
)

from tests.conftest import random_workload

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - optional dependency
    HAVE_HYPOTHESIS = False


def _replicated(rng, n_obj, n_srv, density=0.25):
    shard = rng.integers(0, n_srv, n_obj).astype(np.int32)
    mask = np.zeros((n_obj, n_srv), bool)
    mask[np.arange(n_obj), shard] = True
    mask |= rng.random((n_obj, n_srv)) < density
    return mask, shard


# ---------------------------------------------------------------------------
# policy resolution + scalar oracles
# ---------------------------------------------------------------------------
def test_resolve_dp_policy():
    assert resolve_policy("nearest_copy_dp") == NearestCopyDP()
    assert resolve_policy(nearest_copy_dp(3)).depth == 3
    assert nearest_copy_dp().depth is None
    with pytest.raises(ValueError):
        NearestCopyDP(depth=-2)


def test_pick_holder_scored_ordering():
    holders = np.array([False, True, True, True, False])
    # lowest score wins
    assert pick_holder_scored(holders, home=2, scores=[9, 3, 9, 1, 9]) == 3
    # home breaks score ties, then lowest id
    assert pick_holder_scored(holders, 2, [9, 5, 5, 5, 9]) == 2
    assert pick_holder_scored(holders, 0, [9, 5, 5, 5, 9]) == 1
    assert pick_holder_scored(np.zeros(5, bool), 2, np.zeros(5)) == -1


def test_dp_suffix_scores_window_semantics():
    """E[pos, s] counts optimal hops over the next `depth` accesses only."""
    shard = np.array([0, 1, 2], np.int32)
    mask = np.zeros((3, 3), bool)
    mask[np.arange(3), shard] = True
    objs = [0, 1, 2]
    e1 = dp_suffix_scores(objs, mask, 1)
    # after access 0 at server 1 the next access (obj 1) is local: 0 hops
    assert e1[0, 1] == 0 and e1[0, 0] == 1
    efull = dp_suffix_scores(objs, mask, None)
    # from server 0: obj1 remote (1) + obj2 remote (1)
    assert efull[0, 0] == 2
    # depth widening never increases a window score
    e2 = dp_suffix_scores(objs, mask, 2)
    assert (e1 <= e2).all()  # wider window only adds later-access costs


# ---------------------------------------------------------------------------
# reduction: dp(0) == home_first, dp(1) == nearest_copy (bit-identical)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", BACKENDS)
def test_dp_reduces_to_named_policies(backend):
    rng = np.random.default_rng(7)
    ps, _ = random_workload(rng, n_obj=90, n_srv=8, n_paths=80, max_len=6)
    mask, shard = _replicated(rng, 90, 8)
    eng = LatencyEngine.from_arrays(mask, shard, backend=backend)
    for named, depth in (("home_first", 0), ("nearest_copy", 1)):
        srv_n, loc_n = eng.access_trace(ps, policy=named)
        srv_d, loc_d = eng.access_trace(ps, policy=nearest_copy_dp(depth))
        np.testing.assert_array_equal(srv_n, srv_d)
        np.testing.assert_array_equal(loc_n, loc_d)
        np.testing.assert_array_equal(
            eng.path_latencies(ps, policy=named),
            eng.path_latencies(ps, policy=nearest_copy_dp(depth)),
        )


# ---------------------------------------------------------------------------
# three-way backend parity for the DP walk
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("depth", [0, 1, 2, None])
def test_three_way_dp_parity(depth):
    rng = np.random.default_rng(11)
    ps, _ = random_workload(rng, n_obj=70, n_srv=9, n_paths=60, max_len=6)
    mask, shard = _replicated(rng, 70, 9, density=0.2)
    pol = nearest_copy_dp(depth)
    outs, traces = {}, {}
    for b in BACKENDS:
        eng = LatencyEngine.from_arrays(mask, shard, backend=b)
        outs[b] = eng.path_latencies(ps, policy=pol)
        traces[b] = eng.access_trace(ps, policy=pol)
    for b in ("jnp", "pallas"):
        np.testing.assert_array_equal(outs["reference"], outs[b])
        np.testing.assert_array_equal(traces["reference"][0], traces[b][0])
        np.testing.assert_array_equal(traces["reference"][1], traces[b][1])


def test_dp_single_position_paths():
    rng = np.random.default_rng(3)
    mask, shard = _replicated(rng, 20, 4)
    ps = PathSet.from_lists([[0], [5], [7]])
    for b in BACKENDS:
        eng = LatencyEngine.from_arrays(mask, shard, backend=b)
        h = eng.path_latencies(ps, policy=nearest_copy_dp(None))
        assert h.tolist() == [0, 0, 0]


# ---------------------------------------------------------------------------
# (b) dominance: the optimal walk pathwise-dominates every policy
# ---------------------------------------------------------------------------
def _dominance_case(seed):
    rng = np.random.default_rng(seed)
    ps, _ = random_workload(rng, n_obj=100, n_srv=7, n_paths=120, max_len=7)
    mask, shard = _replicated(rng, 100, 7)
    return ps, mask, shard


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_full_depth_dominates_every_policy_pathwise(seed):
    ps, mask, shard = _dominance_case(seed)
    eng = LatencyEngine.from_arrays(mask, shard)
    h_opt = eng.path_latencies(ps, policy=nearest_copy_dp(None))
    load = np.arange(7, dtype=np.float64)
    for pol, kw in [
        ("home_first", {}),
        ("nearest_copy", {}),
        ("queue_aware", {"load": load}),
        (nearest_copy_dp(2), {}),
        (nearest_copy_dp(3), {}),
    ]:
        h = eng.path_latencies(ps, policy=pol, **kw)
        assert (h_opt <= h).all(), f"optimal walk lost to {pol} pathwise"


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_deeper_lookahead_dominates_in_aggregate(seed):
    """Finite depths are receding-horizon: no pathwise guarantee (that is
    the optimal walk's privilege), but on workload totals deeper
    lookahead must not lose on these seeded instances."""
    ps, mask, shard = _dominance_case(seed)
    eng = LatencyEngine.from_arrays(mask, shard)
    totals = [
        int(eng.path_latencies(ps, policy=nearest_copy_dp(k)).sum())
        for k in (0, 1, 2)
    ]
    totals.append(
        int(eng.path_latencies(ps, policy=nearest_copy_dp(None)).sum())
    )
    assert totals[1] <= totals[0]
    assert totals[3] <= min(totals), totals


# ---------------------------------------------------------------------------
# (a) monotonicity: replicas never hurt the optimal routed latency
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_optimal_walk_monotone_under_additions(seed):
    rng = np.random.default_rng(seed)
    ps, _ = random_workload(rng, n_obj=80, n_srv=6, n_paths=80, max_len=6)
    mask, shard = _replicated(rng, 80, 6, density=0.1)
    eng = LatencyEngine.from_arrays(mask.copy(), shard)
    h = eng.path_latencies(ps, policy=nearest_copy_dp(None))
    for _ in range(6):
        v = rng.integers(0, 80, 15)
        s = rng.integers(0, 6, 15)
        eng.add_replicas(v, s)
        h_new = eng.path_latencies(ps, policy=nearest_copy_dp(None))
        assert (h_new <= h).all(), "a replica addition increased optimal h"
        h = h_new


def test_greedy_walks_not_monotone_documentation():
    """The *executed* home-first walk is NOT monotone under arbitrary
    additions (the constructed counterexample) — the reason the greedy
    driver re-validates routed feasibility instead of assuming it."""
    shard = np.array([0, 1, 2, 1], np.int32)
    mask = np.zeros((4, 3), bool)
    mask[np.arange(4), shard] = True
    mask[2, 1] = True  # replica of c at server 1
    ps = PathSet.from_lists([[0, 1, 2, 3]])
    eng = LatencyEngine.from_arrays(mask.copy(), shard)
    before = int(eng.path_latencies(ps)[0])
    eng.add_replicas([1], [0])  # replica of b at the root's server
    after = int(eng.path_latencies(ps)[0])
    assert after > before  # the addition re-routed the walk for the worse
    # ... while the optimal walk is monotone on the same instance
    eng2 = LatencyEngine.from_arrays(mask.copy(), shard)
    b0 = int(eng2.path_latencies(ps, policy=nearest_copy_dp(None))[0])
    eng2.add_replicas([1], [0])
    b1 = int(eng2.path_latencies(ps, policy=nearest_copy_dp(None))[0])
    assert b1 <= b0


# ---------------------------------------------------------------------------
# (c) prune-then-reevaluate preserves feasibility under the pruning policy
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "policy", ["nearest_copy", NearestCopyDP(depth=None)]
)
def test_prune_preserves_feasibility(policy):
    rng = np.random.default_rng(5)
    ps, _ = random_workload(rng, n_obj=60, n_srv=5, n_paths=70, max_len=6)
    mask, shard = _replicated(rng, 60, 5, density=0.4)
    scheme = ReplicationScheme(mask.copy(), shard)
    eng = LatencyEngine(scheme)
    t = int(eng.path_latencies(ps, policy=policy).max())
    n, saved = prune_scheme_replicas(scheme, ps, t, policy=policy)
    assert n > 0 and saved > 0
    assert LatencyEngine(scheme).is_feasible(ps, t, policy=policy)


# ---------------------------------------------------------------------------
# policy-aware greedy: parity, bit-identity, and budget broadcast
# ---------------------------------------------------------------------------
def _greedy_case(seed=0, n_paths=90):
    rng = np.random.default_rng(seed)
    paths = [
        rng.integers(0, 80, rng.integers(1, 7)).tolist()
        for _ in range(n_paths)
    ]
    shard = rng.integers(0, 5, 80).astype(np.int32)
    return PathSet.from_lists(paths), shard


def test_policy_home_first_bit_identical():
    ps, shard = _greedy_case()
    s0, _ = replicate_workload(ps, shard, 5, t=1)
    s1, _ = replicate_workload(ps, shard, 5, t=1, policy="home_first")
    np.testing.assert_array_equal(s0.mask, s1.mask)


@pytest.mark.parametrize("policy", ["nearest_copy", "nearest_copy_dp"])
def test_policy_greedy_three_way_backend_parity(policy):
    """Acceptance: reference | jnp | pallas agree on the policy-aware
    greedy inner loop (identical gate values => identical schemes)."""
    ps, shard = _greedy_case(seed=1, n_paths=60)
    masks = {}
    stats = {}
    for b in BACKENDS:
        scheme, st = replicate_workload(
            ps, shard, 5, t=1, policy=policy, policy_backend=b
        )
        masks[b] = scheme.mask
        stats[b] = st
    for b in ("jnp", "pallas"):
        np.testing.assert_array_equal(masks["reference"], masks[b])
        assert stats["reference"].routed_skips == stats[b].routed_skips
    # and the result is feasible under the provisioning policy
    eng = LatencyEngine.from_arrays(masks["jnp"], shard)
    assert eng.is_feasible(ps, 1, policy=policy)


def test_policy_greedy_feasible_and_never_more_replicas():
    ps, shard = _greedy_case(seed=2, n_paths=120)
    s_hf, _ = replicate_workload(ps, shard, 5, t=1)
    s_pa, st = replicate_workload(ps, shard, 5, t=1, policy="nearest_copy")
    assert s_pa.replica_count() <= s_hf.replica_count()
    assert st.routed_skips + st.pruned_replicas > 0
    # the driver reports residual routed infeasibility honestly; here the
    # revalidation rounds repaired everything, consistent with is_feasible
    assert st.routed_violations == 0
    assert LatencyEngine(s_pa).is_feasible(ps, 1, policy="nearest_copy")


def test_policy_greedy_scalar_slospec_bit_identical():
    """(d) scalar-budget SLOSpec broadcast == int budget, bit-identically,
    through the policy-aware greedy path."""
    ps, shard = _greedy_case(seed=3)
    s_int, st_int = replicate_workload(
        ps, shard, 5, t=2, policy="nearest_copy"
    )
    s_slo, st_slo = replicate_workload(
        ps, shard, 5, t=SLOSpec.uniform(2, ps.n_queries),
        policy="nearest_copy",
    )
    np.testing.assert_array_equal(s_int.mask, s_slo.mask)
    assert st_int.routed_skips == st_slo.routed_skips
    assert st_int.pruned_replicas == st_slo.pruned_replicas


# ---------------------------------------------------------------------------
# hypothesis wrappers (optional): the same theorems over generated inputs
# ---------------------------------------------------------------------------
if HAVE_HYPOTHESIS:

    @st.composite
    def replicated_workloads(draw):
        n_obj = draw(st.integers(5, 40))
        n_srv = draw(st.integers(2, 6))
        n_paths = draw(st.integers(1, 20))
        seed = draw(st.integers(0, 2**31 - 1))
        rng = np.random.default_rng(seed)
        paths = [
            rng.integers(0, n_obj, rng.integers(1, 6)).tolist()
            for _ in range(n_paths)
        ]
        mask, shard = _replicated(rng, n_obj, n_srv, density=0.3)
        return PathSet.from_lists(paths), mask, shard, rng

    @settings(max_examples=25, deadline=None)
    @given(replicated_workloads())
    def test_hyp_optimal_dominates_and_monotone(wl):
        ps, mask, shard, rng = wl
        eng = LatencyEngine.from_arrays(mask.copy(), shard)
        h_opt = eng.path_latencies(ps, policy=nearest_copy_dp(None))
        for pol in ("home_first", "nearest_copy"):
            assert (h_opt <= eng.path_latencies(ps, policy=pol)).all()
        v = rng.integers(0, mask.shape[0], 10)
        s = rng.integers(0, mask.shape[1], 10)
        eng.add_replicas(v, s)
        h2 = eng.path_latencies(ps, policy=nearest_copy_dp(None))
        assert (h2 <= h_opt).all()

    @settings(max_examples=15, deadline=None)
    @given(replicated_workloads())
    def test_hyp_prune_preserves_feasibility(wl):
        ps, mask, shard, _ = wl
        scheme = ReplicationScheme(mask.copy(), shard)
        eng = LatencyEngine(scheme)
        h = eng.path_latencies(ps, policy="nearest_copy")
        t = int(h.max()) if len(h) else 0
        prune_scheme_replicas(scheme, ps, t, policy="nearest_copy")
        assert LatencyEngine(scheme).is_feasible(
            ps, t, policy="nearest_copy"
        )
