"""Workload analyzers + graph substrate coverage."""
import numpy as np
import pytest

from repro.graph import (
    CSRGraph,
    distributed_hops,
    hash_partition,
    hypergraph_partition,
    ldg_partition,
    minibatch_sampler,
    ogb_like,
    sample_neighborhood,
    snb_like,
)
from repro.workload import (
    gnn_workload_materialized,
    materialize,
    moe_workload_materialized,
    recsys_workload_materialized,
    snb_workload,
    snb_workload_materialized,
    trace_objects,
)


def test_csr_roundtrip():
    g = CSRGraph.from_edges(4, [0, 0, 1, 2], [1, 2, 2, 3])
    assert g.n_nodes == 4 and g.n_edges == 4
    assert g.neighbors(0).tolist() == [1, 2]
    src, dst = g.edge_list()
    assert len(src) == 4
    assert g.degree(0) == 2


def test_csr_dedup_and_symmetrize():
    g = CSRGraph.from_edges(3, [0, 0], [1, 1], symmetrize=True)
    assert g.n_edges == 2  # (0,1) + (1,0), duplicate removed


def test_generators_deterministic():
    a, b = snb_like(1, seed=7), snb_like(1, seed=7)
    assert a.graph.n_edges == b.graph.n_edges
    assert np.array_equal(a.graph.indices, b.graph.indices)
    c = snb_like(1, seed=8)
    assert not np.array_equal(a.graph.indices[:100], c.graph.indices[:100])


def test_partitioners_balance_and_cut():
    g = ogb_like(3000, seed=0)
    for part in (hash_partition(g.n_nodes, 4),
                 ldg_partition(g, 4, passes=1)):
        sizes = np.bincount(part, minlength=4)
        assert sizes.max() <= 1.2 * sizes.mean()
    cut_hash = g.subgraph_stats(hash_partition(g.n_nodes, 4))["cut_fraction"]
    cut_ldg = g.subgraph_stats(ldg_partition(g, 4, passes=1))["cut_fraction"]
    assert cut_ldg < cut_hash  # data-aware beats random


def test_hypergraph_partition_uses_traces():
    snb = snb_like(1, seed=0)
    ps = snb_workload_materialized(snb, n_queries=200, seed=0)
    traces = trace_objects(ps)
    part = hypergraph_partition(traces, snb.graph.n_nodes, 4, iters=2)
    assert part.shape == (snb.graph.n_nodes,)
    assert set(np.unique(part)) <= {0, 1, 2, 3}


def test_sampler_shapes_and_membership():
    g = ogb_like(2000, seed=1)
    mb = minibatch_sampler(g, np.arange(16), (5, 3), seed=0)
    assert mb.layer_nodes[0].shape == (16, 5)
    assert mb.layer_nodes[1].shape == (16, 15)
    # sampled hop-1 nodes are true neighbors of their seed
    for i in range(16):
        nbrs = set(g.neighbors(i).tolist())
        sampled = set(x for x in mb.layer_nodes[0][i].tolist() if x >= 0)
        assert sampled <= nbrs or not nbrs


def test_distributed_hops_counts():
    g = CSRGraph.from_edges(4, [0, 1], [1, 2])
    shard = np.asarray([0, 1, 0, 1], np.int32)
    rng = np.random.default_rng(0)
    fr = sample_neighborhood(g, 0, (2, 2), rng)
    hops = distributed_hops(fr, shard)
    assert hops >= 1  # 0 -> 1 crosses servers


def test_snb_workload_streaming_batches():
    snb = snb_like(1, seed=0)
    batches = list(snb_workload(snb, n_queries=300, seed=0,
                                batch_queries=100))
    assert len(batches) >= 3
    total = materialize(iter(batches))
    assert total.n_queries == 300


def test_gnn_workload_path_lengths():
    g = ogb_like(2000, seed=0)
    ps = gnn_workload_materialized(g, np.arange(20), (5, 3), seed=0)
    assert ps.max_len <= 3  # the paper: sampling needs <= 2 hops


def test_recsys_and_moe_workloads():
    ps = recsys_workload_materialized(100, 500, n_requests=50)
    assert ps.max_len <= 3
    assert ps.objects.max() < 600
    mp = moe_workload_materialized(16, 32, 4, n_queries=50)
    assert mp.max_len == 2  # 1-hop dispatch paths
    assert mp.objects[:, 1].min() >= 16  # experts offset past groups


def test_workload_latency_summary_slo_aware():
    """Streaming per-tenant slack/violation report (SLOSpec-aware)."""
    from repro.core import ReplicationScheme
    from repro.core.paths import PathSet
    from repro.core.slo import SLOSpec, TenantSpec
    from repro.workload import workload_latency_summary

    n_srv = 3
    shard = (np.arange(12) % n_srv).astype(np.int32)
    scheme = ReplicationScheme.from_sharding(shard, n_srv)
    # queries 0-1 tenant "a" (t=0), queries 2-3 tenant "b" (t=2)
    paths = [[0, 1], [3], [0, 1, 2], [6, 7, 8]]
    full = PathSet.from_lists(paths, query_ids=[0, 1, 2, 3])
    slo = SLOSpec.from_tenants(
        (TenantSpec("a", 0), TenantSpec("b", 2)),
        np.asarray([0, 0, 1, 1], np.int32),
    )
    # stream in two batches; the summary must consume budgets in order
    batches = [full.select_queries(0, 2), full.select_queries(2, 4)]
    out = workload_latency_summary(batches, scheme, slo=slo)
    a, b = out["per_tenant"]["a"], out["per_tenant"]["b"]
    # a: query 0 crosses one server boundary (h=1 > 0), query 1 is local
    assert (a["queries"], a["violations"]) == (2, 1)
    assert a["min_slack"] == -1
    # b: h=2 for both queries, within t=2
    assert (b["queries"], b["violations"]) == (2, 0)
    assert b["min_slack"] == 0
    assert out["feasible"] is False
    assert a["violation_frac"] == 0.5

    # scalar-t report unchanged by the refactor
    legacy = workload_latency_summary([full], scheme, t=2)
    assert legacy["feasible"] is True
    assert legacy["n_paths"] == 4

    # and the report can be scored under a routing policy
    nc = workload_latency_summary(batches, scheme, slo=slo,
                                  policy="nearest_copy")
    assert nc["per_tenant"]["a"]["violations"] <= a["violations"]
