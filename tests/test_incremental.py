"""Incremental dirty-set evaluation: index exactness, cache bit-identity,
transfer accounting, greedy/controller threading, forecast pre-warm."""
import numpy as np
import pytest

from repro.core import replicate_delta, replicate_workload
from repro.core.paths import PathSet
from repro.distsys import Cluster
from repro.engine import (
    TRANSFER,
    LatencyEngine,
    PathIndex,
    nearest_copy_dp,
    round_up_rows,
)
from repro.serve import AdaptiveController, ControllerConfig
from tests.conftest import random_workload

POLICIES = ("home_first", "nearest_copy", "queue_aware", nearest_copy_dp(2))
BACKENDS = ("reference", "jnp", "pallas")


def _engine(rng, backend, n_obj=100, n_srv=5, n_paths=120):
    ps, shard = random_workload(
        rng, n_obj=n_obj, n_srv=n_srv, n_paths=n_paths, n_queries=40
    )
    mask = np.zeros((n_obj, n_srv), bool)
    mask[np.arange(n_obj), shard] = True
    eng = LatencyEngine.from_arrays(mask, shard, backend=backend)
    return eng, ps


def _load_for(pol, rng, n_srv=5):
    name = getattr(pol, "name", pol)
    if name == "queue_aware":
        return rng.random(n_srv).astype(np.float32)
    return None


# ---------------------------------------------------------------------------
# PathIndex
# ---------------------------------------------------------------------------
def test_path_index_matches_bruteforce(rng):
    ps, shard = random_workload(rng, n_obj=60, n_paths=80)
    objects = np.asarray(ps.objects)
    idx = PathIndex(objects, 60)
    for v in range(60):
        expect = np.nonzero((objects == v).any(axis=1))[0]
        assert np.array_equal(idx.paths_of(v), expect)
    # multi-object union, with out-of-range ids ignored
    changed = rng.integers(-5, 70, 25)
    valid = changed[(changed >= 0) & (changed < 60)]
    expect = (
        np.unique(np.concatenate([idx.paths_of(int(v)) for v in valid]))
        if valid.size
        else np.zeros(0)
    )
    assert np.array_equal(idx.dirty_paths(changed), expect)
    assert idx.dirty_paths([]).size == 0
    assert idx.dirty_paths([-1, 65]).size == 0


def test_round_up_rows_quantum():
    from repro.engine.sharding import device_count

    q = 128 * device_count()
    assert round_up_rows(0) == q
    assert round_up_rows(1) == q
    assert round_up_rows(q) == q
    assert round_up_rows(q + 1) == 2 * q


# ---------------------------------------------------------------------------
# bit-identity: 4 policies x 3 backends x {add, remove, mixed}
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("pol", POLICIES, ids=lambda p: getattr(p, "name", p))
def test_incremental_bit_identity(rng, backend, pol):
    eng, ps = _engine(rng, backend)
    load = _load_for(pol, rng)

    def check():
        inc = eng.path_latencies(ps, policy=pol, load=load, incremental=True)
        full = eng.path_latencies(ps, policy=pol, load=load)
        assert np.array_equal(inc, full)

    check()  # cold seed
    # add delta
    eng.add_replicas(rng.integers(0, 100, 10), rng.integers(0, 5, 10))
    check()
    # remove delta (drop some of the replicas just added and some originals'
    # copies; removals are where a stale cache would over-report feasibility)
    ao = rng.integers(0, 100, 6)
    eng.add_replicas(ao, (np.asarray(eng.host_shard())[ao] + 1) % 5)
    eng.remove_replicas(ao[:3], (np.asarray(eng.host_shard())[ao[:3]] + 1) % 5)
    check()
    # mixed delta in one step
    eng.add_replicas(rng.integers(0, 100, 5), rng.integers(0, 5, 5))
    eng.remove_replicas(ao[3:], (np.asarray(eng.host_shard())[ao[3:]] + 1) % 5)
    check()


def test_incremental_slack_and_feasibility_budget_kinds(rng):
    from repro.core.slo import SLOSpec

    eng, ps = _engine(rng, "jnp")
    eng.path_latencies(ps, incremental=True)
    eng.add_replicas(rng.integers(0, 100, 8), rng.integers(0, 5, 8))
    vec = rng.integers(0, 4, ps.n_queries).astype(np.int32)
    slo = SLOSpec.uniform(2, ps.n_queries)
    for t in (1, vec, slo):
        s_inc = eng.query_slack(ps, t, incremental=True)
        s_full = eng.query_slack(ps, t)
        assert np.array_equal(s_inc, s_full)
        assert eng.is_feasible(ps, t, incremental=True) == eng.is_feasible(
            ps, t
        )


def test_queue_aware_load_gets_its_own_slot(rng):
    """queue_aware h depends on the load vector: two load profiles must
    not share a cached latency vector."""
    eng, ps = _engine(rng, "jnp")
    la = np.zeros(5, np.float32)
    lb = np.asarray([9.0, 0.0, 0.0, 0.0, 0.0], np.float32)
    eng.add_replicas(np.arange(100), np.full(100, 1))
    for load in (la, lb, la):
        inc = eng.path_latencies(
            ps, policy="queue_aware", load=load, incremental=True
        )
        full = eng.path_latencies(ps, policy="queue_aware", load=load)
        assert np.array_equal(inc, full)


# ---------------------------------------------------------------------------
# cache mechanics: no-op hits, transfer accounting, refresh
# ---------------------------------------------------------------------------
def test_empty_dirty_set_is_a_noop(rng):
    eng, ps = _engine(rng, "jnp")
    eng.path_latencies(ps, incremental=True)
    with TRANSFER.scope():
        h = eng.path_latencies(ps, incremental=True)  # clean hit
        assert TRANSFER.h2d_bytes == 0
        assert TRANSFER.gathered_bytes == 0
    # invalidating objects no windowed path touches must not re-walk either
    eng.note_changed([100_000])
    with TRANSFER.scope():
        h2 = eng.path_latencies(ps, incremental=True)
        assert TRANSFER.gathered_bytes == 0
    assert np.array_equal(h, h2)


def test_dirty_rewalk_books_gathered_bytes(rng):
    eng, ps = _engine(rng, "jnp")
    eng.path_latencies(ps, incremental=True)
    eng.add_replicas([int(np.asarray(ps.objects)[0, 0])], [0])
    with TRANSFER.scope():
        eng.path_latencies(ps, incremental=True)
        assert TRANSFER.gathered_bytes > 0
        # the compacted index vector is the payload: a subset of h2d
        assert TRANSFER.gathered_bytes <= TRANSFER.h2d_bytes
        # and far smaller than re-uploading the whole path block
        assert TRANSFER.h2d_bytes < np.asarray(ps.objects, np.int32).nbytes


def test_refresh_invalidates_everything(rng):
    eng, ps = _engine(rng, "jnp")
    eng.path_latencies(ps, incremental=True)
    # mutate the host mask directly (bypassing add_replicas), then refresh
    eng.scheme.mask[:, 2] = True
    eng.refresh()
    inc = eng.path_latencies(ps, incremental=True)
    assert np.array_equal(inc, eng.path_latencies(ps))


def test_dead_pathset_entries_are_purged(rng):
    eng, ps = _engine(rng, "jnp")
    eng.path_latencies(ps, incremental=True)
    dead = PathSet.from_lists([[0, 1], [2, 3]])
    eng.path_latencies(dead, incremental=True)
    assert len(eng.incremental.caches) == 2
    del dead
    eng.note_changed([0])  # invalidation sweep drops the dead weakref
    assert len(eng.incremental.caches) == 1


# ---------------------------------------------------------------------------
# hypothesis: random delta sequences
# ---------------------------------------------------------------------------
def test_random_delta_sequences_stay_identical(rng):
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import HealthCheck, given, settings, strategies as st

    eng_rng = np.random.default_rng(7)
    eng, ps = _engine(eng_rng, "jnp", n_obj=50, n_srv=4, n_paths=60)
    mask0 = eng.host_mask().copy()

    @settings(
        max_examples=20,
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow],
    )
    @given(
        ops=st.lists(
            st.tuples(
                st.booleans(),  # True = add, False = remove
                st.integers(0, 49),
                st.integers(0, 3),
            ),
            min_size=1,
            max_size=8,
        ),
        pol=st.sampled_from(["home_first", "nearest_copy"]),
    )
    def step(ops, pol):
        for add, v, s in ops:
            if add:
                eng.add_replicas([v], [s])
            elif eng.host_shard()[v] != s:  # never drop an original
                eng.remove_replicas([v], [s])
        inc = eng.path_latencies(ps, policy=pol, incremental=True)
        assert np.array_equal(inc, eng.path_latencies(ps, policy=pol))

    step()
    # restore (hypothesis mutated shared state by design: the cache must
    # have tracked every mutation, which is exactly what was asserted)
    eng.scheme.mask[:] = mask0
    eng.refresh()


# ---------------------------------------------------------------------------
# greedy threading
# ---------------------------------------------------------------------------
def test_replicate_delta_notifies_engine_cache(rng):
    ps, shard = random_workload(rng, n_obj=150, n_srv=5, n_paths=150)
    scheme, _, eng = replicate_workload(
        ps, shard, 5, t=2, return_engine=True
    )
    eng.path_latencies(ps, incremental=True)  # seed against the t=2 scheme
    extra, _ = random_workload(
        np.random.default_rng(3), n_obj=150, n_srv=5, n_paths=60
    )
    replicate_delta(extra, eng, 1)  # mutates packed.words inside jits
    inc = eng.path_latencies(ps, incremental=True)
    assert np.array_equal(inc, eng.path_latencies(ps))
    inc_x = eng.path_latencies(extra, incremental=True)
    assert np.array_equal(inc_x, eng.path_latencies(extra))


def test_routed_revalidation_dirty_scoped_matches_full(rng):
    from repro.core.greedy import (
        GreedyStats,
        _revalidate_routed,
        _routed_gate_fn,
    )
    from repro.engine.packed import PackedScheme
    from repro.engine.routing import resolve_policy

    # many objects / few paths: the violating paths' objects are rare
    # elsewhere, so the dirty set is a genuine subset of the workload
    n_obj = 400
    ps, shard = random_workload(rng, n_obj=n_obj, n_srv=4, n_paths=100)
    mask = np.zeros((n_obj, 4), bool)
    mask[np.arange(n_obj), shard] = True
    pol = resolve_policy("nearest_copy")
    # only the first 5 paths are over budget (t=0 vs a generous 10)
    t_path = np.full(ps.n_paths, 10, np.int64)
    t_path[:5] = 0

    def fake_update(packed):
        # fake UPDATE: replicate every object of the violating paths
        # everywhere (guaranteed repair; touches only those objects)
        def run_classes(sub, tp):
            o = np.asarray(sub.objects)
            o = o[o >= 0]
            for s in range(4):
                packed.add(o, np.full(len(o), s))

        return run_classes

    packed = PackedScheme.from_mask(mask, shard)
    fn = _routed_gate_fn(packed, pol, "jnp")
    assert (np.asarray(fn(
        np.asarray(ps.objects, np.int32), np.asarray(ps.lengths, np.int32)
    ))[:5] > 0).any()  # revalidation has something to do

    s_full = GreedyStats()
    _revalidate_routed(
        fn, ps, t_path, fake_update(packed), s_full, index=None
    )

    packed2 = PackedScheme.from_mask(mask, shard)
    fn2 = _routed_gate_fn(packed2, pol, "jnp")
    s_dirty = GreedyStats()
    _revalidate_routed(
        fn2, ps, t_path, fake_update(packed2), s_dirty,
        index=PathIndex(np.asarray(ps.objects), n_obj),
    )
    assert s_full.routed_violations == s_dirty.routed_violations == 0
    assert np.array_equal(packed.unpack(), packed2.unpack())
    assert s_dirty.revalidate_rows_saved > 0
    assert s_full.revalidate_rows_saved == 0


# ---------------------------------------------------------------------------
# controller threading + forecast pre-warm
# ---------------------------------------------------------------------------
def _drifted_setup(seed=0, n_obj=300, n_srv=5, queries=150):
    from tests.test_serve import synthetic_phases

    phases = synthetic_phases(
        n_phases=2, n_obj=n_obj, n_srv=n_srv, queries=queries, seed=seed
    )
    rng = np.random.default_rng(seed)
    shard = rng.integers(0, n_srv, n_obj).astype(np.int32)
    scheme, _, eng = replicate_workload(
        phases[0].pathset, shard, n_srv, t=1, return_engine=True
    )
    return phases, scheme, eng


def test_controller_incremental_recheck_is_bit_identical():
    """The controller's whole report stream must be unchanged by the
    dirty-set cache (same triggers, same bytes, same feasibility)."""
    outs = []
    for inc in (False, True):
        phases, scheme, eng = _drifted_setup(seed=5)
        ctl = AdaptiveController(
            Cluster(scheme),
            ControllerConfig(
                t=1, window=300, min_queries=20, incremental_recheck=inc
            ),
            engine=eng,
        )
        reports = []
        for _ in range(3):
            reports.append(ctl.observe(phases[1].pathset))
        outs.append(
            [
                (
                    r.trigger, r.paths_repaired, r.replicas_added,
                    r.bytes_added, r.feasible_after,
                )
                if r is not None
                else None
                for r in reports
            ]
        )
    assert outs[0] == outs[1]


def test_forecast_prewarm_shrinks_violation_window():
    """Satellite: feeding the next PhaseDelta as a forecast repairs ahead
    of the flip, so the violations a reactive-only controller serves
    through never land."""
    # reactive-only: the flip lands on the stale scheme and violates
    phases, scheme, eng = _drifted_setup(seed=9)
    flip = phases[1].pathset
    ctl = AdaptiveController(
        Cluster(scheme), ControllerConfig(t=1, window=400, min_queries=20),
        engine=eng,
    )
    pl = eng.path_latencies(flip, policy="home_first")
    ql = eng.query_latencies(flip, pl)
    reactive_bad = int((ql > 1).sum())
    assert reactive_bad > 0  # drift actually violates pre-repair
    r = ctl.observe(flip)
    assert r is not None and r.trigger == "feasibility"

    # forecast-fed: same starting point, but the delta is announced while
    # phase 0 is still being served
    phases, scheme, eng = _drifted_setup(seed=9)
    ctl = AdaptiveController(
        Cluster(scheme), ControllerConfig(t=1, window=400, min_queries=20),
        engine=eng,
    )
    r0 = ctl.observe(phases[0].pathset, forecast=flip)
    assert r0 is not None and r0.trigger == "forecast"
    assert r0.replicas_added > 0 and r0.feasible_after
    # the flip arrives against the pre-warmed scheme: no violations land
    ql = eng.query_latencies(flip, eng.path_latencies(flip))
    forecast_bad = int((ql > 1).sum())
    assert forecast_bad == 0 < reactive_bad
    # and the reactive loop stays quiet (nothing to repair)
    assert ctl.observe(flip) is None
    # a feasible forecast is a cheap no-op, not a repair
    r2 = ctl.observe(phases[0].pathset, forecast=flip)
    assert r2 is not None and r2.trigger == "forecast"
    assert r2.replicas_added == 0


# ---------------------------------------------------------------------------
# benchmark wall-clock guard (tier-1 runs the default grid point)
# ---------------------------------------------------------------------------
def test_default_grid_point_within_budget():
    import time

    from benchmarks.incremental_eval import DEFAULT_BUDGET_S, default_grid_point

    t0 = time.perf_counter()
    fam = default_grid_point(smoke=True)
    secs = time.perf_counter() - t0
    assert fam["bit_identical"]
    assert fam["repairs"] >= 1
    assert secs < DEFAULT_BUDGET_S, (
        f"default grid point took {secs:.1f}s (budget {DEFAULT_BUDGET_S}s)"
    )
